//! `zen` — leader CLI for the synchronization runtime.
//!
//! Subcommands:
//!   sim     simulate data-parallel training on a Table-1 workload
//!   train   really train the embedding LM through the AOT stack
//!   worker  one rank of a two-process sync over real sockets
//!   check   model-check the protocol layer over all delivery orders
//!   schemes list schemes and their Table-2 dimensions
//!
//! Examples:
//!   zen sim --model DeepFM --machines 16 --scheme zen --link tcp25
//!   zen sim --model DeepFM --machines 16 --scheme auto --pipeline
//!   zen sim --model DeepFM --scheme auto --topology 4x2:2,300/50,25
//!   zen sim --model LSTM --machines 16 --scheme zen --pipeline --bucket-kb 256
//!   zen sim --model LSTM --scheme zen --pipeline --priority-schedule --partition-threshold 128
//!   zen sim --model DeepFM --machines 8 --scheme zen --transport channel
//!   zen sim --model DeepFM --machines 4 --gpus 1 --scale 2048 --transport socket
//!   zen sim --machines 1024 --gpus 1 --transport event --topology 32x32 --scheme auto
//!   zen train --shape tiny --workers 4 --scheme auto --steps 50
//!   zen worker --listen 127.0.0.1:4700 --scheme zen   # terminal 1
//!   zen worker --connect 127.0.0.1:4700 --scheme zen  # terminal 2
//!   zen check --all --machines 2,3
//!   zen check --scheme zen --machines 3 --replay "1>0,2>0"
//!   zen schemes
//!
//! `--scheme auto` hands scheme choice to the cost-model planner: each
//! bucket's sparsity is measured, the Appendix-B cost model ranks all
//! seven lossless schemes, and the argmin runs — with the per-bucket
//! plan (predicted vs transport-measured time) printed so a
//! misprediction is visible. `--replan-threshold R` tunes the density
//! hysteresis (default 0.25).
//!
//! `--topology NxG[:ia,ib/ea,eb]` replaces the flat mesh with a
//! two-level cluster: N nodes × G ranks, per-link-class α–β (each pair
//! as latency_µs,Gbps — intra then inter; defaults NVLink / `--link`).
//! Every rank becomes a fabric endpoint, co-located frames ride the
//! intra link, the planner prices candidates per class, and the plan
//! table reports predicted vs measured time per link class.

use zen::cluster::LinkKind;
use zen::config::Args;
use zen::coordinator::lm::{LmConfig, LmTrainer};
use zen::coordinator::{PipelineConfig, SimConfig, SimDriver};
use zen::wire::TransportKind;
use zen::workload::profiles;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("sim") => cmd_sim(&args),
        Some("train") => cmd_train(&args),
        Some("worker") => cmd_worker(&args),
        Some("check") => cmd_check(&args),
        Some("schemes") => cmd_schemes(),
        _ => {
            eprintln!(
                "usage: zen <sim|train|worker|check|schemes> [--options]\n\
                 sim:    --model LSTM|DeepFM|NMT|BERT --machines N --scheme S|auto\n\
                         --link tcp25|rdma100 --transport sim|channel|socket|event|threaded\n\
                         --topology NxG[:ia,ib/ea,eb] (two-level cluster)\n\
                         --replan-threshold R (auto hysteresis, default 0.25)\n\
                         --compress topk:K|threshold:T|none (error-feedback lossy tier)\n\
                         --accuracy-budget B (arms the auto planner's lossy tier)\n\
                         --pipeline --bucket-kb N --priority-schedule (first-needed-first)\n\
                         --partition-threshold KB (split oversized buckets; 0 = off)\n\
                 train:  --shape tiny|paper_100m --workers N --scheme S|auto --steps N\n\
                         --transport sim|channel|socket|event|threaded --topology NxG\n\
                         --replan-threshold R --compress topk:K|threshold:T|none\n\
                         --accuracy-budget B (lossy runs also report the loss delta)\n\
                 worker: --listen ADDR | --connect ADDR (one rank per process)\n\
                         --scheme S --dense-len N --shared N --private N --seed N\n\
                 check:  --all | --scheme S  --machines 2,3 (comma list of group sizes)\n\
                         --dense-len N --shared N --private N --seed N\n\
                         --max-runs N (schedule budget; exhaustive within it)\n\
                         --json PATH (exploration stats) --replay \"src>dst,...\""
            );
            Ok(())
        }
    }
}

/// One rank of a two-process synchronization: the listener is rank 0,
/// the connector rank 1. Both processes derive the *same* pair of
/// sparse gradients from `--seed` (a shared hot set plus per-rank
/// private tails), so the protocol runs over real sockets without any
/// out-of-band gradient shipping, and both sides can independently
/// verify they produced the identical aggregate.
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    use zen::cluster::Network;
    use zen::schemes::{SyncScheme, SyncScratch};
    use zen::wire::WorkerDriver;

    let scheme_name = args.get_or("scheme", "zen");
    let dense_len = args.get_usize("dense-len", 100_000);
    let shared = args.get_usize("shared", 1_500);
    let private = args.get_usize("private", 500);
    let seed = args.get_u64("seed", 0x2e2);
    let link = args.link("link", LinkKind::Tcp25);
    let net = Network::new(2, link);
    let mut driver = match (args.get("listen"), args.get("connect")) {
        (Some(addr), None) => WorkerDriver::listen(addr, net)?,
        (None, Some(addr)) => WorkerDriver::connect(addr, net)?,
        _ => anyhow::bail!("worker needs exactly one of --listen ADDR or --connect ADDR"),
    };
    let rank = driver.rank();
    let inputs = zen::check::gen_inputs(seed, 2, dense_len, shared, private);
    let expected_nnz = shared + private;
    let scheme = zen::schemes::by_name(scheme_name, 2, seed ^ 0x5eed, expected_nnz)
        .ok_or_else(|| anyhow::anyhow!("unknown scheme '{scheme_name}'"))?;
    let sync = scheme.run(&inputs, &mut driver, &mut SyncScratch::new())?;
    println!(
        "rank={rank} scheme={} bytes={} digest={:016x}",
        scheme.name(),
        sync.report.total_bytes(),
        // The same FNV-1a fingerprint the model checker compares across
        // delivery orders; both processes print it for a cross-process
        // bit-identity check.
        zen::check::fnv_digest(&sync.outputs[rank]),
    );
    Ok(())
}

/// Model-check the protocol layer: explore every frame-delivery order
/// (exhaustive at n ∈ {2,3}, bounded by `--max-runs` beyond) and assert
/// the invariant set on each — no deadlock, byte conservation per
/// stage, bit-identical outputs across orders, losslessness vs the
/// dense-sum oracle. A violation prints a minimized schedule that
/// `--replay` re-executes deterministically. Exits nonzero on any
/// violation so CI can gate on it.
fn cmd_check(args: &Args) -> anyhow::Result<()> {
    use zen::check;

    let dense_len = args.get_usize("dense-len", 64);
    let shared = args.get_usize("shared", 6);
    let private = args.get_usize("private", 3);
    let seed = args.get_u64("seed", 7);
    let max_runs = args.get_usize("max-runs", check::DEFAULT_MAX_RUNS);
    let machines: Vec<usize> = args
        .get_or("machines", "2,3")
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse::<usize>()
                .map_err(|e| anyhow::anyhow!("bad --machines entry '{t}': {e}"))
        })
        .collect::<Result<_, _>>()?;
    if machines.iter().any(|&n| n < 2) {
        anyhow::bail!("--machines entries must be >= 2");
    }

    let make_scheme = |name: &str, n: usize, inputs: &[zen::tensor::CooTensor]| {
        let expected_nnz = inputs.iter().map(|t| t.indices.len()).sum::<usize>() / n.max(1);
        zen::schemes::by_name(name, n, seed ^ 0x5eed, expected_nnz)
            .ok_or_else(|| anyhow::anyhow!("unknown scheme '{name}'"))
    };

    // --replay: re-run one explicit schedule under the full invariant
    // set instead of exploring.
    if let Some(spec) = args.get("replay") {
        let name = args.get_or("scheme", "zen");
        let n = machines.first().copied().unwrap_or(3);
        let schedule = check::parse_schedule(spec).map_err(|e| anyhow::anyhow!(e))?;
        let inputs = check::gen_inputs(seed, n, dense_len, shared, private);
        let scheme = make_scheme(name, n, &inputs)?;
        let lossless = !name.starts_with("strawman");
        let (violation, record) =
            check::replay_schedule(scheme.as_ref(), &inputs, lossless, None, &schedule);
        match violation {
            Some(v) => {
                println!("replay {name} n={n}: VIOLATION [{}] {v}", v.kind());
                println!("  schedule: {}", zen::wire::schedule_string(&record.schedule()));
                std::process::exit(1);
            }
            None => {
                println!(
                    "replay {name} n={n}: clean ({} deliveries, {} stages)",
                    record.trace.len(),
                    record.boundaries.len()
                );
                return Ok(());
            }
        }
    }

    let targets: Vec<(String, bool)> = match args.get("scheme") {
        Some(name) => vec![(name.to_string(), !name.starts_with("strawman"))],
        None => check::CHECK_SCHEMES
            .iter()
            .map(|&(n, l)| (n.to_string(), l))
            .collect(),
    };

    let t0 = std::time::Instant::now();
    let mut reports = Vec::new();
    let mut failed = false;
    for (name, lossless) in &targets {
        for &n in &machines {
            let inputs = check::gen_inputs(seed, n, dense_len, shared, private);
            let scheme = make_scheme(name, n, &inputs)?;
            let r = check::check_scheme(scheme.as_ref(), &inputs, *lossless, max_runs);
            let status = match (&r.failure, r.stats.truncated) {
                (Some(_), _) => "VIOLATION",
                (None, true) => "truncated",
                (None, false) => "exhaustive",
            };
            println!(
                "{name:<14} n={n}  runs {:<6} deliveries {:<8} states {:<6} pruned {:<5} \
                 frontier {:<4} {status}",
                r.stats.runs,
                r.stats.deliveries,
                r.stats.distinct_states,
                r.stats.pruned,
                r.stats.max_frontier
            );
            if let Some(f) = &r.failure {
                failed = true;
                println!("  violation [{}]: {}", f.violation.kind(), f.violation);
                println!(
                    "  minimized schedule ({} deliveries): {}",
                    f.schedule.len(),
                    f.replay_arg()
                );
                println!(
                    "  replay: zen check --scheme {name} --machines {n} --seed {seed} \
                     --dense-len {dense_len} --shared {shared} --private {private} \
                     --replay \"{}\"",
                    f.replay_arg()
                );
            }
            reports.push(r);
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    if let Some(path) = args.get("json") {
        std::fs::write(path, check::suite_json(&reports, elapsed))?;
        println!("wrote exploration stats to {path}");
    }
    let states: usize = reports.iter().map(|r| r.stats.distinct_states).sum();
    let runs: usize = reports.iter().map(|r| r.stats.runs).sum();
    println!(
        "checked {} scheme×n combinations: {runs} schedules, {states} distinct states, \
         {:.2}s ({:.0} states/s)",
        reports.len(),
        elapsed,
        if elapsed > 0.0 { states as f64 / elapsed } else { 0.0 }
    );
    if failed {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let args = &args.clone().maybe_load_config("run")?;
    let model = args.get_or("model", "DeepFM");
    let profile = profiles::by_name(model)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model}' (LSTM|DeepFM|NMT|BERT)"))?;
    let mut cfg = SimConfig::new(
        profile,
        args.get_usize("machines", 16),
        args.get_or("scheme", "zen"),
    );
    cfg.link = args.link("link", LinkKind::Tcp25);
    cfg.iterations = args.get_usize("iters", 4);
    cfg.scale = args.get_usize("scale", 64);
    cfg.gpus_per_machine = args.get_usize("gpus", 8);
    cfg.seed = args.get_u64("seed", 0xbeef);
    cfg.transport = args.transport("transport", TransportKind::Sim)?;
    cfg.replan_threshold = args.ratio("replan-threshold", cfg.replan_threshold)?;
    cfg.compress = args.compress("compress")?;
    cfg.accuracy_budget = args.accuracy_budget("accuracy-budget", 0.0)?;
    if let Some(t) = args.topology("topology", cfg.link)? {
        // The topology defines the fabric: machines/gpus follow it so
        // throughput and reporting stay consistent.
        cfg.machines = t.nodes;
        cfg.gpus_per_machine = t.ranks_per_node;
        cfg.topology = Some(t);
    }
    // `--pipeline` may arrive as a bare flag or as `--pipeline=<bool>`;
    // an explicit false wins over the sub-option shorthands.
    let pipeline_requested = match args.get("pipeline") {
        Some(v) => !matches!(v.to_ascii_lowercase().as_str(), "false" | "0" | "no" | "off"),
        None => {
            args.has_flag("pipeline")
                || args.has_flag("priority-schedule")
                || ["bucket-kb", "dense-layers", "emb-shards", "partition-threshold"]
                    .iter()
                    .any(|k| args.get(k).is_some())
        }
    };
    if pipeline_requested {
        let d = PipelineConfig::default();
        // `--priority-schedule` may arrive bare or as `=<bool>`.
        let priority_schedule = match args.get("priority-schedule") {
            Some(v) => !matches!(v.to_ascii_lowercase().as_str(), "false" | "0" | "no" | "off"),
            None => args.has_flag("priority-schedule"),
        };
        // `--partition-threshold KB`; 0 (the default) disables.
        let partition_kb = args.get_usize("partition-threshold", 0);
        cfg.pipeline = Some(PipelineConfig {
            bucket_bytes: args.get_usize("bucket-kb", d.bucket_bytes / 1024) * 1024,
            dense_layers: args.get_usize("dense-layers", d.dense_layers),
            emb_shards: args.get_usize("emb-shards", d.emb_shards),
            priority_schedule,
            partition_bytes: if partition_kb == 0 {
                usize::MAX
            } else {
                partition_kb * 1024
            },
        });
    }
    let r = SimDriver::new(cfg.clone())?.run();
    println!(
        "model={} machines={} gpus/machine={} scheme={} transport={}",
        cfg.profile.name,
        cfg.machines,
        cfg.gpus_per_machine,
        r.scheme,
        cfg.transport.name()
    );
    if let Some(t) = &cfg.topology {
        println!("  topology {}", t.describe());
    }
    // In engine mode the first column is all-bucket communication (it
    // includes dense layers folded into buckets), not embedding-only.
    let sync_label = if cfg.pipeline.is_some() {
        "bucket-comm"
    } else {
        "emb-sync"
    };
    println!(
        "  {sync_label} {:.2}ms  mlp-sync {:.2}ms  intra {:.2}ms  compute {:.0}ms",
        r.emb_sync_mean * 1e3,
        r.mlp_sync_time * 1e3,
        r.intra_time * 1e3,
        r.compute_time * 1e3
    );
    if !r.push_imbalance.is_empty() {
        println!(
            "  push-imbalance {:.3}  pull-imbalance {:.3}",
            r.push_imbalance.iter().sum::<f64>() / r.push_imbalance.len() as f64,
            r.pull_imbalance.iter().sum::<f64>() / r.pull_imbalance.len() as f64
        );
    }
    if let (Some(ser), Some(over)) = (r.engine_serialized, r.engine_overlapped) {
        let fwd = r
            .engine_forward_finish
            .map(|f| format!("  fwd-finish {:.2}ms", f * 1e3))
            .unwrap_or_default();
        println!(
            "  pipeline: serialized {:.2}ms  overlapped {:.2}ms  ({:.2}x from overlap){fwd}",
            ser * 1e3,
            over * 1e3,
            ser / over
        );
    }
    // The executed synchronization plan: one row per bucket with the
    // chosen scheme and predicted vs transport-measured time, so
    // cost-model mispredictions are printed, not hidden. Fixed schemes
    // predict nothing — their output stays exactly as before the
    // planner existed.
    if r.plan.iter().any(|p| p.predicted.is_some() || p.lossy) {
        println!("  plan:");
        let two_level = cfg.topology.as_ref().map(|t| !t.is_flat()).unwrap_or(false);
        for p in &r.plan {
            // A degenerate ratio (nothing predicted, or either side
            // zero) prints as `n/a`, never an inf/NaN.
            let mis = p
                .misprediction()
                .map(|m| format!("(x{m:.2})"))
                .unwrap_or_else(|| "(n/a)".to_string());
            // Lossy rows carry the compressor and the lossless
            // baseline the budget bought its way past.
            let lossy_tag = match (&p.compressor, p.predicted_lossless) {
                (Some(c), Some(base)) => {
                    format!("  lossy[{c}] vs lossless {:.3}ms", base * 1e3)
                }
                (Some(c), None) => format!("  lossy[{c}]"),
                _ => String::new(),
            };
            match p.predicted {
                Some(pred) => println!(
                    "    {:<14} {:<12} predicted {:>8.3}ms  measured {:>8.3}ms  {mis}{lossy_tag}",
                    p.label,
                    p.scheme,
                    pred * 1e3,
                    p.measured * 1e3,
                ),
                None => println!(
                    "    {:<14} {:<12} measured {:>8.3}ms{lossy_tag}",
                    p.label,
                    p.scheme,
                    p.measured * 1e3
                ),
            }
            // Per-link-class split: the predicted-vs-measured row for
            // each physical link of the two-level cluster.
            if two_level {
                let [m_intra, m_inter] = p.measured_by_class;
                match p.predicted_by_class {
                    Some([p_intra, p_inter]) => println!(
                        "      intra predicted {:>8.3}ms measured {:>8.3}ms | \
                         inter predicted {:>8.3}ms measured {:>8.3}ms",
                        p_intra * 1e3,
                        m_intra * 1e3,
                        p_inter * 1e3,
                        m_inter * 1e3
                    ),
                    None => println!(
                        "      intra measured {:>8.3}ms | inter measured {:>8.3}ms",
                        m_intra * 1e3,
                        m_inter * 1e3
                    ),
                }
            }
        }
    }
    if r.bytes_saved > 0 {
        println!(
            "  compression [{}] saved {:.2} MB on the wire (full scale)",
            cfg.compress.label(),
            r.bytes_saved as f64 / 1e6
        );
    }
    println!("  throughput {:.0} samples/s", r.throughput);
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let args = &args.clone().maybe_load_config("train")?;
    let mut cfg = match args.get_or("shape", "tiny") {
        "paper_100m" | "100m" => LmConfig::paper_100m(),
        _ => LmConfig::tiny(),
    };
    cfg.lr = args.get_f64("lr", cfg.lr as f64) as f32;
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.replan_threshold = args.ratio("replan-threshold", cfg.replan_threshold)?;
    cfg.compress = args.compress("compress")?;
    cfg.accuracy_budget = args.accuracy_budget("accuracy-budget", 0.0)?;
    let steps = args.get_usize("steps", 50);
    let scheme = args.get_or("scheme", "zen");
    let link = args.link("link", LinkKind::Tcp25);
    let transport = args.transport("transport", TransportKind::Sim)?;
    // `--topology NxG` overrides `--workers`: one worker per rank.
    let topo = match args.topology("topology", link)? {
        Some(t) => t,
        None => zen::cluster::Topology::flat(args.get_usize("workers", 4), link),
    };
    let workers = topo.endpoints();
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    println!(
        "training {}×{} embedding ({} params) + MLP, {} workers ({}), scheme={}, transport={}",
        cfg.vocab,
        cfg.dim,
        cfg.emb_params() + cfg.mlp_params(),
        workers,
        topo.describe(),
        scheme,
        transport.name()
    );
    let mut t = LmTrainer::builder(cfg.clone())
        .scheme(scheme)
        .topology(topo.clone())
        .transport(transport)
        .artifacts_dir(&artifacts)
        .build()?;
    let log = t.run(steps, args.get_usize("log-every", 10), true)?;
    let final_loss = log.losses.last().copied().unwrap_or(f32::NAN);
    println!(
        "done: final loss {:.4}, total emb comm {:.1}ms (virtual), compute {:.1}s (wall), \
         wire {:.2} MB",
        final_loss,
        log.emb_comm_total * 1e3,
        log.compute_wall_total,
        log.comm_bytes_total as f64 / 1e6
    );
    // Lossy runs replay the identical data lossless so the loss delta
    // is printed next to the bytes the compressor saved — the
    // accuracy-vs-volume trade the budget authorized.
    if cfg.compress.is_active() && log.lossy_steps > 0 {
        let mut base_cfg = cfg.clone();
        base_cfg.compress = zen::compress::CompressSpec::None;
        base_cfg.accuracy_budget = 0.0;
        let mut base = LmTrainer::builder(base_cfg)
            .scheme(scheme)
            .topology(topo)
            .transport(transport)
            .artifacts_dir(&artifacts)
            .build()?;
        let base_log = base.run(steps, 0, false)?;
        let base_loss = base_log.losses.last().copied().unwrap_or(f32::NAN);
        let delta = final_loss - base_loss;
        let saved = base_log.comm_bytes_total.saturating_sub(log.comm_bytes_total);
        println!(
            "lossy [{}]: loss delta {delta:+.4} vs lossless {base_loss:.4} \
             (budget {}), saved {:.2} MB ({:.1}x less wire), lossy steps {}/{steps}",
            cfg.compress.label(),
            cfg.accuracy_budget,
            saved as f64 / 1e6,
            base_log.comm_bytes_total as f64 / log.comm_bytes_total.max(1) as f64,
            log.lossy_steps
        );
        if cfg.accuracy_budget > 0.0 && delta as f64 > cfg.accuracy_budget {
            println!(
                "warning: loss delta {delta:+.4} exceeds --accuracy-budget {}",
                cfg.accuracy_budget
            );
        }
    }
    Ok(())
}

fn cmd_schemes() -> anyhow::Result<()> {
    use zen::schemes::SyncScheme;
    println!(
        "{:<12} {:<14} {:<12} {:<15} {:<14} format",
        "scheme", "communication", "aggregation", "partition", "balance"
    );
    for s in zen::schemes::all_schemes(4, 0, 1024) {
        let d = s.dims();
        println!(
            "{:<12} {:<14} {:<12} {:<15} {:<14} {}",
            s.name(),
            format!("{:?}", d.communication),
            format!("{:?}", d.aggregation),
            format!("{:?}", d.partition),
            format!("{:?}", d.balance),
            d.format
        );
    }
    Ok(())
}
