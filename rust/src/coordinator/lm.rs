//! Real training of the embedding language model through the AOT stack.
//!
//! The model is skip-gram-with-negative-sampling plus an MLP projection
//! (the Table-1 model class: a huge embedding table + a small dense
//! head). The compute graph lives in `python/compile/model.py` (L2,
//! calling the L1 Pallas matmul kernel) and is exported once per shape
//! to `artifacts/train_step_b{B}_k{K}_d{D}_h{H}.hlo.txt`; this module
//! executes it via PJRT, owns the parameter state, builds the sparse
//! embedding gradients, synchronizes them with the scheme its
//! [`Planner`] picks (fixed by name, or cost-model-driven via
//! `--scheme auto`), and applies SGD.
//!
//! Crucially the HLO step only touches *gathered rows* — vocabulary size
//! is a rust-side concern — so one artifact serves any table size, and
//! the embedding gradient is natively sparse (exactly the paper's
//! setting).

use std::collections::HashMap;

use anyhow::{Context, Result};

use super::sgd;
use crate::cluster::{LinkKind, Network, Topology};
use crate::planner::{self, PlanConfig, Planner};
use crate::runtime::{lit, Executable, Runtime};
use crate::schemes::{SyncScheme, SyncScratch};
use crate::tensor::CooTensor;
use crate::util::{Pcg64, Zipf};
use crate::wire::{Driver, TransportKind};

/// Model/shape configuration. Must match an exported artifact.
#[derive(Clone, Debug)]
pub struct LmConfig {
    pub vocab: usize,
    pub dim: usize,
    pub hidden: usize,
    pub batch: usize,
    pub negatives: usize,
    pub zipf_theta: f64,
    pub lr: f32,
    pub seed: u64,
    /// Density-drift hysteresis for `--scheme auto` (see
    /// [`PlanConfig::replan_threshold`]; ignored by fixed schemes).
    pub replan_threshold: f64,
    /// Lossy gradient compression (`zen train --compress
    /// topk:K|threshold:T|none`). Fixed schemes compress every step;
    /// `--scheme auto` compresses only the steps whose lossy plan beat
    /// the best lossless prediction under a positive `accuracy_budget`.
    pub compress: crate::compress::CompressSpec,
    /// Tolerated final-loss degradation that arms the planner's lossy
    /// tier (`--accuracy-budget B`; 0 keeps `auto` lossless).
    pub accuracy_budget: f64,
}

impl LmConfig {
    /// Tiny shape for tests (exported by `make artifacts` alongside the
    /// big one).
    pub fn tiny() -> Self {
        LmConfig {
            vocab: 2_048,
            dim: 32,
            hidden: 64,
            batch: 64,
            negatives: 4,
            zipf_theta: 1.05,
            lr: 0.3,
            seed: 0x11,
            replan_threshold: PlanConfig::default().replan_threshold,
            compress: crate::compress::CompressSpec::None,
            accuracy_budget: 0.0,
        }
    }

    /// ~100M-parameter configuration for the end-to-end example:
    /// 196,608 × 512 embedding (100.7M) + MLP head.
    pub fn paper_100m() -> Self {
        LmConfig {
            vocab: 196_608,
            dim: 512,
            hidden: 512,
            batch: 256,
            negatives: 8,
            zipf_theta: 1.05,
            lr: 0.3,
            seed: 0x100,
            replan_threshold: PlanConfig::default().replan_threshold,
            compress: crate::compress::CompressSpec::None,
            accuracy_budget: 0.0,
        }
    }

    /// Artifact stem for this shape.
    pub fn artifact_stem(&self) -> String {
        format!(
            "train_step_b{}_k{}_d{}_h{}",
            self.batch, self.negatives, self.dim, self.hidden
        )
    }

    pub fn emb_params(&self) -> usize {
        self.vocab * self.dim
    }

    pub fn mlp_params(&self) -> usize {
        self.dim * self.hidden + self.hidden + self.hidden * self.dim + self.dim
    }
}

/// Per-iteration training statistics.
#[derive(Clone, Debug)]
pub struct StepStats {
    pub loss: f32,
    /// Display name of the scheme that synchronized this step's
    /// embedding gradients (constant for fixed schemes; `--scheme auto`
    /// may re-plan when the measured density drifts).
    pub scheme: &'static str,
    /// Virtual network time for the embedding sync this step.
    pub emb_comm_time: f64,
    /// Virtual network time for the dense MLP allreduce.
    pub mlp_comm_time: f64,
    /// Wall-clock compute time (PJRT execution, all workers).
    pub compute_wall: f64,
    /// Wall-clock scheme overhead (hashing etc., from the report).
    pub scheme_overhead: f64,
    /// Wire volume of this step's embedding sync inputs (COO entries ×
    /// 8 bytes) — after compression when the lossy tier fired.
    pub comm_bytes: u64,
    /// Whether this step synchronized compressed gradients.
    pub lossy: bool,
}

/// Accumulated log of a run.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    pub accuracies: Vec<(usize, f64)>, // (step, eval accuracy)
    pub emb_comm_total: f64,
    pub mlp_comm_total: f64,
    pub compute_wall_total: f64,
    /// Total embedding-sync wire volume across the run (bytes; see
    /// [`StepStats::comm_bytes`]).
    pub comm_bytes_total: u64,
    /// Steps that synchronized compressed gradients.
    pub lossy_steps: usize,
}

/// The trainer.
pub struct LmTrainer {
    pub cfg: LmConfig,
    pub workers: usize,
    exe: Executable,
    /// Chooses the embedding-sync scheme per step: fixed for a named
    /// scheme, cost-model-driven for `auto`.
    planner: Box<dyn Planner>,
    net: Network,
    // Parameters (replicated across data-parallel workers → stored once).
    pub embedding: Vec<f32>,
    pub w1: Vec<f32>, // (D, H) row-major
    pub b1: Vec<f32>, // (H,)
    pub w2: Vec<f32>, // (H, D)
    pub b2: Vec<f32>, // (D,)
    zipf: Zipf,
    step_count: u64,
    /// Reused sync working memory — steps after the first reuse the
    /// warmed partition/payload buffers (scratch-arena layer).
    scratch: SyncScratch,
    /// Data plane the scheme's protocols run over, built once per
    /// trainer (a socket mesh persists across steps).
    driver: Box<dyn Driver>,
    /// Lossy compressor (error-feedback residuals live across steps);
    /// `None` when `cfg.compress` is inactive.
    compressor: Option<Box<dyn crate::compress::Compressor>>,
}

/// Validating builder for [`LmTrainer`]: collect the knobs, check them
/// all at [`build`](LmTrainerBuilder::build), and get one combined
/// error instead of the first panic or piecemeal `ensure!`.
pub struct LmTrainerBuilder {
    cfg: LmConfig,
    scheme: String,
    topo: Topology,
    transport: TransportKind,
    artifacts_dir: std::path::PathBuf,
}

impl LmTrainerBuilder {
    pub fn scheme(mut self, name: &str) -> Self {
        self.scheme = name.to_string();
        self
    }

    pub fn topology(mut self, topo: Topology) -> Self {
        self.topo = topo;
        self
    }

    pub fn workers(mut self, workers: usize, link: LinkKind) -> Self {
        self.topo = Topology::flat(workers, link);
        self
    }

    pub fn transport(mut self, t: TransportKind) -> Self {
        self.transport = t;
        self
    }

    pub fn artifacts_dir(mut self, dir: &std::path::Path) -> Self {
        self.artifacts_dir = dir.to_path_buf();
        self
    }

    pub fn replan_threshold(mut self, t: f64) -> Self {
        self.cfg.replan_threshold = t;
        self
    }

    pub fn compress(mut self, spec: crate::compress::CompressSpec) -> Self {
        self.cfg.compress = spec;
        self
    }

    pub fn accuracy_budget(mut self, b: f64) -> Self {
        self.cfg.accuracy_budget = b;
        self
    }

    pub fn build(self) -> Result<LmTrainer> {
        let mut problems = Vec::new();
        if self.topo.endpoints() == 0 {
            problems.push("topology must place at least one worker".to_string());
        }
        if !(0.0..=1.0).contains(&self.cfg.replan_threshold) {
            problems.push(format!(
                "replan threshold {} outside [0, 1]",
                self.cfg.replan_threshold
            ));
        }
        if !self.cfg.accuracy_budget.is_finite() || self.cfg.accuracy_budget < 0.0 {
            problems.push(format!(
                "accuracy budget {} must be a finite non-negative number",
                self.cfg.accuracy_budget
            ));
        }
        if !problems.is_empty() {
            anyhow::bail!("{}", problems.join("; "));
        }
        LmTrainer::with_topology(
            self.cfg,
            &self.scheme,
            self.topo,
            self.transport,
            &self.artifacts_dir,
        )
    }
}

impl LmTrainer {
    /// Start a validating builder (defaults: scheme `zen`, 4 flat
    /// Tcp25 workers, sim transport, `artifacts/`).
    pub fn builder(cfg: LmConfig) -> LmTrainerBuilder {
        LmTrainerBuilder {
            cfg,
            scheme: "zen".to_string(),
            topo: Topology::flat(4, LinkKind::Tcp25),
            transport: TransportKind::Sim,
            artifacts_dir: std::path::PathBuf::from("artifacts"),
        }
    }

    /// Construct with the default virtual-time transport.
    pub fn new(
        cfg: LmConfig,
        workers: usize,
        scheme_name: &str,
        link: LinkKind,
        artifacts_dir: &std::path::Path,
    ) -> Result<Self> {
        Self::with_transport(
            cfg,
            workers,
            scheme_name,
            link,
            TransportKind::Sim,
            artifacts_dir,
        )
    }

    /// Construct with an explicit transport backend
    /// (`zen train --transport sim|channel|socket`) on a flat network.
    pub fn with_transport(
        cfg: LmConfig,
        workers: usize,
        scheme_name: &str,
        link: LinkKind,
        transport: TransportKind,
        artifacts_dir: &std::path::Path,
    ) -> Result<Self> {
        Self::with_topology(
            cfg,
            scheme_name,
            Topology::flat(workers, link),
            transport,
            artifacts_dir,
        )
    }

    /// Construct on an explicit topology (`zen train --topology NxG`):
    /// one worker per rank, per-link-class α–β accounting, and a
    /// planner that prices candidates against the placement.
    pub fn with_topology(
        cfg: LmConfig,
        scheme_name: &str,
        topo: Topology,
        transport: TransportKind,
        artifacts_dir: &std::path::Path,
    ) -> Result<Self> {
        let workers = topo.endpoints();
        let rt = Runtime::cpu()?;
        let path = artifacts_dir.join(format!("{}.hlo.txt", cfg.artifact_stem()));
        let exe = rt.load_hlo(&path).with_context(|| {
            format!(
                "loading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        // Expected per-worker nnz: (1 + 1 + K) rows per pair, B pairs.
        let expected_rows = cfg.batch * (2 + cfg.negatives);
        let expected_nnz = (expected_rows * cfg.dim).min(cfg.emb_params());
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.replan_threshold),
            "replan threshold {} outside [0, 1]",
            cfg.replan_threshold
        );
        anyhow::ensure!(
            cfg.accuracy_budget.is_finite() && cfg.accuracy_budget >= 0.0,
            "accuracy budget {} must be a finite non-negative number",
            cfg.accuracy_budget
        );
        let plan_cfg = PlanConfig {
            replan_threshold: cfg.replan_threshold,
            compress: cfg.compress.clone(),
            accuracy_budget: cfg.accuracy_budget,
            ..PlanConfig::default()
        };
        let planner = planner::by_name(
            scheme_name,
            workers,
            cfg.seed ^ 0x5eed,
            expected_nnz,
            plan_cfg,
        )
        .ok_or_else(|| anyhow::anyhow!("unknown scheme '{scheme_name}' (or 'auto')"))?;
        let net = Network::with_topology(topo);
        let driver = crate::wire::make_driver(transport, &net)?;

        let mut rng = Pcg64::seeded(cfg.seed);
        let scale = 1.0 / (cfg.dim as f64).sqrt();
        let init = |rng: &mut Pcg64, n: usize, s: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * s) as f32).collect()
        };
        let embedding = init(&mut rng, cfg.emb_params(), 0.1);
        let w1 = init(&mut rng, cfg.dim * cfg.hidden, scale);
        let b1 = vec![0.0; cfg.hidden];
        let w2 = init(&mut rng, cfg.hidden * cfg.dim, scale);
        let b2 = vec![0.0; cfg.dim];
        let zipf = Zipf::new(cfg.vocab, cfg.zipf_theta);
        let compressor = cfg.compress.build();

        Ok(LmTrainer {
            cfg,
            workers,
            exe,
            planner,
            net,
            embedding,
            w1,
            b1,
            w2,
            b2,
            zipf,

            step_count: 0,
            scratch: SyncScratch::new(),
            driver,
            compressor,
        })
    }

    /// The synthetic corpus's ground-truth context for a center token:
    /// a fixed affine permutation of the vocabulary (learnable signal).
    fn true_context(&self, center: usize) -> usize {
        (center * 31 + 17) % self.cfg.vocab
    }

    /// Sample one worker's batch: (center, context, negatives) token ids.
    fn sample_batch(&self, rng: &mut Pcg64) -> (Vec<usize>, Vec<usize>, Vec<usize>) {
        let b = self.cfg.batch;
        let k = self.cfg.negatives;
        let mut center = Vec::with_capacity(b);
        let mut context = Vec::with_capacity(b);
        let mut negs = Vec::with_capacity(b * k);
        for _ in 0..b {
            let c = self.zipf.sample(rng);
            // 85% true signal, 15% noise
            let ctx = if rng.next_f64() < 0.85 {
                self.true_context(c)
            } else {
                rng.below(self.cfg.vocab as u64) as usize
            };
            center.push(c);
            context.push(ctx);
            for _ in 0..k {
                negs.push(rng.below(self.cfg.vocab as u64) as usize);
            }
        }
        (center, context, negs)
    }

    fn gather_rows(&self, tokens: &[usize]) -> Vec<f32> {
        let d = self.cfg.dim;
        let mut out = Vec::with_capacity(tokens.len() * d);
        for &t in tokens {
            out.extend_from_slice(&self.embedding[t * d..(t + 1) * d]);
        }
        out
    }

    /// Scatter per-slot row gradients into an accumulator keyed by token.
    fn scatter_rows(
        acc: &mut HashMap<u32, Vec<f32>>,
        tokens: &[usize],
        grads: &[f32],
        dim: usize,
    ) {
        for (i, &t) in tokens.iter().enumerate() {
            let g = &grads[i * dim..(i + 1) * dim];
            let e = acc.entry(t as u32).or_insert_with(|| vec![0.0; dim]);
            for (a, &v) in e.iter_mut().zip(g.iter()) {
                *a += v;
            }
        }
    }

    /// Execute one data-parallel training step across all workers.
    pub fn step(&mut self) -> Result<StepStats> {
        let cfg = self.cfg.clone();
        let (b, k, d, h) = (cfg.batch, cfg.negatives, cfg.dim, cfg.hidden);
        let mut worker_grads: Vec<CooTensor> = Vec::with_capacity(self.workers);
        let mut mlp_grad_acc = vec![0.0f32; cfg.mlp_params()];
        let mut loss_acc = 0.0f32;
        let compute_sw = crate::util::Stopwatch::start();

        // Worker RNG streams derived from the step counter.
        let step_seed = self
            .cfg
            .seed
            .wrapping_add(self.step_count.wrapping_mul(0x9e37_79b9));
        for w in 0..self.workers {
            let mut rng = Pcg64::new(step_seed, w as u64 + 101);
            let (center, context, negs) = self.sample_batch(&mut rng);
            let inputs = [
                lit::f32(&self.gather_rows(&center), &[b as i64, d as i64])?,
                lit::f32(&self.gather_rows(&context), &[b as i64, d as i64])?,
                lit::f32(&self.gather_rows(&negs), &[b as i64, k as i64, d as i64])?,
                lit::f32(&self.w1, &[d as i64, h as i64])?,
                lit::f32(&self.b1, &[h as i64])?,
                lit::f32(&self.w2, &[h as i64, d as i64])?,
                lit::f32(&self.b2, &[d as i64])?,
            ];
            let out = self.exe.run(&inputs)?;
            anyhow::ensure!(out.len() == 8, "expected 8 outputs, got {}", out.len());
            loss_acc += lit::scalar_f32(&out[0])?;
            let g_center = lit::to_f32(&out[1])?;
            let g_context = lit::to_f32(&out[2])?;
            let g_neg = lit::to_f32(&out[3])?;

            // Build this worker's sparse embedding gradient.
            let mut acc: HashMap<u32, Vec<f32>> = HashMap::new();
            Self::scatter_rows(&mut acc, &center, &g_center, d);
            Self::scatter_rows(&mut acc, &context, &g_context, d);
            Self::scatter_rows(&mut acc, &negs, &g_neg, d);
            let mut rows: Vec<u32> = acc.keys().copied().collect();
            rows.sort_unstable();
            let mut indices = Vec::with_capacity(rows.len() * d);
            let mut values = Vec::with_capacity(rows.len() * d);
            for r in rows {
                let g = &acc[&r];
                for (c, &v) in g.iter().enumerate() {
                    indices.push(r * d as u32 + c as u32);
                    values.push(v);
                }
            }
            worker_grads.push(CooTensor::from_sorted(cfg.emb_params(), indices, values));

            // Dense MLP gradients.
            for (slot, idx) in [(4usize, 0usize), (5, 1), (6, 2), (7, 3)] {
                let g = lit::to_f32(&out[slot])?;
                let off = match idx {
                    0 => 0,
                    1 => d * h,
                    2 => d * h + h,
                    _ => d * h + h + h * d,
                };
                sgd::accumulate(&mut mlp_grad_acc[off..off + g.len()], &g);
            }
        }
        let compute_wall = compute_sw.elapsed();

        // Plan, then synchronize the sparse embedding gradients (reused
        // scratch — steady-state steps don't pay allocator noise in the
        // sync) over the trainer's data plane. Fixed schemes make
        // plan() a constant; `auto` serves its cached plan unless the
        // measured gradient density drifted past the hysteresis.
        let planned = self
            .planner
            .plan("embedding", &worker_grads, &self.net.topo);
        // Plan-gated lossy tier (same policy as the sim driver): a
        // fixed scheme under `--compress` compresses every step;
        // `auto` compresses only when the plan says lossy. Error
        // feedback keeps the dropped mass in per-rank residuals, so
        // what SGD never saw this step ships in a later one.
        let lossy = match (&self.compressor, planned.plan.as_deref()) {
            (Some(_), None) => true,
            (Some(_), Some(p)) => p.lossy,
            (None, _) => false,
        };
        let synced: Vec<CooTensor> = if lossy {
            crate::compress::compress_all(
                self.compressor.as_mut().unwrap().as_mut(),
                "embedding",
                &worker_grads,
            )
        } else {
            worker_grads
        };
        let comm_bytes: u64 = synced.iter().map(|t| t.nnz() as u64 * 8).sum();
        let sync = planned
            .scheme
            .run(&synced, self.driver.as_mut(), &mut self.scratch)
            .map_err(|e| {
                anyhow::anyhow!("step {}: embedding gradient sync failed: {e}", self.step_count)
            })?;
        let emb_comm_time = sync.report.comm_time();
        let scheme_overhead = sync.report.compute_overhead;

        // Dense allreduce time for the MLP head.
        let nf = self.workers as f64;
        let mlp_comm_time = if self.workers > 1 {
            2.0 * (nf - 1.0) / nf * (cfg.mlp_params() * 4) as f64 * 8.0
                / self.net.link.bandwidth_bps()
        } else {
            0.0
        };

        // Apply SGD with the aggregated gradients.
        let scale = self.workers as f32;
        sgd::apply_sparse(&mut self.embedding, &sync.outputs[0], cfg.lr, scale);
        let (d_, h_) = (d, h);
        let mut off = 0;
        for (param, len) in [
            (&mut self.w1, d_ * h_),
            (&mut self.b1, h_),
            (&mut self.w2, h_ * d_),
            (&mut self.b2, d_),
        ] {
            sgd::apply_dense(param, &mlp_grad_acc[off..off + len], cfg.lr, scale);
            off += len;
        }

        self.step_count += 1;
        Ok(StepStats {
            loss: loss_acc / self.workers as f32,
            scheme: planned.scheme.name(),
            emb_comm_time,
            mlp_comm_time,
            compute_wall,
            scheme_overhead,
            comm_bytes,
            lossy,
        })
    }

    /// Ranking accuracy on held-out pairs: fraction of centers whose true
    /// context outscores a random token under the current parameters.
    pub fn eval_accuracy(&mut self, samples: usize) -> f64 {
        let d = self.cfg.dim;
        let h = self.cfg.hidden;
        let mut correct = 0usize;
        let mut rng = Pcg64::new(self.cfg.seed ^ 0xe7a1, 7);
        for _ in 0..samples {
            let c = self.zipf.sample(&mut rng);
            let truth = self.true_context(c);
            let rand_tok = rng.below(self.cfg.vocab as u64) as usize;
            // proj = tanh(e_c @ W1 + b1) @ W2 + b2
            let e_c = &self.embedding[c * d..(c + 1) * d];
            let mut hid = self.b1.clone();
            for (j, hv) in hid.iter_mut().enumerate().take(h) {
                let mut s = *hv;
                for i in 0..d {
                    s += e_c[i] * self.w1[i * h + j];
                }
                *hv = s.tanh();
            }
            let mut proj = self.b2.clone();
            for (i, pv) in proj.iter_mut().enumerate().take(d) {
                let mut s = *pv;
                for (j, &hv) in hid.iter().enumerate() {
                    s += hv * self.w2[j * d + i];
                }
                *pv = s;
            }
            let dot = |tok: usize| -> f32 {
                let e = &self.embedding[tok * d..(tok + 1) * d];
                proj.iter().zip(e.iter()).map(|(a, b)| a * b).sum()
            };
            if dot(truth) > dot(rand_tok) {
                correct += 1;
            }
        }
        correct as f64 / samples as f64
    }

    /// Train for `iters` steps, logging and evaluating every `log_every`.
    pub fn run(&mut self, iters: usize, log_every: usize, verbose: bool) -> Result<TrainLog> {
        let mut log = TrainLog::default();
        for it in 0..iters {
            let s = self.step()?;
            log.losses.push(s.loss);
            log.emb_comm_total += s.emb_comm_time;
            log.mlp_comm_total += s.mlp_comm_time;
            log.compute_wall_total += s.compute_wall;
            log.comm_bytes_total += s.comm_bytes;
            log.lossy_steps += s.lossy as usize;
            if log_every > 0 && (it % log_every == 0 || it + 1 == iters) {
                let acc = self.eval_accuracy(512);
                log.accuracies.push((it, acc));
                if verbose {
                    println!(
                        "step {it:4}  loss {:.4}  acc {:.3}  emb-comm {:.2}ms  compute {:.0}ms  \
                         [{}]",
                        s.loss,
                        acc,
                        s.emb_comm_time * 1e3,
                        s.compute_wall * 1e3,
                        s.scheme
                    );
                }
            }
        }
        Ok(log)
    }
}

// note: tests for LmTrainer require artifacts; they live in
// rust/tests/train_lm_integration.rs and run after `make artifacts`.
