//! SGD application for sparse (embedding) and dense (MLP) gradients.

use crate::tensor::CooTensor;

/// Apply a sparse aggregated gradient: `params[idx] -= lr/scale · grad`.
/// `scale` is the data-parallel degree (gradient averaging).
pub fn apply_sparse(params: &mut [f32], grad: &CooTensor, lr: f32, scale: f32) {
    debug_assert_eq!(params.len(), grad.dense_len);
    let step = lr / scale;
    for (&i, &g) in grad.indices.iter().zip(grad.values.iter()) {
        params[i as usize] -= step * g;
    }
}

/// Apply a dense aggregated gradient.
pub fn apply_dense(params: &mut [f32], grad: &[f32], lr: f32, scale: f32) {
    debug_assert_eq!(params.len(), grad.len());
    let step = lr / scale;
    for (p, &g) in params.iter_mut().zip(grad.iter()) {
        *p -= step * g;
    }
}

/// Element-wise accumulate `src` into `acc`.
pub fn accumulate(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    for (a, &s) in acc.iter_mut().zip(src.iter()) {
        *a += s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_updates_only_touched() {
        let mut p = vec![1.0f32; 6];
        let g = CooTensor::from_sorted(6, vec![1, 4], vec![2.0, -4.0]);
        apply_sparse(&mut p, &g, 0.5, 2.0);
        assert_eq!(p, vec![1.0, 0.5, 1.0, 1.0, 2.0, 1.0]);
    }

    #[test]
    fn dense_updates_all() {
        let mut p = vec![1.0f32; 3];
        apply_dense(&mut p, &[1.0, 2.0, 3.0], 0.1, 1.0);
        assert!((p[0] - 0.9).abs() < 1e-6);
        assert!((p[2] - 0.7).abs() < 1e-6);
    }

    #[test]
    fn accumulate_adds() {
        let mut a = vec![1.0f32, 2.0];
        accumulate(&mut a, &[0.5, 0.5]);
        assert_eq!(a, vec![1.5, 2.5]);
    }
}
