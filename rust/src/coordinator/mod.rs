//! L3 coordinator — the training-side runtime that drives synchronization.
//!
//! Two drivers share the scheme/cluster machinery:
//!
//! - [`SimDriver`]: data-parallel training *simulation* on Table-1
//!   workloads — real tensors, real scheme execution, virtual network
//!   time, modeled compute time. Regenerates the throughput and
//!   imbalance figures (11, 12, 13, 15, 18).
//! - [`lm::LmTrainer`]: *real* training of the embedding LM through the
//!   AOT-compiled JAX/Pallas step executed via PJRT — the end-to-end
//!   driver (`examples/train_lm.rs`) and the Fig 14 accuracy experiment.

pub mod lm;
pub mod sgd;

use crate::cluster::{LinkClass, LinkKind, Network, Topology, LINK_CLASSES};
use crate::planner::{self, PlanConfig, Planner};
use crate::schemes::{self, SyncScheme, SyncScratch};
use crate::wire::TransportKind;
use crate::workload::{GradientGen, ModelProfile};

/// Per-model compute time for one iteration on one 8-GPU machine
/// (forward+backward, seconds). Calibration constants standing in for
/// the V100 testbed — chosen so the compute/communication balance sits
/// in the paper's regime (communication-bound at 25 Gbps); documented in
/// DESIGN.md §Substitutions.
pub fn compute_time_per_iter(profile_name: &str) -> f64 {
    match profile_name {
        "LSTM" => 0.20,
        "DeepFM" => 0.12,
        "NMT" => 0.18,
        "BERT" => 0.15,
        _ => 0.15,
    }
}

/// Multi-tensor pipeline options: when set, the simulation synchronizes
/// the model as per-layer gradients through [`crate::engine::SyncEngine`]
/// (bucketing + compute/communication overlap) instead of one blocking
/// `sync()` of the flat tensor.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Bucket close threshold in bytes **at the scaled tensor size**.
    pub bucket_bytes: usize,
    /// Dense (MLP) layers the head is split into.
    pub dense_layers: usize,
    /// Contiguous row shards the embedding is split into.
    pub emb_shards: usize,
    /// First-needed-first bucket scheduling
    /// ([`EngineConfig::priority_schedule`]) — `zen sim
    /// --priority-schedule`.
    pub priority_schedule: bool,
    /// Tensor-partitioning threshold in bytes at the scaled tensor size
    /// ([`EngineConfig::partition_bytes`]); `usize::MAX` disables —
    /// `zen sim --partition-threshold KB`.
    pub partition_bytes: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            bucket_bytes: 256 * 1024,
            dense_layers: 4,
            emb_shards: 8,
            priority_schedule: false,
            partition_bytes: usize::MAX,
        }
    }
}

/// Configuration for a simulated data-parallel training run.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Full-size model profile (Table 1). The simulation runs on a
    /// scaled copy and rescales communication time (see `scale`).
    pub profile: ModelProfile,
    /// Scale-down factor for in-process tensors.
    pub scale: usize,
    pub machines: usize,
    pub gpus_per_machine: usize,
    pub link: LinkKind,
    /// Two-level placement (`zen sim --topology NxG[:links]`): when set,
    /// every rank of the topology is a fabric endpoint with its own
    /// per-GPU gradient, frames between co-located ranks ride the
    /// intra-node link, and the α–β charge is per link class. `None`
    /// keeps the classic flat model (machines are endpoints, GPUs
    /// pre-aggregate over NVLink analytically).
    pub topology: Option<Topology>,
    /// Scheme name (see [`schemes::by_name`]) or `auto` for the
    /// cost-model planner ([`crate::planner::CostPlanner`]).
    pub scheme: String,
    /// Relative measured-density drift that invalidates a cached plan
    /// (`--scheme auto` only; see [`PlanConfig::replan_threshold`]).
    pub replan_threshold: f64,
    /// Lossy gradient compression (`zen sim --compress
    /// topk:K|threshold:T|none`). With a fixed scheme the compressor
    /// runs unconditionally; with `--scheme auto` it runs only on
    /// buckets whose lossy plan beats the best lossless prediction
    /// under a positive [`accuracy_budget`](SimConfig::accuracy_budget).
    pub compress: crate::compress::CompressSpec,
    /// Tolerated final-loss degradation that arms the planner's lossy
    /// tier (`--accuracy-budget B`; 0 keeps `auto` lossless).
    pub accuracy_budget: f64,
    pub iterations: usize,
    pub seed: u64,
    /// `Some` → pipelined multi-tensor engine; `None` → the classic
    /// one-blocking-sync path.
    pub pipeline: Option<PipelineConfig>,
    /// Data plane the schemes run over: virtual-time sim (default),
    /// real-frames channel fabric, the readiness-polled loopback socket
    /// mesh, the single-threaded discrete-event scheduler (the large-n
    /// mode — ranks are event endpoints, not threads), or one OS thread
    /// per rank (`zen sim --transport sim|channel|socket|event|threaded`).
    pub transport: TransportKind,
}

impl SimConfig {
    pub fn new(profile: ModelProfile, machines: usize, scheme: &str) -> Self {
        SimConfig {
            profile,
            scale: 64,
            machines,
            gpus_per_machine: 8,
            link: LinkKind::Tcp25,
            topology: None,
            scheme: scheme.to_string(),
            replan_threshold: PlanConfig::default().replan_threshold,
            compress: crate::compress::CompressSpec::None,
            accuracy_budget: 0.0,
            iterations: 4,
            seed: 0xbeef,
            pipeline: None,
            transport: TransportKind::Sim,
        }
    }

    /// Start a validating builder: every constraint is checked at
    /// [`build`](SimConfigBuilder::build) and reported as one combined
    /// `Err`, instead of surfacing piecemeal from [`SimDriver::new`].
    pub fn builder(profile: ModelProfile, machines: usize, scheme: &str) -> SimConfigBuilder {
        SimConfigBuilder {
            cfg: SimConfig::new(profile, machines, scheme),
        }
    }
}

/// Validating builder for [`SimConfig`] (see [`SimConfig::builder`]).
#[derive(Clone, Debug)]
pub struct SimConfigBuilder {
    cfg: SimConfig,
}

impl SimConfigBuilder {
    pub fn scale(mut self, scale: usize) -> Self {
        self.cfg.scale = scale;
        self
    }

    pub fn gpus_per_machine(mut self, g: usize) -> Self {
        self.cfg.gpus_per_machine = g;
        self
    }

    pub fn link(mut self, link: LinkKind) -> Self {
        self.cfg.link = link;
        self
    }

    pub fn topology(mut self, topo: Topology) -> Self {
        self.cfg.topology = Some(topo);
        self
    }

    pub fn replan_threshold(mut self, t: f64) -> Self {
        self.cfg.replan_threshold = t;
        self
    }

    pub fn compress(mut self, spec: crate::compress::CompressSpec) -> Self {
        self.cfg.compress = spec;
        self
    }

    pub fn accuracy_budget(mut self, b: f64) -> Self {
        self.cfg.accuracy_budget = b;
        self
    }

    pub fn iterations(mut self, iters: usize) -> Self {
        self.cfg.iterations = iters;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn pipeline(mut self, p: PipelineConfig) -> Self {
        self.cfg.pipeline = Some(p);
        self
    }

    pub fn transport(mut self, t: TransportKind) -> Self {
        self.cfg.transport = t;
        self
    }

    pub fn build(self) -> Result<SimConfig, String> {
        let cfg = self.cfg;
        let mut problems = Vec::new();
        if cfg.machines == 0 {
            problems.push("machines must be >= 1".to_string());
        }
        if cfg.scale == 0 {
            problems.push("scale must be >= 1".to_string());
        }
        if cfg.gpus_per_machine == 0 {
            problems.push("gpus_per_machine must be >= 1".to_string());
        }
        if !(0.0..=1.0).contains(&cfg.replan_threshold) {
            problems.push(format!(
                "replan threshold {} outside [0, 1]",
                cfg.replan_threshold
            ));
        }
        if !cfg.accuracy_budget.is_finite() || cfg.accuracy_budget < 0.0 {
            problems.push(format!(
                "accuracy budget {} must be a finite non-negative number",
                cfg.accuracy_budget
            ));
        }
        if let Some(p) = &cfg.pipeline {
            if p.emb_shards == 0 {
                problems
                    .push("pipeline needs at least one embedding shard (--emb-shards)".to_string());
            }
        }
        if let Some(t) = &cfg.topology {
            if t.endpoints() == 0 {
                problems.push("topology must place at least one rank".to_string());
            }
        }
        if problems.is_empty() {
            Ok(cfg)
        } else {
            Err(problems.join("; "))
        }
    }
}

/// One bucket's row in the reported synchronization plan: which scheme
/// the planner chose and how its prediction compared to what the
/// transport actually measured — mispredictions are visible numbers,
/// split by link class on two-level topologies.
#[derive(Clone, Debug)]
pub struct BucketPlanReport {
    /// Bucket label (`embedding` for the flat path).
    pub label: String,
    /// Display name of the executed scheme.
    pub scheme: &'static str,
    /// Cost-model prediction rescaled to full model size (seconds);
    /// `None` under a fixed scheme (nothing was predicted).
    pub predicted: Option<f64>,
    /// Transport-measured full-size virtual time (seconds).
    pub measured: f64,
    /// Cost-model prediction per link class (`[intra, inter]`,
    /// full-size seconds); `None` under a fixed scheme. Flat runs
    /// predict `[0, predicted]`.
    pub predicted_by_class: Option<[f64; 2]>,
    /// Transport-measured full-size time per link class (`[intra,
    /// inter]` — each class's α–β sum alone; the stage charge is their
    /// max, so the two entries need not add up to `measured`).
    pub measured_by_class: [f64; 2],
    /// True when this bucket synchronized compressed gradients — a
    /// planner-chosen lossy plan, or a fixed scheme under `--compress`.
    pub lossy: bool,
    /// Compressor label (`topk:K` / `threshold:T`) when `lossy`.
    pub compressor: Option<String>,
    /// Best lossless candidate's predicted full-size time — kept next
    /// to `predicted` (the executed plan's time) so the table can show
    /// what the lossy tier bought. `None` under a fixed scheme.
    pub predicted_lossless: Option<f64>,
}

impl BucketPlanReport {
    /// measured / predicted (> 1 = cost model optimistic), if predicted.
    pub fn misprediction(&self) -> Option<f64> {
        planner::misprediction_ratio(self.measured, self.predicted)
    }
}

/// Result of a simulated run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Scheme label: the fixed scheme's display name, or `auto` (see
    /// `plan` for the per-bucket choices).
    pub scheme: String,
    /// The synchronization plan executed on the first iteration: one row
    /// per bucket (flat mode: the single `embedding` row) with predicted
    /// vs transport-measured time.
    pub plan: Vec<BucketPlanReport>,
    /// Full-size per-iteration gradient sync time (virtual seconds).
    /// Flat mode: the embedding tensor's sync. Engine mode: total bucket
    /// communication, which also covers any dense layers in the plan.
    pub emb_sync_times: Vec<f64>,
    /// Full-size per-iteration dense (MLP) ring-allreduce time. Zero in
    /// engine mode when the plan's dense layers fold the MLP into
    /// buckets (`emb_sync_times` then carries that cost).
    pub mlp_sync_time: f64,
    /// Intra-machine (NVLink) phase time.
    pub intra_time: f64,
    /// Modeled compute time per iteration.
    pub compute_time: f64,
    /// Push-stage receive imbalance per iteration (servers), if the
    /// scheme is push/pull shaped.
    pub push_imbalance: Vec<f64>,
    /// Pull-stage send imbalance per iteration.
    pub pull_imbalance: Vec<f64>,
    /// Total samples/second at full size.
    pub throughput: f64,
    /// Mean embedding sync time.
    pub emb_sync_mean: f64,
    /// Engine mode only: mean full-size iteration time when every bucket
    /// sync runs after compute (compute + intra + all bucket comm).
    pub engine_serialized: Option<f64>,
    /// Engine mode only: mean full-size iteration time with
    /// compute/communication overlap (the pipeline makespan + intra).
    pub engine_overlapped: Option<f64>,
    /// Engine mode only: mean full-size virtual time at which the
    /// *next* iteration's forward pass completes
    /// ([`crate::cluster::Timeline::forward_finish`] + intra + MLP) —
    /// the stall metric `--priority-schedule` improves.
    pub engine_forward_finish: Option<f64>,
    /// Total wire entries the compressor dropped across the run,
    /// priced in bytes at full model scale (8 bytes per COO entry).
    /// Zero when no compression ran.
    pub bytes_saved: u64,
}

impl SimResult {
    /// Mean total iteration time.
    pub fn iter_time(&self) -> f64 {
        self.compute_time + self.intra_time + self.mlp_sync_time + self.emb_sync_mean
    }
}

/// Simulated data-parallel training driver.
pub struct SimDriver {
    pub cfg: SimConfig,
    gen: GradientGen,
    planner: Box<dyn Planner>,
    /// Machines-×-GPUs shape of the flat path (NVLink pre-aggregation).
    topo: Topology,
    /// Topology of the synchronization fabric itself: flat over
    /// `machines` endpoints, or `cfg.topology` with one endpoint per
    /// rank.
    sync_topo: Topology,
}

impl SimDriver {
    pub fn new(cfg: SimConfig) -> anyhow::Result<Self> {
        if let Some(p) = &cfg.pipeline {
            anyhow::ensure!(
                p.emb_shards >= 1,
                "pipeline needs at least one embedding shard (--emb-shards)"
            );
        }
        let sync_topo = match &cfg.topology {
            Some(t) => {
                anyhow::ensure!(
                    t.endpoints() >= 1,
                    "topology must place at least one rank"
                );
                t.clone()
            }
            None => Topology::flat(cfg.machines, cfg.link),
        };
        let endpoints = sync_topo.endpoints();
        let scaled = cfg.profile.scaled(cfg.scale);
        let gen = GradientGen::new(scaled, cfg.seed);
        // Expected per-endpoint non-zeros: a machine aggregate on the
        // flat path, a single GPU's tensor when every rank is an
        // endpoint of an explicit topology.
        let endpoint_nnz = if cfg.topology.is_some() {
            gen.expected_nnz()
        } else {
            gen.expected_nnz() * cfg.gpus_per_machine.min(4)
        };
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.replan_threshold),
            "replan threshold {} outside [0, 1]",
            cfg.replan_threshold
        );
        anyhow::ensure!(
            cfg.accuracy_budget.is_finite() && cfg.accuracy_budget >= 0.0,
            "accuracy budget {} must be a finite non-negative number",
            cfg.accuracy_budget
        );
        let plan_cfg = PlanConfig {
            replan_threshold: cfg.replan_threshold,
            compress: cfg.compress.clone(),
            accuracy_budget: cfg.accuracy_budget,
            ..PlanConfig::default()
        };
        let planner = planner::by_name(
            &cfg.scheme,
            endpoints,
            cfg.seed ^ 0x5eed,
            endpoint_nnz,
            plan_cfg,
        )
        .ok_or_else(|| anyhow::anyhow!("unknown scheme '{}' (or 'auto')", cfg.scheme))?;
        let topo = Topology::new(cfg.machines, cfg.gpus_per_machine, cfg.link);
        Ok(SimDriver {
            cfg,
            gen,
            planner,
            topo,
            sync_topo,
        })
    }

    /// Endpoint count of the synchronization fabric (machines on the
    /// flat path, total ranks under an explicit topology).
    fn endpoints(&self) -> usize {
        self.sync_topo.endpoints()
    }

    /// One endpoint's gradient for an iteration: a machine's g-GPU
    /// aggregate on the flat path (NVLink pre-aggregation), one GPU's
    /// tensor when ranks are endpoints.
    fn rank_tensor(&self, it: u64, rank: usize) -> crate::tensor::CooTensor {
        if self.cfg.topology.is_some() {
            self.gen.machine_iteration(it, rank, 1)
        } else {
            self.gen
                .machine_iteration(it, rank, self.cfg.gpus_per_machine)
        }
    }

    /// Analytic NVLink pre-aggregation charge — zero under an explicit
    /// topology, where the transport itself prices intra-node frames.
    fn intra_phase_time(&self) -> f64 {
        if self.cfg.topology.is_some() {
            0.0
        } else {
            self.topo
                .intra_machine_time((self.cfg.profile.emb_params() * 4) as u64)
        }
    }

    /// Total GPUs contributing samples per iteration.
    fn sample_gpus(&self) -> usize {
        if self.cfg.topology.is_some() {
            self.endpoints()
        } else {
            self.cfg.machines * self.cfg.gpus_per_machine
        }
    }

    /// Bytes scale factor from the simulated tensor to the full model.
    fn scale_factor(&self) -> f64 {
        self.cfg.profile.emb_params() as f64 / self.gen.profile.emb_params() as f64
    }

    /// Ring-allreduce time for the full-size dense MLP gradients —
    /// shared by the flat path and the no-dense-layers pipelined path so
    /// the two stay comparable. Priced on the inter link: the dense
    /// ring's bandwidth term is dominated by the node-boundary hops.
    fn mlp_allreduce_time(&self) -> f64 {
        let n = self.endpoints();
        if n <= 1 {
            return 0.0;
        }
        let mlp_bytes = (self.cfg.profile.mlp_params * 4) as f64;
        let nf = n as f64;
        2.0 * (nf - 1.0) / nf * mlp_bytes * 8.0 / self.sync_topo.inter.bandwidth_bps()
    }

    /// Full-size α–β time of one link class in one stage (0 when the
    /// class carried nothing): `α_c + busiest_c·scale·8/B_c`.
    fn full_class_time(&self, stage: &crate::cluster::StageReport, class: LinkClass) -> f64 {
        let busiest = stage.classes[class.idx()].busiest;
        if busiest == 0 {
            return 0.0;
        }
        let link = self.sync_topo.link_of(class);
        link.latency() + busiest as f64 * self.scale_factor() * 8.0 / link.bandwidth_bps()
    }

    /// Rescale a stage-structured report to full tensor size:
    /// `t_full = Σ_stages max_class(α_c + busiest_c·scale·8/B_c)` — on a
    /// flat network everything is inter-class and this reduces to the
    /// historical single-link rescaling exactly.
    fn full_size_time(&self, report: &crate::cluster::CommReport) -> f64 {
        report
            .stages
            .iter()
            .map(|s| {
                LINK_CLASSES
                    .iter()
                    .map(|&c| self.full_class_time(s, c))
                    .fold(0.0, f64::max)
            })
            .sum()
    }

    /// Per-link-class full-size α–β sums (`[intra, inter]`) — the
    /// measured side of the plan table's per-class rows.
    fn full_size_time_by_class(&self, report: &crate::cluster::CommReport) -> [f64; 2] {
        let mut out = [0f64; 2];
        for s in &report.stages {
            for c in LINK_CLASSES {
                out[c.idx()] += self.full_class_time(s, c);
            }
        }
        out
    }

    /// Run the simulation.
    pub fn run(&self) -> SimResult {
        match self.cfg.pipeline.clone() {
            Some(p) => self.run_pipelined(&p),
            None => self.run_flat(),
        }
    }

    /// Classic path: one blocking sync of the flat embedding tensor per
    /// iteration — a single planner "bucket" labeled `embedding`.
    fn run_flat(&self) -> SimResult {
        let n = self.endpoints();
        let net = Network::with_topology(self.sync_topo.clone());
        let mut emb_sync_times = Vec::with_capacity(self.cfg.iterations);
        let mut push_imb = Vec::new();
        let mut pull_imb = Vec::new();
        let mut plan: Vec<BucketPlanReport> = Vec::new();
        // One scratch for the whole run: iterations after the first
        // reuse warmed buffers, so the compute charge in the reported
        // stages reflects the algorithm, not the allocator. The driver
        // is likewise built once (a socket mesh persists across
        // iterations) and reset by each sync's `take_report`.
        let mut scratch = SyncScratch::new();
        let mut driver = crate::wire::make_driver(self.cfg.transport, &net)
            .expect("sim driver setup");
        // One compressor for the whole run: error-feedback residuals
        // carry dropped mass across iterations, so the state must
        // outlive the loop.
        let mut compressor = self.cfg.compress.build();

        for it in 0..self.cfg.iterations as u64 {
            // Flat path: each machine's tensor = aggregate of its g
            // GPUs (the intra-machine NVLink phase), densification
            // included. Topology mode: each rank's own GPU tensor.
            let raw: Vec<crate::tensor::CooTensor> =
                (0..n).map(|m| self.rank_tensor(it, m)).collect();
            // Steady-state plan() is a cached lookup plus a mean-density
            // scan; only warm-up (or a density drift past the
            // hysteresis) profiles and re-ranks. Planning sees the raw
            // gradients — the lossy tier prices compression itself.
            let planned = self.planner.plan("embedding", &raw, &net.topo);
            // Plan-gated compression: `--scheme auto` compresses only
            // when the planner's lossy candidate beat every lossless
            // one under the accuracy budget; a fixed scheme under
            // `--compress` compresses unconditionally (no plan to gate).
            let lossy = match (&compressor, planned.plan.as_deref()) {
                (Some(_), None) => true,
                (Some(_), Some(p)) => p.lossy,
                (None, _) => false,
            };
            let inputs = if lossy {
                crate::compress::compress_all(
                    compressor.as_mut().unwrap().as_mut(),
                    "embedding",
                    &raw,
                )
            } else {
                raw
            };
            let result = planned
                .scheme
                .run(&inputs, driver.as_mut(), &mut scratch)
                .unwrap_or_else(|e| {
                    panic!(
                        "embedding sync failed on the {} data plane: {e}",
                        self.cfg.transport.name()
                    )
                });
            // Correctness self-check on the first iteration: the sync
            // must reproduce the sum of whatever it was given — the
            // compressed tensors when the lossy tier ran (the lossy
            // error lives in the residuals, not the collective).
            if it == 0 && !self.cfg.scheme.starts_with("strawman") {
                schemes::verify_outputs(&result, &inputs);
            }
            let measured = self.full_size_time(&result.report);
            if it == 0 {
                let scale = self.scale_factor();
                plan.push(BucketPlanReport {
                    label: "embedding".to_string(),
                    scheme: planned.scheme.name(),
                    predicted: planned
                        .plan
                        .as_ref()
                        .map(|p| p.predicted_at_scale(scale)),
                    measured,
                    predicted_by_class: planned
                        .plan
                        .as_ref()
                        .map(|p| p.predicted_class_at_scale(scale)),
                    measured_by_class: self.full_size_time_by_class(&result.report),
                    lossy,
                    compressor: if lossy {
                        Some(self.cfg.compress.label())
                    } else {
                        None
                    },
                    predicted_lossless: planned
                        .plan
                        .as_ref()
                        .map(|p| p.predicted_lossless_at_scale(scale)),
                });
            }
            emb_sync_times.push(measured);
            if result.report.stages.len() == 2 {
                push_imb.push(result.report.stages[0].recv_imbalance());
                pull_imb.push(result.report.stages[1].sent_imbalance());
            }
        }

        // Dense MLP gradients always go through ring allreduce.
        let mlp_sync_time = self.mlp_allreduce_time();
        let intra_time = self.intra_phase_time();
        let compute_time = compute_time_per_iter(self.cfg.profile.name);
        let emb_sync_mean =
            emb_sync_times.iter().sum::<f64>() / emb_sync_times.len().max(1) as f64;
        let iter_time = compute_time + intra_time + mlp_sync_time + emb_sync_mean;
        let throughput =
            (self.sample_gpus() * self.cfg.profile.batch_size) as f64 / iter_time;
        let bytes_saved = compressor
            .as_ref()
            .map_or(0, |c| (c.stats().bytes_saved() as f64 * self.scale_factor()) as u64);

        SimResult {
            scheme: self.planner.scheme_label(),
            plan,
            emb_sync_times,
            mlp_sync_time,
            intra_time,
            compute_time,
            push_imbalance: push_imb,
            pull_imbalance: pull_imb,
            throughput,
            emb_sync_mean,
            engine_serialized: None,
            engine_overlapped: None,
            engine_forward_finish: None,
            bytes_saved,
        }
    }

    /// Engine path: per-layer gradients through the pipelined
    /// multi-tensor engine (bucketing + compute/communication overlap).
    /// The engine covers the dense head layers too, so the separate
    /// analytic MLP allreduce charge is zero here.
    fn run_pipelined(&self, p: &PipelineConfig) -> SimResult {
        let n = self.endpoints();
        let g = self.cfg.gpus_per_machine;
        let net = Network::with_topology(self.sync_topo.clone());
        let specs = self.gen.layer_specs(p.dense_layers, p.emb_shards);
        let compute_time = compute_time_per_iter(self.cfg.profile.name);
        let engine = crate::engine::SyncEngine::new(
            crate::engine::EngineConfig::new(p.bucket_bytes, compute_time)
                .with_transport(self.cfg.transport)
                .with_priority(p.priority_schedule)
                .with_partition_bytes(p.partition_bytes),
        );

        let mut emb_sync_times = Vec::with_capacity(self.cfg.iterations);
        let mut serialized = Vec::with_capacity(self.cfg.iterations);
        let mut overlapped = Vec::with_capacity(self.cfg.iterations);
        let mut fwd_finishes = Vec::with_capacity(self.cfg.iterations);
        let mut plan: Vec<BucketPlanReport> = Vec::new();
        // Engine path: the compressor runs up-front on every layer
        // (the engine re-buckets tensors, so the per-bucket plan gate
        // of the flat path has no stable tensor to key residuals on).
        let mut compressor = self.cfg.compress.build();
        for it in 0..self.cfg.iterations as u64 {
            // Per-endpoint layer tensors. Flat path: aggregate each
            // layer over the machine's g GPUs (intra-machine NVLink
            // phase, densification included). Topology mode: every rank
            // is one GPU, so its layers ship unaggregated and the
            // transport prices the node-local traffic.
            let machine_layers: Vec<Vec<crate::tensor::CooTensor>> = if self.cfg.topology.is_some()
            {
                (0..n)
                    .map(|rank| self.gen.layer_iteration(&specs, it, rank))
                    .collect()
            } else {
                (0..n)
                    .map(|m| {
                        // Transpose [gpu][layer] -> [layer][gpu] by moving
                        // the tensors (they dominate the sim's data volume).
                        let mut by_layer: Vec<Vec<crate::tensor::CooTensor>> =
                            (0..specs.len()).map(|_| Vec::with_capacity(g)).collect();
                        for gi in 0..g {
                            let gpu_layers = self.gen.layer_iteration(&specs, it, m * g + gi);
                            for (l, t) in gpu_layers.into_iter().enumerate() {
                                by_layer[l].push(t);
                            }
                        }
                        by_layer
                            .into_iter()
                            .map(|shards| crate::tensor::CooTensor::merge_all(&shards))
                            .collect()
                    })
                    .collect()
            };
            let machine_layers: Vec<Vec<crate::tensor::CooTensor>> = match &mut compressor {
                None => machine_layers,
                Some(c) => machine_layers
                    .into_iter()
                    .enumerate()
                    .map(|(rank, layers)| {
                        layers
                            .into_iter()
                            .enumerate()
                            .map(|(l, t)| c.compress(&format!("layer{l}"), rank, &t))
                            .collect()
                    })
                    .collect(),
            };
            let run = engine.run(&specs, &machine_layers, self.planner.as_ref(), &net, |r| {
                self.full_size_time(r)
            });
            if it == 0 && !self.cfg.scheme.starts_with("strawman") {
                crate::engine::verify_layer_outputs(&run, &machine_layers);
            }
            if it == 0 {
                // Per-bucket plan report: the engine's comm_time already
                // went through full_size_time; rescale the prediction's
                // bandwidth part the same way (latency is size-free).
                let scale = self.scale_factor();
                plan = run
                    .buckets
                    .iter()
                    .map(|b| BucketPlanReport {
                        label: b.label.clone(),
                        scheme: b.scheme,
                        predicted: b.plan.as_ref().map(|p| p.predicted_at_scale(scale)),
                        measured: b.comm_time,
                        predicted_by_class: b
                            .plan
                            .as_ref()
                            .map(|p| p.predicted_class_at_scale(scale)),
                        measured_by_class: self.full_size_time_by_class(&b.report),
                        lossy: compressor.is_some(),
                        compressor: compressor
                            .as_ref()
                            .map(|_| self.cfg.compress.label()),
                        predicted_lossless: b
                            .plan
                            .as_ref()
                            .map(|p| p.predicted_lossless_at_scale(scale)),
                    })
                    .collect();
            }
            let comm_total: f64 = run.buckets.iter().map(|b| b.comm_time).sum();
            emb_sync_times.push(comm_total);
            serialized.push(run.serialized_time);
            overlapped.push(run.overlapped_time);
            fwd_finishes.push(run.forward_finish);
        }

        // With dense layers in the plan the engine synchronizes the MLP
        // gradients too (no separate analytic charge); with none, the
        // MLP still goes through the flat path's ring allreduce.
        let mlp_sync_time = if p.dense_layers == 0 {
            self.mlp_allreduce_time()
        } else {
            0.0
        };
        // Same intra-machine charge as the flat path (embedding bytes),
        // so flat-vs-pipelined iteration times differ only in what the
        // engine actually changes: the inter-machine schedule.
        let intra_time = self.intra_phase_time();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        let emb_sync_mean = mean(&emb_sync_times);
        let engine_serialized = intra_time + mlp_sync_time + mean(&serialized);
        let engine_overlapped = intra_time + mlp_sync_time + mean(&overlapped);
        let engine_forward_finish = intra_time + mlp_sync_time + mean(&fwd_finishes);
        let throughput =
            (self.sample_gpus() * self.cfg.profile.batch_size) as f64 / engine_overlapped;
        let bytes_saved = compressor
            .as_ref()
            .map_or(0, |c| (c.stats().bytes_saved() as f64 * self.scale_factor()) as u64);

        SimResult {
            scheme: self.planner.scheme_label(),
            plan,
            emb_sync_times,
            mlp_sync_time,
            intra_time,
            compute_time,
            push_imbalance: Vec::new(),
            pull_imbalance: Vec::new(),
            throughput,
            emb_sync_mean,
            engine_serialized: Some(engine_serialized),
            engine_overlapped: Some(engine_overlapped),
            engine_forward_finish: Some(engine_forward_finish),
            bytes_saved,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::profiles;

    fn cfg(scheme: &str, machines: usize) -> SimConfig {
        let mut c = SimConfig::new(profiles::by_name("DeepFM").unwrap(), machines, scheme);
        c.scale = 512;
        c.iterations = 2;
        c.gpus_per_machine = 2;
        c
    }

    #[test]
    fn zen_beats_allreduce_throughput() {
        let zen = SimDriver::new(cfg("zen", 8)).unwrap().run();
        let dense = SimDriver::new(cfg("allreduce", 8)).unwrap().run();
        assert!(
            zen.throughput > dense.throughput,
            "zen {} vs dense {}",
            zen.throughput,
            dense.throughput
        );
    }

    #[test]
    fn zen_imbalance_below_sparse_ps() {
        let zen = SimDriver::new(cfg("zen", 8)).unwrap().run();
        let ps = SimDriver::new(cfg("sparseps", 8)).unwrap().run();
        let zmax = zen.push_imbalance.iter().cloned().fold(0.0, f64::max);
        let pmax = ps.push_imbalance.iter().cloned().fold(0.0, f64::max);
        assert!(zmax < 1.3, "zen push imbalance {zmax}");
        assert!(pmax > 2.0, "sparse-ps push imbalance {pmax}");
    }

    #[test]
    fn unknown_scheme_rejected() {
        assert!(SimDriver::new(cfg("nccl-magic", 4)).is_err());
    }

    #[test]
    fn fixed_scheme_compression_saves_bytes_and_reports_lossy() {
        let mut c = cfg("zen", 4);
        c.compress = crate::compress::CompressSpec::TopK(0.005);
        let lossy = SimDriver::new(c).unwrap().run();
        let base = SimDriver::new(cfg("zen", 4)).unwrap().run();
        assert!(lossy.bytes_saved > 0, "top-k dropped no entries");
        assert!(lossy.plan[0].lossy);
        assert_eq!(lossy.plan[0].compressor.as_deref(), Some("topk:0.005"));
        assert!(
            lossy.emb_sync_mean < base.emb_sync_mean,
            "compressed sync {} not cheaper than lossless {}",
            lossy.emb_sync_mean,
            base.emb_sync_mean
        );
        assert_eq!(base.bytes_saved, 0);
        assert!(!base.plan[0].lossy);
    }

    #[test]
    fn auto_gates_compression_on_the_plan() {
        // Unarmed (budget 0): `--compress` alone never fires under auto.
        let mut c0 = cfg("auto", 8);
        c0.compress = crate::compress::CompressSpec::TopK(0.001);
        let r0 = SimDriver::new(c0).unwrap().run();
        assert!(!r0.plan[0].lossy);
        assert_eq!(r0.bytes_saved, 0);
        // Armed: compression runs exactly when the plan says lossy.
        let mut c = cfg("auto", 8);
        c.compress = crate::compress::CompressSpec::TopK(0.001);
        c.accuracy_budget = 0.05;
        let r = SimDriver::new(c).unwrap().run();
        assert!(r.throughput > 0.0);
        if r.plan[0].lossy {
            assert!(r.bytes_saved > 0);
            assert!(
                r.plan[0].predicted.unwrap() <= r.plan[0].predicted_lossless.unwrap(),
                "lossy plan predicted above its lossless baseline"
            );
        }
    }

    #[test]
    fn builder_rejects_bad_accuracy_budget() {
        let err = SimConfig::builder(profiles::by_name("DeepFM").unwrap(), 4, "zen")
            .accuracy_budget(f64::NAN)
            .build()
            .unwrap_err();
        assert!(err.contains("accuracy budget"), "{err}");
        assert!(SimConfig::builder(profiles::by_name("DeepFM").unwrap(), 4, "zen")
            .compress(crate::compress::CompressSpec::Threshold(0.5))
            .accuracy_budget(0.02)
            .build()
            .is_ok());
    }

    #[test]
    fn strawman_scheme_runs() {
        let r = SimDriver::new(cfg("strawman:8", 4)).unwrap().run();
        assert!(r.throughput > 0.0);
    }

    #[test]
    fn channel_transport_run_matches_sim() {
        // `--transport channel`: the same protocol over real frames must
        // charge identical virtual time (bytes are the only time input).
        let sim = SimDriver::new(cfg("zen", 4)).unwrap().run();
        let mut c = cfg("zen", 4);
        c.transport = TransportKind::Channel;
        let chan = SimDriver::new(c).unwrap().run();
        assert_eq!(sim.emb_sync_times, chan.emb_sync_times);
        assert_eq!(sim.throughput, chan.throughput);
    }

    #[test]
    fn event_transport_run_matches_sim() {
        // `--transport event`: the discrete-event scheduler replays the
        // same protocol in virtual time — per-stage charges flow through
        // the same accounting, so every reported number is identical.
        let sim = SimDriver::new(cfg("zen", 4)).unwrap().run();
        let mut c = cfg("zen", 4);
        c.transport = TransportKind::Event;
        let ev = SimDriver::new(c).unwrap().run();
        assert_eq!(sim.emb_sync_times, ev.emb_sync_times);
        assert_eq!(sim.throughput, ev.throughput);
    }

    #[test]
    fn throughput_scales_with_machines() {
        // More machines: more samples/s (communication grows slower than
        // aggregate batch for Zen).
        let t4 = SimDriver::new(cfg("zen", 4)).unwrap().run().throughput;
        let t8 = SimDriver::new(cfg("zen", 8)).unwrap().run().throughput;
        assert!(t8 > t4, "t8 {t8} vs t4 {t4}");
    }

    #[test]
    fn flat_path_reports_no_engine_times() {
        let r = SimDriver::new(cfg("zen", 4)).unwrap().run();
        assert!(r.engine_serialized.is_none());
        assert!(r.engine_overlapped.is_none());
        assert!(r.engine_forward_finish.is_none());
    }

    #[test]
    fn pipelined_priority_reports_forward_finish() {
        // Priority scheduling + tensor partitioning through the full
        // sim pipeline: runs clean and reports a forward-finish time at
        // least as large as the overlapped makespan (the forward pass
        // adds compute after the last needed sync).
        let mut c = cfg("zen", 4);
        c.iterations = 1;
        c.pipeline = Some(PipelineConfig {
            bucket_bytes: 64 * 1024,
            dense_layers: 3,
            emb_shards: 4,
            priority_schedule: true,
            partition_bytes: 32 * 1024,
        });
        let r = SimDriver::new(c).unwrap().run();
        let over = r.engine_overlapped.expect("engine mode");
        let fwd = r.engine_forward_finish.expect("engine mode");
        assert!(fwd >= over - 1e-9, "forward finish {fwd} vs overlapped {over}");
    }

    fn pipelined_cfg(scheme: &str, machines: usize) -> SimConfig {
        let mut c = cfg(scheme, machines);
        c.iterations = 1;
        c.pipeline = Some(PipelineConfig {
            bucket_bytes: 64 * 1024,
            dense_layers: 3,
            emb_shards: 4,
            ..PipelineConfig::default()
        });
        c
    }

    #[test]
    fn pipelined_overlap_beats_serialized() {
        for scheme in ["zen", "allreduce"] {
            let r = SimDriver::new(pipelined_cfg(scheme, 4)).unwrap().run();
            let ser = r.engine_serialized.expect("engine mode");
            let over = r.engine_overlapped.expect("engine mode");
            assert!(
                over < ser,
                "{scheme}: overlapped {over} should beat serialized {ser}"
            );
            assert!(r.mlp_sync_time == 0.0, "engine folds the MLP in");
            assert!(r.throughput > 0.0);
        }
    }

    #[test]
    fn pipelined_without_dense_layers_still_charges_mlp() {
        let mut c = pipelined_cfg("zen", 4);
        c.pipeline.as_mut().unwrap().dense_layers = 0;
        let r = SimDriver::new(c).unwrap().run();
        assert!(
            r.mlp_sync_time > 0.0,
            "no dense layers in the plan -> MLP must still be charged"
        );
    }

    #[test]
    fn pipelined_zero_shards_rejected() {
        let mut c = pipelined_cfg("zen", 4);
        c.pipeline.as_mut().unwrap().emb_shards = 0;
        assert!(SimDriver::new(c).is_err());
    }

    #[test]
    fn builder_collects_all_problems() {
        let err = SimConfig::builder(profiles::by_name("DeepFM").unwrap(), 4, "zen")
            .replan_threshold(1.5)
            .pipeline(PipelineConfig {
                bucket_bytes: 64 * 1024,
                dense_layers: 3,
                emb_shards: 0,
                ..PipelineConfig::default()
            })
            .build()
            .unwrap_err();
        assert!(err.contains("replan threshold"), "{err}");
        assert!(err.contains("embedding shard"), "{err}");

        let ok = SimConfig::builder(profiles::by_name("DeepFM").unwrap(), 4, "zen")
            .transport(TransportKind::Socket)
            .iterations(1)
            .build()
            .unwrap();
        assert_eq!(ok.transport, TransportKind::Socket);
        assert_eq!(ok.iterations, 1);
    }

    #[test]
    fn auto_scheme_flat_reports_plan() {
        let r = SimDriver::new(cfg("auto", 8)).unwrap().run();
        assert_eq!(r.scheme, "auto");
        assert_eq!(r.plan.len(), 1, "flat mode: one embedding bucket");
        let p = &r.plan[0];
        assert_eq!(p.label, "embedding");
        assert!(!p.scheme.is_empty());
        let predicted = p.predicted.expect("auto mode predicts");
        assert!(predicted > 0.0 && p.measured > 0.0);
        // The cost model must land in the measured ballpark (COO bytes,
        // bitmap constants, and α stages are all modeled): a large
        // misprediction here means measurement and model diverged.
        let mis = p.misprediction().unwrap();
        assert!((0.3..=3.0).contains(&mis), "measured/predicted = {mis}");
    }

    #[test]
    fn auto_pipelined_mixes_and_competes_with_best_fixed() {
        let auto = SimDriver::new(pipelined_cfg("auto", 8)).unwrap().run();
        assert_eq!(auto.scheme, "auto");
        assert!(auto.plan.len() >= 2, "multiple buckets planned");
        for p in &auto.plan {
            assert!(p.predicted.is_some(), "bucket {} unpredicted", p.label);
        }
        // The planner's whole point: per-bucket choice must at least
        // match the best single fixed scheme on this workload (dense
        // head buckets and sparse embedding buckets want different
        // schemes). Small tolerance for cost-model error on near-ties.
        let zen = SimDriver::new(pipelined_cfg("zen", 8)).unwrap().run();
        let dense = SimDriver::new(pipelined_cfg("allreduce", 8)).unwrap().run();
        let best = zen.emb_sync_mean.min(dense.emb_sync_mean);
        assert!(
            auto.emb_sync_mean <= best * 1.05,
            "auto {} vs best fixed {best}",
            auto.emb_sync_mean
        );
    }

    #[test]
    fn replan_threshold_validated() {
        let mut c = cfg("auto", 4);
        c.replan_threshold = 1.5;
        assert!(SimDriver::new(c).is_err());
    }

    fn topology_cfg(scheme: &str) -> SimConfig {
        let mut c = cfg(scheme, 4);
        // 4 machines × 2 GPUs become 8 ranks on a 4×2 two-level fabric.
        c.topology = Some(Topology::two_level(
            4,
            2,
            LinkKind::NvLink,
            LinkKind::Tcp25,
        ));
        c
    }

    #[test]
    fn topology_run_splits_time_by_class() {
        let r = SimDriver::new(topology_cfg("zen")).unwrap().run();
        assert!(r.throughput > 0.0);
        assert_eq!(r.intra_time, 0.0, "transport prices intra traffic");
        let p = &r.plan[0];
        let [intra, inter] = p.measured_by_class;
        assert!(
            intra > 0.0 && inter > 0.0,
            "both link classes must carry traffic on 4x2 ({:?})",
            p.measured_by_class
        );
        // NVLink inside the node, TCP between: the fabric dominates.
        assert!(inter > intra, "intra {intra} vs inter {inter}");
        assert!((p.measured - intra.max(inter)).abs() <= p.measured * 0.5 + 1e-12);
    }

    #[test]
    fn flat_run_reports_inter_only() {
        let r = SimDriver::new(cfg("zen", 4)).unwrap().run();
        let p = &r.plan[0];
        assert_eq!(p.measured_by_class[LinkClass::Intra.idx()], 0.0);
        assert!(
            (p.measured_by_class[LinkClass::Inter.idx()] - p.measured).abs()
                < p.measured * 1e-9 + 1e-15
        );
    }

    #[test]
    fn topology_auto_plans_per_class() {
        let r = SimDriver::new(topology_cfg("auto")).unwrap().run();
        assert_eq!(r.scheme, "auto");
        let p = &r.plan[0];
        let classes = p.predicted_by_class.expect("auto predicts per class");
        assert!(classes[LinkClass::Inter.idx()] > 0.0);
        // The per-class prediction must be in the measured ballpark on
        // the dominant (inter) class.
        let mis = p.measured_by_class[1] / classes[1].max(1e-12);
        assert!((0.2..=5.0).contains(&mis), "inter measured/predicted {mis}");
    }

    #[test]
    fn topology_pipelined_runs() {
        let mut c = topology_cfg("zen");
        c.iterations = 1;
        c.pipeline = Some(PipelineConfig {
            bucket_bytes: 64 * 1024,
            dense_layers: 2,
            emb_shards: 3,
            ..PipelineConfig::default()
        });
        let r = SimDriver::new(c).unwrap().run();
        assert!(r.engine_overlapped.unwrap() > 0.0);
        assert!(r.plan.iter().any(|p| p.measured_by_class[0] > 0.0));
    }

    #[test]
    fn pipelined_zen_beats_pipelined_allreduce() {
        // Scheme choice still dominates: Zen's buckets ship sparse
        // payloads, so its pipeline drains faster than dense allreduce.
        let zen = SimDriver::new(pipelined_cfg("zen", 8)).unwrap().run();
        let dense = SimDriver::new(pipelined_cfg("allreduce", 8)).unwrap().run();
        assert!(
            zen.engine_overlapped.unwrap() < dense.engine_overlapped.unwrap(),
            "zen {:?} vs allreduce {:?}",
            zen.engine_overlapped,
            dense.engine_overlapped
        );
    }
}
