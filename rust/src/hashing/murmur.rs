//! MurmurHash3 and a seeded universal hash family over u32 indices.
//!
//! The paper implements Algorithm 1 with MurmurHash [Appleby 2008],
//! generating distinct hash functions by seeding (§4.1: "We only need to
//! set the seeds for MurmurHash to generate different hash functions").
//! We provide the canonical MurmurHash3 x86_32 for 4-byte keys plus a
//! `HashFamily` abstraction that the hierarchical hasher, strawman, and
//! hash bitmap all share. The Pallas L1 kernel
//! (`python/compile/kernels/hash.py`) implements bit-identical mixing so
//! python and rust agree on every partition assignment — asserted by
//! `python/tests/test_kernel.py` against vectors exported from here.

/// Canonical MurmurHash3 x86_32 for a single u32 key.
#[inline]
pub fn murmur3_32(key: u32, seed: u32) -> u32 {
    const C1: u32 = 0xcc9e_2d51;
    const C2: u32 = 0x1b87_3593;
    let mut k = key;
    k = k.wrapping_mul(C1);
    k = k.rotate_left(15);
    k = k.wrapping_mul(C2);
    let mut h = seed ^ k;
    h = h.rotate_left(13);
    h = h.wrapping_mul(5).wrapping_add(0xe654_6b64);
    // finalize with len = 4
    h ^= 4;
    h ^= h >> 16;
    h = h.wrapping_mul(0x85eb_ca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2_ae35);
    h ^= h >> 16;
    h
}

/// A family of `k + 1` seeded hash functions: `h0` (partition selector)
/// plus `h1..hk` (slot probes), all MurmurHash3 with distinct seeds.
#[derive(Clone, Debug)]
pub struct HashFamily {
    seeds: Vec<u32>,
}

impl HashFamily {
    /// Derive `count` seeds deterministically from a master seed. All
    /// workers must construct the family from the same master seed —
    /// Zen broadcasts the seed at job start (§4.1), our coordinator passes
    /// it through the run config.
    pub fn new(master_seed: u64, count: usize) -> Self {
        assert!(count >= 1);
        let mut rng = crate::util::Pcg64::seeded(master_seed);
        HashFamily {
            seeds: (0..count).map(|_| rng.next_u32()).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.seeds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seeds.is_empty()
    }

    pub fn seeds(&self) -> &[u32] {
        &self.seeds
    }

    /// Evaluate hash function `fi` on `idx`.
    #[inline]
    pub fn hash(&self, fi: usize, idx: u32) -> u32 {
        murmur3_32(idx, self.seeds[fi])
    }

    /// Range reduction: Lemire's multiply-shift `(h · n) >> 32` — uniform
    /// for uniform `h`, and ~10× cheaper than a 64-bit modulo, which the
    /// perf pass measured as a per-index hot spot. Mirrored bit-for-bit
    /// by the Pallas kernel (`python/compile/kernels/hash.py::_reduce`).
    #[inline]
    pub fn reduce(h: u32, n: usize) -> usize {
        ((h as u64 * n as u64) >> 32) as usize
    }

    /// `h0`: partition assignment in [0, n).
    #[inline]
    pub fn partition(&self, idx: u32, n: usize) -> usize {
        Self::reduce(self.hash(0, idx), n)
    }

    /// A value-captured `h0` partitioner for per-index hot loops
    /// (Algorithm 1 phase 1, domain construction): holds the seed and
    /// `n` by value so the inner loop carries no `seeds[0]` slice load /
    /// bounds check per element.
    #[inline]
    pub fn partitioner(&self, n: usize) -> Partitioner {
        Partitioner {
            seed: self.seeds[0],
            n,
        }
    }

    /// `h_i` for i ≥ 1: slot probe in [0, r).
    #[inline]
    pub fn slot(&self, round: usize, idx: u32, r: usize) -> usize {
        debug_assert!(round >= 1 && round < self.seeds.len());
        Self::reduce(self.hash(round, idx), r)
    }
}

/// Standalone `h0` evaluator produced by [`HashFamily::partitioner`] —
/// agrees bit-for-bit with [`HashFamily::partition`].
#[derive(Clone, Copy, Debug)]
pub struct Partitioner {
    seed: u32,
    n: usize,
}

impl Partitioner {
    #[inline]
    pub fn partition(&self, idx: u32) -> usize {
        HashFamily::reduce(murmur3_32(idx, self.seed), self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, prop_assert};

    #[test]
    fn murmur3_known_vectors() {
        // Verified against the reference MurmurHash3_x86_32 for a 4-byte
        // little-endian key. These same vectors are asserted in
        // python/tests/test_kernel.py against the Pallas kernel.
        assert_eq!(murmur3_32(0, 0), 0x2362_f9de);
        assert_eq!(murmur3_32(1, 0), 0xfbf1_402a);
        assert_eq!(murmur3_32(0x1234_5678, 0x9747_b28c), 0x461a_9426);
        assert_eq!(murmur3_32(42, 7), 0xdaef_e436);
    }

    #[test]
    fn seeds_change_hash() {
        let a = murmur3_32(1234, 1);
        let b = murmur3_32(1234, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn family_deterministic_across_workers() {
        let f1 = HashFamily::new(77, 5);
        let f2 = HashFamily::new(77, 5);
        assert_eq!(f1.seeds(), f2.seeds());
        for idx in [0u32, 1, 99, 1 << 20] {
            assert_eq!(f1.partition(idx, 16), f2.partition(idx, 16));
        }
    }

    #[test]
    fn partition_in_range() {
        let f = HashFamily::new(3, 4);
        for idx in 0..10_000u32 {
            assert!(f.partition(idx, 7) < 7);
            assert!(f.slot(1, idx, 33) < 33);
        }
    }

    #[test]
    fn partition_roughly_uniform() {
        // Theorem 2's balance rests on h0 spreading indices uniformly.
        let f = HashFamily::new(5, 2);
        let n = 16;
        let mut counts = vec![0usize; n];
        let total = 160_000u32;
        for idx in 0..total {
            counts[f.partition(idx, n)] += 1;
        }
        let expect = total as f64 / n as f64;
        for &c in &counts {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.04, "partition deviation {dev}");
        }
    }

    #[test]
    fn partitioner_agrees_with_family() {
        let f = HashFamily::new(17, 4);
        for n in [1usize, 2, 7, 16] {
            let p = f.partitioner(n);
            for idx in (0..50_000u32).step_by(97) {
                assert_eq!(p.partition(idx), f.partition(idx, n));
            }
        }
    }

    #[test]
    fn prop_family_functions_differ() {
        check(50, |g| {
            let seed = g.u64();
            let f = HashFamily::new(seed, 4);
            let idx = g.u32_in(0, u32::MAX - 1);
            // different functions in the family should disagree somewhere
            let vals: Vec<u32> = (0..4).map(|i| f.hash(i, idx)).collect();
            let all_same = vals.windows(2).all(|w| w[0] == w[1]);
            prop_assert(!all_same, "family functions independent")
        });
    }
}
