//! Algorithm 1 — the hierarchical hashing algorithm (paper §3.1.3).
//!
//! Partitions the non-zero indices of a sparse tensor across `n` servers
//! such that (Theorem 2) every partition receives `|I|/n ± O(√(|I| log n / n))`
//! indices, with **no information loss** and **consistent assignment across
//! workers** (the partition of an index depends only on `h0(idx)`).
//!
//! Memory layout per partition: `r1` parallel slots probed by `h1..hk`,
//! then a serial region (`r2` budgeted slots, growing beyond if needed)
//! so the implementation is lossless even when `r2` is undersized — the
//! paper assumes `r2` is big enough; we guarantee it structurally and
//! count overflow events so the parameter studies (Fig 16) can report
//! them.
//!
//! §Hardware-Adaptation: the paper's CUDA kernel uses per-slot CAS and
//! an `atomicAdd` cursor across a global memory. This CPU implementation
//! is reshaped for cache behaviour (see `partition`): bucket by `h0`
//! first, then probe each partition's private region — same mapping and
//! guarantees, no atomics. The Pallas L1 kernel replaces CAS with
//! deterministic scatter-min rounds (python/compile/kernels/hash.py).

use std::sync::atomic::{AtomicUsize, Ordering};

use super::murmur::HashFamily;
use crate::tensor::CooTensor;
use crate::util::ThreadPool;

/// Result of hashing one worker's sparse tensor into `n` partitions.
#[derive(Clone, Debug)]
pub struct PartitionOutput {
    /// Per-partition sparse tensors carrying **global** indices, sorted.
    pub parts: Vec<CooTensor>,
    /// Number of indices that needed the serial memory (collided in all
    /// `k` parallel rounds).
    pub serial_writes: usize,
    /// Number of indices that overflowed even the serial memory.
    pub overflow_writes: usize,
}

impl PartitionOutput {
    /// Imbalance ratio of Push for this worker (Definition 6):
    /// `max_j n·|I_i^j| / |I_i|`.
    pub fn push_imbalance(&self) -> f64 {
        let total: usize = self.parts.iter().map(|p| p.nnz()).sum();
        if total == 0 {
            return 1.0;
        }
        let max = self.parts.iter().map(|p| p.nnz()).max().unwrap_or(0);
        max as f64 * self.parts.len() as f64 / total as f64
    }
}

/// Configuration + state for Algorithm 1.
#[derive(Clone, Debug)]
pub struct HierarchicalHasher {
    family: HashFamily,
    /// Number of partitions (servers) `n`.
    pub n: usize,
    /// Rehash rounds `k`.
    pub k: usize,
    /// Parallel memory slots per partition `r1`.
    pub r1: usize,
    /// Serial memory slots per partition `r2`.
    pub r2: usize,
    pool: ThreadPool,
}

impl HierarchicalHasher {
    /// The paper's default parameterization (§4.2): `k = 3`,
    /// `r1 = 2·|G|·d_G` (≈ 2× the expected nnz), `r2 = r1/10`.
    pub fn with_defaults(master_seed: u64, n: usize, expected_nnz: usize) -> Self {
        let r1_total = (2 * expected_nnz).max(64);
        Self::new(master_seed, n, 3, r1_total / n + 1, r1_total / n / 10 + 1)
    }

    /// Explicit parameters. `r1`/`r2` are per-partition slot counts.
    pub fn new(master_seed: u64, n: usize, k: usize, r1: usize, r2: usize) -> Self {
        assert!(n >= 1 && k >= 1 && r1 >= 1);
        HierarchicalHasher {
            family: HashFamily::new(master_seed, k + 1),
            n,
            k,
            r1,
            r2,
            pool: ThreadPool::new(),
        }
    }

    pub fn family(&self) -> &HashFamily {
        &self.family
    }

    /// Override the worker pool (tests / perf studies).
    pub fn with_pool(mut self, pool: ThreadPool) -> Self {
        self.pool = pool;
        self
    }

    /// Run Algorithm 1 on a sparse tensor. Returns per-partition sparse
    /// tensors over the global index space (sorted, lossless).
    ///
    /// CPU shaping (perf pass, EXPERIMENTS.md §Perf): the paper's GPU
    /// kernel probes a global `n × (r1+r2)` memory with atomics from all
    /// threads. On CPU that meant every probe missed cache in a
    /// multi-megabyte array. We instead (1) bucket index positions by
    /// `h0` partition in one sequential pass, then (2) probe each
    /// partition's *private* `r1` region — which fits L2 — with plain
    /// stores, parallelizing over partitions instead of indices. Same
    /// mapping, same guarantees (partition assignment depends only on
    /// h0; probe order within a partition is irrelevant), ~2× faster
    /// single-core and contention-free multi-core.
    pub fn partition(&self, t: &CooTensor) -> PartitionOutput {
        let nnz = t.nnz();
        // Phase 1: bucket (index, value) pairs by partition (the h0
        // pass). Carrying the value keeps phase 2 entirely inside the
        // L2-sized bucket — no random loads from the big tensor arrays.
        let mut buckets: Vec<Vec<(u32, f32)>> = (0..self.n)
            .map(|_| Vec::with_capacity(nnz / self.n + 16))
            .collect();
        for (&idx, &val) in t.indices.iter().zip(t.values.iter()) {
            buckets[self.family.partition(idx, self.n)].push((idx, val));
        }

        // Phase 2: per-partition probing; partitions are independent.
        let serial_count = AtomicUsize::new(0);
        let overflow_count = AtomicUsize::new(0);
        let results: Vec<std::sync::Mutex<Option<CooTensor>>> =
            (0..self.n).map(|_| std::sync::Mutex::new(None)).collect();
        let process = |p: usize| {
            let bucket = &buckets[p];
            // Slot value: 0 = empty, else (bucket entry index) + 1 —
            // O(1) entry lookup at extraction, supports idx = 0.
            let mut slots = vec![0u32; self.r1];
            let mut serial: Vec<u32> = Vec::new();
            for (e, &(idx, _)) in bucket.iter().enumerate() {
                let mut placed = false;
                for round in 1..=self.k {
                    let q = self.family.slot(round, idx, self.r1);
                    if slots[q] == 0 {
                        slots[q] = e as u32 + 1;
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    // Serial memory (lines 8–11); overflow beyond r2 is
                    // kept too — structural losslessness.
                    serial.push(e as u32 + 1);
                }
            }
            serial_count.fetch_add(serial.len(), Ordering::Relaxed);
            overflow_count.fetch_add(serial.len().saturating_sub(self.r2), Ordering::Relaxed);

            // Extraction (lines 19–23).
            let mut idxs: Vec<u32> = Vec::with_capacity(bucket.len());
            let mut vals: Vec<f32> = Vec::with_capacity(bucket.len());
            for &v in slots.iter().chain(serial.iter()) {
                if v != 0 {
                    let (idx, val) = bucket[(v - 1) as usize];
                    idxs.push(idx);
                    vals.push(val);
                }
            }
            // Sort by global index so downstream merges are linear (the
            // paper notes order is irrelevant for aggregation; we keep
            // the COO invariant). Radix beats comparison sort here.
            crate::util::radix::radix_sort_pairs(&mut idxs, &mut vals);
            *results[p].lock().unwrap() =
                Some(CooTensor::from_sorted(t.dense_len, idxs, vals));
        };
        if self.pool.workers() > 1 && self.n > 1 {
            self.pool.for_ranges(self.n, |range| {
                for p in range {
                    process(p);
                }
            });
        } else {
            for p in 0..self.n {
                process(p);
            }
        }
        let parts: Vec<CooTensor> = results
            .into_iter()
            .map(|m| m.into_inner().unwrap().unwrap())
            .collect();

        PartitionOutput {
            parts,
            serial_writes: serial_count.load(Ordering::Relaxed),
            overflow_writes: overflow_count.load(Ordering::Relaxed),
        }
    }

    /// The set `𝕀_p = {idx ∈ [0, |G|) | h0(idx) = p}` — the index domain
    /// of partition `p`, needed by the hash bitmap (Algorithm 2). Computed
    /// offline once per (h0, |G|) pair, as the paper prescribes.
    pub fn partition_domain(&self, dense_len: usize, p: usize) -> Vec<u32> {
        (0..dense_len as u32)
            .filter(|&idx| self.family.partition(idx, self.n) == p)
            .collect()
    }

    /// All partition domains in one pass (cheaper than n× partition_domain).
    pub fn partition_domains(&self, dense_len: usize) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::with_capacity(dense_len / self.n + 8); self.n];
        for idx in 0..dense_len as u32 {
            out[self.family.partition(idx, self.n)].push(idx);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, prop_assert};
    use crate::util::Pcg64;

    fn random_coo(seed: u64, dense_len: usize, nnz: usize) -> CooTensor {
        let mut rng = Pcg64::seeded(seed);
        let mut idx = rng.sample_distinct(dense_len, nnz);
        idx.sort_unstable();
        let vals: Vec<f32> = (0..nnz).map(|_| rng.next_f32() + 0.01).collect();
        CooTensor::from_sorted(dense_len, idx.into_iter().map(|i| i as u32).collect(), vals)
    }

    #[test]
    fn lossless_partitioning() {
        let t = random_coo(1, 10_000, 800);
        let h = HierarchicalHasher::with_defaults(42, 8, t.nnz());
        let out = h.partition(&t);
        assert_eq!(out.parts.len(), 8);
        let merged = CooTensor::merge_all(&out.parts);
        assert_eq!(merged, t, "no index/value may be lost or duplicated");
        assert_eq!(out.overflow_writes, 0);
    }

    #[test]
    fn lossless_under_tiny_memory() {
        // Force heavy collisions: r1 smaller than nnz/n, r2 tiny.
        let t = random_coo(2, 5_000, 1_000);
        let h = HierarchicalHasher::new(7, 4, 2, 16, 4);
        let out = h.partition(&t);
        let merged = CooTensor::merge_all(&out.parts);
        assert_eq!(merged, t);
        assert!(out.serial_writes > 0, "expected serial-memory pressure");
        assert!(out.overflow_writes > 0, "expected overflow pressure");
    }

    #[test]
    fn assignment_consistent_across_workers() {
        // Same index on two different workers must land in the same
        // partition — the incomplete-aggregation hazard of §3.1.3.
        let t1 = random_coo(3, 20_000, 1_500);
        let t2 = random_coo(4, 20_000, 1_500);
        let h = HierarchicalHasher::with_defaults(99, 8, 1_500);
        let o1 = h.partition(&t1);
        let o2 = h.partition(&t2);
        for p in 0..8 {
            for &idx in &o1.parts[p].indices {
                assert_eq!(h.family().partition(idx, 8), p);
            }
            for &idx in &o2.parts[p].indices {
                assert_eq!(h.family().partition(idx, 8), p);
            }
        }
    }

    #[test]
    fn push_imbalance_near_one() {
        // Theorem 2: imbalance ratio ≈ 1 + Θ(√(n log n / nnz)).
        let t = random_coo(5, 500_000, 50_000);
        let n = 16;
        let h = HierarchicalHasher::with_defaults(11, n, t.nnz());
        let out = h.partition(&t);
        let ratio = out.push_imbalance();
        // paper measures < 1.1 for real models; allow some slack at this nnz
        assert!(ratio < 1.12, "push imbalance {ratio}");
    }

    #[test]
    fn skewed_input_still_balanced() {
        // All non-zeros concentrated in the first 2% of the range —
        // contiguous partitioning would be maximally skewed; hashing must
        // stay balanced (the entire point of Alg 1).
        let mut rng = Pcg64::seeded(6);
        let dense_len = 1_000_000;
        let hot = dense_len / 50;
        let mut idx: Vec<u32> = rng
            .sample_distinct(hot, 20_000)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        idx.sort_unstable();
        let vals = vec![1.0f32; idx.len()];
        let t = CooTensor::from_sorted(dense_len, idx, vals);
        let h = HierarchicalHasher::with_defaults(13, 16, t.nnz());
        let out = h.partition(&t);
        assert!(out.push_imbalance() < 1.15, "imbalance {}", out.push_imbalance());
    }

    #[test]
    fn partition_domains_are_disjoint_cover() {
        let h = HierarchicalHasher::with_defaults(21, 5, 100);
        let domains = h.partition_domains(1_000);
        let total: usize = domains.iter().map(|d| d.len()).sum();
        assert_eq!(total, 1_000);
        for (p, d) in domains.iter().enumerate() {
            assert_eq!(*d, h.partition_domain(1_000, p));
            assert!(d.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn prop_lossless_any_shape() {
        check(40, |g| {
            let dense_len = g.usize_in(8, 4_000);
            let nnz = g.usize_in(0, dense_len.min(300));
            let idx = g.distinct_sorted_u32(nnz, dense_len as u32);
            let vals: Vec<f32> = (0..nnz).map(|_| g.f64_unit() as f32 + 0.01).collect();
            let t = CooTensor::from_sorted(dense_len, idx, vals);
            let n = g.usize_in(1, 12);
            let k = g.usize_in(1, 4);
            let r1 = g.usize_in(1, 64);
            let r2 = g.usize_in(0, 16).max(1);
            let h = HierarchicalHasher::new(g.u64(), n, k, r1, r2);
            let out = h.partition(&t);
            let merged = CooTensor::merge_all(&out.parts);
            prop_assert(merged == t, "lossless for any (n,k,r1,r2)")
        });
    }
}
