//! Algorithm 1 — the hierarchical hashing algorithm (paper §3.1.3).
//!
//! Partitions the non-zero indices of a sparse tensor across `n` servers
//! such that (Theorem 2) every partition receives `|I|/n ± O(√(|I| log n / n))`
//! indices, with **no information loss** and **consistent assignment across
//! workers** (the partition of an index depends only on `h0(idx)`).
//!
//! Memory layout per partition: `r1` parallel slots probed by `h1..hk`,
//! then a serial region (`r2` budgeted slots, growing beyond if needed)
//! so the implementation is lossless even when `r2` is undersized — the
//! paper assumes `r2` is big enough; we guarantee it structurally and
//! count overflow events so the parameter studies (Fig 16) can report
//! them.
//!
//! §Hardware-Adaptation: the paper's CUDA kernel uses per-slot CAS and
//! an `atomicAdd` cursor across a global memory. This CPU implementation
//! is reshaped for cache behaviour (see `partition`): bucket by `h0`
//! first, then probe each partition's private region — same mapping and
//! guarantees, no atomics. The Pallas L1 kernel replaces CAS with
//! deterministic scatter-min rounds (python/compile/kernels/hash.py).

use super::murmur::HashFamily;
use crate::tensor::{CooSlice, CooTensor};
use crate::util::radix::RadixScratch;
use crate::util::ThreadPool;

/// Result of hashing one worker's sparse tensor into `n` partitions.
#[derive(Clone, Debug)]
pub struct PartitionOutput {
    /// Per-partition sparse tensors carrying **global** indices, sorted.
    pub parts: Vec<CooTensor>,
    /// Number of indices that needed the serial memory (collided in all
    /// `k` parallel rounds).
    pub serial_writes: usize,
    /// Number of indices that overflowed even the serial memory.
    pub overflow_writes: usize,
}

impl PartitionOutput {
    /// Imbalance ratio of Push for this worker (Definition 6):
    /// `max_j n·|I_i^j| / |I_i|`.
    pub fn push_imbalance(&self) -> f64 {
        imbalance_of_sizes(self.parts.iter().map(|p| p.nnz()))
    }
}

/// `n · max / total` over per-partition sizes (Definition 6); 1.0 for
/// an all-empty run. Shared by the owned and scratch partition paths.
fn imbalance_of_sizes<I: Iterator<Item = usize>>(sizes: I) -> f64 {
    let (mut total, mut max, mut n) = (0usize, 0usize, 0usize);
    for s in sizes {
        total += s;
        max = max.max(s);
        n += 1;
    }
    if total == 0 {
        1.0
    } else {
        max as f64 * n as f64 / total as f64
    }
}

/// Reusable working memory for [`HierarchicalHasher::partition_into`]:
/// one [`PartitionShard`] per partition, each owning its h0 bucket, probe
/// slots, serial memory, sorted output buffers, and radix-sort scratch.
///
/// Shards are `Send` and mutually disjoint, so phase 2 distributes
/// contiguous shard runs across the thread pool with plain `&mut` access
/// — no atomics, no result mutexes. After `partition_into` returns, the
/// partitions are readable as zero-copy [`CooSlice`]s via
/// [`part`](PartitionScratch::part) until the next call. All buffers are
/// cleared (never shrunk) between calls: steady-state repartitioning of
/// a stable workload performs zero heap allocations.
#[derive(Debug, Default)]
pub struct PartitionScratch {
    shards: Vec<PartitionShard>,
    dense_len: usize,
}

/// One partition's private working memory (see [`PartitionScratch`]).
#[derive(Debug, Default)]
pub struct PartitionShard {
    /// Phase-1 h0 bucket: (index, value) pairs, parallel arrays.
    bucket_idx: Vec<u32>,
    bucket_val: Vec<f32>,
    /// Parallel probe slots: 0 = empty, else bucket entry index + 1.
    slots: Vec<u32>,
    /// Serial memory: bucket entry indices + 1.
    serial: Vec<u32>,
    /// Extracted partition, sorted by global index.
    out_idx: Vec<u32>,
    out_val: Vec<f32>,
    sort: RadixScratch,
    serial_writes: usize,
    overflow_writes: usize,
}

impl PartitionScratch {
    pub fn new() -> Self {
        PartitionScratch::default()
    }

    /// Prepare for a run with `n` partitions and `r1` probe slots each.
    fn reset(&mut self, n: usize, r1: usize, dense_len: usize) {
        self.dense_len = dense_len;
        self.shards.resize_with(n, PartitionShard::default);
        for shard in self.shards.iter_mut() {
            shard.bucket_idx.clear();
            shard.bucket_val.clear();
            shard.slots.clear();
            shard.slots.resize(r1, 0);
            shard.serial.clear();
            shard.out_idx.clear();
            shard.out_val.clear();
            shard.serial_writes = 0;
            shard.overflow_writes = 0;
        }
    }

    /// Number of partitions produced by the last `partition_into`.
    pub fn num_parts(&self) -> usize {
        self.shards.len()
    }

    /// Partition `p` of the last run, as a zero-copy view (sorted global
    /// indices over the input's dense length).
    pub fn part(&self, p: usize) -> CooSlice<'_> {
        let shard = &self.shards[p];
        CooSlice::new(self.dense_len, &shard.out_idx, &shard.out_val)
    }

    /// Indices that needed the serial memory across all partitions.
    pub fn serial_writes(&self) -> usize {
        self.shards.iter().map(|s| s.serial_writes).sum()
    }

    /// Indices that overflowed even the `r2` serial budget.
    pub fn overflow_writes(&self) -> usize {
        self.shards.iter().map(|s| s.overflow_writes).sum()
    }

    /// Imbalance ratio of Push for this run (Definition 6), matching
    /// [`PartitionOutput::push_imbalance`].
    pub fn push_imbalance(&self) -> f64 {
        imbalance_of_sizes(self.shards.iter().map(|s| s.out_idx.len()))
    }
}

/// Configuration + state for Algorithm 1.
#[derive(Clone, Debug)]
pub struct HierarchicalHasher {
    family: HashFamily,
    /// Number of partitions (servers) `n`.
    pub n: usize,
    /// Rehash rounds `k`.
    pub k: usize,
    /// Parallel memory slots per partition `r1`.
    pub r1: usize,
    /// Serial memory slots per partition `r2`.
    pub r2: usize,
    pool: ThreadPool,
}

impl HierarchicalHasher {
    /// The paper's default parameterization (§4.2): `k = 3`,
    /// `r1 = 2·|G|·d_G` (≈ 2× the expected nnz), `r2 = r1/10`.
    pub fn with_defaults(master_seed: u64, n: usize, expected_nnz: usize) -> Self {
        let r1_total = (2 * expected_nnz).max(64);
        Self::new(master_seed, n, 3, r1_total / n + 1, r1_total / n / 10 + 1)
    }

    /// Explicit parameters. `r1`/`r2` are per-partition slot counts.
    pub fn new(master_seed: u64, n: usize, k: usize, r1: usize, r2: usize) -> Self {
        assert!(n >= 1 && k >= 1 && r1 >= 1);
        HierarchicalHasher {
            family: HashFamily::new(master_seed, k + 1),
            n,
            k,
            r1,
            r2,
            pool: ThreadPool::new(),
        }
    }

    pub fn family(&self) -> &HashFamily {
        &self.family
    }

    /// Override the worker pool (tests / perf studies).
    pub fn with_pool(mut self, pool: ThreadPool) -> Self {
        self.pool = pool;
        self
    }

    /// Run Algorithm 1 on a sparse tensor. Returns per-partition sparse
    /// tensors over the global index space (sorted, lossless).
    ///
    /// Allocating convenience wrapper over [`partition_into`]; tests,
    /// figures, and one-shot callers use this, the sync hot path passes
    /// a reused [`PartitionScratch`] instead.
    ///
    /// [`partition_into`]: HierarchicalHasher::partition_into
    pub fn partition(&self, t: &CooTensor) -> PartitionOutput {
        let mut scratch = PartitionScratch::new();
        self.partition_into(t, &mut scratch);
        let serial_writes = scratch.serial_writes();
        let overflow_writes = scratch.overflow_writes();
        let parts = scratch
            .shards
            .drain(..)
            .map(|mut s| {
                CooTensor::from_sorted(
                    t.dense_len,
                    std::mem::take(&mut s.out_idx),
                    std::mem::take(&mut s.out_val),
                )
            })
            .collect();
        PartitionOutput {
            parts,
            serial_writes,
            overflow_writes,
        }
    }

    /// Run Algorithm 1 into a reused [`PartitionScratch`] —
    /// allocation-free at steady state (every buffer is `clear()`ed and
    /// refilled; capacities persist across calls).
    ///
    /// CPU shaping (perf pass, EXPERIMENTS.md §Perf): the paper's GPU
    /// kernel probes a global `n × (r1+r2)` memory with atomics from all
    /// threads. On CPU that meant every probe missed cache in a
    /// multi-megabyte array. We instead (1) bucket (index, value) pairs
    /// by `h0` partition in one sequential pass, then (2) probe each
    /// partition's *private* `r1` region — which fits L2 — with plain
    /// stores, parallelizing over partition shards instead of indices.
    /// Same mapping, same guarantees (partition assignment depends only
    /// on h0; probe order within a partition is irrelevant). Each worker
    /// thread owns a disjoint contiguous run of shards
    /// ([`ThreadPool::scoped_chunks`]), so phase 2 needs no atomics and
    /// no locks, and the per-shard serial/overflow tallies are merged
    /// after the join.
    pub fn partition_into(&self, t: &CooTensor, scratch: &mut PartitionScratch) {
        scratch.reset(self.n, self.r1, t.dense_len);

        // Phase 1: bucket (index, value) pairs by partition (the h0
        // pass). Carrying the value keeps phase 2 entirely inside the
        // L2-sized shard — no random loads from the big tensor arrays.
        let h0 = self.family.partitioner(self.n);
        let shards = &mut scratch.shards;
        crate::kernel::active::partition_scatter(
            |idx| h0.partition(idx),
            &t.indices,
            &t.values,
            |p, idx, val| {
                shards[p].bucket_idx.push(idx);
                shards[p].bucket_val.push(val);
            },
        );

        // Phase 2: per-shard probing; shards are independent.
        let (k, r1, r2) = (self.k, self.r1, self.r2);
        let family = &self.family;
        let process = |shard: &mut PartitionShard| {
            // Slot value: 0 = empty, else (bucket entry index) + 1 —
            // O(1) entry lookup at extraction, supports idx = 0.
            for (e, &idx) in shard.bucket_idx.iter().enumerate() {
                let mut placed = false;
                for round in 1..=k {
                    let q = family.slot(round, idx, r1);
                    if shard.slots[q] == 0 {
                        shard.slots[q] = e as u32 + 1;
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    // Serial memory (lines 8–11); overflow beyond r2 is
                    // kept too — structural losslessness.
                    shard.serial.push(e as u32 + 1);
                }
            }
            shard.serial_writes = shard.serial.len();
            shard.overflow_writes = shard.serial.len().saturating_sub(r2);

            // Extraction (lines 19–23).
            for &v in shard.slots.iter().chain(shard.serial.iter()) {
                if v != 0 {
                    let e = (v - 1) as usize;
                    shard.out_idx.push(shard.bucket_idx[e]);
                    shard.out_val.push(shard.bucket_val[e]);
                }
            }
            // Sort by global index so downstream merges are linear (the
            // paper notes order is irrelevant for aggregation; we keep
            // the COO invariant). Radix beats comparison sort here.
            crate::util::radix::radix_sort_pairs_with(
                &mut shard.out_idx,
                &mut shard.out_val,
                &mut shard.sort,
            );
        };
        if self.pool.workers() > 1 && self.n > 1 {
            let per = crate::util::ceil_div(self.n, self.pool.workers());
            self.pool.scoped_chunks(&mut scratch.shards, per, |_, chunk| {
                for shard in chunk.iter_mut() {
                    process(shard);
                }
            });
        } else {
            for shard in scratch.shards.iter_mut() {
                process(shard);
            }
        }
    }

    /// The set `𝕀_p = {idx ∈ [0, |G|) | h0(idx) = p}` — the index domain
    /// of partition `p`, needed by the hash bitmap (Algorithm 2). Computed
    /// offline once per (h0, |G|) pair, as the paper prescribes.
    pub fn partition_domain(&self, dense_len: usize, p: usize) -> Vec<u32> {
        (0..dense_len as u32)
            .filter(|&idx| self.family.partition(idx, self.n) == p)
            .collect()
    }

    /// All partition domains in one pass (cheaper than n× partition_domain).
    pub fn partition_domains(&self, dense_len: usize) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::with_capacity(dense_len / self.n + 8); self.n];
        let h0 = self.family.partitioner(self.n);
        for idx in 0..dense_len as u32 {
            out[h0.partition(idx)].push(idx);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, prop_assert};
    use crate::util::Pcg64;

    fn random_coo(seed: u64, dense_len: usize, nnz: usize) -> CooTensor {
        let mut rng = Pcg64::seeded(seed);
        let mut idx = rng.sample_distinct(dense_len, nnz);
        idx.sort_unstable();
        let vals: Vec<f32> = (0..nnz).map(|_| rng.next_f32() + 0.01).collect();
        CooTensor::from_sorted(dense_len, idx.into_iter().map(|i| i as u32).collect(), vals)
    }

    #[test]
    fn lossless_partitioning() {
        let t = random_coo(1, 10_000, 800);
        let h = HierarchicalHasher::with_defaults(42, 8, t.nnz());
        let out = h.partition(&t);
        assert_eq!(out.parts.len(), 8);
        let merged = CooTensor::merge_all(&out.parts);
        assert_eq!(merged, t, "no index/value may be lost or duplicated");
        assert_eq!(out.overflow_writes, 0);
    }

    #[test]
    fn lossless_under_tiny_memory() {
        // Force heavy collisions: r1 smaller than nnz/n, r2 tiny.
        let t = random_coo(2, 5_000, 1_000);
        let h = HierarchicalHasher::new(7, 4, 2, 16, 4);
        let out = h.partition(&t);
        let merged = CooTensor::merge_all(&out.parts);
        assert_eq!(merged, t);
        assert!(out.serial_writes > 0, "expected serial-memory pressure");
        assert!(out.overflow_writes > 0, "expected overflow pressure");
    }

    #[test]
    fn assignment_consistent_across_workers() {
        // Same index on two different workers must land in the same
        // partition — the incomplete-aggregation hazard of §3.1.3.
        let t1 = random_coo(3, 20_000, 1_500);
        let t2 = random_coo(4, 20_000, 1_500);
        let h = HierarchicalHasher::with_defaults(99, 8, 1_500);
        let o1 = h.partition(&t1);
        let o2 = h.partition(&t2);
        for p in 0..8 {
            for &idx in &o1.parts[p].indices {
                assert_eq!(h.family().partition(idx, 8), p);
            }
            for &idx in &o2.parts[p].indices {
                assert_eq!(h.family().partition(idx, 8), p);
            }
        }
    }

    #[test]
    fn push_imbalance_near_one() {
        // Theorem 2: imbalance ratio ≈ 1 + Θ(√(n log n / nnz)).
        let t = random_coo(5, 500_000, 50_000);
        let n = 16;
        let h = HierarchicalHasher::with_defaults(11, n, t.nnz());
        let out = h.partition(&t);
        let ratio = out.push_imbalance();
        // paper measures < 1.1 for real models; allow some slack at this nnz
        assert!(ratio < 1.12, "push imbalance {ratio}");
    }

    #[test]
    fn skewed_input_still_balanced() {
        // All non-zeros concentrated in the first 2% of the range —
        // contiguous partitioning would be maximally skewed; hashing must
        // stay balanced (the entire point of Alg 1).
        let mut rng = Pcg64::seeded(6);
        let dense_len = 1_000_000;
        let hot = dense_len / 50;
        let mut idx: Vec<u32> = rng
            .sample_distinct(hot, 20_000)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        idx.sort_unstable();
        let vals = vec![1.0f32; idx.len()];
        let t = CooTensor::from_sorted(dense_len, idx, vals);
        let h = HierarchicalHasher::with_defaults(13, 16, t.nnz());
        let out = h.partition(&t);
        assert!(out.push_imbalance() < 1.15, "imbalance {}", out.push_imbalance());
    }

    #[test]
    fn partition_domains_are_disjoint_cover() {
        let h = HierarchicalHasher::with_defaults(21, 5, 100);
        let domains = h.partition_domains(1_000);
        let total: usize = domains.iter().map(|d| d.len()).sum();
        assert_eq!(total, 1_000);
        for (p, d) in domains.iter().enumerate() {
            assert_eq!(*d, h.partition_domain(1_000, p));
            assert!(d.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn scratch_path_matches_allocating_path() {
        // One scratch reused across different tensors, hasher shapes,
        // and partition counts must never leak state between runs.
        let mut scratch = PartitionScratch::new();
        for (seed, dense_len, nnz, n) in [
            (10u64, 20_000usize, 1_500usize, 8usize),
            (11, 500, 60, 3),
            (12, 40_000, 3_000, 16),
            (13, 1_000, 0, 4),
            (14, 20_000, 1_500, 8),
        ] {
            let t = random_coo(seed, dense_len, nnz);
            let h = HierarchicalHasher::with_defaults(77, n, nnz.max(16));
            let owned = h.partition(&t);
            h.partition_into(&t, &mut scratch);
            assert_eq!(scratch.num_parts(), n);
            assert_eq!(scratch.serial_writes(), owned.serial_writes);
            assert_eq!(scratch.overflow_writes(), owned.overflow_writes);
            assert!((scratch.push_imbalance() - owned.push_imbalance()).abs() < 1e-12);
            for p in 0..n {
                let view = scratch.part(p);
                assert_eq!(view.indices, &owned.parts[p].indices[..]);
                assert_eq!(view.values, &owned.parts[p].values[..]);
                assert_eq!(view.dense_len, dense_len);
            }
        }
    }

    #[test]
    fn scratch_reuse_is_lossless_under_memory_pressure() {
        let mut scratch = PartitionScratch::new();
        let h = HierarchicalHasher::new(7, 4, 2, 16, 4);
        for seed in 0..4u64 {
            let t = random_coo(seed + 20, 5_000, 1_000);
            h.partition_into(&t, &mut scratch);
            let parts: Vec<CooTensor> = (0..4).map(|p| scratch.part(p).to_tensor()).collect();
            let merged = CooTensor::merge_all(&parts);
            assert_eq!(merged, t, "seed {seed}");
            assert!(scratch.serial_writes() > 0);
            assert!(scratch.overflow_writes() > 0);
        }
    }

    #[test]
    fn prop_lossless_any_shape() {
        check(40, |g| {
            let dense_len = g.usize_in(8, 4_000);
            let nnz = g.usize_in(0, dense_len.min(300));
            let idx = g.distinct_sorted_u32(nnz, dense_len as u32);
            let vals: Vec<f32> = (0..nnz).map(|_| g.f64_unit() as f32 + 0.01).collect();
            let t = CooTensor::from_sorted(dense_len, idx, vals);
            let n = g.usize_in(1, 12);
            let k = g.usize_in(1, 4);
            let r1 = g.usize_in(1, 64);
            let r2 = g.usize_in(0, 16).max(1);
            let h = HierarchicalHasher::new(g.u64(), n, k, r1, r2);
            let out = h.partition(&t);
            let merged = CooTensor::merge_all(&out.parts);
            prop_assert(merged == t, "lossless for any (n,k,r1,r2)")
        });
    }
}
