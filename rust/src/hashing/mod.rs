//! Hashing substrate and the paper's core algorithms.
//!
//! - [`murmur`]: MurmurHash3 plus a seeded universal family (the paper uses
//!   MurmurHash with per-run random seeds broadcast to all workers, §4.1).
//! - [`hierarchical`]: Algorithm 1 — the hierarchical hashing algorithm
//!   that realizes Balanced Parallelism with no information loss.
//! - [`strawman`]: Algorithm 3 (lossy single-hash strawman) and the
//!   data-dependent threshold partitioner (§3.1.2), both baselines.
//! - [`hashbitmap`]: Algorithm 2 — the hash-bitmap index format used in
//!   Pull (Theorem 3: constant `|G|/32` index overhead per worker).

pub mod hashbitmap;
pub mod hierarchical;
pub mod murmur;
pub mod strawman;

pub use hashbitmap::{HashBitmapCodec, HashBitmapPayload};
pub use hierarchical::{HierarchicalHasher, PartitionOutput, PartitionScratch};
pub use murmur::{murmur3_32, HashFamily, Partitioner};
pub use strawman::{StrawmanHasher, ThresholdPartitioner};
