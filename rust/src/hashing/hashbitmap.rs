//! Algorithm 2 — the hash bitmap (paper §3.2.2).
//!
//! After Algorithm 1, server `p` holds aggregated gradients whose indices
//! all lie in the *partition domain* `𝕀_p = {idx | h0(idx) = p}` — a set
//! that is identical on every worker and server (same `h0`), computed and
//! sorted offline. The server therefore encodes "which domain members are
//! non-zero" as a bitmap over the *positions within `𝕀_p`*, not over the
//! whole range: size `|𝕀_p|/8` bytes, and Theorem 3 gives a constant
//! total of `|G|/32` FP32-equivalents per worker across all servers —
//! versus `n·|G|/32` for a naive positional bitmap.

use crate::tensor::{Bitmap, CooSlice, CooTensor, WireFormat};

/// Encoder/decoder for one partition's hash bitmap, bound to the
/// partition domain `𝕀_p` (sorted ascending). Borrows the domain —
/// domains are multi-megabyte at real model sizes and are computed
/// once per (h0, |G|); cloning them per sync was the top hot-spot of
/// the first perf pass (EXPERIMENTS.md §Perf).
#[derive(Clone, Debug)]
pub struct HashBitmapCodec<'a> {
    /// Sorted domain `𝕀_p`.
    domain: &'a [u32],
}

/// A transmitted pull payload: the hash bitmap + the non-zero values in
/// domain order. Reusable: [`HashBitmapCodec::encode_into`] resets and
/// refills an existing payload without reallocating.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HashBitmapPayload {
    pub bitmap: Bitmap,
    pub values: Vec<f32>,
}

impl WireFormat for HashBitmapPayload {
    fn wire_bytes(&self) -> usize {
        self.bitmap.wire_bytes() + self.values.len() * crate::tensor::BYTES_F32
    }
}

impl<'a> HashBitmapCodec<'a> {
    pub fn new(domain: &'a [u32]) -> Self {
        debug_assert!(domain.windows(2).all(|w| w[0] < w[1]), "domain must be sorted");
        HashBitmapCodec { domain }
    }

    pub fn domain(&self) -> &[u32] {
        self.domain
    }

    pub fn domain_len(&self) -> usize {
        self.domain.len()
    }

    /// `hash_bitmap_encode` (Alg 2): given the aggregated sparse tensor at
    /// this server (global indices, all members of the domain), produce
    /// the positional bitmap over the domain + values in domain order.
    ///
    /// Allocating convenience wrapper over
    /// [`encode_into`](HashBitmapCodec::encode_into).
    pub fn encode(&self, t: &CooTensor) -> HashBitmapPayload {
        let mut payload = HashBitmapPayload::default();
        self.encode_into(t.as_slice(), &mut payload);
        payload
    }

    /// `hash_bitmap_encode` into a reused payload: the bitmap's word
    /// buffer and the value vector are cleared and refilled in place —
    /// zero heap allocations once `out` has warmed to steady-state size.
    pub fn encode_into(&self, t: CooSlice<'_>, out: &mut HashBitmapPayload) {
        out.bitmap.reset(self.domain.len());
        out.values.clear();
        out.values.reserve(t.nnz());
        // Both `t.indices` and `domain` are sorted: linear merge.
        let mut d = 0usize;
        for (&idx, &v) in t.indices.iter().zip(t.values.iter()) {
            d = crate::kernel::active::domain_rank(self.domain, d, idx);
            assert!(
                d < self.domain.len() && self.domain[d] == idx,
                "index {idx} not in partition domain — h0 mismatch between \
                 worker and server"
            );
            out.bitmap.set(d);
            out.values.push(v);
        }
    }

    /// `hash_bitmap_decode` (Alg 2): recover the global-index sparse
    /// tensor from the bitmap + values.
    ///
    /// Allocating convenience wrapper over
    /// [`decode_into`](HashBitmapCodec::decode_into).
    pub fn decode(&self, payload: &HashBitmapPayload, dense_len: usize) -> CooTensor {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        self.decode_into(payload, &mut indices, &mut values);
        CooTensor::from_sorted(dense_len, indices, values)
    }

    /// `hash_bitmap_decode` into reused index/value buffers (cleared
    /// first) — the zero-allocation steady-state decode path. Output
    /// indices are global and ascending, values parallel to them.
    pub fn decode_into(
        &self,
        payload: &HashBitmapPayload,
        indices: &mut Vec<u32>,
        values: &mut Vec<f32>,
    ) {
        indices.clear();
        values.clear();
        indices.reserve(payload.values.len());
        values.reserve(payload.values.len());
        payload.bitmap.for_each_one(|pos| indices.push(self.domain[pos]));
        assert_eq!(
            indices.len(),
            payload.values.len(),
            "bitmap popcount must match value count"
        );
        values.extend_from_slice(&payload.values);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::HierarchicalHasher;
    use crate::tensor::BYTES_F32;
    use crate::util::propcheck::{check, prop_assert};
    use crate::util::Pcg64;

    fn random_coo(seed: u64, dense_len: usize, nnz: usize) -> CooTensor {
        let mut rng = Pcg64::seeded(seed);
        let mut idx = rng.sample_distinct(dense_len, nnz);
        idx.sort_unstable();
        CooTensor::from_sorted(
            dense_len,
            idx.into_iter().map(|i| i as u32).collect(),
            (0..nnz).map(|_| rng.next_f32() + 0.01).collect(),
        )
    }

    #[test]
    fn paper_worked_example() {
        // Fig 10: |G| = 15, 3 servers, 𝕀_0 with non-zeros at {5, 7}.
        // We reproduce the mechanics with an explicit domain.
        let codec = HashBitmapCodec::new(&[2, 5, 7, 11, 14]);
        let t = CooTensor::from_sorted(15, vec![5, 7], vec![0.5, 0.7]);
        let payload = codec.encode(&t);
        // second and third domain positions are set
        assert_eq!(payload.bitmap.ones(), vec![1, 2]);
        let back = codec.decode(&payload, 15);
        assert_eq!(back, t);
    }

    #[test]
    fn roundtrip_with_hierarchical_domains() {
        let dense_len = 8_192;
        let t = random_coo(1, dense_len, 700);
        let n = 4;
        let h = HierarchicalHasher::with_defaults(42, n, t.nnz());
        let out = h.partition(&t);
        let domains = h.partition_domains(dense_len);
        for p in 0..n {
            let codec = HashBitmapCodec::new(&domains[p]);
            let payload = codec.encode(&out.parts[p]);
            let back = codec.decode(&payload, dense_len);
            assert_eq!(back, out.parts[p]);
        }
    }

    #[test]
    fn theorem3_total_bitmap_size() {
        // Total bitmap bytes across all servers == |G|/8 bytes
        // (= |G|/32 FP32 values), independent of n.
        let dense_len = 4_096;
        for n in [2usize, 4, 8, 16] {
            let h = HierarchicalHasher::with_defaults(7, n, 100);
            let domains = h.partition_domains(dense_len);
            let total_bits: usize = domains.iter().map(|d| d.len()).sum();
            assert_eq!(total_bits, dense_len);
            let total_bytes: usize = domains
                .iter()
                .map(|d| Bitmap::zeros(d.len()).wire_bytes())
                .sum();
            // ceil rounding per server adds at most n-1 bytes
            assert!(total_bytes >= dense_len / 8);
            assert!(total_bytes <= dense_len / 8 + n);
            // FP32-equivalent: |G|/32 values
            let fp32_equiv = total_bytes as f64 / BYTES_F32 as f64;
            assert!((fp32_equiv - dense_len as f64 / 32.0).abs() <= n as f64);
        }
    }

    #[test]
    fn scratch_payload_reuse_matches_allocating_path() {
        // One payload + one pair of decode buffers reused across
        // domains of different sizes must match the allocating path.
        let mut payload = HashBitmapPayload::default();
        let mut dec_idx = Vec::new();
        let mut dec_val = Vec::new();
        let dense_len = 8_192;
        for (seed, nnz, n) in [(5u64, 900usize, 4usize), (6, 40, 2), (7, 1_200, 8)] {
            let t = random_coo(seed, dense_len, nnz);
            let h = HierarchicalHasher::with_defaults(31, n, t.nnz());
            let out = h.partition(&t);
            let domains = h.partition_domains(dense_len);
            for p in 0..n {
                let codec = HashBitmapCodec::new(&domains[p]);
                let fresh = codec.encode(&out.parts[p]);
                codec.encode_into(out.parts[p].as_slice(), &mut payload);
                assert_eq!(payload, fresh, "seed {seed} p {p}");
                codec.decode_into(&payload, &mut dec_idx, &mut dec_val);
                assert_eq!(dec_idx, out.parts[p].indices);
                assert_eq!(dec_val, out.parts[p].values);
            }
        }
    }

    #[test]
    #[should_panic(expected = "not in partition domain")]
    fn encode_rejects_foreign_index() {
        let codec = HashBitmapCodec::new(&[1, 3, 5]);
        let t = CooTensor::from_sorted(10, vec![2], vec![1.0]);
        codec.encode(&t);
    }

    #[test]
    fn prop_roundtrip_any_subset() {
        check(80, |g| {
            let dom_len = g.usize_in(1, 400);
            let domain = g.distinct_sorted_u32(dom_len, 10_000);
            let nnz = g.usize_in(0, dom_len);
            // choose a subset of the domain as the non-zeros
            let mut picks: Vec<usize> = (0..dom_len).collect();
            for i in 0..nnz {
                let j = i + (g.u64() % (dom_len - i) as u64) as usize;
                picks.swap(i, j);
            }
            let mut chosen: Vec<u32> = picks[..nnz].iter().map(|&i| domain[i]).collect();
            chosen.sort_unstable();
            let vals: Vec<f32> = (0..nnz).map(|_| g.f64_unit() as f32 + 0.1).collect();
            let t = CooTensor::from_sorted(10_000, chosen, vals);
            let codec = HashBitmapCodec::new(&domain);
            let back = codec.decode(&codec.encode(&t), 10_000);
            prop_assert(back == t, "hash bitmap roundtrip")
        });
    }
}
