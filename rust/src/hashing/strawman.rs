//! Baseline partitioners from §3.1.2.
//!
//! - [`StrawmanHasher`]: Algorithm 3 — a single universal hash writes each
//!   index into an `n × r` memory; collisions **overwrite** and lose
//!   gradients. Balanced, data-independent, but lossy (Fig 8b, Fig 14).
//! - [`ThresholdPartitioner`]: the data-dependent strawman — sort the
//!   index set periodically, pick `n-1` boundary thresholds, and reuse
//!   them for later iterations. Balanced on the iteration it was fitted
//!   to; drifts (imbalance 1.4–5.1 in the paper's NMT trace) afterwards.

use super::murmur::HashFamily;
use crate::tensor::CooTensor;

/// Algorithm 3: lossy single-hash partitioner.
#[derive(Clone, Debug)]
pub struct StrawmanHasher {
    family: HashFamily,
    /// Partitions `n`.
    pub n: usize,
    /// Memory slots per partition `r`.
    pub r: usize,
}

/// Output of the strawman: partitions plus the loss accounting.
#[derive(Clone, Debug)]
pub struct StrawmanOutput {
    pub parts: Vec<CooTensor>,
    /// Indices lost to hash collisions (overwritten).
    pub lost: usize,
}

impl StrawmanOutput {
    /// Fraction of non-zero gradients lost (the paper's "information
    /// loss rate", e.g. ~15.8% at memory == tensor nnz, Fig 8b).
    pub fn loss_rate(&self, input_nnz: usize) -> f64 {
        if input_nnz == 0 {
            return 0.0;
        }
        self.lost as f64 / input_nnz as f64
    }
}

impl StrawmanHasher {
    /// `r_total` is the total memory size across partitions (the paper
    /// quotes memory in multiples of `|G|·d_G`).
    pub fn new(master_seed: u64, n: usize, r_total: usize) -> Self {
        assert!(n >= 1);
        StrawmanHasher {
            family: HashFamily::new(master_seed, 1),
            n,
            r: (r_total / n).max(1),
        }
    }

    /// Run Algorithm 3. The single hash `h : ℕ → [n·r]` assigns partition
    /// `⌊h/r⌋` and slot `h mod r`; a later index overwrites an earlier
    /// colliding one (order is the input scan order, as on a GPU the
    /// winner is arbitrary — losses are what matter, and they're
    /// deterministic given the hash).
    pub fn partition(&self, t: &CooTensor) -> StrawmanOutput {
        let nr = self.n * self.r;
        let mut mem: Vec<u32> = vec![0; nr]; // pos+1, 0 = empty
        let mut occupied = 0usize;
        for pos in 0..t.nnz() {
            let h = self.family.hash(0, t.indices[pos]) as u64 % nr as u64;
            let slot = &mut mem[h as usize];
            if *slot == 0 {
                occupied += 1;
            }
            *slot = pos as u32 + 1; // overwrite on collision
        }
        let lost = t.nnz() - occupied;
        let mut parts = Vec::with_capacity(self.n);
        for p in 0..self.n {
            let mut idxs = Vec::new();
            let mut vals = Vec::new();
            for s in 0..self.r {
                let v = mem[p * self.r + s];
                if v != 0 {
                    let pos = (v - 1) as usize;
                    idxs.push(t.indices[pos]);
                    vals.push(t.values[pos]);
                }
            }
            let mut order: Vec<usize> = (0..idxs.len()).collect();
            order.sort_unstable_by_key(|&i| idxs[i]);
            parts.push(CooTensor::from_sorted(
                t.dense_len,
                order.iter().map(|&i| idxs[i]).collect(),
                order.iter().map(|&i| vals[i]).collect(),
            ));
        }
        StrawmanOutput { parts, lost }
    }
}

/// Data-dependent threshold partitioner (§3.1.2 strawman).
#[derive(Clone, Debug)]
pub struct ThresholdPartitioner {
    /// `n - 1` ascending index thresholds splitting the range into `n`.
    pub thresholds: Vec<u32>,
    pub n: usize,
}

impl ThresholdPartitioner {
    /// Fit thresholds so that `index_set` splits into `n` equal-count
    /// partitions. `index_set` must be sorted ascending.
    pub fn fit(index_set: &[u32], n: usize) -> Self {
        assert!(n >= 1);
        debug_assert!(index_set.windows(2).all(|w| w[0] < w[1]));
        let mut thresholds = Vec::with_capacity(n - 1);
        for j in 1..n {
            let pos = j * index_set.len() / n;
            let thr = if index_set.is_empty() {
                0
            } else {
                index_set[pos.min(index_set.len() - 1)]
            };
            thresholds.push(thr);
        }
        ThresholdPartitioner { thresholds, n }
    }

    /// Partition id for an index under the fitted thresholds.
    #[inline]
    pub fn partition_of(&self, idx: u32) -> usize {
        self.thresholds.partition_point(|&t| t <= idx)
    }

    /// Split a sparse tensor by the fitted thresholds.
    pub fn partition(&self, t: &CooTensor) -> Vec<CooTensor> {
        let mut parts: Vec<(Vec<u32>, Vec<f32>)> =
            (0..self.n).map(|_| (Vec::new(), Vec::new())).collect();
        for (&i, &v) in t.indices.iter().zip(t.values.iter()) {
            let p = self.partition_of(i);
            parts[p].0.push(i);
            parts[p].1.push(v);
        }
        parts
            .into_iter()
            .map(|(i, v)| CooTensor::from_sorted(t.dense_len, i, v))
            .collect()
    }

    /// Push imbalance of this tensor under the fitted thresholds.
    pub fn push_imbalance(&self, t: &CooTensor) -> f64 {
        if t.nnz() == 0 {
            return 1.0;
        }
        let parts = self.partition(t);
        let max = parts.iter().map(|p| p.nnz()).max().unwrap();
        max as f64 * self.n as f64 / t.nnz() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_coo(seed: u64, dense_len: usize, nnz: usize) -> CooTensor {
        let mut rng = Pcg64::seeded(seed);
        let mut idx = rng.sample_distinct(dense_len, nnz);
        idx.sort_unstable();
        CooTensor::from_sorted(
            dense_len,
            idx.into_iter().map(|i| i as u32).collect(),
            (0..nnz).map(|_| rng.next_f32() + 0.01).collect(),
        )
    }

    #[test]
    fn strawman_loses_under_pressure() {
        let t = random_coo(1, 10_000, 2_000);
        // memory == nnz → expect ≈ 1/e ≈ 37% empty ⇒ substantial loss
        let h = StrawmanHasher::new(5, 4, 2_000);
        let out = h.partition(&t);
        assert!(out.lost > 0);
        let kept: usize = out.parts.iter().map(|p| p.nnz()).sum();
        assert_eq!(kept + out.lost, t.nnz());
        // loss rate in the ballpark of the birthday analysis (1 - (1-e^-1))
        let rate = out.loss_rate(t.nnz());
        assert!(rate > 0.15 && rate < 0.45, "loss rate {rate}");
    }

    #[test]
    fn strawman_lossless_with_huge_memory() {
        let t = random_coo(2, 10_000, 500);
        let h = StrawmanHasher::new(5, 4, 4_000_000);
        let out = h.partition(&t);
        assert_eq!(out.lost, 0);
        assert_eq!(CooTensor::merge_all(&out.parts), t);
    }

    #[test]
    fn strawman_kept_entries_are_subset() {
        let t = random_coo(3, 5_000, 1_000);
        let h = StrawmanHasher::new(7, 4, 1_000);
        let out = h.partition(&t);
        let dense = t.to_dense();
        for p in &out.parts {
            for (&i, &v) in p.indices.iter().zip(p.values.iter()) {
                assert_eq!(dense.values[i as usize], v);
            }
        }
    }

    #[test]
    fn threshold_balanced_on_fit_iteration() {
        let t = random_coo(4, 100_000, 10_000);
        let part = ThresholdPartitioner::fit(&t.indices, 8);
        let ratio = part.push_imbalance(&t);
        assert!(ratio < 1.01, "fit-iteration imbalance {ratio}");
    }

    #[test]
    fn threshold_drifts_on_shifted_distribution() {
        // Fit on uniform indices, apply to a distribution concentrated in
        // the low range — imbalance must blow up (the §3.1.2 failure mode).
        let fit_t = random_coo(5, 100_000, 10_000);
        let part = ThresholdPartitioner::fit(&fit_t.indices, 8);
        let mut rng = Pcg64::seeded(6);
        let mut idx: Vec<u32> = rng
            .sample_distinct(12_500, 5_000)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        idx.sort_unstable();
        let shifted = CooTensor::from_sorted(100_000, idx, vec![1.0; 5_000]);
        let ratio = part.push_imbalance(&shifted);
        assert!(ratio > 4.0, "expected drift, got {ratio}");
    }

    #[test]
    fn threshold_partition_is_lossless() {
        let t = random_coo(7, 50_000, 5_000);
        let part = ThresholdPartitioner::fit(&t.indices, 16);
        let parts = part.partition(&t);
        assert_eq!(CooTensor::merge_all(&parts), t);
    }

    #[test]
    fn threshold_partition_of_contiguous() {
        let part = ThresholdPartitioner {
            thresholds: vec![10, 20],
            n: 3,
        };
        assert_eq!(part.partition_of(5), 0);
        assert_eq!(part.partition_of(10), 1);
        assert_eq!(part.partition_of(19), 1);
        assert_eq!(part.partition_of(25), 2);
    }
}
