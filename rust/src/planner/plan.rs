//! Planning: cost-model argmin over the candidate schemes, per bucket.
//!
//! [`plan_bucket`] evaluates the Appendix-B [`CostModel`] for every
//! candidate in [`crate::schemes::PLANNER_CANDIDATES`] — given the
//! bucket's dense length, the machine count, the execution
//! [`Topology`] (per-link-class bandwidth and per-stage latency), and
//! a [`SparsityStats`] — and emits the argmin as a [`BucketPlan`]. The
//! plan keeps the full ranked cost table and the stats it was derived
//! from, so mispredictions are inspectable, and it records the density
//! it was planned at for the hysteresis check in
//! [`super::CostPlanner`]. On a two-level topology the candidates are
//! priced per link class, so the argmin can flip toward hierarchical
//! schemes exactly where slow inter-node links make them win.

use crate::analysis::costmodel::{ClassedTime, CostModel, SparsityStats, TopoCost};
use crate::cluster::{LinkClass, Topology};

use super::measure::MeasuredStats;

/// Planner configuration. Deliberately *without* a link or topology:
/// the cost model always prices against the [`Topology`] of the
/// `Network` the caller is about to execute on (threaded through
/// [`super::Planner::plan`]), so planning and execution cannot silently
/// disagree on bandwidth, latency, or rank placement.
#[derive(Clone, Debug)]
pub struct PlanConfig {
    /// Relative drift of measured mean density that invalidates a cached
    /// plan: re-plan only when `|d − d_planned| / d_planned` exceeds
    /// this (hysteresis; 0 = re-plan whenever the density moves at all).
    pub replan_threshold: f64,
    /// Block length the OmniReduce candidate is costed (and profiled) at.
    pub block_len: usize,
    /// Lossy compression tier (`--compress`): when active *and*
    /// [`accuracy_budget`](PlanConfig::accuracy_budget) is positive, the
    /// planner additionally ranks
    /// [`crate::schemes::LOSSY_TIER_CANDIDATES`] at the post-compression
    /// density and picks the lossy plan only where it strictly beats the
    /// best lossless candidate.
    pub compress: crate::compress::CompressSpec,
    /// Tolerated final-loss degradation (absolute) for the lossy tier;
    /// `0` disarms it even when a compressor is configured.
    pub accuracy_budget: f64,
}

impl Default for PlanConfig {
    fn default() -> Self {
        PlanConfig {
            replan_threshold: 0.25,
            block_len: crate::tensor::block::DEFAULT_BLOCK,
            compress: crate::compress::CompressSpec::None,
            accuracy_budget: 0.0,
        }
    }
}

impl PlanConfig {
    /// Whether the lossy tier participates in planning at all.
    pub fn lossy_tier_armed(&self) -> bool {
        self.compress.is_active() && self.accuracy_budget > 0.0
    }
}

/// One candidate's predicted synchronization time.
#[derive(Clone, Debug)]
pub struct SchemeCost {
    /// [`crate::schemes::by_name`] name.
    pub scheme: &'static str,
    /// Predicted time in seconds (bandwidth + latency terms).
    pub time: f64,
}

/// The plan for one bucket: the chosen scheme plus everything needed to
/// audit the choice.
#[derive(Clone, Debug)]
pub struct BucketPlan {
    /// Bucket label the plan was made for.
    pub label: String,
    /// Chosen scheme ([`crate::schemes::by_name`] name) — the argmin.
    pub chosen: &'static str,
    /// Predicted time of the chosen scheme (seconds).
    pub predicted_time: f64,
    /// Bandwidth part of the prediction — the piece that rescales with
    /// tensor size (`predicted_time = predicted_bw + predicted_alpha`).
    pub predicted_bw: f64,
    /// Latency part of the prediction (α × stages; size-invariant).
    pub predicted_alpha: f64,
    /// Per-link-class bandwidth part of the prediction (`[intra,
    /// inter]`; the flat model predicts `[0, predicted_bw]`). Each
    /// class's value is the sum of that class's per-stage α–β times
    /// with α zeroed, so it rescales with tensor size like
    /// `predicted_bw`.
    pub predicted_class_bw: [f64; 2],
    /// Per-link-class latency part (`[intra, inter]`; size-invariant).
    pub predicted_class_alpha: [f64; 2],
    /// Every candidate's prediction, sorted ascending by time.
    pub costs: Vec<SchemeCost>,
    /// Best *lossless* candidate's predicted time — equals
    /// `predicted_time` for lossless plans; for lossy plans it is the
    /// baseline the compression tier beat (the "bytes you would have
    /// paid" side of the lossy-vs-lossless report).
    pub predicted_lossless_time: f64,
    /// Bandwidth part of `predicted_lossless_time` (rescales with
    /// tensor size; the remainder is its size-invariant latency).
    pub predicted_lossless_bw: f64,
    /// Best lossy-tier candidate's predicted time at the
    /// post-compression density; `None` when the tier was not ranked.
    pub predicted_lossy_time: Option<f64>,
    /// Whether the chosen scheme runs on *compressed* gradients — only
    /// ever true when the lossy prediction strictly beat the best
    /// lossless one under an armed accuracy budget.
    pub lossy: bool,
    /// Predicted post-compression per-worker density the lossy tier was
    /// priced at (`None` for lossless plans).
    pub lossy_d1: Option<f64>,
    /// Compressor label (`topk:K`/`threshold:T`) for lossy plans.
    pub compressor: Option<String>,
    /// Mean per-worker density the plan was derived at (hysteresis
    /// anchor).
    pub planned_d1: f64,
    /// Topology the plan was priced against — a cached plan is only
    /// valid for the placement and links it was made for.
    pub planned_topo: Topology,
    /// The measured statistics that drove the prediction.
    pub stats: MeasuredStats,
}

/// measured / predicted (> 1 = cost model optimistic): the one
/// misprediction definition shared by every reporting surface
/// (`engine::BucketOutcome`, `coordinator::BucketPlanReport`). `None`
/// when nothing was predicted, and also when either side is zero — a
/// zero prediction (one machine, empty bucket) or a zero measurement
/// has no meaningful ratio, and printers must show `n/a`, never an
/// `inf`/`NaN` born from the division.
pub fn misprediction_ratio(measured: f64, predicted: Option<f64>) -> Option<f64> {
    let p = predicted?;
    if p > 0.0 && measured > 0.0 {
        Some(measured / p)
    } else {
        None
    }
}

impl BucketPlan {
    /// Prediction for the bucket rescaled to `scale ×` the planned
    /// tensor size: bandwidth scales, latency does not — the planner's
    /// twin of `SimDriver::full_size_time`.
    pub fn predicted_at_scale(&self, scale: f64) -> f64 {
        self.predicted_bw * scale + self.predicted_alpha
    }

    /// The lossless baseline rescaled like
    /// [`predicted_at_scale`](BucketPlan::predicted_at_scale) — what
    /// the bucket would have cost without the lossy tier.
    pub fn predicted_lossless_at_scale(&self, scale: f64) -> f64 {
        self.predicted_lossless_bw * scale
            + (self.predicted_lossless_time - self.predicted_lossless_bw)
    }

    /// Per-link-class prediction at `scale ×` the planned tensor size
    /// (`[intra, inter]`), the classed twin of
    /// [`predicted_at_scale`](BucketPlan::predicted_at_scale).
    pub fn predicted_class_at_scale(&self, scale: f64) -> [f64; 2] {
        [
            self.predicted_class_bw[0] * scale + self.predicted_class_alpha[0],
            self.predicted_class_bw[1] * scale + self.predicted_class_alpha[1],
        ]
    }

    /// The runner-up candidate (second-smallest predicted time), if any.
    pub fn runner_up(&self) -> Option<&SchemeCost> {
        self.costs.get(1)
    }
}

/// Build the cost model a bucket is priced with: inter-class bandwidth
/// and latency as the base α–β pair, plus per-class pricing when the
/// topology is two-level. One constructor for ranking and splitting, so
/// the two can never disagree.
fn cost_model<'a, S: SparsityStats>(
    m: f64,
    n: usize,
    topo: &Topology,
    stats: &'a S,
) -> CostModel<'a, S> {
    CostModel::new(m, n, topo.inter.bandwidth_bps() / 32.0, stats)
        .with_latency(topo.inter.latency())
        .with_topology(TopoCost::from_topology(topo))
}

/// Evaluate the cost model for every planner candidate and return the
/// ranked cost table (ascending). `m` is the bucket's dense length in
/// values; `topo` is the execution topology (flat via
/// [`Topology::flat`] reproduces the historical single-link ranking).
pub fn rank_candidates<S: SparsityStats>(
    m: f64,
    n: usize,
    topo: &Topology,
    block_len: usize,
    stats: &S,
) -> Vec<SchemeCost> {
    rank_candidates_among(&crate::schemes::PLANNER_CANDIDATES, m, n, topo, block_len, stats)
}

/// [`rank_candidates`] over an explicit name list — the lossy tier
/// ranks [`crate::schemes::LOSSY_TIER_CANDIDATES`] at the
/// post-compression density through the same code path.
pub fn rank_candidates_among<S: SparsityStats>(
    names: &[&'static str],
    m: f64,
    n: usize,
    topo: &Topology,
    block_len: usize,
    stats: &S,
) -> Vec<SchemeCost> {
    let cm = cost_model(m, n, topo, stats);
    let mut costs: Vec<SchemeCost> = names
        .iter()
        .map(|&name| SchemeCost {
            scheme: name,
            time: cm
                .time_for(name, block_len)
                .expect("every planner candidate has a closed form"),
        })
        .collect();
    costs.sort_by(|a, b| a.time.total_cmp(&b.time));
    costs
}

/// Plan one bucket from measured statistics: the cost-model argmin over
/// all candidates (priced for `topo`), packaged with its audit trail.
pub fn plan_bucket(
    label: &str,
    m: f64,
    n: usize,
    topo: &Topology,
    cfg: &PlanConfig,
    stats: MeasuredStats,
) -> BucketPlan {
    let costs = rank_candidates(m, n, topo, cfg.block_len, &stats);
    let best = costs.first().expect("non-empty candidate list");
    let chosen = best.scheme;
    let predicted_time = best.time;
    // Split the winning prediction into its rescalable and fixed parts,
    // total and per class: re-price with every α zeroed, the remainder
    // is latency.
    let full: ClassedTime = cost_model(m, n, topo, &stats)
        .time_for_by_class(chosen, cfg.block_len)
        .expect("chosen candidate has a closed form");
    let bw_only: ClassedTime = CostModel::new(m, n, topo.inter.bandwidth_bps() / 32.0, &stats)
        .with_topology(TopoCost::from_topology(topo).without_latency())
        .time_for_by_class(chosen, cfg.block_len)
        .expect("chosen candidate has a closed form");
    debug_assert_eq!(LinkClass::Intra.idx(), 0);
    BucketPlan {
        label: label.to_string(),
        chosen,
        predicted_time,
        predicted_bw: bw_only.total,
        predicted_alpha: predicted_time - bw_only.total,
        predicted_class_bw: [bw_only.intra, bw_only.inter],
        predicted_class_alpha: [
            (full.intra - bw_only.intra).max(0.0),
            (full.inter - bw_only.inter).max(0.0),
        ],
        costs,
        predicted_lossless_time: predicted_time,
        predicted_lossless_bw: bw_only.total,
        predicted_lossy_time: None,
        lossy: false,
        lossy_d1: None,
        compressor: None,
        planned_d1: stats.d1,
        planned_topo: topo.clone(),
        stats,
    }
}

/// The measured statistics rescaled to a predicted post-compression
/// density: aggregate densities shrink by the survivor ratio (capped at
/// 1), skewness carries over (compression keeps the largest entries,
/// which live where the mass already was), and the block share falls
/// back to the independence approximation — Top-k survivors are
/// scattered, so the raw tensor's measured clustering no longer
/// applies. At ratio 1 (no reduction) the view is bit-identical to the
/// underlying stats, so a degenerate compressor can never flip a plan.
struct ScaledStats<'a> {
    inner: &'a MeasuredStats,
    ratio: f64,
}

impl SparsityStats for ScaledStats<'_> {
    fn agg_density(&self, j: usize) -> f64 {
        (self.inner.agg_density(j) * self.ratio).min(1.0)
    }

    fn skewness(&self, n: usize) -> f64 {
        self.inner.skewness(n)
    }

    fn block_density(&self, j: usize, block_len: usize) -> f64 {
        if self.ratio >= 1.0 {
            self.inner.block_density(j, block_len)
        } else {
            crate::analysis::costmodel::independent_block_density(self.agg_density(j), block_len)
        }
    }
}

/// [`plan_bucket`], then — when the config arms the lossy tier — a
/// second ranking of [`crate::schemes::LOSSY_TIER_CANDIDATES`] at the
/// predicted post-compression density `compressed_d1`. The lossy plan
/// is adopted only where it *strictly* beats the best lossless
/// prediction; both predictions are kept on the plan so every report
/// can show the volume the budget actually bought.
pub fn plan_bucket_compressed(
    label: &str,
    m: f64,
    n: usize,
    topo: &Topology,
    cfg: &PlanConfig,
    stats: MeasuredStats,
    compressed_d1: f64,
) -> BucketPlan {
    let mut plan = plan_bucket(label, m, n, topo, cfg, stats);
    if !cfg.lossy_tier_armed() {
        return plan;
    }
    let ratio = if plan.stats.d1 > 0.0 {
        (compressed_d1 / plan.stats.d1).min(1.0)
    } else {
        1.0
    };
    let (lossy_costs, full, bw_only) = {
        let scaled = ScaledStats {
            inner: &plan.stats,
            ratio,
        };
        let costs = rank_candidates_among(
            &crate::schemes::LOSSY_TIER_CANDIDATES,
            m,
            n,
            topo,
            cfg.block_len,
            &scaled,
        );
        let best = costs.first().expect("non-empty lossy candidate list").scheme;
        let full: ClassedTime = cost_model(m, n, topo, &scaled)
            .time_for_by_class(best, cfg.block_len)
            .expect("lossy candidate has a closed form");
        let bw_only: ClassedTime =
            CostModel::new(m, n, topo.inter.bandwidth_bps() / 32.0, &scaled)
                .with_topology(TopoCost::from_topology(topo).without_latency())
                .time_for_by_class(best, cfg.block_len)
                .expect("lossy candidate has a closed form");
        (costs, full, bw_only)
    };
    let best_lossy = lossy_costs[0].time;
    plan.predicted_lossy_time = Some(best_lossy);
    if best_lossy < plan.predicted_lossless_time {
        plan.chosen = lossy_costs[0].scheme;
        plan.predicted_time = best_lossy;
        plan.predicted_bw = bw_only.total;
        plan.predicted_alpha = best_lossy - bw_only.total;
        plan.predicted_class_bw = [bw_only.intra, bw_only.inter];
        plan.predicted_class_alpha = [
            (full.intra - bw_only.intra).max(0.0),
            (full.inter - bw_only.inter).max(0.0),
        ];
        plan.costs = lossy_costs;
        plan.lossy = true;
        plan.lossy_d1 = Some(compressed_d1);
        plan.compressor = Some(cfg.compress.label());
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::LinkKind;
    use crate::workload::random_uniform_inputs;

    fn measured(n: usize, density: f64) -> MeasuredStats {
        let inputs = random_uniform_inputs(0x91a4, n, 1 << 14, density);
        MeasuredStats::from_tensors(&inputs, &[n], &[crate::tensor::block::DEFAULT_BLOCK])
    }

    #[test]
    fn ranks_every_candidate_ascending() {
        let stats = measured(8, 0.02);
        let topo = Topology::flat(8, LinkKind::Tcp25);
        let plan =
            plan_bucket("b0", (1 << 14) as f64, 8, &topo, &PlanConfig::default(), stats);
        assert_eq!(plan.costs.len(), crate::schemes::PLANNER_CANDIDATES.len());
        assert!(plan
            .costs
            .windows(2)
            .all(|w| w[0].time <= w[1].time));
        assert_eq!(plan.chosen, plan.costs[0].scheme);
        assert!((plan.predicted_time - plan.predicted_bw - plan.predicted_alpha).abs() < 1e-15);
        assert!(plan.runner_up().is_some());
    }

    #[test]
    fn dense_bucket_chooses_allreduce() {
        // Fully dense inputs: the ring allreduce's 2(n−1)/n factor beats
        // every index-carrying scheme on pure bandwidth. Zero-latency
        // link: at small m the per-stage α otherwise lets the 2-stage
        // OmniReduce (whose full-density traffic is within 1/b of dense)
        // edge out the 2(n−1)-stage ring — a real crossover, but not the
        // one under test here.
        let m = 1 << 16;
        let dense: Vec<crate::tensor::CooTensor> = (0..4)
            .map(|_| {
                crate::tensor::CooTensor::from_sorted(
                    m,
                    (0..m as u32).collect(),
                    vec![1.0; m],
                )
            })
            .collect();
        let stats = MeasuredStats::from_tensors(&dense, &[4], &[256]);
        let topo = Topology::flat(4, LinkKind::Custom(25_000_000_000, 0));
        let plan = plan_bucket("dense", m as f64, 4, &topo, &PlanConfig::default(), stats);
        assert_eq!(plan.chosen, "allreduce");
        assert_eq!(plan.planned_topo, topo);
        // flat plans put the whole prediction in the inter class
        assert_eq!(plan.predicted_class_bw[0], 0.0);
        assert!((plan.predicted_class_bw[1] - plan.predicted_bw).abs() < 1e-15);
    }

    #[test]
    fn sparse_bucket_avoids_allreduce() {
        let stats = measured(8, 0.01);
        let plan = plan_bucket(
            "sparse",
            (1 << 22) as f64,
            8,
            &Topology::flat(8, LinkKind::Tcp25),
            &PlanConfig::default(),
            stats,
        );
        assert_ne!(plan.chosen, "allreduce", "1% density must go sparse");
    }

    #[test]
    fn scale_split_reconstructs_prediction() {
        let stats = measured(4, 0.05);
        let topo = Topology::flat(4, LinkKind::Tcp25);
        let plan =
            plan_bucket("b", (1 << 14) as f64, 4, &topo, &PlanConfig::default(), stats);
        assert!((plan.predicted_at_scale(1.0) - plan.predicted_time).abs() < 1e-15);
        let doubled = plan.predicted_at_scale(2.0);
        assert!(doubled > plan.predicted_time);
        assert!((doubled - (2.0 * plan.predicted_bw + plan.predicted_alpha)).abs() < 1e-15);
    }

    #[test]
    fn misprediction_ratio_guards_degenerate_zeroes() {
        assert_eq!(misprediction_ratio(1.0, None), None);
        assert_eq!(misprediction_ratio(1.0, Some(0.0)), None, "zero prediction");
        assert_eq!(misprediction_ratio(0.0, Some(1.0)), None, "zero measurement");
        assert_eq!(misprediction_ratio(0.0, Some(0.0)), None);
        assert_eq!(misprediction_ratio(2.0, Some(1.0)), Some(2.0));
    }

    #[test]
    fn lossy_tier_wins_only_under_real_reduction() {
        let stats = measured(8, 0.02);
        let d1 = stats.d1;
        let topo = Topology::flat(8, LinkKind::Tcp25);
        let cfg = PlanConfig {
            compress: crate::compress::CompressSpec::TopK(0.001),
            accuracy_budget: 0.05,
            ..PlanConfig::default()
        };
        let m = (1 << 18) as f64;
        // 20× density reduction: the lossy prediction must win, and the
        // plan must carry both sides of the comparison.
        let compressed = cfg.compress.predicted_density(1 << 18, d1);
        assert!(compressed < d1 / 10.0);
        let plan = plan_bucket_compressed("c", m, 8, &topo, &cfg, stats.clone(), compressed);
        assert!(plan.lossy, "a real volume reduction must be taken");
        let lossy_t = plan.predicted_lossy_time.unwrap();
        assert!(lossy_t < plan.predicted_lossless_time);
        assert_eq!(plan.predicted_time, lossy_t);
        assert_eq!(plan.lossy_d1, Some(compressed));
        assert_eq!(plan.compressor.as_deref(), Some("topk:0.001"));
        assert!(crate::schemes::LOSSY_TIER_CANDIDATES.contains(&plan.chosen));
        // Degenerate compressor (k >= nnz → no reduction): the lossy
        // ranking prices identically to lossless plus the Ok-Topk
        // premium, so lossless must win and the plan stays bit-lossless.
        let same = plan_bucket_compressed("c", m, 8, &topo, &cfg, stats.clone(), d1);
        assert!(!same.lossy, "no reduction → never trade accuracy");
        assert_eq!(same.predicted_time, same.predicted_lossless_time);
        assert!(same.predicted_lossy_time.unwrap() >= same.predicted_lossless_time);
        // Disarmed budget: the lossy tier is never even ranked.
        let cfg0 = PlanConfig {
            accuracy_budget: 0.0,
            ..cfg.clone()
        };
        let off = plan_bucket_compressed("c", m, 8, &topo, &cfg0, stats, compressed);
        assert!(!off.lossy);
        assert!(off.predicted_lossy_time.is_none());
    }

    #[test]
    fn two_level_plan_records_class_split() {
        let stats = measured(8, 0.02);
        let topo = Topology::two_level(
            4,
            2,
            LinkKind::Custom(250_000_000_000, 0),
            LinkKind::Custom(25_000_000_000, 0),
        );
        let plan =
            plan_bucket("t", (1 << 16) as f64, 8, &topo, &PlanConfig::default(), stats);
        assert_eq!(plan.planned_topo, topo);
        let classes = plan.predicted_class_at_scale(1.0);
        // zero-latency links: the class bandwidth sums bracket the total
        assert!(classes[1] > 0.0, "inter class carries traffic");
        assert!(
            plan.predicted_time <= classes[0] + classes[1] + 1e-12,
            "total {} vs classes {classes:?}",
            plan.predicted_time
        );
        assert!(plan.predicted_time + 1e-12 >= classes[0].max(classes[1]));
    }
}
