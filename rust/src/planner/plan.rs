//! Planning: cost-model argmin over the candidate schemes, per bucket.
//!
//! [`plan_bucket`] evaluates the Appendix-B [`CostModel`] for every
//! candidate in [`crate::schemes::PLANNER_CANDIDATES`] — given the
//! bucket's dense length, the machine count, the link's bandwidth and
//! per-stage latency, and a [`SparsityStats`] — and emits the argmin as
//! a [`BucketPlan`]. The plan keeps the full ranked cost table and the
//! stats it was derived from, so mispredictions are inspectable, and it
//! records the density it was planned at for the hysteresis check in
//! [`super::CostPlanner`].

use crate::analysis::costmodel::{CostModel, SparsityStats};
use crate::cluster::LinkKind;

use super::measure::MeasuredStats;

/// Planner configuration. Deliberately *without* a link: the cost model
/// always prices against the link of the `Network` the caller is about
/// to execute on (threaded through [`super::Planner::plan`]), so
/// planning and execution cannot silently disagree on bandwidth or
/// latency.
#[derive(Clone, Debug)]
pub struct PlanConfig {
    /// Relative drift of measured mean density that invalidates a cached
    /// plan: re-plan only when `|d − d_planned| / d_planned` exceeds
    /// this (hysteresis; 0 = re-plan whenever the density moves at all).
    pub replan_threshold: f64,
    /// Block length the OmniReduce candidate is costed (and profiled) at.
    pub block_len: usize,
}

impl Default for PlanConfig {
    fn default() -> Self {
        PlanConfig {
            replan_threshold: 0.25,
            block_len: crate::tensor::block::DEFAULT_BLOCK,
        }
    }
}

/// One candidate's predicted synchronization time.
#[derive(Clone, Debug)]
pub struct SchemeCost {
    /// [`crate::schemes::by_name`] name.
    pub scheme: &'static str,
    /// Predicted time in seconds (bandwidth + latency terms).
    pub time: f64,
}

/// The plan for one bucket: the chosen scheme plus everything needed to
/// audit the choice.
#[derive(Clone, Debug)]
pub struct BucketPlan {
    /// Bucket label the plan was made for.
    pub label: String,
    /// Chosen scheme ([`crate::schemes::by_name`] name) — the argmin.
    pub chosen: &'static str,
    /// Predicted time of the chosen scheme (seconds).
    pub predicted_time: f64,
    /// Bandwidth part of the prediction — the piece that rescales with
    /// tensor size (`predicted_time = predicted_bw + predicted_alpha`).
    pub predicted_bw: f64,
    /// Latency part of the prediction (α × stages; size-invariant).
    pub predicted_alpha: f64,
    /// Every candidate's prediction, sorted ascending by time.
    pub costs: Vec<SchemeCost>,
    /// Mean per-worker density the plan was derived at (hysteresis
    /// anchor).
    pub planned_d1: f64,
    /// Link the plan was priced against — a cached plan is only valid
    /// for the network it was made for.
    pub planned_link: LinkKind,
    /// The measured statistics that drove the prediction.
    pub stats: MeasuredStats,
}

/// measured / predicted (> 1 = cost model optimistic): the one
/// misprediction definition shared by every reporting surface
/// (`engine::BucketOutcome`, `coordinator::BucketPlanReport`). `None`
/// when nothing was predicted; 1.0 (neutral) for a zero prediction.
pub fn misprediction_ratio(measured: f64, predicted: Option<f64>) -> Option<f64> {
    predicted.map(|p| if p > 0.0 { measured / p } else { 1.0 })
}

impl BucketPlan {
    /// Prediction for the bucket rescaled to `scale ×` the planned
    /// tensor size: bandwidth scales, latency does not — the planner's
    /// twin of `SimDriver::full_size_time`.
    pub fn predicted_at_scale(&self, scale: f64) -> f64 {
        self.predicted_bw * scale + self.predicted_alpha
    }

    /// The runner-up candidate (second-smallest predicted time), if any.
    pub fn runner_up(&self) -> Option<&SchemeCost> {
        self.costs.get(1)
    }
}

/// Evaluate the cost model for every planner candidate and return the
/// ranked cost table (ascending). `m` is the bucket's dense length in
/// values.
pub fn rank_candidates<S: SparsityStats>(
    m: f64,
    n: usize,
    link: LinkKind,
    block_len: usize,
    stats: &S,
) -> Vec<SchemeCost> {
    let bandwidth_values = link.bandwidth_bps() / 32.0;
    let cm = CostModel::new(m, n, bandwidth_values, stats).with_latency(link.latency());
    let mut costs: Vec<SchemeCost> = crate::schemes::PLANNER_CANDIDATES
        .iter()
        .map(|&name| SchemeCost {
            scheme: name,
            time: cm
                .time_for(name, block_len)
                .expect("every planner candidate has a closed form"),
        })
        .collect();
    costs.sort_by(|a, b| a.time.total_cmp(&b.time));
    costs
}

/// Plan one bucket from measured statistics: the cost-model argmin over
/// all candidates (priced for `link`), packaged with its audit trail.
pub fn plan_bucket(
    label: &str,
    m: f64,
    n: usize,
    link: LinkKind,
    cfg: &PlanConfig,
    stats: MeasuredStats,
) -> BucketPlan {
    let costs = rank_candidates(m, n, link, cfg.block_len, &stats);
    let best = costs.first().expect("non-empty candidate list");
    let chosen = best.scheme;
    let predicted_time = best.time;
    // Split the winning prediction into its rescalable and fixed parts.
    let bandwidth_values = link.bandwidth_bps() / 32.0;
    let cm = CostModel::new(m, n, bandwidth_values, &stats);
    let predicted_bw = cm
        .time_for(chosen, cfg.block_len)
        .expect("chosen candidate has a closed form");
    let predicted_alpha = predicted_time - predicted_bw;
    BucketPlan {
        label: label.to_string(),
        chosen,
        predicted_time,
        predicted_bw,
        predicted_alpha,
        costs,
        planned_d1: stats.d1,
        planned_link: link,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::random_uniform_inputs;

    fn measured(n: usize, density: f64) -> MeasuredStats {
        let inputs = random_uniform_inputs(0x91a4, n, 1 << 14, density);
        MeasuredStats::from_tensors(&inputs, &[n], &[crate::tensor::block::DEFAULT_BLOCK])
    }

    #[test]
    fn ranks_every_candidate_ascending() {
        let stats = measured(8, 0.02);
        let plan =
            plan_bucket("b0", (1 << 14) as f64, 8, LinkKind::Tcp25, &PlanConfig::default(), stats);
        assert_eq!(plan.costs.len(), crate::schemes::PLANNER_CANDIDATES.len());
        assert!(plan
            .costs
            .windows(2)
            .all(|w| w[0].time <= w[1].time));
        assert_eq!(plan.chosen, plan.costs[0].scheme);
        assert!((plan.predicted_time - plan.predicted_bw - plan.predicted_alpha).abs() < 1e-15);
        assert!(plan.runner_up().is_some());
    }

    #[test]
    fn dense_bucket_chooses_allreduce() {
        // Fully dense inputs: the ring allreduce's 2(n−1)/n factor beats
        // every index-carrying scheme on pure bandwidth. Zero-latency
        // link: at small m the per-stage α otherwise lets the 2-stage
        // OmniReduce (whose full-density traffic is within 1/b of dense)
        // edge out the 2(n−1)-stage ring — a real crossover, but not the
        // one under test here.
        let m = 1 << 16;
        let dense: Vec<crate::tensor::CooTensor> = (0..4)
            .map(|_| {
                crate::tensor::CooTensor::from_sorted(
                    m,
                    (0..m as u32).collect(),
                    vec![1.0; m],
                )
            })
            .collect();
        let stats = MeasuredStats::from_tensors(&dense, &[4], &[256]);
        let link = LinkKind::Custom(25_000_000_000, 0);
        let plan = plan_bucket("dense", m as f64, 4, link, &PlanConfig::default(), stats);
        assert_eq!(plan.chosen, "allreduce");
        assert_eq!(plan.planned_link, link);
    }

    #[test]
    fn sparse_bucket_avoids_allreduce() {
        let stats = measured(8, 0.01);
        let plan = plan_bucket(
            "sparse",
            (1 << 22) as f64,
            8,
            LinkKind::Tcp25,
            &PlanConfig::default(),
            stats,
        );
        assert_ne!(plan.chosen, "allreduce", "1% density must go sparse");
    }

    #[test]
    fn scale_split_reconstructs_prediction() {
        let stats = measured(4, 0.05);
        let plan =
            plan_bucket("b", (1 << 14) as f64, 4, LinkKind::Tcp25, &PlanConfig::default(), stats);
        assert!((plan.predicted_at_scale(1.0) - plan.predicted_time).abs() < 1e-15);
        let doubled = plan.predicted_at_scale(2.0);
        assert!(doubled > plan.predicted_time);
        assert!((doubled - (2.0 * plan.predicted_bw + plan.predicted_alpha)).abs() < 1e-15);
    }
}
