//! Measurement: per-workload sparsity statistics from real gradients.
//!
//! [`MeasuredStats`] is the measured implementation of
//! [`SparsityStats`] the cost model consumes: aggregate densities
//! `d(j)` from incremental bitmap unions of the profiled tensors,
//! skewness `s(n)` from contiguous-partition counts (Definition 5,
//! averaged over workers), and the non-zero *block* share OmniReduce's
//! formula needs — measured, because clustered non-zeros (embedding
//! rows) touch far fewer blocks than the independence approximation
//! predicts.
//!
//! Profiling one bucket is `O(n · nnz)` — cheap, but not free — so the
//! planner ([`super::CostPlanner`]) computes a `MeasuredStats` once per
//! bucket during warm-up and caches it behind a density-drift
//! hysteresis check; steady-state iterations only pay a mean-density
//! scan. [`MeasuredStats::from_tensors`] itself is deterministic: the
//! same tensors always produce identical stats (asserted by
//! `rust/tests/planner_integration.rs`).

use crate::analysis::costmodel::SparsityStats;
use crate::tensor::{metrics, Bitmap, CooTensor};
use crate::workload::GradientGen;

/// Measured sparsity statistics of one workload (or one bucket of one).
#[derive(Clone, Debug, PartialEq)]
pub struct MeasuredStats {
    /// Mean per-worker density of the profiled tensors.
    pub d1: f64,
    /// `agg[j-1]` = density of the union of the first `j` tensors.
    agg: Vec<f64>,
    /// `(partitions, skewness)` at each profiled partition count.
    skew: Vec<(usize, f64)>,
    /// `(block_len, share[j-1])` — fraction of `block_len`-blocks with
    /// ≥ 1 non-zero in the `j`-aggregate (union prefixes), per profiled
    /// block length.
    blocks: Vec<(usize, Vec<f64>)>,
    /// `(block_len, share)` — *mean per-worker* non-zero-block share,
    /// the `j = 1` value (a union prefix would be worker 0 alone, which
    /// misrepresents heterogeneous workers exactly like `agg[0]` would
    /// for `d1`).
    block_d1: Vec<(usize, f64)>,
}

impl MeasuredStats {
    /// Profile one set of per-worker tensors. `parts` lists the
    /// partition counts to measure skewness at (the planner passes the
    /// machine count); `block_lens` the block lengths to measure the
    /// non-zero-block share at (the planner passes its OmniReduce block
    /// length).
    pub fn from_tensors(tensors: &[CooTensor], parts: &[usize], block_lens: &[usize]) -> Self {
        assert!(!tensors.is_empty());
        let len = tensors[0].dense_len;
        let n = tensors.len();

        // Incremental unions: one pass over each tensor's indices keeps
        // the whole d(1..n) profile O(n · nnz).
        let mut union = Bitmap::zeros(len.max(1));
        let mut block_union: Vec<(usize, Bitmap)> = block_lens
            .iter()
            .map(|&b| {
                assert!(b > 0, "block length must be positive");
                (b, Bitmap::zeros(crate::util::ceil_div(len, b).max(1)))
            })
            .collect();
        let mut agg = Vec::with_capacity(n);
        let mut blocks: Vec<(usize, Vec<f64>)> = block_lens
            .iter()
            .map(|&b| (b, Vec::with_capacity(n)))
            .collect();
        // Per-worker block shares (for the j = 1 mean): one scratch
        // bitmap per block length, reset per worker.
        let mut worker_blocks: Vec<(usize, Bitmap, f64)> = block_lens
            .iter()
            .map(|&b| (b, Bitmap::zeros(crate::util::ceil_div(len, b).max(1)), 0.0))
            .collect();
        for t in tensors {
            assert_eq!(t.dense_len, len, "profiled tensors must share a range");
            for (_, bm, _) in worker_blocks.iter_mut() {
                let nblocks = bm.len();
                bm.reset(nblocks);
            }
            for &i in &t.indices {
                union.set(i as usize);
                for (b, bm) in block_union.iter_mut() {
                    bm.set(i as usize / *b);
                }
                for (b, bm, _) in worker_blocks.iter_mut() {
                    bm.set(i as usize / *b);
                }
            }
            agg.push(union.count_ones() as f64 / len.max(1) as f64);
            for ((b, bm), (_, shares)) in block_union.iter().zip(blocks.iter_mut()) {
                let nblocks = crate::util::ceil_div(len, *b).max(1);
                shares.push(bm.count_ones() as f64 / nblocks as f64);
            }
            for (b, bm, acc) in worker_blocks.iter_mut() {
                let nblocks = crate::util::ceil_div(len, *b).max(1);
                *acc += bm.count_ones() as f64 / nblocks as f64;
            }
        }
        let block_d1: Vec<(usize, f64)> = worker_blocks
            .into_iter()
            .map(|(b, _, acc)| (b, acc / n as f64))
            .collect();

        let d1 = tensors.iter().map(|t| t.density()).sum::<f64>() / n as f64;
        let skew = parts
            .iter()
            .map(|&p| {
                let mean = tensors
                    .iter()
                    .map(|t| metrics::skewness_ratio(t, p))
                    .sum::<f64>()
                    / n as f64;
                (p, mean)
            })
            .collect();

        MeasuredStats {
            d1,
            agg,
            skew,
            blocks,
            block_d1,
        }
    }

    /// Profile a generated workload: average `from_tensors` over
    /// `iterations` sampled iterations of `machines` workers — the
    /// O(warm-up) measurement pass the planner and the measured-Fig-7
    /// exhibit share.
    pub fn profile_workload(
        gen: &GradientGen,
        machines: usize,
        iterations: usize,
        block_lens: &[usize],
    ) -> Self {
        assert!(iterations >= 1);
        let runs: Vec<MeasuredStats> = (0..iterations as u64)
            .map(|it| Self::from_tensors(&gen.iteration_all(it, machines), &[machines], block_lens))
            .collect();
        Self::average(&runs)
    }

    /// Element-wise mean of several profiles (all must share the same
    /// shape: same worker count, partition counts, block lengths).
    pub fn average(runs: &[MeasuredStats]) -> Self {
        assert!(!runs.is_empty());
        let k = runs.len() as f64;
        let mut out = runs[0].clone();
        for r in &runs[1..] {
            // Full shape check up front — a silently truncated zip would
            // average mismatched profiles into plausible-looking garbage.
            assert_eq!(r.agg.len(), out.agg.len(), "profiles must share shape");
            assert_eq!(r.skew.len(), out.skew.len(), "skew shapes differ");
            assert_eq!(r.blocks.len(), out.blocks.len(), "block shapes differ");
            assert_eq!(
                r.block_d1.len(),
                out.block_d1.len(),
                "block_d1 shapes differ"
            );
            for (o, v) in out.agg.iter_mut().zip(r.agg.iter()) {
                *o += v;
            }
            for ((p, o), (q, v)) in out.skew.iter_mut().zip(r.skew.iter()) {
                assert_eq!(p, q);
                *o += v;
            }
            for ((b, os), (c, vs)) in out.blocks.iter_mut().zip(r.blocks.iter()) {
                assert_eq!(b, c);
                for (o, v) in os.iter_mut().zip(vs.iter()) {
                    *o += v;
                }
            }
            for ((b, o), (c, v)) in out.block_d1.iter_mut().zip(r.block_d1.iter()) {
                assert_eq!(b, c);
                *o += v;
            }
            out.d1 += r.d1;
        }
        out.d1 /= k;
        out.agg.iter_mut().for_each(|v| *v /= k);
        out.skew.iter_mut().for_each(|(_, v)| *v /= k);
        out.blocks
            .iter_mut()
            .for_each(|(_, vs)| vs.iter_mut().for_each(|v| *v /= k));
        out.block_d1.iter_mut().for_each(|(_, v)| *v /= k);
        out
    }

    /// Number of workers the stats were profiled over.
    pub fn profiled_workers(&self) -> usize {
        self.agg.len()
    }
}

impl SparsityStats for MeasuredStats {
    fn agg_density(&self, j: usize) -> f64 {
        assert!(j >= 1, "aggregate of at least one tensor");
        // d(1) is the *mean* per-worker density, not worker 0's alone —
        // with heterogeneous workers (a frozen worker among active
        // ones) the union-prefix value agg[0] would misrepresent the
        // per-worker push terms every formula scales by d(1). Larger
        // aggregates come from the measured union prefixes, floored at
        // d(1) so the profile stays monotone even when the prefix order
        // starts with atypically sparse workers.
        if j == 1 {
            return self.d1;
        }
        // Beyond the profiled worker count the union is clamped at the
        // last measurement (the planner always profiles j up to n).
        self.agg[(j - 1).min(self.agg.len() - 1)].max(self.d1)
    }

    fn skewness(&self, n: usize) -> f64 {
        // Exact measurement if present, else the nearest profiled
        // partition count (skewness varies slowly in log n — Fig 2b).
        self.skew
            .iter()
            .min_by_key(|(p, _)| p.abs_diff(n))
            .map(|&(_, s)| s)
            .unwrap_or(1.0)
    }

    fn block_density(&self, j: usize, block_len: usize) -> f64 {
        match self.blocks.iter().find(|(b, _)| *b == block_len) {
            Some((_, shares)) => {
                // Same shape as agg_density: j = 1 is the mean
                // per-worker share; union prefixes (floored at it) for
                // larger aggregates.
                let d1 = self
                    .block_d1
                    .iter()
                    .find(|(b, _)| *b == block_len)
                    .map(|&(_, s)| s)
                    .unwrap_or(0.0);
                if j == 1 {
                    d1
                } else {
                    shares[(j - 1).min(shares.len() - 1)].max(d1)
                }
            }
            // Unprofiled block length: independence approximation.
            None => {
                crate::analysis::costmodel::independent_block_density(
                    self.agg_density(j),
                    block_len,
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::random_uniform_inputs;

    #[test]
    fn unions_monotone_and_match_metrics() {
        let inputs = random_uniform_inputs(1, 6, 4096, 0.03);
        let s = MeasuredStats::from_tensors(&inputs, &[6], &[64]);
        let mut prev = 0.0;
        for j in 1..=6 {
            let d = s.agg_density(j);
            assert!(d >= prev && d <= 1.0, "j={j}");
            prev = d;
        }
        // the full union must equal the metrics-module measurement
        let full = metrics::aggregated_density(&inputs);
        assert!((s.agg_density(6) - full).abs() < 1e-12);
        // clamped beyond the profiled count
        assert_eq!(s.agg_density(60), s.agg_density(6));
    }

    #[test]
    fn clustered_blocks_beat_independence() {
        // 64-wide runs of non-zeros: measured block share at b=64 is far
        // below the independent-position approximation.
        let dense_len = 1 << 16;
        let idx: Vec<u32> = (0..16u32).flat_map(|r| (0..64).map(move |c| r * 4096 + c)).collect();
        let t = CooTensor::from_sorted(dense_len, idx.clone(), vec![1.0; idx.len()]);
        let s = MeasuredStats::from_tensors(&[t], &[4], &[64]);
        let independent = 1.0 - (1.0 - s.agg_density(1)).powi(64);
        assert!(
            s.block_density(1, 64) < independent * 0.5,
            "measured {} vs independent {independent}",
            s.block_density(1, 64)
        );
        // unprofiled block length falls back to the approximation
        assert!(s.block_density(1, 128) > 0.0);
    }

    #[test]
    fn deterministic_and_average_identity() {
        let inputs = random_uniform_inputs(7, 4, 2048, 0.05);
        let a = MeasuredStats::from_tensors(&inputs, &[4], &[256]);
        let b = MeasuredStats::from_tensors(&inputs, &[4], &[256]);
        assert_eq!(a, b, "profiling must be deterministic");
        let avg = MeasuredStats::average(&[a.clone(), b]);
        assert!((avg.d1 - a.d1).abs() < 1e-15);
        assert_eq!(avg.profiled_workers(), 4);
    }

    #[test]
    fn skewness_nearest_fallback() {
        let inputs = random_uniform_inputs(3, 2, 2048, 0.05);
        let s = MeasuredStats::from_tensors(&inputs, &[4, 16], &[64]);
        assert_eq!(s.skewness(4), s.skewness(5), "nearest profiled count");
        assert_eq!(s.skewness(16), s.skewness(64));
    }

    #[test]
    fn empty_tensors_profile_cleanly() {
        let t = vec![CooTensor::empty(1024); 3];
        let s = MeasuredStats::from_tensors(&t, &[3], &[64]);
        assert_eq!(s.d1, 0.0);
        assert_eq!(s.agg_density(3), 0.0);
        assert_eq!(s.block_density(2, 64), 0.0);
        assert_eq!(s.skewness(3), 1.0, "all-zero skewness is neutral");
    }
}
