//! Plan-driven synchronization: measure → plan → execute.
//!
//! The paper's headline contribution is the *design-space exploration*:
//! no single scheme wins everywhere — the optimum depends on density,
//! densification, skew, machine count, and tensor size (Fig 7). This
//! subsystem turns that observation into a first-class mechanism:
//!
//! 1. **Measure** ([`measure::MeasuredStats`]): profile real per-worker
//!    gradients — aggregate densities `d(j)` via incremental bitmap
//!    unions, skewness `s(n)` from contiguous partition counts, and the
//!    non-zero-block share — once per bucket, cached.
//! 2. **Plan** ([`plan::plan_bucket`]): evaluate the Appendix-B
//!    [`crate::analysis::CostModel`] (with the α–β latency term) for
//!    all seven candidates in [`crate::schemes::PLANNER_CANDIDATES`]
//!    and emit the argmin as a [`BucketPlan`], with the full ranked
//!    cost table kept for auditing. With a `--compress` tier armed
//!    ([`PlanConfig::lossy_tier_armed`]), a second ranking over
//!    [`crate::schemes::LOSSY_TIER_CANDIDATES`] at the predicted
//!    post-compression density decides whether the bucket goes lossy
//!    ([`plan::plan_bucket_compressed`]) — only where the predicted
//!    volume strictly beats the best lossless candidate.
//! 3. **Execute** ([`Planner`]): [`crate::engine::SyncEngine::run`],
//!    `SimDriver`, and `LmTrainer` consume a `dyn Planner` instead of a
//!    single scheme. [`FixedPlanner`] preserves the old single-scheme
//!    behavior verbatim; [`CostPlanner`] (`--scheme auto`) picks per
//!    bucket, re-planning only when the measured density drifts past
//!    [`PlanConfig::replan_threshold`] (hysteresis), so profiling costs
//!    O(warm-up), not O(every iteration).
//!
//! Every execution reports predicted *and* transport-measured time per
//! bucket, so a misprediction is a visible number, never silent.

pub mod measure;
pub mod plan;

pub use measure::MeasuredStats;
pub use plan::{
    misprediction_ratio, plan_bucket, plan_bucket_compressed, rank_candidates,
    rank_candidates_among, BucketPlan, PlanConfig, SchemeCost,
};

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::Topology;
use crate::schemes::{self, SyncScheme};
use crate::tensor::CooTensor;

/// The outcome of planning one synchronization: which scheme to run and
/// (for cost-driven planners) the audit trail behind the choice.
pub struct PlannedSync {
    /// The scheme to execute the synchronization with.
    pub scheme: Arc<dyn SyncScheme>,
    /// The plan that chose it; `None` for [`FixedPlanner`].
    pub plan: Option<Arc<BucketPlan>>,
    /// Whether this call computed a fresh plan (profiling + argmin)
    /// rather than serving the cached one.
    pub replanned: bool,
}

/// Chooses the synchronization scheme for each bucket of gradients.
///
/// Called from inside the engine's concurrent bucket loop, so
/// implementations must be `Sync`; `plan` takes the bucket's actual
/// per-machine tensors so cost-driven planners can measure them.
pub trait Planner: Send + Sync {
    /// Planner identity for logs (`fixed:Zen`, `auto`).
    fn name(&self) -> String;

    /// Label results are reported under — the scheme's display name for
    /// fixed planners (preserving pre-planner output), `auto` otherwise.
    fn scheme_label(&self) -> String;

    /// Plan the synchronization of one bucket. `label` keys the plan
    /// cache (stable across iterations); `inputs` holds one tensor per
    /// machine; `topo` is the topology of the `Network` the caller will
    /// execute on — cost planners price against its per-class links, so
    /// planning and execution can never disagree on bandwidth, latency,
    /// or placement.
    fn plan(&self, label: &str, inputs: &[CooTensor], topo: &Topology) -> PlannedSync;
}

/// The pre-planner behavior as a `Planner`: every bucket runs the same
/// scheme, nothing is measured.
pub struct FixedPlanner {
    scheme: Arc<dyn SyncScheme>,
}

impl FixedPlanner {
    pub fn new(scheme: Box<dyn SyncScheme>) -> Self {
        FixedPlanner {
            scheme: Arc::from(scheme),
        }
    }

    /// The wrapped scheme.
    pub fn scheme(&self) -> &dyn SyncScheme {
        self.scheme.as_ref()
    }
}

impl Planner for FixedPlanner {
    fn name(&self) -> String {
        format!("fixed:{}", self.scheme.name())
    }

    fn scheme_label(&self) -> String {
        self.scheme.name().to_string()
    }

    fn plan(&self, _label: &str, _inputs: &[CooTensor], _topo: &Topology) -> PlannedSync {
        PlannedSync {
            scheme: self.scheme.clone(),
            plan: None,
            replanned: false,
        }
    }
}

/// The cost-model planner behind `--scheme auto`: one scheme instance
/// per candidate, one cached [`BucketPlan`] per bucket label, density
/// hysteresis deciding when to re-profile.
pub struct CostPlanner {
    cfg: PlanConfig,
    /// Machine count the candidate schemes were constructed for.
    n: usize,
    /// Candidate schemes keyed by their [`schemes::by_name`] name, in
    /// [`schemes::LOSSY_TIER_CANDIDATES`] order (a superset of
    /// [`schemes::PLANNER_CANDIDATES`]; lossless plans never choose the
    /// extra entries, so building them unconditionally is harmless).
    candidates: Vec<(&'static str, Arc<dyn SyncScheme>)>,
    /// Cached plan per bucket label.
    cache: Mutex<HashMap<String, Arc<BucketPlan>>>,
    /// How many full profile-and-plan passes ran — the O(warm-up)
    /// regression hook (steady state must not grow this).
    profiles: AtomicUsize,
}

impl CostPlanner {
    /// Build the planner and all its candidate schemes. `seed` and
    /// `expected_nnz` parameterize the hash-based candidates exactly as
    /// [`schemes::by_name`] does.
    pub fn new(n: usize, seed: u64, expected_nnz: usize, cfg: PlanConfig) -> Self {
        let candidates = schemes::LOSSY_TIER_CANDIDATES
            .iter()
            .map(|&name| {
                // The executed candidate must match what the cost model
                // priced: OmniReduce is block-length-parameterized, and
                // `by_name` would pin it to DEFAULT_BLOCK regardless of
                // the configured `block_len`.
                let scheme: Box<dyn SyncScheme> = if name == "omnireduce" {
                    Box::new(schemes::OmniReduce::new(cfg.block_len))
                } else {
                    schemes::by_name(name, n, seed, expected_nnz)
                        .expect("planner candidates are constructible by name")
                };
                (name, Arc::from(scheme))
            })
            .collect();
        CostPlanner {
            cfg,
            n,
            candidates,
            cache: Mutex::new(HashMap::new()),
            profiles: AtomicUsize::new(0),
        }
    }

    /// Number of full profile-and-plan passes performed so far.
    pub fn profile_count(&self) -> usize {
        self.profiles.load(Ordering::Relaxed)
    }

    /// Snapshot of every cached bucket plan (reporting).
    pub fn plans(&self) -> Vec<Arc<BucketPlan>> {
        let mut v: Vec<Arc<BucketPlan>> =
            self.cache.lock().unwrap().values().cloned().collect();
        v.sort_by(|a, b| a.label.cmp(&b.label));
        v
    }

    /// The planner's configuration.
    pub fn config(&self) -> &PlanConfig {
        &self.cfg
    }

    fn scheme_for(&self, name: &str) -> Arc<dyn SyncScheme> {
        self.candidates
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, s)| s.clone())
            .expect("plans only choose known candidates")
    }
}

impl Planner for CostPlanner {
    fn name(&self) -> String {
        "auto".to_string()
    }

    fn scheme_label(&self) -> String {
        "auto".to_string()
    }

    fn plan(&self, label: &str, inputs: &[CooTensor], topo: &Topology) -> PlannedSync {
        assert!(!inputs.is_empty());
        let n = inputs.len();
        // The candidates (Zen's hasher in particular) were built for a
        // fixed machine count; pricing one n and executing another would
        // fail deep inside a scheme instead of at the plan boundary.
        assert_eq!(
            n, self.n,
            "CostPlanner built for {} machines asked to plan for {n}",
            self.n
        );
        // The cheap per-iteration measurement: mean density only.
        let d1 = inputs.iter().map(|t| t.density()).sum::<f64>() / n as f64;

        if let Some(cached) = self.cache.lock().unwrap().get(label).cloned() {
            let drift = if cached.planned_d1 > 0.0 {
                (d1 - cached.planned_d1).abs() / cached.planned_d1
            } else if d1 > 0.0 {
                f64::INFINITY
            } else {
                0.0
            };
            // A plan priced for a different topology (links or rank
            // placement) is stale regardless of density (the caller may
            // rebuild its Network between runs).
            if drift <= self.cfg.replan_threshold && cached.planned_topo == *topo {
                return PlannedSync {
                    scheme: self.scheme_for(cached.chosen),
                    plan: Some(cached),
                    replanned: false,
                };
            }
        }

        // Warm-up (or post-drift) path: full profile + argmin. Computed
        // outside the cache lock — concurrent buckets have distinct
        // labels, so no duplicated work in practice.
        let stats = MeasuredStats::from_tensors(inputs, &[n], &[self.cfg.block_len]);
        let m = inputs[0].dense_len as f64;
        let plan = if self.cfg.lossy_tier_armed() {
            let cd1 = compressed_density(&self.cfg.compress, inputs, stats.d1);
            Arc::new(plan_bucket_compressed(label, m, n, topo, &self.cfg, stats, cd1))
        } else {
            Arc::new(plan_bucket(label, m, n, topo, &self.cfg, stats))
        };
        self.profiles.fetch_add(1, Ordering::Relaxed);
        self.cache
            .lock()
            .unwrap()
            .insert(label.to_string(), plan.clone());
        PlannedSync {
            scheme: self.scheme_for(plan.chosen),
            plan: Some(plan),
            replanned: true,
        }
    }
}

/// Predicted mean post-compression per-worker density for one bucket.
/// Top-k has a closed form; a magnitude threshold has none, so its
/// survivor fraction is counted from the actual values being planned —
/// one linear pass, done only on the (cached, O(warm-up)) profiling
/// path.
pub fn compressed_density(
    spec: &crate::compress::CompressSpec,
    inputs: &[crate::tensor::CooTensor],
    d1: f64,
) -> f64 {
    let dense_len = inputs.first().map_or(0, |t| t.dense_len);
    match *spec {
        crate::compress::CompressSpec::Threshold(t) => {
            if dense_len == 0 || inputs.is_empty() {
                return d1;
            }
            let survivors: usize = inputs
                .iter()
                .map(|x| x.values.iter().filter(|v| v.abs() >= t).count())
                .sum();
            survivors as f64 / (inputs.len() * dense_len) as f64
        }
        _ => spec.predicted_density(dense_len, d1),
    }
}

/// Construct a planner by CLI name: `auto` → [`CostPlanner`]; any
/// [`schemes::by_name`] name → [`FixedPlanner`] around that scheme.
pub fn by_name(
    name: &str,
    n: usize,
    seed: u64,
    expected_nnz: usize,
    cfg: PlanConfig,
) -> Option<Box<dyn Planner>> {
    if name.eq_ignore_ascii_case("auto") {
        Some(Box::new(CostPlanner::new(n, seed, expected_nnz, cfg)))
    } else {
        schemes::by_name(name, n, seed, expected_nnz)
            .map(|s| Box::new(FixedPlanner::new(s)) as Box<dyn Planner>)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::LinkKind;
    use crate::workload::random_uniform_inputs;

    #[test]
    fn fixed_planner_is_transparent() {
        let scheme = schemes::by_name("zen", 4, 7, 256).unwrap();
        let p = FixedPlanner::new(scheme);
        assert_eq!(p.scheme_label(), "Zen");
        assert_eq!(p.name(), "fixed:Zen");
        let inputs = random_uniform_inputs(1, 4, 1024, 0.05);
        let planned = p.plan("anything", &inputs, &Topology::flat(4, LinkKind::Tcp25));
        assert_eq!(planned.scheme.name(), "Zen");
        assert!(planned.plan.is_none());
        assert!(!planned.replanned);
    }

    #[test]
    fn auto_planner_caches_per_label() {
        let p = CostPlanner::new(4, 7, 256, PlanConfig::default());
        let inputs = random_uniform_inputs(2, 4, 4096, 0.03);
        let tcp = Topology::flat(4, LinkKind::Tcp25);
        let a = p.plan("bucket0", &inputs, &tcp);
        assert!(a.replanned);
        assert_eq!(p.profile_count(), 1);
        let b = p.plan("bucket0", &inputs, &tcp);
        assert!(!b.replanned, "same density → cached plan");
        assert_eq!(p.profile_count(), 1, "profiling is O(warm-up)");
        assert_eq!(
            a.plan.as_ref().unwrap().chosen,
            b.plan.as_ref().unwrap().chosen
        );
        // a different link invalidates the cached plan (re-priced)
        let c = p.plan("bucket0", &inputs, &Topology::flat(4, LinkKind::Rdma100));
        assert!(c.replanned, "new link → stale plan");
        assert_eq!(p.profile_count(), 2);
        // so does a different placement of the same endpoints
        let hier = Topology::two_level(2, 2, LinkKind::NvLink, LinkKind::Rdma100);
        let d = p.plan("bucket0", &inputs, &hier);
        assert!(d.replanned, "new placement → stale plan");
        assert_eq!(p.profile_count(), 3);
        // a different bucket label profiles once more
        p.plan("bucket1", &inputs, &tcp);
        assert_eq!(p.profile_count(), 4);
        assert_eq!(p.plans().len(), 2);
    }

    #[test]
    fn density_drift_triggers_replan() {
        let p = CostPlanner::new(4, 7, 256, PlanConfig::default());
        let tcp = Topology::flat(4, LinkKind::Tcp25);
        let sparse = random_uniform_inputs(3, 4, 4096, 0.01);
        p.plan("b", &sparse, &tcp);
        assert_eq!(p.profile_count(), 1);
        // within hysteresis: no re-plan
        let nudged = random_uniform_inputs(4, 4, 4096, 0.011);
        p.plan("b", &nudged, &tcp);
        assert_eq!(p.profile_count(), 1);
        // 4× density: outside hysteresis → re-profile and re-plan
        let denser = random_uniform_inputs(5, 4, 4096, 0.04);
        let r = p.plan("b", &denser, &tcp);
        assert!(r.replanned);
        assert_eq!(p.profile_count(), 2);
    }

    #[test]
    fn armed_cost_planner_goes_lossy_and_can_execute_the_choice() {
        let cfg = PlanConfig {
            compress: crate::compress::CompressSpec::TopK(0.001),
            accuracy_budget: 0.05,
            ..PlanConfig::default()
        };
        let p = CostPlanner::new(8, 7, 256, cfg);
        let inputs = random_uniform_inputs(6, 8, 1 << 16, 0.03);
        let planned = p.plan("b", &inputs, &Topology::flat(8, LinkKind::Tcp25));
        let plan = planned.plan.as_ref().unwrap();
        assert!(plan.lossy, "30× reduction must beat lossless");
        assert!(plan.predicted_lossy_time.unwrap() < plan.predicted_lossless_time);
        // Whatever the lossy tier chose must be executable by this
        // planner — including the oktopk-only candidate.
        assert_eq!(planned.scheme.name().is_empty(), false);
        // Unarmed planner on the same bucket: lossless plan, no tier.
        let p2 = CostPlanner::new(8, 7, 256, PlanConfig::default());
        let planned2 = p2.plan("b", &inputs, &Topology::flat(8, LinkKind::Tcp25));
        let plan2 = planned2.plan.as_ref().unwrap();
        assert!(!plan2.lossy);
        assert!(plan2.predicted_lossy_time.is_none());
    }

    #[test]
    fn compressed_density_measures_threshold_survivors() {
        use crate::compress::CompressSpec;
        let t = crate::tensor::CooTensor::from_sorted(
            8,
            vec![0, 1, 2, 3],
            vec![0.1, -0.9, 0.5, -0.05],
        );
        let d1 = t.density();
        let spec = CompressSpec::Threshold(0.5);
        let got = compressed_density(&spec, &[t.clone()], d1);
        assert!((got - 2.0 / 8.0).abs() < 1e-12, "|v| >= 0.5 keeps 2 of 8");
        // Top-k path delegates to the closed form.
        let k = CompressSpec::TopK(2.0);
        assert_eq!(compressed_density(&k, &[t], d1), k.predicted_density(8, d1));
    }

    #[test]
    fn by_name_resolves_auto_and_fixed() {
        let auto = by_name("auto", 4, 1, 64, PlanConfig::default()).unwrap();
        assert_eq!(auto.scheme_label(), "auto");
        let fixed = by_name("sparcml", 4, 1, 64, PlanConfig::default()).unwrap();
        assert_eq!(fixed.scheme_label(), "SparCML");
        assert!(by_name("warp-drive", 4, 1, 64, PlanConfig::default()).is_none());
    }
}
