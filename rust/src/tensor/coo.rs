//! COO (coordinate list) sparse tensors — paper Definition 2.
//!
//! The canonical sparse wire format: a list of non-zero values plus the
//! list of their u32 indices. Invariant: indices are strictly ascending,
//! so merges are linear scans.

use super::{DenseTensor, WireFormat, BYTES_F32, BYTES_IDX};

/// A sparse gradient tensor in COO format over a logical dense length.
#[derive(Clone, Debug, PartialEq)]
pub struct CooTensor {
    /// Logical length of the underlying dense tensor `|G|`.
    pub dense_len: usize,
    /// Strictly ascending non-zero indices.
    pub indices: Vec<u32>,
    /// Gradient values, parallel to `indices`.
    pub values: Vec<f32>,
}

/// A borrowed view of a COO tensor: the zero-copy currency of the
/// scratch-arena hot path. [`PartitionScratch`] hands out its partitions
/// as `CooSlice`s so the Zen sync loop can size wire payloads, encode
/// hash bitmaps, and merge aggregates without materializing owned
/// tensors per iteration.
///
/// [`PartitionScratch`]: crate::hashing::hierarchical::PartitionScratch
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CooSlice<'a> {
    pub dense_len: usize,
    /// Strictly ascending non-zero indices.
    pub indices: &'a [u32],
    /// Gradient values, parallel to `indices`.
    pub values: &'a [f32],
}

impl<'a> CooSlice<'a> {
    pub fn new(dense_len: usize, indices: &'a [u32], values: &'a [f32]) -> Self {
        debug_assert_eq!(indices.len(), values.len());
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
        CooSlice {
            dense_len,
            indices,
            values,
        }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Same accounting as [`CooTensor`]'s [`WireFormat`] impl.
    pub fn wire_bytes(&self) -> usize {
        self.nnz() * (BYTES_F32 + BYTES_IDX)
    }

    /// Materialize an owned tensor (allocates; off the hot path).
    pub fn to_tensor(self) -> CooTensor {
        CooTensor::from_sorted(self.dense_len, self.indices.to_vec(), self.values.to_vec())
    }
}

impl CooTensor {
    /// Build and enforce the sorted-unique invariant (sorts if needed).
    pub fn new(dense_len: usize, indices: Vec<u32>, values: Vec<f32>) -> Self {
        assert_eq!(indices.len(), values.len());
        debug_assert!(indices.iter().all(|&i| (i as usize) < dense_len));
        let mut t = CooTensor {
            dense_len,
            indices,
            values,
        };
        if !t.is_sorted_unique() {
            t.sort_and_combine();
        }
        t
    }

    /// Build from already-sorted unique indices without re-checking in
    /// release builds (hot path).
    pub fn from_sorted(dense_len: usize, indices: Vec<u32>, values: Vec<f32>) -> Self {
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
        CooTensor {
            dense_len,
            indices,
            values,
        }
    }

    pub fn empty(dense_len: usize) -> Self {
        CooTensor {
            dense_len,
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    fn is_sorted_unique(&self) -> bool {
        self.indices.windows(2).all(|w| w[0] < w[1])
    }

    /// Sort by index and sum duplicate entries.
    fn sort_and_combine(&mut self) {
        let mut pairs: Vec<(u32, f32)> = self
            .indices
            .iter()
            .copied()
            .zip(self.values.iter().copied())
            .collect();
        pairs.sort_unstable_by_key(|p| p.0);
        let mut indices = Vec::with_capacity(pairs.len());
        let mut values: Vec<f32> = Vec::with_capacity(pairs.len());
        for (i, v) in pairs {
            if indices.last() == Some(&i) {
                *values.last_mut().unwrap() += v;
            } else {
                indices.push(i);
                values.push(v);
            }
        }
        self.indices = indices;
        self.values = values;
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn density(&self) -> f64 {
        if self.dense_len == 0 {
            return 0.0;
        }
        self.nnz() as f64 / self.dense_len as f64
    }

    pub fn to_dense(&self) -> DenseTensor {
        let mut d = DenseTensor::zeros(self.dense_len);
        for (&i, &v) in self.indices.iter().zip(self.values.iter()) {
            d.values[i as usize] = v;
        }
        d
    }

    /// Merge-aggregate two sorted COO tensors (gradients with the same
    /// index are summed) — the aggregation primitive of every scheme.
    pub fn merge(&self, other: &CooTensor) -> CooTensor {
        let mut indices = Vec::with_capacity(self.nnz() + other.nnz());
        let mut values = Vec::with_capacity(self.nnz() + other.nnz());
        merge_into(self.as_slice(), other.as_slice(), &mut indices, &mut values);
        CooTensor::from_sorted(self.dense_len, indices, values)
    }

    /// Borrowed view of this tensor (zero-copy hot-path currency).
    pub fn as_slice(&self) -> CooSlice<'_> {
        CooSlice {
            dense_len: self.dense_len,
            indices: &self.indices,
            values: &self.values,
        }
    }

    /// Aggregate many COO tensors with a k-way balanced reduction.
    pub fn merge_all(tensors: &[CooTensor]) -> CooTensor {
        assert!(!tensors.is_empty());
        if tensors.len() == 1 {
            return tensors[0].clone();
        }
        merge_tree(tensors.to_vec())
    }

    /// Aggregate many borrowed COO views with the same balanced tree
    /// reduction as [`merge_all`](CooTensor::merge_all), without first
    /// materializing owned inputs — the aggregation step of the
    /// scratch-path Zen sync (server `p` merges every worker's
    /// partition-`p` view straight out of the partition scratch).
    pub fn merge_all_slices(parts: &[CooSlice<'_>]) -> CooTensor {
        assert!(!parts.is_empty());
        if parts.len() == 1 {
            return parts[0].to_tensor();
        }
        // First round: merge view pairs into owned tensors, then tree.
        let mut layer: Vec<CooTensor> = Vec::with_capacity(crate::util::ceil_div(parts.len(), 2));
        let mut it = parts.chunks(2);
        for pair in &mut it {
            if pair.len() == 2 {
                let mut indices = Vec::with_capacity(pair[0].nnz() + pair[1].nnz());
                let mut values = Vec::with_capacity(pair[0].nnz() + pair[1].nnz());
                merge_into(pair[0], pair[1], &mut indices, &mut values);
                layer.push(CooTensor::from_sorted(pair[0].dense_len, indices, values));
            } else {
                layer.push(pair[0].to_tensor());
            }
        }
        merge_tree(layer)
    }

    /// Restrict to indices within [lo, hi), re-based to the sub-range —
    /// the contiguous-partition primitive of Sparse PS.
    pub fn slice_range(&self, lo: u32, hi: u32) -> CooTensor {
        let hi = hi.max(lo);
        let start = self.indices.partition_point(|&i| i < lo);
        let end = self.indices.partition_point(|&i| i < hi);
        CooTensor::from_sorted(
            (hi - lo) as usize,
            self.indices[start..end].iter().map(|&i| i - lo).collect(),
            self.values[start..end].to_vec(),
        )
    }

    /// Concatenate tensors that partition disjoint contiguous ranges back
    /// into one tensor over the full range.
    pub fn concat_ranges(parts: &[(u32, CooTensor)], dense_len: usize) -> CooTensor {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        let mut sorted: Vec<&(u32, CooTensor)> = parts.iter().collect();
        sorted.sort_by_key(|(off, _)| *off);
        for (off, t) in sorted {
            indices.extend(t.indices.iter().map(|&i| i + off));
            values.extend_from_slice(&t.values);
        }
        CooTensor::new(dense_len, indices, values)
    }
}

impl WireFormat for CooTensor {
    fn wire_bytes(&self) -> usize {
        self.nnz() * (BYTES_F32 + BYTES_IDX)
    }
}

/// Pairwise balanced tree reduction over owned tensors — the shared
/// tail of [`CooTensor::merge_all`] and [`CooTensor::merge_all_slices`].
fn merge_tree(mut layer: Vec<CooTensor>) -> CooTensor {
    assert!(!layer.is_empty());
    while layer.len() > 1 {
        let mut next = Vec::with_capacity(crate::util::ceil_div(layer.len(), 2));
        let mut it = layer.chunks(2);
        for pair in &mut it {
            if pair.len() == 2 {
                next.push(pair[0].merge(&pair[1]));
            } else {
                next.push(pair[0].clone());
            }
        }
        layer = next;
    }
    layer.pop().unwrap()
}

/// Linear merge of two sorted COO views into caller-owned output
/// buffers (cleared first; gradients at equal indices are summed).
/// The borrowed-buffer primitive behind [`CooTensor::merge`] and
/// [`CooTensor::merge_all_slices`]: with warmed buffers it performs no
/// allocation.
pub fn merge_into(a: CooSlice<'_>, b: CooSlice<'_>, indices: &mut Vec<u32>, values: &mut Vec<f32>) {
    assert_eq!(a.dense_len, b.dense_len);
    indices.clear();
    values.clear();
    indices.reserve(a.nnz() + b.nnz());
    values.reserve(a.nnz() + b.nnz());
    crate::kernel::active::merge_sorted(a.indices, a.values, b.indices, b.values, indices, values);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, prop_assert};

    fn t(dense_len: usize, pairs: &[(u32, f32)]) -> CooTensor {
        CooTensor::new(
            dense_len,
            pairs.iter().map(|p| p.0).collect(),
            pairs.iter().map(|p| p.1).collect(),
        )
    }

    #[test]
    fn new_sorts_and_combines() {
        let c = t(10, &[(5, 1.0), (2, 2.0), (5, 3.0)]);
        assert_eq!(c.indices, vec![2, 5]);
        assert_eq!(c.values, vec![2.0, 4.0]);
    }

    #[test]
    fn merge_sums_overlaps() {
        let a = t(10, &[(1, 1.0), (3, 1.0)]);
        let b = t(10, &[(3, 2.0), (7, 5.0)]);
        let m = a.merge(&b);
        assert_eq!(m.indices, vec![1, 3, 7]);
        assert_eq!(m.values, vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn merge_all_matches_dense_sum() {
        let xs = vec![
            t(8, &[(0, 1.0), (4, 2.0)]),
            t(8, &[(4, 3.0)]),
            t(8, &[(7, 1.0), (0, -1.0)]),
        ];
        let merged = CooTensor::merge_all(&xs);
        let mut dense = DenseTensor::zeros(8);
        for x in &xs {
            dense.add_coo(x);
        }
        // index 0 sums to 0.0 but stays an explicit entry after merge
        assert_eq!(merged.to_dense(), dense);
    }

    #[test]
    fn slice_range_rebases() {
        let a = t(12, &[(1, 1.0), (5, 2.0), (9, 3.0)]);
        let s = a.slice_range(4, 8);
        assert_eq!(s.dense_len, 4);
        assert_eq!(s.indices, vec![1]);
        assert_eq!(s.values, vec![2.0]);
    }

    #[test]
    fn concat_ranges_roundtrip() {
        let a = t(12, &[(1, 1.0), (5, 2.0), (9, 3.0)]);
        let parts: Vec<(u32, CooTensor)> = (0..3)
            .map(|p| (p * 4, a.slice_range(p * 4, (p + 1) * 4)))
            .collect();
        let back = CooTensor::concat_ranges(&parts, 12);
        assert_eq!(back, a);
    }

    #[test]
    fn wire_bytes_counts_pairs() {
        let a = t(100, &[(1, 1.0), (5, 2.0)]);
        assert_eq!(a.wire_bytes(), 2 * 8);
    }

    #[test]
    fn merge_all_slices_matches_merge_all() {
        let xs = vec![
            t(16, &[(0, 1.0), (4, 2.0), (9, 1.5)]),
            t(16, &[(4, 3.0), (15, 1.0)]),
            t(16, &[(7, 1.0), (0, -1.0)]),
            t(16, &[]),
            t(16, &[(9, 0.5)]),
        ];
        let views: Vec<CooSlice> = xs.iter().map(|x| x.as_slice()).collect();
        let from_views = CooTensor::merge_all_slices(&views);
        let from_owned = CooTensor::merge_all(&xs);
        assert_eq!(from_views.to_dense(), from_owned.to_dense());
        // single view: plain copy-out
        let one = CooTensor::merge_all_slices(&views[..1]);
        assert_eq!(one, xs[0]);
    }

    #[test]
    fn merge_into_reuses_buffers() {
        let a = t(10, &[(1, 1.0), (3, 1.0)]);
        let b = t(10, &[(3, 2.0), (7, 5.0)]);
        let mut idx = Vec::new();
        let mut val = Vec::new();
        merge_into(a.as_slice(), b.as_slice(), &mut idx, &mut val);
        assert_eq!(idx, vec![1, 3, 7]);
        assert_eq!(val, vec![1.0, 3.0, 5.0]);
        // second merge into the same buffers: previous contents cleared
        merge_into(b.as_slice(), b.as_slice(), &mut idx, &mut val);
        assert_eq!(idx, vec![3, 7]);
        assert_eq!(val, vec![4.0, 10.0]);
    }

    #[test]
    fn slice_view_accounting_matches_owned() {
        let a = t(100, &[(1, 1.0), (5, 2.0)]);
        let v = a.as_slice();
        assert_eq!(v.nnz(), 2);
        assert_eq!(v.wire_bytes(), a.wire_bytes());
        assert_eq!(v.to_tensor(), a);
    }

    #[test]
    fn prop_merge_equals_dense_add() {
        check(100, |g| {
            let len = g.usize_in(1, 200);
            let na = g.usize_in(0, len.min(50));
            let nb = g.usize_in(0, len.min(50));
            let ia = g.distinct_sorted_u32(na, len as u32);
            let ib = g.distinct_sorted_u32(nb, len as u32);
            let va: Vec<f32> = (0..na).map(|_| g.f64_unit() as f32 + 0.1).collect();
            let vb: Vec<f32> = (0..nb).map(|_| g.f64_unit() as f32 + 0.1).collect();
            let a = CooTensor::from_sorted(len, ia, va);
            let b = CooTensor::from_sorted(len, ib, vb);
            let m = a.merge(&b);
            let mut d = a.to_dense();
            d.add_assign(&b.to_dense());
            prop_assert(m.to_dense() == d, "merge == dense add")
        });
    }

    #[test]
    fn prop_slice_concat_identity() {
        check(100, |g| {
            let len = g.usize_in(4, 300);
            let n = g.usize_in(0, len.min(40));
            let idx = g.distinct_sorted_u32(n, len as u32);
            let vals: Vec<f32> = (0..n).map(|_| g.f64_unit() as f32 + 0.5).collect();
            let a = CooTensor::from_sorted(len, idx, vals);
            let parts_n = g.usize_in(1, 8);
            let per = crate::util::ceil_div(len, parts_n) as u32;
            let parts: Vec<(u32, CooTensor)> = (0..parts_n as u32)
                .map(|p| {
                    let lo = (p * per).min(len as u32);
                    let hi = ((p + 1) * per).min(len as u32);
                    (lo, a.slice_range(lo, hi))
                })
                .collect();
            let back = CooTensor::concat_ranges(&parts, len);
            prop_assert(back == a, "slice+concat identity")
        });
    }
}
