//! Positional bitmaps (paper §3.2.1 "Bitmap") and the bit-level substrate
//! shared with the hash bitmap (Algorithm 2).
//!
//! One bit per position: 1 ⇔ the gradient at that position is non-zero.
//! Wire size is `ceil(len/8)` bytes — for a full dense range that is
//! `|G|/32` in FP32-value units, matching the paper's accounting.

use super::WireFormat;

/// A fixed-length bitmap.
#[derive(Clone, Debug, PartialEq)]
pub struct Bitmap {
    words: Vec<u64>,
    len: usize,
}

impl Bitmap {
    pub fn zeros(len: usize) -> Self {
        Bitmap {
            words: vec![0u64; crate::util::ceil_div(len.max(1), 64)],
            len,
        }
    }

    /// Build from the set bit positions.
    pub fn from_ones(len: usize, ones: &[u32]) -> Self {
        let mut b = Bitmap::zeros(len);
        for &i in ones {
            b.set(i as usize);
        }
        b
    }

    /// Build from little-endian u64 word storage (the wire layout used
    /// by [`crate::wire::codec`]). `bytes` must hold exactly
    /// `ceil(len.max(1)/64)` words; bits beyond `len` are masked off, so
    /// a forged frame cannot smuggle out-of-range positions in.
    pub fn from_le_bytes(len: usize, bytes: &[u8]) -> Self {
        let n = crate::util::ceil_div(len.max(1), 64);
        assert_eq!(bytes.len(), n * 8, "word count must match bit length");
        let mut words: Vec<u64> = bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        if len == 0 {
            words[0] = 0;
        } else if len % 64 != 0 {
            words[n - 1] &= (1u64 << (len % 64)) - 1;
        }
        Bitmap { words, len }
    }

    /// Reinitialize in place to an all-zero bitmap of `len` bits,
    /// reusing the word buffer (allocation-free once the buffer has
    /// grown to the steady-state length).
    pub fn reset(&mut self, len: usize) {
        let n = crate::util::ceil_div(len.max(1), 64);
        self.words.clear();
        self.words.resize(n, 0);
        self.len = len;
    }

    /// The u64 word storage (little-endian bit order within words) —
    /// lets the wire codec bulk-copy the bitmap without re-deriving
    /// words from `ones()`.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        crate::kernel::active::count_ones_words(&self.words)
    }

    /// Positions of set bits, ascending (word-level scan, not bit loop).
    pub fn ones(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.count_ones());
        self.for_each_one(|i| out.push(i as u32));
        out
    }

    /// Visit the set bit positions in ascending order without
    /// materializing them — the allocation-free sibling of `ones()`,
    /// used by the hash-bitmap decode hot path.
    #[inline]
    pub fn for_each_one<F: FnMut(usize)>(&self, mut f: F) {
        for (wi, &w) in self.words.iter().enumerate() {
            let mut w = w;
            while w != 0 {
                let b = w.trailing_zeros() as usize;
                f(wi * 64 + b);
                w &= w - 1;
            }
        }
    }

    /// Bitwise OR (set union) with another bitmap of equal length.
    pub fn or_assign(&mut self, other: &Bitmap) {
        assert_eq!(self.len, other.len);
        crate::kernel::active::or_words(&mut self.words, &other.words);
    }

    /// Bitwise AND count — fast overlap cardinality for Definition 3.
    pub fn and_count(&self, other: &Bitmap) -> usize {
        assert_eq!(self.len, other.len);
        crate::kernel::active::and_count_words(&self.words, &other.words)
    }
}

impl Default for Bitmap {
    /// An empty bitmap laid out identically to `Bitmap::zeros(0)` (one
    /// zero word), so default-constructed scratch payloads compare equal
    /// to constructed ones.
    fn default() -> Self {
        Bitmap::zeros(0)
    }
}

impl WireFormat for Bitmap {
    fn wire_bytes(&self) -> usize {
        crate::util::ceil_div(self.len, 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, prop_assert};

    #[test]
    fn set_get_roundtrip() {
        let mut b = Bitmap::zeros(130);
        for i in [0usize, 63, 64, 65, 129] {
            assert!(!b.get(i));
            b.set(i);
            assert!(b.get(i));
        }
        assert_eq!(b.count_ones(), 5);
    }

    #[test]
    fn ones_ascending() {
        let b = Bitmap::from_ones(200, &[5, 64, 3, 199]);
        assert_eq!(b.ones(), vec![3, 5, 64, 199]);
    }

    #[test]
    fn or_union() {
        let mut a = Bitmap::from_ones(100, &[1, 2]);
        let b = Bitmap::from_ones(100, &[2, 3]);
        a.or_assign(&b);
        assert_eq!(a.ones(), vec![1, 2, 3]);
    }

    #[test]
    fn and_count_overlap() {
        let a = Bitmap::from_ones(100, &[1, 2, 50]);
        let b = Bitmap::from_ones(100, &[2, 50, 99]);
        assert_eq!(a.and_count(&b), 2);
    }

    #[test]
    fn wire_bytes_len_over_8() {
        assert_eq!(Bitmap::zeros(15).wire_bytes(), 2);
        assert_eq!(Bitmap::zeros(16).wire_bytes(), 2);
        assert_eq!(Bitmap::zeros(17).wire_bytes(), 3);
    }

    #[test]
    fn reset_reuses_and_clears() {
        let mut b = Bitmap::from_ones(100, &[1, 64, 99]);
        b.reset(130);
        assert_eq!(b.len(), 130);
        assert_eq!(b.count_ones(), 0, "reset must clear stale bits");
        b.set(129);
        assert_eq!(b.ones(), vec![129]);
        b.reset(5);
        assert_eq!(b, Bitmap::zeros(5));
    }

    #[test]
    fn le_bytes_words_roundtrip() {
        for len in [0usize, 1, 63, 64, 65, 130, 500] {
            let ones: Vec<u32> = (0..len as u32).filter(|i| i % 7 == 3).collect();
            let b = Bitmap::from_ones(len, &ones);
            let bytes: Vec<u8> = b.words().iter().flat_map(|w| w.to_le_bytes()).collect();
            let back = Bitmap::from_le_bytes(len, &bytes);
            assert_eq!(back, b, "len {len}");
        }
    }

    #[test]
    fn le_bytes_masks_out_of_range_bits() {
        // All-ones words with len = 10: bits 10..64 must be dropped.
        let bytes = [0xFFu8; 8];
        let b = Bitmap::from_le_bytes(10, &bytes);
        assert_eq!(b.count_ones(), 10);
        let z = Bitmap::from_le_bytes(0, &bytes);
        assert_eq!(z.count_ones(), 0);
    }

    #[test]
    fn for_each_one_matches_ones() {
        let b = Bitmap::from_ones(200, &[5, 64, 3, 199]);
        let mut seen = Vec::new();
        b.for_each_one(|i| seen.push(i as u32));
        assert_eq!(seen, b.ones());
    }

    #[test]
    fn prop_ones_roundtrip() {
        check(100, |g| {
            let len = g.usize_in(1, 500);
            let n = g.usize_in(0, len.min(64));
            let idx = g.distinct_sorted_u32(n, len as u32);
            let b = Bitmap::from_ones(len, &idx);
            prop_assert(b.ones() == idx && b.count_ones() == n, "ones roundtrip")
        });
    }
}
