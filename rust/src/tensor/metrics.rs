//! Sparsity metrics from paper §2.2: overlap ratio (Definition 3),
//! densification ratio (Definition 4), skewness ratio (Definition 5).

use super::{Bitmap, CooTensor};

/// Overlap ratio of two index sets (Definition 3):
/// `|I1 ∩ I2| / min(|I1|, |I2|)`.
pub fn overlap_ratio(a: &CooTensor, b: &CooTensor) -> f64 {
    assert_eq!(a.dense_len, b.dense_len);
    let min = a.nnz().min(b.nnz());
    if min == 0 {
        return 0.0;
    }
    // Sorted-merge intersection count.
    let (mut i, mut j, mut inter) = (0usize, 0usize, 0usize);
    while i < a.nnz() && j < b.nnz() {
        match a.indices[i].cmp(&b.indices[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
        }
    }
    inter as f64 / min as f64
}

/// Overlap ratio via bitmaps — used when tensors are already bitmap-encoded.
pub fn overlap_ratio_bitmap(a: &Bitmap, b: &Bitmap) -> f64 {
    let min = a.count_ones().min(b.count_ones());
    if min == 0 {
        return 0.0;
    }
    a.and_count(b) as f64 / min as f64
}

/// Density after aggregating `tensors` (the union of index sets over the
/// dense length): `d_G^n`.
pub fn aggregated_density(tensors: &[CooTensor]) -> f64 {
    assert!(!tensors.is_empty());
    let len = tensors[0].dense_len;
    let mut bm = Bitmap::zeros(len);
    for t in tensors {
        assert_eq!(t.dense_len, len);
        for &i in &t.indices {
            bm.set(i as usize);
        }
    }
    bm.count_ones() as f64 / len.max(1) as f64
}

/// Densification ratio `γ_G^n = d_G^n / d_G` (Definition 4), where `d_G`
/// is the mean per-worker density.
pub fn densification_ratio(tensors: &[CooTensor]) -> f64 {
    assert!(!tensors.is_empty());
    let mean_density: f64 =
        tensors.iter().map(|t| t.density()).sum::<f64>() / tensors.len() as f64;
    if mean_density == 0.0 {
        return 0.0;
    }
    aggregated_density(tensors) / mean_density
}

/// Per-partition non-zero counts when the dense range is split evenly into
/// `n` contiguous partitions (basis for Fig 2a's heatmap).
pub fn partition_nnz(t: &CooTensor, n: usize) -> Vec<usize> {
    assert!(n > 0);
    let per = crate::util::ceil_div(t.dense_len, n) as u32;
    let mut counts = vec![0usize; n];
    for &i in &t.indices {
        counts[(i / per.max(1)) as usize] += 1;
    }
    counts
}

/// Skewness ratio `s_G^n = max_i d_{G_i} / d_G` (Definition 5) for an even
/// contiguous split into `n` partitions.
pub fn skewness_ratio(t: &CooTensor, n: usize) -> f64 {
    let d_g = t.density();
    if d_g == 0.0 {
        return 1.0;
    }
    let per = crate::util::ceil_div(t.dense_len, n) as f64;
    partition_nnz(t, n)
        .into_iter()
        .map(|c| (c as f64 / per) / d_g)
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coo(len: usize, idx: &[u32]) -> CooTensor {
        CooTensor::from_sorted(len, idx.to_vec(), vec![1.0; idx.len()])
    }

    #[test]
    fn overlap_full_and_none() {
        let a = coo(10, &[1, 2, 3]);
        let b = coo(10, &[1, 2, 3, 4]);
        assert!((overlap_ratio(&a, &b) - 1.0).abs() < 1e-12);
        let c = coo(10, &[7, 8]);
        assert_eq!(overlap_ratio(&a, &c), 0.0);
    }

    #[test]
    fn overlap_partial() {
        let a = coo(10, &[1, 2, 3, 4]);
        let b = coo(10, &[3, 4, 5, 6]);
        assert!((overlap_ratio(&a, &b) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn overlap_bitmap_matches_coo() {
        let a = coo(64, &[1, 5, 9, 33]);
        let b = coo(64, &[5, 9, 60]);
        let ba = Bitmap::from_ones(64, &a.indices);
        let bb = Bitmap::from_ones(64, &b.indices);
        assert!((overlap_ratio(&a, &b) - overlap_ratio_bitmap(&ba, &bb)).abs() < 1e-12);
    }

    #[test]
    fn densification_bounds() {
        // identical tensors: union == each, ratio 1
        let xs = vec![coo(100, &[1, 2, 3]); 4];
        assert!((densification_ratio(&xs) - 1.0).abs() < 1e-12);
        // disjoint tensors: ratio == n
        let ys: Vec<CooTensor> = (0..4u32).map(|w| coo(100, &[w * 10, w * 10 + 1])).collect();
        assert!((densification_ratio(&ys) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn skewness_uniform_is_one() {
        // perfectly even non-zeros across 4 partitions of 8
        let t = coo(8, &[0, 2, 4, 6]);
        assert!((skewness_ratio(&t, 4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skewness_concentrated() {
        // all non-zeros in partition 0 of 4 → s = 4
        let t = coo(8, &[0, 1]);
        assert!((skewness_ratio(&t, 4) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn partition_nnz_sums_to_nnz() {
        let t = coo(100, &[0, 5, 49, 50, 99]);
        let c = partition_nnz(&t, 7);
        assert_eq!(c.iter().sum::<usize>(), 5);
    }
}
