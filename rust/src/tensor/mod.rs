//! Gradient tensor representations and sparsity metrics.
//!
//! The paper (§2.2) defines *dense tensors* (Definition 1) and *sparse
//! tensors* (Definition 2, COO realization), plus three sparsity metrics:
//! the overlap ratio (Definition 3), the densification ratio
//! (Definition 4), and the skewness ratio (Definition 5). §3.2 adds three
//! wire formats for indices — COO, tensor blocks (OmniReduce), positional
//! bitmap — and Zen's hash bitmap (Algorithm 2, implemented in
//! [`crate::hashing::hashbitmap`] since it depends on the hash partition).
//!
//! All formats implement [`WireFormat::wire_bytes`], the byte count a
//! scheme puts on the network — the quantity every figure in the paper's
//! evaluation ultimately measures.

pub mod bitmap;
pub mod block;
pub mod coo;
pub mod dense;
pub mod metrics;

pub use bitmap::Bitmap;
pub use block::BlockTensor;
pub use coo::{merge_into, CooSlice, CooTensor};
pub use dense::DenseTensor;

/// Bytes per FP32 gradient value.
pub const BYTES_F32: usize = 4;
/// Bytes per COO index (u32).
pub const BYTES_IDX: usize = 4;

/// Anything that can report its on-the-wire size.
pub trait WireFormat {
    /// Bytes this representation occupies when transmitted.
    fn wire_bytes(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_are_fp32() {
        assert_eq!(BYTES_F32, std::mem::size_of::<f32>());
        assert_eq!(BYTES_IDX, std::mem::size_of::<u32>());
    }
}
