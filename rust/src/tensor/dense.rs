//! Dense gradient tensors (paper Definition 1).

use super::{CooTensor, WireFormat, BYTES_F32};

/// A dense gradient tensor: every parameter's gradient, zeros included.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseTensor {
    pub values: Vec<f32>,
}

impl DenseTensor {
    pub fn zeros(len: usize) -> Self {
        DenseTensor {
            values: vec![0.0; len],
        }
    }

    pub fn from_values(values: Vec<f32>) -> Self {
        DenseTensor { values }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Number of non-zero gradients.
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|v| **v != 0.0).count()
    }

    /// Density `d_G`: fraction of non-zero gradients (paper §2.1).
    pub fn density(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.nnz() as f64 / self.values.len() as f64
    }

    /// Indices of non-zero gradients, ascending.
    pub fn nonzero_indices(&self) -> Vec<u32> {
        self.values
            .iter()
            .enumerate()
            .filter(|(_, v)| **v != 0.0)
            .map(|(i, _)| i as u32)
            .collect()
    }

    /// Convert to COO (sorted by index).
    pub fn to_coo(&self) -> CooTensor {
        let mut indices = Vec::new();
        let mut vals = Vec::new();
        for (i, &v) in self.values.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                vals.push(v);
            }
        }
        CooTensor::new(self.values.len(), indices, vals)
    }

    /// In-place element-wise accumulation.
    pub fn add_assign(&mut self, other: &DenseTensor) {
        assert_eq!(self.len(), other.len());
        for (a, b) in self.values.iter_mut().zip(other.values.iter()) {
            *a += *b;
        }
    }

    /// Scatter-add a COO tensor into this dense tensor.
    pub fn add_coo(&mut self, coo: &CooTensor) {
        assert_eq!(self.len(), coo.dense_len);
        for (&i, &v) in coo.indices.iter().zip(coo.values.iter()) {
            self.values[i as usize] += v;
        }
    }

    /// Even contiguous split into `n` partitions (last may be shorter),
    /// used by Sparse PS / OmniReduce partitioning and the skewness metric.
    pub fn split_even(&self, n: usize) -> Vec<DenseTensor> {
        assert!(n > 0);
        let per = crate::util::ceil_div(self.len(), n);
        (0..n)
            .map(|i| {
                let lo = (i * per).min(self.len());
                let hi = ((i + 1) * per).min(self.len());
                DenseTensor::from_values(self.values[lo..hi].to_vec())
            })
            .collect()
    }
}

impl WireFormat for DenseTensor {
    fn wire_bytes(&self) -> usize {
        self.values.len() * BYTES_F32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DenseTensor {
        DenseTensor::from_values(vec![0.0, 1.0, 0.0, 2.0, 0.0, 0.0, 3.0, 0.0])
    }

    #[test]
    fn density_and_nnz() {
        let t = sample();
        assert_eq!(t.nnz(), 3);
        assert!((t.density() - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn nonzero_indices_sorted() {
        assert_eq!(sample().nonzero_indices(), vec![1, 3, 6]);
    }

    #[test]
    fn to_coo_roundtrip() {
        let t = sample();
        let coo = t.to_coo();
        assert_eq!(coo.to_dense(), t);
        assert_eq!(coo.nnz(), 3);
    }

    #[test]
    fn add_assign_elementwise() {
        let mut a = sample();
        let b = sample();
        a.add_assign(&b);
        assert_eq!(a.values[1], 2.0);
        assert_eq!(a.values[6], 6.0);
        assert_eq!(a.nnz(), 3);
    }

    #[test]
    fn add_coo_scatters() {
        let mut a = DenseTensor::zeros(8);
        a.add_coo(&sample().to_coo());
        assert_eq!(a, sample());
    }

    #[test]
    fn split_even_covers() {
        let t = sample();
        let parts = t.split_even(3);
        assert_eq!(parts.len(), 3);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, t.len());
        let rejoined: Vec<f32> = parts.iter().flat_map(|p| p.values.clone()).collect();
        assert_eq!(rejoined, t.values);
    }

    #[test]
    fn wire_bytes_fp32() {
        assert_eq!(sample().wire_bytes(), 8 * 4);
    }

    #[test]
    fn empty_density_zero() {
        assert_eq!(DenseTensor::zeros(0).density(), 0.0);
    }
}
