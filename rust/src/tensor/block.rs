//! Tensor-block format (OmniReduce, paper §2.3.3 & §3.2.1).
//!
//! The dense tensor is split into fixed-size blocks of gradients; only
//! *non-zero blocks* (blocks containing at least one non-zero gradient)
//! travel. A block is addressed by one u32 block id and carries all of its
//! gradients, zeros included — cheap indices, but padding cost when
//! non-zeros are scattered.

use super::{CooTensor, DenseTensor, WireFormat, BYTES_F32, BYTES_IDX};

/// OmniReduce's default block length (gradients per block).
pub const DEFAULT_BLOCK: usize = 256;

/// A sparse tensor as a set of non-zero blocks.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockTensor {
    pub dense_len: usize,
    pub block_len: usize,
    /// Ascending block ids.
    pub block_ids: Vec<u32>,
    /// Block payloads, each `block_len` long (tail block zero-padded),
    /// parallel to `block_ids`.
    pub blocks: Vec<Vec<f32>>,
}

impl BlockTensor {
    /// Build from a dense tensor, keeping only non-zero blocks.
    pub fn from_dense(t: &DenseTensor, block_len: usize) -> Self {
        assert!(block_len > 0);
        let mut block_ids = Vec::new();
        let mut blocks = Vec::new();
        for (bi, chunk) in t.values.chunks(block_len).enumerate() {
            if chunk.iter().any(|&v| v != 0.0) {
                let mut block = chunk.to_vec();
                block.resize(block_len, 0.0);
                block_ids.push(bi as u32);
                blocks.push(block);
            }
        }
        BlockTensor {
            dense_len: t.len(),
            block_len,
            block_ids,
            blocks,
        }
    }

    /// Build from a COO tensor without materializing the dense vector.
    pub fn from_coo(t: &CooTensor, block_len: usize) -> Self {
        assert!(block_len > 0);
        let mut block_ids: Vec<u32> = Vec::new();
        let mut blocks: Vec<Vec<f32>> = Vec::new();
        for (&i, &v) in t.indices.iter().zip(t.values.iter()) {
            let bi = i as usize / block_len;
            if block_ids.last() != Some(&(bi as u32)) {
                block_ids.push(bi as u32);
                blocks.push(vec![0.0; block_len]);
            }
            blocks.last_mut().unwrap()[i as usize % block_len] = v;
        }
        BlockTensor {
            dense_len: t.dense_len,
            block_len,
            block_ids,
            blocks,
        }
    }

    /// Rebuild from wire parts: ascending block ids plus the
    /// concatenated block payloads (`block_len` values per id) — the
    /// layout of a `Blocks` frame ([`crate::wire::codec`]).
    pub fn from_wire_parts(
        dense_len: usize,
        block_len: usize,
        block_ids: Vec<u32>,
        values: Vec<f32>,
    ) -> Self {
        assert!(block_len > 0);
        assert_eq!(values.len(), block_ids.len() * block_len);
        debug_assert!(block_ids.windows(2).all(|w| w[0] < w[1]));
        let blocks = values.chunks(block_len).map(|c| c.to_vec()).collect();
        BlockTensor {
            dense_len,
            block_len,
            block_ids,
            blocks,
        }
    }

    pub fn num_blocks(&self) -> usize {
        self.block_ids.len()
    }

    pub fn to_dense(&self) -> DenseTensor {
        let mut d = DenseTensor::zeros(self.dense_len);
        for (&bi, block) in self.block_ids.iter().zip(self.blocks.iter()) {
            let lo = bi as usize * self.block_len;
            let hi = (lo + self.block_len).min(self.dense_len);
            d.values[lo..hi].copy_from_slice(&block[..hi - lo]);
        }
        d
    }

    /// Merge-aggregate: blocks with the same id are summed element-wise.
    pub fn merge(&self, other: &BlockTensor) -> BlockTensor {
        assert_eq!(self.dense_len, other.dense_len);
        assert_eq!(self.block_len, other.block_len);
        let (mut i, mut j) = (0usize, 0usize);
        let mut block_ids = Vec::new();
        let mut blocks = Vec::new();
        while i < self.num_blocks() && j < other.num_blocks() {
            match self.block_ids[i].cmp(&other.block_ids[j]) {
                std::cmp::Ordering::Less => {
                    block_ids.push(self.block_ids[i]);
                    blocks.push(self.blocks[i].clone());
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    block_ids.push(other.block_ids[j]);
                    blocks.push(other.blocks[j].clone());
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    let mut b = self.blocks[i].clone();
                    for (a, x) in b.iter_mut().zip(other.blocks[j].iter()) {
                        *a += *x;
                    }
                    block_ids.push(self.block_ids[i]);
                    blocks.push(b);
                    i += 1;
                    j += 1;
                }
            }
        }
        while i < self.num_blocks() {
            block_ids.push(self.block_ids[i]);
            blocks.push(self.blocks[i].clone());
            i += 1;
        }
        while j < other.num_blocks() {
            block_ids.push(other.block_ids[j]);
            blocks.push(other.blocks[j].clone());
            j += 1;
        }
        BlockTensor {
            dense_len: self.dense_len,
            block_len: self.block_len,
            block_ids,
            blocks,
        }
    }
}

impl WireFormat for BlockTensor {
    fn wire_bytes(&self) -> usize {
        // one block id + block_len gradients per non-zero block
        self.num_blocks() * (BYTES_IDX + self.block_len * BYTES_F32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, prop_assert};

    fn dense(vals: &[f32]) -> DenseTensor {
        DenseTensor::from_values(vals.to_vec())
    }

    #[test]
    fn keeps_only_nonzero_blocks() {
        let t = dense(&[0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 2.0]);
        let b = BlockTensor::from_dense(&t, 4);
        assert_eq!(b.block_ids, vec![1, 2]);
        assert_eq!(b.to_dense(), t);
    }

    #[test]
    fn from_coo_matches_from_dense() {
        let t = dense(&[0.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0, 7.0, 1.0]);
        let a = BlockTensor::from_dense(&t, 3);
        let b = BlockTensor::from_coo(&t.to_coo(), 3);
        assert_eq!(a, b);
    }

    #[test]
    fn merge_matches_dense_add() {
        let a = dense(&[1.0, 0.0, 0.0, 0.0, 2.0, 0.0]);
        let bb = dense(&[0.0, 0.0, 5.0, 0.0, 2.0, 0.0]);
        let m = BlockTensor::from_dense(&a, 2).merge(&BlockTensor::from_dense(&bb, 2));
        let mut d = a.clone();
        d.add_assign(&bb);
        assert_eq!(m.to_dense(), d);
        assert_eq!(m.num_blocks(), 3);
    }

    #[test]
    fn wire_bytes_includes_padding() {
        let t = dense(&[1.0, 0.0, 0.0, 0.0]);
        let b = BlockTensor::from_dense(&t, 4);
        // one block: 4B id + 4 * 4B values
        assert_eq!(b.wire_bytes(), 4 + 16);
    }

    #[test]
    fn from_wire_parts_roundtrip() {
        let t = dense(&[0.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0, 7.0, 1.0]);
        let b = BlockTensor::from_dense(&t, 3);
        let flat: Vec<f32> = b.blocks.iter().flatten().copied().collect();
        let back =
            BlockTensor::from_wire_parts(b.dense_len, b.block_len, b.block_ids.clone(), flat);
        assert_eq!(back, b);
    }

    #[test]
    fn prop_roundtrip_any_block_len() {
        check(100, |g| {
            let len = g.usize_in(1, 300);
            let bl = g.usize_in(1, 64);
            let n = g.usize_in(0, len.min(40));
            let idx = g.distinct_sorted_u32(n, len as u32);
            let vals: Vec<f32> = (0..n).map(|_| g.f64_unit() as f32 + 0.5).collect();
            let coo = CooTensor::from_sorted(len, idx, vals);
            let b = BlockTensor::from_coo(&coo, bl);
            prop_assert(b.to_dense() == coo.to_dense(), "block roundtrip")
        });
    }
}
