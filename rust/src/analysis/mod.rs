//! Analytical cost models (paper Appendix B) and the Fig 7 comparison.
//!
//! [`costmodel`] implements the closed-form communication-time formulas
//! from the proofs of Theorem 1 — they drive the theory tests (the lemma
//! orderings must hold) and the `Dense`/lower-bound reference lines.
//! [`numeric`] generates model-profile workloads and evaluates every
//! scheme's *actual* traffic on them, reproducing Fig 7's normalized
//! comparison.

pub mod costmodel;
pub mod numeric;

pub use costmodel::{ClassedTime, CostModel, TopoCost};
pub use numeric::fig7_sweep;
