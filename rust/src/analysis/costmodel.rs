//! Closed-form communication-time formulas from Appendix B.
//!
//! All times are expressed in *value-transmission units*: seconds when
//! `M` is in FP32 values and `b_values_per_sec = B / 32` for bandwidth
//! `B` bits/s. COO entries count as 2 value units (index + value), as in
//! the paper's accounting.
//!
//! Inputs are the measured sparsity statistics of a workload:
//! `d(j)` — expected density of the aggregate of `j` workers' tensors
//! (`d(1) = d_G`), and `s(n)` — skewness ratio of one worker's tensor at
//! `n` partitions.
//!
//! Beyond the paper's formulas this model also carries an optional
//! per-stage latency term `α` ([`CostModel::with_latency`]): each
//! synchronous stage costs `α` on top of its bandwidth term, exactly
//! like [`crate::cluster::Network::stage_time`]. The planner
//! ([`crate::planner`]) needs it — at small bucket sizes the stage
//! count, not the byte volume, decides the argmin.

/// Sparsity statistics provider for a workload.
pub trait SparsityStats {
    /// Density of the aggregation of `j` tensors, `d_G^j`; `j >= 1`.
    fn agg_density(&self, j: usize) -> f64;
    /// Skewness ratio at `n` partitions (Definition 5).
    fn skewness(&self, n: usize) -> f64;
    /// Fraction of length-`block_len` blocks that contain at least one
    /// non-zero of the `j`-aggregate (OmniReduce's traffic driver).
    /// Default: [`independent_block_density`]; measured implementations
    /// override it (clustered non-zeros touch far fewer blocks than
    /// independence predicts).
    fn block_density(&self, j: usize, block_len: usize) -> f64 {
        independent_block_density(self.agg_density(j), block_len)
    }
}

/// Independent-position approximation of the non-zero-block share:
/// `1 − (1 − d)^block_len` — the one definition shared by the
/// [`SparsityStats`] default and any measured implementation's fallback
/// for unprofiled block lengths.
pub fn independent_block_density(d: f64, block_len: usize) -> f64 {
    1.0 - (1.0 - d).powi(block_len as i32)
}

/// Closed-form scheme times for a dense tensor of `m` values on `n`
/// machines with `bandwidth_values` values/s.
pub struct CostModel<'a, S: SparsityStats> {
    pub m: f64,
    pub n: usize,
    pub bandwidth_values: f64,
    /// Per-stage latency α in seconds (0 = the paper's pure-bandwidth
    /// accounting).
    pub alpha: f64,
    pub stats: &'a S,
}

impl<'a, S: SparsityStats> CostModel<'a, S> {
    pub fn new(m: f64, n: usize, bandwidth_values: f64, stats: &'a S) -> Self {
        assert!(n >= 1);
        CostModel {
            m,
            n,
            bandwidth_values,
            alpha: 0.0,
            stats,
        }
    }

    /// Add the α–β model's per-stage latency to every formula (builder
    /// style). `stage_count` documents each scheme's stage structure.
    pub fn with_latency(mut self, alpha: f64) -> Self {
        assert!(alpha >= 0.0);
        self.alpha = alpha;
        self
    }

    fn nf(&self) -> f64 {
        self.n as f64
    }

    /// Latency charge for `stages` synchronous stages (0 when `n == 1`:
    /// a single machine never touches the network).
    fn lat(&self, stages: usize) -> f64 {
        if self.n <= 1 {
            0.0
        } else {
            self.alpha * stages as f64
        }
    }

    /// Number of synchronous stages each planner candidate executes at
    /// this `n` — mirrors the actual `sync_transport` protocols, which
    /// is what [`crate::cluster::Network::stage_time`] charges α for.
    pub fn stage_count(&self, scheme: &str) -> Option<usize> {
        let n = self.n;
        // Arithmetic-safe stand-in for degenerate n (the result is
        // clamped to 0 below anyway); keeps the name-validating match
        // free of usize underflow even for a hand-built model with
        // n < 2 that bypassed `new`'s assert.
        let nn = n.max(2);
        let stages = match scheme {
            // ring reduce-scatter + ring all-gather
            "allreduce" | "dense" => 2 * (nn - 1),
            // one-shot point-to-point broadcast
            "agsparse" => 1,
            "agsparse-ring" => nn - 1,
            "agsparse-hier" => nn.next_power_of_two().trailing_zeros() as usize,
            // fold-in + recursive doubling + fold-out
            "sparcml" => {
                let core = largest_pow2_at_most(nn);
                let folds = if core == nn { 0 } else { 2 };
                core.trailing_zeros() as usize + folds
            }
            // push + pull
            "sparseps" | "sparse-ps" | "omnireduce" | "zen" | "zen-coo" => 2,
            _ => return None,
        };
        // A single machine executes no network stage at all, whatever
        // the protocol's shape — but an unknown name is still an error.
        Some(if n <= 1 { 0 } else { stages })
    }

    /// Predicted synchronization time for a planner candidate by its
    /// [`crate::schemes::by_name`] name — bandwidth term + α·stages.
    /// `block_len` parameterizes the OmniReduce formula; `None` for
    /// names without a closed form (lossy strawman). One machine moves
    /// nothing, whatever the formula says (Zen's `M/32` bitmap constant
    /// in particular does not vanish with the `(n−1)` factors).
    pub fn time_for(&self, scheme: &str, block_len: usize) -> Option<f64> {
        if self.n <= 1 {
            // Validate the name anyway so typos stay loud.
            self.stage_count(scheme)?;
            return Some(0.0);
        }
        let bw = match scheme {
            "allreduce" | "dense" => self.dense(),
            "agsparse" | "agsparse-ring" | "agsparse-hier" => self.agsparse(),
            "sparcml" => self.sparcml(),
            "sparseps" | "sparse-ps" => self.sparse_ps(),
            "omnireduce" => self.omnireduce(block_len),
            "zen-coo" => self.balanced_parallelism(),
            "zen" => self.zen(),
            _ => return None,
        };
        Some(bw + self.lat(self.stage_count(scheme)?))
    }

    /// Ring AllReduce over the dense tensor: `2(n−1)/n · M / B`.
    pub fn dense(&self) -> f64 {
        2.0 * (self.nf() - 1.0) / self.nf() * self.m / self.bandwidth_values
    }

    /// AGsparse (all-gather of COO): each GPU receives `(n−1) · 2dM / B`.
    pub fn agsparse(&self) -> f64 {
        let d = self.stats.agg_density(1);
        (self.nf() - 1.0) * 2.0 * d * self.m / self.bandwidth_values
    }

    /// SparCML SSAR recursive doubling, generalized to arbitrary `n`.
    ///
    /// Power-of-two `n = 2^k`: stage `i` ships the aggregate of `2^i`
    /// tensors (density `d^{2^i}`) as COO both ways — `Σ_i 2·d^{2^i}·M/B`
    /// (the Appendix-B closed form, kept as the test oracle below).
    ///
    /// Other `n`: the scheme folds the `n − core` excess nodes into the
    /// largest power-of-two `core` first and broadcasts the final
    /// aggregate back (exactly what [`crate::schemes::SparCml`]
    /// executes), so the model adds one `2·d(1)` fold-in stage and one
    /// `2·d(n)` fold-out stage, and the busiest core node at doubling
    /// stage `i` ships an aggregate of up to `2^{i+1}` inputs.
    pub fn sparcml(&self) -> f64 {
        if self.n <= 1 {
            return 0.0;
        }
        let core = largest_pow2_at_most(self.n);
        let excess = self.n - core;
        let per = |j: usize| 2.0 * self.stats.agg_density(j) * self.m / self.bandwidth_values;
        let mut t = 0.0;
        if excess > 0 {
            t += per(1); // fold-in: excess nodes ship their own tensor
        }
        for i in 0..core.trailing_zeros() as usize {
            let j = if excess > 0 {
                (1usize << (i + 1)).min(self.n)
            } else {
                1usize << i
            };
            t += per(j);
        }
        if excess > 0 {
            t += per(self.n); // fold-out: full aggregate back to excess
        }
        t
    }

    /// Sparse PS (point-to-point pull): `2(n−1)(d_G + d_G^n)·s^n·M/n/B`
    /// (Appendix B, proof of Lemma 4).
    pub fn sparse_ps(&self) -> f64 {
        let d1 = self.stats.agg_density(1);
        let dn = self.stats.agg_density(self.n);
        let s = self.stats.skewness(self.n);
        2.0 * (self.nf() - 1.0) * (d1 + dn) * s * self.m / self.nf() / self.bandwidth_values
    }

    /// OmniReduce: contiguous even partitions, non-zero *blocks* shipped
    /// as (id + `block_len` values) — `(1 + 1/b)` value units per block
    /// slot. The busiest aggregator owns the hottest partition, whose
    /// block share is approximated as `min(1, s^n · blocks(d))`:
    /// `(n−1)·M/n·(1+1/b)·(blocks(d_G)·s + blocks(d_G^n)·s)/B`.
    pub fn omnireduce(&self, block_len: usize) -> f64 {
        assert!(block_len > 0);
        let s = self.stats.skewness(self.n);
        let push = (self.stats.block_density(1, block_len) * s).min(1.0);
        let pull = (self.stats.block_density(self.n, block_len) * s).min(1.0);
        let unit = 1.0 + 1.0 / block_len as f64;
        (self.nf() - 1.0) * self.m / self.nf() * unit * (push + pull) / self.bandwidth_values
    }

    /// Balanced Parallelism with COO (the hypothetical optimum of Fig 7):
    /// Sparse PS with `s^n = 1`: `2(n−1)(d_G + d_G^n)·M/n/B`.
    pub fn balanced_parallelism(&self) -> f64 {
        let d1 = self.stats.agg_density(1);
        let dn = self.stats.agg_density(self.n);
        2.0 * (self.nf() - 1.0) * (d1 + dn) * self.m / self.nf() / self.bandwidth_values
    }

    /// Zen: COO push (balanced) + hash-bitmap pull
    /// (`(n−1)·(d_G^n·M/n + (|𝕀_p| bits)/32)` per worker ⇒ values:
    /// `(n−1)·(2d_G·M/n)` push + `(n−1)·(d_G^n·M/n) + M/32` pull).
    pub fn zen(&self) -> f64 {
        let d1 = self.stats.agg_density(1);
        let dn = self.stats.agg_density(self.n);
        let push = (self.nf() - 1.0) * 2.0 * d1 * self.m / self.nf();
        let pull = (self.nf() - 1.0) * dn * self.m / self.nf() + self.m / 32.0;
        (push + pull) / self.bandwidth_values
    }

    /// Communication lower bound (paper footnote 3): every GPU must
    /// receive the aggregate of the other `n−1` GPUs' non-zeros, no
    /// indices: `d_G^{n−1}·M/B`.
    pub fn lower_bound(&self) -> f64 {
        let d = self.stats.agg_density(self.n.saturating_sub(1).max(1));
        d * self.m / self.bandwidth_values
    }
}

/// Largest power of two ≤ `n` (`n ≥ 1`).
fn largest_pow2_at_most(n: usize) -> usize {
    1usize << (usize::BITS - 1 - n.leading_zeros())
}

/// An analytic stats model: densification follows the independent-union
/// approximation `d(j) = 1 − (1 − c·d)^j` scaled to match `d(1) = d`,
/// with skewness supplied directly. Useful for tests and for sweeps
/// beyond measured scales.
#[derive(Clone, Debug)]
pub struct AnalyticStats {
    pub d1: f64,
    /// Effective "fresh mass" per additional worker, in (0, 1]: 1 =
    /// independent tensors (maximal densification), → 0 = identical.
    pub freshness: f64,
    pub skew: f64,
}

impl SparsityStats for AnalyticStats {
    fn agg_density(&self, j: usize) -> f64 {
        // union of j sets each of density d1, pairwise-correlated via
        // freshness: d(j) = d1 · (1 + freshness·(j−1) damped by overlap)
        let j = j as f64;
        let f = self.freshness;
        // geometric saturation: d(j) = d1 · (1 − (1−f)^j) / f   (≤ d1·j)
        if f >= 1.0 {
            (self.d1 * j).min(1.0)
        } else {
            (self.d1 * (1.0 - (1.0 - f).powf(j)) / f).min(1.0)
        }
    }

    fn skewness(&self, _n: usize) -> f64 {
        self.skew
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> AnalyticStats {
        // NMT-like: d = 2.47%, moderate overlap, strong skew
        AnalyticStats {
            d1: 0.0247,
            freshness: 0.35,
            skew: 20.0,
        }
    }

    fn model(n: usize) -> (f64, f64) {
        let s = stats();
        let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
        (cm.dense(), cm.zen())
    }

    /// The Appendix-B power-of-two closed form, kept verbatim as the
    /// oracle the generalized `sparcml` must reproduce at `n = 2^k`.
    fn sparcml_pow2_oracle<S: SparsityStats>(m: f64, n: usize, bw: f64, stats: &S) -> f64 {
        assert!(n.is_power_of_two());
        let stages = n.trailing_zeros() as usize;
        (0..stages)
            .map(|i| 2.0 * stats.agg_density(1 << i) * m / bw)
            .sum()
    }

    #[test]
    fn lemma4_balanced_beats_sparse_ps() {
        let s = stats();
        for n in [4usize, 8, 16, 64, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            assert!(
                cm.balanced_parallelism() < cm.sparse_ps(),
                "n={n}: BP must beat Sparse PS"
            );
        }
    }

    #[test]
    fn lemma5_bp_beats_sparcml_with_overlap() {
        let s = stats();
        for n in [8usize, 16, 64, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            assert!(
                cm.balanced_parallelism() < cm.sparcml(),
                "n={n}: BP must beat SparCML when overlapped"
            );
        }
    }

    #[test]
    fn sparcml_matches_pow2_closed_form() {
        let s = stats();
        for n in [1usize, 2, 4, 8, 16, 32, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            let oracle = if n == 1 {
                0.0
            } else {
                sparcml_pow2_oracle(112e6, n, 25e9 / 32.0, &s)
            };
            assert!(
                (cm.sparcml() - oracle).abs() < 1e-12,
                "n={n}: generalized {} vs closed form {oracle}",
                cm.sparcml()
            );
        }
    }

    #[test]
    fn sparcml_non_pow2_no_panic_and_bracketed() {
        // The planner evaluates every candidate at arbitrary n (the old
        // hard assert panicked on n = 6). The generalized stage sum must
        // be finite and sit between the two adjacent power-of-two costs'
        // natural bounds: at least the core's closed form, and at most
        // the core's plus the two fold stages at extreme densities.
        let s = stats();
        for n in [3usize, 5, 6, 7, 12, 100] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            let t = cm.sparcml();
            assert!(t.is_finite() && t > 0.0, "n={n}: {t}");
            let core = 1usize << (usize::BITS - 1 - n.leading_zeros());
            let core_t = sparcml_pow2_oracle(112e6, core, 25e9 / 32.0, &s);
            assert!(t > core_t, "n={n}: folds must add cost over core {core}");
            let bound = core_t
                + 2.0 * (s.agg_density(1) + s.agg_density(n)) * 112e6 / (25e9 / 32.0)
                + 2.0 * (s.agg_density(2 * core.min(n)) - s.agg_density(1)).abs() * 112e6
                    / (25e9 / 32.0)
                    * core.trailing_zeros() as f64;
            assert!(t <= bound * 1.0001, "n={n}: {t} vs bound {bound}");
        }
    }

    #[test]
    fn latency_term_counts_stages() {
        let s = stats();
        let alpha = 1e-3;
        let cm0 = CostModel::new(1e6, 8, 25e9 / 32.0, &s);
        let cm1 = CostModel::new(1e6, 8, 25e9 / 32.0, &s).with_latency(alpha);
        for scheme in ["allreduce", "agsparse", "sparcml", "sparseps", "omnireduce", "zen-coo", "zen"]
        {
            let stages = cm1.stage_count(scheme).unwrap();
            let d = cm1.time_for(scheme, 256).unwrap() - cm0.time_for(scheme, 256).unwrap();
            assert!(
                (d - alpha * stages as f64).abs() < 1e-12,
                "{scheme}: latency delta {d} for {stages} stages"
            );
        }
        // one machine: everything is free, latency included
        let cm_solo = CostModel::new(1e6, 1, 25e9 / 32.0, &s).with_latency(alpha);
        assert_eq!(cm_solo.time_for("zen", 256), Some(0.0));
    }

    #[test]
    fn omnireduce_interpolates_between_dense_and_coo() {
        // Scattered non-zeros (independent positions): at block_len 256
        // and density 1%, nearly every block is non-zero → OmniReduce
        // approaches the dense cost ballpark; at block_len 1 it becomes
        // a COO-like 2-units-per-nnz scheme and beats it.
        let s = AnalyticStats {
            d1: 0.01,
            freshness: 1.0,
            skew: 1.0,
        };
        let cm = CostModel::new(1e8, 8, 25e9 / 32.0, &s);
        let coarse = cm.omnireduce(256);
        let fine = cm.omnireduce(1);
        assert!(fine < coarse, "fine blocks {fine} vs coarse {coarse}");
        assert!(coarse > cm.dense() * 0.5, "coarse ≈ dense regime");
        assert!(fine < cm.dense(), "b=1 ships only non-zeros");
    }

    #[test]
    fn block_density_default_monotone() {
        let s = stats();
        let b64 = s.block_density(1, 64);
        let b256 = s.block_density(1, 256);
        assert!(s.agg_density(1) <= b64 && b64 <= b256 && b256 <= 1.0);
    }

    #[test]
    fn no_overlap_centralization_matches_bp_push() {
        // With freshness = 1 (disjoint tensors), AGsparse's per-GPU recv
        // equals 2d(n-1)M/B, and BP cannot beat the no-index lower bound
        // by much — Theorem 1.1's regime: centralization is competitive.
        let s = AnalyticStats {
            d1: 0.001,
            freshness: 1.0,
            skew: 1.0,
        };
        let cm = CostModel::new(1e8, 16, 25e9 / 32.0, &s);
        // BP's pull alone ≈ (n-1)/n·d^n·M = (n-1)/n·n·d·M ≈ AGsparse/2;
        // with push it is within 2× of AGsparse — no big win without overlap.
        assert!(cm.balanced_parallelism() > cm.agsparse() * 0.45);
    }

    #[test]
    fn fig7_shape_agsparse_crosses_dense() {
        // AGsparse degrades linearly with n and crosses Dense around
        // n ≈ 1/d (paper: > 40 GPUs for NMT).
        let s = stats();
        let mut crossed = None;
        for n in [4usize, 8, 16, 32, 64, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            if cm.agsparse() > cm.dense() {
                crossed = Some(n);
                break;
            }
        }
        let c = crossed.expect("AGsparse should cross Dense");
        assert!((16..=64).contains(&c), "crossover at {c}");
    }

    #[test]
    fn fig7_shape_zen_beats_dense_at_128() {
        // Paper: Balanced Parallelism still 36% below Dense at 128 GPUs.
        let (dense, zen) = model(128);
        assert!(
            zen < dense * 0.8,
            "zen {zen} should clearly beat dense {dense} at 128"
        );
    }

    #[test]
    fn lower_bound_is_lowest() {
        let s = stats();
        for n in [4usize, 16, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            let lb = cm.lower_bound();
            for (name, t) in [
                ("dense", cm.dense()),
                ("ag", cm.agsparse()),
                ("sparcml", cm.sparcml()),
                ("ps", cm.sparse_ps()),
                ("bp", cm.balanced_parallelism()),
                ("zen", cm.zen()),
            ] {
                assert!(lb <= t * 1.0001, "n={n}: lower bound above {name}");
            }
        }
    }

    #[test]
    fn analytic_stats_monotone_saturating() {
        let s = stats();
        let mut prev = 0.0;
        for j in 1..=128 {
            let d = s.agg_density(j);
            assert!(d >= prev && d <= 1.0);
            prev = d;
        }
        // sublinear: d(8) < 8·d(1)
        assert!(s.agg_density(8) < 8.0 * s.agg_density(1));
    }
}
