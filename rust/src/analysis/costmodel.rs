//! Closed-form communication-time formulas from Appendix B.
//!
//! All times are expressed in *value-transmission units*: seconds when
//! `M` is in FP32 values and `b_values_per_sec = B / 32` for bandwidth
//! `B` bits/s. COO entries count as 2 value units (index + value), as in
//! the paper's accounting.
//!
//! Inputs are the measured sparsity statistics of a workload:
//! `d(j)` — expected density of the aggregate of `j` workers' tensors
//! (`d(1) = d_G`), and `s(n)` — skewness ratio of one worker's tensor at
//! `n` partitions.

/// Sparsity statistics provider for a workload.
pub trait SparsityStats {
    /// Density of the aggregation of `j` tensors, `d_G^j`; `j >= 1`.
    fn agg_density(&self, j: usize) -> f64;
    /// Skewness ratio at `n` partitions (Definition 5).
    fn skewness(&self, n: usize) -> f64;
}

/// Closed-form scheme times for a dense tensor of `m` values on `n`
/// machines with `bandwidth_values` values/s.
pub struct CostModel<'a, S: SparsityStats> {
    pub m: f64,
    pub n: usize,
    pub bandwidth_values: f64,
    pub stats: &'a S,
}

impl<'a, S: SparsityStats> CostModel<'a, S> {
    pub fn new(m: f64, n: usize, bandwidth_values: f64, stats: &'a S) -> Self {
        assert!(n >= 1);
        CostModel {
            m,
            n,
            bandwidth_values,
            stats,
        }
    }

    fn nf(&self) -> f64 {
        self.n as f64
    }

    /// Ring AllReduce over the dense tensor: `2(n−1)/n · M / B`.
    pub fn dense(&self) -> f64 {
        2.0 * (self.nf() - 1.0) / self.nf() * self.m / self.bandwidth_values
    }

    /// AGsparse (all-gather of COO): each GPU receives `(n−1) · 2dM / B`.
    pub fn agsparse(&self) -> f64 {
        let d = self.stats.agg_density(1);
        (self.nf() - 1.0) * 2.0 * d * self.m / self.bandwidth_values
    }

    /// SparCML SSAR recursive doubling: stage `i` ships the aggregate of
    /// `2^i` tensors (density `d^{2^i}`) as COO both ways:
    /// `Σ_i 2·d^{2^i}·M / B`.
    pub fn sparcml(&self) -> f64 {
        assert!(self.n.is_power_of_two(), "SSAR formula needs 2^k nodes");
        let stages = self.n.trailing_zeros() as usize;
        (0..stages)
            .map(|i| 2.0 * self.stats.agg_density(1 << i) * self.m / self.bandwidth_values)
            .sum()
    }

    /// Sparse PS (point-to-point pull): `2(n−1)(d_G + d_G^n)·s^n·M/n/B`
    /// (Appendix B, proof of Lemma 4).
    pub fn sparse_ps(&self) -> f64 {
        let d1 = self.stats.agg_density(1);
        let dn = self.stats.agg_density(self.n);
        let s = self.stats.skewness(self.n);
        2.0 * (self.nf() - 1.0) * (d1 + dn) * s * self.m / self.nf() / self.bandwidth_values
    }

    /// Balanced Parallelism with COO (the hypothetical optimum of Fig 7):
    /// Sparse PS with `s^n = 1`: `2(n−1)(d_G + d_G^n)·M/n/B`.
    pub fn balanced_parallelism(&self) -> f64 {
        let d1 = self.stats.agg_density(1);
        let dn = self.stats.agg_density(self.n);
        2.0 * (self.nf() - 1.0) * (d1 + dn) * self.m / self.nf() / self.bandwidth_values
    }

    /// Zen: COO push (balanced) + hash-bitmap pull
    /// (`(n−1)·(d_G^n·M/n + (|𝕀_p| bits)/32)` per worker ⇒ values:
    /// `(n−1)·(2d_G·M/n)` push + `(n−1)·(d_G^n·M/n) + M/32` pull).
    pub fn zen(&self) -> f64 {
        let d1 = self.stats.agg_density(1);
        let dn = self.stats.agg_density(self.n);
        let push = (self.nf() - 1.0) * 2.0 * d1 * self.m / self.nf();
        let pull = (self.nf() - 1.0) * dn * self.m / self.nf() + self.m / 32.0;
        (push + pull) / self.bandwidth_values
    }

    /// Communication lower bound (paper footnote 3): every GPU must
    /// receive the aggregate of the other `n−1` GPUs' non-zeros, no
    /// indices: `d_G^{n−1}·M/B`.
    pub fn lower_bound(&self) -> f64 {
        let d = self.stats.agg_density(self.n.saturating_sub(1).max(1));
        d * self.m / self.bandwidth_values
    }
}

/// An analytic stats model: densification follows the independent-union
/// approximation `d(j) = 1 − (1 − c·d)^j` scaled to match `d(1) = d`,
/// with skewness supplied directly. Useful for tests and for sweeps
/// beyond measured scales.
#[derive(Clone, Debug)]
pub struct AnalyticStats {
    pub d1: f64,
    /// Effective "fresh mass" per additional worker, in (0, 1]: 1 =
    /// independent tensors (maximal densification), → 0 = identical.
    pub freshness: f64,
    pub skew: f64,
}

impl SparsityStats for AnalyticStats {
    fn agg_density(&self, j: usize) -> f64 {
        // union of j sets each of density d1, pairwise-correlated via
        // freshness: d(j) = d1 · (1 + freshness·(j−1) damped by overlap)
        let j = j as f64;
        let f = self.freshness;
        // geometric saturation: d(j) = d1 · (1 − (1−f)^j) / f   (≤ d1·j)
        if f >= 1.0 {
            (self.d1 * j).min(1.0)
        } else {
            (self.d1 * (1.0 - (1.0 - f).powf(j)) / f).min(1.0)
        }
    }

    fn skewness(&self, _n: usize) -> f64 {
        self.skew
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> AnalyticStats {
        // NMT-like: d = 2.47%, moderate overlap, strong skew
        AnalyticStats {
            d1: 0.0247,
            freshness: 0.35,
            skew: 20.0,
        }
    }

    fn model(n: usize) -> (f64, f64) {
        let s = stats();
        let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
        (cm.dense(), cm.zen())
    }

    #[test]
    fn lemma4_balanced_beats_sparse_ps() {
        let s = stats();
        for n in [4usize, 8, 16, 64, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            assert!(
                cm.balanced_parallelism() < cm.sparse_ps(),
                "n={n}: BP must beat Sparse PS"
            );
        }
    }

    #[test]
    fn lemma5_bp_beats_sparcml_with_overlap() {
        let s = stats();
        for n in [8usize, 16, 64, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            assert!(
                cm.balanced_parallelism() < cm.sparcml(),
                "n={n}: BP must beat SparCML when overlapped"
            );
        }
    }

    #[test]
    fn no_overlap_centralization_matches_bp_push() {
        // With freshness = 1 (disjoint tensors), AGsparse's per-GPU recv
        // equals 2d(n-1)M/B, and BP cannot beat the no-index lower bound
        // by much — Theorem 1.1's regime: centralization is competitive.
        let s = AnalyticStats {
            d1: 0.001,
            freshness: 1.0,
            skew: 1.0,
        };
        let cm = CostModel::new(1e8, 16, 25e9 / 32.0, &s);
        // BP's pull alone ≈ (n-1)/n·d^n·M = (n-1)/n·n·d·M ≈ AGsparse/2;
        // with push it is within 2× of AGsparse — no big win without overlap.
        assert!(cm.balanced_parallelism() > cm.agsparse() * 0.45);
    }

    #[test]
    fn fig7_shape_agsparse_crosses_dense() {
        // AGsparse degrades linearly with n and crosses Dense around
        // n ≈ 1/d (paper: > 40 GPUs for NMT).
        let s = stats();
        let mut crossed = None;
        for n in [4usize, 8, 16, 32, 64, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            if cm.agsparse() > cm.dense() {
                crossed = Some(n);
                break;
            }
        }
        let c = crossed.expect("AGsparse should cross Dense");
        assert!((16..=64).contains(&c), "crossover at {c}");
    }

    #[test]
    fn fig7_shape_zen_beats_dense_at_128() {
        // Paper: Balanced Parallelism still 36% below Dense at 128 GPUs.
        let (dense, zen) = model(128);
        assert!(
            zen < dense * 0.8,
            "zen {zen} should clearly beat dense {dense} at 128"
        );
    }

    #[test]
    fn lower_bound_is_lowest() {
        let s = stats();
        for n in [4usize, 16, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            let lb = cm.lower_bound();
            for (name, t) in [
                ("dense", cm.dense()),
                ("ag", cm.agsparse()),
                ("sparcml", cm.sparcml()),
                ("ps", cm.sparse_ps()),
                ("bp", cm.balanced_parallelism()),
                ("zen", cm.zen()),
            ] {
                assert!(lb <= t * 1.0001, "n={n}: lower bound above {name}");
            }
        }
    }

    #[test]
    fn analytic_stats_monotone_saturating() {
        let s = stats();
        let mut prev = 0.0;
        for j in 1..=128 {
            let d = s.agg_density(j);
            assert!(d >= prev && d <= 1.0);
            prev = d;
        }
        // sublinear: d(8) < 8·d(1)
        assert!(s.agg_density(8) < 8.0 * s.agg_density(1));
    }
}
