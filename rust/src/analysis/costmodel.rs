//! Closed-form communication-time formulas from Appendix B.
//!
//! All times are expressed in *value-transmission units*: seconds when
//! `M` is in FP32 values and `b_values_per_sec = B / 32` for bandwidth
//! `B` bits/s. COO entries count as 2 value units (index + value), as in
//! the paper's accounting.
//!
//! Inputs are the measured sparsity statistics of a workload:
//! `d(j)` — expected density of the aggregate of `j` workers' tensors
//! (`d(1) = d_G`), and `s(n)` — skewness ratio of one worker's tensor at
//! `n` partitions.
//!
//! Beyond the paper's formulas this model also carries an optional
//! per-stage latency term `α` ([`CostModel::with_latency`]): each
//! synchronous stage costs `α` on top of its bandwidth term, exactly
//! like [`crate::cluster::Network::stage_time`]. The planner
//! ([`crate::planner`]) needs it — at small bucket sizes the stage
//! count, not the byte volume, decides the argmin.
//!
//! With [`CostModel::with_topology`] the model prices *per link class*
//! on a two-level cluster ([`crate::cluster::Topology`]): each stage's
//! busiest-endpoint load is split into its intra-node and inter-node
//! shares, each class pays its own α–β, and the stage costs the max of
//! the two (parallel physical links) — mirroring what the classed
//! transports measure. Hierarchical variants price the inter-node
//! stages separately: SparCML's and AGsparse-hier's first doubling
//! exchanges are node-local when partners are co-located, which is what
//! produces the hierarchy crossovers a flat mesh cannot see.

use crate::util::largest_pow2_at_most;

/// Sparsity statistics provider for a workload.
pub trait SparsityStats {
    /// Density of the aggregation of `j` tensors, `d_G^j`; `j >= 1`.
    fn agg_density(&self, j: usize) -> f64;
    /// Skewness ratio at `n` partitions (Definition 5).
    fn skewness(&self, n: usize) -> f64;
    /// Fraction of length-`block_len` blocks that contain at least one
    /// non-zero of the `j`-aggregate (OmniReduce's traffic driver).
    /// Default: [`independent_block_density`]; measured implementations
    /// override it (clustered non-zeros touch far fewer blocks than
    /// independence predicts).
    fn block_density(&self, j: usize, block_len: usize) -> f64 {
        independent_block_density(self.agg_density(j), block_len)
    }
}

/// Independent-position approximation of the non-zero-block share:
/// `1 − (1 − d)^block_len` — the one definition shared by the
/// [`SparsityStats`] default and any measured implementation's fallback
/// for unprofiled block lengths.
pub fn independent_block_density(d: f64, block_len: usize) -> f64 {
    1.0 - (1.0 - d).powi(block_len as i32)
}

/// Two-level pricing parameters: the cost-model view of a
/// [`crate::cluster::Topology`], with bandwidths already converted to
/// FP32 values/s.
#[derive(Clone, Copy, Debug)]
pub struct TopoCost {
    pub nodes: usize,
    pub ranks_per_node: usize,
    pub intra_alpha: f64,
    pub intra_bandwidth_values: f64,
    pub inter_alpha: f64,
    pub inter_bandwidth_values: f64,
}

impl TopoCost {
    /// Convert a cluster topology into pricing parameters.
    pub fn from_topology(t: &crate::cluster::Topology) -> TopoCost {
        TopoCost {
            nodes: t.nodes,
            ranks_per_node: t.ranks_per_node,
            intra_alpha: t.intra.latency(),
            intra_bandwidth_values: t.intra.bandwidth_bps() / 32.0,
            inter_alpha: t.inter.latency(),
            inter_bandwidth_values: t.inter.bandwidth_bps() / 32.0,
        }
    }

    /// Copy with both latency terms zeroed (bandwidth-only pricing —
    /// the rescalable part of a prediction).
    pub fn without_latency(mut self) -> TopoCost {
        self.intra_alpha = 0.0;
        self.inter_alpha = 0.0;
        self
    }

    /// A flat topology behaves like the single-link model: no pair of
    /// ranks shares a node.
    pub fn is_flat(&self) -> bool {
        self.ranks_per_node <= 1
    }

    fn node_of(&self, rank: usize) -> usize {
        rank / self.ranks_per_node
    }
}

/// A candidate's predicted time split by link class. `total` is what
/// the classed transports charge (per-stage max over classes); `intra`
/// and `inter` sum each class's α–β times alone, so
/// `max(intra, inter) <= total <= intra + inter`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ClassedTime {
    pub total: f64,
    pub intra: f64,
    pub inter: f64,
}

/// One stage's busiest-endpoint load per link class, in value units.
#[derive(Clone, Copy, Debug, Default)]
struct StageLoad {
    intra: f64,
    inter: f64,
}

impl StageLoad {
    fn inter_only(units: f64) -> StageLoad {
        StageLoad {
            intra: 0.0,
            inter: units,
        }
    }
}

/// Closed-form scheme times for a dense tensor of `m` values on `n`
/// machines with `bandwidth_values` values/s.
pub struct CostModel<'a, S: SparsityStats> {
    pub m: f64,
    pub n: usize,
    pub bandwidth_values: f64,
    /// Per-stage latency α in seconds (0 = the paper's pure-bandwidth
    /// accounting).
    pub alpha: f64,
    /// Two-level pricing, when the workload runs on a non-flat
    /// topology. `bandwidth_values`/`alpha` should then equal the
    /// inter-class parameters (the planner guarantees it).
    topo: Option<TopoCost>,
    pub stats: &'a S,
}

impl<'a, S: SparsityStats> CostModel<'a, S> {
    pub fn new(m: f64, n: usize, bandwidth_values: f64, stats: &'a S) -> Self {
        assert!(n >= 1);
        CostModel {
            m,
            n,
            bandwidth_values,
            alpha: 0.0,
            topo: None,
            stats,
        }
    }

    /// Add the α–β model's per-stage latency to every formula (builder
    /// style). `stage_count` documents each scheme's stage structure.
    pub fn with_latency(mut self, alpha: f64) -> Self {
        assert!(alpha >= 0.0);
        self.alpha = alpha;
        self
    }

    /// Price per link class on a two-level topology (builder style). A
    /// flat `TopoCost` is accepted and ignored, so callers can pass the
    /// execution topology unconditionally.
    pub fn with_topology(mut self, topo: TopoCost) -> Self {
        self.topo = Some(topo);
        self
    }

    /// The active two-level pricing, if any (flat topologies price
    /// identically to the single-link model, so they take the flat
    /// path — keeping every historical prediction bit-identical).
    fn topo_active(&self) -> Option<TopoCost> {
        self.topo.filter(|t| !t.is_flat() && self.n > 1)
    }

    fn nf(&self) -> f64 {
        self.n as f64
    }

    /// Latency charge for `stages` synchronous stages (0 when `n == 1`:
    /// a single machine never touches the network).
    fn lat(&self, stages: usize) -> f64 {
        if self.n <= 1 {
            0.0
        } else {
            self.alpha * stages as f64
        }
    }

    /// Number of synchronous stages each planner candidate executes at
    /// this `n` — mirrors the actual protocol machines, which
    /// is what [`crate::cluster::Network::stage_time`] charges α for.
    pub fn stage_count(&self, scheme: &str) -> Option<usize> {
        let n = self.n;
        // Arithmetic-safe stand-in for degenerate n (the result is
        // clamped to 0 below anyway); keeps the name-validating match
        // free of usize underflow even for a hand-built model with
        // n < 2 that bypassed `new`'s assert.
        let nn = n.max(2);
        let stages = match scheme {
            // ring reduce-scatter + ring all-gather
            "allreduce" | "dense" => 2 * (nn - 1),
            // one-shot point-to-point broadcast
            "agsparse" => 1,
            "agsparse-ring" => nn - 1,
            // doubling over the pow-2 core, plus fold-in/out when n is
            // not a power of two (mirrors `schemes::AgSparse`'s folded
            // schedule — the old ceil(log2 n) assumed a pow-2-only
            // protocol that used to panic elsewhere).
            "agsparse-hier" => {
                let core = largest_pow2_at_most(nn);
                let folds = if core == nn { 0 } else { 2 };
                core.trailing_zeros() as usize + folds
            }
            // fold-in + recursive doubling + fold-out
            "sparcml" => {
                let core = largest_pow2_at_most(nn);
                let folds = if core == nn { 0 } else { 2 };
                core.trailing_zeros() as usize + folds
            }
            // push + pull
            "sparseps" | "sparse-ps" | "omnireduce" | "zen" | "zen-coo" => 2,
            // balance histogram + scatter + gather
            "oktopk" | "ok-topk" => 3,
            _ => return None,
        };
        // A single machine executes no network stage at all, whatever
        // the protocol's shape — but an unknown name is still an error.
        Some(if n <= 1 { 0 } else { stages })
    }

    /// Predicted synchronization time for a planner candidate by its
    /// [`crate::schemes::by_name`] name — bandwidth term + α·stages on a
    /// flat network, per-class max-over-links pricing when a two-level
    /// topology is configured ([`with_topology`](CostModel::with_topology)).
    /// `block_len` parameterizes the OmniReduce formula; `None` for
    /// names without a closed form (lossy strawman). One machine moves
    /// nothing, whatever the formula says (Zen's `M/32` bitmap constant
    /// in particular does not vanish with the `(n−1)` factors).
    pub fn time_for(&self, scheme: &str, block_len: usize) -> Option<f64> {
        if self.topo_active().is_some() {
            return self.time_for_by_class(scheme, block_len).map(|c| c.total);
        }
        self.time_for_flat(scheme, block_len)
    }

    /// The flat single-link prediction (the historical path, unchanged).
    fn time_for_flat(&self, scheme: &str, block_len: usize) -> Option<f64> {
        if self.n <= 1 {
            // Validate the name anyway so typos stay loud.
            self.stage_count(scheme)?;
            return Some(0.0);
        }
        let bw = match scheme {
            "allreduce" | "dense" => self.dense(),
            "agsparse" | "agsparse-ring" => self.agsparse(),
            "agsparse-hier" => self.agsparse_hier(),
            "sparcml" => self.sparcml(),
            "sparseps" | "sparse-ps" => self.sparse_ps(),
            "omnireduce" => self.omnireduce(block_len),
            "oktopk" | "ok-topk" => self.oktopk(),
            "zen-coo" => self.balanced_parallelism(),
            "zen" => self.zen(),
            _ => return None,
        };
        Some(bw + self.lat(self.stage_count(scheme)?))
    }

    /// Predicted time split by link class (`[intra, inter]` sums plus
    /// the per-stage-max total the transports charge). On a flat model
    /// everything is inter-class, so `total == inter` and `intra == 0`.
    pub fn time_for_by_class(&self, scheme: &str, block_len: usize) -> Option<ClassedTime> {
        if self.n <= 1 {
            self.stage_count(scheme)?;
            return Some(ClassedTime::default());
        }
        match self.topo_active() {
            Some(t) => {
                let loads = self.stage_loads(scheme, block_len, &t)?;
                Some(classed_total(&loads, &t))
            }
            None => {
                let total = self.time_for_flat(scheme, block_len)?;
                Some(ClassedTime {
                    total,
                    intra: 0.0,
                    inter: total,
                })
            }
        }
    }

    /// Per-stage busiest-endpoint loads of a candidate, split by link
    /// class, under topology `t` — the classed twin of the flat closed
    /// forms. The per-scheme structure mirrors each scheme's protocol
    /// protocol: p2p transfers split a rank's `n−1` peers into `g−1`
    /// co-located and `n−g` remote ones; doubling exchanges are
    /// node-local while the partner distance stays below the node size.
    fn stage_loads(&self, scheme: &str, block_len: usize, t: &TopoCost) -> Option<Vec<StageLoad>> {
        let n = self.n;
        let nf = self.nf();
        let g = t.ranks_per_node.min(n).max(1);
        let remote = (n - g) as f64;
        let local = (g - 1) as f64;
        let d = |j: usize| self.stats.agg_density(j);
        // A per-peer p2p transfer of `units` per peer: the busiest rank
        // talks to g−1 co-located and n−g remote peers.
        let split = |units_per_peer: f64| StageLoad {
            intra: local * units_per_peer,
            inter: remote * units_per_peer,
        };
        let loads = match scheme {
            "allreduce" | "dense" => {
                // Ring of dense chunks: every stage, boundary ranks
                // cross nodes while interior neighbors stay local.
                let chunk = self.m / nf;
                let per_stage = StageLoad {
                    intra: if g > 1 { chunk } else { 0.0 },
                    inter: if n > g { chunk } else { 0.0 },
                };
                vec![per_stage; 2 * (n - 1)]
            }
            "agsparse" => vec![split(2.0 * d(1) * self.m)],
            "agsparse-ring" => {
                let u = 2.0 * d(1) * self.m;
                let per_stage = StageLoad {
                    intra: if g > 1 { u } else { 0.0 },
                    inter: if n > g { u } else { 0.0 },
                };
                vec![per_stage; n - 1]
            }
            "agsparse-hier" => {
                let core = largest_pow2_at_most(n);
                let excess = n - core;
                let u1 = 2.0 * d(1) * self.m;
                let mut loads = Vec::new();
                if excess > 0 {
                    loads.push(fold_load(t, core, excess, u1));
                }
                for s in 0..core.trailing_zeros() as usize {
                    let set = if excess > 0 {
                        (1usize << (s + 1)).min(n)
                    } else {
                        1usize << s
                    };
                    loads.push(doubling_load(1 << s, g, set as f64 * u1));
                }
                if excess > 0 {
                    loads.push(fold_load(t, core, excess, 2.0 * d(n) * self.m));
                }
                loads
            }
            "sparcml" => {
                let core = largest_pow2_at_most(n);
                let excess = n - core;
                let per = |j: usize| 2.0 * d(j) * self.m;
                let mut loads = Vec::new();
                if excess > 0 {
                    loads.push(fold_load(t, core, excess, per(1)));
                }
                for i in 0..core.trailing_zeros() as usize {
                    let j = if excess > 0 {
                        (1usize << (i + 1)).min(n)
                    } else {
                        1usize << i
                    };
                    loads.push(doubling_load(1 << i, g, per(j)));
                }
                if excess > 0 {
                    loads.push(fold_load(t, core, excess, per(n)));
                }
                loads
            }
            "sparseps" | "sparse-ps" => {
                let s = self.stats.skewness(n);
                vec![
                    split(2.0 * d(1) * s * self.m / nf),
                    split(2.0 * d(n) * s * self.m / nf),
                ]
            }
            "omnireduce" => {
                assert!(block_len > 0);
                let s = self.stats.skewness(n);
                let unit = 1.0 + 1.0 / block_len as f64;
                let push = (self.stats.block_density(1, block_len) * s).min(1.0);
                let pull = (self.stats.block_density(n, block_len) * s).min(1.0);
                vec![
                    split(self.m / nf * unit * push),
                    split(self.m / nf * unit * pull),
                ]
            }
            "oktopk" | "ok-topk" => {
                let blocks = crate::schemes::oktopk::balance_blocks(self.m as usize, n) as f64;
                vec![
                    split(blocks),
                    split(2.0 * d(1) * self.m / nf),
                    split(2.0 * d(n) * self.m / nf),
                ]
            }
            "zen-coo" => vec![
                split(2.0 * d(1) * self.m / nf),
                split(2.0 * d(n) * self.m / nf),
            ],
            "zen" => vec![
                split(2.0 * d(1) * self.m / nf),
                // Hash-bitmap pull: per-peer values + the per-partition
                // bitmap (|domain_p| ≈ M/n bits = M/32/n value units).
                split((d(n) * self.m + self.m / 32.0) / nf),
            ],
            _ => return None,
        };
        Some(loads)
    }

    /// Ring AllReduce over the dense tensor: `2(n−1)/n · M / B`.
    pub fn dense(&self) -> f64 {
        2.0 * (self.nf() - 1.0) / self.nf() * self.m / self.bandwidth_values
    }

    /// AGsparse (all-gather of COO): each GPU receives `(n−1) · 2dM / B`.
    pub fn agsparse(&self) -> f64 {
        let d = self.stats.agg_density(1);
        (self.nf() - 1.0) * 2.0 * d * self.m / self.bandwidth_values
    }

    /// AGsparse with the folded recursive-doubling schedule: identical
    /// to [`agsparse`](CostModel::agsparse) at `n = 2^k` (the doubling
    /// sum telescopes to `n−1` tensors), plus one fold-in of a raw
    /// tensor and one fold-out of the full aggregate otherwise.
    pub fn agsparse_hier(&self) -> f64 {
        if self.n <= 1 {
            return 0.0;
        }
        let core = largest_pow2_at_most(self.n);
        let excess = self.n - core;
        let u1 = 2.0 * self.stats.agg_density(1) * self.m / self.bandwidth_values;
        let mut t = 0.0;
        if excess > 0 {
            t += u1;
        }
        for s in 0..core.trailing_zeros() as usize {
            let set = if excess > 0 {
                (1usize << (s + 1)).min(self.n)
            } else {
                1usize << s
            };
            t += set as f64 * u1;
        }
        if excess > 0 {
            t += 2.0 * self.stats.agg_density(self.n) * self.m / self.bandwidth_values;
        }
        t
    }

    /// SparCML SSAR recursive doubling, generalized to arbitrary `n`.
    ///
    /// Power-of-two `n = 2^k`: stage `i` ships the aggregate of `2^i`
    /// tensors (density `d^{2^i}`) as COO both ways — `Σ_i 2·d^{2^i}·M/B`
    /// (the Appendix-B closed form, kept as the test oracle below).
    ///
    /// Other `n`: the scheme folds the `n − core` excess nodes into the
    /// largest power-of-two `core` first and broadcasts the final
    /// aggregate back (exactly what [`crate::schemes::SparCml`]
    /// executes), so the model adds one `2·d(1)` fold-in stage and one
    /// `2·d(n)` fold-out stage, and the busiest core node at doubling
    /// stage `i` ships an aggregate of up to `2^{i+1}` inputs.
    pub fn sparcml(&self) -> f64 {
        if self.n <= 1 {
            return 0.0;
        }
        let core = largest_pow2_at_most(self.n);
        let excess = self.n - core;
        let per = |j: usize| 2.0 * self.stats.agg_density(j) * self.m / self.bandwidth_values;
        let mut t = 0.0;
        if excess > 0 {
            t += per(1); // fold-in: excess nodes ship their own tensor
        }
        for i in 0..core.trailing_zeros() as usize {
            let j = if excess > 0 {
                (1usize << (i + 1)).min(self.n)
            } else {
                1usize << i
            };
            t += per(j);
        }
        if excess > 0 {
            t += per(self.n); // fold-out: full aggregate back to excess
        }
        t
    }

    /// Sparse PS (point-to-point pull): `2(n−1)(d_G + d_G^n)·s^n·M/n/B`
    /// (Appendix B, proof of Lemma 4).
    pub fn sparse_ps(&self) -> f64 {
        let d1 = self.stats.agg_density(1);
        let dn = self.stats.agg_density(self.n);
        let s = self.stats.skewness(self.n);
        2.0 * (self.nf() - 1.0) * (d1 + dn) * s * self.m / self.nf() / self.bandwidth_values
    }

    /// OmniReduce: contiguous even partitions, non-zero *blocks* shipped
    /// as (id + `block_len` values) — `(1 + 1/b)` value units per block
    /// slot. The busiest aggregator owns the hottest partition, whose
    /// block share is approximated as `min(1, s^n · blocks(d))`:
    /// `(n−1)·M/n·(1+1/b)·(blocks(d_G)·s + blocks(d_G^n)·s)/B`.
    pub fn omnireduce(&self, block_len: usize) -> f64 {
        assert!(block_len > 0);
        let s = self.stats.skewness(self.n);
        let push = (self.stats.block_density(1, block_len) * s).min(1.0);
        let pull = (self.stats.block_density(self.n, block_len) * s).min(1.0);
        let unit = 1.0 + 1.0 / block_len as f64;
        (self.nf() - 1.0) * self.m / self.nf() * unit * (push + pull) / self.bandwidth_values
    }

    /// Ok-Topk balanced sparse allreduce: the Balanced-Parallelism COO
    /// transfer achieved for real (the balance histogram removes the
    /// skew penalty) plus the histogram broadcast that pays for it —
    /// `(n−1)·blocks/B + 2(n−1)(d_G + d_G^n)·M/n/B` with
    /// `blocks = `[`crate::schemes::oktopk::balance_blocks`]`(M, n)`.
    pub fn oktopk(&self) -> f64 {
        let blocks = crate::schemes::oktopk::balance_blocks(self.m as usize, self.n) as f64;
        (self.nf() - 1.0) * blocks / self.bandwidth_values + self.balanced_parallelism()
    }

    /// Balanced Parallelism with COO (the hypothetical optimum of Fig 7):
    /// Sparse PS with `s^n = 1`: `2(n−1)(d_G + d_G^n)·M/n/B`.
    pub fn balanced_parallelism(&self) -> f64 {
        let d1 = self.stats.agg_density(1);
        let dn = self.stats.agg_density(self.n);
        2.0 * (self.nf() - 1.0) * (d1 + dn) * self.m / self.nf() / self.bandwidth_values
    }

    /// Zen: COO push (balanced) + hash-bitmap pull
    /// (`(n−1)·(d_G^n·M/n + (|𝕀_p| bits)/32)` per worker ⇒ values:
    /// `(n−1)·(2d_G·M/n)` push + `(n−1)·(d_G^n·M/n) + M/32` pull).
    pub fn zen(&self) -> f64 {
        let d1 = self.stats.agg_density(1);
        let dn = self.stats.agg_density(self.n);
        let push = (self.nf() - 1.0) * 2.0 * d1 * self.m / self.nf();
        let pull = (self.nf() - 1.0) * dn * self.m / self.nf() + self.m / 32.0;
        (push + pull) / self.bandwidth_values
    }

    /// Communication lower bound (paper footnote 3): every GPU must
    /// receive the aggregate of the other `n−1` GPUs' non-zeros, no
    /// indices: `d_G^{n−1}·M/B`.
    pub fn lower_bound(&self) -> f64 {
        let d = self.stats.agg_density(self.n.saturating_sub(1).max(1));
        d * self.m / self.bandwidth_values
    }
}

/// Sum a stage-load list into per-class times + the per-stage-max total.
fn classed_total(loads: &[StageLoad], t: &TopoCost) -> ClassedTime {
    let mut out = ClassedTime::default();
    for l in loads {
        let ti = if l.intra > 0.0 {
            t.intra_alpha + l.intra / t.intra_bandwidth_values
        } else {
            0.0
        };
        let te = if l.inter > 0.0 {
            t.inter_alpha + l.inter / t.inter_bandwidth_values
        } else {
            0.0
        };
        out.intra += ti;
        out.inter += te;
        out.total += ti.max(te);
    }
    out
}

/// Class split of one recursive-doubling exchange at partner distance
/// `dist` with `g` ranks per node: node-local while `dist < g` (the
/// standard aligned placement needs `g` to be a power of two), cross-
/// node beyond. Non-pow-2 node sizes mix both classes in one stage —
/// priced conservatively with the full load on each.
fn doubling_load(dist: usize, g: usize, units: f64) -> StageLoad {
    if g <= 1 {
        StageLoad::inter_only(units)
    } else if g.is_power_of_two() {
        if dist < g {
            StageLoad {
                intra: units,
                inter: 0.0,
            }
        } else {
            StageLoad::inter_only(units)
        }
    } else {
        StageLoad {
            intra: units,
            inter: units,
        }
    }
}

/// Class split of a fold stage: pair `(j, core + j)` for each excess
/// rank, classified by actual placement. Fold pairs are disjoint, so
/// the busiest endpoint of each active class carries exactly `units`.
fn fold_load(t: &TopoCost, core: usize, excess: usize, units: f64) -> StageLoad {
    let mut l = StageLoad::default();
    for j in 0..excess {
        if t.ranks_per_node > 1 && t.node_of(j) == t.node_of(core + j) {
            l.intra = units;
        } else {
            l.inter = units;
        }
    }
    l
}

/// An analytic stats model: densification follows the independent-union
/// approximation `d(j) = 1 − (1 − c·d)^j` scaled to match `d(1) = d`,
/// with skewness supplied directly. Useful for tests and for sweeps
/// beyond measured scales.
#[derive(Clone, Debug)]
pub struct AnalyticStats {
    pub d1: f64,
    /// Effective "fresh mass" per additional worker, in (0, 1]: 1 =
    /// independent tensors (maximal densification), → 0 = identical.
    pub freshness: f64,
    pub skew: f64,
}

impl SparsityStats for AnalyticStats {
    fn agg_density(&self, j: usize) -> f64 {
        // union of j sets each of density d1, pairwise-correlated via
        // freshness: d(j) = d1 · (1 + freshness·(j−1) damped by overlap)
        let j = j as f64;
        let f = self.freshness;
        // geometric saturation: d(j) = d1 · (1 − (1−f)^j) / f   (≤ d1·j)
        if f >= 1.0 {
            (self.d1 * j).min(1.0)
        } else {
            (self.d1 * (1.0 - (1.0 - f).powf(j)) / f).min(1.0)
        }
    }

    fn skewness(&self, _n: usize) -> f64 {
        self.skew
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> AnalyticStats {
        // NMT-like: d = 2.47%, moderate overlap, strong skew
        AnalyticStats {
            d1: 0.0247,
            freshness: 0.35,
            skew: 20.0,
        }
    }

    fn model(n: usize) -> (f64, f64) {
        let s = stats();
        let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
        (cm.dense(), cm.zen())
    }

    /// The Appendix-B power-of-two closed form, kept verbatim as the
    /// oracle the generalized `sparcml` must reproduce at `n = 2^k`.
    fn sparcml_pow2_oracle<S: SparsityStats>(m: f64, n: usize, bw: f64, stats: &S) -> f64 {
        assert!(n.is_power_of_two());
        let stages = n.trailing_zeros() as usize;
        (0..stages)
            .map(|i| 2.0 * stats.agg_density(1 << i) * m / bw)
            .sum()
    }

    #[test]
    fn lemma4_balanced_beats_sparse_ps() {
        let s = stats();
        for n in [4usize, 8, 16, 64, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            assert!(
                cm.balanced_parallelism() < cm.sparse_ps(),
                "n={n}: BP must beat Sparse PS"
            );
        }
    }

    #[test]
    fn lemma5_bp_beats_sparcml_with_overlap() {
        let s = stats();
        for n in [8usize, 16, 64, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            assert!(
                cm.balanced_parallelism() < cm.sparcml(),
                "n={n}: BP must beat SparCML when overlapped"
            );
        }
    }

    #[test]
    fn sparcml_matches_pow2_closed_form() {
        let s = stats();
        for n in [1usize, 2, 4, 8, 16, 32, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            let oracle = if n == 1 {
                0.0
            } else {
                sparcml_pow2_oracle(112e6, n, 25e9 / 32.0, &s)
            };
            assert!(
                (cm.sparcml() - oracle).abs() < 1e-12,
                "n={n}: generalized {} vs closed form {oracle}",
                cm.sparcml()
            );
        }
    }

    #[test]
    fn sparcml_non_pow2_no_panic_and_bracketed() {
        // The planner evaluates every candidate at arbitrary n (the old
        // hard assert panicked on n = 6). The generalized stage sum must
        // be finite and sit between the two adjacent power-of-two costs'
        // natural bounds: at least the core's closed form, and at most
        // the core's plus the two fold stages at extreme densities.
        let s = stats();
        for n in [3usize, 5, 6, 7, 12, 100] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            let t = cm.sparcml();
            assert!(t.is_finite() && t > 0.0, "n={n}: {t}");
            let core = largest_pow2_at_most(n);
            let core_t = sparcml_pow2_oracle(112e6, core, 25e9 / 32.0, &s);
            assert!(t > core_t, "n={n}: folds must add cost over core {core}");
            let bound = core_t
                + 2.0 * (s.agg_density(1) + s.agg_density(n)) * 112e6 / (25e9 / 32.0)
                + 2.0 * (s.agg_density(2 * core.min(n)) - s.agg_density(1)).abs() * 112e6
                    / (25e9 / 32.0)
                    * core.trailing_zeros() as f64;
            assert!(t <= bound * 1.0001, "n={n}: {t} vs bound {bound}");
        }
    }

    #[test]
    fn latency_term_counts_stages() {
        let s = stats();
        let alpha = 1e-3;
        let cm0 = CostModel::new(1e6, 8, 25e9 / 32.0, &s);
        let cm1 = CostModel::new(1e6, 8, 25e9 / 32.0, &s).with_latency(alpha);
        for scheme in [
            "allreduce",
            "agsparse",
            "sparcml",
            "sparseps",
            "omnireduce",
            "oktopk",
            "zen-coo",
            "zen",
        ] {
            let stages = cm1.stage_count(scheme).unwrap();
            let d = cm1.time_for(scheme, 256).unwrap() - cm0.time_for(scheme, 256).unwrap();
            assert!(
                (d - alpha * stages as f64).abs() < 1e-12,
                "{scheme}: latency delta {d} for {stages} stages"
            );
        }
        // one machine: everything is free, latency included
        let cm_solo = CostModel::new(1e6, 1, 25e9 / 32.0, &s).with_latency(alpha);
        assert_eq!(cm_solo.time_for("zen", 256), Some(0.0));
    }

    #[test]
    fn oktopk_is_balanced_parallelism_plus_histogram() {
        let s = stats();
        let bw = 25e9 / 32.0;
        let cm = CostModel::new(1e7, 8, bw, &s);
        let blocks = crate::schemes::oktopk::balance_blocks(1e7 as usize, 8) as f64;
        let expect = cm.balanced_parallelism() + 7.0 * blocks / bw;
        let got = cm.time_for("oktopk", 256).unwrap();
        assert!((got - expect).abs() < 1e-12, "{got} vs {expect}");
        // The histogram premium is what separates it from the Fig-7
        // hypothetical optimum — strictly above, but vanishingly so at
        // realistic sizes (a few hundred counts vs millions of values).
        assert!(got > cm.balanced_parallelism());
        assert!(got < cm.balanced_parallelism() * 1.01);
        // And it beats skewed Sparse PS whenever skew is real.
        assert!(got < cm.sparse_ps(), "balance must beat skew penalty");
        assert_eq!(cm.stage_count("oktopk"), Some(3));
    }

    /// Group-clustered stats: workers 0..n/2 share one support, workers
    /// n/2..n another of equal size — d(j) stays at d1 through the first
    /// half and doubles only once the second group joins. The
    /// placement-correlated sparsity of locality-sharded loaders.
    struct GroupStats {
        d1: f64,
        n: usize,
    }

    impl SparsityStats for GroupStats {
        fn agg_density(&self, j: usize) -> f64 {
            if j <= self.n / 2 {
                self.d1
            } else {
                2.0 * self.d1
            }
        }
        fn skewness(&self, _n: usize) -> f64 {
            1.1
        }
    }

    fn topo_4x2(inter_bw: f64) -> TopoCost {
        TopoCost {
            nodes: 4,
            ranks_per_node: 2,
            intra_alpha: 0.0,
            intra_bandwidth_values: inter_bw * 10.0,
            inter_alpha: 0.0,
            inter_bandwidth_values: inter_bw,
        }
    }

    #[test]
    fn flat_topology_prices_identically() {
        let s = stats();
        let flat = TopoCost {
            nodes: 8,
            ranks_per_node: 1,
            intra_alpha: 1e-6,
            intra_bandwidth_values: 1e12,
            inter_alpha: 50e-6,
            inter_bandwidth_values: 25e9 / 32.0,
        };
        let plain = CostModel::new(1e7, 8, 25e9 / 32.0, &s).with_latency(50e-6);
        let with_topo = CostModel::new(1e7, 8, 25e9 / 32.0, &s)
            .with_latency(50e-6)
            .with_topology(flat);
        let all = [
            "allreduce",
            "agsparse",
            "agsparse-hier",
            "sparcml",
            "sparseps",
            "omnireduce",
            "oktopk",
            "zen-coo",
            "zen",
        ];
        for scheme in all {
            assert_eq!(
                plain.time_for(scheme, 256),
                with_topo.time_for(scheme, 256),
                "{scheme}: a flat topology must not change the prediction"
            );
            let c = with_topo.time_for_by_class(scheme, 256).unwrap();
            assert_eq!(c.intra, 0.0, "{scheme}");
            assert_eq!(c.total, c.inter, "{scheme}");
        }
    }

    #[test]
    fn classed_times_bracket_total() {
        let s = stats();
        let cm = CostModel::new(1e7, 8, 25e9 / 32.0, &s).with_topology(topo_4x2(25e9 / 32.0));
        let all = [
            "allreduce",
            "agsparse",
            "agsparse-hier",
            "sparcml",
            "sparseps",
            "omnireduce",
            "oktopk",
            "zen-coo",
            "zen",
        ];
        for scheme in all {
            let c = cm.time_for_by_class(scheme, 256).unwrap();
            assert!(c.total.is_finite() && c.total > 0.0, "{scheme}: {c:?}");
            assert!(
                c.total + 1e-15 >= c.intra.max(c.inter),
                "{scheme}: total below a class sum ({c:?})"
            );
            assert!(
                c.total <= c.intra + c.inter + 1e-15,
                "{scheme}: total beyond the class sums ({c:?})"
            );
            assert_eq!(cm.time_for(scheme, 256), Some(c.total), "{scheme}");
        }
    }

    #[test]
    fn doubling_first_stage_is_node_local() {
        // At 4×2, SparCML's dist-1 exchange is co-located: its inter
        // share must only price the dist-2 and dist-4 stages — strictly
        // below the flat prediction's three full-rate stages.
        let s = GroupStats { d1: 0.01, n: 8 };
        let bw = 25e9 / 32.0;
        let flat = CostModel::new(1e7, 8, bw, &s);
        let topo = CostModel::new(1e7, 8, bw, &s).with_topology(topo_4x2(bw));
        let c = topo.time_for_by_class("sparcml", 256).unwrap();
        // inter prices d(2) + d(4) = 2·d1 aggregates; flat prices
        // d(1) + d(2) + d(4) = 3·d1.
        let expect_inter = 2.0 * (s.agg_density(2) + s.agg_density(4)) * 1e7 / bw;
        assert!((c.inter - expect_inter).abs() < expect_inter * 1e-9, "{c:?}");
        assert!(c.intra > 0.0, "dist-1 stage rides the intra link");
        assert!(c.total < flat.time_for("sparcml", 256).unwrap());
    }

    #[test]
    fn hierarchy_crossover_under_group_clustered_sparsity() {
        // The tentpole's decision flip: with group-clustered sparsity
        // (d(2) = d(4) = d(1), d(8) = 2·d(1)) the flat mesh prefers
        // Balanced Parallelism (zen-coo: 5.25·d1·M vs SparCML's 6·d1·M),
        // but on 4×2 with 10× slower inter-node links SparCML's
        // node-local first stage drops its inter volume to 4·d1·M,
        // below zen-coo's 4.5·d1·M — the hierarchy wins.
        let s = GroupStats { d1: 0.01, n: 8 };
        let bw = 25e9 / 32.0;
        let flat = CostModel::new(1e7, 8, bw, &s);
        let topo = CostModel::new(1e7, 8, bw, &s).with_topology(topo_4x2(bw));
        assert!(
            flat.time_for("zen-coo", 256).unwrap() < flat.time_for("sparcml", 256).unwrap(),
            "flat: balanced parallelism wins"
        );
        assert!(
            topo.time_for("sparcml", 256).unwrap() < topo.time_for("zen-coo", 256).unwrap(),
            "two-level: the hierarchical scheme wins"
        );
    }

    #[test]
    fn agsparse_hier_matches_p2p_at_pow2_and_adds_folds() {
        let s = stats();
        for n in [2usize, 4, 8, 16] {
            let cm = CostModel::new(1e7, n, 25e9 / 32.0, &s);
            assert!(
                (cm.agsparse_hier() - cm.agsparse()).abs() < 1e-12,
                "n={n}: pow-2 doubling telescopes to the p2p volume"
            );
        }
        for n in [3usize, 5, 6, 12] {
            let cm = CostModel::new(1e7, n, 25e9 / 32.0, &s);
            let t = cm.agsparse_hier();
            assert!(t.is_finite() && t > cm.agsparse() * 0.5, "n={n}");
            assert!(
                t > CostModel::new(1e7, largest_pow2_at_most(n), 25e9 / 32.0, &s).agsparse(),
                "n={n}: folds add cost over the core"
            );
            assert_eq!(
                cm.stage_count("agsparse-hier").unwrap(),
                largest_pow2_at_most(n).trailing_zeros() as usize + 2,
                "n={n}"
            );
        }
    }

    #[test]
    fn omnireduce_interpolates_between_dense_and_coo() {
        // Scattered non-zeros (independent positions): at block_len 256
        // and density 1%, nearly every block is non-zero → OmniReduce
        // approaches the dense cost ballpark; at block_len 1 it becomes
        // a COO-like 2-units-per-nnz scheme and beats it.
        let s = AnalyticStats {
            d1: 0.01,
            freshness: 1.0,
            skew: 1.0,
        };
        let cm = CostModel::new(1e8, 8, 25e9 / 32.0, &s);
        let coarse = cm.omnireduce(256);
        let fine = cm.omnireduce(1);
        assert!(fine < coarse, "fine blocks {fine} vs coarse {coarse}");
        assert!(coarse > cm.dense() * 0.5, "coarse ≈ dense regime");
        assert!(fine < cm.dense(), "b=1 ships only non-zeros");
    }

    #[test]
    fn block_density_default_monotone() {
        let s = stats();
        let b64 = s.block_density(1, 64);
        let b256 = s.block_density(1, 256);
        assert!(s.agg_density(1) <= b64 && b64 <= b256 && b256 <= 1.0);
    }

    #[test]
    fn no_overlap_centralization_matches_bp_push() {
        // With freshness = 1 (disjoint tensors), AGsparse's per-GPU recv
        // equals 2d(n-1)M/B, and BP cannot beat the no-index lower bound
        // by much — Theorem 1.1's regime: centralization is competitive.
        let s = AnalyticStats {
            d1: 0.001,
            freshness: 1.0,
            skew: 1.0,
        };
        let cm = CostModel::new(1e8, 16, 25e9 / 32.0, &s);
        // BP's pull alone ≈ (n-1)/n·d^n·M = (n-1)/n·n·d·M ≈ AGsparse/2;
        // with push it is within 2× of AGsparse — no big win without overlap.
        assert!(cm.balanced_parallelism() > cm.agsparse() * 0.45);
    }

    #[test]
    fn fig7_shape_agsparse_crosses_dense() {
        // AGsparse degrades linearly with n and crosses Dense around
        // n ≈ 1/d (paper: > 40 GPUs for NMT).
        let s = stats();
        let mut crossed = None;
        for n in [4usize, 8, 16, 32, 64, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            if cm.agsparse() > cm.dense() {
                crossed = Some(n);
                break;
            }
        }
        let c = crossed.expect("AGsparse should cross Dense");
        assert!((16..=64).contains(&c), "crossover at {c}");
    }

    #[test]
    fn fig7_shape_zen_beats_dense_at_128() {
        // Paper: Balanced Parallelism still 36% below Dense at 128 GPUs.
        let (dense, zen) = model(128);
        assert!(
            zen < dense * 0.8,
            "zen {zen} should clearly beat dense {dense} at 128"
        );
    }

    #[test]
    fn lower_bound_is_lowest() {
        let s = stats();
        for n in [4usize, 16, 128] {
            let cm = CostModel::new(112e6, n, 25e9 / 32.0, &s);
            let lb = cm.lower_bound();
            for (name, t) in [
                ("dense", cm.dense()),
                ("ag", cm.agsparse()),
                ("sparcml", cm.sparcml()),
                ("ps", cm.sparse_ps()),
                ("bp", cm.balanced_parallelism()),
                ("zen", cm.zen()),
            ] {
                assert!(lb <= t * 1.0001, "n={n}: lower bound above {name}");
            }
        }
    }

    #[test]
    fn analytic_stats_monotone_saturating() {
        let s = stats();
        let mut prev = 0.0;
        for j in 1..=128 {
            let d = s.agg_density(j);
            assert!(d >= prev && d <= 1.0);
            prev = d;
        }
        // sublinear: d(8) < 8·d(1)
        assert!(s.agg_density(8) < 8.0 * s.agg_density(1));
    }
}
