//! Fig 7 — numerical comparison of schemes on measured workload traffic.
//!
//! Generates per-worker sparse tensors from a model profile, runs every
//! sparse scheme's *actual* byte accounting on them (the schemes really
//! move and aggregate the data), and normalizes communication time to
//! the closed-form Dense ring-allreduce — exactly the paper's
//! methodology ("we only consider their theoretical communication time",
//! normalized to Dense).
//!
//! Fig 7 runs NMT at up to 128 GPUs; we use the scaled profile (ratios
//! are scale-invariant — asserted by `scaling_invariance` below).

use crate::cluster::{LinkKind, Network};
use crate::schemes::{self, SyncScheme};
use crate::util::table::Table;
use crate::workload::{GradientGen, ModelProfile};

/// Measured sparsity statistics now live in the planner subsystem
/// (incremental unions, block shares, deterministic profiles) — the
/// historical `analysis::numeric::MeasuredStats` path stays importable.
pub use crate::planner::MeasuredStats;

/// One Fig 7 data point: scheme communication times normalized to Dense.
#[derive(Clone, Debug)]
pub struct Fig7Point {
    pub n: usize,
    /// (scheme name, time / dense_time)
    pub normalized: Vec<(String, f64)>,
}

/// Run the Fig 7 sweep for a profile over machine counts.
/// `link` sets bandwidth/latency; Zen hashing overhead is excluded here
/// (the figure is pure communication time, as in the paper).
pub fn fig7_sweep(
    profile: &ModelProfile,
    machine_counts: &[usize],
    link: LinkKind,
    seed: u64,
) -> Vec<Fig7Point> {
    let gen = GradientGen::new(profile.clone(), seed);
    let mut out = Vec::new();
    for &n in machine_counts {
        let inputs = gen.iteration_all(0, n);
        let net = Network::new(n, link);
        // Closed-form dense time (data-independent).
        let dense_time = {
            let nf = n as f64;
            let bytes = profile.emb_params() as f64 * 4.0;
            2.0 * (nf - 1.0) / nf * bytes * 8.0 / link.bandwidth_bps()
        };
        // Fig 7 is pure communication time: exclude Zen's compute charge.
        let mut zen_coo = schemes::Zen::new(
            seed ^ 0x5a5a_1234,
            n,
            gen.expected_nnz(),
            schemes::ZenIndexFormat::Coo, // Fig 7 uses COO for fairness
        );
        zen_coo.charge_compute = false;
        let mut zen_hb = schemes::Zen::new(
            seed ^ 0x5a5a_1234,
            n,
            gen.expected_nnz(),
            schemes::ZenIndexFormat::HashBitmap,
        );
        zen_hb.charge_compute = false;
        let schemes_list: Vec<Box<dyn SyncScheme>> = vec![
            Box::new(schemes::AgSparse::new(schemes::AgPattern::PointToPoint)),
            Box::new(schemes::SparCml::new()),
            Box::new(schemes::SparsePs::new()),
            Box::new(schemes::OmniReduce::new(crate::tensor::block::DEFAULT_BLOCK)),
            Box::new(zen_coo),
            Box::new(zen_hb),
        ];
        let mut normalized = vec![("Dense".to_string(), 1.0)];
        let mut scratch = schemes::SyncScratch::new();
        for s in schemes_list.iter() {
            let r = s.run_sim(&inputs, &net, &mut scratch);
            normalized.push((s.name().to_string(), r.report.comm_time() / dense_time));
        }
        out.push(Fig7Point { n, normalized });
    }
    out
}

/// Render a Fig 7 sweep as a table (rows = n, columns = schemes).
pub fn fig7_table(points: &[Fig7Point]) -> Table {
    let mut headers: Vec<&str> = vec!["machines"];
    let names: Vec<String> = points
        .first()
        .map(|p| p.normalized.iter().map(|(n, _)| n.clone()).collect())
        .unwrap_or_default();
    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    headers.extend(name_refs);
    let mut t = Table::new(
        "Fig 7 — normalized communication time (lower is better)",
        &headers,
    );
    for p in points {
        let mut row = vec![p.n.to_string()];
        row.extend(p.normalized.iter().map(|(_, v)| format!("{v:.3}")));
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::profiles;

    fn nmt_small() -> ModelProfile {
        profiles::by_name("NMT").unwrap().scaled(256)
    }

    #[test]
    fn fig7_orderings_hold() {
        let pts = fig7_sweep(&nmt_small(), &[8, 32], LinkKind::Tcp25, 42);
        for p in &pts {
            let get = |name: &str| {
                p.normalized
                    .iter()
                    .find(|(n, _)| n == name)
                    .map(|(_, v)| *v)
                    .unwrap()
            };
            // Zen (COO) must beat Sparse PS (same format, balanced).
            assert!(
                get("Zen-COO") < get("SparsePS"),
                "n={}: Zen-COO {} vs SparsePS {}",
                p.n,
                get("Zen-COO"),
                get("SparsePS")
            );
            // Zen must beat SparCML and OmniReduce (the paper's headline).
            assert!(get("Zen") < get("SparCML"), "n={}", p.n);
            assert!(get("Zen") < get("OmniReduce"), "n={}", p.n);
        }
    }

    #[test]
    fn agsparse_grows_linearly_with_n() {
        let pts = fig7_sweep(&nmt_small(), &[4, 8, 16], LinkKind::Tcp25, 7);
        let ag: Vec<f64> = pts
            .iter()
            .map(|p| {
                p.normalized
                    .iter()
                    .find(|(n, _)| n == "AGsparse")
                    .unwrap()
                    .1
            })
            .collect();
        assert!(ag[1] > ag[0] * 1.4, "AGsparse should grow with n: {ag:?}");
        assert!(ag[2] > ag[1] * 1.4, "AGsparse should grow with n: {ag:?}");
    }

    #[test]
    fn scaling_invariance() {
        // Normalized ratios are (approximately) invariant to model scale.
        let a = fig7_sweep(&nmt_small(), &[8], LinkKind::Tcp25, 3);
        let b = fig7_sweep(
            &profiles::by_name("NMT").unwrap().scaled(128),
            &[8],
            LinkKind::Tcp25,
            3,
        );
        for ((name_a, va), (name_b, vb)) in a[0].normalized.iter().zip(b[0].normalized.iter()) {
            assert_eq!(name_a, name_b);
            if *va > 0.01 {
                let rel = (va - vb).abs() / va;
                assert!(rel < 0.35, "{name_a}: {va} vs {vb} (rel {rel})");
            }
        }
    }

    #[test]
    fn table_renders() {
        let pts = fig7_sweep(&nmt_small(), &[4], LinkKind::Tcp25, 1);
        let t = fig7_table(&pts);
        assert!(t.to_markdown().contains("Zen"));
        assert_eq!(t.rows.len(), 1);
    }
}
