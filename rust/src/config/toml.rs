//! Minimal TOML-subset parser for run configuration files.
//!
//! Supported: `[table]` headers, `key = value` with string / integer /
//! float / boolean / homogeneous array values, `#` comments. That is
//! exactly the subset run configs need; anything fancier errors loudly.
//!
//! ```toml
//! # examples/configs/deepfm_16.toml
//! [run]
//! model    = "DeepFM"
//! machines = 16
//! scheme   = "zen"
//! link     = "tcp25"
//! ```

use std::collections::BTreeMap;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: table name → key → value. Top-level keys live in
/// the "" table.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Document {
    pub tables: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Document {
    pub fn get(&self, table: &str, key: &str) -> Option<&Value> {
        self.tables.get(table).and_then(|t| t.get(key))
    }

    pub fn str_or<'a>(&'a self, table: &str, key: &str, default: &'a str) -> &'a str {
        self.get(table, key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn int_or(&self, table: &str, key: &str, default: i64) -> i64 {
        self.get(table, key).and_then(|v| v.as_int()).unwrap_or(default)
    }

    pub fn float_or(&self, table: &str, key: &str, default: f64) -> f64 {
        self.get(table, key)
            .and_then(|v| v.as_float())
            .unwrap_or(default)
    }
}

/// Parse error with line context.
#[derive(Debug, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError {
        line,
        msg: msg.into(),
    }
}

/// Parse a TOML-subset document.
pub fn parse(input: &str) -> Result<Document, ParseError> {
    let mut doc = Document::default();
    let mut current = String::new();
    doc.tables.insert(current.clone(), BTreeMap::new());
    for (ln, raw) in input.lines().enumerate() {
        let line_no = ln + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(line_no, "unterminated table header"))?
                .trim();
            if name.is_empty() {
                return Err(err(line_no, "empty table name"));
            }
            current = name.to_string();
            doc.tables.entry(current.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| err(line_no, "expected `key = value`"))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(err(line_no, "empty key"));
        }
        let value = parse_value(value.trim(), line_no)?;
        let table = doc.tables.get_mut(&current).unwrap();
        if table.insert(key.to_string(), value).is_some() {
            return Err(err(line_no, format!("duplicate key '{key}'")));
        }
    }
    Ok(doc)
}

/// Load and parse a config file.
pub fn load(path: &std::path::Path) -> anyhow::Result<Document> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

fn strip_comment(line: &str) -> &str {
    // '#' outside a string starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    if s.is_empty() {
        return Err(err(line, "empty value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(line, "unterminated string"))?;
        if inner.contains('"') {
            return Err(err(line, "escaped quotes unsupported in the subset"));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err(line, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items: Result<Vec<Value>, _> = split_top_level(inner)
            .into_iter()
            .map(|item| parse_value(item.trim(), line))
            .collect();
        return Ok(Value::Array(items?));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(line, format!("cannot parse value '{s}'")))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = parse(
            r#"
# run configuration
title = "demo"

[run]
model    = "DeepFM"   # the Table-1 profile
machines = 16
lr       = 0.5
verbose  = true
sizes    = [1, 2, 3]
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("", "title", ""), "demo");
        assert_eq!(doc.str_or("run", "model", ""), "DeepFM");
        assert_eq!(doc.int_or("run", "machines", 0), 16);
        assert_eq!(doc.float_or("run", "lr", 0.0), 0.5);
        assert_eq!(doc.get("run", "verbose").unwrap().as_bool(), Some(true));
        assert_eq!(
            doc.get("run", "sizes").unwrap(),
            &Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
    }

    #[test]
    fn underscored_numbers() {
        let doc = parse("n = 1_000_000").unwrap();
        assert_eq!(doc.int_or("", "n", 0), 1_000_000);
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = parse(r##"s = "a # b""##).unwrap();
        assert_eq!(doc.str_or("", "s", ""), "a # b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("x = \"unterminated").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("[nope").unwrap_err();
        assert!(e.msg.contains("unterminated table"));
    }

    #[test]
    fn duplicate_keys_rejected() {
        let e = parse("a = 1\na = 2").unwrap_err();
        assert!(e.msg.contains("duplicate"));
    }

    #[test]
    fn nested_arrays() {
        let doc = parse("m = [[1, 2], [3]]").unwrap();
        match doc.get("", "m").unwrap() {
            Value::Array(rows) => {
                assert_eq!(rows.len(), 2);
                assert_eq!(rows[0], Value::Array(vec![Value::Int(1), Value::Int(2)]));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn floats_and_negatives() {
        let doc = parse("a = -3\nb = 2.5e-3").unwrap();
        assert_eq!(doc.int_or("", "a", 0), -3);
        assert!((doc.float_or("", "b", 0.0) - 2.5e-3).abs() < 1e-12);
    }
}
