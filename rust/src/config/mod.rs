//! Configuration: CLI argument parsing (clap stand-in) and a
//! TOML-subset file format ([`toml`]).
//!
//! The CLI supports `--key value`, `--key=value`, bare flags, and
//! positional arguments; `--config <file>` merges a TOML document under
//! the CLI (explicit flags win).

pub mod toml;

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Merge a config-file table under the CLI options: keys already
    /// present on the command line win. Array/boolean values are
    /// stringified. Returns self for chaining.
    pub fn with_config_table(mut self, doc: &toml::Document, table: &str) -> Self {
        if let Some(t) = doc.tables.get(table) {
            for (k, v) in t {
                let key = k.replace('_', "-");
                if self.options.contains_key(k) || self.options.contains_key(&key) {
                    continue;
                }
                let s = match v {
                    toml::Value::Str(s) => s.clone(),
                    toml::Value::Int(i) => i.to_string(),
                    toml::Value::Float(f) => f.to_string(),
                    toml::Value::Bool(b) => b.to_string(),
                    toml::Value::Array(_) => continue,
                };
                self.options.insert(key, s);
            }
        }
        self
    }

    /// If `--config <path>` was given, load it and merge `table`.
    pub fn maybe_load_config(self, table: &str) -> anyhow::Result<Self> {
        match self.get("config").map(|s| s.to_string()) {
            Some(path) => {
                let doc = toml::load(std::path::Path::new(&path))?;
                Ok(self.with_config_table(&doc, table))
            }
            None => Ok(self),
        }
    }

    /// Parse a link preset name.
    pub fn link(&self, key: &str, default: crate::cluster::LinkKind) -> crate::cluster::LinkKind {
        match self.get(key).map(|s| s.to_ascii_lowercase()).as_deref() {
            Some("tcp25") => crate::cluster::LinkKind::Tcp25,
            Some("rdma100") => crate::cluster::LinkKind::Rdma100,
            Some("nvlink") => crate::cluster::LinkKind::NvLink,
            _ => default,
        }
    }

    /// Parse a ratio-valued option (e.g. `--replan-threshold 0.25`):
    /// must parse as a float inside `[0, 1]`. Unlike the defaulting
    /// getters, a present-but-invalid value is an error — a planner
    /// silently running with hysteresis 0 because "0.2.5" failed to
    /// parse would be wrong.
    pub fn ratio(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                let x: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--{key} wants a number in [0, 1], got '{v}'"))?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&x),
                    "--{key} must be within [0, 1], got {x}"
                );
                Ok(x)
            }
        }
    }

    /// Parse a `--topology NxG[:ia,ib/ea,eb]` option into a two-level
    /// [`crate::cluster::Topology`] (see [`crate::cluster::Topology::parse`]).
    /// `default_inter` is the inter-node link when the spec names none
    /// (the CLI passes `--link`'s value). A present-but-invalid spec is
    /// an error — silently simulating a flat mesh when the user asked
    /// for a hierarchy would be wrong.
    pub fn topology(
        &self,
        key: &str,
        default_inter: crate::cluster::LinkKind,
    ) -> anyhow::Result<Option<crate::cluster::Topology>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => crate::cluster::Topology::parse(v, default_inter)
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    /// Parse a `--compress topk:K|threshold:T|none` option into a
    /// [`crate::compress::CompressSpec`]. Absent means lossless. A
    /// present-but-invalid spec is an error — silently training
    /// lossless when the user asked for compression (or vice versa)
    /// would be wrong.
    pub fn compress(&self, key: &str) -> anyhow::Result<crate::compress::CompressSpec> {
        match self.get(key) {
            None => Ok(crate::compress::CompressSpec::None),
            Some(v) => crate::compress::CompressSpec::parse(v)
                .map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    /// Parse an `--accuracy-budget B` option: a finite non-negative
    /// final-loss degradation allowance (0 disarms the lossy planner
    /// tier). NaN and negative budgets are errors, not silent zeroes —
    /// a budget the planner cannot compare against would arm nothing.
    pub fn accuracy_budget(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                let x: f64 = v.parse().map_err(|_| {
                    anyhow::anyhow!("--{key} wants a non-negative number, got '{v}'")
                })?;
                anyhow::ensure!(
                    x.is_finite() && x >= 0.0,
                    "--{key} must be a finite non-negative number, got {x}"
                );
                Ok(x)
            }
        }
    }

    /// Parse a transport backend name (`sim`, `channel`, `socket`,
    /// `event`, `threaded`).
    /// Unlike [`link`](Args::link), an unknown value is an error —
    /// silently simulating when the user asked for real frames would be
    /// wrong.
    pub fn transport(
        &self,
        key: &str,
        default: crate::wire::TransportKind,
    ) -> anyhow::Result<crate::wire::TransportKind> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => crate::wire::TransportKind::parse(v)
                .ok_or_else(|| {
                    anyhow::anyhow!("unknown transport '{v}' (sim|channel|socket|event|threaded)")
                }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn transport_parses_socket_and_rejects_unknown() {
        use crate::wire::TransportKind;
        let a = parse("sim --transport socket");
        assert_eq!(
            a.transport("transport", TransportKind::Sim).unwrap(),
            TransportKind::Socket
        );
        // Legacy spelling still lands on the socket mesh.
        let b = parse("sim --transport tcp");
        assert_eq!(
            b.transport("transport", TransportKind::Sim).unwrap(),
            TransportKind::Socket
        );
        let c = parse("sim --transport warp");
        let err = c.transport("transport", TransportKind::Sim).unwrap_err();
        assert!(
            err.to_string().contains("sim|channel|socket|event|threaded"),
            "{err}"
        );
        // The driver-level backends parse too (and `des` is an alias).
        for (spelling, want) in [
            ("event", TransportKind::Event),
            ("des", TransportKind::Event),
            ("threaded", TransportKind::Threaded),
        ] {
            let a = parse(&format!("sim --transport {spelling}"));
            assert_eq!(a.transport("transport", TransportKind::Sim).unwrap(), want);
        }
    }

    #[test]
    fn mixed_forms() {
        // note: a bare token after `--flag` is consumed as its value, so
        // positionals go before options (documented behavior).
        let a = parse("sim file.txt --machines 16 --scheme=zen --verbose");
        assert_eq!(a.positional, vec!["sim", "file.txt"]);
        assert_eq!(a.get("machines"), Some("16"));
        assert_eq!(a.get("scheme"), Some("zen"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("--n 8 --lr 0.5");
        assert_eq!(a.get_usize("n", 1), 8);
        assert_eq!(a.get_f64("lr", 0.0), 0.5);
        assert_eq!(a.get_usize("missing", 3), 3);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--fast --check");
        assert!(a.has_flag("fast") && a.has_flag("check"));
    }

    #[test]
    fn link_parsing() {
        let a = parse("--link rdma100");
        assert_eq!(
            a.link("link", crate::cluster::LinkKind::Tcp25),
            crate::cluster::LinkKind::Rdma100
        );
    }

    #[test]
    fn ratio_parsing() {
        assert_eq!(parse("--hys 0.4").ratio("hys", 0.25).unwrap(), 0.4);
        assert_eq!(parse("").ratio("hys", 0.25).unwrap(), 0.25);
        assert!(parse("--hys 1.5").ratio("hys", 0.25).is_err());
        assert!(parse("--hys nope").ratio("hys", 0.25).is_err());
    }

    #[test]
    fn compress_parsing() {
        use crate::compress::CompressSpec;
        assert_eq!(parse("").compress("compress").unwrap(), CompressSpec::None);
        assert_eq!(
            parse("--compress none").compress("compress").unwrap(),
            CompressSpec::None
        );
        assert_eq!(
            parse("--compress topk:0.01").compress("compress").unwrap(),
            CompressSpec::TopK(0.01)
        );
        assert_eq!(
            parse("--compress threshold:0.5").compress("compress").unwrap(),
            CompressSpec::Threshold(0.5)
        );
        // Named-field error messages, `--key:` prefixed like topology().
        let err = parse("--compress topk:0").compress("compress").unwrap_err();
        assert!(err.to_string().starts_with("--compress:"), "{err}");
        assert!(err.to_string().contains("topk"), "{err}");
        for bad in ["topk:-2", "threshold:-0.5", "threshold:NaN", "gzip:9"] {
            assert!(
                parse(&format!("--compress {bad}")).compress("compress").is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn accuracy_budget_parsing() {
        assert_eq!(parse("").accuracy_budget("accuracy-budget", 0.0).unwrap(), 0.0);
        assert_eq!(
            parse("--accuracy-budget 0.05")
                .accuracy_budget("accuracy-budget", 0.0)
                .unwrap(),
            0.05
        );
        for bad in ["NaN", "inf", "-0.1", "nope"] {
            let r = parse(&format!("--accuracy-budget {bad}"))
                .accuracy_budget("accuracy-budget", 0.0);
            assert!(r.is_err(), "budget '{bad}' must be rejected");
            assert!(
                r.unwrap_err().to_string().contains("--accuracy-budget"),
                "{bad}: error must name the flag"
            );
        }
    }

    #[test]
    fn topology_parsing() {
        use crate::cluster::LinkKind;
        let a = parse("--topology 4x2");
        let t = a.topology("topology", LinkKind::Tcp25).unwrap().unwrap();
        assert_eq!((t.nodes, t.ranks_per_node), (4, 2));
        assert_eq!(t.inter, LinkKind::Tcp25);
        assert!(parse("")
            .topology("topology", LinkKind::Tcp25)
            .unwrap()
            .is_none());
        assert!(parse("--topology nonsense")
            .topology("topology", LinkKind::Tcp25)
            .is_err());
    }

    #[test]
    fn transport_parsing() {
        use crate::wire::TransportKind;
        let a = parse("--transport channel");
        assert_eq!(
            a.transport("transport", TransportKind::Sim).unwrap(),
            TransportKind::Channel
        );
        assert_eq!(
            parse("").transport("transport", TransportKind::Sim).unwrap(),
            TransportKind::Sim
        );
        assert!(parse("--transport warp")
            .transport("transport", TransportKind::Sim)
            .is_err());
    }
}
