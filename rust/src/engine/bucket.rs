//! Gradient bucketing (DDP-style): pack consecutive layers into
//! size-capped buckets whose gradients travel as one concatenated
//! sparse tensor.
//!
//! Buckets follow backward-completion order, so a bucket is ready to
//! transmit as soon as its *last* member layer's gradient exists —
//! exactly how PyTorch DDP overlaps allreduce with backward. Small
//! layers amortize per-sync latency by sharing a bucket; a threshold
//! smaller than a single layer degenerates to per-layer synchronization
//! (every bucket still holds at least one layer).

use crate::tensor::CooTensor;
use crate::workload::LayerSpec;

/// A contiguous run of layers synchronized as one tensor.
#[derive(Clone, Debug)]
pub struct Bucket {
    /// Indices into the layer-spec list.
    pub layers: std::ops::Range<usize>,
    /// Offset of each member layer inside the concatenated tensor,
    /// parallel to `layers`.
    pub offsets: Vec<usize>,
    /// Dense length of the concatenated bucket tensor.
    pub dense_len: usize,
    /// Estimated wire payload of the bucket (sum of member estimates).
    pub est_bytes: usize,
    /// Fraction of backward compute done when the whole bucket is ready
    /// (the max over members = the last member, specs being ordered).
    pub ready_frac: f64,
    /// Forward-consumption rank of the bucket: the minimum
    /// [`LayerSpec::fwd_order`] over members — the bucket is needed as
    /// soon as its earliest-forward layer is. 0 = needed first in the
    /// next iteration's forward pass.
    pub priority: usize,
}

impl Bucket {
    pub fn label(&self, specs: &[LayerSpec]) -> String {
        let first = &specs[self.layers.start].name;
        if self.layers.len() == 1 {
            first.clone()
        } else {
            format!("{first}..{}", specs[self.layers.end - 1].name)
        }
    }
}

/// Greedy size-capped bucketing over layers in backward-completion
/// order. A bucket closes once its estimated payload reaches
/// `bucket_bytes`; `est_bytes[l]` is the caller's per-layer wire
/// estimate (typically the max COO payload across machines).
pub fn plan_buckets(specs: &[LayerSpec], est_bytes: &[usize], bucket_bytes: usize) -> Vec<Bucket> {
    assert_eq!(specs.len(), est_bytes.len());
    let mut buckets = Vec::new();
    let mut start = 0usize;
    let mut offsets = Vec::new();
    let mut dense_len = 0usize;
    let mut est = 0usize;
    let mut priority = usize::MAX;
    for (l, spec) in specs.iter().enumerate() {
        offsets.push(dense_len);
        dense_len += spec.params;
        est += est_bytes[l];
        priority = priority.min(spec.fwd_order);
        if est >= bucket_bytes || l + 1 == specs.len() {
            buckets.push(Bucket {
                layers: start..l + 1,
                offsets: std::mem::take(&mut offsets),
                dense_len,
                est_bytes: est,
                ready_frac: spec.ready_frac,
                priority,
            });
            start = l + 1;
            dense_len = 0;
            est = 0;
            priority = usize::MAX;
        }
    }
    buckets
}

/// One independently schedulable slice of a bucket: the dense index
/// range `lo..hi` of piece `piece` out of `pieces`. Oversized buckets
/// are partitioned so a huge tensor does not monopolize the link
/// (tensor partitioning à la ByteScheduler); every piece shares its
/// bucket's ready time and forward priority, and the pieces' outputs
/// are re-concatenated before layer splitting, so partitioning can
/// never change synchronized values — only the timeline.
#[derive(Clone, Debug)]
pub struct BucketPiece {
    /// Index into the bucket list.
    pub bucket: usize,
    /// This piece's ordinal within the bucket (0-based).
    pub piece: usize,
    /// Total pieces the bucket was split into (1 = not split).
    pub pieces: usize,
    /// Dense-range start within the bucket tensor (inclusive).
    pub lo: u32,
    /// Dense-range end within the bucket tensor (exclusive).
    pub hi: u32,
}

impl BucketPiece {
    /// `"label[piece/pieces]"` for split buckets, the plain bucket
    /// label otherwise — keeps single-piece runs byte-identical to
    /// the pre-partitioning engine output.
    pub fn label(&self, bucket: &Bucket, specs: &[LayerSpec]) -> String {
        let base = bucket.label(specs);
        if self.pieces == 1 {
            base
        } else {
            format!("{base}[{}/{}]", self.piece, self.pieces)
        }
    }
}

/// Split every bucket whose estimated payload exceeds
/// `partition_bytes` into `ceil(est_bytes / partition_bytes)` equal
/// dense-range pieces (capped at one piece per dense element). With
/// `partition_bytes == usize::MAX` (the default) every bucket stays
/// whole. Pieces are emitted in bucket order, then piece order — the
/// same backward-completion order the scheduler's submission index
/// ties break on.
pub fn partition_pieces(buckets: &[Bucket], partition_bytes: usize) -> Vec<BucketPiece> {
    let mut out = Vec::with_capacity(buckets.len());
    for (bi, b) in buckets.iter().enumerate() {
        let k = if b.est_bytes > partition_bytes {
            crate::util::ceil_div(b.est_bytes, partition_bytes.max(1)).min(b.dense_len.max(1))
        } else {
            1
        };
        for p in 0..k {
            out.push(BucketPiece {
                bucket: bi,
                piece: p,
                pieces: k,
                lo: (p * b.dense_len / k) as u32,
                hi: ((p + 1) * b.dense_len / k) as u32,
            });
        }
    }
    out
}

/// Concatenate one machine's member-layer tensors into the bucket
/// tensor (indices shifted by the member offsets).
pub fn concat_layers(bucket: &Bucket, layer_tensors: &[CooTensor]) -> CooTensor {
    let mut indices = Vec::new();
    let mut values = Vec::new();
    for (slot, l) in bucket.layers.clone().enumerate() {
        let off = bucket.offsets[slot] as u32;
        let t = &layer_tensors[l];
        indices.extend(t.indices.iter().map(|&i| i + off));
        values.extend_from_slice(&t.values);
    }
    CooTensor::from_sorted(bucket.dense_len, indices, values)
}

/// Split an aggregated bucket tensor back into per-layer tensors
/// (inverse of [`concat_layers`]). `specs` supplies per-layer lengths.
pub fn split_layers(bucket: &Bucket, specs: &[LayerSpec], t: &CooTensor) -> Vec<CooTensor> {
    assert_eq!(t.dense_len, bucket.dense_len);
    bucket
        .layers
        .clone()
        .enumerate()
        .map(|(slot, l)| {
            let lo = bucket.offsets[slot] as u32;
            let hi = lo + specs[l].params as u32;
            t.slice_range(lo, hi)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::LayerKind;

    fn spec(name: &str, params: usize, frac: f64) -> LayerSpec {
        LayerSpec {
            name: name.into(),
            params,
            kind: LayerKind::Dense,
            ready_frac: frac,
            fwd_order: 0,
        }
    }

    fn specs3() -> Vec<LayerSpec> {
        vec![
            spec("a", 10, 0.25),
            spec("b", 20, 0.50),
            spec("c", 5, 1.00),
        ]
    }

    #[test]
    fn huge_threshold_gives_single_bucket() {
        let s = specs3();
        let b = plan_buckets(&s, &[80, 160, 40], usize::MAX);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].layers, 0..3);
        assert_eq!(b[0].dense_len, 35);
        assert_eq!(b[0].offsets, vec![0, 10, 30]);
        assert!((b[0].ready_frac - 1.0).abs() < 1e-12);
    }

    #[test]
    fn tiny_threshold_gives_per_layer_buckets() {
        let s = specs3();
        let b = plan_buckets(&s, &[80, 160, 40], 1);
        assert_eq!(b.len(), 3);
        for (i, bk) in b.iter().enumerate() {
            assert_eq!(bk.layers, i..i + 1);
            assert_eq!(bk.offsets, vec![0]);
            assert_eq!(bk.dense_len, s[i].params);
        }
    }

    #[test]
    fn threshold_packs_greedily() {
        let s = specs3();
        // 80 + 160 crosses 200 → close; c alone in the tail bucket.
        let b = plan_buckets(&s, &[80, 160, 40], 200);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].layers, 0..2);
        assert_eq!(b[0].est_bytes, 240);
        assert_eq!(b[1].layers, 2..3);
        assert!((b[0].ready_frac - 0.5).abs() < 1e-12);
    }

    #[test]
    fn buckets_partition_all_layers() {
        let s: Vec<LayerSpec> = (0..17)
            .map(|i| spec(&format!("l{i}"), i + 1, (i + 1) as f64 / 17.0))
            .collect();
        let est: Vec<usize> = s.iter().map(|x| x.params * 8).collect();
        let b = plan_buckets(&s, &est, 50);
        let mut covered = Vec::new();
        for bk in &b {
            covered.extend(bk.layers.clone());
        }
        assert_eq!(covered, (0..17).collect::<Vec<_>>());
    }

    #[test]
    fn concat_split_roundtrip() {
        let s = specs3();
        let b = plan_buckets(&s, &[1, 1, 1], usize::MAX);
        let layers = vec![
            CooTensor::from_sorted(10, vec![2, 9], vec![1.0, 2.0]),
            CooTensor::from_sorted(20, vec![0, 19], vec![3.0, 4.0]),
            CooTensor::empty(5),
        ];
        let cat = concat_layers(&b[0], &layers);
        assert_eq!(cat.indices, vec![2, 9, 10, 29]);
        let back = split_layers(&b[0], &s, &cat);
        assert_eq!(back, layers);
    }

    #[test]
    fn bucket_priority_is_min_member_fwd_order() {
        // Backward order a, b, c; forward needs c first (fwd_order 0).
        let mut s = specs3();
        s[0].fwd_order = 2;
        s[1].fwd_order = 1;
        s[2].fwd_order = 0;
        let b = plan_buckets(&s, &[80, 160, 40], 200);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].priority, 1, "min over members a,b");
        assert_eq!(b[1].priority, 0);
        let single = plan_buckets(&s, &[80, 160, 40], usize::MAX);
        assert_eq!(single[0].priority, 0);
    }

    #[test]
    fn max_threshold_keeps_buckets_whole() {
        let s = specs3();
        let b = plan_buckets(&s, &[80, 160, 40], usize::MAX);
        let pieces = partition_pieces(&b, usize::MAX);
        assert_eq!(pieces.len(), 1);
        assert_eq!(pieces[0].pieces, 1);
        assert_eq!((pieces[0].lo, pieces[0].hi), (0, 35));
        assert_eq!(pieces[0].label(&b[0], &s), b[0].label(&s));
    }

    #[test]
    fn partition_splits_oversized_buckets_evenly() {
        let s = specs3();
        let b = plan_buckets(&s, &[80, 160, 40], usize::MAX);
        assert_eq!(b[0].est_bytes, 280);
        // 280 bytes over a 100-byte threshold → ceil(280/100) = 3 pieces
        let pieces = partition_pieces(&b, 100);
        assert_eq!(pieces.len(), 3);
        // pieces tile 0..35 contiguously without gaps or overlap
        assert_eq!(pieces[0].lo, 0);
        assert_eq!(pieces.last().unwrap().hi, 35);
        for w in pieces.windows(2) {
            assert_eq!(w[0].hi, w[1].lo);
        }
        for (p, pc) in pieces.iter().enumerate() {
            assert_eq!(pc.bucket, 0);
            assert_eq!(pc.piece, p);
            assert_eq!(pc.pieces, 3);
            assert!(pc.lo < pc.hi, "no empty pieces at this size");
        }
        assert_eq!(pieces[1].label(&b[0], &s), "a..c[1/3]");
    }

    #[test]
    fn partition_caps_pieces_at_dense_len() {
        // A 5-element bucket with a huge payload estimate cannot split
        // into more than 5 pieces.
        let s = vec![spec("t", 5, 1.0)];
        let b = plan_buckets(&s, &[10_000], usize::MAX);
        let pieces = partition_pieces(&b, 1);
        assert_eq!(pieces.len(), 5);
        for (p, pc) in pieces.iter().enumerate() {
            assert_eq!((pc.lo, pc.hi), (p as u32, p as u32 + 1));
        }
    }

    #[test]
    fn zero_param_layer_is_harmless() {
        let s = vec![spec("empty", 0, 0.5), spec("tail", 4, 1.0)];
        let b = plan_buckets(&s, &[0, 32], usize::MAX);
        assert_eq!(b.len(), 1);
        let layers = vec![
            CooTensor::empty(0),
            CooTensor::from_sorted(4, vec![1], vec![5.0]),
        ];
        let cat = concat_layers(&b[0], &layers);
        assert_eq!(cat.indices, vec![1]);
        let back = split_layers(&b[0], &s, &cat);
        assert_eq!(back, layers);
    }
}
