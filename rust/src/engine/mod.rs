//! Multi-tensor synchronization engine: bucketing + compute/communication
//! overlap on top of any [`crate::schemes::SyncScheme`], with scheme
//! choice delegated to a [`Planner`] per bucket.
//!
//! The schemes in [`crate::schemes`] synchronize *one* tensor with one
//! blocking `sync()` call. Real models have many gradient tensors that
//! become available one by one as the backward pass walks output → input
//! (the DAG model of synchronous SGD), and production data-parallel
//! stacks (PyTorch DDP, Ok-Topk's pipelined sparse allreduce) exploit
//! that: small tensors are packed into size-capped **buckets**, and a
//! bucket's communication starts as soon as its backward slice finishes
//! — overlapping communication with the remainder of the backward pass.
//!
//! [`SyncEngine`] reproduces that pipeline in virtual time:
//!
//! 1. [`bucket::plan_buckets`] packs the per-layer gradients
//!    ([`crate::workload::LayerSpec`]) into buckets up to a configurable
//!    byte threshold;
//! 2. every bucket asks the [`Planner`] which scheme to run — a
//!    [`crate::planner::FixedPlanner`] reproduces the classic
//!    one-scheme-everywhere behavior, a
//!    [`crate::planner::CostPlanner`] (`--scheme auto`) picks the
//!    cost-model argmin per bucket from its measured sparsity — then
//!    synchronizes with the *same* scheme protocol the single-tensor
//!    path uses (bucket-level reuse — Zen, AllReduce, SparCML, … all
//!    work unchanged), concurrently on a [`crate::util::ThreadPool`],
//!    over the data plane selected by
//!    [`EngineConfig::transport`] (virtual-time sim, real-frames
//!    channel, or the loopback socket mesh);
//! 3. a [`Timeline`] charges virtual time twice: **serialized** (compute,
//!    then every bucket in turn — the one-blocking-`sync()` baseline)
//!    and **overlapped** (bucket *k*'s communication may start at
//!    `compute_time × ready_frac_k`, buckets share the link in order —
//!    per link *class* under the event driver, so intra-node and fabric
//!    traffic of different buckets pipeline past each other).
//!
//! The spread between the two is the pipelining win the engine exists to
//! measure; `benches/bench_engine.rs` sweeps it over schemes × models.

pub mod bucket;

pub use bucket::{partition_pieces, plan_buckets, Bucket, BucketPiece};

use crate::cluster::{ClassedJob, CommReport, Network, Timeline, TimelineJob};
use crate::planner::Planner;
use crate::schemes::{SyncScheme, SyncScratch};
use crate::tensor::{CooTensor, WireFormat};
use crate::util::{ScratchPool, ThreadPool};
use crate::wire::TransportKind;
use crate::workload::LayerSpec;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Bucket close threshold in estimated wire bytes (DDP's
    /// `bucket_cap_mb` analog). `usize::MAX` → one bucket for the whole
    /// model; `0`/smaller-than-a-layer → one bucket per layer.
    pub bucket_bytes: usize,
    /// Modeled backward-pass time for one iteration (virtual seconds);
    /// layer readiness is `compute_time × ready_frac`.
    pub compute_time: f64,
    /// Data plane every bucket sync runs over: the virtual-time
    /// simulator (default), the real-frames channel fabric, or the
    /// readiness-polled loopback socket mesh. Each in-flight bucket
    /// gets its own driver instance — cheap for sim/channel; the socket
    /// driver opens a fresh mesh per bucket, so prefer the flat
    /// (`SimDriver`) path for socket runs.
    pub transport: TransportKind,
    /// First-needed-first link scheduling (ByteScheduler-style): when a
    /// backlog of ready buckets forms, transmit the one the *next*
    /// iteration's forward pass consumes earliest instead of FIFO
    /// backward order. Never changes synchronized values or (single
    /// link) the makespan — it improves [`EngineRun::forward_finish`].
    pub priority_schedule: bool,
    /// Tensor-partitioning threshold in estimated wire bytes: a bucket
    /// whose payload estimate exceeds this splits into
    /// `ceil(est / partition_bytes)` independently scheduled pieces so
    /// one huge tensor cannot monopolize the link. `usize::MAX`
    /// (default) disables partitioning.
    pub partition_bytes: usize,
    /// Modeled forward-pass time of the *next* iteration (virtual
    /// seconds), distributed over buckets by parameter share; feeds
    /// [`crate::cluster::Timeline::forward_finish`]. Defaults to
    /// `compute_time / 2` (backward ≈ 2× forward).
    pub forward_time: f64,
}

impl EngineConfig {
    pub fn new(bucket_bytes: usize, compute_time: f64) -> Self {
        assert!(compute_time >= 0.0);
        EngineConfig {
            bucket_bytes,
            compute_time,
            transport: TransportKind::Sim,
            priority_schedule: false,
            partition_bytes: usize::MAX,
            forward_time: compute_time * 0.5,
        }
    }

    /// Start a validating builder (errors at `build()` instead of
    /// panicking mid-construction).
    pub fn builder() -> EngineConfigBuilder {
        EngineConfigBuilder::default()
    }

    /// Select the data plane (builder style).
    pub fn with_transport(mut self, transport: TransportKind) -> Self {
        self.transport = transport;
        self
    }

    /// Enable/disable first-needed-first scheduling (builder style).
    pub fn with_priority(mut self, priority_schedule: bool) -> Self {
        self.priority_schedule = priority_schedule;
        self
    }

    /// Set the tensor-partitioning threshold (builder style).
    pub fn with_partition_bytes(mut self, partition_bytes: usize) -> Self {
        self.partition_bytes = partition_bytes;
        self
    }

    /// Set the modeled next-iteration forward time (builder style).
    pub fn with_forward_time(mut self, forward_time: f64) -> Self {
        assert!(forward_time.is_finite() && forward_time >= 0.0);
        self.forward_time = forward_time;
        self
    }
}

/// Validating builder for [`EngineConfig`]: all checks run at
/// [`build`](EngineConfigBuilder::build), returning `Err` with every
/// violated constraint instead of panicking.
#[derive(Clone, Debug)]
pub struct EngineConfigBuilder {
    bucket_bytes: usize,
    compute_time: f64,
    transport: TransportKind,
    priority_schedule: bool,
    partition_bytes: usize,
    /// `None` → derive `compute_time / 2` at build time.
    forward_time: Option<f64>,
}

impl Default for EngineConfigBuilder {
    fn default() -> Self {
        EngineConfigBuilder {
            bucket_bytes: usize::MAX,
            compute_time: 0.0,
            transport: TransportKind::Sim,
            priority_schedule: false,
            partition_bytes: usize::MAX,
            forward_time: None,
        }
    }
}

impl EngineConfigBuilder {
    /// Bucket close threshold in estimated wire bytes.
    pub fn bucket_bytes(mut self, bytes: usize) -> Self {
        self.bucket_bytes = bytes;
        self
    }

    /// Modeled backward-pass time (virtual seconds).
    pub fn compute_time(mut self, seconds: f64) -> Self {
        self.compute_time = seconds;
        self
    }

    /// Data plane every bucket sync runs over.
    pub fn transport(mut self, transport: TransportKind) -> Self {
        self.transport = transport;
        self
    }

    /// First-needed-first link scheduling.
    pub fn priority_schedule(mut self, enabled: bool) -> Self {
        self.priority_schedule = enabled;
        self
    }

    /// Tensor-partitioning threshold in estimated wire bytes.
    pub fn partition_bytes(mut self, bytes: usize) -> Self {
        self.partition_bytes = bytes;
        self
    }

    /// Modeled next-iteration forward time (virtual seconds); unset →
    /// `compute_time / 2`.
    pub fn forward_time(mut self, seconds: f64) -> Self {
        self.forward_time = Some(seconds);
        self
    }

    pub fn build(self) -> Result<EngineConfig, String> {
        let mut problems = Vec::new();
        if !self.compute_time.is_finite() || self.compute_time < 0.0 {
            problems.push(format!(
                "compute_time must be finite and >= 0, got {}",
                self.compute_time
            ));
        }
        if let Some(fwd) = self.forward_time {
            if !fwd.is_finite() || fwd < 0.0 {
                problems.push(format!("forward_time must be finite and >= 0, got {fwd}"));
            }
        }
        if !problems.is_empty() {
            return Err(problems.join("; "));
        }
        Ok(EngineConfig {
            bucket_bytes: self.bucket_bytes,
            compute_time: self.compute_time,
            transport: self.transport,
            priority_schedule: self.priority_schedule,
            partition_bytes: self.partition_bytes,
            forward_time: self.forward_time.unwrap_or(self.compute_time * 0.5),
        })
    }
}

/// Per-bucket outcome of one engine run.
#[derive(Clone, Debug)]
pub struct BucketOutcome {
    pub label: String,
    /// Indices into the layer-spec list.
    pub layers: std::ops::Range<usize>,
    /// Display name of the scheme the planner chose for this bucket.
    pub scheme: &'static str,
    /// The full plan behind the choice (ranked costs, measured stats,
    /// bandwidth/latency split for rescaling); `None` under a fixed
    /// planner.
    pub plan: Option<std::sync::Arc<crate::planner::BucketPlan>>,
    /// Cost-model prediction for this bucket at engine scale (seconds);
    /// `None` under a fixed planner (nothing was predicted).
    pub predicted_time: Option<f64>,
    /// Whether this run computed a fresh plan for the bucket (warm-up /
    /// post-drift) rather than serving the planner's cache.
    pub replanned: bool,
    /// Bytes this bucket's sync put on the network.
    pub bytes: u64,
    /// Virtual communication time charged for this bucket (through the
    /// caller's `time_of` rescaling).
    pub comm_time: f64,
    /// Transport-measured virtual time at engine scale — the number the
    /// cost-model prediction is judged against
    /// ([`BucketOutcome::misprediction`]).
    pub raw_comm_time: f64,
    /// Full communication report from the scheme.
    pub report: CommReport,
}

impl BucketOutcome {
    /// Transport-measured / predicted time at engine scale: > 1 means
    /// the cost model was optimistic, < 1 pessimistic, `None` under a
    /// fixed planner.
    pub fn misprediction(&self) -> Option<f64> {
        crate::planner::misprediction_ratio(self.raw_comm_time, self.predicted_time)
    }
}

/// Result of synchronizing a whole model's gradient tensors.
#[derive(Clone, Debug)]
pub struct EngineRun {
    pub buckets: Vec<BucketOutcome>,
    /// The overlapped schedule (per-bucket ready/start/finish).
    pub timeline: Timeline,
    /// Iteration time without overlap: compute + Σ bucket comm.
    pub serialized_time: f64,
    /// Iteration time with overlap: the pipeline makespan.
    pub overlapped_time: f64,
    /// Total bytes on the network across all buckets.
    pub total_bytes: u64,
    /// Aggregated per-layer gradients (identical at every machine).
    pub layer_outputs: Vec<CooTensor>,
    /// Wall-clock seconds the engine spent executing bucket syncs.
    pub wall_time: f64,
    /// Virtual time at which the *next* iteration's forward pass
    /// completes ([`Timeline::forward_finish`]) — the metric priority
    /// scheduling improves when the makespan cannot move.
    pub forward_finish: f64,
}

impl EngineRun {
    /// Serialized / overlapped — ≥ 1, the pipelining win.
    pub fn speedup(&self) -> f64 {
        if self.overlapped_time == 0.0 {
            1.0
        } else {
            self.serialized_time / self.overlapped_time
        }
    }
}

/// The pipelined multi-tensor synchronization engine.
pub struct SyncEngine {
    pub cfg: EngineConfig,
    pool: ThreadPool,
    /// Per-bucket sync scratch: each in-flight bucket checks out its own
    /// [`SyncScratch`], so concurrent bucket syncs never contend on (or
    /// corrupt) shared working memory, and iterating callers reuse the
    /// warmed buffers across `run` calls — the engine-level piece of the
    /// scratch-arena layer.
    scratch: ScratchPool<SyncScratch>,
    /// Bucket plan frozen after the first `run` (keyed by a spec-list
    /// fingerprint), exactly like DDP rebuilds its buckets once. Without
    /// this, per-iteration wire-size estimates oscillating around the
    /// byte threshold would flip bucket boundaries — and with them the
    /// labels the [`Planner`] keys its cache on, silently degrading
    /// O(warm-up) profiling to O(iterations).
    buckets: std::sync::Mutex<Option<(Vec<(String, usize)>, Vec<Bucket>)>>,
}

impl SyncEngine {
    pub fn new(cfg: EngineConfig) -> Self {
        // Bucket syncs are themselves internally parallel (Zen's hasher
        // runs on its own pool), so cap the outer fan-out at a few
        // concurrent buckets to avoid core oversubscription while still
        // overlapping bucket work. Override with `with_pool`.
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        SyncEngine {
            cfg,
            pool: ThreadPool::with_workers(cores.min(4)),
            scratch: ScratchPool::new(),
            buckets: std::sync::Mutex::new(None),
        }
    }

    /// Override the worker pool (tests / perf studies).
    pub fn with_pool(mut self, pool: ThreadPool) -> Self {
        self.pool = pool;
        self
    }

    /// Synchronize one iteration's per-layer gradients.
    ///
    /// `per_worker_layers[w][l]` is machine `w`'s gradient for layer `l`
    /// (see [`crate::workload::GradientGen::layer_iteration_all`]);
    /// `planner` chooses each bucket's scheme (wrap a single scheme in
    /// [`crate::planner::FixedPlanner`] for the classic behavior);
    /// `time_of` converts a bucket's [`CommReport`] into virtual seconds
    /// (identity: `|r| r.comm_time()`; the simulator passes its
    /// full-model rescaling instead).
    pub fn run<F>(
        &self,
        specs: &[LayerSpec],
        per_worker_layers: &[Vec<CooTensor>],
        planner: &dyn Planner,
        net: &Network,
        time_of: F,
    ) -> EngineRun
    where
        F: Fn(&CommReport) -> f64 + Sync,
    {
        let n = per_worker_layers.len();
        assert!(n >= 1, "need at least one machine");
        assert_eq!(n, net.endpoints);
        for worker in per_worker_layers {
            assert_eq!(worker.len(), specs.len(), "one tensor per layer");
        }
        for spec in specs {
            assert!(
                spec.ready_frac > 0.0 && spec.ready_frac <= 1.0,
                "layer '{}': ready_frac {} outside (0, 1]",
                spec.name,
                spec.ready_frac
            );
        }

        // Bucket plan, frozen on first use for this spec list (DDP
        // semantics: buckets are built once, from the first iteration's
        // sizes). Stable buckets mean stable labels, which is what lets
        // a cost planner's per-label cache stay O(warm-up).
        let matches_specs = |fp: &[(String, usize)]| {
            fp.len() == specs.len()
                && fp
                    .iter()
                    .zip(specs.iter())
                    .all(|((name, params), sp)| *name == sp.name && *params == sp.params)
        };
        let buckets = {
            let mut cached = self.buckets.lock().unwrap();
            match cached.as_ref() {
                Some((fp, b)) if matches_specs(fp) => b.clone(),
                _ => {
                    // Per-layer wire estimate: the largest COO payload
                    // any machine would ship for that layer (drives
                    // bucket packing only).
                    let est_bytes: Vec<usize> = (0..specs.len())
                        .map(|l| {
                            per_worker_layers
                                .iter()
                                .map(|w| w[l].wire_bytes())
                                .max()
                                .unwrap_or(0)
                        })
                        .collect();
                    let b = plan_buckets(specs, &est_bytes, self.cfg.bucket_bytes);
                    let fingerprint: Vec<(String, usize)> = specs
                        .iter()
                        .map(|sp| (sp.name.clone(), sp.params))
                        .collect();
                    *cached = Some((fingerprint, b.clone()));
                    b
                }
            }
        };

        // Tensor partitioning: oversized buckets split into
        // independently scheduled dense-range pieces; with the default
        // `partition_bytes == usize::MAX` every bucket is one piece and
        // this whole layer is the identity.
        let pieces = bucket::partition_pieces(&buckets, self.cfg.partition_bytes);
        let total_params: usize = buckets.iter().map(|b| b.dense_len).sum();

        // Concatenate each machine's member layers once per bucket
        // (sequential — cheap next to the syncs); pieces slice these.
        let bucket_inputs: Vec<Vec<CooTensor>> = buckets
            .iter()
            .map(|b| {
                per_worker_layers
                    .iter()
                    .map(|w| bucket::concat_layers(b, w))
                    .collect()
            })
            .collect();

        // Plan and synchronize every piece, concurrently. The planner
        // sees each piece's actual per-machine tensors (cost planners
        // measure them; cached plans make that O(warm-up)); each
        // in-flight piece runs over its own transport instance of the
        // configured backend (transports are single-sync state).
        let sw = crate::util::Stopwatch::start();
        type Synced = (
            BucketPiece,
            crate::planner::PlannedSync,
            crate::schemes::SyncOutput,
        );
        let synced: Vec<Synced> = self.pool.map(pieces, |pc| {
            let b = &buckets[pc.bucket];
            let inputs: Vec<CooTensor> = if pc.pieces == 1 {
                bucket_inputs[pc.bucket].clone()
            } else {
                bucket_inputs[pc.bucket]
                    .iter()
                    .map(|t| t.slice_range(pc.lo, pc.hi))
                    .collect()
            };
            let label = pc.label(b, specs);
            let planned = planner.plan(&label, &inputs, &net.topo);
            let mut scratch = self.scratch.acquire();
            let mut driver =
                crate::wire::make_driver(self.cfg.transport, net).expect("engine driver setup");
            // The engine owns every endpoint of its in-process data
            // planes, so a mid-sync wire error here is unrecoverable
            // state, not a flaky peer — fail loudly with the bucket
            // context.
            let result = planned
                .scheme
                .run(&inputs, driver.as_mut(), &mut scratch)
                .unwrap_or_else(|e| {
                    panic!(
                        "bucket '{label}' sync failed on the {} data plane: {e}",
                        self.cfg.transport.name()
                    )
                });
            (pc, planned, result)
        });
        let wall_time = sw.elapsed();

        // Charge virtual time and build the overlap schedule. Under the
        // event driver the overlap model is classed link-busy intervals
        // (buckets on disjoint link classes pipeline past each other);
        // every other backend keeps the single shared-link queue.
        let classed = self.cfg.transport == TransportKind::Event;
        let mut outcomes = Vec::with_capacity(synced.len());
        let mut jobs = Vec::with_capacity(synced.len());
        let mut classed_jobs = Vec::with_capacity(if classed { synced.len() } else { 0 });
        let mut piece_outs: Vec<Vec<(u32, CooTensor)>> = vec![Vec::new(); buckets.len()];
        let mut total_bytes = 0u64;
        for (pc, planned, result) in synced {
            let b = &buckets[pc.bucket];
            let crate::schemes::SyncOutput { outputs, report } = result;
            let comm_time = time_of(&report);
            let bytes = report.total_bytes();
            total_bytes += bytes;
            let label = pc.label(b, specs);
            // Next-forward compute share of this piece's parameters —
            // what forward_finish charges once the piece has synced.
            let fwd_duration = if total_params == 0 {
                0.0
            } else {
                self.cfg.forward_time * (pc.hi - pc.lo) as f64 / total_params as f64
            };
            jobs.push(TimelineJob {
                label: label.clone(),
                ready: self.cfg.compute_time * b.ready_frac,
                duration: comm_time,
                bytes,
                priority: b.priority,
                fwd_duration,
            });
            if classed {
                // Split the (possibly `time_of`-rescaled) duration over
                // the link classes in the report's own proportions so
                // the classed schedule and the caller's rescaling agree.
                let raw = report.comm_time();
                let scale = if raw > 0.0 { comm_time / raw } else { 0.0 };
                let per_class = report.time_by_class();
                classed_jobs.push(ClassedJob {
                    label: label.clone(),
                    ready: self.cfg.compute_time * b.ready_frac,
                    durations: [per_class[0] * scale, per_class[1] * scale],
                    bytes,
                    priority: b.priority,
                    fwd_duration,
                });
            }
            // Every endpoint holds the same aggregate; keep machine 0's
            // copy for reassembly into per-layer outputs below.
            piece_outs[pc.bucket].push((
                pc.lo,
                outputs.into_iter().next().expect("scheme output per machine"),
            ));
            outcomes.push(BucketOutcome {
                label,
                layers: b.layers.clone(),
                scheme: planned.scheme.name(),
                predicted_time: planned.plan.as_ref().map(|p| p.predicted_time),
                plan: planned.plan,
                replanned: planned.replanned,
                bytes,
                comm_time,
                raw_comm_time: report.comm_time(),
                report,
            });
        }

        // Reassemble each bucket's aggregate from its pieces (identity
        // for unsplit buckets) and unbucket into per-layer outputs.
        let mut layer_outputs: Vec<Option<CooTensor>> = vec![None; specs.len()];
        for (b, parts) in buckets.iter().zip(piece_outs) {
            let full = if parts.len() == 1 {
                parts.into_iter().next().unwrap().1
            } else {
                CooTensor::concat_ranges(&parts, b.dense_len)
            };
            for (l, t) in b.layers.clone().zip(bucket::split_layers(b, specs, &full)) {
                layer_outputs[l] = Some(t);
            }
        }

        let timeline = match (classed, self.cfg.priority_schedule) {
            (true, true) => Timeline::schedule_classed_priority(self.cfg.compute_time, &classed_jobs),
            (true, false) => Timeline::schedule_classed(self.cfg.compute_time, &classed_jobs),
            (false, true) => Timeline::schedule_priority(self.cfg.compute_time, &jobs),
            (false, false) => Timeline::schedule(self.cfg.compute_time, &jobs),
        };
        let serialized_time = timeline.serialized_time();
        let overlapped_time = timeline.overlapped_time();
        let forward_finish = timeline.forward_finish();

        EngineRun {
            buckets: outcomes,
            timeline,
            serialized_time,
            overlapped_time,
            total_bytes,
            layer_outputs: layer_outputs.into_iter().map(|t| t.unwrap()).collect(),
            wall_time,
            forward_finish,
        }
    }
}

/// Assert every per-layer engine output equals the dense reference sum
/// of that layer's inputs (the engine-level analog of
/// [`crate::schemes::verify_outputs`]).
pub fn verify_layer_outputs(run: &EngineRun, per_worker_layers: &[Vec<CooTensor>]) {
    for (l, out) in run.layer_outputs.iter().enumerate() {
        let inputs: Vec<CooTensor> = per_worker_layers.iter().map(|w| w[l].clone()).collect();
        let reference = crate::schemes::reference_sum(&inputs);
        crate::schemes::assert_matches_reference(out, &reference, &format!("layer {l}"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::LinkKind;
    use crate::planner::{CostPlanner, FixedPlanner, PlanConfig};
    use crate::schemes;
    use crate::workload::{profiles, GradientGen};

    fn small_gen() -> GradientGen {
        GradientGen::new(profiles::by_name("NMT").unwrap().scaled(1024), 0xe6)
    }

    fn fixed(scheme_name: &str, machines: usize, expected_nnz: usize) -> FixedPlanner {
        FixedPlanner::new(schemes::by_name(scheme_name, machines, 0x5eed, expected_nnz).unwrap())
    }

    fn run_engine(
        scheme_name: &str,
        machines: usize,
        bucket_bytes: usize,
        compute: f64,
    ) -> (EngineRun, Vec<Vec<CooTensor>>) {
        let gen = small_gen();
        let specs = gen.layer_specs(3, 4);
        let layers = gen.layer_iteration_all(&specs, 0, machines);
        let planner = fixed(scheme_name, machines, gen.expected_nnz().max(64));
        let net = Network::new(machines, LinkKind::Tcp25);
        let engine = SyncEngine::new(EngineConfig::new(bucket_bytes, compute));
        let run = engine.run(&specs, &layers, &planner, &net, |r| r.comm_time());
        (run, layers)
    }

    #[test]
    fn engine_aggregates_exactly_per_layer() {
        for scheme in ["zen", "allreduce", "sparcml", "omnireduce"] {
            let (run, layers) = run_engine(scheme, 4, 64 * 1024, 0.05);
            verify_layer_outputs(&run, &layers);
        }
    }

    #[test]
    fn overlapped_strictly_below_serialized() {
        // ≥ 2 buckets and the first one ready before compute ends →
        // strict pipelining win for any scheme.
        for scheme in ["zen", "allreduce"] {
            let (run, _) = run_engine(scheme, 4, 16 * 1024, 0.05);
            assert!(run.buckets.len() >= 2, "want multiple buckets");
            assert!(
                run.overlapped_time < run.serialized_time,
                "{scheme}: overlapped {} !< serialized {}",
                run.overlapped_time,
                run.serialized_time
            );
            assert!(run.speedup() > 1.0);
        }
    }

    #[test]
    fn single_bucket_matches_flat_sync_time() {
        // One bucket for the whole model: serialized == compute + one
        // sync of the concatenated tensor.
        let (run, _) = run_engine("zen", 4, usize::MAX, 0.05);
        assert_eq!(run.buckets.len(), 1);
        let total_comm: f64 = run.buckets.iter().map(|b| b.comm_time).sum();
        assert!((run.serialized_time - (0.05 + total_comm)).abs() < 1e-12);
        // a lone bucket ready at compute end cannot overlap
        assert!((run.overlapped_time - run.serialized_time).abs() < 1e-12);
    }

    #[test]
    fn per_layer_buckets_when_threshold_tiny() {
        let (run, layers) = run_engine("zen", 4, 1, 0.05);
        let num_layers = layers[0].len();
        assert_eq!(run.buckets.len(), num_layers);
        verify_layer_outputs(&run, &layers);
    }

    #[test]
    fn single_machine_is_trivial_but_exact() {
        let (run, layers) = run_engine("zen", 1, 32 * 1024, 0.05);
        verify_layer_outputs(&run, &layers);
        assert_eq!(run.total_bytes, 0, "one machine moves nothing");
        assert!((run.overlapped_time - 0.05).abs() < 1e-12);
    }

    #[test]
    fn channel_transport_equals_sim_per_bucket() {
        // The engine's transport selector: running every bucket sync
        // over real frames must reproduce the simulator's outputs and
        // byte accounting exactly.
        let gen = small_gen();
        let specs = gen.layer_specs(3, 4);
        let layers = gen.layer_iteration_all(&specs, 0, 4);
        let planner = fixed("zen", 4, gen.expected_nnz().max(64));
        let net = Network::new(4, LinkKind::Tcp25);
        let sim = SyncEngine::new(EngineConfig::new(16 * 1024, 0.05)).run(
            &specs,
            &layers,
            &planner,
            &net,
            |r| r.comm_time(),
        );
        let chan_cfg =
            EngineConfig::new(16 * 1024, 0.05).with_transport(crate::wire::TransportKind::Channel);
        let chan =
            SyncEngine::new(chan_cfg).run(&specs, &layers, &planner, &net, |r| r.comm_time());
        assert_eq!(sim.total_bytes, chan.total_bytes);
        assert_eq!(sim.buckets.len(), chan.buckets.len());
        for (a, b) in sim.buckets.iter().zip(chan.buckets.iter()) {
            assert_eq!(a.bytes, b.bytes, "bucket {}", a.label);
        }
        verify_layer_outputs(&chan, &layers);
    }

    #[test]
    fn event_driver_engine_matches_sim_and_reduces_flat() {
        // Buckets synced over the discrete-event driver must reproduce
        // the simulator's outputs, bytes, and per-bucket α–β comm times
        // exactly; on a flat network every bucket is inter-only, so the
        // classed link-busy schedule reduces to the shared-link queue
        // and the overlapped makespans coincide too.
        let gen = small_gen();
        let specs = gen.layer_specs(3, 4);
        let layers = gen.layer_iteration_all(&specs, 0, 4);
        let planner = fixed("zen", 4, gen.expected_nnz().max(64));
        let net = Network::new(4, LinkKind::Tcp25);
        let sim = SyncEngine::new(EngineConfig::new(16 * 1024, 0.05)).run(
            &specs,
            &layers,
            &planner,
            &net,
            |r| r.comm_time(),
        );
        let ev_cfg =
            EngineConfig::new(16 * 1024, 0.05).with_transport(crate::wire::TransportKind::Event);
        let ev = SyncEngine::new(ev_cfg).run(&specs, &layers, &planner, &net, |r| r.comm_time());
        assert_eq!(sim.total_bytes, ev.total_bytes);
        assert_eq!(sim.buckets.len(), ev.buckets.len());
        for (a, b) in sim.buckets.iter().zip(ev.buckets.iter()) {
            assert_eq!(a.bytes, b.bytes, "bucket {}", a.label);
            assert_eq!(a.comm_time, b.comm_time, "bucket {}", a.label);
        }
        verify_layer_outputs(&ev, &layers);
        assert_eq!(sim.serialized_time, ev.serialized_time);
        assert_eq!(sim.overlapped_time, ev.overlapped_time);
    }

    #[test]
    fn auto_planner_mixes_schemes_per_bucket() {
        // Per-layer buckets over a model with one fully dense head layer
        // and sparse embedding shards: the cost planner must pick the
        // ring allreduce for the dense bucket and a sparse scheme for
        // the embedding buckets — the heterogeneity a fixed scheme
        // cannot express. Zero-latency link so the argmin is pure
        // bandwidth (deterministic at this scale).
        let machines = 4;
        let gen = small_gen();
        let specs = gen.layer_specs(1, 2);
        let layers = gen.layer_iteration_all(&specs, 0, machines);
        let planner = CostPlanner::new(
            machines,
            0x5eed,
            gen.expected_nnz().max(64),
            PlanConfig::default(),
        );
        let net = Network::new(machines, LinkKind::Custom(25_000_000_000, 0));
        let engine = SyncEngine::new(EngineConfig::new(1, 0.05));
        let run = engine.run(&specs, &layers, &planner, &net, |r| r.comm_time());
        verify_layer_outputs(&run, &layers);
        assert_eq!(run.buckets.len(), specs.len(), "per-layer buckets");
        assert_eq!(run.buckets[0].scheme, "AllReduce", "dense head bucket");
        for b in &run.buckets[1..] {
            assert_ne!(b.scheme, "AllReduce", "sparse bucket {}", b.label);
            assert!(b.predicted_time.is_some());
            assert!(b.misprediction().unwrap().is_finite());
            assert!(b.replanned, "first run plans every bucket");
        }
        assert_eq!(planner.profile_count(), specs.len());
        // second iteration: every plan served from cache
        let again = engine.run(&specs, &layers, &planner, &net, |r| r.comm_time());
        assert!(again.buckets.iter().all(|b| !b.replanned));
        assert_eq!(planner.profile_count(), specs.len(), "O(warm-up) profiling");
    }

    #[test]
    fn builder_validates_instead_of_panicking() {
        let ok = EngineConfig::builder()
            .bucket_bytes(16 * 1024)
            .compute_time(0.05)
            .transport(crate::wire::TransportKind::Channel)
            .build()
            .expect("valid config");
        assert_eq!(ok.bucket_bytes, 16 * 1024);
        assert_eq!(ok.transport, crate::wire::TransportKind::Channel);
        let err = EngineConfig::builder().compute_time(-1.0).build();
        assert!(err.is_err());
        assert!(err.unwrap_err().contains("compute_time"));
        let nan = EngineConfig::builder().compute_time(f64::NAN).build();
        assert!(nan.is_err());
    }

    #[test]
    fn timeline_and_outcomes_agree() {
        let (run, _) = run_engine("allreduce", 4, 16 * 1024, 0.1);
        assert_eq!(run.timeline.entries.len(), run.buckets.len());
        let sum: f64 = run.buckets.iter().map(|b| b.comm_time).sum();
        assert!((run.timeline.comm_time() - sum).abs() < 1e-9);
        assert_eq!(run.timeline.total_bytes(), run.total_bytes);
        // buckets keep backward order: ready times monotone
        assert!(run
            .timeline
            .entries
            .windows(2)
            .all(|w| w[0].ready <= w[1].ready));
    }

    #[test]
    fn partitioned_pieces_aggregate_exactly() {
        // Split oversized buckets into pieces: the synchronized values
        // must be identical to the unsplit run, piece by piece
        // reassembled — partitioning only changes the timeline.
        let gen = small_gen();
        let specs = gen.layer_specs(3, 4);
        let layers = gen.layer_iteration_all(&specs, 0, 4);
        let planner = fixed("zen", 4, gen.expected_nnz().max(64));
        let net = Network::new(4, LinkKind::Tcp25);
        let whole = SyncEngine::new(EngineConfig::new(64 * 1024, 0.05)).run(
            &specs,
            &layers,
            &planner,
            &net,
            |r| r.comm_time(),
        );
        let split_cfg = EngineConfig::new(64 * 1024, 0.05).with_partition_bytes(8 * 1024);
        let split =
            SyncEngine::new(split_cfg).run(&specs, &layers, &planner, &net, |r| r.comm_time());
        assert!(
            split.buckets.len() > whole.buckets.len(),
            "want actual splitting: {} pieces vs {} buckets",
            split.buckets.len(),
            whole.buckets.len()
        );
        assert_eq!(whole.layer_outputs, split.layer_outputs);
        verify_layer_outputs(&split, &layers);
        // piece labels carry the [i/k] suffix
        assert!(split.buckets.iter().any(|b| b.label.contains('[')));
    }

    #[test]
    fn priority_schedule_preserves_values_and_timing_bounds() {
        // Priority scheduling reorders link access only: identical
        // synchronized values, identical serialized time and bytes,
        // identical single-link makespan (work conservation), and a
        // next-forward finish no later than greedy's.
        let gen = small_gen();
        let specs = gen.layer_specs(3, 4);
        let layers = gen.layer_iteration_all(&specs, 0, 4);
        let planner = fixed("zen", 4, gen.expected_nnz().max(64));
        let net = Network::new(4, LinkKind::Tcp25);
        let greedy = SyncEngine::new(EngineConfig::new(16 * 1024, 0.05)).run(
            &specs,
            &layers,
            &planner,
            &net,
            |r| r.comm_time(),
        );
        let prio_cfg = EngineConfig::new(16 * 1024, 0.05).with_priority(true);
        let prio =
            SyncEngine::new(prio_cfg).run(&specs, &layers, &planner, &net, |r| r.comm_time());
        assert!(greedy.buckets.len() >= 2, "want a multi-bucket workload");
        assert_eq!(greedy.layer_outputs, prio.layer_outputs);
        assert_eq!(greedy.total_bytes, prio.total_bytes);
        assert!((greedy.serialized_time - prio.serialized_time).abs() < 1e-12);
        assert!((greedy.overlapped_time - prio.overlapped_time).abs() < 1e-9);
        assert!(prio.forward_finish <= greedy.forward_finish + 1e-9);
        verify_layer_outputs(&prio, &layers);
    }
}
