//! Dense ring AllReduce — the paper's `Dense` baseline (Horovod/NCCL).
//!
//! Ring reduce-scatter (n−1 stages) + ring all-gather (n−1 stages); each
//! node moves one dense chunk of ≈`M/n` values per stage, `2(n−1)/n · M`
//! in total — the textbook bandwidth-optimal dense collective (paper
//! footnote 2: Ring, incremental aggregation, Parallelism, Balanced).
//!
//! Each rank is a sans-IO machine that circulates `DenseChunk` frames
//! with its ring neighbors: per step it sends its accumulator to the
//! successor, parks on `NeedFrame` for the predecessor's chunk
//! (deterministic one-frame count), folds its own contribution in, and
//! closes the step's stage. Only one chunk per rank is ever
//! materialized (the in-flight accumulator), so the full `n × M` dense
//! expansion the first perf pass removed never comes back; during
//! all-gather every rank assembles the full aggregate from the
//! circulating fully-reduced chunks, which are bit-identical at every
//! rank by construction.

use super::*;
use crate::wire::{Event, Inbox};

/// Dense Ring-AllReduce.
#[derive(Clone, Debug, Default)]
pub struct DenseAllReduce;

impl DenseAllReduce {
    pub fn new() -> Self {
        DenseAllReduce
    }
}

/// Scatter-add the entries of `t` within `[lo, hi)` into `dst`
/// (indexed relative to `lo`).
fn add_range(t: &CooTensor, lo: u32, hi: u32, dst: &mut [f32]) {
    let start = t.indices.partition_point(|&i| i < lo);
    let end = t.indices.partition_point(|&i| i < hi);
    for (&i, &v) in t.indices[start..end].iter().zip(&t.values[start..end]) {
        dst[(i - lo) as usize] += v;
    }
}

fn expect_chunk(msg: Message) -> (u64, Vec<f32>) {
    match msg {
        Message::DenseChunk { offset, values, .. } => (offset, values),
        other => panic!("unexpected frame on the ring: {other:?}"),
    }
}

impl SyncScheme for DenseAllReduce {
    fn name(&self) -> &'static str {
        "AllReduce"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::Ring,
            aggregation: AggPattern::Incremental,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Balanced,
            format: "dense",
        }
    }

    fn protocols<'a>(&'a self, inputs: &'a [CooTensor]) -> Vec<Box<dyn Protocol + 'a>> {
        (0..inputs.len())
            .map(|rank| Box::new(RingMachine::new(rank, inputs)) as Box<dyn Protocol + 'a>)
            .collect()
    }
}

enum RingState {
    Init,
    /// Reduce-scatter step `s`: accumulator not yet sent.
    RsSend(usize),
    /// Waiting for the predecessor's step-`s` partial chunk.
    RsWait(usize),
    /// Folded; parked on the step-`s` `reduce-scatter` stage.
    RsParked(usize),
    /// Initialize the full-assembly buffer, then start all-gather.
    AgStart,
    AgSend(usize),
    AgWait(usize),
    AgParked(usize),
    Done,
}

struct RingMachine<'a> {
    rank: usize,
    n: usize,
    dense_len: usize,
    per: usize,
    inputs: &'a [CooTensor],
    inbox: Inbox,
    state: RingState,
    /// The in-flight chunk accumulator (the only materialized chunk).
    acc: Vec<f32>,
    /// Full dense assembly, filled during all-gather.
    full: Vec<f32>,
}

impl<'a> RingMachine<'a> {
    fn new(rank: usize, inputs: &'a [CooTensor]) -> RingMachine<'a> {
        let n = inputs.len();
        let dense_len = inputs[0].dense_len;
        RingMachine {
            rank,
            n,
            dense_len,
            per: crate::util::ceil_div(dense_len, n),
            inputs,
            inbox: Inbox::new(n),
            state: RingState::Init,
            acc: Vec::new(),
            full: Vec::new(),
        }
    }

    fn lo(&self, c: usize) -> usize {
        (c * self.per).min(self.dense_len)
    }

    fn hi(&self, c: usize) -> usize {
        ((c + 1) * self.per).min(self.dense_len)
    }

    fn succ(&self) -> usize {
        (self.rank + 1) % self.n
    }

    fn pred(&self) -> usize {
        (self.rank + self.n - 1) % self.n
    }

    fn chunk_msg(&self, c: usize) -> Message {
        Message::DenseChunk {
            from: small_u32(self.rank, "ring rank"),
            offset: self.lo(c) as u64,
            values: self.acc.clone(),
        }
    }
}

impl Protocol for RingMachine<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
        loop {
            match self.state {
                RingState::Init => {
                    if self.n == 1 {
                        self.state = RingState::Done;
                        return Ok(Event::Complete(reference_sum(self.inputs).to_coo()));
                    }
                    let (lo, hi) = (self.lo(self.rank), self.hi(self.rank));
                    self.acc = vec![0.0f32; hi - lo];
                    add_range(
                        &self.inputs[self.rank],
                        small_u32(lo, "chunk offset"),
                        small_u32(hi, "chunk end"),
                        &mut self.acc,
                    );
                    self.state = RingState::RsSend(0);
                }
                RingState::RsSend(s) => {
                    let c = (self.rank + self.n - s) % self.n;
                    let msg = self.chunk_msg(c);
                    self.state = RingState::RsWait(s);
                    return Ok(Event::Send {
                        dst: self.succ(),
                        msg,
                    });
                }
                RingState::RsWait(s) => {
                    let pred = self.pred();
                    match self.inbox.take_from(pred) {
                        Some(msg) => {
                            let c = (self.rank + self.n - 1 - s) % self.n;
                            let (offset, mut values) = expect_chunk(msg);
                            assert_eq!(offset, self.lo(c) as u64, "ring chunk out of order");
                            assert_eq!(values.len(), self.hi(c) - self.lo(c));
                            add_range(
                                &self.inputs[self.rank],
                                small_u32(self.lo(c), "chunk offset"),
                                small_u32(self.hi(c), "chunk end"),
                                &mut values,
                            );
                            self.acc = values;
                            self.state = RingState::RsParked(s);
                            return Ok(Event::StageDone {
                                name: "reduce-scatter",
                            });
                        }
                        None => return Ok(Event::NeedFrame { src: pred }),
                    }
                }
                RingState::RsParked(_) => {
                    return Ok(Event::StageDone {
                        name: "reduce-scatter",
                    })
                }
                RingState::AgStart => {
                    // This rank now holds the fully reduced chunk
                    // (rank + 1) mod n; seed the assembly with it.
                    self.full = vec![0.0f32; self.dense_len];
                    let c = (self.rank + 1) % self.n;
                    self.full[self.lo(c)..self.hi(c)].copy_from_slice(&self.acc);
                    self.state = RingState::AgSend(0);
                }
                RingState::AgSend(s) => {
                    let c = (self.rank + 1 + self.n - s) % self.n;
                    let msg = self.chunk_msg(c);
                    self.state = RingState::AgWait(s);
                    return Ok(Event::Send {
                        dst: self.succ(),
                        msg,
                    });
                }
                RingState::AgWait(s) => {
                    let pred = self.pred();
                    match self.inbox.take_from(pred) {
                        Some(msg) => {
                            let c = (self.rank + self.n - s) % self.n;
                            let (offset, values) = expect_chunk(msg);
                            assert_eq!(offset, self.lo(c) as u64, "ring chunk out of order");
                            self.full[self.lo(c)..self.hi(c)].copy_from_slice(&values);
                            self.acc = values;
                            self.state = RingState::AgParked(s);
                            return Ok(Event::StageDone { name: "all-gather" });
                        }
                        None => return Ok(Event::NeedFrame { src: pred }),
                    }
                }
                RingState::AgParked(_) => return Ok(Event::StageDone { name: "all-gather" }),
                RingState::Done => {
                    let full = std::mem::take(&mut self.full);
                    return Ok(Event::Complete(
                        crate::tensor::DenseTensor::from_values(full).to_coo(),
                    ));
                }
            }
        }
    }

    fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
        self.inbox.push(src, msg);
        Ok(())
    }

    fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
        match (&self.state, name) {
            (RingState::RsParked(s), "reduce-scatter") => {
                self.state = if s + 1 < self.n - 1 {
                    RingState::RsSend(s + 1)
                } else {
                    RingState::AgStart
                };
            }
            (RingState::AgParked(s), "all-gather") => {
                self.state = if s + 1 < self.n - 1 {
                    RingState::AgSend(s + 1)
                } else {
                    RingState::Done
                };
            }
            _ => panic!("AllReduce: unexpected stage '{name}' closed"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::tensor::BYTES_F32;
    use crate::wire::codec::DENSE_CHUNK_OVERHEAD;

    fn run(inputs: &[CooTensor], net: &Network) -> SyncOutput {
        DenseAllReduce::new().run_sim(inputs, net, &mut SyncScratch::new())
    }

    #[test]
    fn correct_aggregation() {
        let inputs = overlapping_inputs(1, 4, 1000, 50, 30);
        let net = Network::new(4, LinkKind::Tcp25);
        let r = run(&inputs, &net);
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn traffic_matches_formula() {
        // Each of the 2(n−1) stages moves every chunk exactly once
        // (chunks partition the range): M·4 payload bytes + n framed
        // chunk headers per stage.
        let n = 8;
        let m = 4096;
        let inputs = overlapping_inputs(2, n, m, 10, 10);
        let net = Network::new(n, LinkKind::Tcp25);
        let r = run(&inputs, &net);
        let per_stage = (m * BYTES_F32 + n * DENSE_CHUNK_OVERHEAD) as u64;
        assert_eq!(r.report.total_bytes(), 2 * (n as u64 - 1) * per_stage);
        assert_eq!(r.report.stages.len(), 2 * (n - 1));
    }

    #[test]
    fn uneven_range_still_exact() {
        // dense_len not divisible by n: tail chunks shrink/empty, but the
        // chunks still partition the range and the aggregate is exact.
        let n = 5;
        let inputs = overlapping_inputs(7, n, 1013, 40, 20);
        let net = Network::new(n, LinkKind::Tcp25);
        let r = run(&inputs, &net);
        verify_outputs(&r, &inputs);
        let payload: u64 = r.report.total_bytes()
            - (2 * (n as u64 - 1)) * (n * DENSE_CHUNK_OVERHEAD) as u64;
        assert_eq!(payload, 2 * (n as u64 - 1) * (1013 * BYTES_F32) as u64);
    }

    #[test]
    fn single_node_is_free() {
        let inputs = overlapping_inputs(3, 1, 100, 5, 5);
        let net = Network::new(1, LinkKind::Tcp25);
        let r = run(&inputs, &net);
        assert_eq!(r.report.total_bytes(), 0);
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn time_independent_of_sparsity() {
        // Dense pays for zeros: same time whatever the density.
        let net = Network::new(4, LinkKind::Tcp25);
        let sparse = overlapping_inputs(4, 4, 10_000, 5, 5);
        let denser = overlapping_inputs(5, 4, 10_000, 2_000, 500);
        let t1 = run(&sparse, &net).report.comm_time();
        let t2 = run(&denser, &net).report.comm_time();
        assert!((t1 - t2).abs() < 1e-12);
    }
}
