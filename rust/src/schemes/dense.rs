//! Dense ring AllReduce — the paper's `Dense` baseline (Horovod/NCCL).
//!
//! Ring reduce-scatter (n−1 stages) + ring all-gather (n−1 stages); each
//! node moves `M/n` dense values per stage, `2(n−1)/n · M` in total —
//! the textbook bandwidth-optimal dense collective (paper footnote 2:
//! Ring, incremental aggregation, Parallelism, Balanced).

use super::*;
use crate::tensor::BYTES_F32;

/// Dense Ring-AllReduce.
#[derive(Clone, Debug, Default)]
pub struct DenseAllReduce;

impl DenseAllReduce {
    pub fn new() -> Self {
        DenseAllReduce
    }
}

impl SyncScheme for DenseAllReduce {
    fn name(&self) -> &'static str {
        "AllReduce"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::Ring,
            aggregation: AggPattern::Incremental,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Balanced,
            format: "dense",
        }
    }

    fn sync_with(
        &self,
        inputs: &[CooTensor],
        net: &Network,
        _scratch: &mut SyncScratch,
    ) -> SyncResult {
        let n = inputs.len();
        assert_eq!(n, net.endpoints);
        let dense_len = inputs[0].dense_len;

        // Ring reduce-scatter + all-gather accounting. Dense payloads are
        // data-independent, so we charge the exact stage structure without
        // materializing n dense copies (the first perf pass found the
        // 8×|G| dense materialization dominated large-model steps) and
        // aggregate once via sparse scatter-add.
        let shard_bytes = (crate::util::ceil_div(dense_len, n) * BYTES_F32) as u64;
        let mut report = CommReport::new();
        if n > 1 {
            for _s in 0..n - 1 {
                report.push(StageSpec::uniform(net, "reduce-scatter", shard_bytes));
            }
            for _s in 0..n - 1 {
                report.push(StageSpec::uniform(net, "all-gather", shard_bytes));
            }
        }

        let sum = reference_sum(inputs);
        let out = sum.to_coo();
        SyncResult {
            outputs: vec![out; n],
            report,
        }
    }
}

/// Helper: a stage where every endpoint sends and receives the same
/// number of bytes (balanced ring stages).
pub(crate) struct StageSpec;

impl StageSpec {
    pub(crate) fn uniform(
        net: &Network,
        name: &str,
        bytes_per_endpoint: u64,
    ) -> crate::cluster::StageReport {
        let sent = vec![bytes_per_endpoint; net.endpoints];
        let recv = vec![bytes_per_endpoint; net.endpoints];
        let time = net.stage_time(&sent, &recv);
        crate::cluster::StageReport {
            name: name.to_string(),
            sent,
            recv,
            time,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;

    #[test]
    fn correct_aggregation() {
        let inputs = overlapping_inputs(1, 4, 1000, 50, 30);
        let net = Network::new(4, LinkKind::Tcp25);
        let r = DenseAllReduce::new().sync(&inputs, &net);
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn traffic_matches_formula() {
        // total bytes = n · 2(n-1) · M/n · 4  = 2(n-1) · M · 4
        let n = 8;
        let m = 4096;
        let inputs = overlapping_inputs(2, n, m, 10, 10);
        let net = Network::new(n, LinkKind::Tcp25);
        let r = DenseAllReduce::new().sync(&inputs, &net);
        let expect = (2 * (n - 1) * m * BYTES_F32) as u64;
        assert_eq!(r.report.total_bytes(), expect);
        assert_eq!(r.report.stages.len(), 2 * (n - 1));
    }

    #[test]
    fn single_node_is_free() {
        let inputs = overlapping_inputs(3, 1, 100, 5, 5);
        let net = Network::new(1, LinkKind::Tcp25);
        let r = DenseAllReduce::new().sync(&inputs, &net);
        assert_eq!(r.report.total_bytes(), 0);
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn time_independent_of_sparsity() {
        // Dense pays for zeros: same time whatever the density.
        let net = Network::new(4, LinkKind::Tcp25);
        let sparse = overlapping_inputs(4, 4, 10_000, 5, 5);
        let denser = overlapping_inputs(5, 4, 10_000, 2_000, 500);
        let t1 = DenseAllReduce::new().sync(&sparse, &net).report.comm_time();
        let t2 = DenseAllReduce::new().sync(&denser, &net).report.comm_time();
        assert!((t1 - t2).abs() < 1e-12);
    }
}
