//! Dense ring AllReduce — the paper's `Dense` baseline (Horovod/NCCL).
//!
//! Ring reduce-scatter (n−1 stages) + ring all-gather (n−1 stages); each
//! node moves one dense chunk of ≈`M/n` values per stage, `2(n−1)/n · M`
//! in total — the textbook bandwidth-optimal dense collective (paper
//! footnote 2: Ring, incremental aggregation, Parallelism, Balanced).
//!
//! The protocol executes for real over the transport: chunks of dense
//! values travel as `DenseChunk` frames and are incrementally reduced at
//! each hop. Only one chunk per node is ever materialized (the in-flight
//! accumulator), so the full `n × M` dense expansion the first perf pass
//! removed never comes back.

use super::*;
use crate::wire::Message;

/// Dense Ring-AllReduce.
#[derive(Clone, Debug, Default)]
pub struct DenseAllReduce;

impl DenseAllReduce {
    pub fn new() -> Self {
        DenseAllReduce
    }
}

/// Scatter-add the entries of `t` within `[lo, hi)` into `dst`
/// (indexed relative to `lo`).
fn add_range(t: &CooTensor, lo: u32, hi: u32, dst: &mut [f32]) {
    let start = t.indices.partition_point(|&i| i < lo);
    let end = t.indices.partition_point(|&i| i < hi);
    for (&i, &v) in t.indices[start..end].iter().zip(&t.values[start..end]) {
        dst[(i - lo) as usize] += v;
    }
}

impl SyncScheme for DenseAllReduce {
    fn name(&self) -> &'static str {
        "AllReduce"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::Ring,
            aggregation: AggPattern::Incremental,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Balanced,
            format: "dense",
        }
    }

    fn sync_transport(
        &self,
        inputs: &[CooTensor],
        tx: &mut dyn Transport,
        _scratch: &mut SyncScratch,
    ) -> Result<SyncResult, crate::wire::WireError> {
        let n = inputs.len();
        assert_eq!(n, tx.endpoints());
        let dense_len = inputs[0].dense_len;
        if n == 1 {
            let out = reference_sum(inputs).to_coo();
            return Ok(SyncResult {
                outputs: vec![out],
                report: tx.take_report(),
            });
        }

        // Chunk c covers [lo(c), hi(c)); chunks partition the range, so
        // every stage moves exactly `dense_len` values across the ring.
        let per = crate::util::ceil_div(dense_len, n);
        let lo = |c: usize| (c * per).min(dense_len);
        let hi = |c: usize| ((c + 1) * per).min(dense_len);

        // --- Ring reduce-scatter: at step s node i forwards the partial
        // sum of chunk (i − s) mod n and folds its own contribution into
        // the chunk it receives from its predecessor.
        let mut cur: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                let mut acc = vec![0.0f32; hi(i) - lo(i)];
                add_range(&inputs[i], lo(i) as u32, hi(i) as u32, &mut acc);
                acc
            })
            .collect();
        for s in 0..n - 1 {
            for (i, chunk) in cur.iter().enumerate() {
                let c = (i + n - s) % n;
                tx.send(
                    i,
                    (i + 1) % n,
                    FrameRef::DenseChunk {
                        from: i as u32,
                        offset: lo(c) as u64,
                        values: chunk,
                    },
                )?;
            }
            for (i, slot) in cur.iter_mut().enumerate() {
                let c = (i + n - 1 - s) % n;
                match tx.recv(i)? {
                    Message::DenseChunk {
                        offset, mut values, ..
                    } => {
                        assert_eq!(offset as usize, lo(c), "ring chunk out of order");
                        assert_eq!(values.len(), hi(c) - lo(c));
                        add_range(&inputs[i], lo(c) as u32, hi(c) as u32, &mut values);
                        *slot = values;
                    }
                    other => panic!("unexpected frame during reduce-scatter: {other:?}"),
                }
            }
            tx.end_stage("reduce-scatter")?;
        }

        // Node i now holds the fully reduced chunk (i + 1) mod n.
        // --- Ring all-gather: circulate the reduced chunks; node 0
        // assembles the aggregate every endpoint ends up with.
        let mut full = vec![0.0f32; dense_len];
        let first = 1 % n;
        full[lo(first)..hi(first)].copy_from_slice(&cur[0]);
        for s in 0..n - 1 {
            for (i, chunk) in cur.iter().enumerate() {
                let c = (i + 1 + n - s) % n;
                tx.send(
                    i,
                    (i + 1) % n,
                    FrameRef::DenseChunk {
                        from: i as u32,
                        offset: lo(c) as u64,
                        values: chunk,
                    },
                )?;
            }
            for (i, slot) in cur.iter_mut().enumerate() {
                let c = (i + n - s) % n;
                match tx.recv(i)? {
                    Message::DenseChunk { offset, values, .. } => {
                        assert_eq!(offset as usize, lo(c), "ring chunk out of order");
                        if i == 0 {
                            full[lo(c)..hi(c)].copy_from_slice(&values);
                        }
                        *slot = values;
                    }
                    other => panic!("unexpected frame during all-gather: {other:?}"),
                }
            }
            tx.end_stage("all-gather")?;
        }

        let out = crate::tensor::DenseTensor::from_values(full).to_coo();
        Ok(SyncResult {
            outputs: vec![out; n],
            report: tx.take_report(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::tensor::BYTES_F32;
    use crate::wire::codec::DENSE_CHUNK_OVERHEAD;

    #[test]
    fn correct_aggregation() {
        let inputs = overlapping_inputs(1, 4, 1000, 50, 30);
        let net = Network::new(4, LinkKind::Tcp25);
        let r = DenseAllReduce::new().sync(&inputs, &net);
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn traffic_matches_formula() {
        // Each of the 2(n−1) stages moves every chunk exactly once
        // (chunks partition the range): M·4 payload bytes + n framed
        // chunk headers per stage.
        let n = 8;
        let m = 4096;
        let inputs = overlapping_inputs(2, n, m, 10, 10);
        let net = Network::new(n, LinkKind::Tcp25);
        let r = DenseAllReduce::new().sync(&inputs, &net);
        let per_stage = (m * BYTES_F32 + n * DENSE_CHUNK_OVERHEAD) as u64;
        assert_eq!(r.report.total_bytes(), 2 * (n as u64 - 1) * per_stage);
        assert_eq!(r.report.stages.len(), 2 * (n - 1));
    }

    #[test]
    fn uneven_range_still_exact() {
        // dense_len not divisible by n: tail chunks shrink/empty, but the
        // chunks still partition the range and the aggregate is exact.
        let n = 5;
        let inputs = overlapping_inputs(7, n, 1013, 40, 20);
        let net = Network::new(n, LinkKind::Tcp25);
        let r = DenseAllReduce::new().sync(&inputs, &net);
        verify_outputs(&r, &inputs);
        let payload: u64 = r.report.total_bytes()
            - (2 * (n as u64 - 1)) * (n * DENSE_CHUNK_OVERHEAD) as u64;
        assert_eq!(payload, 2 * (n as u64 - 1) * (1013 * BYTES_F32) as u64);
    }

    #[test]
    fn single_node_is_free() {
        let inputs = overlapping_inputs(3, 1, 100, 5, 5);
        let net = Network::new(1, LinkKind::Tcp25);
        let r = DenseAllReduce::new().sync(&inputs, &net);
        assert_eq!(r.report.total_bytes(), 0);
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn time_independent_of_sparsity() {
        // Dense pays for zeros: same time whatever the density.
        let net = Network::new(4, LinkKind::Tcp25);
        let sparse = overlapping_inputs(4, 4, 10_000, 5, 5);
        let denser = overlapping_inputs(5, 4, 10_000, 2_000, 500);
        let t1 = DenseAllReduce::new().sync(&sparse, &net).report.comm_time();
        let t2 = DenseAllReduce::new().sync(&denser, &net).report.comm_time();
        assert!((t1 - t2).abs() < 1e-12);
    }
}
