//! The lossy strawman as a full synchronization scheme (§3.1.2, Alg 3).
//!
//! Balanced Parallelism achieved with a *single* hash function and a
//! fixed memory: colliding indices are overwritten and their gradients
//! silently dropped. Communication is balanced like Zen's, but the
//! aggregate is incomplete — Fig 14 shows the accuracy cost, Fig 8 the
//! memory/loss trade-off. Push ships the surviving hash partitions as
//! `PushCoo` frames; Pull uses COO broadcast.
//!
//! Empty partitions are never framed (like SparsePS), so the per-rank
//! machines are receive-until-stage-closed. Each machine records its
//! own `(nnz, lost)` into a per-rank slot on the scheme; the loss rate
//! is the ratio over whichever ranks ran in this process — all of them
//! in-process, just the local rank under `zen worker`.

use super::*;
use crate::hashing::StrawmanHasher;
use crate::wire::{Event, Inbox};

/// Lossy strawman scheme with memory `mem_multiple × expected_nnz` slots.
pub struct StrawmanScheme {
    hasher: StrawmanHasher,
    /// Per-rank `(nnz, lost)` of the last sync (interior mutability for
    /// the accuracy experiment's reporting); reset when machines are
    /// built, filled by each rank's machine at partition time.
    last_loss: std::sync::Mutex<Vec<Option<(usize, usize)>>>,
}

impl StrawmanScheme {
    pub fn new(master_seed: u64, n: usize, expected_nnz: usize, mem_multiple: f64) -> Self {
        // mem_multiple is a small CLI-provided factor, so the product
        // stays far below 2^53 and the float→int cast keeps the exact
        // integer part — the truncation lint is waived for this line.
        #[allow(clippy::cast_possible_truncation)]
        let slots = ((expected_nnz as f64 * mem_multiple).max(0.0) as usize).max(n);
        StrawmanScheme {
            hasher: StrawmanHasher::new(master_seed, n, slots),
            last_loss: std::sync::Mutex::new(Vec::new()),
        }
    }

    /// Information-loss rate measured on the most recent sync, over the
    /// ranks that ran in this process.
    pub fn last_loss_rate(&self) -> f64 {
        let slots = crate::wire::lock_or_panic(&self.last_loss, "loss slots");
        let (nnz, lost) = slots
            .iter()
            .flatten()
            .fold((0usize, 0usize), |(a, b), &(n, l)| (a + n, b + l));
        if nnz == 0 {
            0.0
        } else {
            lost as f64 / nnz as f64
        }
    }

    fn record_loss(&self, rank: usize, nnz: usize, lost: usize) {
        crate::wire::lock_or_panic(&self.last_loss, "loss slots")[rank] = Some((nnz, lost));
    }
}

impl SyncScheme for StrawmanScheme {
    fn name(&self) -> &'static str {
        "Strawman-lossy"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::PointToPoint,
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Balanced,
            format: "COO (lossy)",
        }
    }

    fn protocols<'a>(&'a self, inputs: &'a [CooTensor]) -> Vec<Box<dyn Protocol + 'a>> {
        let n = inputs.len();
        assert_eq!(self.hasher.n, n);
        *crate::wire::lock_or_panic(&self.last_loss, "loss slots") = vec![None; n];
        (0..n)
            .map(|rank| {
                Box::new(StrawmanMachine {
                    rank,
                    n,
                    scheme: self,
                    inputs,
                    inbox: Inbox::new(n),
                    state: StrawState::PushSend,
                    cursor: 0,
                    parts: Vec::new(),
                    own: None,
                    agg: None,
                    output: None,
                }) as Box<dyn Protocol + 'a>
            })
            .collect()
    }
}

enum StrawState {
    /// Lossy-partition, then frame non-empty foreign partitions.
    PushSend,
    PushParked,
    /// Broadcast the (possibly empty → unframed) aggregate.
    PullSend,
    PullParked,
    Done,
}

struct StrawmanMachine<'a> {
    rank: usize,
    n: usize,
    scheme: &'a StrawmanScheme,
    inputs: &'a [CooTensor],
    inbox: Inbox,
    state: StrawState,
    cursor: usize,
    /// Surviving partitions of this rank's input (drained as sent).
    parts: Vec<Option<CooTensor>>,
    own: Option<CooTensor>,
    agg: Option<CooTensor>,
    output: Option<CooTensor>,
}

impl Protocol for StrawmanMachine<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
        match self.state {
            StrawState::PushSend => {
                if self.parts.is_empty() {
                    let t = &self.inputs[self.rank];
                    let out = self.scheme.hasher.partition(t);
                    self.scheme.record_loss(self.rank, t.nnz(), out.lost);
                    self.parts = out.parts.into_iter().map(Some).collect();
                }
                while self.cursor < self.n {
                    let p = self.cursor;
                    self.cursor += 1;
                    let part = state(self.parts[p].take(), "partition present");
                    if p == self.rank {
                        self.own = Some(part);
                    } else if part.nnz() > 0 {
                        return Ok(Event::Send {
                            dst: p,
                            msg: push_msg(self.rank, &part),
                        });
                    }
                }
                self.state = StrawState::PushParked;
                Ok(Event::StageDone { name: "push" })
            }
            StrawState::PushParked => Ok(Event::StageDone { name: "push" }),
            StrawState::PullSend => {
                let nonempty = state(self.agg.as_ref(), "aggregate present").nnz() > 0;
                if nonempty {
                    while self.cursor < self.n {
                        let w = self.cursor;
                        self.cursor += 1;
                        if w != self.rank {
                            let agg = state(self.agg.as_ref(), "aggregate present");
                            let msg = pull_msg(self.rank, agg);
                            return Ok(Event::Send { dst: w, msg });
                        }
                    }
                }
                self.state = StrawState::PullParked;
                Ok(Event::StageDone { name: "pull" })
            }
            StrawState::PullParked => Ok(Event::StageDone { name: "pull" }),
            StrawState::Done => Ok(Event::Complete(state(
                self.output.take(),
                "output assembled",
            ))),
        }
    }

    fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
        self.inbox.push(src, msg);
        Ok(())
    }

    fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
        match name {
            "push" => {
                let mut shards = vec![state(self.own.take(), "own shard present")];
                for (_, msg) in self.inbox.drain_ascending() {
                    shards.push(expect_push(msg).1);
                }
                self.agg = Some(CooTensor::merge_all(&shards));
                self.cursor = 0;
                self.state = StrawState::PullSend;
            }
            "pull" => {
                let pieces: Vec<CooTensor> = self
                    .inbox
                    .drain_ascending()
                    .into_iter()
                    .map(|(_, msg)| expect_pull_coo(msg).1)
                    .collect();
                self.output = Some(merge_with_own(
                    &pieces,
                    state(self.agg.as_ref(), "aggregate present"),
                ));
                self.state = StrawState::Done;
            }
            other => panic!("Strawman-lossy: unknown stage '{other}' closed"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::schemes::reference_sum;

    fn run(s: &StrawmanScheme, inputs: &[CooTensor], net: &Network) -> SyncOutput {
        s.run_sim(inputs, net, &mut SyncScratch::new())
    }

    #[test]
    fn loses_gradients_under_small_memory() {
        let inputs = overlapping_inputs(1, 4, 20_000, 500, 400);
        let net = Network::new(4, LinkKind::Tcp25);
        let s = StrawmanScheme::new(3, 4, 900, 1.0);
        let r = run(&s, &inputs, &net);
        assert!(s.last_loss_rate() > 0.05, "loss {}", s.last_loss_rate());
        // outputs are a *partial* sum: every surviving entry must match
        // some subset-sum ≤ reference count
        let reference = reference_sum(&inputs);
        let out = r.outputs[0].to_dense();
        assert!(out.nnz() < reference.nnz());
    }

    #[test]
    fn near_lossless_with_big_memory() {
        let inputs = overlapping_inputs(2, 4, 20_000, 500, 400);
        let net = Network::new(4, LinkKind::Tcp25);
        let s = StrawmanScheme::new(3, 4, 900, 64.0);
        let r = run(&s, &inputs, &net);
        assert!(s.last_loss_rate() < 0.02, "loss {}", s.last_loss_rate());
        let _ = r;
    }

    #[test]
    fn communications_balanced() {
        let inputs = overlapping_inputs(3, 8, 50_000, 1_500, 500);
        let net = Network::new(8, LinkKind::Tcp25);
        let s = StrawmanScheme::new(5, 8, 2_000, 8.0);
        let r = run(&s, &inputs, &net);
        assert!(r.report.stages[0].recv_imbalance() < 1.2);
    }
}
