//! The lossy strawman as a full synchronization scheme (§3.1.2, Alg 3).
//!
//! Balanced Parallelism achieved with a *single* hash function and a
//! fixed memory: colliding indices are overwritten and their gradients
//! silently dropped. Communication is balanced like Zen's, but the
//! aggregate is incomplete — Fig 14 shows the accuracy cost, Fig 8 the
//! memory/loss trade-off. Push ships the surviving hash partitions as
//! `PushCoo` frames; Pull uses COO broadcast.

use super::*;
use crate::hashing::StrawmanHasher;

/// Lossy strawman scheme with memory `mem_multiple × expected_nnz` slots.
pub struct StrawmanScheme {
    hasher: StrawmanHasher,
    /// Measured info-loss of the last sync (interior mutability for the
    /// accuracy experiment's reporting).
    last_loss_rate: std::sync::Mutex<f64>,
}

impl StrawmanScheme {
    pub fn new(master_seed: u64, n: usize, expected_nnz: usize, mem_multiple: f64) -> Self {
        let slots = ((expected_nnz as f64 * mem_multiple) as usize).max(n);
        StrawmanScheme {
            hasher: StrawmanHasher::new(master_seed, n, slots),
            last_loss_rate: std::sync::Mutex::new(0.0),
        }
    }

    /// Information-loss rate measured on the most recent `sync`.
    pub fn last_loss_rate(&self) -> f64 {
        *self.last_loss_rate.lock().unwrap()
    }
}

impl SyncScheme for StrawmanScheme {
    fn name(&self) -> &'static str {
        "Strawman-lossy"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::PointToPoint,
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Balanced,
            format: "COO (lossy)",
        }
    }

    fn sync_transport(
        &self,
        inputs: &[CooTensor],
        tx: &mut dyn Transport,
        _scratch: &mut SyncScratch,
    ) -> Result<SyncResult, crate::wire::WireError> {
        let n = inputs.len();
        assert_eq!(n, tx.endpoints());
        assert_eq!(self.hasher.n, n);

        // Push: strawman-partition (lossy) on every worker; frame every
        // non-empty foreign partition.
        let mut own: Vec<Option<CooTensor>> = (0..n).map(|_| None).collect();
        let mut expected = vec![0usize; n];
        let mut total_nnz = 0usize;
        let mut total_lost = 0usize;
        for (w, t) in inputs.iter().enumerate() {
            let out = self.hasher.partition(t);
            total_nnz += t.nnz();
            total_lost += out.lost;
            for (p, part) in out.parts.into_iter().enumerate() {
                if p == w {
                    own[w] = Some(part);
                } else if part.nnz() > 0 {
                    tx.send(w, p, push_frame(w, &part))?;
                    expected[p] += 1;
                }
            }
        }
        *self.last_loss_rate.lock().unwrap() = if total_nnz == 0 {
            0.0
        } else {
            total_lost as f64 / total_nnz as f64
        };

        let mut aggregated: Vec<CooTensor> = Vec::with_capacity(n);
        for p in 0..n {
            let mut shards = vec![own[p].take().expect("own shard present")];
            for _ in 0..expected[p] {
                shards.push(expect_push(tx.recv(p)?).1);
            }
            aggregated.push(CooTensor::merge_all(&shards));
        }
        tx.end_stage("push")?;

        // Pull: COO broadcast of each server's (disjoint) aggregate.
        let mut expected = vec![0usize; n];
        for (p, agg) in aggregated.iter().enumerate() {
            if agg.nnz() == 0 {
                continue;
            }
            for w in 0..n {
                if w != p {
                    tx.send(p, w, pull_frame(p, agg))?;
                    expected[w] += 1;
                }
            }
        }
        let mut outputs = Vec::with_capacity(n);
        for w in 0..n {
            let mut pieces: Vec<CooTensor> = Vec::with_capacity(expected[w]);
            for _ in 0..expected[w] {
                pieces.push(expect_pull_coo(tx.recv(w)?).1);
            }
            outputs.push(merge_with_own(&pieces, &aggregated[w]));
        }
        tx.end_stage("pull")?;

        Ok(SyncResult {
            outputs,
            report: tx.take_report(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::schemes::reference_sum;

    #[test]
    fn loses_gradients_under_small_memory() {
        let inputs = overlapping_inputs(1, 4, 20_000, 500, 400);
        let net = Network::new(4, LinkKind::Tcp25);
        let s = StrawmanScheme::new(3, 4, 900, 1.0);
        let r = s.sync(&inputs, &net);
        assert!(s.last_loss_rate() > 0.05, "loss {}", s.last_loss_rate());
        // outputs are a *partial* sum: every surviving entry must match
        // some subset-sum ≤ reference count
        let reference = reference_sum(&inputs);
        let out = r.outputs[0].to_dense();
        assert!(out.nnz() < reference.nnz());
    }

    #[test]
    fn near_lossless_with_big_memory() {
        let inputs = overlapping_inputs(2, 4, 20_000, 500, 400);
        let net = Network::new(4, LinkKind::Tcp25);
        let s = StrawmanScheme::new(3, 4, 900, 64.0);
        let r = s.sync(&inputs, &net);
        assert!(s.last_loss_rate() < 0.02, "loss {}", s.last_loss_rate());
        let _ = r;
    }

    #[test]
    fn communications_balanced() {
        let inputs = overlapping_inputs(3, 8, 50_000, 1_500, 500);
        let net = Network::new(8, LinkKind::Tcp25);
        let s = StrawmanScheme::new(5, 8, 2_000, 8.0);
        let r = s.sync(&inputs, &net);
        assert!(r.report.stages[0].recv_imbalance() < 1.2);
    }
}
