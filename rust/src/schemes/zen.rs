//! Zen — the paper's system (§3): Balanced Parallelism realized by the
//! hierarchical hashing algorithm (Alg 1) + hash bitmap Pull format
//! (Alg 2).
//!
//! Push: every worker partitions its non-zero indices with the shared
//! hash family (same master seed on all workers → consistent assignment)
//! and point-to-point pushes COO partitions to the servers. Theorem 2
//! guarantees every server receives `≈ nnz/n`.
//!
//! Pull: each server encodes its aggregated partition as a hash bitmap
//! over its partition domain `𝕀_p` plus the values, and broadcasts it.
//! Theorem 3: total index overhead per worker is a constant `|G|/32`
//! FP32-equivalents. The COO-Pull variant exists for the Fig 18 ablation,
//! and a naive positional bitmap variant for Fig 17's comparison.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::*;
use crate::hashing::{HashBitmapCodec, HashBitmapPayload, HierarchicalHasher};
use crate::util::OnceMap;
use crate::wire::Message;

/// Which index representation Pull uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ZenIndexFormat {
    /// Algorithm 2 (the full Zen system).
    HashBitmap,
    /// COO pull — "Zen (COO)" ablation in Fig 18.
    Coo,
    /// Naive positional bitmap over the whole range (§3.2.1's strawman:
    /// `n·|G|/32` total) — included to regenerate Fig 17.
    NaiveBitmap,
}

/// Capacity of the lock-free tier of the per-scheme domain cache:
/// distinct `dense_len`s one Zen instance is asked to sync. The engine
/// produces one dense length per bucket; 64 covers every workload in
/// the repo with headroom. Keys beyond capacity are still cached in a
/// mutex-guarded overflow tier — never recomputed per sync.
const DOMAIN_CACHE_CAPACITY: usize = 64;

/// The Zen synchronization scheme.
pub struct Zen {
    hasher: HierarchicalHasher,
    format: ZenIndexFormat,
    /// Partition domains keyed by dense_len (computed offline per h0,
    /// exactly as the paper prescribes for Algorithm 2). A lock-free
    /// insert-once snapshot table: readers pay a few atomic loads and an
    /// `Arc` clone — the `Mutex<HashMap>` this replaces serialized every
    /// concurrent bucket sync on one lock (perf pass, ISSUE 2).
    domains: OnceMap<Arc<Vec<Vec<u32>>>>,
    /// Overflow tier once the fixed table fills (> 64 distinct
    /// dense_lens, e.g. a bucket plan with many buckets): still cached —
    /// never recomputed per sync — but behind a lock, matching the old
    /// `Mutex<HashMap>` cost only for these rare extra keys.
    domains_overflow: Mutex<Vec<(usize, Arc<Vec<Vec<u32>>>)>>,
    /// How many times partition domains were actually computed — the
    /// exactly-once-per-(dense_len, seed) regression hook.
    domain_computes: AtomicUsize,
    /// Charge the measured hashing wall time into the report.
    pub charge_compute: bool,
}

impl Zen {
    /// `n`: number of partitions (= machines). Paper defaults (§4.2):
    /// k = 3, r1 = 2·E[nnz], r2 = r1/10.
    pub fn new(master_seed: u64, n: usize, expected_nnz: usize, format: ZenIndexFormat) -> Self {
        Self::with_hasher(
            HierarchicalHasher::with_defaults(master_seed, n, expected_nnz),
            format,
        )
    }

    /// Build from an explicit hasher (parameter studies).
    pub fn with_hasher(hasher: HierarchicalHasher, format: ZenIndexFormat) -> Self {
        Zen {
            hasher,
            format,
            domains: OnceMap::with_capacity(DOMAIN_CACHE_CAPACITY),
            domains_overflow: Mutex::new(Vec::new()),
            domain_computes: AtomicUsize::new(0),
            charge_compute: true,
        }
    }

    pub fn hasher(&self) -> &HierarchicalHasher {
        &self.hasher
    }

    /// Number of times this instance computed partition domains from
    /// scratch. With the snapshot cache this equals the number of
    /// distinct `dense_len`s synced (the hash seed is fixed per
    /// instance), regardless of sync count or concurrency.
    pub fn domain_compute_count(&self) -> usize {
        self.domain_computes.load(Ordering::Relaxed)
    }

    fn domains_for(&self, dense_len: usize) -> Arc<Vec<Vec<u32>>> {
        if let Some(d) = self.domains.get_or_init(dense_len, || {
            self.domain_computes.fetch_add(1, Ordering::Relaxed);
            Arc::new(self.hasher.partition_domains(dense_len))
        }) {
            return d.clone();
        }
        // Fast table full of other dense_lens: the overflow tier still
        // caches (compute under the lock, after a re-check, so
        // exactly-once holds here too).
        let mut overflow = self.domains_overflow.lock().unwrap();
        if let Some((_, d)) = overflow.iter().find(|(k, _)| *k == dense_len) {
            return d.clone();
        }
        self.domain_computes.fetch_add(1, Ordering::Relaxed);
        let d = Arc::new(self.hasher.partition_domains(dense_len));
        overflow.push((dense_len, d.clone()));
        d
    }
}

impl SyncScheme for Zen {
    fn name(&self) -> &'static str {
        match self.format {
            ZenIndexFormat::HashBitmap => "Zen",
            ZenIndexFormat::Coo => "Zen-COO",
            ZenIndexFormat::NaiveBitmap => "Zen-naive-bitmap",
        }
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::PointToPoint,
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Balanced,
            format: match self.format {
                ZenIndexFormat::HashBitmap => "COO push / hash bitmap pull",
                ZenIndexFormat::Coo => "COO",
                ZenIndexFormat::NaiveBitmap => "COO push / bitmap pull",
            },
        }
    }

    fn sync_transport(
        &self,
        inputs: &[CooTensor],
        tx: &mut dyn Transport,
        scratch: &mut SyncScratch,
    ) -> Result<SyncResult, crate::wire::WireError> {
        let n = inputs.len();
        assert_eq!(n, tx.endpoints());
        assert_eq!(self.hasher.n, n, "Zen hasher partitions must equal endpoints");
        let dense_len = inputs[0].dense_len;

        // --- Push: hash-partition on every worker (Alg 1) into reused
        // per-worker scratch, then frame each foreign partition straight
        // out of its zero-copy view — the send side never materializes
        // owned tensors.
        let sw = crate::util::Stopwatch::start();
        if scratch.partitions.len() < n {
            scratch
                .partitions
                .resize_with(n, crate::hashing::PartitionScratch::new);
        }
        for (t, ps) in inputs.iter().zip(scratch.partitions.iter_mut()) {
            self.hasher.partition_into(t, ps);
        }
        // Workers hash in parallel in the real system; charge the max.
        let hash_time = sw.elapsed() / n as f64;

        let partitions = &scratch.partitions[..n];
        for (w, ps) in partitions.iter().enumerate() {
            for p in 0..n {
                if p != w {
                    tx.send(w, p, push_frame_slice(w, ps.part(p)))?;
                }
            }
        }

        // --- One-shot aggregation at each server: server p merges its
        // own partition-p view with the n−1 shards it received.
        let mut received: Vec<Vec<CooTensor>> = Vec::with_capacity(n);
        for p in 0..n {
            let mut got = Vec::with_capacity(n - 1);
            for _ in 0..n.saturating_sub(1) {
                got.push(expect_push(tx.recv(p)?).1);
            }
            received.push(got);
        }
        let mut views: Vec<CooSlice<'_>> = Vec::with_capacity(n);
        let aggregated: Vec<CooTensor> = (0..n)
            .map(|p| {
                views.clear();
                views.push(partitions[p].part(p));
                views.extend(received[p].iter().map(|t| t.as_slice()));
                CooTensor::merge_all_slices(&views)
            })
            .collect();
        tx.end_stage("push")?;

        // --- Pull: broadcast each server's aggregate in the configured
        // index format; every worker decodes what it receives and merges
        // the (disjoint) aggregated partitions.
        let mut enc_time = 0.0f64;
        let outputs: Vec<CooTensor> = match self.format {
            ZenIndexFormat::Coo => {
                for (p, agg) in aggregated.iter().enumerate() {
                    for w in 0..n {
                        if w != p {
                            tx.send(p, w, pull_frame(p, agg))?;
                        }
                    }
                }
                let mut outputs = Vec::with_capacity(n);
                for w in 0..n {
                    let mut pieces: Vec<CooTensor> = Vec::with_capacity(n - 1);
                    for _ in 0..n.saturating_sub(1) {
                        pieces.push(expect_pull_coo(tx.recv(w)?).1);
                    }
                    outputs.push(merge_with_own(&pieces, &aggregated[w]));
                }
                outputs
            }
            ZenIndexFormat::HashBitmap => {
                let domains = self.domains_for(dense_len);
                for (p, agg) in aggregated.iter().enumerate() {
                    let codec = HashBitmapCodec::new(&domains[p]);
                    let sw = crate::util::Stopwatch::start();
                    codec.encode_into(agg.as_slice(), &mut scratch.payload);
                    enc_time += sw.elapsed();
                    for w in 0..n {
                        if w != p {
                            tx.send(
                                p,
                                w,
                                FrameRef::PullHashBitmap {
                                    server: p as u32,
                                    bitmap: &scratch.payload.bitmap,
                                    values: &scratch.payload.values,
                                },
                            )?;
                        }
                    }
                }
                let mut outputs = Vec::with_capacity(n);
                for w in 0..n {
                    let mut pieces: Vec<CooTensor> = Vec::with_capacity(n - 1);
                    for _ in 0..n.saturating_sub(1) {
                        match tx.recv(w)? {
                            Message::PullHashBitmap {
                                server,
                                bitmap,
                                values,
                            } => {
                                let codec = HashBitmapCodec::new(&domains[server as usize]);
                                let payload = HashBitmapPayload { bitmap, values };
                                pieces.push(codec.decode(&payload, dense_len));
                            }
                            other => panic!("zen pull expected PullHashBitmap, got {other:?}"),
                        }
                    }
                    outputs.push(merge_with_own(&pieces, &aggregated[w]));
                }
                outputs
            }
            ZenIndexFormat::NaiveBitmap => {
                // Naive positional bitmap over the WHOLE range + values
                // (§3.2.1's strawman: n·|G|/32 total, Fig 17).
                for (p, agg) in aggregated.iter().enumerate() {
                    let sw = crate::util::Stopwatch::start();
                    scratch.payload.bitmap.reset(dense_len);
                    for &i in &agg.indices {
                        scratch.payload.bitmap.set(i as usize);
                    }
                    enc_time += sw.elapsed();
                    for w in 0..n {
                        if w != p {
                            tx.send(
                                p,
                                w,
                                FrameRef::PullHashBitmap {
                                    server: p as u32,
                                    bitmap: &scratch.payload.bitmap,
                                    values: &agg.values,
                                },
                            )?;
                        }
                    }
                }
                let mut outputs = Vec::with_capacity(n);
                for w in 0..n {
                    let mut pieces: Vec<CooTensor> = Vec::with_capacity(n - 1);
                    for _ in 0..n.saturating_sub(1) {
                        match tx.recv(w)? {
                            Message::PullHashBitmap { bitmap, values, .. } => {
                                // positions are global indices directly
                                pieces.push(CooTensor::from_sorted(
                                    dense_len,
                                    bitmap.ones(),
                                    values,
                                ));
                            }
                            other => panic!("zen pull expected PullHashBitmap, got {other:?}"),
                        }
                    }
                    outputs.push(merge_with_own(&pieces, &aggregated[w]));
                }
                outputs
            }
        };
        tx.end_stage("pull")?;

        let mut report = tx.take_report();
        if self.charge_compute {
            report.compute_overhead += hash_time + enc_time / n as f64;
        }
        Ok(SyncResult { outputs, report })
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::util::Pcg64;

    #[test]
    fn correct_aggregation_all_formats() {
        let inputs = overlapping_inputs(1, 4, 4096, 120, 60);
        let net = Network::new(4, LinkKind::Tcp25);
        for fmt in [
            ZenIndexFormat::HashBitmap,
            ZenIndexFormat::Coo,
            ZenIndexFormat::NaiveBitmap,
        ] {
            let zen = Zen::new(7, 4, 200, fmt);
            let r = zen.sync(&inputs, &net);
            verify_outputs(&r, &inputs);
            assert_eq!(r.report.stages.len(), 2);
        }
    }

    #[test]
    fn push_balanced_under_skew() {
        // Skewed inputs that would crush Sparse PS server 0.
        let n = 8;
        let dense_len = 80_000;
        let mut rng = Pcg64::seeded(5);
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_distinct(dense_len / 10, 2_000)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                idx.sort_unstable();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; 2_000])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let zen = Zen::new(11, n, 2_000, ZenIndexFormat::HashBitmap);
        let r = zen.sync(&inputs, &net);
        let push = &r.report.stages[0];
        let total: u64 = push.recv.iter().sum();
        let max = *push.recv.iter().max().unwrap();
        let imbalance = max as f64 * n as f64 / total as f64;
        assert!(imbalance < 1.15, "push imbalance {imbalance}");
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn hash_bitmap_pull_cheaper_than_coo_when_dense() {
        // High aggregated density: COO pays 8B/nnz, hash bitmap pays
        // 4B/nnz + |G|/8 total.
        let n = 4;
        let dense_len = 8_192;
        let mut rng = Pcg64::seeded(9);
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_distinct(dense_len, dense_len / 3)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                idx.sort_unstable();
                let len = idx.len();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; len])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let coo_pull = Zen::new(3, n, dense_len / 3, ZenIndexFormat::Coo)
            .sync(&inputs, &net)
            .report
            .stages[1]
            .total_bytes();
        let hb_pull = Zen::new(3, n, dense_len / 3, ZenIndexFormat::HashBitmap)
            .sync(&inputs, &net)
            .report
            .stages[1]
            .total_bytes();
        assert!(hb_pull < coo_pull, "hash bitmap {hb_pull} vs COO {coo_pull}");
    }

    #[test]
    fn naive_bitmap_scales_with_n() {
        // Total pull index bytes: hash bitmap → |G|/8 per worker,
        // naive bitmap → n·|G|/8 per worker.
        let dense_len = 16_384;
        for n in [2usize, 8] {
            let idx: Vec<u32> = (0..64).collect();
            let inputs: Vec<CooTensor> = (0..n)
                .map(|_| CooTensor::from_sorted(dense_len, idx.clone(), vec![1.0; 64]))
                .collect();
            let net = Network::new(n, LinkKind::Tcp25);
            let naive = Zen::new(3, n, 64, ZenIndexFormat::NaiveBitmap).sync(&inputs, &net);
            // per-worker pull recv from n-1 servers
            let per_worker: u64 = naive.report.stages[1].recv[0];
            let bitmap_part = (n - 1) as u64 * (dense_len as u64 / 8);
            assert!(per_worker >= bitmap_part);
        }
    }

    #[test]
    fn domains_computed_exactly_once_per_dense_len() {
        // Regression for the Mutex<HashMap> → OnceMap swap: repeated
        // syncs at one (dense_len, seed) must compute domains once, a
        // second dense_len exactly one more time, and reusing scratch
        // across syncs must not change the answer.
        let zen = Zen::new(7, 4, 200, ZenIndexFormat::HashBitmap);
        let net = Network::new(4, LinkKind::Tcp25);
        let inputs_a = overlapping_inputs(1, 4, 4096, 120, 60);
        let inputs_b = overlapping_inputs(2, 4, 8192, 100, 50);
        assert_eq!(zen.domain_compute_count(), 0);
        let mut scratch = SyncScratch::new();
        for _ in 0..5 {
            let r = zen.sync_with(&inputs_a, &net, &mut scratch);
            verify_outputs(&r, &inputs_a);
        }
        assert_eq!(zen.domain_compute_count(), 1, "one compute per dense_len");
        for _ in 0..3 {
            zen.sync_with(&inputs_b, &net, &mut scratch);
        }
        assert_eq!(zen.domain_compute_count(), 2);
        zen.sync_with(&inputs_a, &net, &mut scratch);
        assert_eq!(zen.domain_compute_count(), 2, "cache hit on revisit");
    }

    #[test]
    fn domains_still_cached_beyond_fast_table_capacity() {
        // More distinct dense_lens than the lock-free table holds: the
        // overflow tier must keep caching (exactly one compute per
        // dense_len across repeated rounds), not regress to
        // recompute-per-sync.
        let n = 2;
        let zen = Zen::new(5, n, 16, ZenIndexFormat::HashBitmap);
        let net = Network::new(n, LinkKind::Tcp25);
        let distinct = 70; // > DOMAIN_CACHE_CAPACITY
        for round in 0..2 {
            for i in 0..distinct {
                let dense_len = 64 + i * 8;
                let inputs: Vec<CooTensor> = (0..n)
                    .map(|w| {
                        let idx = vec![w as u32, 32 + w as u32];
                        CooTensor::from_sorted(dense_len, idx, vec![1.0, 2.0])
                    })
                    .collect();
                zen.sync(&inputs, &net);
            }
            assert_eq!(
                zen.domain_compute_count(),
                distinct,
                "round {round}: one compute per distinct dense_len"
            );
        }
    }

    #[test]
    fn domains_computed_exactly_once_under_concurrent_syncs() {
        // Eight threads race the first sync of one dense_len; the
        // OnceMap must run the domain computation exactly once.
        let zen = Zen::new(13, 4, 150, ZenIndexFormat::HashBitmap);
        let net = Network::new(4, LinkKind::Tcp25);
        let inputs = overlapping_inputs(3, 4, 4096, 80, 40);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let r = zen.sync(&inputs, &net);
                    verify_outputs(&r, &inputs);
                });
            }
        });
        assert_eq!(zen.domain_compute_count(), 1);
    }

    #[test]
    fn hasher_partition_count_must_match() {
        let inputs = overlapping_inputs(2, 4, 1000, 10, 10);
        let net = Network::new(4, LinkKind::Tcp25);
        let zen = Zen::new(7, 8, 100, ZenIndexFormat::Coo); // wrong n
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            zen.sync(&inputs, &net)
        }));
        assert!(result.is_err());
    }
}
