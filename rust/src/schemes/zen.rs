//! Zen — the paper's system (§3): Balanced Parallelism realized by the
//! hierarchical hashing algorithm (Alg 1) + hash bitmap Pull format
//! (Alg 2).
//!
//! Push: every worker partitions its non-zero indices with the shared
//! hash family (same master seed on all workers → consistent assignment)
//! and point-to-point pushes COO partitions to the servers. Theorem 2
//! guarantees every server receives `≈ nnz/n`.
//!
//! Pull: each server encodes its aggregated partition as a hash bitmap
//! over its partition domain `𝕀_p` plus the values, and broadcasts it.
//! Theorem 3: total index overhead per worker is a constant `|G|/32`
//! FP32-equivalents. The COO-Pull variant exists for the Fig 18 ablation,
//! and a naive positional bitmap variant for Fig 17's comparison.
//!
//! Each rank is a sans-IO machine. Frame counts are deterministic
//! (every worker pushes to every server, every server broadcasts its
//! pull, empty or not), so both stages consume exactly `n−1` frames via
//! `NeedFrame` and aggregate inside `poll` — where the machine has the
//! [`SyncScratch`] it hashes and encodes into. Hashing and encode wall
//! time is accumulated per machine into a shared per-sync accumulator
//! (each rank contributes its own `(hash + encode)/n`, reproducing the
//! orchestrated "workers hash in parallel, charge the max" estimate)
//! and charged into the report by [`Zen`]'s `run` override.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::*;
use crate::hashing::{HashBitmapCodec, HashBitmapPayload, HierarchicalHasher};
use crate::util::OnceMap;
use crate::wire::{Event, Inbox, Message};

/// Which index representation Pull uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ZenIndexFormat {
    /// Algorithm 2 (the full Zen system).
    HashBitmap,
    /// COO pull — "Zen (COO)" ablation in Fig 18.
    Coo,
    /// Naive positional bitmap over the whole range (§3.2.1's strawman:
    /// `n·|G|/32` total) — included to regenerate Fig 17.
    NaiveBitmap,
}

/// Capacity of the lock-free tier of the per-scheme domain cache:
/// distinct `dense_len`s one Zen instance is asked to sync. The engine
/// produces one dense length per bucket; 64 covers every workload in
/// the repo with headroom. Keys beyond capacity are still cached in a
/// mutex-guarded overflow tier — never recomputed per sync.
const DOMAIN_CACHE_CAPACITY: usize = 64;

/// The Zen synchronization scheme.
pub struct Zen {
    hasher: HierarchicalHasher,
    format: ZenIndexFormat,
    /// Partition domains keyed by dense_len (computed offline per h0,
    /// exactly as the paper prescribes for Algorithm 2). A lock-free
    /// insert-once snapshot table: readers pay a few atomic loads and an
    /// `Arc` clone — the `Mutex<HashMap>` this replaces serialized every
    /// concurrent bucket sync on one lock (perf pass, ISSUE 2).
    domains: OnceMap<Arc<Vec<Vec<u32>>>>,
    /// Overflow tier once the fixed table fills (> 64 distinct
    /// dense_lens, e.g. a bucket plan with many buckets): still cached —
    /// never recomputed per sync — but behind a lock, matching the old
    /// `Mutex<HashMap>` cost only for these rare extra keys.
    domains_overflow: Mutex<Vec<(usize, Arc<Vec<Vec<u32>>>)>>,
    /// How many times partition domains were actually computed — the
    /// exactly-once-per-(dense_len, seed) regression hook.
    domain_computes: AtomicUsize,
    /// Charge the measured hashing wall time into the report.
    pub charge_compute: bool,
}

impl Zen {
    /// `n`: number of partitions (= machines). Paper defaults (§4.2):
    /// k = 3, r1 = 2·E[nnz], r2 = r1/10.
    pub fn new(master_seed: u64, n: usize, expected_nnz: usize, format: ZenIndexFormat) -> Self {
        Self::with_hasher(
            HierarchicalHasher::with_defaults(master_seed, n, expected_nnz),
            format,
        )
    }

    /// Build from an explicit hasher (parameter studies).
    pub fn with_hasher(hasher: HierarchicalHasher, format: ZenIndexFormat) -> Self {
        Zen {
            hasher,
            format,
            domains: OnceMap::with_capacity(DOMAIN_CACHE_CAPACITY),
            domains_overflow: Mutex::new(Vec::new()),
            domain_computes: AtomicUsize::new(0),
            charge_compute: true,
        }
    }

    pub fn hasher(&self) -> &HierarchicalHasher {
        &self.hasher
    }

    /// Number of times this instance computed partition domains from
    /// scratch. With the snapshot cache this equals the number of
    /// distinct `dense_len`s synced (the hash seed is fixed per
    /// instance), regardless of sync count or concurrency.
    pub fn domain_compute_count(&self) -> usize {
        self.domain_computes.load(Ordering::Relaxed)
    }

    fn domains_for(&self, dense_len: usize) -> Arc<Vec<Vec<u32>>> {
        if let Some(d) = self.domains.get_or_init(dense_len, || {
            self.domain_computes.fetch_add(1, Ordering::Relaxed);
            Arc::new(self.hasher.partition_domains(dense_len))
        }) {
            return d.clone();
        }
        // Fast table full of other dense_lens: the overflow tier still
        // caches (compute under the lock, after a re-check, so
        // exactly-once holds here too).
        let mut overflow = crate::wire::lock_or_panic(&self.domains_overflow, "domain cache");
        if let Some((_, d)) = overflow.iter().find(|(k, _)| *k == dense_len) {
            return d.clone();
        }
        self.domain_computes.fetch_add(1, Ordering::Relaxed);
        let d = Arc::new(self.hasher.partition_domains(dense_len));
        overflow.push((dense_len, d.clone()));
        d
    }

    /// Build the per-rank machines sharing one compute-time accumulator.
    /// The accumulator belongs to one sync, never to the (possibly
    /// concurrently shared) scheme instance.
    fn machines<'a>(
        &'a self,
        inputs: &'a [CooTensor],
        compute: Arc<Mutex<f64>>,
    ) -> Vec<Box<dyn Protocol + 'a>> {
        let n = inputs.len();
        assert_eq!(self.hasher.n, n, "Zen hasher partitions must equal endpoints");
        let dense_len = inputs[0].dense_len;
        let domains = match self.format {
            ZenIndexFormat::HashBitmap => Some(self.domains_for(dense_len)),
            _ => None,
        };
        (0..n)
            .map(|rank| {
                Box::new(ZenMachine {
                    rank,
                    n,
                    dense_len,
                    scheme: self,
                    inputs,
                    domains: domains.clone(),
                    compute: compute.clone(),
                    inbox: Inbox::new(n),
                    state: ZenState::Push,
                    cursor: 0,
                    hashed: false,
                    encoded: false,
                    pending: std::collections::VecDeque::new(),
                    agg: None,
                    output: None,
                }) as Box<dyn Protocol + 'a>
            })
            .collect()
    }
}

impl SyncScheme for Zen {
    fn name(&self) -> &'static str {
        match self.format {
            ZenIndexFormat::HashBitmap => "Zen",
            ZenIndexFormat::Coo => "Zen-COO",
            ZenIndexFormat::NaiveBitmap => "Zen-naive-bitmap",
        }
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::PointToPoint,
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Balanced,
            format: match self.format {
                ZenIndexFormat::HashBitmap => "COO push / hash bitmap pull",
                ZenIndexFormat::Coo => "COO",
                ZenIndexFormat::NaiveBitmap => "COO push / bitmap pull",
            },
        }
    }

    fn protocols<'a>(&'a self, inputs: &'a [CooTensor]) -> Vec<Box<dyn Protocol + 'a>> {
        // Callers driving the machines directly get correct frames and
        // bytes; compute-time charging needs `run`, which keeps the
        // accumulator and folds it into the report.
        self.machines(inputs, Arc::new(Mutex::new(0.0)))
    }

    fn run(
        &self,
        inputs: &[CooTensor],
        driver: &mut dyn Driver,
        scratch: &mut SyncScratch,
    ) -> Result<SyncOutput, WireError> {
        let compute = Arc::new(Mutex::new(0.0f64));
        let outcome = driver.drive(self.machines(inputs, compute.clone()), scratch)?;
        let mut report = outcome.report;
        if self.charge_compute {
            report.compute_overhead += *crate::wire::lock_or_panic(&compute, "compute accumulator");
        }
        Ok(SyncOutput {
            outputs: outcome.outputs,
            report,
        })
    }
}

enum ZenState {
    /// Hash-partition, push foreign shards, consume n−1, aggregate.
    Push,
    PushParked,
    /// Encode + broadcast the aggregate, consume n−1, assemble output.
    Pull,
    PullParked,
    Done,
}

struct ZenMachine<'a> {
    rank: usize,
    n: usize,
    dense_len: usize,
    scheme: &'a Zen,
    inputs: &'a [CooTensor],
    /// Partition domains (hash-bitmap format only).
    domains: Option<Arc<Vec<Vec<u32>>>>,
    /// Per-sync compute-time accumulator shared by all machines.
    compute: Arc<Mutex<f64>>,
    inbox: Inbox,
    state: ZenState,
    cursor: usize,
    hashed: bool,
    encoded: bool,
    /// Pull frames staged at encode time, emitted one per poll.
    pending: std::collections::VecDeque<(usize, Message)>,
    /// This server's aggregated partition.
    agg: Option<CooTensor>,
    output: Option<CooTensor>,
}

impl ZenMachine<'_> {
    fn charge(&self, seconds: f64) {
        *crate::wire::lock_or_panic(&self.compute, "compute accumulator") +=
            seconds / self.n as f64;
    }

    /// First peer (ascending) whose frame has not arrived yet, if any.
    fn missing_peer(&self) -> Option<usize> {
        (0..self.n).find(|&w| w != self.rank && self.inbox.from_src(w) == 0)
    }
}

impl Protocol for ZenMachine<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn poll(&mut self, scratch: &mut SyncScratch) -> Result<Event, WireError> {
        match self.state {
            ZenState::Push => {
                if !self.hashed {
                    self.hashed = true;
                    if scratch.partitions.len() < self.n {
                        scratch
                            .partitions
                            .resize_with(self.n, crate::hashing::PartitionScratch::new);
                    }
                    // Alg 1 on this rank's own input only; in the real
                    // system workers hash in parallel, so each rank
                    // charges its own time divided by n.
                    let sw = crate::util::Stopwatch::start();
                    self.scheme
                        .hasher
                        .partition_into(&self.inputs[self.rank], &mut scratch.partitions[self.rank]);
                    self.charge(sw.elapsed());
                }
                while self.cursor < self.n {
                    let p = self.cursor;
                    self.cursor += 1;
                    if p != self.rank {
                        let msg = push_msg_slice(self.rank, scratch.partitions[self.rank].part(p));
                        return Ok(Event::Send { dst: p, msg });
                    }
                }
                if let Some(w) = self.missing_peer() {
                    return Ok(Event::NeedFrame { src: w });
                }
                // One-shot aggregation: own partition-p view first, then
                // the shards in ascending-worker order (the orchestrated
                // global-FIFO order).
                let received: Vec<CooTensor> = self
                    .inbox
                    .drain_ascending()
                    .into_iter()
                    .map(|(_, msg)| expect_push(msg).1)
                    .collect();
                let mut views: Vec<CooSlice<'_>> = Vec::with_capacity(self.n);
                views.push(scratch.partitions[self.rank].part(self.rank));
                views.extend(received.iter().map(|t| t.as_slice()));
                self.agg = Some(CooTensor::merge_all_slices(&views));
                self.state = ZenState::PushParked;
                Ok(Event::StageDone { name: "push" })
            }
            ZenState::PushParked => Ok(Event::StageDone { name: "push" }),
            ZenState::Pull => {
                if !self.encoded {
                    self.encoded = true;
                    let agg = state(self.agg.as_ref(), "aggregated partition");
                    match self.scheme.format {
                        ZenIndexFormat::Coo => {
                            for w in 0..self.n {
                                if w != self.rank {
                                    self.pending.push_back((w, pull_msg(self.rank, agg)));
                                }
                            }
                        }
                        ZenIndexFormat::HashBitmap => {
                            let domains = state(self.domains.as_ref(), "domains computed");
                            let codec = HashBitmapCodec::new(&domains[self.rank]);
                            let sw = crate::util::Stopwatch::start();
                            codec.encode_into(agg.as_slice(), &mut scratch.payload);
                            self.charge(sw.elapsed());
                            for w in 0..self.n {
                                if w != self.rank {
                                    self.pending.push_back((
                                        w,
                                        Message::PullHashBitmap {
                                            server: small_u32(self.rank, "server rank"),
                                            bitmap: scratch.payload.bitmap.clone(),
                                            values: scratch.payload.values.clone(),
                                        },
                                    ));
                                }
                            }
                        }
                        ZenIndexFormat::NaiveBitmap => {
                            // Naive positional bitmap over the WHOLE
                            // range + values (§3.2.1: n·|G|/32, Fig 17).
                            let sw = crate::util::Stopwatch::start();
                            scratch.payload.bitmap.reset(self.dense_len);
                            for &i in &agg.indices {
                                scratch.payload.bitmap.set(i as usize);
                            }
                            self.charge(sw.elapsed());
                            for w in 0..self.n {
                                if w != self.rank {
                                    self.pending.push_back((
                                        w,
                                        Message::PullHashBitmap {
                                            server: small_u32(self.rank, "server rank"),
                                            bitmap: scratch.payload.bitmap.clone(),
                                            values: agg.values.clone(),
                                        },
                                    ));
                                }
                            }
                        }
                    }
                }
                if let Some((dst, msg)) = self.pending.pop_front() {
                    return Ok(Event::Send { dst, msg });
                }
                if let Some(w) = self.missing_peer() {
                    return Ok(Event::NeedFrame { src: w });
                }
                // Decode in ascending-server order and merge the
                // disjoint aggregated partitions with our own.
                let mut pieces: Vec<CooTensor> = Vec::with_capacity(self.n - 1);
                for (_, msg) in self.inbox.drain_ascending() {
                    let piece = match (self.scheme.format, msg) {
                        (ZenIndexFormat::Coo, msg) => expect_pull_coo(msg).1,
                        (
                            ZenIndexFormat::HashBitmap,
                            Message::PullHashBitmap {
                                server,
                                bitmap,
                                values,
                            },
                        ) => {
                            let domains = state(self.domains.as_ref(), "domains computed");
                            let codec = HashBitmapCodec::new(&domains[server as usize]);
                            let payload = HashBitmapPayload { bitmap, values };
                            codec.decode(&payload, self.dense_len)
                        }
                        (
                            ZenIndexFormat::NaiveBitmap,
                            Message::PullHashBitmap { bitmap, values, .. },
                        ) => {
                            // positions are global indices directly
                            CooTensor::from_sorted(self.dense_len, bitmap.ones(), values)
                        }
                        (_, other) => panic!("zen pull expected PullHashBitmap, got {other:?}"),
                    };
                    pieces.push(piece);
                }
                self.output = Some(merge_with_own(
                    &pieces,
                    state(self.agg.as_ref(), "aggregated partition"),
                ));
                self.state = ZenState::PullParked;
                Ok(Event::StageDone { name: "pull" })
            }
            ZenState::PullParked => Ok(Event::StageDone { name: "pull" }),
            ZenState::Done => Ok(Event::Complete(state(
                self.output.take(),
                "output assembled",
            ))),
        }
    }

    fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
        self.inbox.push(src, msg);
        Ok(())
    }

    fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
        match name {
            "push" => self.state = ZenState::Pull,
            "pull" => self.state = ZenState::Done,
            other => panic!("Zen: unknown stage '{other}' closed"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::util::Pcg64;

    fn run(zen: &Zen, inputs: &[CooTensor], net: &Network) -> SyncOutput {
        zen.run_sim(inputs, net, &mut SyncScratch::new())
    }

    #[test]
    fn correct_aggregation_all_formats() {
        let inputs = overlapping_inputs(1, 4, 4096, 120, 60);
        let net = Network::new(4, LinkKind::Tcp25);
        for fmt in [
            ZenIndexFormat::HashBitmap,
            ZenIndexFormat::Coo,
            ZenIndexFormat::NaiveBitmap,
        ] {
            let zen = Zen::new(7, 4, 200, fmt);
            let r = run(&zen, &inputs, &net);
            verify_outputs(&r, &inputs);
            assert_eq!(r.report.stages.len(), 2);
        }
    }

    #[test]
    fn push_balanced_under_skew() {
        // Skewed inputs that would crush Sparse PS server 0.
        let n = 8;
        let dense_len = 80_000;
        let mut rng = Pcg64::seeded(5);
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_distinct(dense_len / 10, 2_000)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                idx.sort_unstable();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; 2_000])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let zen = Zen::new(11, n, 2_000, ZenIndexFormat::HashBitmap);
        let r = run(&zen, &inputs, &net);
        let push = &r.report.stages[0];
        let total: u64 = push.recv.iter().sum();
        let max = *push.recv.iter().max().unwrap();
        let imbalance = max as f64 * n as f64 / total as f64;
        assert!(imbalance < 1.15, "push imbalance {imbalance}");
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn hash_bitmap_pull_cheaper_than_coo_when_dense() {
        // High aggregated density: COO pays 8B/nnz, hash bitmap pays
        // 4B/nnz + |G|/8 total.
        let n = 4;
        let dense_len = 8_192;
        let mut rng = Pcg64::seeded(9);
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_distinct(dense_len, dense_len / 3)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                idx.sort_unstable();
                let len = idx.len();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; len])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let coo_zen = Zen::new(3, n, dense_len / 3, ZenIndexFormat::Coo);
        let coo_pull = run(&coo_zen, &inputs, &net).report.stages[1].total_bytes();
        let hb_zen = Zen::new(3, n, dense_len / 3, ZenIndexFormat::HashBitmap);
        let hb_pull = run(&hb_zen, &inputs, &net).report.stages[1].total_bytes();
        assert!(hb_pull < coo_pull, "hash bitmap {hb_pull} vs COO {coo_pull}");
    }

    #[test]
    fn naive_bitmap_scales_with_n() {
        // Total pull index bytes: hash bitmap → |G|/8 per worker,
        // naive bitmap → n·|G|/8 per worker.
        let dense_len = 16_384;
        for n in [2usize, 8] {
            let idx: Vec<u32> = (0..64).collect();
            let inputs: Vec<CooTensor> = (0..n)
                .map(|_| CooTensor::from_sorted(dense_len, idx.clone(), vec![1.0; 64]))
                .collect();
            let net = Network::new(n, LinkKind::Tcp25);
            let zen = Zen::new(3, n, 64, ZenIndexFormat::NaiveBitmap);
            let naive = run(&zen, &inputs, &net);
            // per-worker pull recv from n-1 servers
            let per_worker: u64 = naive.report.stages[1].recv[0];
            let bitmap_part = (n - 1) as u64 * (dense_len as u64 / 8);
            assert!(per_worker >= bitmap_part);
        }
    }

    #[test]
    fn domains_computed_exactly_once_per_dense_len() {
        // Regression for the Mutex<HashMap> → OnceMap swap: repeated
        // syncs at one (dense_len, seed) must compute domains once, a
        // second dense_len exactly one more time, and reusing scratch
        // across syncs must not change the answer.
        let zen = Zen::new(7, 4, 200, ZenIndexFormat::HashBitmap);
        let net = Network::new(4, LinkKind::Tcp25);
        let inputs_a = overlapping_inputs(1, 4, 4096, 120, 60);
        let inputs_b = overlapping_inputs(2, 4, 8192, 100, 50);
        assert_eq!(zen.domain_compute_count(), 0);
        let mut scratch = SyncScratch::new();
        for _ in 0..5 {
            let r = zen.run_sim(&inputs_a, &net, &mut scratch);
            verify_outputs(&r, &inputs_a);
        }
        assert_eq!(zen.domain_compute_count(), 1, "one compute per dense_len");
        for _ in 0..3 {
            zen.run_sim(&inputs_b, &net, &mut scratch);
        }
        assert_eq!(zen.domain_compute_count(), 2);
        zen.run_sim(&inputs_a, &net, &mut scratch);
        assert_eq!(zen.domain_compute_count(), 2, "cache hit on revisit");
    }

    #[test]
    fn domains_still_cached_beyond_fast_table_capacity() {
        // More distinct dense_lens than the lock-free table holds: the
        // overflow tier must keep caching (exactly one compute per
        // dense_len across repeated rounds), not regress to
        // recompute-per-sync.
        let n = 2;
        let zen = Zen::new(5, n, 16, ZenIndexFormat::HashBitmap);
        let net = Network::new(n, LinkKind::Tcp25);
        let distinct = 70; // > DOMAIN_CACHE_CAPACITY
        for round in 0..2 {
            for i in 0..distinct {
                let dense_len = 64 + i * 8;
                let inputs: Vec<CooTensor> = (0..n)
                    .map(|w| {
                        let idx = vec![w as u32, 32 + w as u32];
                        CooTensor::from_sorted(dense_len, idx, vec![1.0, 2.0])
                    })
                    .collect();
                run(&zen, &inputs, &net);
            }
            assert_eq!(
                zen.domain_compute_count(),
                distinct,
                "round {round}: one compute per distinct dense_len"
            );
        }
    }

    #[test]
    fn domains_computed_exactly_once_under_concurrent_syncs() {
        // Eight threads race the first sync of one dense_len; the
        // OnceMap must run the domain computation exactly once.
        let zen = Zen::new(13, 4, 150, ZenIndexFormat::HashBitmap);
        let net = Network::new(4, LinkKind::Tcp25);
        let inputs = overlapping_inputs(3, 4, 4096, 80, 40);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let r = run(&zen, &inputs, &net);
                    verify_outputs(&r, &inputs);
                });
            }
        });
        assert_eq!(zen.domain_compute_count(), 1);
    }

    #[test]
    fn hasher_partition_count_must_match() {
        let inputs = overlapping_inputs(2, 4, 1000, 10, 10);
        let net = Network::new(4, LinkKind::Tcp25);
        let zen = Zen::new(7, 8, 100, ZenIndexFormat::Coo); // wrong n
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run(&zen, &inputs, &net)
        }));
        assert!(result.is_err());
    }
}
