//! SparCML — SSAR_Recursive_double (Renggli et al., paper §2.3.3).
//!
//! Sparse allreduce with recursive doubling: `log n` stages; at stage `s`
//! each node exchanges its *current partial aggregate* with the partner
//! at distance `2^s` (a `PushCoo` frame each way) and merges
//! incrementally (Hierarchy, Incremental, Centralization in Table 2).
//! Densification bites: stage-`s` payloads have density `d^(2^s)`, so
//! overlapped gradients are shipped repeatedly — Lemma 5's slack versus
//! Balanced Parallelism.
//!
//! Non-power-of-two node counts use the standard pre/post folding step:
//! the excess nodes first send their tensor to a partner inside the
//! power-of-two core, and receive the final aggregate back at the end.

use super::*;

/// SparCML SSAR recursive-doubling scheme.
#[derive(Clone, Debug, Default)]
pub struct SparCml;

impl SparCml {
    pub fn new() -> Self {
        SparCml
    }
}

impl SyncScheme for SparCml {
    fn name(&self) -> &'static str {
        "SparCML"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::Hierarchy,
            aggregation: AggPattern::Incremental,
            partition: PartitionPattern::Centralization,
            balance: BalancePattern::NotApplicable,
            format: "COO",
        }
    }

    fn sync_transport(
        &self,
        inputs: &[CooTensor],
        tx: &mut dyn Transport,
        _scratch: &mut SyncScratch,
    ) -> Result<SyncResult, crate::wire::WireError> {
        let n = inputs.len();
        assert_eq!(n, tx.endpoints());
        if n == 1 {
            return Ok(SyncResult {
                outputs: vec![inputs[0].clone()],
                report: tx.take_report(),
            });
        }

        // Largest power of two ≤ n.
        let core = crate::util::largest_pow2_at_most(n);
        let excess = n - core;
        // Current partial aggregate per node.
        let mut partial: Vec<CooTensor> = inputs.to_vec();

        // Pre-fold: node core+j sends its tensor to node j, which merges.
        if excess > 0 {
            for j in 0..excess {
                let src = core + j;
                tx.send(src, j, push_frame(src, &partial[src]))?;
            }
            for j in 0..excess {
                let (_, t) = expect_push(tx.recv(j)?);
                partial[j] = partial[j].merge(&t);
            }
            tx.end_stage("fold-in")?;
        }

        // Recursive doubling within the core: all sends of a stage leave
        // before any merge, so partners exchange the same snapshot.
        let mut dist = 1usize;
        while dist < core {
            for (i, t) in partial.iter().enumerate().take(core) {
                tx.send(i, i ^ dist, push_frame(i, t))?;
            }
            for i in 0..core {
                let (from, t) = expect_push(tx.recv(i)?);
                assert_eq!(from as usize, i ^ dist, "recursive-doubling partner");
                partial[i] = partial[i].merge(&t);
            }
            tx.end_stage("rec-double")?;
            dist <<= 1;
        }

        // Post-fold: send the final aggregate back to the excess nodes.
        if excess > 0 {
            for j in 0..excess {
                tx.send(j, core + j, push_frame(j, &partial[j]))?;
            }
            for j in 0..excess {
                let (_, t) = expect_push(tx.recv(core + j)?);
                partial[core + j] = t;
            }
            tx.end_stage("fold-out")?;
        }

        Ok(SyncResult {
            outputs: partial,
            report: tx.take_report(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::wire::codec::COO_FRAME_OVERHEAD;

    #[test]
    fn power_of_two_correct() {
        let inputs = overlapping_inputs(1, 8, 4000, 80, 40);
        let net = Network::new(8, LinkKind::Tcp25);
        let r = SparCml::new().sync(&inputs, &net);
        verify_outputs(&r, &inputs);
        assert_eq!(r.report.stages.len(), 3);
    }

    #[test]
    fn non_power_of_two_correct() {
        for n in [3usize, 5, 6, 7, 12] {
            let inputs = overlapping_inputs(n as u64, n, 2000, 40, 30);
            let net = Network::new(n, LinkKind::Tcp25);
            let r = SparCml::new().sync(&inputs, &net);
            verify_outputs(&r, &inputs);
        }
    }

    #[test]
    fn payload_grows_with_densification() {
        // With disjoint tensors, the stage-s COO payload (frame overhead
        // excluded) doubles every stage.
        let n = 8;
        let nnz = 100usize;
        let inputs: Vec<CooTensor> = (0..n as u32)
            .map(|w| {
                let idx: Vec<u32> = (0..nnz as u32).map(|i| w * nnz as u32 + i).collect();
                CooTensor::from_sorted(nnz * n, idx, vec![1.0; nnz])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = SparCml::new().sync(&inputs, &net);
        let payload: Vec<u64> = r
            .report
            .stages
            .iter()
            .map(|s| s.sent[0] - COO_FRAME_OVERHEAD as u64)
            .collect();
        assert_eq!(payload.len(), 3);
        assert_eq!(payload[1], payload[0] * 2);
        assert_eq!(payload[2], payload[0] * 4);
    }

    #[test]
    fn full_overlap_payload_constant() {
        // Identical index sets: densification ratio 1, payload constant
        // across stages — but the overlap is still shipped log n times.
        let n = 8;
        let idx: Vec<u32> = (0..100).collect();
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| CooTensor::from_sorted(1000, idx.clone(), vec![1.0; 100]))
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = SparCml::new().sync(&inputs, &net);
        let per_stage: Vec<u64> = r.report.stages.iter().map(|s| s.sent[0]).collect();
        assert!(per_stage.windows(2).all(|w| w[0] == w[1]));
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn single_node_noop() {
        let inputs = overlapping_inputs(9, 1, 500, 10, 10);
        let net = Network::new(1, LinkKind::Tcp25);
        let r = SparCml::new().sync(&inputs, &net);
        assert_eq!(r.report.total_bytes(), 0);
        verify_outputs(&r, &inputs);
    }
}
