//! SparCML — SSAR_Recursive_double (Renggli et al., paper §2.3.3).
//!
//! Sparse allreduce with recursive doubling: `log n` stages; at stage `s`
//! each node exchanges its *current partial aggregate* with the partner
//! at distance `2^s` (a `PushCoo` frame each way) and merges
//! incrementally (Hierarchy, Incremental, Centralization in Table 2).
//! Densification bites: stage-`s` payloads have density `d^(2^s)`, so
//! overlapped gradients are shipped repeatedly — Lemma 5's slack versus
//! Balanced Parallelism.
//!
//! Non-power-of-two node counts use the standard pre/post folding step:
//! the excess nodes first send their tensor to a partner inside the
//! power-of-two core, and receive the final aggregate back at the end.
//!
//! Each rank is a sans-IO machine: per doubling stage it emits its
//! partial-aggregate snapshot to the partner *before* consuming the
//! partner's frame, so both sides exchange pre-merge snapshots exactly
//! as the orchestrated loop did (all sends of a stage leave before any
//! merge).

use super::*;
use crate::util::largest_pow2_at_most;
use crate::wire::{Event, Inbox};

/// SparCML SSAR recursive-doubling scheme.
#[derive(Clone, Debug, Default)]
pub struct SparCml;

impl SparCml {
    pub fn new() -> Self {
        SparCml
    }
}

impl SyncScheme for SparCml {
    fn name(&self) -> &'static str {
        "SparCML"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::Hierarchy,
            aggregation: AggPattern::Incremental,
            partition: PartitionPattern::Centralization,
            balance: BalancePattern::NotApplicable,
            format: "COO",
        }
    }

    fn protocols<'a>(&'a self, inputs: &'a [CooTensor]) -> Vec<Box<dyn Protocol + 'a>> {
        (0..inputs.len())
            .map(|rank| Box::new(SparCmlMachine::new(rank, inputs)) as Box<dyn Protocol + 'a>)
            .collect()
    }
}

enum CmlPhase {
    /// Fold-in stage (skipped when n is a power of two).
    FoldIn,
    /// Doubling stage at distance `dist`.
    Double { dist: usize },
    /// Fold the aggregate back out to the excess ranks.
    FoldOut,
    Done,
}

struct SparCmlMachine<'a> {
    rank: usize,
    core: usize,
    excess: usize,
    inputs: &'a [CooTensor],
    inbox: Inbox,
    phase: CmlPhase,
    sent: bool,
    parked: bool,
    /// The running partial aggregate (starts as this rank's input).
    partial: Option<CooTensor>,
}

impl<'a> SparCmlMachine<'a> {
    fn new(rank: usize, inputs: &'a [CooTensor]) -> SparCmlMachine<'a> {
        let n = inputs.len();
        let core = largest_pow2_at_most(n);
        let excess = n - core;
        SparCmlMachine {
            rank,
            core,
            excess,
            inputs,
            inbox: Inbox::new(n),
            phase: if n == 1 {
                CmlPhase::Done
            } else if excess > 0 {
                CmlPhase::FoldIn
            } else {
                CmlPhase::Double { dist: 1 }
            },
            sent: false,
            parked: false,
            partial: Some(inputs[rank].clone()),
        }
    }
}

impl Protocol for SparCmlMachine<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
        loop {
            match self.phase {
                CmlPhase::FoldIn => {
                    if self.parked {
                        return Ok(Event::StageDone { name: "fold-in" });
                    }
                    if self.rank >= self.core {
                        // Excess rank: ship the tensor into the core.
                        if !self.sent {
                            self.sent = true;
                            let j = self.rank - self.core;
                            let msg =
                                push_msg(self.rank, state(self.partial.as_ref(), "partial"));
                            return Ok(Event::Send { dst: j, msg });
                        }
                        self.parked = true;
                        return Ok(Event::StageDone { name: "fold-in" });
                    }
                    if self.rank < self.excess {
                        // Fold target: merge exactly one frame.
                        let src = self.core + self.rank;
                        match self.inbox.take_from(src) {
                            Some(msg) => {
                                let (_, t) = expect_push(msg);
                                let p = state(self.partial.take(), "partial");
                                self.partial = Some(p.merge(&t));
                                self.parked = true;
                                return Ok(Event::StageDone { name: "fold-in" });
                            }
                            None => return Ok(Event::NeedFrame { src }),
                        }
                    }
                    self.parked = true;
                    return Ok(Event::StageDone { name: "fold-in" });
                }
                CmlPhase::Double { dist } => {
                    if dist >= self.core {
                        self.phase = if self.excess > 0 {
                            CmlPhase::FoldOut
                        } else {
                            CmlPhase::Done
                        };
                        continue;
                    }
                    if self.parked {
                        return Ok(Event::StageDone { name: "rec-double" });
                    }
                    if self.rank >= self.core {
                        // Excess ranks sit out the doubling.
                        self.parked = true;
                        return Ok(Event::StageDone { name: "rec-double" });
                    }
                    let peer = self.rank ^ dist;
                    if !self.sent {
                        self.sent = true;
                        let msg = push_msg(self.rank, state(self.partial.as_ref(), "partial"));
                        return Ok(Event::Send { dst: peer, msg });
                    }
                    match self.inbox.take_from(peer) {
                        Some(msg) => {
                            let (from, t) = expect_push(msg);
                            assert_eq!(from as usize, peer, "recursive-doubling partner");
                            let p = state(self.partial.take(), "partial");
                            self.partial = Some(p.merge(&t));
                            self.parked = true;
                            return Ok(Event::StageDone { name: "rec-double" });
                        }
                        None => return Ok(Event::NeedFrame { src: peer }),
                    }
                }
                CmlPhase::FoldOut => {
                    if self.parked {
                        return Ok(Event::StageDone { name: "fold-out" });
                    }
                    if self.rank < self.excess {
                        // Return the final aggregate to the excess rank.
                        if !self.sent {
                            self.sent = true;
                            let msg =
                                push_msg(self.rank, state(self.partial.as_ref(), "partial"));
                            return Ok(Event::Send {
                                dst: self.core + self.rank,
                                msg,
                            });
                        }
                        self.parked = true;
                        return Ok(Event::StageDone { name: "fold-out" });
                    }
                    if self.rank >= self.core {
                        let src = self.rank - self.core;
                        match self.inbox.take_from(src) {
                            Some(msg) => {
                                self.partial = Some(expect_push(msg).1);
                                self.parked = true;
                                return Ok(Event::StageDone { name: "fold-out" });
                            }
                            None => return Ok(Event::NeedFrame { src }),
                        }
                    }
                    self.parked = true;
                    return Ok(Event::StageDone { name: "fold-out" });
                }
                CmlPhase::Done => {
                    return Ok(Event::Complete(state(
                        self.partial.take(),
                        "partial aggregate present",
                    )))
                }
            }
        }
    }

    fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
        self.inbox.push(src, msg);
        Ok(())
    }

    fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
        self.sent = false;
        self.parked = false;
        match name {
            "fold-in" => self.phase = CmlPhase::Double { dist: 1 },
            "rec-double" => {
                if let CmlPhase::Double { dist } = self.phase {
                    self.phase = CmlPhase::Double { dist: dist << 1 };
                } else {
                    panic!("SparCML: rec-double closed outside doubling");
                }
            }
            "fold-out" => self.phase = CmlPhase::Done,
            other => panic!("SparCML: unknown stage '{other}' closed"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::wire::codec::COO_FRAME_OVERHEAD;

    fn run(inputs: &[CooTensor], net: &Network) -> SyncOutput {
        SparCml::new().run_sim(inputs, net, &mut SyncScratch::new())
    }

    #[test]
    fn power_of_two_correct() {
        let inputs = overlapping_inputs(1, 8, 4000, 80, 40);
        let net = Network::new(8, LinkKind::Tcp25);
        let r = run(&inputs, &net);
        verify_outputs(&r, &inputs);
        assert_eq!(r.report.stages.len(), 3);
    }

    #[test]
    fn non_power_of_two_correct() {
        for n in [3usize, 5, 6, 7, 12] {
            let inputs = overlapping_inputs(n as u64, n, 2000, 40, 30);
            let net = Network::new(n, LinkKind::Tcp25);
            let r = run(&inputs, &net);
            verify_outputs(&r, &inputs);
        }
    }

    #[test]
    fn payload_grows_with_densification() {
        // With disjoint tensors, the stage-s COO payload (frame overhead
        // excluded) doubles every stage.
        let n = 8;
        let nnz = 100usize;
        let inputs: Vec<CooTensor> = (0..n as u32)
            .map(|w| {
                let idx: Vec<u32> = (0..nnz as u32).map(|i| w * nnz as u32 + i).collect();
                CooTensor::from_sorted(nnz * n, idx, vec![1.0; nnz])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = run(&inputs, &net);
        let payload: Vec<u64> = r
            .report
            .stages
            .iter()
            .map(|s| s.sent[0] - COO_FRAME_OVERHEAD as u64)
            .collect();
        assert_eq!(payload.len(), 3);
        assert_eq!(payload[1], payload[0] * 2);
        assert_eq!(payload[2], payload[0] * 4);
    }

    #[test]
    fn full_overlap_payload_constant() {
        // Identical index sets: densification ratio 1, payload constant
        // across stages — but the overlap is still shipped log n times.
        let n = 8;
        let idx: Vec<u32> = (0..100).collect();
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| CooTensor::from_sorted(1000, idx.clone(), vec![1.0; 100]))
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = run(&inputs, &net);
        let per_stage: Vec<u64> = r.report.stages.iter().map(|s| s.sent[0]).collect();
        assert!(per_stage.windows(2).all(|w| w[0] == w[1]));
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn single_node_noop() {
        let inputs = overlapping_inputs(9, 1, 500, 10, 10);
        let net = Network::new(1, LinkKind::Tcp25);
        let r = run(&inputs, &net);
        assert_eq!(r.report.total_bytes(), 0);
        verify_outputs(&r, &inputs);
    }
}
