//! Ok-Topk-style near-optimal sparse allreduce ("Near-Optimal Sparse
//! Allreduce for Distributed Deep Learning", PAPERS.md).
//!
//! Sparse PS partitions the dense range into `n` *even* contiguous
//! ranges, so skewed non-zero distributions (Definition 5) concentrate
//! traffic on one server. Ok-Topk instead *measures* the distribution
//! first and splits the range where the mass actually is:
//!
//! 1. `balance` — every rank broadcasts a coarse per-block non-zero
//!    histogram (`DenseChunk` frames carrying counts as f32 — exact for
//!    counts below 2^24). Every rank sums the histograms and computes
//!    the same balanced contiguous block→owner partition by prefix
//!    walking the totals: pure function of the summed histogram, so no
//!    coordinator round is needed.
//! 2. `scatter` — each rank ships its non-empty range slices to the
//!    partition owners (`PushCoo`, range-local indices; empty slices
//!    are never framed, so frame counts are data-dependent and the
//!    machines are receive-until-stage-closed).
//! 3. `gather` — each owner merges its slices (ascending-source order,
//!    bit-reproducible) and broadcasts the aggregated partition
//!    (`PullCoo`); ranks reassemble the full tensor at closure.
//!
//! The scheme is itself lossless — the lossy part of the Ok-Topk
//! construction (error-feedback Top-k selection) lives one layer up in
//! [`crate::compress`], composable with *any* scheme — but its
//! balanced split is what makes it the natural carrier for compressed
//! gradients, whose surviving non-zeros are even more skewed than raw
//! ones. The planner ranks it in the lossy tier (`--compress ...`).

use super::*;
use crate::wire::{Event, Inbox};

/// Block count of the balance histogram: fine enough for ~16 cut
/// candidates per owner, capped by the range itself. The cost model's
/// `oktopk` closed form prices the same count.
pub fn balance_blocks(dense_len: usize, n: usize) -> usize {
    let target = (16 * n.max(1)).min(dense_len.max(1));
    let block_len = crate::util::ceil_div(dense_len.max(1), target).max(1);
    crate::util::ceil_div(dense_len.max(1), block_len)
}

/// Ok-Topk sparse allreduce scheme.
#[derive(Clone, Debug, Default)]
pub struct OkTopk;

impl OkTopk {
    pub fn new() -> Self {
        OkTopk
    }
}

impl SyncScheme for OkTopk {
    fn name(&self) -> &'static str {
        "OkTopk"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::PointToPoint,
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Balanced,
            format: "COO",
        }
    }

    fn protocols<'a>(&'a self, inputs: &'a [CooTensor]) -> Vec<Box<dyn Protocol + 'a>> {
        let n = inputs.len();
        (0..n)
            .map(|rank| Box::new(OkMachine::new(rank, inputs)) as Box<dyn Protocol + 'a>)
            .collect()
    }
}

enum OkPhase {
    /// Broadcasting the per-block count histogram.
    BalanceSend,
    /// Parked on `balance`; partition is computed at stage closure.
    BalanceParked,
    /// Framing non-empty slices to the balanced-partition owners.
    ScatterSend,
    /// Parked on `scatter`; aggregation happens at stage closure.
    ScatterParked,
    /// Broadcasting the aggregated partition.
    GatherSend,
    /// Parked on `gather`; reassembly happens at stage closure.
    GatherParked,
    /// Output assembled, next poll completes.
    Done,
}

struct OkMachine<'a> {
    rank: usize,
    n: usize,
    dense_len: usize,
    block_len: usize,
    nblocks: usize,
    inputs: &'a [CooTensor],
    inbox: Inbox,
    phase: OkPhase,
    cursor: usize,
    /// Own per-block counts while balancing; the summed totals after.
    hist: Vec<f32>,
    /// Owner start positions in block units (`starts[n] = nblocks`).
    starts: Vec<u32>,
    /// This rank's own shard of its balanced partition.
    own: Option<CooTensor>,
    /// The aggregated partition this rank owns.
    agg: Option<CooTensor>,
    output: Option<CooTensor>,
}

impl<'a> OkMachine<'a> {
    fn new(rank: usize, inputs: &'a [CooTensor]) -> OkMachine<'a> {
        let n = inputs.len();
        let dense_len = inputs[0].dense_len;
        let nblocks = balance_blocks(dense_len, n);
        let block_len = crate::util::ceil_div(dense_len.max(1), nblocks).max(1);
        let mut hist = vec![0f32; nblocks];
        for &i in &inputs[rank].indices {
            hist[i as usize / block_len] += 1.0;
        }
        OkMachine {
            rank,
            n,
            dense_len,
            block_len,
            nblocks,
            inputs,
            inbox: Inbox::new(n),
            phase: OkPhase::BalanceSend,
            cursor: 0,
            hist,
            starts: Vec::new(),
            own: None,
            agg: None,
            output: None,
        }
    }

    /// Balanced contiguous block→owner split: owner `p` starts at the
    /// first block whose count prefix reaches `p/n` of the total. A
    /// pure function of the summed histogram, so every rank computes
    /// identical bounds without another round.
    fn compute_starts(&mut self) {
        let total: f64 = self.hist.iter().map(|&c| c as f64).sum();
        let target = total / self.n as f64;
        let mut starts = vec![0u32; self.n + 1];
        starts[self.n] = small_u32(self.nblocks, "histogram blocks");
        let mut acc = 0f64;
        let mut owner = 1;
        for b in 0..self.nblocks {
            while owner < self.n && acc >= target * owner as f64 {
                starts[owner] = small_u32(b, "histogram block");
                owner += 1;
            }
            acc += self.hist[b] as f64;
        }
        while owner < self.n {
            starts[owner] = small_u32(self.nblocks, "histogram blocks");
            owner += 1;
        }
        self.starts = starts;
    }

    fn lo(&self, p: usize) -> u32 {
        small_u32(
            (self.starts[p] as usize * self.block_len).min(self.dense_len),
            "partition offset",
        )
    }

    fn hi(&self, p: usize) -> u32 {
        small_u32(
            (self.starts[p + 1] as usize * self.block_len).min(self.dense_len),
            "partition end",
        )
    }
}

impl Protocol for OkMachine<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
        match self.phase {
            OkPhase::BalanceSend => {
                while self.cursor < self.n {
                    let p = self.cursor;
                    self.cursor += 1;
                    if p != self.rank {
                        return Ok(Event::Send {
                            dst: p,
                            msg: Message::DenseChunk {
                                from: small_u32(self.rank, "worker rank"),
                                offset: 0,
                                values: self.hist.clone(),
                            },
                        });
                    }
                }
                self.phase = OkPhase::BalanceParked;
                Ok(Event::StageDone { name: "balance" })
            }
            OkPhase::BalanceParked => Ok(Event::StageDone { name: "balance" }),
            OkPhase::ScatterSend => {
                while self.cursor < self.n {
                    let p = self.cursor;
                    self.cursor += 1;
                    let part = self.inputs[self.rank].slice_range(self.lo(p), self.hi(p));
                    if p == self.rank {
                        self.own = Some(part);
                    } else if part.nnz() > 0 {
                        return Ok(Event::Send {
                            dst: p,
                            msg: push_msg(self.rank, &part),
                        });
                    }
                }
                self.phase = OkPhase::ScatterParked;
                Ok(Event::StageDone { name: "scatter" })
            }
            OkPhase::ScatterParked => Ok(Event::StageDone { name: "scatter" }),
            OkPhase::GatherSend => {
                let nonempty = state(self.agg.as_ref(), "aggregated partition").nnz() > 0;
                if nonempty {
                    while self.cursor < self.n {
                        let w = self.cursor;
                        self.cursor += 1;
                        if w != self.rank {
                            let agg = state(self.agg.as_ref(), "aggregated partition");
                            let msg = pull_msg(self.rank, agg);
                            return Ok(Event::Send { dst: w, msg });
                        }
                    }
                }
                self.phase = OkPhase::GatherParked;
                Ok(Event::StageDone { name: "gather" })
            }
            OkPhase::GatherParked => Ok(Event::StageDone { name: "gather" }),
            OkPhase::Done => Ok(Event::Complete(state(
                self.output.take(),
                "output assembled at gather closure",
            ))),
        }
    }

    fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
        self.inbox.push(src, msg);
        Ok(())
    }

    fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
        match name {
            "balance" => {
                // Counts are small integers, so the f32 additions are
                // exact in any order; ascending drain keeps the walk
                // deterministic anyway.
                for (_, msg) in self.inbox.drain_ascending() {
                    match msg {
                        Message::DenseChunk { values, .. } => {
                            assert_eq!(values.len(), self.nblocks, "histogram shape");
                            for (t, v) in self.hist.iter_mut().zip(values.iter()) {
                                *t += v;
                            }
                        }
                        other => panic!("OkTopk balance: expected DenseChunk, got {other:?}"),
                    }
                }
                self.compute_starts();
                self.cursor = 0;
                self.phase = OkPhase::ScatterSend;
            }
            "scatter" => {
                let mut shards = vec![state(self.own.take(), "own shard present")];
                for (_, msg) in self.inbox.drain_ascending() {
                    shards.push(expect_push(msg).1);
                }
                self.agg = Some(CooTensor::merge_all(&shards));
                self.cursor = 0;
                self.phase = OkPhase::GatherSend;
            }
            "gather" => {
                let mut parts: Vec<(u32, CooTensor)> = Vec::with_capacity(self.n);
                parts.push((
                    self.lo(self.rank),
                    state(self.agg.take(), "aggregated partition"),
                ));
                for (_, msg) in self.inbox.drain_ascending() {
                    let (server, tensor) = expect_pull_coo(msg);
                    parts.push((self.lo(server as usize), tensor));
                }
                self.output = Some(CooTensor::concat_ranges(&parts, self.dense_len));
                self.phase = OkPhase::Done;
            }
            other => panic!("OkTopk: unknown stage '{other}' closed"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::util::Pcg64;

    #[test]
    fn correct_aggregation() {
        for n in [2usize, 3, 5, 6, 8] {
            let inputs = overlapping_inputs(9 ^ n as u64, n, 3000, 70, 30);
            let net = Network::new(n, LinkKind::Tcp25);
            let r = OkTopk::new().run_sim(&inputs, &net, &mut SyncScratch::new());
            verify_outputs(&r, &inputs);
            assert_eq!(r.report.stages.len(), 3, "balance + scatter + gather");
        }
    }

    /// The workload that breaks Sparse PS (all non-zeros in the first
    /// 1/8 of the range): the balanced partition must spread scatter
    /// traffic over many owners instead of one.
    fn skewed_inputs(n: usize, dense_len: usize, nnz: usize) -> Vec<CooTensor> {
        let mut rng = Pcg64::seeded(2);
        (0..n)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_distinct(dense_len / 8, nnz)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                idx.sort_unstable();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; nnz])
            })
            .collect()
    }

    #[test]
    fn skew_is_rebalanced_across_owners() {
        let n = 8;
        let inputs = skewed_inputs(n, 8_000, 200);
        let net = Network::new(n, LinkKind::Tcp25);
        let ok = OkTopk::new().run_sim(&inputs, &net, &mut SyncScratch::new());
        verify_outputs(&ok, &inputs);
        let scatter = &ok.report.stages[1];
        let receivers = scatter.recv.iter().filter(|&&b| b > 0).count();
        assert!(
            receivers >= n / 2,
            "balanced split must use many owners, got {receivers} ({:?})",
            scatter.recv
        );
        // Same workload through Sparse PS: everything lands on server 0.
        let ps = SparsePs::new().run_sim(&inputs, &net, &mut SyncScratch::new());
        let ps_receivers = ps.report.stages[0].recv.iter().filter(|&&b| b > 0).count();
        assert_eq!(ps_receivers, 1, "sparse PS concentrates the skew");
        assert!(
            ok.report.stages[1].recv_imbalance() < ps.report.stages[0].recv_imbalance(),
            "oktopk {} vs sparseps {}",
            ok.report.stages[1].recv_imbalance(),
            ps.report.stages[0].recv_imbalance()
        );
    }

    #[test]
    fn all_empty_inputs_complete_losslessly() {
        let n = 4;
        let inputs = vec![CooTensor::empty(4096); n];
        let net = Network::new(n, LinkKind::Tcp25);
        let r = OkTopk::new().run_sim(&inputs, &net, &mut SyncScratch::new());
        verify_outputs(&r, &inputs);
        // Only the balance histograms move: scatter and gather frame
        // nothing for empty partitions.
        assert!(r.report.stages[0].sent.iter().all(|&b| b > 0));
        assert!(r.report.stages[1].sent.iter().all(|&b| b == 0));
        assert!(r.report.stages[2].sent.iter().all(|&b| b == 0));
    }

    #[test]
    fn balance_blocks_is_bounded_and_positive() {
        assert_eq!(balance_blocks(0, 4), 1);
        assert!(balance_blocks(10, 4) <= 10);
        assert!(balance_blocks(1 << 20, 8) >= 64);
        for n in [1usize, 2, 7, 64] {
            for len in [1usize, 5, 4096, 1 << 18] {
                let b = balance_blocks(len, n);
                assert!(b >= 1 && b <= len.max(1), "n={n} len={len} b={b}");
            }
        }
    }
}
