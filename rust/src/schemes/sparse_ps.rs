//! Sparse Parameter Server (paper §2.3.3).
//!
//! Point-to-point, one-shot, Parallelism — but the tensor is partitioned
//! into `n` **contiguous even ranges**, so the skewed distribution of
//! non-zero gradients (Definition 5, Fig 2) concentrates traffic on one
//! server: Push imbalance equals the skewness ratio and Pull inherits it.
//! Servers are colocated with workers (server `p` on machine `p`), as in
//! BytePS-style deployments.
//!
//! Push ships each worker's non-empty range slices as `PushCoo` frames
//! (range-local indices); Pull broadcasts each server's aggregated
//! partition as `PullCoo` frames. Empty payloads are never framed — a
//! partition that holds no non-zeros generates no traffic at all.

use super::*;

/// Sparse PS scheme.
#[derive(Clone, Debug, Default)]
pub struct SparsePs;

impl SparsePs {
    pub fn new() -> Self {
        SparsePs
    }
}

impl SyncScheme for SparsePs {
    fn name(&self) -> &'static str {
        "SparsePS"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::PointToPoint,
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Imbalanced,
            format: "COO",
        }
    }

    fn sync_transport(
        &self,
        inputs: &[CooTensor],
        tx: &mut dyn Transport,
        _scratch: &mut SyncScratch,
    ) -> Result<SyncResult, crate::wire::WireError> {
        let n = inputs.len();
        assert_eq!(n, tx.endpoints());
        let dense_len = inputs[0].dense_len;
        let per = crate::util::ceil_div(dense_len, n) as u32;
        let lo = |p: usize| (p as u32 * per).min(dense_len as u32);
        let hi = |p: usize| ((p as u32 + 1) * per).min(dense_len as u32);

        // Push: worker w frames contiguous partition p to server p.
        let mut own: Vec<Option<CooTensor>> = (0..n).map(|_| None).collect();
        let mut expected = vec![0usize; n];
        for (w, t) in inputs.iter().enumerate() {
            for p in 0..n {
                let part = t.slice_range(lo(p), hi(p));
                if w == p {
                    own[p] = Some(part);
                } else if part.nnz() > 0 {
                    tx.send(w, p, push_frame(w, &part))?;
                    expected[p] += 1;
                }
            }
        }

        // One-shot aggregation at each server.
        let mut aggregated: Vec<CooTensor> = Vec::with_capacity(n);
        for p in 0..n {
            let mut shards = vec![own[p].take().expect("own shard present")];
            for _ in 0..expected[p] {
                shards.push(expect_push(tx.recv(p)?).1);
            }
            aggregated.push(CooTensor::merge_all(&shards));
        }
        tx.end_stage("push")?;

        // Pull: server p point-to-point broadcasts its aggregated
        // partition to every worker (existing PS implementations, App. B).
        let mut expected = vec![0usize; n];
        for (p, agg) in aggregated.iter().enumerate() {
            if agg.nnz() == 0 {
                continue;
            }
            for w in 0..n {
                if w != p {
                    tx.send(p, w, pull_frame(p, agg))?;
                    expected[w] += 1;
                }
            }
        }

        // Reassemble the full tensor at every worker.
        let mut outputs = Vec::with_capacity(n);
        for w in 0..n {
            let mut parts: Vec<(u32, CooTensor)> = Vec::with_capacity(n);
            parts.push((lo(w), aggregated[w].clone()));
            for _ in 0..expected[w] {
                let (server, tensor) = expect_pull_coo(tx.recv(w)?);
                parts.push((lo(server as usize), tensor));
            }
            outputs.push(CooTensor::concat_ranges(&parts, dense_len));
        }
        tx.end_stage("pull")?;

        Ok(SyncResult {
            outputs,
            report: tx.take_report(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::util::Pcg64;
    use crate::wire::codec::COO_FRAME_OVERHEAD;

    #[test]
    fn correct_aggregation() {
        let inputs = overlapping_inputs(1, 6, 3000, 70, 30);
        let net = Network::new(6, LinkKind::Tcp25);
        let r = SparsePs::new().sync(&inputs, &net);
        verify_outputs(&r, &inputs);
        assert_eq!(r.report.stages.len(), 2);
    }

    #[test]
    fn skew_concentrates_push_on_one_server() {
        // All non-zeros in the first 1/8 of the range → server 0 receives
        // everything; push imbalance ≈ n.
        let n = 8;
        let dense_len = 8_000;
        let mut rng = Pcg64::seeded(2);
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_distinct(dense_len / 8, 200)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                idx.sort_unstable();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; 200])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = SparsePs::new().sync(&inputs, &net);
        let push = &r.report.stages[0];
        let recv0 = push.recv[0];
        let recv_rest: u64 = push.recv[1..].iter().sum();
        assert!(recv0 > 0);
        assert_eq!(recv_rest, 0, "all traffic should hit server 0");
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn uniform_input_is_balanced() {
        let n = 4;
        let dense_len = 40_000;
        let mut rng = Pcg64::seeded(3);
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_distinct(dense_len, 4_000)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                idx.sort_unstable();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; 4_000])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = SparsePs::new().sync(&inputs, &net);
        assert!(r.report.recv_imbalance() < 1.15);
    }

    #[test]
    fn payload_is_8_bytes_per_nnz_plus_frame() {
        // Two workers, disjoint halves: worker 1's nnz all in partition 0.
        let a = CooTensor::from_sorted(100, vec![0, 1, 2], vec![1.0; 3]);
        let b = CooTensor::from_sorted(100, vec![3, 4], vec![1.0; 2]);
        let net = Network::new(2, LinkKind::Tcp25);
        let r = SparsePs::new().sync(&[a, b], &net);
        // push: b frames its 2 entries (both < 50) to server 0 → 16 B of
        // COO payload + one frame of overhead; a has nothing for
        // server 1, so no frame at all.
        assert_eq!(
            r.report.stages[0].recv[0],
            16 + COO_FRAME_OVERHEAD as u64
        );
        assert_eq!(r.report.stages[0].recv[1], 0);
    }
}
