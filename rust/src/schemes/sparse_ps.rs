//! Sparse Parameter Server (paper §2.3.3).
//!
//! Point-to-point, one-shot, Parallelism — but the tensor is partitioned
//! into `n` **contiguous even ranges**, so the skewed distribution of
//! non-zero gradients (Definition 5, Fig 2) concentrates traffic on one
//! server: Push imbalance equals the skewness ratio and Pull inherits it.
//! Servers are colocated with workers (server `p` on machine `p`), as in
//! BytePS-style deployments.

use super::*;

/// Sparse PS scheme.
#[derive(Clone, Debug, Default)]
pub struct SparsePs;

impl SparsePs {
    pub fn new() -> Self {
        SparsePs
    }
}

impl SyncScheme for SparsePs {
    fn name(&self) -> &'static str {
        "SparsePS"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::PointToPoint,
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Imbalanced,
            format: "COO",
        }
    }

    fn sync_with(
        &self,
        inputs: &[CooTensor],
        net: &Network,
        _scratch: &mut SyncScratch,
    ) -> SyncResult {
        let n = inputs.len();
        assert_eq!(n, net.endpoints);
        let dense_len = inputs[0].dense_len;
        let per = crate::util::ceil_div(dense_len, n) as u32;

        // Push: worker w sends contiguous partition p to server p.
        // Payload: COO entries (4B local index + 4B value).
        let mut push = vec![vec![0u64; n]; n];
        // server p's received shards (including its own, free locally)
        let mut shards: Vec<Vec<CooTensor>> = vec![Vec::with_capacity(n); n];
        for (w, t) in inputs.iter().enumerate() {
            for p in 0..n {
                let lo = (p as u32 * per).min(dense_len as u32);
                let hi = ((p as u32 + 1) * per).min(dense_len as u32);
                let part = t.slice_range(lo, hi);
                if w != p {
                    push[w][p] = crate::tensor::WireFormat::wire_bytes(&part) as u64;
                }
                shards[p].push(part);
            }
        }
        let mut report = CommReport::new();
        report.push(net.stage_from_matrix("push", &push));

        // One-shot aggregation at each server.
        let aggregated: Vec<CooTensor> = shards
            .iter()
            .map(|parts| CooTensor::merge_all(parts))
            .collect();

        // Pull: server p point-to-point broadcasts its aggregated
        // partition to every worker (existing PS implementations, App. B).
        let mut pull = vec![vec![0u64; n]; n];
        for (p, row) in pull.iter_mut().enumerate() {
            let bytes = crate::tensor::WireFormat::wire_bytes(&aggregated[p]) as u64;
            for (w, cell) in row.iter_mut().enumerate() {
                if w != p {
                    *cell = bytes;
                }
            }
        }
        report.push(net.stage_from_matrix("pull", &pull));

        // Reassemble the full tensor at every worker.
        let parts: Vec<(u32, CooTensor)> = aggregated
            .iter()
            .enumerate()
            .map(|(p, t)| ((p as u32 * per).min(dense_len as u32), t.clone()))
            .collect();
        let full = CooTensor::concat_ranges(&parts, dense_len);
        SyncResult {
            outputs: vec![full; n],
            report,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::util::Pcg64;

    #[test]
    fn correct_aggregation() {
        let inputs = overlapping_inputs(1, 6, 3000, 70, 30);
        let net = Network::new(6, LinkKind::Tcp25);
        let r = SparsePs::new().sync(&inputs, &net);
        verify_outputs(&r, &inputs);
        assert_eq!(r.report.stages.len(), 2);
    }

    #[test]
    fn skew_concentrates_push_on_one_server() {
        // All non-zeros in the first 1/8 of the range → server 0 receives
        // everything; push imbalance ≈ n.
        let n = 8;
        let dense_len = 8_000;
        let mut rng = Pcg64::seeded(2);
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_distinct(dense_len / 8, 200)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                idx.sort_unstable();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; 200])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = SparsePs::new().sync(&inputs, &net);
        let push = &r.report.stages[0];
        let recv0 = push.recv[0];
        let recv_rest: u64 = push.recv[1..].iter().sum();
        assert!(recv0 > 0);
        assert_eq!(recv_rest, 0, "all traffic should hit server 0");
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn uniform_input_is_balanced() {
        let n = 4;
        let dense_len = 40_000;
        let mut rng = Pcg64::seeded(3);
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_distinct(dense_len, 4_000)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                idx.sort_unstable();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; 4_000])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = SparsePs::new().sync(&inputs, &net);
        assert!(r.report.recv_imbalance() < 1.15);
    }

    #[test]
    fn payload_is_8_bytes_per_nnz() {
        // Two workers, disjoint halves: worker 1's nnz all in partition 0.
        let a = CooTensor::from_sorted(100, vec![0, 1, 2], vec![1.0; 3]);
        let b = CooTensor::from_sorted(100, vec![3, 4], vec![1.0; 2]);
        let net = Network::new(2, LinkKind::Tcp25);
        let r = SparsePs::new().sync(&[a, b], &net);
        // push: b sends its 2 entries (both < 50) to server 0 → 16 bytes;
        // a sends nothing to server 1.
        assert_eq!(r.report.stages[0].recv[0], 16);
        assert_eq!(r.report.stages[0].recv[1], 0);
    }
}
