//! Sparse Parameter Server (paper §2.3.3).
//!
//! Point-to-point, one-shot, Parallelism — but the tensor is partitioned
//! into `n` **contiguous even ranges**, so the skewed distribution of
//! non-zero gradients (Definition 5, Fig 2) concentrates traffic on one
//! server: Push imbalance equals the skewness ratio and Pull inherits it.
//! Servers are colocated with workers (server `p` on machine `p`), as in
//! BytePS-style deployments.
//!
//! Push ships each worker's non-empty range slices as `PushCoo` frames
//! (range-local indices); Pull broadcasts each server's aggregated
//! partition as `PullCoo` frames. Empty payloads are never framed — a
//! partition that holds no non-zeros generates no traffic at all, which
//! is why the per-rank machines are receive-until-stage-closed: the
//! frame count is data-dependent, so a server aggregates whatever its
//! inbox holds when the `push` stage closes (ascending-source order,
//! reproducing the orchestrated merge order bit for bit).

use super::*;
use crate::wire::{Event, Inbox};

/// Sparse PS scheme.
#[derive(Clone, Debug, Default)]
pub struct SparsePs;

impl SparsePs {
    pub fn new() -> Self {
        SparsePs
    }
}

impl SyncScheme for SparsePs {
    fn name(&self) -> &'static str {
        "SparsePS"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::PointToPoint,
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Imbalanced,
            format: "COO",
        }
    }

    fn protocols<'a>(&'a self, inputs: &'a [CooTensor]) -> Vec<Box<dyn Protocol + 'a>> {
        let n = inputs.len();
        (0..n)
            .map(|rank| Box::new(PsMachine::new(rank, inputs)) as Box<dyn Protocol + 'a>)
            .collect()
    }
}

enum PsState {
    /// Framing non-empty range slices to the other servers.
    PushSend,
    /// Parked on `push`; aggregation happens at stage closure.
    PushParked,
    /// Broadcasting the aggregated partition to the other workers.
    PullSend,
    /// Parked on `pull`; reassembly happens at stage closure.
    PullParked,
    /// Output assembled, next poll completes.
    Done,
}

struct PsMachine<'a> {
    rank: usize,
    n: usize,
    dense_len: usize,
    inputs: &'a [CooTensor],
    state: PsState,
    inbox: Inbox,
    cursor: usize,
    /// This rank's own shard of its server partition.
    own: Option<CooTensor>,
    /// The aggregated partition this rank serves.
    agg: Option<CooTensor>,
    output: Option<CooTensor>,
}

impl<'a> PsMachine<'a> {
    fn new(rank: usize, inputs: &'a [CooTensor]) -> PsMachine<'a> {
        let n = inputs.len();
        PsMachine {
            rank,
            n,
            dense_len: inputs[0].dense_len,
            inputs,
            state: PsState::PushSend,
            inbox: Inbox::new(n),
            cursor: 0,
            own: None,
            agg: None,
            output: None,
        }
    }

    fn per(&self) -> u32 {
        small_u32(
            crate::util::ceil_div(self.dense_len, self.n),
            "partition width",
        )
    }

    fn lo(&self, p: usize) -> u32 {
        (small_u32(p, "server rank") * self.per()).min(small_u32(self.dense_len, "dense length"))
    }

    fn hi(&self, p: usize) -> u32 {
        ((small_u32(p, "server rank") + 1) * self.per())
            .min(small_u32(self.dense_len, "dense length"))
    }
}

impl Protocol for PsMachine<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
        match self.state {
            PsState::PushSend => {
                while self.cursor < self.n {
                    let p = self.cursor;
                    self.cursor += 1;
                    let part = self.inputs[self.rank].slice_range(self.lo(p), self.hi(p));
                    if p == self.rank {
                        self.own = Some(part);
                    } else if part.nnz() > 0 {
                        return Ok(Event::Send {
                            dst: p,
                            msg: push_msg(self.rank, &part),
                        });
                    }
                }
                self.state = PsState::PushParked;
                Ok(Event::StageDone { name: "push" })
            }
            PsState::PushParked => Ok(Event::StageDone { name: "push" }),
            PsState::PullSend => {
                let nonempty = state(self.agg.as_ref(), "aggregated partition").nnz() > 0;
                if nonempty {
                    while self.cursor < self.n {
                        let w = self.cursor;
                        self.cursor += 1;
                        if w != self.rank {
                            let agg = state(self.agg.as_ref(), "aggregated partition");
                            let msg = pull_msg(self.rank, agg);
                            return Ok(Event::Send { dst: w, msg });
                        }
                    }
                }
                self.state = PsState::PullParked;
                Ok(Event::StageDone { name: "pull" })
            }
            PsState::PullParked => Ok(Event::StageDone { name: "pull" }),
            PsState::Done => Ok(Event::Complete(state(
                self.output.take(),
                "output assembled at pull closure",
            ))),
        }
    }

    fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
        self.inbox.push(src, msg);
        Ok(())
    }

    fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
        match name {
            "push" => {
                // One-shot aggregation: own shard first, then the
                // received shards in ascending-worker order (the old
                // orchestrated global-FIFO order).
                let mut shards = vec![state(self.own.take(), "own shard present")];
                for (_, msg) in self.inbox.drain_ascending() {
                    shards.push(expect_push(msg).1);
                }
                self.agg = Some(CooTensor::merge_all(&shards));
                self.cursor = 0;
                self.state = PsState::PullSend;
            }
            "pull" => {
                let mut parts: Vec<(u32, CooTensor)> = Vec::with_capacity(self.n);
                parts.push((
                    self.lo(self.rank),
                    state(self.agg.take(), "aggregated partition"),
                ));
                for (_, msg) in self.inbox.drain_ascending() {
                    let (server, tensor) = expect_pull_coo(msg);
                    parts.push((self.lo(server as usize), tensor));
                }
                self.output = Some(CooTensor::concat_ranges(&parts, self.dense_len));
                self.state = PsState::Done;
            }
            other => panic!("SparsePS: unknown stage '{other}' closed"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::util::Pcg64;
    use crate::wire::codec::COO_FRAME_OVERHEAD;

    #[test]
    fn correct_aggregation() {
        let inputs = overlapping_inputs(1, 6, 3000, 70, 30);
        let net = Network::new(6, LinkKind::Tcp25);
        let r = SparsePs::new().run_sim(&inputs, &net, &mut SyncScratch::new());
        verify_outputs(&r, &inputs);
        assert_eq!(r.report.stages.len(), 2);
    }

    #[test]
    fn skew_concentrates_push_on_one_server() {
        // All non-zeros in the first 1/8 of the range → server 0 receives
        // everything; push imbalance ≈ n.
        let n = 8;
        let dense_len = 8_000;
        let mut rng = Pcg64::seeded(2);
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_distinct(dense_len / 8, 200)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                idx.sort_unstable();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; 200])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = SparsePs::new().run_sim(&inputs, &net, &mut SyncScratch::new());
        let push = &r.report.stages[0];
        let recv0 = push.recv[0];
        let recv_rest: u64 = push.recv[1..].iter().sum();
        assert!(recv0 > 0);
        assert_eq!(recv_rest, 0, "all traffic should hit server 0");
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn uniform_input_is_balanced() {
        let n = 4;
        let dense_len = 40_000;
        let mut rng = Pcg64::seeded(3);
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_distinct(dense_len, 4_000)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                idx.sort_unstable();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; 4_000])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = SparsePs::new().run_sim(&inputs, &net, &mut SyncScratch::new());
        assert!(r.report.recv_imbalance() < 1.15);
    }

    #[test]
    fn payload_is_8_bytes_per_nnz_plus_frame() {
        // Two workers, disjoint halves: worker 1's nnz all in partition 0.
        let a = CooTensor::from_sorted(100, vec![0, 1, 2], vec![1.0; 3]);
        let b = CooTensor::from_sorted(100, vec![3, 4], vec![1.0; 2]);
        let net = Network::new(2, LinkKind::Tcp25);
        let r = SparsePs::new().run_sim(&[a, b], &net, &mut SyncScratch::new());
        // push: b frames its 2 entries (both < 50) to server 0 → 16 B of
        // COO payload + one frame of overhead; a has nothing for
        // server 1, so no frame at all.
        assert_eq!(
            r.report.stages[0].recv[0],
            16 + COO_FRAME_OVERHEAD as u64
        );
        assert_eq!(r.report.stages[0].recv[1], 0);
    }
}
