//! Communication schemes for sparse tensor synchronization (paper §2.3).
//!
//! Every scheme implements [`SyncScheme`]: given one sparse gradient
//! tensor per machine, it builds one sans-IO
//! [`Protocol`](crate::wire::Protocol) state machine per rank
//! ([`SyncScheme::protocols`]); a [`Driver`](crate::wire::Driver) moves
//! the frames. The same protocol body runs the virtual-time simulator,
//! the real-frames mpsc fabric, the readiness-polled loopback socket
//! mesh, and one-rank-per-process deployment (`zen worker`) — the
//! single public entry point is [`SyncScheme::run`], with
//! [`SyncScheme::run_sim`] as the simulator convenience. Byte
//! accounting is observed by the driver, not hand-maintained per
//! scheme, so the [`CommReport`] a sync returns is byte-for-byte the
//! traffic its frames put on the data plane (frame headers included).
//!
//! The paper's four design dimensions (communication / aggregation /
//! partition / balance, Table 2) are exposed via [`SchemeDims`] so the
//! taxonomy table regenerates from the implementations themselves.

// Cargo `[lints]` tables are package-wide; the hardening guarantee is
// scoped to the protocol layer (wire/ + schemes/), so the denies live
// here as inner attributes (mirrors wire/mod.rs). Every waiver below is
// a scoped `#[allow]` with its reason next to it.
#![deny(
    clippy::cast_possible_truncation,
    clippy::unwrap_used,
    clippy::expect_used
)]

pub mod agsparse;
pub mod dense;
pub mod oktopk;
pub mod omnireduce;
pub mod sparcml;
pub mod sparse_ps;
pub mod strawman_scheme;
pub mod zen;

pub use agsparse::{AgPattern, AgSparse};
pub use dense::DenseAllReduce;
pub use oktopk::OkTopk;
pub use omnireduce::OmniReduce;
pub use sparcml::SparCml;
pub use sparse_ps::SparsePs;
pub use strawman_scheme::StrawmanScheme;
pub use zen::{Zen, ZenIndexFormat};

use crate::cluster::{CommReport, Network};
use crate::hashing::{HashBitmapPayload, PartitionScratch};
use crate::tensor::{CooSlice, CooTensor};
use crate::wire::{Driver, Message, Protocol, SimTransport, TransportDriver, WireError};

/// Table 2 dimension values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommPattern {
    Ring,
    Hierarchy,
    PointToPoint,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggPattern {
    Incremental,
    OneShot,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionPattern {
    Centralization,
    Parallelism,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BalancePattern {
    Balanced,
    Imbalanced,
    NotApplicable,
}

/// A scheme's position in the design space (Table 2 row).
#[derive(Clone, Debug)]
pub struct SchemeDims {
    pub communication: CommPattern,
    pub aggregation: AggPattern,
    pub partition: PartitionPattern,
    pub balance: BalancePattern,
    pub format: &'static str,
}

/// Result of synchronizing one tensor across all endpoints.
#[derive(Clone, Debug)]
pub struct SyncOutput {
    /// Aggregated tensor at each endpoint (must all equal the sum).
    pub outputs: Vec<CooTensor>,
    pub report: CommReport,
}

/// Pre-redesign name of [`SyncOutput`].
#[deprecated(since = "0.6.0", note = "renamed to SyncOutput")]
pub type SyncResult = SyncOutput;

/// Reusable working memory for one in-flight [`SyncScheme::run`] (or
/// [`run_sim`](SyncScheme::run_sim)) call — the scheme-level scratch
/// arena (see [`crate::util::arena`]).
///
/// One `SyncScratch` serves one concurrent synchronization at a time;
/// the engine checks one out per in-flight bucket from a
/// [`crate::util::ScratchPool`] so concurrent bucket syncs never
/// contend. Schemes use the fields they need (Zen the partition and
/// payload buffers, OmniReduce the block staging; the COO-only schemes
/// ignore it) and must leave the scratch in a reusable state — every
/// buffer is cleared by its consumer on the next call, so no cross-call
/// cleanup is required.
#[derive(Default)]
pub struct SyncScratch {
    /// Algorithm-1 scratch, one per worker input (grown on demand).
    pub partitions: Vec<PartitionScratch>,
    /// Hash-bitmap pull payload, reused across servers.
    pub payload: HashBitmapPayload,
    /// Hash-bitmap decode output buffers.
    pub decode_indices: Vec<u32>,
    pub decode_values: Vec<f32>,
    /// Flattened block payload staging (OmniReduce's `Blocks` frames).
    pub block_values: Vec<f32>,
}

impl SyncScratch {
    pub fn new() -> Self {
        SyncScratch::default()
    }
}

/// Convert a value that is structurally small (a rank bounded by the
/// machine count, a count bounded by a validated frame field) to the
/// `u32` the wire format carries. Panics with context on overflow
/// instead of silently truncating.
pub(crate) fn small_u32(v: usize, what: &str) -> u32 {
    match u32::try_from(v) {
        Ok(v) => v,
        Err(_) => panic!("{what} ({v}) exceeds u32 — the wire format carries 32-bit ids"),
    }
}

/// Take a staged value out of an `Option` slot that the protocol's
/// stage sequencing guarantees is filled. Panics with context when the
/// sequencing invariant is broken (a scheme bug, not recoverable input).
pub(crate) fn state<T>(slot: Option<T>, what: &str) -> T {
    match slot {
        Some(v) => v,
        None => panic!("protocol state missing: {what}"),
    }
}

/// An owned `PushCoo` message from worker `from` (what protocol
/// machines emit through [`Event::Send`](crate::wire::Event::Send)).
pub(crate) fn push_msg(from: usize, t: &CooTensor) -> Message {
    Message::PushCoo {
        from: small_u32(from, "worker rank"),
        tensor: t.clone(),
    }
}

/// An owned `PushCoo` message materialized from a borrowed COO view.
pub(crate) fn push_msg_slice(from: usize, t: CooSlice<'_>) -> Message {
    Message::PushCoo {
        from: small_u32(from, "worker rank"),
        tensor: CooTensor::from_sorted(t.dense_len, t.indices.to_vec(), t.values.to_vec()),
    }
}

/// An owned `PullCoo` message from server `server`.
pub(crate) fn pull_msg(server: usize, t: &CooTensor) -> Message {
    Message::PullCoo {
        server: small_u32(server, "server rank"),
        tensor: t.clone(),
    }
}

/// Unwrap a received frame as a `PushCoo`; panic with context otherwise
/// (a wrong kind mid-protocol is a scheme bug, not recoverable input).
pub(crate) fn expect_push(msg: crate::wire::Message) -> (u32, CooTensor) {
    match msg {
        crate::wire::Message::PushCoo { from, tensor } => (from, tensor),
        other => panic!("expected PushCoo, got {other:?}"),
    }
}

/// Unwrap a received frame as a `PullCoo`; panic with context otherwise.
pub(crate) fn expect_pull_coo(msg: crate::wire::Message) -> (u32, CooTensor) {
    match msg {
        crate::wire::Message::PullCoo { server, tensor } => (server, tensor),
        other => panic!("expected PullCoo, got {other:?}"),
    }
}

/// Merge received pieces with a node's own aggregate through borrowed
/// views — no clone of the owned tensors (the worker-side assembly step
/// of the push/pull schemes).
pub(crate) fn merge_with_own(pieces: &[CooTensor], own: &CooTensor) -> CooTensor {
    let mut views: Vec<CooSlice<'_>> = Vec::with_capacity(pieces.len() + 1);
    views.extend(pieces.iter().map(|t| t.as_slice()));
    views.push(own.as_slice());
    CooTensor::merge_all_slices(&views)
}

/// A communication scheme for synchronizing sparse gradient tensors.
pub trait SyncScheme: Send + Sync {
    fn name(&self) -> &'static str;

    /// Table 2 classification.
    fn dims(&self) -> SchemeDims;

    /// Build the scheme's per-rank sans-IO state machines for one
    /// synchronization — the one implementation every scheme provides.
    /// `protocols(inputs)[r]` plays rank `r`; machines borrow the
    /// inputs (and the scheme) for the duration of the sync. See
    /// [`crate::wire::protocol`] for the lifecycle contract.
    fn protocols<'a>(&'a self, inputs: &'a [CooTensor]) -> Vec<Box<dyn Protocol + 'a>>;

    /// Synchronize: every endpoint contributes one sparse tensor over
    /// the same dense range; every endpoint ends with the full
    /// aggregation. The single public entry point since the sans-IO
    /// redesign — the driver decides what the data plane physically is
    /// (virtual time, mpsc channels, kernel sockets, remote peers).
    ///
    /// Data-plane failures surface as `Err`: a hung-up channel or dead
    /// socket peer yields [`WireError::Disconnected`] mid-protocol
    /// instead of aborting the process, and an oversized frame is
    /// rejected as [`WireError::FrameTooLarge`]. Protocol violations
    /// (wrong frame kind mid-stage, mismatched endpoint counts) are
    /// scheme bugs and still panic.
    fn run(
        &self,
        inputs: &[CooTensor],
        driver: &mut dyn Driver,
        scratch: &mut SyncScratch,
    ) -> Result<SyncOutput, WireError> {
        let outcome = driver.drive(self.protocols(inputs), scratch)?;
        Ok(SyncOutput {
            outputs: outcome.outputs,
            report: outcome.report,
        })
    }

    /// Synchronize over the virtual-time simulator backend
    /// ([`SimTransport`] charging `net`'s α–β model) with
    /// caller-provided scratch memory — the hot path every figure and
    /// sweep runs on. Implementations must be oblivious to the
    /// scratch's previous contents, and callers must not share one
    /// scratch across concurrent calls.
    fn run_sim(
        &self,
        inputs: &[CooTensor],
        net: &Network,
        scratch: &mut SyncScratch,
    ) -> SyncOutput {
        let mut driver = TransportDriver::new(Box::new(SimTransport::new(net.clone())));
        // The in-process virtual-time backend has no peer to lose; an
        // error here is a scheme protocol bug, so the panic is correct
        // and the expect lint is waived for this one call.
        #[allow(clippy::expect_used)]
        let out = self
            .run(inputs, &mut driver, scratch)
            .expect("virtual-time sync failed (scheme protocol bug)");
        out
    }
}

/// Reference aggregation: dense element-wise sum of all inputs.
pub fn reference_sum(inputs: &[CooTensor]) -> crate::tensor::DenseTensor {
    assert!(!inputs.is_empty());
    let mut acc = crate::tensor::DenseTensor::zeros(inputs[0].dense_len);
    for t in inputs {
        assert_eq!(t.dense_len, acc.len());
        acc.add_coo(t);
    }
    acc
}

/// Assert one aggregated tensor equals the dense reference within float
/// tolerance (summation order differs across schemes); `what` labels
/// the failing site. Shared by [`verify_outputs`] and the engine's
/// per-layer verifier ([`crate::engine::verify_layer_outputs`]).
pub fn assert_matches_reference(
    out: &CooTensor,
    reference: &crate::tensor::DenseTensor,
    what: &str,
) {
    let dense = out.to_dense();
    assert_eq!(dense.len(), reference.len(), "{what} length");
    for i in 0..dense.len() {
        let (a, b) = (dense.values[i], reference.values[i]);
        let tol = 1e-5f32.max(b.abs() * 1e-5);
        assert!(
            (a - b).abs() <= tol,
            "{what}, index {i}: got {a}, reference {b}"
        );
    }
}

/// Assert all endpoint outputs equal the reference within float tolerance.
/// Panics with context on mismatch; used by tests and the coordinator's
/// self-check mode.
pub fn verify_outputs(result: &SyncOutput, inputs: &[CooTensor]) {
    let reference = reference_sum(inputs);
    for (e, out) in result.outputs.iter().enumerate() {
        assert_matches_reference(out, &reference, &format!("endpoint {e}"));
    }
}

/// Construct every scheme (for sweeps) at a given endpoint count.
/// `zen_seed` feeds Zen's hash family.
pub fn all_schemes(n: usize, zen_seed: u64, expected_nnz: usize) -> Vec<Box<dyn SyncScheme>> {
    vec![
        Box::new(DenseAllReduce::new()),
        Box::new(AgSparse::new(AgPattern::PointToPoint)),
        Box::new(SparCml::new()),
        Box::new(SparsePs::new()),
        Box::new(OmniReduce::new(crate::tensor::block::DEFAULT_BLOCK)),
        Box::new(Zen::new(zen_seed, n, expected_nnz, ZenIndexFormat::HashBitmap)),
    ]
}

/// The lossless scheme names the cost-model planner ranks — one per
/// Appendix-B closed form ([`crate::analysis::CostModel::time_for`]).
/// `crate::planner::CostPlanner` instantiates each via [`by_name`]; the
/// lossy strawman is excluded (a planner must never trade gradients
/// away silently).
pub const PLANNER_CANDIDATES: [&str; 7] = [
    "allreduce",
    "agsparse",
    "sparcml",
    "sparseps",
    "omnireduce",
    "zen-coo",
    "zen",
];

/// The candidate list the planner ranks when a lossy compression tier
/// is armed (`--compress topk:K|threshold:T`): every lossless candidate
/// plus the Ok-Topk balanced sparse allreduce, which only pays off on
/// the skewed survivor sets compression produces. The compressor itself
/// stays outside the scheme (error feedback in [`crate::compress`]),
/// so each candidate still synchronizes exactly — "lossy" is a property
/// of the tier, never of a scheme silently dropping gradients.
pub const LOSSY_TIER_CANDIDATES: [&str; 8] = [
    "allreduce",
    "agsparse",
    "sparcml",
    "sparseps",
    "omnireduce",
    "zen-coo",
    "zen",
    "oktopk",
];

/// Construct a scheme by CLI name. Recognized: `allreduce`/`dense`,
/// `agsparse`, `sparcml`, `sparseps`, `omnireduce`, `oktopk`, `zen`,
/// `zen-coo`, `strawman:<mem_multiple>` (lossy). `auto` is *not* a
/// scheme — it is
/// resolved one level up by `crate::planner::by_name` into a
/// cost-model-driven per-bucket choice among [`PLANNER_CANDIDATES`].
pub fn by_name(
    name: &str,
    n: usize,
    seed: u64,
    expected_nnz: usize,
) -> Option<Box<dyn SyncScheme>> {
    let lower = name.to_ascii_lowercase();
    if let Some(mult) = lower.strip_prefix("strawman:") {
        let m: f64 = mult.parse().ok()?;
        return Some(Box::new(StrawmanScheme::new(seed, n, expected_nnz, m)));
    }
    Some(match lower.as_str() {
        "allreduce" | "dense" => Box::new(DenseAllReduce::new()),
        "agsparse" => Box::new(AgSparse::new(AgPattern::PointToPoint)),
        "agsparse-ring" => Box::new(AgSparse::new(AgPattern::Ring)),
        "agsparse-hier" => Box::new(AgSparse::new(AgPattern::Hierarchy)),
        "sparcml" => Box::new(SparCml::new()),
        "sparseps" | "sparse-ps" => Box::new(SparsePs::new()),
        "oktopk" | "ok-topk" => Box::new(OkTopk::new()),
        "omnireduce" => Box::new(OmniReduce::new(crate::tensor::block::DEFAULT_BLOCK)),
        "zen" => Box::new(Zen::new(seed, n, expected_nnz, ZenIndexFormat::HashBitmap)),
        "zen-coo" => Box::new(Zen::new(seed, n, expected_nnz, ZenIndexFormat::Coo)),
        _ => return None,
    })
}

#[cfg(test)]
pub(crate) mod testutil {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

    use super::*;
    use crate::util::Pcg64;

    /// Random per-worker sparse tensors with a shared hot set (overlap)
    /// plus private tails — the §2.2 structure in miniature.
    pub fn overlapping_inputs(
        seed: u64,
        n: usize,
        dense_len: usize,
        shared: usize,
        private: usize,
    ) -> Vec<CooTensor> {
        let mut rng = Pcg64::seeded(seed);
        let hot: Vec<usize> = rng.sample_distinct(dense_len, shared);
        (0..n)
            .map(|w| {
                let mut idx: Vec<u32> = hot.iter().map(|&i| i as u32).collect();
                let mut priv_rng = Pcg64::new(seed ^ w as u64, 55);
                for _ in 0..private {
                    idx.push(priv_rng.below(dense_len as u64) as u32);
                }
                idx.sort_unstable();
                idx.dedup();
                let vals: Vec<f32> = idx
                    .iter()
                    .map(|_| priv_rng.next_f32() * 2.0 - 1.0)
                    .map(|v| if v == 0.0 { 0.5 } else { v })
                    .collect();
                CooTensor::from_sorted(dense_len, idx, vals)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

    use super::*;

    #[test]
    fn reference_sum_adds() {
        let a = CooTensor::from_sorted(4, vec![0, 2], vec![1.0, 2.0]);
        let b = CooTensor::from_sorted(4, vec![2, 3], vec![3.0, 4.0]);
        let s = reference_sum(&[a, b]);
        assert_eq!(s.values, vec![1.0, 0.0, 5.0, 4.0]);
    }

    #[test]
    fn planner_candidates_all_constructible() {
        for name in PLANNER_CANDIDATES {
            let s = by_name(name, 6, 1, 128)
                .unwrap_or_else(|| panic!("candidate '{name}' must construct"));
            assert!(!s.name().is_empty());
        }
    }

    #[test]
    fn lossy_tier_extends_lossless_candidates() {
        assert_eq!(
            &LOSSY_TIER_CANDIDATES[..PLANNER_CANDIDATES.len()],
            &PLANNER_CANDIDATES[..],
            "lossy tier is a strict superset, same order"
        );
        for name in LOSSY_TIER_CANDIDATES {
            let s = by_name(name, 6, 1, 128)
                .unwrap_or_else(|| panic!("candidate '{name}' must construct"));
            assert!(!s.name().is_empty());
        }
    }

    #[test]
    fn all_schemes_constructs_six() {
        let schemes = all_schemes(4, 1, 100);
        assert_eq!(schemes.len(), 6);
        let names: Vec<_> = schemes.iter().map(|s| s.name()).collect();
        assert!(names.contains(&"Zen"));
        assert!(names.contains(&"AllReduce"));
    }
}
