//! AGsparse — sparse all-gather (PyTorch DDP's sparse path, paper §2.3.3).
//!
//! Every GPU collects every other GPU's COO tensor, then aggregates
//! locally (one-shot, Centralization). Three communication patterns are
//! implemented, matching footnote 1 ("different implementations for
//! AGsparse with different communication patterns"): point-to-point
//! (default), ring, and hierarchy (recursive doubling) — each built as
//! per-rank sans-IO machines exchanging `PushCoo` frames.
//!
//! Traffic per GPU grows with `Σ_j nnz_j` — overlaps between tensors are
//! transmitted in full and reduced only at the destination, which is why
//! AGsparse degrades past ~40 GPUs in Fig 7.
//!
//! The hierarchy machines never gossip set sizes: after the fold-in a
//! rank's set size is `2` for the fold targets and `1` otherwise, and
//! each doubling stage adds the partner's size — fully determined by
//! `(n, rank, stage)`, so every rank computes its partner's expected
//! frame count locally and parks on `NeedFrame` until they arrived.

use super::*;
use crate::util::largest_pow2_at_most;
use crate::wire::{Event, Inbox};

/// Which all-gather topology to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AgPattern {
    PointToPoint,
    Ring,
    Hierarchy,
}

/// AGsparse scheme.
#[derive(Clone, Debug)]
pub struct AgSparse {
    pattern: AgPattern,
}

impl AgSparse {
    pub fn new(pattern: AgPattern) -> Self {
        AgSparse { pattern }
    }
}

impl SyncScheme for AgSparse {
    fn name(&self) -> &'static str {
        match self.pattern {
            AgPattern::PointToPoint => "AGsparse",
            AgPattern::Ring => "AGsparse-ring",
            AgPattern::Hierarchy => "AGsparse-hier",
        }
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: match self.pattern {
                AgPattern::PointToPoint => CommPattern::PointToPoint,
                AgPattern::Ring => CommPattern::Ring,
                AgPattern::Hierarchy => CommPattern::Hierarchy,
            },
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Centralization,
            balance: BalancePattern::NotApplicable,
            format: "COO",
        }
    }

    fn protocols<'a>(&'a self, inputs: &'a [CooTensor]) -> Vec<Box<dyn Protocol + 'a>> {
        let n = inputs.len();
        (0..n)
            .map(|rank| match self.pattern {
                AgPattern::PointToPoint => {
                    Box::new(P2pMachine::new(rank, inputs)) as Box<dyn Protocol + 'a>
                }
                AgPattern::Ring => Box::new(RingAgMachine::new(rank, inputs)),
                AgPattern::Hierarchy => Box::new(HierMachine::new(rank, inputs)),
            })
            .collect()
    }
}

// --- Point-to-point: one stage, everyone broadcasts, merge at closure.

struct P2pMachine<'a> {
    rank: usize,
    n: usize,
    inputs: &'a [CooTensor],
    inbox: Inbox,
    cursor: usize,
    parked: bool,
    output: Option<CooTensor>,
}

impl<'a> P2pMachine<'a> {
    fn new(rank: usize, inputs: &'a [CooTensor]) -> P2pMachine<'a> {
        P2pMachine {
            rank,
            n: inputs.len(),
            inputs,
            inbox: Inbox::new(inputs.len()),
            cursor: 0,
            parked: false,
            output: None,
        }
    }
}

impl Protocol for P2pMachine<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
        if let Some(out) = self.output.take() {
            return Ok(Event::Complete(out));
        }
        while self.cursor < self.n {
            let j = self.cursor;
            self.cursor += 1;
            if j != self.rank {
                return Ok(Event::Send {
                    dst: j,
                    msg: push_msg(self.rank, &self.inputs[self.rank]),
                });
            }
        }
        self.parked = true;
        Ok(Event::StageDone { name: "ag-p2p" })
    }

    fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
        self.inbox.push(src, msg);
        Ok(())
    }

    fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
        assert_eq!(name, "ag-p2p");
        let got: Vec<CooTensor> = self
            .inbox
            .drain_ascending()
            .into_iter()
            .map(|(_, msg)| expect_push(msg).1)
            .collect();
        self.output = Some(merge_with_own(&got, &self.inputs[self.rank]));
        Ok(())
    }
}

// --- Ring: n−1 stages; forward the last-received tensor each step.

struct RingAgMachine<'a> {
    rank: usize,
    n: usize,
    inputs: &'a [CooTensor],
    inbox: Inbox,
    /// Current step `s`; `sent` marks this step's frame as emitted.
    step: usize,
    sent: bool,
    parked: bool,
    received: Vec<CooTensor>,
}

impl<'a> RingAgMachine<'a> {
    fn new(rank: usize, inputs: &'a [CooTensor]) -> RingAgMachine<'a> {
        let n = inputs.len();
        RingAgMachine {
            rank,
            n,
            inputs,
            inbox: Inbox::new(n),
            step: 0,
            sent: false,
            parked: false,
            received: Vec::with_capacity(n.saturating_sub(1)),
        }
    }

    fn pred(&self) -> usize {
        (self.rank + self.n - 1) % self.n
    }
}

impl Protocol for RingAgMachine<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
        if self.step >= self.n.saturating_sub(1) {
            let out = merge_with_own(&self.received, &self.inputs[self.rank]);
            return Ok(Event::Complete(out));
        }
        if !self.sent {
            self.sent = true;
            let s = self.step;
            let origin = (self.rank + self.n - s) % self.n;
            let t = if s == 0 {
                &self.inputs[self.rank]
            } else {
                state(self.received.last(), "ring holds the last tensor")
            };
            return Ok(Event::Send {
                dst: (self.rank + 1) % self.n,
                msg: push_msg(origin, t),
            });
        }
        if self.parked {
            return Ok(Event::StageDone { name: "ag-ring" });
        }
        let pred = self.pred();
        match self.inbox.take_from(pred) {
            Some(msg) => {
                let (from, t) = expect_push(msg);
                assert_eq!(
                    from as usize,
                    (self.rank + self.n - 1 - self.step) % self.n,
                    "ring origin"
                );
                self.received.push(t);
                self.parked = true;
                Ok(Event::StageDone { name: "ag-ring" })
            }
            None => Ok(Event::NeedFrame { src: pred }),
        }
    }

    fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
        self.inbox.push(src, msg);
        Ok(())
    }

    fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
        assert_eq!(name, "ag-ring");
        self.step += 1;
        self.sent = false;
        self.parked = false;
        Ok(())
    }
}

// --- Hierarchy: fold-in, recursive doubling over the pow-2 core,
// fold-out.

enum HierPhase {
    /// Fold-in stage (skipped when n is a power of two).
    FoldIn,
    /// Doubling stage at distance `dist`.
    Double { dist: usize },
    /// Fold the aggregate back out to the excess ranks.
    FoldOut,
    Done,
}

struct HierMachine<'a> {
    rank: usize,
    n: usize,
    core: usize,
    excess: usize,
    inputs: &'a [CooTensor],
    inbox: Inbox,
    phase: HierPhase,
    /// Send progress within the current stage.
    send_cursor: usize,
    parked: bool,
    /// The set of original tensors this rank has gathered (core ranks).
    set: Vec<CooTensor>,
    output: Option<CooTensor>,
}

impl<'a> HierMachine<'a> {
    fn new(rank: usize, inputs: &'a [CooTensor]) -> HierMachine<'a> {
        let n = inputs.len();
        let core = largest_pow2_at_most(n);
        let excess = n - core;
        HierMachine {
            rank,
            n,
            core,
            excess,
            inputs,
            inbox: Inbox::new(n),
            phase: if excess > 0 {
                HierPhase::FoldIn
            } else {
                HierPhase::Double { dist: 1 }
            },
            send_cursor: 0,
            parked: false,
            set: vec![inputs[rank].clone()],
            output: None,
        }
    }

    /// The deterministic set size of core rank `i` before the doubling
    /// stage at distance `dist`: 2 for fold targets, 1 otherwise, then
    /// doubled per completed stage.
    fn set_size_before(&self, i: usize, dist: usize) -> usize {
        let mut size = if i < self.excess { 2 } else { 1 };
        let mut d = 1;
        while d < dist {
            size += self.set_size_at(i ^ d, d);
            d <<= 1;
        }
        size
    }

    /// Recursive helper: set size of rank `i` entering distance `d`.
    fn set_size_at(&self, i: usize, d: usize) -> usize {
        self.set_size_before(i, d)
    }
}

impl Protocol for HierMachine<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
        loop {
            match self.phase {
                HierPhase::FoldIn => {
                    if self.parked {
                        return Ok(Event::StageDone {
                            name: "ag-hier-fold-in",
                        });
                    }
                    if self.rank >= self.core {
                        // Excess rank: fold the tensor into core rank j.
                        let j = self.rank - self.core;
                        if self.send_cursor == 0 {
                            self.send_cursor = 1;
                            return Ok(Event::Send {
                                dst: j,
                                msg: push_msg(self.rank, &self.inputs[self.rank]),
                            });
                        }
                        self.parked = true;
                        return Ok(Event::StageDone {
                            name: "ag-hier-fold-in",
                        });
                    }
                    if self.rank < self.excess {
                        // Fold target: consume exactly one frame.
                        let src = self.core + self.rank;
                        match self.inbox.take_from(src) {
                            Some(msg) => {
                                self.set.push(expect_push(msg).1);
                                self.parked = true;
                                return Ok(Event::StageDone {
                                    name: "ag-hier-fold-in",
                                });
                            }
                            None => return Ok(Event::NeedFrame { src }),
                        }
                    }
                    // Core rank with no fold partner: idle this stage.
                    self.parked = true;
                    return Ok(Event::StageDone {
                        name: "ag-hier-fold-in",
                    });
                }
                HierPhase::Double { dist } => {
                    if dist >= self.core {
                        // Doubling finished: aggregate, then fold out.
                        if self.rank < self.core {
                            self.output = Some(CooTensor::merge_all(&self.set));
                            self.set.clear();
                        }
                        if self.excess > 0 {
                            self.phase = HierPhase::FoldOut;
                            continue;
                        }
                        self.phase = HierPhase::Done;
                        continue;
                    }
                    if self.parked {
                        return Ok(Event::StageDone { name: "ag-hier" });
                    }
                    if self.rank >= self.core {
                        self.parked = true;
                        return Ok(Event::StageDone { name: "ag-hier" });
                    }
                    let peer = self.rank ^ dist;
                    // Send the whole set, one frame per tensor.
                    if self.send_cursor < self.set.len() {
                        let t = &self.set[self.send_cursor];
                        let msg = push_msg(self.rank, t);
                        self.send_cursor += 1;
                        return Ok(Event::Send { dst: peer, msg });
                    }
                    // Then consume the partner's (locally computed) count.
                    let expected = self.set_size_before(peer, dist);
                    if self.inbox.from_src(peer) < expected {
                        return Ok(Event::NeedFrame { src: peer });
                    }
                    for _ in 0..expected {
                        let msg = state(self.inbox.take_from(peer), "counted above");
                        self.set.push(expect_push(msg).1);
                    }
                    self.parked = true;
                    return Ok(Event::StageDone { name: "ag-hier" });
                }
                HierPhase::FoldOut => {
                    if self.parked {
                        return Ok(Event::StageDone {
                            name: "ag-hier-fold-out",
                        });
                    }
                    if self.rank < self.excess {
                        // Core fold source: ship the aggregate out.
                        if self.send_cursor == 0 {
                            self.send_cursor = 1;
                            let out = state(self.output.as_ref(), "aggregate ready");
                            let msg = push_msg(self.rank, out);
                            return Ok(Event::Send {
                                dst: self.core + self.rank,
                                msg,
                            });
                        }
                        self.parked = true;
                        return Ok(Event::StageDone {
                            name: "ag-hier-fold-out",
                        });
                    }
                    if self.rank >= self.core {
                        // Excess rank: the received aggregate is the output.
                        let src = self.rank - self.core;
                        match self.inbox.take_from(src) {
                            Some(msg) => {
                                self.output = Some(expect_push(msg).1);
                                self.parked = true;
                                return Ok(Event::StageDone {
                                    name: "ag-hier-fold-out",
                                });
                            }
                            None => return Ok(Event::NeedFrame { src }),
                        }
                    }
                    self.parked = true;
                    return Ok(Event::StageDone {
                        name: "ag-hier-fold-out",
                    });
                }
                HierPhase::Done => {
                    return Ok(Event::Complete(state(
                        self.output.take(),
                        "aggregate ready",
                    )))
                }
            }
        }
    }

    fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
        self.inbox.push(src, msg);
        Ok(())
    }

    fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
        self.parked = false;
        self.send_cursor = 0;
        match name {
            "ag-hier-fold-in" => self.phase = HierPhase::Double { dist: 1 },
            "ag-hier" => {
                if let HierPhase::Double { dist } = self.phase {
                    self.phase = HierPhase::Double { dist: dist << 1 };
                } else {
                    panic!("AGsparse-hier: ag-hier closed outside doubling");
                }
            }
            "ag-hier-fold-out" => self.phase = HierPhase::Done,
            other => panic!("AGsparse-hier: unknown stage '{other}' closed"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::tensor::WireFormat;
    use crate::wire::codec::COO_FRAME_OVERHEAD;

    fn run(pattern: AgPattern, inputs: &[CooTensor], net: &Network) -> SyncOutput {
        AgSparse::new(pattern).run_sim(inputs, net, &mut SyncScratch::new())
    }

    #[test]
    fn all_patterns_correct() {
        let inputs = overlapping_inputs(1, 4, 2000, 60, 40);
        let net = Network::new(4, LinkKind::Tcp25);
        for p in [AgPattern::PointToPoint, AgPattern::Ring, AgPattern::Hierarchy] {
            let r = run(p, &inputs, &net);
            verify_outputs(&r, &inputs);
        }
    }

    #[test]
    fn p2p_traffic_is_n_minus_1_times_all() {
        let n = 5;
        let inputs = overlapping_inputs(2, n, 1000, 20, 20);
        let net = Network::new(n, LinkKind::Tcp25);
        let r = run(AgPattern::PointToPoint, &inputs, &net);
        let total: u64 = inputs.iter().map(|t| t.wire_bytes() as u64).sum();
        let framing = (n * COO_FRAME_OVERHEAD) as u64;
        assert_eq!(r.report.total_bytes(), (n as u64 - 1) * (total + framing));
    }

    #[test]
    fn ring_and_p2p_same_total_traffic() {
        // Same payloads, same n(n−1) frame count — only the stage
        // structure differs.
        let n = 4;
        let inputs = overlapping_inputs(3, n, 1000, 30, 10);
        let net = Network::new(n, LinkKind::Tcp25);
        let p2p = run(AgPattern::PointToPoint, &inputs, &net);
        let ring = run(AgPattern::Ring, &inputs, &net);
        assert_eq!(p2p.report.total_bytes(), ring.report.total_bytes());
        assert_eq!(ring.report.stages.len(), n - 1);
        assert_eq!(p2p.report.stages.len(), 1);
        verify_outputs(&ring, &inputs);
    }

    #[test]
    fn hierarchy_gathers_everything() {
        let n = 8;
        let inputs = overlapping_inputs(4, n, 3000, 50, 25);
        let net = Network::new(n, LinkKind::Tcp25);
        let r = run(AgPattern::Hierarchy, &inputs, &net);
        verify_outputs(&r, &inputs);
        assert_eq!(r.report.stages.len(), 3); // log2(8), no fold stages
    }

    #[test]
    fn hierarchy_non_power_of_two_correct() {
        // The old schedule asserted 2^k nodes; the folded one must be
        // exact at every machine count, with log2(core) + 2 stages.
        for n in [3usize, 5, 6, 7, 12] {
            let inputs = overlapping_inputs(11 + n as u64, n, 2500, 40, 30);
            let net = Network::new(n, LinkKind::Tcp25);
            let r = run(AgPattern::Hierarchy, &inputs, &net);
            verify_outputs(&r, &inputs);
            let core = largest_pow2_at_most(n);
            assert_eq!(
                r.report.stages.len(),
                core.trailing_zeros() as usize + 2,
                "n={n}: doubling over the pow-2 core plus fold-in/out"
            );
        }
    }

    #[test]
    fn hierarchy_pow2_matches_p2p_traffic() {
        // The pow-2 oracle: recursive doubling moves exactly the p2p
        // all-gather's n(n−1) frames, only staged differently.
        let n = 4;
        let inputs = overlapping_inputs(6, n, 1000, 30, 10);
        let net = Network::new(n, LinkKind::Tcp25);
        let p2p = run(AgPattern::PointToPoint, &inputs, &net);
        let hier = run(AgPattern::Hierarchy, &inputs, &net);
        assert_eq!(p2p.report.total_bytes(), hier.report.total_bytes());
    }

    #[test]
    fn traffic_does_not_shrink_with_overlap() {
        // Centralization can't exploit overlap: identical vs disjoint
        // tensors with equal nnz produce identical traffic.
        let n = 4;
        let net = Network::new(n, LinkKind::Tcp25);
        let same = overlapping_inputs(5, n, 1000, 100, 0);
        let r1 = run(AgPattern::PointToPoint, &same, &net);
        let nnz = same[0].nnz();
        let disjoint: Vec<CooTensor> = (0..n as u32)
            .map(|w| {
                let idx: Vec<u32> = (0..nnz as u32).map(|i| w * nnz as u32 + i).collect();
                CooTensor::from_sorted(1000 * n, idx, vec![1.0; nnz])
            })
            .collect();
        let r2 = run(AgPattern::PointToPoint, &disjoint, &net);
        assert_eq!(r1.report.total_bytes(), r2.report.total_bytes());
    }
}
