//! AGsparse — sparse all-gather (PyTorch DDP's sparse path, paper §2.3.3).
//!
//! Every GPU collects every other GPU's COO tensor, then aggregates
//! locally (one-shot, Centralization). Three communication patterns are
//! implemented, matching footnote 1 ("different implementations for
//! AGsparse with different communication patterns"): point-to-point
//! (default), ring, and hierarchy (recursive doubling).
//!
//! Traffic per GPU grows with `Σ_j nnz_j` — overlaps between tensors are
//! transmitted in full and reduced only at the destination, which is why
//! AGsparse degrades past ~40 GPUs in Fig 7.

use super::*;
use crate::cluster::StageReport;

/// Which all-gather topology to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AgPattern {
    PointToPoint,
    Ring,
    Hierarchy,
}

/// AGsparse scheme.
#[derive(Clone, Debug)]
pub struct AgSparse {
    pattern: AgPattern,
}

impl AgSparse {
    pub fn new(pattern: AgPattern) -> Self {
        AgSparse { pattern }
    }
}

impl SyncScheme for AgSparse {
    fn name(&self) -> &'static str {
        match self.pattern {
            AgPattern::PointToPoint => "AGsparse",
            AgPattern::Ring => "AGsparse-ring",
            AgPattern::Hierarchy => "AGsparse-hier",
        }
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: match self.pattern {
                AgPattern::PointToPoint => CommPattern::PointToPoint,
                AgPattern::Ring => CommPattern::Ring,
                AgPattern::Hierarchy => CommPattern::Hierarchy,
            },
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Centralization,
            balance: BalancePattern::NotApplicable,
            format: "COO",
        }
    }

    fn sync_with(
        &self,
        inputs: &[CooTensor],
        net: &Network,
        _scratch: &mut SyncScratch,
    ) -> SyncResult {
        let n = inputs.len();
        assert_eq!(n, net.endpoints);
        let bytes: Vec<u64> = inputs
            .iter()
            .map(|t| crate::tensor::WireFormat::wire_bytes(t) as u64)
            .collect();

        let mut report = CommReport::new();
        match self.pattern {
            AgPattern::PointToPoint => {
                // One stage: node i sends its tensor to all others.
                let mut m = vec![vec![0u64; n]; n];
                for (i, row) in m.iter_mut().enumerate() {
                    for (j, cell) in row.iter_mut().enumerate() {
                        if i != j {
                            *cell = bytes[i];
                        }
                    }
                }
                report.push(net.stage_from_matrix("ag-p2p", &m));
            }
            AgPattern::Ring => {
                // n-1 stages; stage s: node i forwards the tensor that
                // originated at (i - s) mod n to (i + 1) mod n.
                for s in 0..n.saturating_sub(1) {
                    let mut m = vec![vec![0u64; n]; n];
                    for i in 0..n {
                        let origin = (i + n - s) % n;
                        m[i][(i + 1) % n] = bytes[origin];
                    }
                    report.push(net.stage_from_matrix("ag-ring", &m));
                }
            }
            AgPattern::Hierarchy => {
                // Recursive doubling: stage s exchanges the 2^s tensors
                // gathered so far with the partner at distance 2^s.
                assert!(n.is_power_of_two(), "hierarchy pattern needs 2^k nodes");
                let mut have: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
                let mut dist = 1;
                while dist < n {
                    let mut m = vec![vec![0u64; n]; n];
                    let mut new_have = have.clone();
                    for i in 0..n {
                        let peer = i ^ dist;
                        let payload: u64 = have[i].iter().map(|&t| bytes[t]).sum();
                        m[i][peer] = payload;
                        new_have[peer].extend(have[i].iter().copied());
                    }
                    for h in new_have.iter_mut() {
                        h.sort_unstable();
                        h.dedup();
                    }
                    have = new_have;
                    report.push(net.stage_from_matrix("ag-hier", &m));
                    dist <<= 1;
                }
            }
        }

        // One-shot aggregation at every node.
        let aggregated = CooTensor::merge_all(inputs);
        SyncResult {
            outputs: vec![aggregated; n],
            report,
        }
    }
}

#[allow(dead_code)]
fn unused(_: StageReport) {}

#[cfg(test)]
mod tests {
    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::tensor::WireFormat;

    #[test]
    fn all_patterns_correct() {
        let inputs = overlapping_inputs(1, 4, 2000, 60, 40);
        let net = Network::new(4, LinkKind::Tcp25);
        for p in [AgPattern::PointToPoint, AgPattern::Ring, AgPattern::Hierarchy] {
            let r = AgSparse::new(p).sync(&inputs, &net);
            verify_outputs(&r, &inputs);
        }
    }

    #[test]
    fn p2p_traffic_is_n_minus_1_times_all() {
        let n = 5;
        let inputs = overlapping_inputs(2, n, 1000, 20, 20);
        let net = Network::new(n, LinkKind::Tcp25);
        let r = AgSparse::new(AgPattern::PointToPoint).sync(&inputs, &net);
        let total: u64 = inputs.iter().map(|t| t.wire_bytes() as u64).sum();
        assert_eq!(r.report.total_bytes(), (n as u64 - 1) * total);
    }

    #[test]
    fn ring_and_p2p_same_total_traffic() {
        let n = 4;
        let inputs = overlapping_inputs(3, n, 1000, 30, 10);
        let net = Network::new(n, LinkKind::Tcp25);
        let p2p = AgSparse::new(AgPattern::PointToPoint).sync(&inputs, &net);
        let ring = AgSparse::new(AgPattern::Ring).sync(&inputs, &net);
        assert_eq!(p2p.report.total_bytes(), ring.report.total_bytes());
        // but ring has n-1 sequential stages
        assert_eq!(ring.report.stages.len(), n - 1);
        assert_eq!(p2p.report.stages.len(), 1);
    }

    #[test]
    fn hierarchy_gathers_everything() {
        let n = 8;
        let inputs = overlapping_inputs(4, n, 3000, 50, 25);
        let net = Network::new(n, LinkKind::Tcp25);
        let r = AgSparse::new(AgPattern::Hierarchy).sync(&inputs, &net);
        verify_outputs(&r, &inputs);
        assert_eq!(r.report.stages.len(), 3); // log2(8)
    }

    #[test]
    fn traffic_does_not_shrink_with_overlap() {
        // Centralization can't exploit overlap: identical vs disjoint
        // tensors with equal nnz produce identical traffic.
        let n = 4;
        let net = Network::new(n, LinkKind::Tcp25);
        let same = overlapping_inputs(5, n, 1000, 100, 0);
        let r1 = AgSparse::new(AgPattern::PointToPoint).sync(&same, &net);
        let nnz = same[0].nnz();
        let disjoint: Vec<CooTensor> = (0..n as u32)
            .map(|w| {
                let idx: Vec<u32> = (0..nnz as u32).map(|i| w * nnz as u32 + i).collect();
                CooTensor::from_sorted(1000 * n, idx, vec![1.0; nnz])
            })
            .collect();
        let r2 = AgSparse::new(AgPattern::PointToPoint).sync(&disjoint, &net);
        assert_eq!(r1.report.total_bytes(), r2.report.total_bytes());
    }
}
