//! AGsparse — sparse all-gather (PyTorch DDP's sparse path, paper §2.3.3).
//!
//! Every GPU collects every other GPU's COO tensor, then aggregates
//! locally (one-shot, Centralization). Three communication patterns are
//! implemented, matching footnote 1 ("different implementations for
//! AGsparse with different communication patterns"): point-to-point
//! (default), ring, and hierarchy (recursive doubling) — each expressed
//! as `PushCoo` frames over the transport.
//!
//! Traffic per GPU grows with `Σ_j nnz_j` — overlaps between tensors are
//! transmitted in full and reduced only at the destination, which is why
//! AGsparse degrades past ~40 GPUs in Fig 7.

use super::*;
use crate::util::largest_pow2_at_most;

/// Which all-gather topology to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AgPattern {
    PointToPoint,
    Ring,
    Hierarchy,
}

/// AGsparse scheme.
#[derive(Clone, Debug)]
pub struct AgSparse {
    pattern: AgPattern,
}

impl AgSparse {
    pub fn new(pattern: AgPattern) -> Self {
        AgSparse { pattern }
    }
}

impl SyncScheme for AgSparse {
    fn name(&self) -> &'static str {
        match self.pattern {
            AgPattern::PointToPoint => "AGsparse",
            AgPattern::Ring => "AGsparse-ring",
            AgPattern::Hierarchy => "AGsparse-hier",
        }
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: match self.pattern {
                AgPattern::PointToPoint => CommPattern::PointToPoint,
                AgPattern::Ring => CommPattern::Ring,
                AgPattern::Hierarchy => CommPattern::Hierarchy,
            },
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Centralization,
            balance: BalancePattern::NotApplicable,
            format: "COO",
        }
    }

    fn sync_transport(
        &self,
        inputs: &[CooTensor],
        tx: &mut dyn Transport,
        _scratch: &mut SyncScratch,
    ) -> Result<SyncResult, crate::wire::WireError> {
        let n = inputs.len();
        assert_eq!(n, tx.endpoints());

        let outputs = match self.pattern {
            AgPattern::PointToPoint => {
                // One stage: node i broadcasts its tensor to all others.
                for (i, t) in inputs.iter().enumerate() {
                    for j in 0..n {
                        if j != i {
                            tx.send(i, j, push_frame(i, t))?;
                        }
                    }
                }
                let mut outputs = Vec::with_capacity(n);
                for j in 0..n {
                    let mut got = Vec::with_capacity(n - 1);
                    for _ in 0..n.saturating_sub(1) {
                        got.push(expect_push(tx.recv(j)?).1);
                    }
                    outputs.push(merge_with_own(&got, &inputs[j]));
                }
                tx.end_stage("ag-p2p")?;
                outputs
            }
            AgPattern::Ring => {
                // n−1 stages; stage s: node i forwards the tensor that
                // originated at (i − s) mod n to (i + 1) mod n.
                let mut received: Vec<Vec<CooTensor>> =
                    (0..n).map(|_| Vec::with_capacity(n - 1)).collect();
                for s in 0..n.saturating_sub(1) {
                    for i in 0..n {
                        let origin = (i + n - s) % n;
                        let t = if s == 0 {
                            &inputs[i]
                        } else {
                            received[i].last().expect("ring holds the last tensor")
                        };
                        tx.send(i, (i + 1) % n, push_frame(origin, t))?;
                    }
                    for (i, store) in received.iter_mut().enumerate() {
                        let (from, t) = expect_push(tx.recv(i)?);
                        assert_eq!(from as usize, (i + n - 1 - s) % n, "ring origin");
                        store.push(t);
                    }
                    tx.end_stage("ag-ring")?;
                }
                (0..n)
                    .map(|i| merge_with_own(&received[i], &inputs[i]))
                    .collect()
            }
            AgPattern::Hierarchy => {
                // Recursive doubling over the largest power-of-two core,
                // with a SparCML-style fold for the excess nodes: each
                // excess node core+j first folds its tensor into core
                // node j, the core exchanges *sets* of original tensors
                // at doubling distances (disjoint blocks, so no dedup),
                // and the final aggregate folds back out. Power-of-two n
                // keeps the classic scheduled (the fold stages vanish),
                // which the pow-2 tests pin as the oracle.
                let core = largest_pow2_at_most(n);
                let excess = n - core;
                let mut sets: Vec<Vec<CooTensor>> =
                    inputs.iter().map(|t| vec![t.clone()]).collect();
                if excess > 0 {
                    for j in 0..excess {
                        let src = core + j;
                        tx.send(src, j, push_frame(src, &inputs[src]))?;
                    }
                    for (j, set) in sets.iter_mut().enumerate().take(excess) {
                        set.push(expect_push(tx.recv(j)?).1);
                    }
                    tx.end_stage("ag-hier-fold-in")?;
                }
                let mut dist = 1;
                while dist < core {
                    // Set sizes differ once a fold happened: snapshot
                    // them so each receiver knows its partner's count.
                    let sizes: Vec<usize> = sets[..core].iter().map(|s| s.len()).collect();
                    for (i, set) in sets.iter().enumerate().take(core) {
                        let peer = i ^ dist;
                        for t in set {
                            tx.send(i, peer, push_frame(i, t))?;
                        }
                    }
                    for i in 0..core {
                        for _ in 0..sizes[i ^ dist] {
                            let t = expect_push(tx.recv(i)?).1;
                            sets[i].push(t);
                        }
                    }
                    tx.end_stage("ag-hier")?;
                    dist <<= 1;
                }
                // Core nodes hold every tensor; aggregate one-shot, then
                // fold the (much smaller) aggregate back out.
                let mut outputs: Vec<CooTensor> = sets[..core]
                    .iter()
                    .map(|set| CooTensor::merge_all(set))
                    .collect();
                if excess > 0 {
                    for (j, out) in outputs.iter().enumerate().take(excess) {
                        tx.send(j, core + j, push_frame(j, out))?;
                    }
                    for j in 0..excess {
                        outputs.push(expect_push(tx.recv(core + j)?).1);
                    }
                    tx.end_stage("ag-hier-fold-out")?;
                }
                outputs
            }
        };

        Ok(SyncResult {
            outputs,
            report: tx.take_report(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;
    use crate::tensor::WireFormat;
    use crate::wire::codec::COO_FRAME_OVERHEAD;

    #[test]
    fn all_patterns_correct() {
        let inputs = overlapping_inputs(1, 4, 2000, 60, 40);
        let net = Network::new(4, LinkKind::Tcp25);
        for p in [AgPattern::PointToPoint, AgPattern::Ring, AgPattern::Hierarchy] {
            let r = AgSparse::new(p).sync(&inputs, &net);
            verify_outputs(&r, &inputs);
        }
    }

    #[test]
    fn p2p_traffic_is_n_minus_1_times_all() {
        let n = 5;
        let inputs = overlapping_inputs(2, n, 1000, 20, 20);
        let net = Network::new(n, LinkKind::Tcp25);
        let r = AgSparse::new(AgPattern::PointToPoint).sync(&inputs, &net);
        let total: u64 = inputs.iter().map(|t| t.wire_bytes() as u64).sum();
        let framing = (n * COO_FRAME_OVERHEAD) as u64;
        assert_eq!(r.report.total_bytes(), (n as u64 - 1) * (total + framing));
    }

    #[test]
    fn ring_and_p2p_same_total_traffic() {
        // Same payloads, same n(n−1) frame count — only the stage
        // structure differs.
        let n = 4;
        let inputs = overlapping_inputs(3, n, 1000, 30, 10);
        let net = Network::new(n, LinkKind::Tcp25);
        let p2p = AgSparse::new(AgPattern::PointToPoint).sync(&inputs, &net);
        let ring = AgSparse::new(AgPattern::Ring).sync(&inputs, &net);
        assert_eq!(p2p.report.total_bytes(), ring.report.total_bytes());
        assert_eq!(ring.report.stages.len(), n - 1);
        assert_eq!(p2p.report.stages.len(), 1);
        verify_outputs(&ring, &inputs);
    }

    #[test]
    fn hierarchy_gathers_everything() {
        let n = 8;
        let inputs = overlapping_inputs(4, n, 3000, 50, 25);
        let net = Network::new(n, LinkKind::Tcp25);
        let r = AgSparse::new(AgPattern::Hierarchy).sync(&inputs, &net);
        verify_outputs(&r, &inputs);
        assert_eq!(r.report.stages.len(), 3); // log2(8), no fold stages
    }

    #[test]
    fn hierarchy_non_power_of_two_correct() {
        // The old schedule asserted 2^k nodes; the folded one must be
        // exact at every machine count, with log2(core) + 2 stages.
        for n in [3usize, 5, 6, 7, 12] {
            let inputs = overlapping_inputs(11 + n as u64, n, 2500, 40, 30);
            let net = Network::new(n, LinkKind::Tcp25);
            let r = AgSparse::new(AgPattern::Hierarchy).sync(&inputs, &net);
            verify_outputs(&r, &inputs);
            let core = largest_pow2_at_most(n);
            assert_eq!(
                r.report.stages.len(),
                core.trailing_zeros() as usize + 2,
                "n={n}: doubling over the pow-2 core plus fold-in/out"
            );
        }
    }

    #[test]
    fn hierarchy_pow2_matches_p2p_traffic() {
        // The pow-2 oracle: recursive doubling moves exactly the p2p
        // all-gather's n(n−1) frames, only staged differently.
        let n = 4;
        let inputs = overlapping_inputs(6, n, 1000, 30, 10);
        let net = Network::new(n, LinkKind::Tcp25);
        let p2p = AgSparse::new(AgPattern::PointToPoint).sync(&inputs, &net);
        let hier = AgSparse::new(AgPattern::Hierarchy).sync(&inputs, &net);
        assert_eq!(p2p.report.total_bytes(), hier.report.total_bytes());
    }

    #[test]
    fn traffic_does_not_shrink_with_overlap() {
        // Centralization can't exploit overlap: identical vs disjoint
        // tensors with equal nnz produce identical traffic.
        let n = 4;
        let net = Network::new(n, LinkKind::Tcp25);
        let same = overlapping_inputs(5, n, 1000, 100, 0);
        let r1 = AgSparse::new(AgPattern::PointToPoint).sync(&same, &net);
        let nnz = same[0].nnz();
        let disjoint: Vec<CooTensor> = (0..n as u32)
            .map(|w| {
                let idx: Vec<u32> = (0..nnz as u32).map(|i| w * nnz as u32 + i).collect();
                CooTensor::from_sorted(1000 * n, idx, vec![1.0; nnz])
            })
            .collect();
        let r2 = AgSparse::new(AgPattern::PointToPoint).sync(&disjoint, &net);
        assert_eq!(r1.report.total_bytes(), r2.report.total_bytes());
    }
}
