//! OmniReduce (Fei et al., SIGCOMM'21 — paper §2.3.3).
//!
//! Workers split the tensor into contiguous even partitions (one per
//! aggregator) and transmit only *non-zero blocks* of each partition
//! (block id + all `b` gradients of the block). No per-gradient indices —
//! cheaper than COO at moderate density — but still contiguous
//! partitioning, so it inherits Sparse PS's skew-driven imbalance, and
//! dense-after-aggregation partitions degenerate to near-dense traffic.

use super::*;
use crate::tensor::{BlockTensor, WireFormat};

/// OmniReduce scheme with a configurable block length.
#[derive(Clone, Debug)]
pub struct OmniReduce {
    pub block_len: usize,
}

impl OmniReduce {
    pub fn new(block_len: usize) -> Self {
        assert!(block_len > 0);
        OmniReduce { block_len }
    }
}

impl SyncScheme for OmniReduce {
    fn name(&self) -> &'static str {
        "OmniReduce"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::PointToPoint,
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Imbalanced,
            format: "tensor block",
        }
    }

    fn sync_with(
        &self,
        inputs: &[CooTensor],
        net: &Network,
        _scratch: &mut SyncScratch,
    ) -> SyncResult {
        let n = inputs.len();
        assert_eq!(n, net.endpoints);
        let dense_len = inputs[0].dense_len;
        let per = crate::util::ceil_div(dense_len, n) as u32;

        // Push: block-encode each contiguous partition.
        let mut push = vec![vec![0u64; n]; n];
        let mut shards: Vec<Vec<BlockTensor>> = vec![Vec::with_capacity(n); n];
        for (w, t) in inputs.iter().enumerate() {
            for p in 0..n {
                let lo = (p as u32 * per).min(dense_len as u32);
                let hi = ((p as u32 + 1) * per).min(dense_len as u32);
                let part = t.slice_range(lo, hi);
                let blocks = BlockTensor::from_coo(&part, self.block_len);
                if w != p {
                    push[w][p] = blocks.wire_bytes() as u64;
                }
                shards[p].push(blocks);
            }
        }
        let mut report = CommReport::new();
        report.push(net.stage_from_matrix("push", &push));

        // One-shot aggregation at each aggregator (block merge).
        let aggregated: Vec<BlockTensor> = shards
            .iter()
            .map(|parts| {
                let mut acc = parts[0].clone();
                for p in &parts[1..] {
                    acc = acc.merge(p);
                }
                acc
            })
            .collect();

        // Pull: aggregator p broadcasts its aggregated block tensor.
        let mut pull = vec![vec![0u64; n]; n];
        for (p, row) in pull.iter_mut().enumerate() {
            let bytes = aggregated[p].wire_bytes() as u64;
            for (w, cell) in row.iter_mut().enumerate() {
                if w != p {
                    *cell = bytes;
                }
            }
        }
        report.push(net.stage_from_matrix("pull", &pull));

        // Reassemble at every worker.
        let parts: Vec<(u32, CooTensor)> = aggregated
            .iter()
            .enumerate()
            .map(|(p, bt)| {
                let off = (p as u32 * per).min(dense_len as u32);
                (off, bt.to_dense().to_coo())
            })
            .collect();
        let full = CooTensor::concat_ranges(&parts, dense_len);
        SyncResult {
            outputs: vec![full; n],
            report,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;

    #[test]
    fn correct_aggregation() {
        let inputs = overlapping_inputs(1, 4, 4096, 100, 50);
        let net = Network::new(4, LinkKind::Tcp25);
        let r = OmniReduce::new(64).sync(&inputs, &net);
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn clustered_nonzeros_beat_coo() {
        // Non-zeros clustered into few blocks: block format ≪ COO bytes.
        let n = 2;
        let dense_len = 65_536;
        let inputs: Vec<CooTensor> = (0..n as u32)
            .map(|w| {
                // 512 consecutive non-zeros starting at w*1024
                let idx: Vec<u32> = (0..512).map(|i| w * 1024 + i).collect();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; 512])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let omni = OmniReduce::new(256).sync(&inputs, &net);
        let ag = AgSparse::new(AgPattern::PointToPoint).sync(&inputs, &net);
        assert!(omni.report.total_bytes() < ag.report.total_bytes());
        verify_outputs(&omni, &inputs);
    }

    #[test]
    fn scattered_nonzeros_pay_padding() {
        // One non-zero every 2·block_len: every block is non-zero with a
        // single real value → traffic ≈ dense/2, far worse than COO.
        let dense_len = 16_384;
        let block = 64;
        let idx: Vec<u32> = (0..(dense_len as u32) / 128).map(|i| i * 128).collect();
        let t = CooTensor::from_sorted(dense_len, idx.clone(), vec![1.0; idx.len()]);
        let inputs = vec![t.clone(), t];
        let net = Network::new(2, LinkKind::Tcp25);
        let omni = OmniReduce::new(block).sync(&inputs, &net);
        let coo_bytes = (idx.len() * 8) as u64; // per tensor per hop
        let omni_push = omni.report.stages[0].sent[0];
        assert!(omni_push > 2 * coo_bytes, "padding should dominate");
    }

    #[test]
    fn skew_hits_one_aggregator() {
        let n = 4;
        let dense_len = 4096;
        // all non-zeros in first quarter
        let idx: Vec<u32> = (0..256).collect();
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| CooTensor::from_sorted(dense_len, idx.clone(), vec![1.0; 256]))
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = OmniReduce::new(64).sync(&inputs, &net);
        let push = &r.report.stages[0];
        assert!(push.recv[0] > 0);
        assert_eq!(push.recv[1..].iter().sum::<u64>(), 0);
    }
}
