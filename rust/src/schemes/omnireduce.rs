//! OmniReduce (Fei et al., SIGCOMM'21 — paper §2.3.3).
//!
//! Workers split the tensor into contiguous even partitions (one per
//! aggregator) and transmit only *non-zero blocks* of each partition
//! (block id + all `b` gradients of the block) as `Blocks` frames. No
//! per-gradient indices — cheaper than COO at moderate density — but
//! still contiguous partitioning, so it inherits Sparse PS's
//! skew-driven imbalance, and dense-after-aggregation partitions
//! degenerate to near-dense traffic.

use super::*;
use crate::tensor::BlockTensor;
use crate::wire::{FrameRef, Message};

/// OmniReduce scheme with a configurable block length.
#[derive(Clone, Debug)]
pub struct OmniReduce {
    pub block_len: usize,
}

impl OmniReduce {
    pub fn new(block_len: usize) -> Self {
        assert!(block_len > 0);
        OmniReduce { block_len }
    }
}

/// Frame a block tensor: ids borrowed, blocks flattened into `buf`.
fn send_block_tensor(
    tx: &mut dyn Transport,
    src: usize,
    dst: usize,
    from: usize,
    bt: &BlockTensor,
    buf: &mut Vec<f32>,
) -> Result<(), crate::wire::WireError> {
    buf.clear();
    for block in &bt.blocks {
        buf.extend_from_slice(block);
    }
    tx.send(
        src,
        dst,
        FrameRef::Blocks {
            from: from as u32,
            dense_len: bt.dense_len as u64,
            block_len: bt.block_len as u32,
            block_ids: &bt.block_ids,
            values: &buf[..],
        },
    )
}

fn expect_blocks(msg: Message, block_len: usize) -> (u32, BlockTensor) {
    match msg {
        Message::Blocks {
            from,
            dense_len,
            block_len: bl,
            block_ids,
            values,
        } => {
            assert_eq!(bl as usize, block_len, "block length mismatch");
            (
                from,
                BlockTensor::from_wire_parts(dense_len as usize, block_len, block_ids, values),
            )
        }
        other => panic!("omnireduce expected Blocks, got {other:?}"),
    }
}

impl SyncScheme for OmniReduce {
    fn name(&self) -> &'static str {
        "OmniReduce"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::PointToPoint,
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Imbalanced,
            format: "tensor block",
        }
    }

    fn sync_transport(
        &self,
        inputs: &[CooTensor],
        tx: &mut dyn Transport,
        scratch: &mut SyncScratch,
    ) -> Result<SyncResult, crate::wire::WireError> {
        let n = inputs.len();
        assert_eq!(n, tx.endpoints());
        let dense_len = inputs[0].dense_len;
        let per = crate::util::ceil_div(dense_len, n) as u32;
        let lo = |p: usize| (p as u32 * per).min(dense_len as u32);
        let hi = |p: usize| ((p as u32 + 1) * per).min(dense_len as u32);

        // Push: block-encode each contiguous partition; only non-empty
        // block sets are framed.
        let mut own: Vec<Option<BlockTensor>> = (0..n).map(|_| None).collect();
        let mut expected = vec![0usize; n];
        for (w, t) in inputs.iter().enumerate() {
            for p in 0..n {
                let part = t.slice_range(lo(p), hi(p));
                let blocks = BlockTensor::from_coo(&part, self.block_len);
                if w == p {
                    own[p] = Some(blocks);
                } else if blocks.num_blocks() > 0 {
                    send_block_tensor(tx, w, p, w, &blocks, &mut scratch.block_values)?;
                    expected[p] += 1;
                }
            }
        }

        // One-shot aggregation at each aggregator (block merge).
        let mut aggregated: Vec<BlockTensor> = Vec::with_capacity(n);
        for p in 0..n {
            let mut acc = own[p].take().expect("own block shard present");
            for _ in 0..expected[p] {
                let (_, bt) = expect_blocks(tx.recv(p)?, self.block_len);
                acc = acc.merge(&bt);
            }
            aggregated.push(acc);
        }
        tx.end_stage("push")?;

        // Pull: aggregator p broadcasts its aggregated block tensor —
        // flattened once per aggregator, then framed to every recipient
        // from the same borrowed staging buffer.
        let mut expected = vec![0usize; n];
        for (p, agg) in aggregated.iter().enumerate() {
            if agg.num_blocks() == 0 {
                continue;
            }
            scratch.block_values.clear();
            for block in &agg.blocks {
                scratch.block_values.extend_from_slice(block);
            }
            for w in 0..n {
                if w != p {
                    tx.send(
                        p,
                        w,
                        FrameRef::Blocks {
                            from: p as u32,
                            dense_len: agg.dense_len as u64,
                            block_len: agg.block_len as u32,
                            block_ids: &agg.block_ids,
                            values: &scratch.block_values,
                        },
                    )?;
                    expected[w] += 1;
                }
            }
        }

        // Reassemble at every worker.
        let mut outputs = Vec::with_capacity(n);
        for w in 0..n {
            let mut parts: Vec<(u32, CooTensor)> = Vec::with_capacity(n);
            parts.push((lo(w), aggregated[w].to_dense().to_coo()));
            for _ in 0..expected[w] {
                let (from, bt) = expect_blocks(tx.recv(w)?, self.block_len);
                parts.push((lo(from as usize), bt.to_dense().to_coo()));
            }
            outputs.push(CooTensor::concat_ranges(&parts, dense_len));
        }
        tx.end_stage("pull")?;

        Ok(SyncResult {
            outputs,
            report: tx.take_report(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;

    #[test]
    fn correct_aggregation() {
        let inputs = overlapping_inputs(1, 4, 4096, 100, 50);
        let net = Network::new(4, LinkKind::Tcp25);
        let r = OmniReduce::new(64).sync(&inputs, &net);
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn clustered_nonzeros_beat_coo() {
        // Non-zeros clustered into few blocks: block format ≪ COO bytes.
        let n = 2;
        let dense_len = 65_536;
        let inputs: Vec<CooTensor> = (0..n as u32)
            .map(|w| {
                // 512 consecutive non-zeros starting at w*1024
                let idx: Vec<u32> = (0..512).map(|i| w * 1024 + i).collect();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; 512])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let omni = OmniReduce::new(256).sync(&inputs, &net);
        let ag = AgSparse::new(AgPattern::PointToPoint).sync(&inputs, &net);
        assert!(omni.report.total_bytes() < ag.report.total_bytes());
        verify_outputs(&omni, &inputs);
    }

    #[test]
    fn scattered_nonzeros_pay_padding() {
        // One non-zero every 2·block_len: every block is non-zero with a
        // single real value → traffic ≈ dense/2, far worse than COO.
        let dense_len = 16_384;
        let block = 64;
        let idx: Vec<u32> = (0..(dense_len as u32) / 128).map(|i| i * 128).collect();
        let t = CooTensor::from_sorted(dense_len, idx.clone(), vec![1.0; idx.len()]);
        let inputs = vec![t.clone(), t];
        let net = Network::new(2, LinkKind::Tcp25);
        let omni = OmniReduce::new(block).sync(&inputs, &net);
        let coo_bytes = (idx.len() * 8) as u64; // per tensor per hop
        let omni_push = omni.report.stages[0].sent[0];
        assert!(omni_push > 2 * coo_bytes, "padding should dominate");
    }

    #[test]
    fn skew_hits_one_aggregator() {
        let n = 4;
        let dense_len = 4096;
        // all non-zeros in first quarter
        let idx: Vec<u32> = (0..256).collect();
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| CooTensor::from_sorted(dense_len, idx.clone(), vec![1.0; 256]))
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = OmniReduce::new(64).sync(&inputs, &net);
        let push = &r.report.stages[0];
        assert!(push.recv[0] > 0);
        assert_eq!(push.recv[1..].iter().sum::<u64>(), 0);
    }
}
