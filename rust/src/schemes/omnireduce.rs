//! OmniReduce (Fei et al., SIGCOMM'21 — paper §2.3.3).
//!
//! Workers split the tensor into contiguous even partitions (one per
//! aggregator) and transmit only *non-zero blocks* of each partition
//! (block id + all `b` gradients of the block) as `Blocks` frames. No
//! per-gradient indices — cheaper than COO at moderate density — but
//! still contiguous partitioning, so it inherits Sparse PS's
//! skew-driven imbalance, and dense-after-aggregation partitions
//! degenerate to near-dense traffic.
//!
//! Like SparsePS, the frame count is data-dependent (empty block sets
//! are never framed), so the per-rank machines are
//! receive-until-stage-closed: an aggregator merges whatever its inbox
//! holds when the `push` stage closes, in ascending-source order.

use super::*;
use crate::tensor::BlockTensor;
use crate::wire::{Event, Inbox, Message};

/// OmniReduce scheme with a configurable block length.
#[derive(Clone, Debug)]
pub struct OmniReduce {
    pub block_len: usize,
}

impl OmniReduce {
    pub fn new(block_len: usize) -> Self {
        assert!(block_len > 0);
        OmniReduce { block_len }
    }
}

/// Build an owned `Blocks` frame from a block tensor (values flattened).
fn blocks_msg(from: usize, bt: &BlockTensor) -> Message {
    let mut values = Vec::with_capacity(bt.num_blocks() * bt.block_len);
    for block in &bt.blocks {
        values.extend_from_slice(block);
    }
    Message::Blocks {
        from: small_u32(from, "worker rank"),
        dense_len: bt.dense_len as u64,
        block_len: small_u32(bt.block_len, "block length"),
        block_ids: bt.block_ids.clone(),
        values,
    }
}

fn expect_blocks(msg: Message, block_len: usize) -> (u32, BlockTensor) {
    match msg {
        Message::Blocks {
            from,
            dense_len,
            block_len: bl,
            block_ids,
            values,
        } => {
            assert_eq!(bl as usize, block_len, "block length mismatch");
            let dense_len = match usize::try_from(dense_len) {
                Ok(v) => v,
                Err(_) => panic!("blocks dense length exceeds the address space"),
            };
            (
                from,
                BlockTensor::from_wire_parts(dense_len, block_len, block_ids, values),
            )
        }
        other => panic!("omnireduce expected Blocks, got {other:?}"),
    }
}

impl SyncScheme for OmniReduce {
    fn name(&self) -> &'static str {
        "OmniReduce"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::PointToPoint,
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Parallelism,
            balance: BalancePattern::Imbalanced,
            format: "tensor block",
        }
    }

    fn protocols<'a>(&'a self, inputs: &'a [CooTensor]) -> Vec<Box<dyn Protocol + 'a>> {
        (0..inputs.len())
            .map(|rank| {
                Box::new(OmniMachine::new(rank, inputs, self.block_len)) as Box<dyn Protocol + 'a>
            })
            .collect()
    }
}

enum OmniState {
    /// Framing non-empty block sets to the other aggregators.
    PushSend,
    /// Parked on `push`; block merge happens at stage closure.
    PushParked,
    /// Broadcasting the aggregated block tensor.
    PullSend,
    /// Parked on `pull`; reassembly happens at stage closure.
    PullParked,
    Done,
}

struct OmniMachine<'a> {
    rank: usize,
    n: usize,
    dense_len: usize,
    block_len: usize,
    inputs: &'a [CooTensor],
    state: OmniState,
    inbox: Inbox,
    cursor: usize,
    /// This rank's own block shard of its aggregator partition.
    own: Option<BlockTensor>,
    /// The aggregated block tensor this rank serves.
    agg: Option<BlockTensor>,
    output: Option<CooTensor>,
}

impl<'a> OmniMachine<'a> {
    fn new(rank: usize, inputs: &'a [CooTensor], block_len: usize) -> OmniMachine<'a> {
        let n = inputs.len();
        OmniMachine {
            rank,
            n,
            dense_len: inputs[0].dense_len,
            block_len,
            inputs,
            state: OmniState::PushSend,
            inbox: Inbox::new(n),
            cursor: 0,
            own: None,
            agg: None,
            output: None,
        }
    }

    fn per(&self) -> u32 {
        small_u32(
            crate::util::ceil_div(self.dense_len, self.n),
            "partition width",
        )
    }

    fn lo(&self, p: usize) -> u32 {
        (small_u32(p, "aggregator rank") * self.per())
            .min(small_u32(self.dense_len, "dense length"))
    }

    fn hi(&self, p: usize) -> u32 {
        ((small_u32(p, "aggregator rank") + 1) * self.per())
            .min(small_u32(self.dense_len, "dense length"))
    }
}

impl Protocol for OmniMachine<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
        match self.state {
            OmniState::PushSend => {
                while self.cursor < self.n {
                    let p = self.cursor;
                    self.cursor += 1;
                    let part = self.inputs[self.rank].slice_range(self.lo(p), self.hi(p));
                    let blocks = BlockTensor::from_coo(&part, self.block_len);
                    if p == self.rank {
                        self.own = Some(blocks);
                    } else if blocks.num_blocks() > 0 {
                        return Ok(Event::Send {
                            dst: p,
                            msg: blocks_msg(self.rank, &blocks),
                        });
                    }
                }
                self.state = OmniState::PushParked;
                Ok(Event::StageDone { name: "push" })
            }
            OmniState::PushParked => Ok(Event::StageDone { name: "push" }),
            OmniState::PullSend => {
                let nonempty = state(self.agg.as_ref(), "aggregated blocks").num_blocks() > 0;
                if nonempty {
                    while self.cursor < self.n {
                        let w = self.cursor;
                        self.cursor += 1;
                        if w != self.rank {
                            let agg = state(self.agg.as_ref(), "aggregated blocks");
                            let msg = blocks_msg(self.rank, agg);
                            return Ok(Event::Send { dst: w, msg });
                        }
                    }
                }
                self.state = OmniState::PullParked;
                Ok(Event::StageDone { name: "pull" })
            }
            OmniState::PullParked => Ok(Event::StageDone { name: "pull" }),
            OmniState::Done => Ok(Event::Complete(state(
                self.output.take(),
                "output assembled at pull closure",
            ))),
        }
    }

    fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
        self.inbox.push(src, msg);
        Ok(())
    }

    fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
        match name {
            "push" => {
                // One-shot block merge, ascending-worker order.
                let mut acc = state(self.own.take(), "own block shard present");
                for (_, msg) in self.inbox.drain_ascending() {
                    let (_, bt) = expect_blocks(msg, self.block_len);
                    acc = acc.merge(&bt);
                }
                self.agg = Some(acc);
                self.cursor = 0;
                self.state = OmniState::PullSend;
            }
            "pull" => {
                let agg = state(self.agg.take(), "aggregated blocks");
                let mut parts: Vec<(u32, CooTensor)> = Vec::with_capacity(self.n);
                parts.push((self.lo(self.rank), agg.to_dense().to_coo()));
                for (_, msg) in self.inbox.drain_ascending() {
                    let (from, bt) = expect_blocks(msg, self.block_len);
                    parts.push((self.lo(from as usize), bt.to_dense().to_coo()));
                }
                self.output = Some(CooTensor::concat_ranges(&parts, self.dense_len));
                self.state = OmniState::Done;
            }
            other => panic!("OmniReduce: unknown stage '{other}' closed"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

    use super::super::testutil::overlapping_inputs;
    use super::*;
    use crate::cluster::LinkKind;

    fn run(block_len: usize, inputs: &[CooTensor], net: &Network) -> SyncOutput {
        OmniReduce::new(block_len).run_sim(inputs, net, &mut SyncScratch::new())
    }

    #[test]
    fn correct_aggregation() {
        let inputs = overlapping_inputs(1, 4, 4096, 100, 50);
        let net = Network::new(4, LinkKind::Tcp25);
        let r = run(64, &inputs, &net);
        verify_outputs(&r, &inputs);
    }

    #[test]
    fn clustered_nonzeros_beat_coo() {
        // Non-zeros clustered into few blocks: block format ≪ COO bytes.
        let n = 2;
        let dense_len = 65_536;
        let inputs: Vec<CooTensor> = (0..n as u32)
            .map(|w| {
                // 512 consecutive non-zeros starting at w*1024
                let idx: Vec<u32> = (0..512).map(|i| w * 1024 + i).collect();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; 512])
            })
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let omni = run(256, &inputs, &net);
        let ag = AgSparse::new(AgPattern::PointToPoint).run_sim(
            &inputs,
            &net,
            &mut SyncScratch::new(),
        );
        assert!(omni.report.total_bytes() < ag.report.total_bytes());
        verify_outputs(&omni, &inputs);
    }

    #[test]
    fn scattered_nonzeros_pay_padding() {
        // One non-zero every 2·block_len: every block is non-zero with a
        // single real value → traffic ≈ dense/2, far worse than COO.
        let dense_len = 16_384;
        let block = 64;
        let idx: Vec<u32> = (0..(dense_len as u32) / 128).map(|i| i * 128).collect();
        let t = CooTensor::from_sorted(dense_len, idx.clone(), vec![1.0; idx.len()]);
        let inputs = vec![t.clone(), t];
        let net = Network::new(2, LinkKind::Tcp25);
        let omni = run(block, &inputs, &net);
        let coo_bytes = (idx.len() * 8) as u64; // per tensor per hop
        let omni_push = omni.report.stages[0].sent[0];
        assert!(omni_push > 2 * coo_bytes, "padding should dominate");
    }

    #[test]
    fn skew_hits_one_aggregator() {
        let n = 4;
        let dense_len = 4096;
        // all non-zeros in first quarter
        let idx: Vec<u32> = (0..256).collect();
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| CooTensor::from_sorted(dense_len, idx.clone(), vec![1.0; 256]))
            .collect();
        let net = Network::new(n, LinkKind::Tcp25);
        let r = run(64, &inputs, &net);
        let push = &r.report.stages[0];
        assert!(push.recv[0] > 0);
        assert_eq!(push.recv[1..].iter().sum::<u64>(), 0);
    }
}
