//! PJRT runtime — loads AOT-compiled HLO artifacts and executes them on
//! the request path (rust only; python never runs at training time).
//!
//! The interchange format is **HLO text** (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects,
//! while the text parser reassigns ids cleanly. `python/compile/aot.py`
//! writes `artifacts/*.hlo.txt`; [`Runtime::load_hlo`] compiles them once
//! per process and [`Executable::run`] executes with concrete literals.
//!
//! The real PJRT client sits behind the `xla` cargo feature (the `xla`
//! bindings crate is absent from the offline registry). Without the
//! feature this module compiles a **stub**: the [`lit`] literal helpers
//! are fully functional (host-side vectors + shapes), while
//! [`Runtime::cpu`] and [`Executable::run`] return errors — so every
//! consumer (trainer, examples, benches) compiles and degrades
//! gracefully at run time.

#[cfg(feature = "xla")]
mod pjrt {
    use std::path::Path;

    use anyhow::{Context, Result};

    /// Wraps the PJRT CPU client.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Construct the CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it.
        pub fn load_hlo<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Executable {
                exe,
                name: path
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_else(|| "hlo".into()),
            })
        }
    }

    /// A compiled HLO module ready to execute.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Executable {
        /// Execute with literal inputs; returns the flattened tuple outputs.
        /// (aot.py lowers with `return_tuple=True`, so the single result is a
        /// tuple literal that we decompose.)
        pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let result = self
                .exe
                .execute::<xla::Literal>(inputs)
                .with_context(|| format!("executing {}", self.name))?;
            let literal = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            literal.to_tuple().with_context(|| {
                format!(
                    "expected tuple output from {} — lower with return_tuple=True",
                    self.name
                )
            })
        }
    }

    /// Literal construction/extraction helpers used by the coordinator.
    pub mod lit {
        use super::*;

        /// f32 literal of the given shape from a flat slice.
        pub fn f32(values: &[f32], dims: &[i64]) -> Result<xla::Literal> {
            let n: i64 = dims.iter().product();
            anyhow::ensure!(n as usize == values.len(), "shape/data mismatch");
            Ok(xla::Literal::vec1(values).reshape(dims)?)
        }

        /// i32 literal of the given shape.
        pub fn i32(values: &[i32], dims: &[i64]) -> Result<xla::Literal> {
            let n: i64 = dims.iter().product();
            anyhow::ensure!(n as usize == values.len(), "shape/data mismatch");
            Ok(xla::Literal::vec1(values).reshape(dims)?)
        }

        /// Extract a flat f32 vector.
        pub fn to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
            Ok(l.to_vec::<f32>()?)
        }

        /// Extract a scalar f32 (rank-0 or single-element).
        pub fn scalar_f32(l: &xla::Literal) -> Result<f32> {
            let v = l.to_vec::<f32>()?;
            anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
            Ok(v[0])
        }

        /// Extract a flat u32 vector.
        pub fn to_u32(l: &xla::Literal) -> Result<Vec<u32>> {
            Ok(l.to_vec::<u32>()?)
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{lit, Executable, Runtime};

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    use anyhow::Result;

    const UNAVAILABLE: &str =
        "zen was built without the `xla` feature; the PJRT runtime is unavailable \
         (add the `xla` crate and rebuild with `--features xla`)";

    /// Host-side literal: a shape plus typed flat data. Mirrors the subset
    /// of `xla::Literal` the coordinator constructs and extracts.
    #[derive(Clone, Debug, PartialEq)]
    pub struct Literal {
        pub dims: Vec<i64>,
        pub data: LiteralData,
    }

    /// Typed storage behind a stub [`Literal`].
    #[derive(Clone, Debug, PartialEq)]
    pub enum LiteralData {
        F32(Vec<f32>),
        I32(Vec<i32>),
        U32(Vec<u32>),
    }

    /// Stub runtime: construction always fails with a clear message.
    pub struct Runtime;

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            Err(anyhow::anyhow!("{UNAVAILABLE}"))
        }

        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        pub fn load_hlo<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
            Err(anyhow::anyhow!(
                "cannot load {}: {UNAVAILABLE}",
                path.as_ref().display()
            ))
        }
    }

    /// Stub executable (never constructed; methods exist for type-compat).
    pub struct Executable {
        pub name: String,
    }

    impl Executable {
        pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
            Err(anyhow::anyhow!("cannot execute {}: {UNAVAILABLE}", self.name))
        }
    }

    /// Literal construction/extraction helpers used by the coordinator.
    /// Fully functional in the stub (host vectors only).
    pub mod lit {
        use super::*;

        fn checked(dims: &[i64], len: usize) -> Result<()> {
            let n: i64 = dims.iter().product();
            anyhow::ensure!(n as usize == len, "shape/data mismatch");
            Ok(())
        }

        /// f32 literal of the given shape from a flat slice.
        pub fn f32(values: &[f32], dims: &[i64]) -> Result<Literal> {
            checked(dims, values.len())?;
            Ok(Literal {
                dims: dims.to_vec(),
                data: LiteralData::F32(values.to_vec()),
            })
        }

        /// i32 literal of the given shape.
        pub fn i32(values: &[i32], dims: &[i64]) -> Result<Literal> {
            checked(dims, values.len())?;
            Ok(Literal {
                dims: dims.to_vec(),
                data: LiteralData::I32(values.to_vec()),
            })
        }

        /// Extract a flat f32 vector.
        pub fn to_f32(l: &Literal) -> Result<Vec<f32>> {
            match &l.data {
                LiteralData::F32(v) => Ok(v.clone()),
                other => Err(anyhow::anyhow!("literal is not f32: {other:?}")),
            }
        }

        /// Extract a scalar f32 (rank-0 or single-element).
        pub fn scalar_f32(l: &Literal) -> Result<f32> {
            let v = to_f32(l)?;
            anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
            Ok(v[0])
        }

        /// Extract a flat u32 vector.
        pub fn to_u32(l: &Literal) -> Result<Vec<u32>> {
            match &l.data {
                LiteralData::U32(v) => Ok(v.clone()),
                other => Err(anyhow::anyhow!("literal is not u32: {other:?}")),
            }
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{lit, Executable, Literal, LiteralData, Runtime};

#[cfg(test)]
mod tests {
    // Tests that need artifacts live in rust/tests/runtime_hlo.rs
    // (integration, after `make artifacts`). Here: client + literals only.
    use super::*;

    #[cfg(feature = "xla")]
    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert!(!rt.platform().is_empty());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_errors_clearly() {
        let err = Runtime::cpu().err().expect("stub must not boot");
        assert!(err.to_string().contains("xla"), "{err}");
    }

    #[test]
    fn literal_roundtrip() {
        let l = lit::f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(lit::to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(lit::f32(&[1.0, 2.0], &[3]).is_err());
    }
}
