//! Figure/table generators — one function per paper exhibit.
//!
//! Every function returns [`Table`]s whose rows are the series the paper
//! plots; `examples/figures.rs` renders them to `reports/*.csv` and
//! markdown. Absolute numbers come from this testbed's simulator; the
//! *shape* (who wins, by what factor, where crossovers sit) is the
//! reproduction target — see EXPERIMENTS.md for paper-vs-measured.

use crate::analysis::costmodel::CostModel;
use crate::analysis::numeric::{fig7_sweep, fig7_table};
use crate::cluster::{LinkKind, Network, Topology};
use crate::coordinator::{compute_time_per_iter, SimConfig, SimDriver};
use crate::hashing::{HierarchicalHasher, StrawmanHasher};
use crate::planner::{rank_candidates, MeasuredStats};
use crate::schemes::{self, SyncScheme};
use crate::tensor::{metrics, BlockTensor, CooTensor, WireFormat};
use crate::util::stats::Histogram;
use crate::util::table::Table;
use crate::util::{Pcg64, Stopwatch};
use crate::workload::{
    group_clustered_inputs, profiles, random_uniform_inputs, GradientGen, ModelProfile,
};

/// Default scale-down for figure workloads (documented in DESIGN.md).
pub const FIG_SCALE: usize = 256;
const SEED: u64 = 0x2e17;

fn gen_for(name: &str, scale: usize) -> GradientGen {
    GradientGen::new(profiles::by_name(name).unwrap().scaled(scale), SEED)
}

/// Table 1 — model statistics (paper values + measured calibration).
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1 — DNN models and training statistics",
        &[
            "model",
            "task",
            "dataset",
            "mlp params",
            "emb params",
            "batch",
            "density (paper)",
            "density (measured)",
        ],
    );
    for p in profiles::table1() {
        let gen = GradientGen::new(p.scaled(FIG_SCALE), SEED);
        let measured: f64 = (0..4)
            .map(|it| gen.iteration(it, 0).density())
            .sum::<f64>()
            / 4.0;
        t.row(vec![
            p.name.into(),
            p.task.into(),
            p.dataset.into(),
            format!("{}M", p.mlp_params / 1_000_000),
            format!("{}M", p.emb_params() / 1_000_000),
            p.batch_size.to_string(),
            format!("{:.2}%", p.density * 100.0),
            format!("{:.2}%", measured * 100.0),
        ]);
    }
    t
}

/// Table 2 — scheme taxonomy, generated from the implementations.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2 — communication schemes by design dimension",
        &["scheme", "communication", "aggregation", "partition", "balance", "format"],
    );
    for s in schemes::all_schemes(4, 0, 1024) {
        let d = s.dims();
        t.row(vec![
            s.name().into(),
            format!("{:?}", d.communication),
            format!("{:?}", d.aggregation),
            format!("{:?}", d.partition),
            format!("{:?}", d.balance),
            d.format.into(),
        ]);
    }
    t
}

/// Fig 1a — PDF of pairwise overlap ratios per model.
pub fn fig1a() -> Table {
    let mut t = Table::new(
        "Fig 1a — overlap ratio PDF",
        &["model", "overlap bin center", "pdf"],
    );
    for p in profiles::table1() {
        let gen = GradientGen::new(p.scaled(FIG_SCALE), SEED);
        let mut h = Histogram::new(0.0, 1.0, 20);
        for it in 0..3u64 {
            let tensors = gen.iteration_all(it, 8);
            for i in 0..tensors.len() {
                for j in i + 1..tensors.len() {
                    h.add(metrics::overlap_ratio(&tensors[i], &tensors[j]));
                }
            }
        }
        for (c, pdf) in h.centers().iter().zip(h.pdf()) {
            t.row(vec![p.name.into(), format!("{c:.3}"), format!("{pdf:.4}")]);
        }
    }
    t
}

/// Fig 1b — densification ratio vs number of GPUs.
pub fn fig1b() -> Table {
    let mut t = Table::new(
        "Fig 1b — densification ratio vs GPUs",
        &["model", "gpus", "densification ratio", "gamma < n"],
    );
    for p in profiles::table1() {
        let gen = GradientGen::new(p.scaled(FIG_SCALE), SEED);
        for n in [2usize, 4, 8, 16, 32, 64, 128] {
            let tensors = gen.iteration_all(0, n);
            let gamma = metrics::densification_ratio(&tensors);
            t.row(vec![
                p.name.into(),
                n.to_string(),
                format!("{gamma:.2}"),
                (gamma < n as f64).to_string(),
            ]);
        }
    }
    t
}

/// Fig 2a — share of non-zeros per partition (8 partitions).
pub fn fig2a() -> Table {
    let mut t = Table::new(
        "Fig 2a — non-zero share per partition (8 partitions)",
        &["model", "partition", "share %"],
    );
    for p in profiles::table1() {
        let gen = GradientGen::new(p.scaled(FIG_SCALE), SEED);
        let tensor = gen.iteration(0, 0);
        let counts = metrics::partition_nnz(&tensor, 8);
        let total: usize = counts.iter().sum();
        for (i, c) in counts.iter().enumerate() {
            t.row(vec![
                p.name.into(),
                i.to_string(),
                format!("{:.1}", *c as f64 / total.max(1) as f64 * 100.0),
            ]);
        }
    }
    t
}

/// Fig 2b — skewness ratio vs number of partitions.
pub fn fig2b() -> Table {
    let mut t = Table::new(
        "Fig 2b — skewness ratio vs partitions",
        &["model", "partitions", "skewness ratio"],
    );
    for p in profiles::table1() {
        let gen = GradientGen::new(p.scaled(FIG_SCALE), SEED);
        let tensor = gen.iteration(0, 0);
        for n in [2usize, 4, 8, 16, 32, 64, 128] {
            t.row(vec![
                p.name.into(),
                n.to_string(),
                format!("{:.1}", metrics::skewness_ratio(&tensor, n)),
            ]);
        }
    }
    t
}

/// Fig 7 — normalized communication-time comparison (NMT).
pub fn fig7() -> Table {
    let profile = profiles::by_name("NMT").unwrap().scaled(FIG_SCALE);
    let pts = fig7_sweep(&profile, &[4, 8, 16, 32, 64, 128], LinkKind::Tcp25, SEED);
    fig7_table(&pts)
}

/// Fig 8 — strawman memory size vs extraction cost and collision loss.
pub fn fig8() -> Table {
    let mut t = Table::new(
        "Fig 8 — strawman memory vs extraction cost / loss",
        &["memory multiple (of nnz)", "density", "extract+hash ms", "loss rate %"],
    );
    // DeepFM-like tensor scaled: 214M → FIG_SCALE.
    let gen = gen_for("DeepFM", FIG_SCALE);
    for density_mult in [1usize, 4] {
        // densities ~2.8% and ~11% (post-aggregation regime)
        let tensors = gen.iteration_all(0, density_mult * density_mult);
        let tensor = CooTensor::merge_all(&tensors);
        for mem_mult in [1.0f64, 2.0, 4.0, 8.0, 16.0] {
            let h = StrawmanHasher::new(SEED, 16, (tensor.nnz() as f64 * mem_mult) as usize);
            let sw = Stopwatch::start();
            let out = h.partition(&tensor);
            let ms = sw.elapsed() * 1e3;
            t.row(vec![
                format!("{mem_mult}"),
                format!("{:.3}", tensor.density()),
                format!("{ms:.2}"),
                format!("{:.1}", out.loss_rate(tensor.nnz()) * 100.0),
            ]);
        }
    }
    t
}

const FIG11_SCHEMES: [&str; 6] = [
    "allreduce",
    "agsparse",
    "sparcml",
    "sparseps",
    "omnireduce",
    "zen",
];

/// Figs 11/12 — training throughput (samples/s) per model × machines.
pub fn fig11_12(link: LinkKind, title: &str) -> Table {
    let mut t = Table::new(title, &["model", "machines", "scheme", "samples/s"]);
    for p in profiles::table1() {
        for machines in [4usize, 8, 16] {
            for scheme in FIG11_SCHEMES {
                let mut cfg = SimConfig::new(p.clone(), machines, scheme);
                cfg.link = link;
                cfg.scale = FIG_SCALE;
                cfg.iterations = 2;
                let r = SimDriver::new(cfg).unwrap().run();
                t.row(vec![
                    p.name.into(),
                    machines.to_string(),
                    r.scheme.clone(),
                    format!("{:.0}", r.throughput),
                ]);
            }
            // Upper bound: communication at the no-index lower bound.
            let gen = GradientGen::new(p.scaled(FIG_SCALE), SEED);
            let tensors = gen.iteration_all(0, machines);
            let d_agg = metrics::aggregated_density(&tensors);
            let lb = d_agg * (p.emb_params() * 4) as f64 * 8.0 / link.bandwidth_bps();
            let compute = compute_time_per_iter(p.name);
            let tput = (machines * 8 * p.batch_size) as f64 / (compute + lb);
            t.row(vec![
                p.name.into(),
                machines.to_string(),
                "UpperBound".into(),
                format!("{tput:.0}"),
            ]);
        }
    }
    t
}

/// Fig 13 — communication speedup over AllReduce at 16 machines.
pub fn fig13() -> Table {
    let mut t = Table::new(
        "Fig 13 — communication speedup vs AllReduce (16 machines, 25Gbps)",
        &["model", "scheme", "speedup"],
    );
    for p in profiles::table1() {
        let mut base = None;
        for scheme in FIG11_SCHEMES {
            let mut cfg = SimConfig::new(p.clone(), 16, scheme);
            cfg.scale = FIG_SCALE;
            cfg.iterations = 2;
            let r = SimDriver::new(cfg).unwrap().run();
            let sync = r.emb_sync_mean;
            if scheme == "allreduce" {
                base = Some(sync);
            }
            t.row(vec![
                p.name.into(),
                r.scheme.clone(),
                format!("{:.2}", base.unwrap() / sync),
            ]);
        }
    }
    t
}

/// Fig 15 — Push/Pull imbalance ratio, Sparse PS vs Zen (DeepFM).
pub fn fig15() -> Table {
    let mut t = Table::new(
        "Fig 15 — imbalance ratio (DeepFM)",
        &["machines", "scheme", "push imbalance", "pull imbalance"],
    );
    for machines in [4usize, 8, 16, 32, 64] {
        for scheme in ["sparseps", "zen"] {
            let mut cfg = SimConfig::new(profiles::by_name("DeepFM").unwrap(), machines, scheme);
            cfg.scale = FIG_SCALE;
            cfg.iterations = 2;
            cfg.gpus_per_machine = 4;
            let r = SimDriver::new(cfg).unwrap().run();
            let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
            t.row(vec![
                machines.to_string(),
                r.scheme.clone(),
                format!("{:.2}", mean(&r.push_imbalance)),
                format!("{:.2}", mean(&r.pull_imbalance)),
            ]);
        }
    }
    t
}

/// Fig 16 — Algorithm 1 computation cost vs r1 (a) and k (b).
pub fn fig16() -> Table {
    let mut t = Table::new(
        "Fig 16 — Algorithm 1 cost vs memory and rehash count",
        &["r1 multiple", "k", "density %", "hash+extract ms", "serial writes", "overflow"],
    );
    let gen = gen_for("DeepFM", FIG_SCALE);
    let tensors = gen.iteration_all(0, 4);
    let tensor = CooTensor::merge_all(&tensors); // denser, post-agg regime
    let nnz = tensor.nnz();
    let n = 16;
    // (a) sweep r1 at k = 3
    for r1_mult in [1.0f64, 2.0, 4.0, 8.0] {
        let r1 = ((nnz as f64 * r1_mult) as usize / n).max(1);
        let h = HierarchicalHasher::new(SEED, n, 3, r1, (r1 / 10).max(1));
        let sw = Stopwatch::start();
        let out = h.partition(&tensor);
        t.row(vec![
            format!("{r1_mult}"),
            "3".into(),
            format!("{:.2}", tensor.density() * 100.0),
            format!("{:.2}", sw.elapsed() * 1e3),
            out.serial_writes.to_string(),
            out.overflow_writes.to_string(),
        ]);
    }
    // (b) sweep k at r1 = 2×nnz
    for k in [1usize, 2, 3, 4] {
        let r1 = (2 * nnz / n).max(1);
        let h = HierarchicalHasher::new(SEED, n, k, r1, (r1 / 10).max(1));
        let sw = Stopwatch::start();
        let out = h.partition(&tensor);
        t.row(vec![
            "2".into(),
            k.to_string(),
            format!("{:.2}", tensor.density() * 100.0),
            format!("{:.2}", sw.elapsed() * 1e3),
            out.serial_writes.to_string(),
            out.overflow_writes.to_string(),
        ]);
    }
    t
}

/// Fig 17 — index-format wire size vs aggregated tensor density,
/// normalized to the dense tensor (16 servers).
pub fn fig17() -> Table {
    let mut t = Table::new(
        "Fig 17 — format size vs density (normalized to dense)",
        &["density %", "COO", "bitmap", "tensor block", "hash bitmap"],
    );
    let dense_len = 1 << 20;
    let n_servers = 16;
    let mut rng = Pcg64::seeded(SEED);
    let hasher = HierarchicalHasher::with_defaults(SEED, n_servers, dense_len / 20);
    let domains = hasher.partition_domains(dense_len);
    for density_pct in [1.0f64, 5.0, 10.0, 20.0, 40.0, 60.0, 80.0, 95.0] {
        let nnz = ((density_pct / 100.0) * dense_len as f64) as usize;
        let mut idx: Vec<u32> = rng
            .sample_distinct(dense_len, nnz)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        idx.sort_unstable();
        let tensor = CooTensor::from_sorted(dense_len, idx, vec![1.0; nnz]);
        let dense_bytes = (dense_len * 4) as f64;
        let coo = tensor.wire_bytes() as f64 / dense_bytes;
        // positional bitmap: each of n servers must describe the full
        // range (hashed indices are spread everywhere) → n·|G|/8 bits
        // + values
        let bitmap = (n_servers * crate::util::ceil_div(dense_len, 8) + nnz * 4) as f64
            / dense_bytes;
        let blocks =
            BlockTensor::from_coo(&tensor, 256).wire_bytes() as f64 / dense_bytes;
        // hash bitmap: Σ_p |domain_p|/8 + values = |G|/8 + values
        let hb: usize = domains
            .iter()
            .map(|d| crate::util::ceil_div(d.len(), 8))
            .sum::<usize>()
            + nnz * 4;
        t.row(vec![
            format!("{density_pct}"),
            format!("{coo:.3}"),
            format!("{bitmap:.3}"),
            format!("{blocks:.3}"),
            format!("{:.3}", hb as f64 / dense_bytes),
        ]);
    }
    t
}

/// Fig P1 (beyond the paper) — the planner crossover map: which scheme
/// the cost model picks per (density × machines) cell, from *measured*
/// stats of uniform synthetic tensors. The diagram behind
/// `--scheme auto`: Fig 7's crossovers as a decision surface.
pub fn planner_crossover() -> Table {
    let mut t = Table::new(
        "Fig P1 — planner crossover map (chosen scheme per density × machines)",
        &["density %", "machines", "chosen", "predicted ms", "runner-up", "margin"],
    );
    let dense_len = 1 << 16;
    let block = crate::tensor::block::DEFAULT_BLOCK;
    for density in [0.0005f64, 0.002, 0.01, 0.05, 0.2, 0.5] {
        for machines in [2usize, 4, 8, 16, 32, 64] {
            let inputs =
                random_uniform_inputs(SEED ^ machines as u64, machines, dense_len, density);
            let stats = MeasuredStats::from_tensors(&inputs, &[machines], &[block]);
            let topo = Topology::flat(machines, LinkKind::Tcp25);
            let costs = rank_candidates(dense_len as f64, machines, &topo, block, &stats);
            let best = &costs[0];
            let second = &costs[1];
            t.row(vec![
                format!("{:.2}", density * 100.0),
                machines.to_string(),
                best.scheme.to_string(),
                format!("{:.4}", best.time * 1e3),
                second.scheme.to_string(),
                format!("{:.2}x", second.time / best.time.max(1e-12)),
            ]);
        }
    }
    t
}

/// Fig 7-M (beyond the paper) — the Fig 7 sweep re-derived from
/// *measured* statistics: the cost model is fed
/// [`MeasuredStats::profile_workload`] profiles instead of analytic
/// ones, and its per-scheme predictions sit next to the
/// transport-measured times (both normalized to closed-form Dense), so
/// the model's fidelity is a printed column, not an assumption.
pub fn fig7_measured() -> Table {
    fig7_measured_for(
        &profiles::by_name("NMT").unwrap().scaled(FIG_SCALE),
        &[4, 8, 16, 32],
        SEED,
    )
}

/// Parameterized body of [`fig7_measured`] (tests run smaller sweeps).
pub fn fig7_measured_for(profile: &ModelProfile, machine_counts: &[usize], seed: u64) -> Table {
    let mut t = Table::new(
        "Fig 7-M — cost-model predictions from measured stats vs transport-measured (x Dense)",
        &["machines", "scheme", "predicted", "measured", "measured/predicted"],
    );
    let gen = GradientGen::new(profile.clone(), seed);
    let link = LinkKind::Tcp25;
    let block = crate::tensor::block::DEFAULT_BLOCK;
    let m = profile.emb_params() as f64;
    for &n in machine_counts {
        let stats = MeasuredStats::profile_workload(&gen, n, 2, &[block]);
        // Unlike Fig 7's pure-bandwidth accounting, this refit includes
        // the α-per-stage term — it is the planner's *actual*
        // prediction, judged against what the transport measured.
        let cm = CostModel::new(m, n, link.bandwidth_bps() / 32.0, &stats)
            .with_latency(link.latency());
        let dense_time = cm.dense();
        let inputs = gen.iteration_all(0, n);
        let net = Network::new(n, link);
        for name in schemes::PLANNER_CANDIDATES {
            let predicted = cm.time_for(name, block).expect("candidate closed form");
            let scheme = schemes::by_name(name, n, seed ^ 0x5a5a, gen.expected_nnz()).unwrap();
            // comm_time() is pure stage time — Zen's hashing charge
            // lands in compute_overhead and stays out of this column.
            let measured = scheme
                .run_sim(&inputs, &net, &mut schemes::SyncScratch::new())
                .report
                .comm_time();
            t.row(vec![
                n.to_string(),
                scheme.name().to_string(),
                format!("{:.3}", predicted / dense_time),
                format!("{:.3}", measured / dense_time),
                format!("{:.2}", measured / predicted.max(1e-12)),
            ]);
        }
    }
    t
}

/// Fig 7-E (beyond the paper) — the Fig 7 scheme crossover at
/// event-driver scale: transport-measured comm time per candidate,
/// normalized to the dense ring, at machine counts no thread-per-rank
/// backend could sweep — all simulated on one thread by
/// [`crate::wire::EventDriver`]. Each cell also checks the planner:
/// `planner-pick` marks the cost model's argmin, `measured-best` the
/// transport-measured winner; the crossover reproduces when the marks
/// coincide (or sit within a near-tie).
pub fn fig7_event_scale() -> Table {
    fig7_event_scale_for(&[64, 128, 256, 512])
}

/// Parameterized body of [`fig7_event_scale`] (tests run smaller sweeps).
pub fn fig7_event_scale_for(machine_counts: &[usize]) -> Table {
    let mut t = Table::new(
        "Fig 7-E — scheme crossover at event-driver scale (x Dense, one thread)",
        &["machines", "scheme", "measured", "events", "flags"],
    );
    let dense_len = 1 << 12;
    let density = 0.005;
    let block = crate::tensor::block::DEFAULT_BLOCK;
    let link = LinkKind::Tcp25;
    for &n in machine_counts {
        let inputs = random_uniform_inputs(SEED ^ (n as u64) << 1, n, dense_len, density);
        let nnz = inputs[0].nnz().max(8);
        let stats = MeasuredStats::from_tensors(&inputs, &[n], &[block]);
        let topo = Topology::flat(n, link);
        let planner_pick = rank_candidates(dense_len as f64, n, &topo, block, &stats)[0].scheme;
        let net = Network::new(n, link);
        let mut measured: Vec<(&str, f64, u64)> = Vec::new();
        for name in schemes::PLANNER_CANDIDATES {
            let scheme = schemes::by_name(name, n, SEED ^ 0x5a5a, nnz).unwrap();
            let mut drv = crate::wire::EventDriver::new(net.clone()).totals_only();
            scheme
                .run(&inputs, &mut drv, &mut schemes::SyncScratch::new())
                .expect("event-driver sweep sync");
            measured.push((name, drv.totals().time, drv.events_processed()));
        }
        let dense_time = measured
            .iter()
            .find(|(name, ..)| *name == "allreduce")
            .map(|&(_, time, _)| time)
            .unwrap_or(f64::NAN);
        let best = measured
            .iter()
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .map(|&(name, ..)| name)
            .unwrap_or("");
        for (name, time, events) in measured {
            let mut flags: Vec<&str> = Vec::new();
            if name == planner_pick {
                flags.push("planner-pick");
            }
            if name == best {
                flags.push("measured-best");
            }
            t.row(vec![
                n.to_string(),
                name.to_string(),
                format!("{:.3}", time / dense_time),
                events.to_string(),
                flags.join("+"),
            ]);
        }
    }
    t
}

/// Fig T1 (beyond the paper) — the hierarchy crossover under
/// heterogeneous links: the planner's chosen scheme per (sparsity
/// structure × topology). Group-clustered workers (co-located ranks
/// share their gradient support) make SparCML's node-local first
/// doubling stage a genuine win once inter-node links are 10× slower —
/// a flip the flat mesh cannot see. Uniform workers keep Balanced
/// Parallelism on top everywhere.
pub fn topology_crossover() -> Table {
    let mut t = Table::new(
        "Fig T1 — planner choice per sparsity structure × topology (4 nodes × 2 ranks)",
        &["workload", "topology", "chosen", "predicted ms", "runner-up", "margin"],
    );
    let dense_len = 1 << 18;
    let block = crate::tensor::block::DEFAULT_BLOCK;
    let nodes = 4usize;
    let ranks = 2usize;
    let n = nodes * ranks;
    // Zero-latency links isolate the bandwidth crossover; the inter
    // fabric is 10× slower than the intra-node link.
    let inter = LinkKind::Custom(25_000_000_000, 0);
    let intra = LinkKind::Custom(250_000_000_000, 0);
    let topos = [
        ("flat", Topology::flat(n, inter)),
        ("4x2 two-level", Topology::two_level(nodes, ranks, intra, inter)),
    ];
    let workloads: [(&str, Vec<crate::tensor::CooTensor>); 2] = [
        (
            // Two rack-level groups of 4 ranks each (nodes 0-1 / 2-3
            // share one support): d(2)=d(4)=d(1), d(8)=2·d(1).
            "group-clustered",
            group_clustered_inputs(SEED, 2, n / 2, dense_len, 0.01),
        ),
        ("uniform", random_uniform_inputs(SEED ^ 0x70, n, dense_len, 0.01)),
    ];
    for (wname, inputs) in &workloads {
        let stats = MeasuredStats::from_tensors(inputs, &[n], &[block]);
        for (tname, topo) in &topos {
            let costs = rank_candidates(dense_len as f64, n, topo, block, &stats);
            let best = &costs[0];
            let second = &costs[1];
            t.row(vec![
                (*wname).into(),
                (*tname).into(),
                best.scheme.to_string(),
                format!("{:.4}", best.time * 1e3),
                second.scheme.to_string(),
                format!("{:.2}x", second.time / best.time.max(1e-12)),
            ]);
        }
    }
    t
}

/// Fig 18 — Zen speedup breakdown: Algorithm 1 (COO pull) vs + hash bitmap.
pub fn fig18() -> Table {
    let mut t = Table::new(
        "Fig 18 — Zen speedup breakdown over AllReduce (16 machines)",
        &["model", "Zen (Alg1 + COO)", "Zen (Alg1 + hash bitmap)"],
    );
    for p in profiles::table1() {
        let mut speedups = Vec::new();
        let mut base = 0.0;
        for scheme in ["allreduce", "zen-coo", "zen"] {
            let mut cfg = SimConfig::new(p.clone(), 16, scheme);
            cfg.scale = FIG_SCALE;
            cfg.iterations = 2;
            let r = SimDriver::new(cfg).unwrap().run();
            if scheme == "allreduce" {
                base = r.emb_sync_mean;
            } else {
                speedups.push(base / r.emb_sync_mean);
            }
        }
        t.row(vec![
            p.name.into(),
            format!("{:.2}", speedups[0]),
            format!("{:.2}", speedups[1]),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_and_2_render() {
        assert_eq!(table1().rows.len(), 4);
        assert!(table2().rows.len() >= 6);
    }

    #[test]
    fn fig2b_skew_increases_with_partitions() {
        let t = fig2b();
        // For each model, skewness at 128 partitions > at 2 partitions.
        for model in ["LSTM", "DeepFM", "NMT", "BERT"] {
            let vals: Vec<f64> = t
                .rows
                .iter()
                .filter(|r| r[0] == model)
                .map(|r| r[2].parse().unwrap())
                .collect();
            assert!(vals.last().unwrap() > vals.first().unwrap(), "{model}");
        }
    }

    #[test]
    fn crossover_map_has_both_regimes() {
        let t = planner_crossover();
        // density 0.05% at 2 machines: index-carrying sparse schemes win
        let sparse_cell = t
            .rows
            .iter()
            .find(|r| r[0] == "0.05" && r[1] == "2")
            .unwrap();
        assert_ne!(sparse_cell[2], "allreduce", "sparse regime");
        // density 50% at 64 machines: aggregates are fully dense — the
        // planner must fall back to a dense-traffic scheme (ring
        // allreduce, or block-format OmniReduce whose full-density
        // traffic matches dense within 1/b but pays fewer α stages).
        let dense_cell = t
            .rows
            .iter()
            .find(|r| r[0] == "50.00" && r[1] == "64")
            .unwrap();
        assert!(
            dense_cell[2] == "allreduce" || dense_cell[2] == "omnireduce",
            "dense regime picked {}",
            dense_cell[2]
        );
        // every cell chose a real candidate
        for row in &t.rows {
            assert!(
                schemes::PLANNER_CANDIDATES.contains(&row[2].as_str()),
                "unknown choice {}",
                row[2]
            );
        }
    }

    #[test]
    fn fig7_measured_predictions_track_measurements() {
        let t = fig7_measured_for(
            &profiles::by_name("NMT").unwrap().scaled(1024),
            &[4, 8],
            0x7a,
        );
        assert_eq!(t.rows.len(), 2 * schemes::PLANNER_CANDIDATES.len());
        for row in &t.rows {
            let ratio: f64 = row[4].parse().unwrap();
            // Pure-bandwidth predictions vs α-and-frame-charged
            // measurements at small scale: generous envelope, but a
            // model an order of magnitude off would be broken.
            assert!(
                (0.2..=8.0).contains(&ratio),
                "{} at n={}: measured/predicted {ratio}",
                row[1],
                row[0]
            );
        }
    }

    #[test]
    fn topology_crossover_flips_to_hierarchy() {
        let t = topology_crossover();
        let cell = |w: &str, topo: &str| {
            t.rows
                .iter()
                .find(|r| r[0] == w && r[1] == topo)
                .unwrap_or_else(|| panic!("missing cell {w}/{topo}"))[2]
                .clone()
        };
        let flat = cell("group-clustered", "flat");
        let hier = cell("group-clustered", "4x2 two-level");
        let is_hier = |name: &str| {
            let s = schemes::by_name(name, 8, 1, 64).expect("chosen scheme constructs");
            s.dims().communication == schemes::CommPattern::Hierarchy
        };
        assert!(
            !is_hier(&flat),
            "flat mesh must pick a non-hierarchical scheme, got {flat}"
        );
        assert!(
            is_hier(&hier),
            "two-level 10x-slower-inter must pick a hierarchical scheme, got {hier}"
        );
    }

    #[test]
    fn fig7_event_scale_rows_are_complete_and_marked() {
        // Small counts keep the test fast; the 512-rank sweep is the
        // example binary's job.
        let t = fig7_event_scale_for(&[8, 16]);
        assert_eq!(t.rows.len(), 2 * schemes::PLANNER_CANDIDATES.len());
        for machines in ["8", "16"] {
            let rows: Vec<_> = t.rows.iter().filter(|r| r[0] == machines).collect();
            // Normalization anchor: the dense ring's own ratio is 1.
            let dense = rows.iter().find(|r| r[1] == "allreduce").unwrap();
            let ratio: f64 = dense[2].parse().unwrap();
            assert!((ratio - 1.0).abs() < 1e-9, "n={machines}: {ratio}");
            assert_eq!(
                rows.iter().filter(|r| r[4].contains("planner-pick")).count(),
                1,
                "n={machines}: exactly one planner pick"
            );
            assert_eq!(
                rows.iter().filter(|r| r[4].contains("measured-best")).count(),
                1,
                "n={machines}: exactly one measured best"
            );
            for r in &rows {
                assert!(r[2].parse::<f64>().unwrap().is_finite());
                assert!(r[3].parse::<u64>().unwrap() > 0, "events counted");
            }
        }
    }

    #[test]
    fn fig17_hash_bitmap_wins_at_high_density() {
        let t = fig17();
        let last = t.rows.last().unwrap(); // 95% density
        let coo: f64 = last[1].parse().unwrap();
        let hb: f64 = last[4].parse().unwrap();
        assert!(hb < 1.0, "hash bitmap must beat dense even at 95%: {hb}");
        assert!(coo > 1.0, "COO must exceed dense at 95%: {coo}");
    }
}
