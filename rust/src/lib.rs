//! # zen-sync
//!
//! A reproduction of **"Zen: Near-Optimal Sparse Tensor Synchronization
//! for Distributed DNN Training"** (arXiv title: *Empowering Distributed
//! Training with Sparsity-driven Data Synchronization*) as a three-layer
//! rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the distributed-training synchronization
//!   runtime — sparse tensor formats, the hierarchical hashing algorithm
//!   (Alg 1), the hash bitmap (Alg 2), all baseline communication schemes,
//!   a virtual-time cluster/network simulator, and the training
//!   coordinator that drives the AOT-compiled model.
//! - **L2**: `python/compile/model.py` — the embedding-LM compute graph,
//!   lowered once to HLO text and executed via [`runtime`] (PJRT CPU).
//! - **L1**: `python/compile/kernels/` — Pallas kernels (hash mixing,
//!   fused embedding+MLP) validated against pure-jnp oracles.
//!
//! See DESIGN.md (repository root) for the experiment index mapping every
//! paper table and figure to a module and a regeneration command.
pub mod analysis;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod figures;
pub mod hashing;
pub mod planner;
pub mod runtime;
pub mod schemes;
pub mod tensor;
pub mod util;
pub mod wire;
pub mod workload;
