//! # zen-sync
//!
//! A reproduction of **"Zen: Near-Optimal Sparse Tensor Synchronization
//! for Distributed DNN Training"** (arXiv title: *Empowering Distributed
//! Training with Sparsity-driven Data Synchronization*) as a three-layer
//! rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the distributed-training synchronization
//!   runtime — sparse tensor formats, the hierarchical hashing algorithm
//!   (Alg 1), the hash bitmap (Alg 2), all baseline communication schemes,
//!   a virtual-time cluster/network simulator, and the training
//!   coordinator that drives the AOT-compiled model.
//! - **L2**: `python/compile/model.py` — the embedding-LM compute graph,
//!   lowered once to HLO text and executed via [`runtime`] (PJRT CPU).
//! - **L1**: `python/compile/kernels/` — Pallas kernels (hash mixing,
//!   fused embedding+MLP) validated against pure-jnp oracles.
//!
//! See DESIGN.md (repository root) for the experiment index mapping every
//! paper table and figure to a module and a regeneration command.
pub mod analysis;
pub mod check;
pub mod cluster;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod figures;
pub mod hashing;
pub mod kernel;
pub mod planner;
pub mod runtime;
pub mod schemes;
pub mod tensor;
pub mod util;
pub mod wire;
pub mod workload;

/// The supported public surface in one import.
///
/// ```no_run
/// use zen::prelude::*;
///
/// let inputs: Vec<CooTensor> = /* per-rank sparse gradients */ vec![];
/// let net = Network::new(4, LinkKind::Tcp25);
/// let scheme = schemes::by_name("zen", 4, 7, 1024).unwrap();
/// let out = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
/// # let _ = out;
/// ```
///
/// Everything here is semver-intended API; paths *not* re-exported
/// (e.g. `wire::fabric` internals, per-scheme machine types) are
/// implementation detail and may change without notice. See DESIGN.md
/// § "API boundary".
pub mod prelude {
    pub use crate::cluster::{CommReport, LinkKind, Network, Topology};
    pub use crate::compress::{CompressSpec, Compressor};
    pub use crate::coordinator::lm::{LmConfig, LmTrainer};
    pub use crate::coordinator::{PipelineConfig, SimConfig, SimDriver, SimResult};
    pub use crate::engine::{EngineConfig, SyncEngine};
    pub use crate::planner;
    pub use crate::schemes::{self, SyncOutput, SyncScheme, SyncScratch};
    pub use crate::tensor::CooTensor;
    pub use crate::wire::{
        make_driver, Driver, Event, EventDriver, Protocol, SocketDriver, ThreadedDriver,
        Transport, TransportDriver, TransportKind, WireError, WorkerDriver,
    };
}
