//! Schedule-recording executor under the model checker (`zen check`).
//!
//! [`ScheduleDriver`] is the trace-record/replay hook on [`Driver`]: it
//! drives all n machines itself (like
//! [`TransportDriver`](crate::wire::TransportDriver)) but *defers*
//! every delivery into a per-(src, dst) FIFO matrix and chooses which
//! pending frame the destination sees next — prescribed by an explicit
//! schedule prefix, canonical (lowest source) past it. Every delivery,
//! branch point, and stage boundary lands in a [`RunRecord`], which is
//! what [`crate::check`] enumerates delivery orders over; invariant
//! breaches surface as a typed [`Violation`] instead of a panic or a
//! hang.
//!
//! ## Canonical order and the DPOR-style reduction
//!
//! Deliveries to *distinct* destinations commute: [`Protocol::deliver`]
//! mutates only the destination machine, and the poll phase runs every
//! machine to a parked state independently (a machine touches only its
//! own state plus its per-rank scratch slot). The executor therefore
//! fixes the destination — the lowest rank with any pending frame —
//! and branches only over which *source*'s head frame that destination
//! receives, collapsing the factorial interleaving of independent
//! deliveries to the product of per-receiver arrival orders. The
//! reduction is complete for every scheme in this repo because within a
//! stage (a) the star-pattern machines emit all their sends before
//! consuming any same-stage delivery, and (b) the ring and
//! recursive-doubling stages have exactly one source per destination. A
//! hypothetical protocol whose mid-stage deliveries trigger *new* sends
//! could realize arrival orders the reduction never explores; the
//! per-run output digest in [`crate::check`] is the safety net for that
//! assumption.

use std::collections::VecDeque;

use super::codec::{Message, WireError};
use super::driver::{consensus_stage, DriveOutcome, Driver};
use super::protocol::{Event, Protocol};
use super::transport::StageAcc;
use crate::cluster::Network;
use crate::schemes::SyncScratch;
use crate::tensor::CooTensor;

/// Hard cap on poll events per run: a machine that livelocks (emits
/// events forever without completing) is reported instead of hanging
/// the checker.
const MAX_POLLS: usize = 4_000_000;

/// Hard cap on closed stages per run (same livelock guard).
const MAX_STAGES: usize = 4_096;

/// An invariant the executor (or the checker above it) caught a
/// protocol breaking, with enough context to print and to replay.
#[derive(Clone, Debug, PartialEq)]
pub enum Violation {
    /// Every machine is parked, no frame is pending delivery, and at
    /// least one machine still waits on `NeedFrame` — nothing can ever
    /// wake it. `parked_done` lists the ranks already at the stage
    /// boundary (non-empty means a premature `StageDone` somewhere).
    Deadlock {
        waiting: Vec<usize>,
        parked_done: Vec<usize>,
    },
    /// A frame was sent to, or was still undelivered at, a rank that
    /// already emitted `Complete`.
    SentToFinished { src: usize, dst: usize },
    /// A machine completed while frames addressed to it were still
    /// pending delivery.
    CompletedWithPending { dst: usize, pending: usize },
    /// Stage-boundary accounting failed: parked machines disagree on
    /// the open stage, or byte conservation broke (`StageAcc` refused
    /// to close, or per-stage sent/delivered totals diverged).
    StageError { detail: String },
    /// A machine returned a `WireError` from poll/deliver/stage_closed,
    /// or exceeded the livelock budget.
    MachineError { rank: usize, detail: String },
    /// A machine panicked (caught by the checker's `catch_unwind`).
    MachinePanic { detail: String },
    /// A prescribed replay step named a (src, dst) pair with no pending
    /// frame — the schedule does not belong to this protocol run.
    BadSchedule { step: usize, src: usize, dst: usize },
    /// Two explored delivery orders produced different outputs
    /// (checker-level: the bit-identical-output invariant).
    OutputDivergence { detail: String },
    /// An output failed the losslessness oracle (checker-level: sum of
    /// inputs, within float tolerance).
    OracleFailure { detail: String },
}

impl Violation {
    /// Stable short name — what counterexample minimization matches on.
    pub fn kind(&self) -> &'static str {
        match self {
            Violation::Deadlock { .. } => "deadlock",
            Violation::SentToFinished { .. } => "sent-to-finished",
            Violation::CompletedWithPending { .. } => "completed-with-pending",
            Violation::StageError { .. } => "stage-error",
            Violation::MachineError { .. } => "machine-error",
            Violation::MachinePanic { .. } => "machine-panic",
            Violation::BadSchedule { .. } => "bad-schedule",
            Violation::OutputDivergence { .. } => "output-divergence",
            Violation::OracleFailure { .. } => "oracle-failure",
        }
    }
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Violation::Deadlock {
                waiting,
                parked_done,
            } => write!(
                f,
                "deadlock: ranks {waiting:?} wait on frames nobody will send \
                 (ranks {parked_done:?} already parked on the stage boundary)"
            ),
            Violation::SentToFinished { src, dst } => {
                write!(f, "rank {src} sent a frame to finished rank {dst}")
            }
            Violation::CompletedWithPending { dst, pending } => write!(
                f,
                "rank {dst} completed with {pending} frame(s) still pending delivery to it"
            ),
            Violation::StageError { detail } => write!(f, "stage accounting: {detail}"),
            Violation::MachineError { rank, detail } => {
                write!(f, "rank {rank} machine error: {detail}")
            }
            Violation::MachinePanic { detail } => write!(f, "machine panicked: {detail}"),
            Violation::BadSchedule { step, src, dst } => write!(
                f,
                "schedule step {step} names {src}>{dst} but no such frame is pending"
            ),
            Violation::OutputDivergence { detail } => {
                write!(f, "outputs differ across delivery orders: {detail}")
            }
            Violation::OracleFailure { detail } => {
                write!(f, "losslessness oracle failed: {detail}")
            }
        }
    }
}

/// One delivered frame: step `i` of a run moved `bytes` from `src` to
/// `dst`; `digest` fingerprints the encoded frame bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Delivery {
    pub src: usize,
    pub dst: usize,
    pub bytes: u64,
    pub digest: u64,
}

/// A step at which more than one source had a deliverable head frame
/// for the chosen destination: the DFS re-runs the schedule with each
/// alternative source swapped in at `step`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChoicePoint {
    /// Index into the run's trace where the branch happened.
    pub step: usize,
    /// The destination every branch delivers to.
    pub dst: usize,
    /// The canonically chosen source (lowest rank).
    pub chosen: usize,
    /// The other eligible sources.
    pub alternatives: Vec<usize>,
}

/// A closed stage boundary: `step` deliveries were complete when stage
/// `name` closed, and `state_hash` digests everything delivered so far
/// — order-insensitive within each stage, chained across stages — so
/// two runs that reach a boundary with the same hash are in the same
/// protocol state regardless of intra-stage delivery order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageBoundary {
    pub step: usize,
    pub name: &'static str,
    pub state_hash: u64,
}

/// Everything one executed schedule produced: the full delivery trace,
/// the branch points the DFS can flip, the stage boundaries for state
/// deduplication, and poll-count stats.
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    pub trace: Vec<Delivery>,
    pub choices: Vec<ChoicePoint>,
    pub boundaries: Vec<StageBoundary>,
    pub polls: usize,
}

impl RunRecord {
    /// The trace as a plain (src, dst) schedule — the replay currency.
    pub fn schedule(&self) -> Vec<(usize, usize)> {
        self.trace.iter().map(|d| (d.src, d.dst)).collect()
    }
}

/// Render a schedule as the `src>dst,src>dst,…` form `zen check
/// --replay` accepts.
pub fn schedule_string(sched: &[(usize, usize)]) -> String {
    let steps: Vec<String> = sched.iter().map(|&(s, d)| format!("{s}>{d}")).collect();
    steps.join(",")
}

/// FNV-1a over a byte slice — the frame/output fingerprint shared with
/// `zen worker`'s digest line.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Order-sensitive 64-bit mix of three words (boundary-hash chaining).
pub fn mix3(a: u64, b: u64, c: u64) -> u64 {
    let mut h = a ^ 0x9e37_79b9_7f4a_7c15;
    for v in [b, c] {
        h ^= v;
        h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
        h ^= h >> 33;
    }
    h
}

/// The schedule-record/replay driver. One instance runs one schedule
/// per [`run_checked`](ScheduleDriver::run_checked) call; the record of
/// the last run stays readable until the next call.
pub struct ScheduleDriver {
    net: Network,
    prefix: Vec<(usize, usize)>,
    record: RunRecord,
}

/// A frame parked in the pending-delivery matrix.
struct PendingFrame {
    msg: Message,
    bytes: u64,
    digest: u64,
}

impl ScheduleDriver {
    /// Canonical-order executor (empty prefix: every choice point takes
    /// the lowest eligible source).
    pub fn new(net: Network) -> ScheduleDriver {
        ScheduleDriver::with_prefix(net, Vec::new())
    }

    /// Executor that replays `prefix` verbatim, then continues
    /// canonically — the DFS and `--replay` entry point.
    pub fn with_prefix(net: Network, prefix: Vec<(usize, usize)>) -> ScheduleDriver {
        ScheduleDriver {
            net,
            prefix,
            record: RunRecord::default(),
        }
    }

    /// The record of the last `run_checked`/`drive` call.
    pub fn record(&self) -> &RunRecord {
        &self.record
    }

    /// Take the record, leaving an empty one.
    pub fn take_record(&mut self) -> RunRecord {
        std::mem::take(&mut self.record)
    }

    /// Run the machines under the prescribed schedule prefix (canonical
    /// lowest-source order past it), recording every delivery, choice
    /// point, and stage boundary. Returns the outputs or the first
    /// invariant violation; the record is retained either way (on a
    /// violation it holds the deliveries completed before the failure —
    /// the counterexample trace).
    pub fn run_checked<'a>(
        &mut self,
        mut machines: Vec<Box<dyn Protocol + 'a>>,
        scratch: &mut SyncScratch,
    ) -> Result<DriveOutcome, Violation> {
        self.record = RunRecord::default();
        let n = machines.len();
        if n != self.net.endpoints {
            return Err(Violation::StageError {
                detail: format!("{n} machines on {} endpoints", self.net.endpoints),
            });
        }
        let mut acc = StageAcc::new(self.net.clone());
        let mut done: Vec<Option<&'static str>> = (0..n).map(|_| None).collect();
        let mut need = vec![false; n];
        let mut outs: Vec<Option<CooTensor>> = (0..n).map(|_| None).collect();
        let mut finished = 0usize;
        // pending[src][dst]: frames sent but not yet delivered (FIFO —
        // per-source order is part of the protocol contract and never
        // reordered; the checker branches only across sources).
        let mut pending: Vec<Vec<VecDeque<PendingFrame>>> = (0..n)
            .map(|_| (0..n).map(|_| VecDeque::new()).collect())
            .collect();
        let mut pending_total = 0usize;
        let mut step = 0usize;
        let mut chain_hash = 0u64;
        let mut encode_buf: Vec<u8> = Vec::new();

        loop {
            // Phase 1: poll every runnable machine to a parked state,
            // in ascending rank (polls commute — each machine touches
            // only its own state plus its per-rank scratch slot).
            for i in 0..n {
                if outs[i].is_some() || done[i].is_some() || need[i] {
                    continue;
                }
                loop {
                    self.record.polls += 1;
                    if self.record.polls > MAX_POLLS {
                        return Err(Violation::MachineError {
                            rank: i,
                            detail: format!("poll budget ({MAX_POLLS}) exceeded — livelock?"),
                        });
                    }
                    match machines[i].poll(scratch) {
                        Err(e) => {
                            return Err(Violation::MachineError {
                                rank: i,
                                detail: e.to_string(),
                            })
                        }
                        Ok(Event::Send { dst, msg }) => {
                            if dst < n && outs[dst].is_some() {
                                return Err(Violation::SentToFinished { src: i, dst });
                            }
                            let frame = msg.as_frame();
                            if let Err(e) = acc.check_send(i, dst, &frame) {
                                return Err(Violation::MachineError {
                                    rank: i,
                                    detail: format!("invalid send to {dst}: {e}"),
                                });
                            }
                            let bytes = frame.encoded_len() as u64;
                            encode_buf.clear();
                            frame.encode(&mut encode_buf);
                            let digest = fnv1a(&encode_buf);
                            acc.charge(i, dst, bytes);
                            pending[i][dst].push_back(PendingFrame { msg, bytes, digest });
                            pending_total += 1;
                        }
                        Ok(Event::NeedFrame { .. }) => {
                            need[i] = true;
                            break;
                        }
                        Ok(Event::StageDone { name }) => {
                            done[i] = Some(name);
                            break;
                        }
                        Ok(Event::Complete(t)) => {
                            let inbound: usize = (0..n).map(|s| pending[s][i].len()).sum();
                            if inbound > 0 {
                                return Err(Violation::CompletedWithPending {
                                    dst: i,
                                    pending: inbound,
                                });
                            }
                            outs[i] = Some(t);
                            finished += 1;
                            break;
                        }
                    }
                }
            }
            if finished == n {
                break;
            }

            // Phase 2: deliver one pending frame — prescribed while
            // inside the replay prefix, canonical past it.
            if pending_total > 0 {
                let (src, dst) = if step < self.prefix.len() {
                    let (s, d) = self.prefix[step];
                    if s >= n || d >= n || pending[s][d].is_empty() {
                        return Err(Violation::BadSchedule {
                            step,
                            src: s,
                            dst: d,
                        });
                    }
                    (s, d)
                } else {
                    // Canonical choice: lowest destination with pending
                    // frames; branch across its eligible sources.
                    let dst = match (0..n).find(|&d| (0..n).any(|s| !pending[s][d].is_empty())) {
                        Some(d) => d,
                        None => unreachable!("pending_total > 0 but no pending frame found"),
                    };
                    let srcs: Vec<usize> =
                        (0..n).filter(|&s| !pending[s][dst].is_empty()).collect();
                    let chosen = srcs[0];
                    if srcs.len() > 1 {
                        self.record.choices.push(ChoicePoint {
                            step,
                            dst,
                            chosen,
                            alternatives: srcs[1..].to_vec(),
                        });
                    }
                    (chosen, dst)
                };
                let frame = match pending[src][dst].pop_front() {
                    Some(fr) => fr,
                    None => unreachable!("chosen queue verified non-empty"),
                };
                pending_total -= 1;
                acc.on_recv();
                if outs[dst].is_some() {
                    return Err(Violation::SentToFinished { src, dst });
                }
                if let Err(e) = machines[dst].deliver(src, frame.msg) {
                    return Err(Violation::MachineError {
                        rank: dst,
                        detail: format!("deliver from {src}: {e}"),
                    });
                }
                need[dst] = false;
                self.record.trace.push(Delivery {
                    src,
                    dst,
                    bytes: frame.bytes,
                    digest: frame.digest,
                });
                step += 1;
                continue; // eager re-poll before the next delivery
            }

            // Phase 3: nothing pending and nobody pollable — close the
            // stage, or report the deadlock.
            if need.iter().any(|&w| w) {
                return Err(Violation::Deadlock {
                    waiting: (0..n).filter(|&i| need[i]).collect(),
                    parked_done: (0..n).filter(|&i| done[i].is_some()).collect(),
                });
            }
            let name = match consensus_stage(&done) {
                Ok(name) => name,
                Err(e) => {
                    return Err(Violation::StageError {
                        detail: e.to_string(),
                    })
                }
            };
            if let Err(e) = acc.end_stage(name) {
                return Err(Violation::StageError {
                    detail: format!("stage '{name}': {e}"),
                });
            }
            // Boundary state hash: order-insensitive within the stage
            // (commutative add over per-delivery mixes), chained across
            // stages.
            let from = self.record.boundaries.last().map_or(0, |b| b.step);
            let mut stage_hash = 0u64;
            for d in &self.record.trace[from..] {
                stage_hash = stage_hash.wrapping_add(mix3(d.src as u64, d.dst as u64, d.digest));
            }
            chain_hash = mix3(chain_hash, fnv1a(name.as_bytes()), stage_hash);
            self.record.boundaries.push(StageBoundary {
                step,
                name,
                state_hash: chain_hash,
            });
            if self.record.boundaries.len() > MAX_STAGES {
                return Err(Violation::StageError {
                    detail: format!("stage budget ({MAX_STAGES}) exceeded — livelock?"),
                });
            }
            for (i, slot) in done.iter_mut().enumerate() {
                if slot.take().is_some() {
                    if let Err(e) = machines[i].stage_closed(name) {
                        return Err(Violation::MachineError {
                            rank: i,
                            detail: format!("stage_closed('{name}'): {e}"),
                        });
                    }
                }
            }
        }

        let outputs = outs
            .into_iter()
            .enumerate()
            .map(|(r, o)| match o {
                Some(t) => t,
                None => unreachable!("rank {r} counted finished without an output"),
            })
            .collect();
        Ok(DriveOutcome {
            outputs,
            report: acc.take_report(),
        })
    }
}

impl Driver for ScheduleDriver {
    fn endpoints(&self) -> usize {
        self.net.endpoints
    }

    /// The [`Driver`]-trait view: run under the recorded schedule and
    /// fold any violation into a [`WireError`] (the rich record stays
    /// readable via [`record`](ScheduleDriver::record)).
    fn drive<'a>(
        &mut self,
        machines: Vec<Box<dyn Protocol + 'a>>,
        scratch: &mut SyncScratch,
    ) -> Result<DriveOutcome, WireError> {
        self.run_checked(machines, scratch).map_err(|v| {
            WireError::Malformed(match v.kind() {
                "deadlock" => "model check: deadlock",
                "sent-to-finished" => "model check: frame sent to a finished machine",
                "completed-with-pending" => "model check: completed with pending frames",
                "stage-error" => "model check: stage accounting violation",
                "bad-schedule" => "model check: schedule does not fit this run",
                _ => "model check: invariant violation",
            })
        })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]
mod tests {
    use super::*;
    use crate::cluster::LinkKind;
    use crate::wire::protocol::Inbox;

    /// Star toy protocol: every rank pushes its tensor to every other
    /// rank in stage "x", waits for n−1 frames, sums ascending, then
    /// completes after closure — enough fan-in to create real choice
    /// points at n ≥ 3.
    struct Star {
        rank: usize,
        n: usize,
        sent: usize,
        inbox: Inbox,
        parked: bool,
        closed: bool,
        out: Option<CooTensor>,
    }

    fn star_machines(n: usize) -> Vec<Box<dyn Protocol>> {
        (0..n)
            .map(|rank| {
                Box::new(Star {
                    rank,
                    n,
                    sent: 0,
                    inbox: Inbox::new(n),
                    parked: false,
                    closed: false,
                    out: None,
                }) as Box<dyn Protocol>
            })
            .collect()
    }

    impl Protocol for Star {
        fn rank(&self) -> usize {
            self.rank
        }

        fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
            let peers: Vec<usize> = (0..self.n).filter(|&p| p != self.rank).collect();
            if self.sent < peers.len() {
                let dst = peers[self.sent];
                self.sent += 1;
                let t =
                    CooTensor::from_sorted(8, vec![self.rank as u32], vec![self.rank as f32 + 1.0]);
                return Ok(Event::Send {
                    dst,
                    msg: Message::PushCoo {
                        from: self.rank as u32,
                        tensor: t,
                    },
                });
            }
            if self.inbox.len() < self.n - 1 {
                let src = (0..self.n)
                    .find(|&w| w != self.rank && self.inbox.from_src(w) == 0)
                    .unwrap();
                return Ok(Event::NeedFrame { src });
            }
            if !self.parked {
                self.parked = true;
                return Ok(Event::StageDone { name: "x" });
            }
            assert!(self.closed, "polled past StageDone before closure");
            let mut parts: Vec<CooTensor> = self
                .inbox
                .drain_ascending()
                .into_iter()
                .map(|(_, m)| match m {
                    Message::PushCoo { tensor, .. } => tensor,
                    other => panic!("unexpected {other:?}"),
                })
                .collect();
            parts.push(CooTensor::from_sorted(
                8,
                vec![self.rank as u32],
                vec![self.rank as f32 + 1.0],
            ));
            let views: Vec<_> = parts.iter().map(|t| t.as_slice()).collect();
            self.out = Some(CooTensor::merge_all_slices(&views));
            Ok(Event::Complete(self.out.take().unwrap()))
        }

        fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
            self.inbox.push(src, msg);
            Ok(())
        }

        fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
            assert_eq!(name, "x");
            self.closed = true;
            Ok(())
        }
    }

    fn net(n: usize) -> Network {
        Network::new(n, LinkKind::Tcp25)
    }

    #[test]
    fn canonical_run_completes_and_records_choices() {
        let mut d = ScheduleDriver::new(net(3));
        let out = d
            .run_checked(star_machines(3), &mut SyncScratch::new())
            .expect("clean protocol");
        assert_eq!(out.outputs.len(), 3);
        assert_eq!(out.outputs[0], out.outputs[1]);
        let rec = d.record();
        assert_eq!(rec.trace.len(), 6, "3 ranks × 2 frames each");
        // Each destination has 2 competing sources → one choice point
        // per destination.
        assert_eq!(rec.choices.len(), 3);
        assert_eq!(rec.boundaries.len(), 1);
        assert_eq!(rec.boundaries[0].name, "x");
        assert_eq!(rec.boundaries[0].step, 6);
        // Report carries the stage with conserved bytes.
        let st = &out.report.stages[0];
        let sent: u64 = st.sent.iter().sum();
        let recv: u64 = st.recv.iter().sum();
        assert_eq!(sent, recv);
        assert_eq!(sent, rec.trace.iter().map(|t| t.bytes).sum::<u64>());
    }

    #[test]
    fn alternative_prefix_replays_and_boundary_hash_is_order_insensitive() {
        let mut canon = ScheduleDriver::new(net(3));
        let canon_out = canon
            .run_checked(star_machines(3), &mut SyncScratch::new())
            .unwrap();
        let canon_rec = canon.record().clone();
        // Flip the first choice point: deliver the alternative source's
        // frame first.
        let cp = &canon_rec.choices[0];
        let mut prefix: Vec<(usize, usize)> = canon_rec.schedule()[..cp.step].to_vec();
        prefix.push((cp.alternatives[0], cp.dst));
        let mut alt = ScheduleDriver::with_prefix(net(3), prefix);
        let alt_out = alt
            .run_checked(star_machines(3), &mut SyncScratch::new())
            .unwrap();
        let alt_rec = alt.record();
        assert_ne!(
            canon_rec.schedule(),
            alt_rec.schedule(),
            "the flipped prefix must actually change the order"
        );
        assert_eq!(canon_out.outputs, alt_out.outputs, "order must not matter");
        assert_eq!(
            canon_rec.boundaries[0].state_hash, alt_rec.boundaries[0].state_hash,
            "same delivered multiset → same boundary hash"
        );
        // Choice points inside the prescribed prefix are not re-recorded.
        assert!(alt_rec.choices.iter().all(|c| c.step >= cp.step));
    }

    #[test]
    fn bad_schedule_is_reported_not_panicked() {
        let mut d = ScheduleDriver::with_prefix(net(3), vec![(2, 2)]);
        let err = d
            .run_checked(star_machines(3), &mut SyncScratch::new())
            .unwrap_err();
        assert_eq!(err.kind(), "bad-schedule");
    }

    /// A rank that waits forever on a frame rank 0 never sends.
    struct Stuck {
        rank: usize,
    }

    impl Protocol for Stuck {
        fn rank(&self) -> usize {
            self.rank
        }
        fn poll(&mut self, _s: &mut SyncScratch) -> Result<Event, WireError> {
            if self.rank == 0 {
                Ok(Event::StageDone { name: "never" })
            } else {
                Ok(Event::NeedFrame { src: 0 })
            }
        }
        fn deliver(&mut self, _src: usize, _msg: Message) -> Result<(), WireError> {
            Ok(())
        }
        fn stage_closed(&mut self, _name: &str) -> Result<(), WireError> {
            Ok(())
        }
    }

    #[test]
    fn mixed_park_with_nothing_pending_is_a_deadlock() {
        let mut d = ScheduleDriver::new(net(2));
        let machines: Vec<Box<dyn Protocol>> = vec![
            Box::new(Stuck { rank: 0 }),
            Box::new(Stuck { rank: 1 }),
        ];
        let err = d
            .run_checked(machines, &mut SyncScratch::new())
            .unwrap_err();
        match err {
            Violation::Deadlock {
                waiting,
                parked_done,
            } => {
                assert_eq!(waiting, vec![1]);
                assert_eq!(parked_done, vec![0]);
            }
            other => panic!("expected deadlock, got {other}"),
        }
    }

    #[test]
    fn driver_trait_view_maps_violations_to_wire_errors() {
        let mut d = ScheduleDriver::new(net(2));
        let machines: Vec<Box<dyn Protocol>> = vec![
            Box::new(Stuck { rank: 0 }),
            Box::new(Stuck { rank: 1 }),
        ];
        let err = d
            .drive(machines, &mut SyncScratch::new())
            .expect_err("deadlock folds into a WireError");
        assert!(matches!(err, WireError::Malformed(m) if m.contains("deadlock")));
        // The rich record survives the trait boundary.
        assert!(d.record().trace.is_empty());
    }

    #[test]
    fn schedule_string_round_shape() {
        assert_eq!(schedule_string(&[(0, 1), (2, 1)]), "0>1,2>1");
        assert_eq!(schedule_string(&[]), "");
    }
}
