//! In-process `Transport` backends and the shared stage accounting.
//!
//! Since the sans-IO redesign the schemes never touch a transport
//! directly: each scheme builds per-rank [`Protocol`] machines
//! ([`crate::wire::protocol`]) and a [`Driver`](crate::wire::Driver)
//! moves the frames. The in-process drivers loop over a `dyn Transport`
//! from this module; the backend decides what a frame physically is:
//!
//! - [`SimTransport`] — virtual time. Frames are *accounted* at their
//!   exact encoded size and delivered zero-serialization through
//!   in-process queues; each synchronous stage is charged the α–β
//!   [`Network`] time of the byte matrix the transport observed. This is
//!   the simulator mode every paper figure runs on.
//! - [`ChannelTransport`] — real frames. Every payload is encoded to
//!   bytes, moved through the mpsc [`Fabric`], and decoded at the
//!   receiver, with per-endpoint byte counters. Byte-for-byte parity
//!   with `SimTransport` per stage is asserted by
//!   `rust/tests/transport_parity.rs` for every scheme.
//!
//! The socket backend lives at the driver layer
//! ([`SocketDriver`](crate::wire::SocketDriver)): real sockets need
//! per-peer send/recv queues pumped on readiness, which does not fit the
//! synchronous send/recv surface below. All backends charge the same
//! virtual stage time from the bytes they observe through [`StageAcc`],
//! so [`CommReport`]s are produced uniformly everywhere.
//!
//! ## Stage contract
//!
//! A synchronization is a sequence of *synchronous stages*. Within a
//! stage, every `send` is matched by a `recv` (per-receiver FIFO order =
//! global send order — the in-process drivers deliver each frame
//! immediately, so queues hold at most one frame); then
//! [`end_stage`](Transport::end_stage) closes the stage, failing if any
//! frame is still undelivered. `take_report` closes the synchronization
//! and resets the transport for the next one, so a transport instance is
//! reusable across sequential syncs.

use std::collections::VecDeque;

use super::codec::{FrameRef, Message, WireError};
use super::fabric::{Endpoint, Fabric};
use crate::cluster::{ClassStage, CommReport, Network, StageReport, LINK_CLASSES};

/// Which data-plane backend to run a synchronization over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Virtual time, zero-serialization loopback (`SimTransport`).
    Sim,
    /// Real encoded frames over in-process mpsc channels.
    Channel,
    /// Real encoded frames over a readiness-polled loopback socket mesh
    /// ([`SocketDriver`](crate::wire::SocketDriver) — a driver-level
    /// backend, not a `Transport`).
    Socket,
    /// Single-threaded discrete-event virtual time
    /// ([`EventDriver`](crate::wire::EventDriver) — a driver-level
    /// backend, not a `Transport`): every rank is an event endpoint on
    /// one binary heap, so thousands of ranks simulate on one thread.
    Event,
    /// One OS thread per rank over in-process channels
    /// ([`ThreadedDriver`](crate::wire::ThreadedDriver) — a driver-level
    /// backend, not a `Transport`): the real-concurrency baseline the
    /// event scheduler is benchmarked against.
    Threaded,
}

impl TransportKind {
    /// Parse a CLI name: `sim`, `channel`, `socket`, `event`,
    /// `threaded` (the historical `tcp` spelling still parses).
    pub fn parse(name: &str) -> Option<TransportKind> {
        Some(match name.to_ascii_lowercase().as_str() {
            "sim" | "virtual" => TransportKind::Sim,
            "channel" | "mpsc" | "fabric" => TransportKind::Channel,
            "socket" | "tcp" | "tcp-loopback" => TransportKind::Socket,
            "event" | "des" | "event-sim" => TransportKind::Event,
            "threaded" | "thread" | "thread-per-rank" => TransportKind::Threaded,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Sim => "sim",
            TransportKind::Channel => "channel",
            TransportKind::Socket => "socket",
            TransportKind::Event => "event",
            TransportKind::Threaded => "threaded",
        }
    }
}

/// The pluggable data plane under every synchronization scheme.
pub trait Transport {
    /// Which backend this is.
    fn kind(&self) -> TransportKind;

    /// Number of endpoints on the fabric.
    fn endpoints(&self) -> usize;

    /// Move one frame from `src` to `dst` (`src != dst`). The frame's
    /// exact encoded size is charged to the current stage.
    fn send(&mut self, src: usize, dst: usize, frame: FrameRef<'_>) -> Result<(), WireError>;

    /// Move an owned [`Message`] from `src` to `dst`. Protocol machines
    /// emit owned messages; a backend that delivers frames in-process
    /// without serializing ([`SimTransport`]) overrides this to queue
    /// the message directly instead of re-materializing it from a
    /// borrowed view.
    fn send_msg(&mut self, src: usize, dst: usize, msg: Message) -> Result<(), WireError> {
        self.send(src, dst, msg.as_frame())
    }

    /// Dequeue the next frame addressed to `dst`, in FIFO order of the
    /// sends that targeted it.
    fn recv(&mut self, dst: usize) -> Result<Message, WireError>;

    /// Close the current synchronous stage: every sent frame must have
    /// been received; the α–β stage time of the observed byte matrix is
    /// charged and a [`StageReport`] appended.
    fn end_stage(&mut self, name: &str) -> Result<(), WireError>;

    /// Take the accumulated report, resetting the transport for the next
    /// synchronization.
    fn take_report(&mut self) -> CommReport;
}

/// Construct an in-process transport backend over `net`'s endpoints.
/// The socket backend is driver-level — ask
/// [`make_driver`](crate::wire::make_driver) for it instead.
pub fn make_transport(kind: TransportKind, net: &Network) -> anyhow::Result<Box<dyn Transport>> {
    Ok(match kind {
        TransportKind::Sim => Box::new(SimTransport::new(net.clone())),
        TransportKind::Channel => Box::new(ChannelTransport::new(net.clone())),
        TransportKind::Socket => anyhow::bail!(
            "the socket backend is a driver, not a transport — use wire::make_driver"
        ),
        TransportKind::Event => anyhow::bail!(
            "the event backend is a driver, not a transport — use wire::make_driver"
        ),
        TransportKind::Threaded => anyhow::bail!(
            "the threaded backend is a driver, not a transport — use wire::make_driver"
        ),
    })
}

/// Shared per-stage accounting: byte matrix → `StageReport` → report.
/// Bytes are tracked per [`crate::cluster::LinkClass`] against the
/// network's topology — co-located ranks charge the intra-node link,
/// cross-node frames the fabric — and a stage costs the max over its
/// classes (parallel physical links). On a flat network every frame is
/// inter-class and the numbers reduce exactly to the historical
/// single-link model. Driver-level backends ([`SocketDriver`],
/// [`WorkerDriver`](crate::wire::WorkerDriver)) reuse this accumulator
/// directly so every data plane reports identically.
///
/// [`SocketDriver`]: crate::wire::SocketDriver
pub(crate) struct StageAcc {
    pub(crate) net: Network,
    sent: Vec<u64>,
    recv: Vec<u64>,
    /// Per-class per-endpoint bytes (`[intra, inter]`).
    class_sent: [Vec<u64>; 2],
    class_recv: [Vec<u64>; 2],
    in_flight: usize,
    report: CommReport,
}

impl StageAcc {
    pub(crate) fn new(net: Network) -> StageAcc {
        let n = net.endpoints;
        StageAcc {
            net,
            sent: vec![0; n],
            recv: vec![0; n],
            class_sent: [vec![0; n], vec![0; n]],
            class_recv: [vec![0; n], vec![0; n]],
            in_flight: 0,
            report: CommReport::new(),
        }
    }

    /// Validate an endpoint pair and the frame's wire-size fields
    /// before any transmit is attempted.
    pub(crate) fn check_send(
        &self,
        src: usize,
        dst: usize,
        frame: &FrameRef<'_>,
    ) -> Result<(), WireError> {
        let n = self.net.endpoints;
        if src >= n || dst >= n || src == dst {
            return Err(WireError::Malformed("invalid endpoint pair"));
        }
        frame.validate()
    }

    /// Charge a *successfully transmitted* frame to the current stage —
    /// infallible, so a failed send never corrupts the byte matrix.
    pub(crate) fn charge(&mut self, src: usize, dst: usize, bytes: u64) {
        self.sent[src] += bytes;
        self.recv[dst] += bytes;
        let c = self.net.topo.class_of(src, dst).idx();
        self.class_sent[c][src] += bytes;
        self.class_recv[c][dst] += bytes;
        self.in_flight += 1;
    }

    pub(crate) fn on_recv(&mut self) {
        self.in_flight = self.in_flight.saturating_sub(1);
    }

    /// Charge a frame whose delivery this process never observes (the
    /// remote half of a [`WorkerDriver`](crate::wire::WorkerDriver)
    /// link drains it) or observes immediately (a staged arrival being
    /// handed to the local machine): charge without raising the
    /// in-flight count, so the stage can close with a complete n×n byte
    /// matrix while only local traffic is tracked for delivery.
    pub(crate) fn charge_delivered(&mut self, src: usize, dst: usize, bytes: u64) {
        self.charge(src, dst, bytes);
        self.on_recv();
    }

    /// Frames charged but not yet delivered in the current stage.
    pub(crate) fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Compute the per-class stage summaries from the current byte
    /// matrices and zero the class matrices in place — allocation-free.
    fn close_classes(&mut self) -> [ClassStage; 2] {
        LINK_CLASSES.map(|class| {
            let c = class.idx();
            let busiest = self.class_sent[c]
                .iter()
                .zip(self.class_recv[c].iter())
                .map(|(&s, &r)| s.max(r))
                .max()
                .unwrap_or(0);
            let stage = ClassStage {
                bytes: self.class_sent[c].iter().sum(),
                busiest,
                time: self.net.class_time(class, busiest),
            };
            self.class_sent[c].iter_mut().for_each(|v| *v = 0);
            self.class_recv[c].iter_mut().for_each(|v| *v = 0);
            stage
        })
    }

    /// Close the stage, appending a [`StageReport`]; returns the
    /// stage's max-over-classes α–β time (the event driver advances its
    /// virtual clock by exactly this number).
    pub(crate) fn end_stage(&mut self, name: &str) -> Result<f64, WireError> {
        if self.in_flight != 0 {
            return Err(WireError::Malformed("stage closed with undelivered frames"));
        }
        let n = self.net.endpoints;
        let sent = std::mem::replace(&mut self.sent, vec![0; n]);
        let recv = std::mem::replace(&mut self.recv, vec![0; n]);
        let classes = self.close_classes();
        let time = classes[0].time.max(classes[1].time);
        self.report.push(StageReport {
            name: name.to_string(),
            sent,
            recv,
            time,
            classes,
        });
        Ok(time)
    }

    /// Close a stage without materializing a [`StageReport`]: the class
    /// summaries are returned by value and every matrix is zeroed in
    /// place, so the call performs **zero heap allocations** — this is
    /// what keeps the [`EventDriver`](crate::wire::EventDriver) totals
    /// mode allocation-free per simulated iteration.
    pub(crate) fn end_stage_lite(&mut self) -> Result<[ClassStage; 2], WireError> {
        if self.in_flight != 0 {
            return Err(WireError::Malformed("stage closed with undelivered frames"));
        }
        let classes = self.close_classes();
        self.sent.iter_mut().for_each(|v| *v = 0);
        self.recv.iter_mut().for_each(|v| *v = 0);
        Ok(classes)
    }

    pub(crate) fn take_report(&mut self) -> CommReport {
        std::mem::take(&mut self.report)
    }
}

/// Virtual-time backend: frames are charged at their exact encoded size
/// and delivered as owned in-process messages (sender and receiver share
/// an address space, so no serialization happens — the byte matrix is
/// observed from [`FrameRef::encoded_len`], which the codec tests pin to
/// the real encoder's output length).
pub struct SimTransport {
    acc: StageAcc,
    queues: Vec<VecDeque<Message>>,
}

impl SimTransport {
    pub fn new(net: Network) -> SimTransport {
        let n = net.endpoints;
        SimTransport {
            acc: StageAcc::new(net),
            queues: (0..n).map(|_| VecDeque::new()).collect(),
        }
    }
}

impl Transport for SimTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Sim
    }

    fn endpoints(&self) -> usize {
        self.acc.net.endpoints
    }

    fn send(&mut self, src: usize, dst: usize, frame: FrameRef<'_>) -> Result<(), WireError> {
        self.acc.check_send(src, dst, &frame)?;
        self.queues[dst].push_back(frame.to_message());
        self.acc.charge(src, dst, frame.encoded_len() as u64);
        Ok(())
    }

    fn send_msg(&mut self, src: usize, dst: usize, msg: Message) -> Result<(), WireError> {
        // Owned fast path: validate and account through the borrowed
        // view, then queue the message itself — no re-materialization,
        // preserving the one-allocation-per-frame profile.
        let len = {
            let frame = msg.as_frame();
            self.acc.check_send(src, dst, &frame)?;
            frame.encoded_len() as u64
        };
        self.queues[dst].push_back(msg);
        self.acc.charge(src, dst, len);
        Ok(())
    }

    fn recv(&mut self, dst: usize) -> Result<Message, WireError> {
        let msg = self.queues[dst]
            .pop_front()
            .ok_or(WireError::Malformed("recv from empty inbox"))?;
        self.acc.on_recv();
        Ok(msg)
    }

    fn end_stage(&mut self, name: &str) -> Result<(), WireError> {
        self.acc.end_stage(name).map(|_| ())
    }

    fn take_report(&mut self) -> CommReport {
        self.acc.take_report()
    }
}

/// Real-frames backend over the mpsc [`Fabric`]: every payload is
/// encoded once into the buffer the channel takes ownership of, moved,
/// and decoded at the receiver. The fabric's per-endpoint byte counters
/// must agree with the stage reports — asserted by the parity harness.
pub struct ChannelTransport {
    acc: StageAcc,
    fabric: Fabric,
    endpoints: Vec<Endpoint>,
}

impl ChannelTransport {
    pub fn new(net: Network) -> ChannelTransport {
        let (fabric, endpoints) = Fabric::new(net.endpoints);
        ChannelTransport {
            acc: StageAcc::new(net),
            fabric,
            endpoints,
        }
    }

    /// The underlying fabric (byte-counter access for tests/telemetry).
    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    /// Hang up endpoint `e`: its subsequent sends fail with
    /// [`WireError::Disconnected`], exactly like a crashed peer whose
    /// channel half is gone. The disconnect-regression suite drives
    /// every scheme through this mid-protocol.
    pub fn disconnect_endpoint(&mut self, e: usize) {
        if let Some(ep) = self.endpoints.get_mut(e) {
            ep.disconnect();
        }
    }
}

impl Transport for ChannelTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Channel
    }

    fn endpoints(&self) -> usize {
        self.acc.net.endpoints
    }

    fn send(&mut self, src: usize, dst: usize, frame: FrameRef<'_>) -> Result<(), WireError> {
        self.acc.check_send(src, dst, &frame)?;
        // Encode straight into the buffer the channel will own: one
        // encode, one move, no re-copy.
        let mut buf = Vec::with_capacity(frame.encoded_len());
        frame.encode(&mut buf);
        debug_assert_eq!(buf.len(), frame.encoded_len());
        let len = buf.len() as u64;
        self.endpoints[src].send_owned(dst, buf)?;
        self.acc.charge(src, dst, len);
        Ok(())
    }

    fn recv(&mut self, dst: usize) -> Result<Message, WireError> {
        // In orchestrated use every frame is already in the inbox when
        // the scheme asks for it; an empty inbox is a protocol bug, not
        // something to block on.
        let msg = self.endpoints[dst]
            .try_recv()?
            .ok_or(WireError::Malformed("recv from empty inbox"))?;
        self.acc.on_recv();
        Ok(msg)
    }

    fn end_stage(&mut self, name: &str) -> Result<(), WireError> {
        self.acc.end_stage(name).map(|_| ())
    }

    fn take_report(&mut self) -> CommReport {
        self.acc.take_report()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]
mod tests {
    use super::*;
    use crate::cluster::LinkKind;
    use crate::tensor::CooTensor;
    use crate::wire::codec::Encode;

    fn net(n: usize) -> Network {
        Network::new(n, LinkKind::Tcp25)
    }

    fn exercise(tx: &mut dyn Transport) {
        let t = CooTensor::from_sorted(50, vec![3, 9, 41], vec![1.0, -2.0, 0.5]);
        tx.send(
            0,
            1,
            FrameRef::PushCoo {
                from: 0,
                dense_len: t.dense_len,
                indices: &t.indices,
                values: &t.values,
            },
        )
        .unwrap();
        tx.send(2, 1, FrameRef::Barrier { epoch: 7 }).unwrap();
        // FIFO per receiver: the COO frame first, then the barrier.
        match tx.recv(1).unwrap() {
            Message::PushCoo { from, tensor } => {
                assert_eq!(from, 0);
                assert_eq!(tensor, t);
            }
            other => panic!("expected PushCoo, got {other:?}"),
        }
        assert_eq!(tx.recv(1).unwrap(), Message::Barrier { epoch: 7 });
        tx.end_stage("stage-a").unwrap();

        let report = tx.take_report();
        assert_eq!(report.stages.len(), 1);
        let st = &report.stages[0];
        assert_eq!(st.name, "stage-a");
        let coo_len = Message::PushCoo { from: 0, tensor: t }.encoded_len() as u64;
        let bar_len = Message::Barrier { epoch: 7 }.encoded_len() as u64;
        assert_eq!(st.sent, vec![coo_len, 0, bar_len]);
        assert_eq!(st.recv, vec![0, coo_len + bar_len, 0]);
        assert!(st.time > 0.0);
    }

    #[test]
    fn sim_transport_moves_and_accounts() {
        exercise(&mut SimTransport::new(net(3)));
    }

    #[test]
    fn channel_transport_moves_and_accounts() {
        let mut tx = ChannelTransport::new(net(3));
        exercise(&mut tx);
        // fabric counters agree with the stage accounting
        assert!(tx.fabric().total_bytes() > 0);
    }

    #[test]
    fn send_msg_owned_path_matches_borrowed_path() {
        // The owned fast path must charge exactly what the borrowed
        // path charges and deliver an identical message.
        let t = CooTensor::from_sorted(50, vec![3, 9, 41], vec![1.0, -2.0, 0.5]);
        let msg = Message::PushCoo {
            from: 0,
            tensor: t,
        };
        let mut a = SimTransport::new(net(2));
        a.send(0, 1, msg.as_frame()).unwrap();
        let mut b = SimTransport::new(net(2));
        b.send_msg(0, 1, msg.clone()).unwrap();
        assert_eq!(a.recv(1).unwrap(), b.recv(1).unwrap());
        a.end_stage("s").unwrap();
        b.end_stage("s").unwrap();
        assert_eq!(
            a.take_report().stages[0].sent,
            b.take_report().stages[0].sent
        );
    }

    #[test]
    fn undelivered_frames_fail_the_stage() {
        let mut tx = SimTransport::new(net(2));
        tx.send(0, 1, FrameRef::Barrier { epoch: 1 }).unwrap();
        assert!(tx.end_stage("leaky").is_err());
        // draining fixes it
        tx.recv(1).unwrap();
        tx.end_stage("drained").unwrap();
    }

    #[test]
    fn self_send_rejected() {
        let mut tx = SimTransport::new(net(2));
        assert!(tx.send(1, 1, FrameRef::Barrier { epoch: 0 }).is_err());
    }

    #[test]
    fn empty_inbox_is_an_error_not_a_hang() {
        let mut sim = SimTransport::new(net(2));
        assert!(sim.recv(0).is_err());
        let mut ch = ChannelTransport::new(net(2));
        assert!(ch.recv(0).is_err());
    }

    #[test]
    fn take_report_resets_for_next_sync() {
        let mut tx = SimTransport::new(net(2));
        tx.send(0, 1, FrameRef::Barrier { epoch: 1 }).unwrap();
        tx.recv(1).unwrap();
        tx.end_stage("s").unwrap();
        assert_eq!(tx.take_report().stages.len(), 1);
        assert_eq!(tx.take_report().stages.len(), 0);
    }

    #[test]
    fn classed_accounting_splits_colocated_frames() {
        use crate::cluster::{LinkClass, LinkKind, Topology};
        // 2 nodes × 2 ranks: 0→1 is intra, 0→2 inter.
        let topo = Topology::two_level(2, 2, LinkKind::NvLink, LinkKind::Tcp25);
        let mut tx = SimTransport::new(Network::with_topology(topo));
        tx.send(0, 1, FrameRef::Barrier { epoch: 1 }).unwrap();
        tx.send(0, 2, FrameRef::Barrier { epoch: 2 }).unwrap();
        tx.recv(1).unwrap();
        tx.recv(2).unwrap();
        tx.end_stage("mixed").unwrap();
        let report = tx.take_report();
        let st = &report.stages[0];
        let frame = Message::Barrier { epoch: 1 }.encoded_len() as u64;
        assert_eq!(st.classes[LinkClass::Intra.idx()].bytes, frame);
        assert_eq!(st.classes[LinkClass::Inter.idx()].bytes, frame);
        // same bytes, but the TCP fabric is slower and pays more α
        let intra = st.classes[LinkClass::Intra.idx()].time;
        let inter = st.classes[LinkClass::Inter.idx()].time;
        assert!(inter > intra && intra > 0.0);
        assert_eq!(st.time, inter, "stage charges the max class");
        // totals remain class-agnostic
        assert_eq!(st.sent, vec![2 * frame, 0, 0, 0]);
        assert_eq!(report.bytes_by_class(), [frame, frame]);
    }

    #[test]
    fn oversized_frame_rejected_before_charging() {
        // The validation hook: a frame whose u32 size fields would
        // truncate is refused by send with a typed error on every
        // backend (length-only check, no huge allocation).
        let ids = [0u32];
        let values = [0.0f32; 4];
        let bad = FrameRef::Blocks {
            from: 0,
            dense_len: u64::MAX,
            block_len: u32::MAX,
            block_ids: &ids,
            values: &values,
        };
        let mut tx = SimTransport::new(net(2));
        assert!(matches!(
            tx.send(0, 1, bad),
            Err(WireError::FrameTooLarge { .. })
        ));
        tx.end_stage("clean").unwrap();
        assert_eq!(tx.take_report().stages[0].total_bytes(), 0);
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [
            TransportKind::Sim,
            TransportKind::Channel,
            TransportKind::Socket,
            TransportKind::Event,
            TransportKind::Threaded,
        ] {
            assert_eq!(TransportKind::parse(k.name()), Some(k));
        }
        // historical spelling still accepted
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Socket));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
    }

    #[test]
    fn make_transport_refuses_driver_level_kinds() {
        for k in [
            TransportKind::Socket,
            TransportKind::Event,
            TransportKind::Threaded,
        ] {
            assert!(make_transport(k, &net(2)).is_err(), "{}", k.name());
        }
    }
}
