//! Wire layer: binary codecs, the pluggable [`Transport`] data plane,
//! and the in-process message [`Fabric`].
//!
//! Every synchronization scheme in [`crate::schemes`] runs its protocol
//! over a `dyn Transport`: [`SimTransport`] charges virtual α–β time
//! from the byte matrix it observes (the simulator mode),
//! [`ChannelTransport`] moves real encoded frames through mpsc channels,
//! and [`TcpTransport`] moves them through loopback sockets. One code
//! path, three data planes — sim-vs-channel byte parity per stage is
//! asserted for every scheme by `rust/tests/transport_parity.rs`, which
//! is what lets the repo keep a single source of truth for byte
//! accounting.
//!
//! No serde offline, so the codecs are hand-rolled little-endian
//! framing with explicit versioning and exhaustive roundtrip tests.

pub mod codec;
pub mod fabric;
pub mod transport;

pub use codec::{
    encode_blocks, encode_dense_chunk, encode_pull_hash_bitmap, encode_push_coo, Decode, Encode,
    FrameRef, Message, WireError,
};
pub use fabric::{Endpoint, Fabric};
pub use transport::{
    make_transport, ChannelTransport, SimTransport, TcpTransport, Transport, TransportKind,
    MAX_TCP_INFLIGHT_BYTES,
};
