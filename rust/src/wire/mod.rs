//! Wire layer: binary serialization codecs + a real message-passing
//! transport between in-process endpoints.
//!
//! The scheme implementations in [`crate::schemes`] account bytes
//! analytically; this module provides the *execution* mode — payloads
//! are really serialized to framed byte buffers, moved through
//! channels between worker threads, deserialized, and aggregated. The
//! byte counts the analytic mode charges are asserted against the real
//! encoded sizes (`rust/tests/wire_integration.rs`), closing the loop
//! between the simulator and a deployable data plane.
//!
//! No serde offline, so the codecs are hand-rolled little-endian
//! framing with explicit versioning and exhaustive roundtrip tests.

pub mod codec;
pub mod transport;

pub use codec::{
    encode_pull_hash_bitmap, encode_push_coo, Decode, Encode, Message, WireError,
};
pub use transport::{Endpoint, Fabric};
