//! Wire layer: binary codecs, sans-IO [`Protocol`] machines, and the
//! [`Driver`] IO shells that run them.
//!
//! Every synchronization scheme in [`crate::schemes`] builds one
//! [`Protocol`] state machine per rank ([`protocol`]); a [`Driver`]
//! moves the frames: [`TransportDriver`] loops over an in-process
//! [`Transport`] ([`SimTransport`] charges virtual α–β time from the
//! byte matrix it observes, [`ChannelTransport`] moves real encoded
//! frames through mpsc channels), [`EventDriver`] schedules every frame
//! on a single-threaded discrete-event heap (thousands of ranks, one
//! thread), [`ThreadedDriver`] runs one OS thread per rank over
//! in-process channels, [`SocketDriver`] pumps a readiness-polled
//! loopback socket mesh, and [`WorkerDriver`] runs one rank per OS
//! process (`zen worker`). One protocol body, six data planes —
//! per-stage byte parity across all of them is asserted by
//! `rust/tests/transport_parity.rs` and
//! `rust/tests/driver_equivalence.rs`, which is what lets the repo keep
//! a single source of truth for byte accounting.
//!
//! No serde offline, so the codecs are hand-rolled little-endian
//! framing with explicit versioning and exhaustive roundtrip tests.

// Cargo `[lints]` tables are package-wide, so the module-scoped part of
// the lint policy lives here: protocol/driver code must not truncate
// sizes with `as` or panic through unwrap/expect — every exception
// carries an `#[allow]` with a reason. (Crate-wide denies — unsafe_code,
// dbg/todo/unimplemented — are in Cargo.toml.)
#![deny(
    clippy::cast_possible_truncation,
    clippy::unwrap_used,
    clippy::expect_used
)]

pub mod codec;
pub mod driver;
pub mod event;
pub(crate) mod fabric;
pub mod protocol;
pub mod threaded;
pub mod trace;
pub mod transport;

/// Lock a mutex, panicking with context if a peer thread panicked while
/// holding it. Lock poisoning here is always a secondary failure — the
/// original panic is the bug — so unwrapping with a label beats
/// threading `PoisonError` through every protocol body.
pub(crate) fn lock_or_panic<'a, T>(
    m: &'a std::sync::Mutex<T>,
    what: &str,
) -> std::sync::MutexGuard<'a, T> {
    match m.lock() {
        Ok(g) => g,
        Err(_) => panic!("{what}: mutex poisoned by a peer panic"),
    }
}

pub use codec::{
    encode_blocks, encode_dense_chunk, encode_pull_hash_bitmap, encode_push_coo, Decode, Encode,
    FrameRef, Message, WireError,
};
pub use driver::{make_driver, DriveOutcome, Driver, SocketDriver, TransportDriver, WorkerDriver};
pub use event::{EventDriver, EventTotals};
pub use fabric::Fabric;
pub use protocol::{Event, Inbox, Protocol};
pub use threaded::ThreadedDriver;
pub use trace::{schedule_string, ChoicePoint, RunRecord, ScheduleDriver, StageBoundary, Violation};
pub use transport::{make_transport, ChannelTransport, SimTransport, Transport, TransportKind};
