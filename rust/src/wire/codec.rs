//! Binary codecs for every synchronization payload.
//!
//! Format: little-endian, length-prefixed frames.
//!
//! ```text
//! frame   := magic(u16) version(u8) kind(u8) body_len(u32) body
//! ```
//!
//! Body layouts per message kind are documented on each variant. The
//! encoded size of each payload equals the analytic `wire_bytes()` of
//! the corresponding tensor format plus the fixed frame/header overhead
//! — asserted by tests so the simulator's accounting stays honest.

use crate::tensor::{Bitmap, CooTensor};

const MAGIC: u16 = 0x5A45; // "ZE"
const VERSION: u8 = 1;

/// Frame header bytes: magic + version + kind + body_len.
pub const FRAME_HEADER: usize = 2 + 1 + 1 + 4;

/// Fixed per-frame bytes a COO frame (`PushCoo`/`PullCoo`) adds on top
/// of its 8·nnz payload: header + from/server(4) + dense_len(8) + nnz(4).
pub const COO_FRAME_OVERHEAD: usize = FRAME_HEADER + 4 + 8 + 4;

/// Fixed per-frame bytes of a `DenseChunk` on top of its 4·count payload:
/// header + from(4) + offset(8) + count(4).
pub const DENSE_CHUNK_OVERHEAD: usize = FRAME_HEADER + 4 + 8 + 4;

/// Fixed per-frame bytes of a `Blocks` frame on top of its
/// `nblocks·(4 + 4·block_len)` payload: header + from(4) + dense_len(8)
/// + block_len(4) + nblocks(4).
pub const BLOCKS_FRAME_OVERHEAD: usize = FRAME_HEADER + 4 + 8 + 4 + 4;

/// Fixed per-frame bytes of a `PullHashBitmap` on top of its bitmap
/// words + 4·nnz values: header + server(4) + domain_len(8) + nnz(4).
/// (The bitmap itself is u64-word padded: `ceil(bits/64)·8` bytes on the
/// wire versus the byte-granular `ceil(bits/8)` analytic size.)
pub const HASH_BITMAP_FRAME_OVERHEAD: usize = FRAME_HEADER + 4 + 8 + 4;

/// Reject pull-bitmap frames claiming more than 2^40 bits (128 GiB of
/// words) before sizing any buffer from the untrusted length field.
const MAX_BITMAP_BITS: u64 = 1 << 40;

/// Reject block frames claiming more than 2^32 gradient values (16 GiB)
/// before multiplying the two untrusted u32 size fields.
const MAX_BLOCK_VALUES: u64 = 1 << 32;

/// Codec error.
#[derive(Debug, PartialEq)]
pub enum WireError {
    Truncated { need: usize, have: usize },
    BadMagic(u16),
    BadVersion(u8),
    BadKind(u8),
    LengthMismatch { header: usize, actual: usize },
    Malformed(&'static str),
    /// The peer endpoint is gone: its channel hung up, its socket closed,
    /// or it was explicitly disconnected. Distinct from [`Malformed`]
    /// (which means the bytes arrived but could not be decoded).
    ///
    /// [`Malformed`]: WireError::Malformed
    Disconnected,
    /// A frame field that the format encodes as a `u32` (nnz, block
    /// count, body length, …) would not fit one: the value would have
    /// been silently truncated by the old `as u32` casts. Rejected by
    /// [`FrameRef::validate`] before any byte is written.
    FrameTooLarge {
        /// Which size field overflowed.
        what: &'static str,
        /// The offending value.
        len: u64,
    },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need}, have {have}")
            }
            WireError::BadMagic(m) => write!(f, "bad magic {m:#06x}"),
            WireError::BadVersion(v) => write!(f, "unsupported version {v}"),
            WireError::BadKind(k) => write!(f, "unknown message kind {k}"),
            WireError::LengthMismatch { header, actual } => {
                write!(f, "body length mismatch: header {header}, actual {actual}")
            }
            WireError::Malformed(msg) => write!(f, "malformed body: {msg}"),
            WireError::Disconnected => write!(f, "peer endpoint disconnected"),
            WireError::FrameTooLarge { what, len } => {
                write!(f, "frame {what} {len} exceeds the u32 wire limit")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// A synchronization message.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Push of a COO shard to a server.
    /// Body: dense_len(u64) nnz(u32) indices[u32×nnz] values[f32×nnz]
    PushCoo { from: u32, tensor: CooTensor },
    /// Pull payload: hash bitmap over the server's partition domain +
    /// values in domain order.
    /// Body: server(u32) domain_len(u64) bitmap_words nnz(u32) values
    PullHashBitmap {
        server: u32,
        bitmap: Bitmap,
        values: Vec<f32>,
    },
    /// Pull payload in COO (Zen-COO ablation / Sparse PS).
    PullCoo { server: u32, tensor: CooTensor },
    /// A contiguous run of dense gradient values — the shard currency of
    /// ring collectives (dense reduce-scatter / all-gather).
    /// Body: from(u32) offset(u64) count(u32) values[f32×count]
    DenseChunk {
        from: u32,
        /// Start of the run within the dense range.
        offset: u64,
        values: Vec<f32>,
    },
    /// Non-zero blocks of a contiguous partition (OmniReduce's format):
    /// one u32 id plus all `block_len` gradients per block.
    /// Body: from(u32) dense_len(u64) block_len(u32) nblocks(u32)
    ///       block_ids[u32×nblocks] values[f32×nblocks·block_len]
    Blocks {
        from: u32,
        /// Dense length of the (partition-local) range the blocks tile.
        dense_len: u64,
        block_len: u32,
        /// Ascending block ids.
        block_ids: Vec<u32>,
        /// Concatenated block payloads, `block_len` values per id.
        values: Vec<f32>,
    },
    /// Control: barrier/done marker used by the fabric tests.
    Barrier { epoch: u32 },
}

/// Encoding into a byte buffer.
pub trait Encode {
    fn encode(&self, out: &mut Vec<u8>);
    fn encoded_len(&self) -> usize;
}

/// Decoding from a byte slice, returning (value, bytes consumed).
pub trait Decode: Sized {
    fn decode(buf: &[u8]) -> Result<(Self, usize), WireError>;
}

// -- primitive helpers -------------------------------------------------

/// Elements staged per bulk-write flush (×4 or ×8 bytes on the stack).
const STAGE_ELEMS: usize = 64;

struct Writer<'a>(&'a mut Vec<u8>);

impl Writer<'_> {
    fn u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    // Bulk little-endian writes: one up-front reserve, then stage
    // fixed-size chunks on the stack and append each with a single
    // `extend_from_slice` — no per-element capacity checks (the
    // per-element `push` loops were a measured hot spot of the encode
    // path; ISSUE 2). `W` is the element's wire width in bytes.
    fn bulk<T: Copy, const W: usize>(&mut self, vs: &[T], enc: impl Fn(&T) -> [u8; W]) {
        self.0.reserve(vs.len() * W);
        let mut stage = [0u8; STAGE_ELEMS * 8];
        for chunk in vs.chunks(STAGE_ELEMS) {
            for (slot, v) in stage.chunks_exact_mut(W).zip(chunk.iter()) {
                slot.copy_from_slice(&enc(v));
            }
            self.0.extend_from_slice(&stage[..chunk.len() * W]);
        }
    }
    fn u32s(&mut self, vs: &[u32]) {
        self.bulk(vs, |v| v.to_le_bytes());
    }
    fn f32s(&mut self, vs: &[f32]) {
        self.bulk(vs, |v| v.to_le_bytes());
    }
    fn u64s(&mut self, vs: &[u64]) {
        self.bulk(vs, |v| v.to_le_bytes());
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

/// `chunks_exact(4)` guarantees 4-byte chunks; spelled out so the
/// conversion cannot silently panic through `unwrap`.
fn le4(c: &[u8]) -> [u8; 4] {
    match c.try_into() {
        Ok(a) => a,
        Err(_) => unreachable!("chunks_exact(4) yielded a non-4-byte chunk"),
    }
}

impl Reader<'_> {
    fn need(&self, n: usize) -> Result<(), WireError> {
        if self.pos + n > self.buf.len() {
            Err(WireError::Truncated {
                need: self.pos + n,
                have: self.buf.len(),
            })
        } else {
            Ok(())
        }
    }
    /// Consume the next `N` bytes as a fixed array — the bounds check
    /// is the only failure mode, so the array conversion is infallible.
    fn take<const N: usize>(&mut self) -> Result<[u8; N], WireError> {
        self.need(N)?;
        let mut out = [0u8; N];
        out.copy_from_slice(&self.buf[self.pos..self.pos + N]);
        self.pos += N;
        Ok(out)
    }
    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take()?))
    }
    fn u8(&mut self) -> Result<u8, WireError> {
        self.need(1)?;
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take()?))
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take()?))
    }
    // Bulk reads: one bounds check, then a chunked scan of the raw byte
    // region — the read-side twin of the writer's bulk path.
    fn u32s(&mut self, n: usize) -> Result<Vec<u32>, WireError> {
        self.need(n * 4)?;
        let mut out = Vec::with_capacity(n);
        out.extend(
            self.buf[self.pos..self.pos + n * 4]
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(le4(c))),
        );
        self.pos += n * 4;
        Ok(out)
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        self.need(n * 4)?;
        let mut out = Vec::with_capacity(n);
        out.extend(
            self.buf[self.pos..self.pos + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(le4(c))),
        );
        self.pos += n * 4;
        Ok(out)
    }
    /// Borrow the next `n * 8` bytes as a raw little-endian word region
    /// (the bitmap payload) without copying into an intermediate `Vec`.
    fn word_bytes(&mut self, n: usize) -> Result<&[u8], WireError> {
        self.need(n * 8)?;
        let region = &self.buf[self.pos..self.pos + n * 8];
        self.pos += n * 8;
        Ok(region)
    }
}

/// Convert a length the wire format stores as `u32`. The transports
/// gate every send through [`FrameRef::validate`], which rejects
/// oversized counts as typed [`WireError::FrameTooLarge`] — reaching
/// this with an unrepresentable value is a codec-internal bug, so it
/// panics rather than truncating the wire image.
fn count_u32(what: &'static str, len: usize) -> u32 {
    match u32::try_from(len) {
        Ok(v) => v,
        Err(_) => panic!("{what} {len} exceeds the u32 wire limit; FrameRef::validate must gate it"),
    }
}

fn write_coo_parts(w: &mut Writer, dense_len: usize, indices: &[u32], values: &[f32]) {
    debug_assert_eq!(indices.len(), values.len());
    w.u64(dense_len as u64);
    w.u32(count_u32("coo nnz", indices.len()));
    w.u32s(indices);
    w.f32s(values);
}

fn read_coo(r: &mut Reader) -> Result<CooTensor, WireError> {
    let dense_len = usize::try_from(r.u64()?)
        .map_err(|_| WireError::Malformed("dense length exceeds the address space"))?;
    let nnz = r.u32()? as usize;
    let indices = r.u32s(nnz)?;
    let values = r.f32s(nnz)?;
    if indices.windows(2).any(|w| w[0] >= w[1]) {
        return Err(WireError::Malformed("indices not strictly ascending"));
    }
    if indices.last().map(|&i| i as usize >= dense_len).unwrap_or(false) {
        return Err(WireError::Malformed("index out of range"));
    }
    Ok(CooTensor::from_sorted(dense_len, indices, values))
}

impl Encode for Message {
    fn encoded_len(&self) -> usize {
        self.as_frame().encoded_len()
    }

    fn encode(&self, out: &mut Vec<u8>) {
        self.as_frame().encode(out)
    }
}

impl Message {
    /// Borrow this message as a [`FrameRef`] (the encoders' currency).
    pub fn as_frame(&self) -> FrameRef<'_> {
        match self {
            Message::PushCoo { from, tensor } => FrameRef::PushCoo {
                from: *from,
                dense_len: tensor.dense_len,
                indices: &tensor.indices,
                values: &tensor.values,
            },
            Message::PullHashBitmap {
                server,
                bitmap,
                values,
            } => FrameRef::PullHashBitmap {
                server: *server,
                bitmap,
                values,
            },
            Message::PullCoo { server, tensor } => FrameRef::PullCoo {
                server: *server,
                dense_len: tensor.dense_len,
                indices: &tensor.indices,
                values: &tensor.values,
            },
            Message::DenseChunk {
                from,
                offset,
                values,
            } => FrameRef::DenseChunk {
                from: *from,
                offset: *offset,
                values,
            },
            Message::Blocks {
                from,
                dense_len,
                block_len,
                block_ids,
                values,
            } => FrameRef::Blocks {
                from: *from,
                dense_len: *dense_len,
                block_len: *block_len,
                block_ids,
                values,
            },
            Message::Barrier { epoch } => FrameRef::Barrier { epoch: *epoch },
        }
    }
}

/// A borrowed view of a [`Message`] — what schemes hand to
/// [`crate::wire::Transport::send`]. Frames are built from slices the
/// caller already owns (partition views, reused payload buffers), so
/// sending never clones tensor data: `SimTransport` only reads
/// [`encoded_len`](FrameRef::encoded_len), the byte-moving backends
/// encode straight from the borrows.
#[derive(Clone, Copy, Debug)]
pub enum FrameRef<'a> {
    PushCoo {
        from: u32,
        dense_len: usize,
        indices: &'a [u32],
        values: &'a [f32],
    },
    PullHashBitmap {
        server: u32,
        bitmap: &'a Bitmap,
        values: &'a [f32],
    },
    PullCoo {
        server: u32,
        dense_len: usize,
        indices: &'a [u32],
        values: &'a [f32],
    },
    DenseChunk {
        from: u32,
        offset: u64,
        values: &'a [f32],
    },
    Blocks {
        from: u32,
        dense_len: u64,
        block_len: u32,
        block_ids: &'a [u32],
        values: &'a [f32],
    },
    Barrier {
        epoch: u32,
    },
}

/// Reject any size field that the wire format stores as a `u32` but
/// whose value would not fit one — the length-only core of
/// [`FrameRef::validate`], shared with the boundary tests (which probe
/// the limits with synthetic counts instead of 4-billion-element
/// allocations). Each entry is `(field name, value)`.
pub fn validate_frame_counts(counts: &[(&'static str, u64)]) -> Result<(), WireError> {
    for &(what, len) in counts {
        if len > u32::MAX as u64 {
            return Err(WireError::FrameTooLarge { what, len });
        }
    }
    Ok(())
}

/// Size fields of a COO frame (`PushCoo`/`PullCoo`) at `nnz` entries:
/// the nnz count itself and the body length it implies.
pub fn coo_frame_counts(nnz: u64) -> [(&'static str, u64); 2] {
    [
        ("coo nnz", nnz),
        ("body length", (4 + 8 + 4) + nnz.saturating_mul(8)),
    ]
}

/// Size fields of a `PullHashBitmap` frame at `bits` bitmap bits and
/// `values` payload values.
pub fn hash_bitmap_frame_counts(bits: u64, values: u64) -> [(&'static str, u64); 2] {
    let words = bits.max(1).div_ceil(64);
    [
        ("bitmap value count", values),
        (
            "body length",
            (4 + 8 + 4)
                .saturating_add(words.saturating_mul(8))
                .saturating_add(values.saturating_mul(4)),
        ),
    ]
}

/// Size fields of a `DenseChunk` frame at `count` values.
pub fn dense_chunk_frame_counts(count: u64) -> [(&'static str, u64); 2] {
    [
        ("dense chunk count", count),
        ("body length", (4 + 8 + 4) + count.saturating_mul(4)),
    ]
}

/// Size fields of a `Blocks` frame at `nblocks` blocks of `block_len`
/// values each.
pub fn blocks_frame_counts(nblocks: u64, block_len: u64) -> [(&'static str, u64); 3] {
    let values = nblocks.saturating_mul(block_len);
    [
        ("block count", nblocks),
        ("block value count", values),
        (
            "body length",
            (4 + 8 + 4 + 4)
                .saturating_add(nblocks.saturating_mul(4))
                .saturating_add(values.saturating_mul(4)),
        ),
    ]
}

impl FrameRef<'_> {
    /// Check every `u32`-encoded size field of this frame *before*
    /// encoding: the frame writers would otherwise truncate an
    /// oversized nnz/count/body length silently via `as u32`. The
    /// transports call this on every `send`, so an oversized frame
    /// surfaces as a typed [`WireError::FrameTooLarge`] instead of a
    /// corrupted wire image.
    pub fn validate(&self) -> Result<(), WireError> {
        match self {
            FrameRef::PushCoo { indices, .. } | FrameRef::PullCoo { indices, .. } => {
                validate_frame_counts(&coo_frame_counts(indices.len() as u64))
            }
            FrameRef::PullHashBitmap { bitmap, values, .. } => validate_frame_counts(
                &hash_bitmap_frame_counts(bitmap.len() as u64, values.len() as u64),
            ),
            FrameRef::DenseChunk { values, .. } => {
                validate_frame_counts(&dense_chunk_frame_counts(values.len() as u64))
            }
            FrameRef::Blocks {
                block_ids,
                block_len,
                ..
            } => validate_frame_counts(&blocks_frame_counts(
                block_ids.len() as u64,
                *block_len as u64,
            )),
            FrameRef::Barrier { .. } => Ok(()),
        }
    }

    /// Exact size of the encoded frame (header included). Asserted equal
    /// to `encode`'s output length by the codec tests — this is the byte
    /// matrix `SimTransport` observes.
    pub fn encoded_len(&self) -> usize {
        FRAME_HEADER
            + match self {
                FrameRef::PushCoo { indices, .. } => 4 + 8 + 4 + indices.len() * 8,
                FrameRef::PullHashBitmap { bitmap, values, .. } => {
                    let words = crate::util::ceil_div(bitmap.len().max(1), 64);
                    4 + 8 + words * 8 + 4 + values.len() * 4
                }
                FrameRef::PullCoo { indices, .. } => 4 + 8 + 4 + indices.len() * 8,
                FrameRef::DenseChunk { values, .. } => 4 + 8 + 4 + values.len() * 4,
                FrameRef::Blocks {
                    block_ids, values, ..
                } => 4 + 8 + 4 + 4 + block_ids.len() * 4 + values.len() * 4,
                FrameRef::Barrier { .. } => 4,
            }
    }

    /// Append the encoded frame to `out` (cleared by the caller when the
    /// buffer is reused).
    pub fn encode(&self, out: &mut Vec<u8>) {
        match *self {
            FrameRef::PushCoo {
                from,
                dense_len,
                indices,
                values,
            } => encode_push_coo(from, dense_len, indices, values, out),
            FrameRef::PullHashBitmap {
                server,
                bitmap,
                values,
            } => encode_pull_hash_bitmap(server, bitmap, values, out),
            FrameRef::PullCoo {
                server,
                dense_len,
                indices,
                values,
            } => {
                frame(out, 3, |w| {
                    w.u32(server);
                    write_coo_parts(w, dense_len, indices, values);
                });
            }
            FrameRef::DenseChunk {
                from,
                offset,
                values,
            } => encode_dense_chunk(from, offset, values, out),
            FrameRef::Blocks {
                from,
                dense_len,
                block_len,
                block_ids,
                values,
            } => encode_blocks(from, dense_len, block_len, block_ids, values, out),
            FrameRef::Barrier { epoch } => {
                frame(out, 4, |w| w.u32(epoch));
            }
        }
    }

    /// Materialize an owned [`Message`] (the in-process loopback path of
    /// `SimTransport`: sender and receiver share an address space, so the
    /// payload is cloned instead of serialized).
    pub fn to_message(&self) -> Message {
        match *self {
            FrameRef::PushCoo {
                from,
                dense_len,
                indices,
                values,
            } => Message::PushCoo {
                from,
                tensor: CooTensor::from_sorted(dense_len, indices.to_vec(), values.to_vec()),
            },
            FrameRef::PullHashBitmap {
                server,
                bitmap,
                values,
            } => Message::PullHashBitmap {
                server,
                bitmap: bitmap.clone(),
                values: values.to_vec(),
            },
            FrameRef::PullCoo {
                server,
                dense_len,
                indices,
                values,
            } => Message::PullCoo {
                server,
                tensor: CooTensor::from_sorted(dense_len, indices.to_vec(), values.to_vec()),
            },
            FrameRef::DenseChunk {
                from,
                offset,
                values,
            } => Message::DenseChunk {
                from,
                offset,
                values: values.to_vec(),
            },
            FrameRef::Blocks {
                from,
                dense_len,
                block_len,
                block_ids,
                values,
            } => Message::Blocks {
                from,
                dense_len,
                block_len,
                block_ids: block_ids.to_vec(),
                values: values.to_vec(),
            },
            FrameRef::Barrier { epoch } => Message::Barrier { epoch },
        }
    }
}

/// Append one frame (header + `body`-written payload + back-patched
/// body length) to `out`.
fn frame<F: FnOnce(&mut Writer)>(out: &mut Vec<u8>, kind: u8, body: F) {
    let start = out.len();
    let mut w = Writer(out);
    w.u16(MAGIC);
    w.u8(VERSION);
    w.u8(kind);
    w.u32(0); // body_len placeholder
    let body_start = w.0.len();
    body(&mut w);
    let body_len = count_u32("body length", out.len() - body_start);
    out[start + 4..start + 8].copy_from_slice(&body_len.to_le_bytes());
}

/// Append a `PushCoo` frame from borrowed tensor parts — the
/// zero-allocation steady-state writer: hot loops pass partition views
/// and a reused (cleared) `out` buffer instead of building a
/// [`Message`].
pub fn encode_push_coo(
    from: u32,
    dense_len: usize,
    indices: &[u32],
    values: &[f32],
    out: &mut Vec<u8>,
) {
    frame(out, 1, |w| {
        w.u32(from);
        write_coo_parts(w, dense_len, indices, values);
    });
}

/// Append a `PullHashBitmap` frame from a borrowed bitmap + values —
/// the zero-allocation steady-state writer for the Pull path (the
/// bitmap's word storage is bulk-copied, never re-derived from
/// `ones()`).
pub fn encode_pull_hash_bitmap(server: u32, bitmap: &Bitmap, values: &[f32], out: &mut Vec<u8>) {
    frame(out, 2, |w| {
        w.u32(server);
        w.u64(bitmap.len() as u64);
        w.u64s(bitmap.words());
        w.u32(count_u32("bitmap value count", values.len()));
        w.f32s(values);
    });
}

/// Append a `DenseChunk` frame from a borrowed value run — the shard
/// writer of the dense ring collectives.
pub fn encode_dense_chunk(from: u32, offset: u64, values: &[f32], out: &mut Vec<u8>) {
    frame(out, 5, |w| {
        w.u32(from);
        w.u64(offset);
        w.u32(count_u32("dense chunk count", values.len()));
        w.f32s(values);
    });
}

/// Append a `Blocks` frame from borrowed block ids + concatenated block
/// values (`block_len` values per id) — OmniReduce's wire format.
pub fn encode_blocks(
    from: u32,
    dense_len: u64,
    block_len: u32,
    block_ids: &[u32],
    values: &[f32],
    out: &mut Vec<u8>,
) {
    debug_assert_eq!(values.len(), block_ids.len() * block_len as usize);
    frame(out, 6, |w| {
        w.u32(from);
        w.u64(dense_len);
        w.u32(block_len);
        w.u32(count_u32("block count", block_ids.len()));
        w.u32s(block_ids);
        w.f32s(values);
    });
}

impl Decode for Message {
    fn decode(buf: &[u8]) -> Result<(Self, usize), WireError> {
        let mut r = Reader { buf, pos: 0 };
        let magic = r.u16()?;
        if magic != MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        let version = r.u8()?;
        if version != VERSION {
            return Err(WireError::BadVersion(version));
        }
        let kind = r.u8()?;
        let body_len = r.u32()? as usize;
        let body_start = r.pos;
        let msg = match kind {
            1 => {
                let from = r.u32()?;
                let tensor = read_coo(&mut r)?;
                Message::PushCoo { from, tensor }
            }
            2 => {
                let server = r.u32()?;
                let bits64 = r.u64()?;
                if bits64 > MAX_BITMAP_BITS {
                    return Err(WireError::Malformed("bitmap length implausible"));
                }
                let bits = usize::try_from(bits64)
                    .map_err(|_| WireError::Malformed("bitmap length implausible"))?;
                let n_words = crate::util::ceil_div(bits.max(1), 64);
                let bitmap = Bitmap::from_le_bytes(bits, r.word_bytes(n_words)?);
                let nnz = r.u32()? as usize;
                let values = r.f32s(nnz)?;
                if bitmap.count_ones() != nnz {
                    return Err(WireError::Malformed("bitmap popcount != value count"));
                }
                Message::PullHashBitmap {
                    server,
                    bitmap,
                    values,
                }
            }
            3 => {
                let server = r.u32()?;
                let tensor = read_coo(&mut r)?;
                Message::PullCoo { server, tensor }
            }
            4 => Message::Barrier { epoch: r.u32()? },
            5 => {
                let from = r.u32()?;
                let offset = r.u64()?;
                let count = r.u32()? as usize;
                let values = r.f32s(count)?;
                Message::DenseChunk {
                    from,
                    offset,
                    values,
                }
            }
            6 => {
                let from = r.u32()?;
                let dense_len = r.u64()?;
                let block_len = r.u32()?;
                if block_len == 0 {
                    return Err(WireError::Malformed("zero block length"));
                }
                let nblocks = r.u32()? as usize;
                // Bound the value count before sizing anything from the
                // two untrusted u32s (their product can overflow).
                if nblocks as u64 * block_len as u64 > MAX_BLOCK_VALUES {
                    return Err(WireError::Malformed("implausible block payload"));
                }
                let block_ids = r.u32s(nblocks)?;
                if block_ids.windows(2).any(|w| w[0] >= w[1]) {
                    return Err(WireError::Malformed("block ids not strictly ascending"));
                }
                if let Some(&last) = block_ids.last() {
                    if last as u64 * block_len as u64 >= dense_len {
                        return Err(WireError::Malformed("block id out of range"));
                    }
                }
                let values = r.f32s(nblocks * block_len as usize)?;
                Message::Blocks {
                    from,
                    dense_len,
                    block_len,
                    block_ids,
                    values,
                }
            }
            k => return Err(WireError::BadKind(k)),
        };
        let actual = r.pos - body_start;
        if actual != body_len {
            return Err(WireError::LengthMismatch {
                header: body_len,
                actual,
            });
        }
        Ok((msg, r.pos))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, prop_assert};

    fn roundtrip(m: &Message) -> Message {
        let mut buf = Vec::new();
        m.encode(&mut buf);
        assert_eq!(buf.len(), m.encoded_len(), "encoded_len must be exact");
        let (back, used) = Message::decode(&buf).unwrap();
        assert_eq!(used, buf.len());
        back
    }

    #[test]
    fn push_coo_roundtrip() {
        let t = CooTensor::from_sorted(100, vec![3, 40, 99], vec![1.0, -2.5, 0.125]);
        let m = Message::PushCoo { from: 7, tensor: t };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn pull_hash_bitmap_roundtrip() {
        let bitmap = Bitmap::from_ones(130, &[0, 64, 129]);
        let m = Message::PullHashBitmap {
            server: 2,
            bitmap,
            values: vec![1.0, 2.0, 3.0],
        };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn barrier_roundtrip() {
        let m = Message::Barrier { epoch: 42 };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn bad_magic_rejected() {
        let m = Message::Barrier { epoch: 1 };
        let mut buf = Vec::new();
        m.encode(&mut buf);
        buf[0] = 0;
        assert!(matches!(Message::decode(&buf), Err(WireError::BadMagic(_))));
    }

    #[test]
    fn truncation_rejected() {
        let t = CooTensor::from_sorted(50, vec![1, 2], vec![1.0, 2.0]);
        let m = Message::PushCoo { from: 0, tensor: t };
        let mut buf = Vec::new();
        m.encode(&mut buf);
        for cut in [1, 5, buf.len() - 1] {
            assert!(Message::decode(&buf[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn malformed_indices_rejected() {
        // hand-craft a PushCoo with descending indices
        let t = CooTensor::from_sorted(50, vec![1, 2], vec![1.0, 2.0]);
        let m = Message::PushCoo { from: 0, tensor: t };
        let mut buf = Vec::new();
        m.encode(&mut buf);
        // indices start after header(8) + from(4) + dense_len(8) + nnz(4)
        let idx_off = FRAME_HEADER + 4 + 8 + 4;
        buf[idx_off..idx_off + 4].copy_from_slice(&10u32.to_le_bytes());
        buf[idx_off + 4..idx_off + 8].copy_from_slice(&3u32.to_le_bytes());
        assert!(matches!(
            Message::decode(&buf),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn version_checked() {
        let m = Message::Barrier { epoch: 1 };
        let mut buf = Vec::new();
        m.encode(&mut buf);
        buf[2] = 99;
        assert_eq!(Message::decode(&buf), Err(WireError::BadVersion(99)));
    }

    #[test]
    fn every_kind_roundtrips_on_empty_single_and_max_bodies() {
        // COO kinds: nnz ∈ {0, 1, full density}; bitmap kind: bits ∈
        // {0, 1, large} with none/one/all bits set; barrier: epoch
        // extremes. Exercises the bulk writers' chunk boundaries
        // (0, 1, exactly STAGE_ELEMS, and non-multiples).
        let dense = 5 * STAGE_ELEMS + 7;
        let coo_shapes: Vec<CooTensor> = vec![
            CooTensor::empty(10),
            CooTensor::from_sorted(10, vec![9], vec![-1.5]),
            CooTensor::from_sorted(
                dense,
                (0..dense as u32).collect(),
                (0..dense).map(|i| i as f32 * 0.5 - 3.0).collect(),
            ),
            CooTensor::from_sorted(
                STAGE_ELEMS,
                (0..STAGE_ELEMS as u32).collect(),
                vec![1.0; STAGE_ELEMS],
            ),
        ];
        for t in &coo_shapes {
            let push = Message::PushCoo {
                from: 3,
                tensor: t.clone(),
            };
            assert_eq!(roundtrip(&push), push);
            let pull = Message::PullCoo {
                server: 1,
                tensor: t.clone(),
            };
            assert_eq!(roundtrip(&pull), pull);
        }
        let bitmap_shapes: Vec<(usize, Vec<u32>)> = vec![
            (0, vec![]),
            (1, vec![0]),
            (1, vec![]),
            (1000, (0..1000).collect()),
            (1000, vec![999]),
        ];
        for (bits, ones) in bitmap_shapes {
            let m = Message::PullHashBitmap {
                server: 0,
                bitmap: Bitmap::from_ones(bits, &ones),
                values: vec![0.25; ones.len()],
            };
            assert_eq!(roundtrip(&m), m, "bits {bits}");
        }
        for epoch in [0u32, 1, u32::MAX] {
            let m = Message::Barrier { epoch };
            assert_eq!(roundtrip(&m), m);
        }
    }

    #[test]
    fn borrowed_writers_match_message_encode() {
        // The zero-alloc frame writers must be byte-identical to the
        // Message-based encoder.
        let t = CooTensor::from_sorted(300, (0..150).collect(), vec![2.5; 150]);
        let mut via_msg = Vec::new();
        Message::PushCoo {
            from: 9,
            tensor: t.clone(),
        }
        .encode(&mut via_msg);
        let mut via_parts = Vec::new();
        encode_push_coo(9, t.dense_len, &t.indices, &t.values, &mut via_parts);
        assert_eq!(via_parts, via_msg);

        let bitmap = Bitmap::from_ones(130, &[0, 64, 129]);
        let values = vec![1.0, 2.0, 3.0];
        let mut via_msg = Vec::new();
        Message::PullHashBitmap {
            server: 2,
            bitmap: bitmap.clone(),
            values: values.clone(),
        }
        .encode(&mut via_msg);
        let mut via_parts = Vec::new();
        encode_pull_hash_bitmap(2, &bitmap, &values, &mut via_parts);
        assert_eq!(via_parts, via_msg);

        // Reused buffer: clear + re-encode must reproduce the frame.
        via_parts.clear();
        encode_pull_hash_bitmap(2, &bitmap, &values, &mut via_parts);
        assert_eq!(via_parts, via_msg);
    }

    #[test]
    fn encoded_size_equals_wire_bytes_plus_frame_overhead() {
        // The simulator's analytic accounting vs the real frames, for
        // every kind, after the bulk-write rewrite. Per-kind metadata on
        // top of `wire_bytes()` + FRAME_HEADER:
        //   COO kinds:   from/server(4) + dense_len(8) + nnz(4)
        //   hash bitmap: server(4) + domain_len(8) + nnz(4)
        //                + word padding (words are u64-aligned, wire
        //                  accounting is byte-granular)
        const COO_META: usize = 4 + 8 + 4;
        const HB_META: usize = 4 + 8 + 4;
        for nnz in [0usize, 1, 513] {
            let t = CooTensor::from_sorted(1000, (0..nnz as u32).collect(), vec![1.0; nnz]);
            let m = Message::PushCoo {
                from: 0,
                tensor: t.clone(),
            };
            assert_eq!(
                m.encoded_len(),
                crate::tensor::WireFormat::wire_bytes(&t) + FRAME_HEADER + COO_META
            );
            let mut buf = Vec::new();
            m.encode(&mut buf);
            assert_eq!(buf.len(), m.encoded_len());
        }
        for bits in [0usize, 1, 64, 65, 1000] {
            let ones: Vec<u32> = (0..bits as u32).step_by(3).collect();
            let bitmap = Bitmap::from_ones(bits, &ones);
            let payload_bytes = crate::tensor::WireFormat::wire_bytes(&bitmap) + ones.len() * 4;
            let words = crate::util::ceil_div(bits.max(1), 64);
            let padding = words * 8 - crate::util::ceil_div(bits, 8);
            let m = Message::PullHashBitmap {
                server: 0,
                bitmap,
                values: vec![0.5; ones.len()],
            };
            assert_eq!(
                m.encoded_len(),
                payload_bytes + FRAME_HEADER + HB_META + padding,
                "bits {bits}"
            );
            let mut buf = Vec::new();
            m.encode(&mut buf);
            assert_eq!(buf.len(), m.encoded_len(), "bits {bits}");
        }
    }

    #[test]
    fn implausible_bitmap_length_rejected() {
        // Forge a header claiming 2^50 bits; the decoder must refuse
        // before sizing anything from it.
        let m = Message::PullHashBitmap {
            server: 0,
            bitmap: Bitmap::from_ones(10, &[1]),
            values: vec![1.0],
        };
        let mut buf = Vec::new();
        m.encode(&mut buf);
        let bits_off = FRAME_HEADER + 4;
        buf[bits_off..bits_off + 8].copy_from_slice(&(1u64 << 50).to_le_bytes());
        assert!(matches!(
            Message::decode(&buf),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn encoded_size_matches_analytic_accounting() {
        // PushCoo body ≈ CooTensor::wire_bytes + (frame + from + header)
        let t = CooTensor::from_sorted(1000, (0..100).collect(), vec![1.0; 100]);
        let m = Message::PushCoo {
            from: 0,
            tensor: t.clone(),
        };
        let overhead = FRAME_HEADER + 4 + 8 + 4;
        assert_eq!(
            m.encoded_len(),
            crate::tensor::WireFormat::wire_bytes(&t) + overhead
        );
    }

    #[test]
    fn dense_chunk_roundtrips_and_sizes_exactly() {
        for count in [0usize, 1, STAGE_ELEMS, 777] {
            let m = Message::DenseChunk {
                from: 3,
                offset: 1 << 33,
                values: (0..count).map(|i| i as f32 * 0.25 - 1.0).collect(),
            };
            assert_eq!(roundtrip(&m), m, "count {count}");
            assert_eq!(m.encoded_len(), DENSE_CHUNK_OVERHEAD + count * 4);
        }
    }

    #[test]
    fn blocks_roundtrips_and_sizes_exactly() {
        for (bl, ids) in [(4u32, vec![]), (4, vec![0u32]), (3, vec![1, 5, 9]), (1, vec![0, 2])] {
            let values: Vec<f32> = (0..ids.len() * bl as usize).map(|i| i as f32 + 0.5).collect();
            let m = Message::Blocks {
                from: 1,
                dense_len: 64,
                block_len: bl,
                block_ids: ids.clone(),
                values,
            };
            assert_eq!(roundtrip(&m), m, "bl {bl}");
            assert_eq!(
                m.encoded_len(),
                BLOCKS_FRAME_OVERHEAD + ids.len() * (4 + bl as usize * 4)
            );
        }
    }

    #[test]
    fn blocks_validation_rejects_malformed() {
        let good = Message::Blocks {
            from: 0,
            dense_len: 64,
            block_len: 4,
            block_ids: vec![1, 2],
            values: vec![0.5; 8],
        };
        let mut buf = Vec::new();
        good.encode(&mut buf);
        // descending ids
        let ids_off = FRAME_HEADER + 4 + 8 + 4 + 4;
        let mut bad = buf.clone();
        bad[ids_off..ids_off + 4].copy_from_slice(&9u32.to_le_bytes());
        assert!(matches!(Message::decode(&bad), Err(WireError::Malformed(_))));
        // id beyond the dense range (id·block_len ≥ dense_len)
        let mut bad = buf.clone();
        bad[ids_off + 4..ids_off + 8].copy_from_slice(&16u32.to_le_bytes());
        assert!(matches!(Message::decode(&bad), Err(WireError::Malformed(_))));
        // zero block length
        let bl_off = FRAME_HEADER + 4 + 8;
        let mut bad = buf.clone();
        bad[bl_off..bl_off + 4].copy_from_slice(&0u32.to_le_bytes());
        assert!(matches!(Message::decode(&bad), Err(WireError::Malformed(_))));
    }

    #[test]
    fn frame_ref_is_identical_to_owned_encode() {
        // as_frame().encode / encoded_len / to_message must be exact
        // inverses of the owned Message paths for every kind.
        let msgs = vec![
            Message::PushCoo {
                from: 2,
                tensor: CooTensor::from_sorted(40, vec![1, 7], vec![0.5, -1.0]),
            },
            Message::PullHashBitmap {
                server: 1,
                bitmap: Bitmap::from_ones(70, &[3, 69]),
                values: vec![1.0, 2.0],
            },
            Message::PullCoo {
                server: 0,
                tensor: CooTensor::empty(9),
            },
            Message::DenseChunk {
                from: 4,
                offset: 12,
                values: vec![9.0; 5],
            },
            Message::Blocks {
                from: 5,
                dense_len: 32,
                block_len: 8,
                block_ids: vec![0, 3],
                values: vec![0.25; 16],
            },
            Message::Barrier { epoch: 77 },
        ];
        for m in msgs {
            let fr = m.as_frame();
            let mut via_ref = Vec::new();
            fr.encode(&mut via_ref);
            let mut via_msg = Vec::new();
            m.encode(&mut via_msg);
            assert_eq!(via_ref, via_msg);
            assert_eq!(fr.encoded_len(), via_msg.len());
            assert_eq!(fr.to_message(), m);
        }
    }

    #[test]
    fn disconnected_error_covered() {
        let e = WireError::Disconnected;
        assert!(e.to_string().contains("disconnected"), "{e}");
        assert!(std::error::Error::source(&e).is_none());
        assert_eq!(e, WireError::Disconnected);
    }

    #[test]
    fn frame_too_large_error_covered() {
        let e = WireError::FrameTooLarge {
            what: "coo nnz",
            len: 1 << 33,
        };
        assert!(e.to_string().contains("coo nnz"), "{e}");
        assert!(e.to_string().contains("u32"), "{e}");
        assert_ne!(e, WireError::Disconnected);
    }

    #[test]
    fn ordinary_frames_validate_clean() {
        let t = CooTensor::from_sorted(100, vec![3, 40, 99], vec![1.0, -2.5, 0.125]);
        let msgs = [
            Message::PushCoo { from: 1, tensor: t },
            Message::PullHashBitmap {
                server: 0,
                bitmap: Bitmap::from_ones(130, &[0, 129]),
                values: vec![1.0, 2.0],
            },
            Message::DenseChunk {
                from: 0,
                offset: 0,
                values: vec![0.5; 9],
            },
            Message::Blocks {
                from: 0,
                dense_len: 64,
                block_len: 4,
                block_ids: vec![0, 3],
                values: vec![0.25; 8],
            },
            Message::Barrier { epoch: 1 },
        ];
        for m in &msgs {
            m.as_frame().validate().unwrap_or_else(|e| panic!("{m:?}: {e}"));
        }
    }

    #[test]
    fn prop_coo_roundtrip_any_shape() {
        check(100, |g| {
            let len = g.usize_in(1, 2000);
            let nnz = g.usize_in(0, len.min(200));
            let idx = g.distinct_sorted_u32(nnz, len as u32);
            let vals: Vec<f32> = (0..nnz).map(|_| g.f64_unit() as f32 - 0.5).collect();
            let t = CooTensor::from_sorted(len, idx, vals);
            let m = Message::PushCoo { from: 1, tensor: t };
            prop_assert(roundtrip(&m) == m, "coo roundtrip")
        });
    }

    #[test]
    fn prop_bitmap_roundtrip_any_shape() {
        check(100, |g| {
            let bits = g.usize_in(1, 1500);
            let n = g.usize_in(0, bits.min(128));
            let ones = g.distinct_sorted_u32(n, bits as u32);
            let bitmap = Bitmap::from_ones(bits, &ones);
            let m = Message::PullHashBitmap {
                server: 0,
                bitmap,
                values: vec![0.5; n],
            };
            prop_assert(roundtrip(&m) == m, "bitmap roundtrip")
        });
    }
}
