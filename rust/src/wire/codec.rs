//! Binary codecs for every synchronization payload.
//!
//! Format: little-endian, length-prefixed frames.
//!
//! ```text
//! frame   := magic(u16) version(u8) kind(u8) body_len(u32) body
//! ```
//!
//! Body layouts per message kind are documented on each variant. The
//! encoded size of each payload equals the analytic `wire_bytes()` of
//! the corresponding tensor format plus the fixed frame/header overhead
//! — asserted by tests so the simulator's accounting stays honest.

use crate::tensor::{Bitmap, CooTensor};

const MAGIC: u16 = 0x5A45; // "ZE"
const VERSION: u8 = 1;

/// Frame header bytes: magic + version + kind + body_len.
pub const FRAME_HEADER: usize = 2 + 1 + 1 + 4;

/// Codec error.
#[derive(Debug, PartialEq)]
pub enum WireError {
    Truncated { need: usize, have: usize },
    BadMagic(u16),
    BadVersion(u8),
    BadKind(u8),
    LengthMismatch { header: usize, actual: usize },
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need}, have {have}")
            }
            WireError::BadMagic(m) => write!(f, "bad magic {m:#06x}"),
            WireError::BadVersion(v) => write!(f, "unsupported version {v}"),
            WireError::BadKind(k) => write!(f, "unknown message kind {k}"),
            WireError::LengthMismatch { header, actual } => {
                write!(f, "body length mismatch: header {header}, actual {actual}")
            }
            WireError::Malformed(msg) => write!(f, "malformed body: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

/// A synchronization message.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Push of a COO shard to a server.
    /// Body: dense_len(u64) nnz(u32) indices[u32×nnz] values[f32×nnz]
    PushCoo { from: u32, tensor: CooTensor },
    /// Pull payload: hash bitmap over the server's partition domain +
    /// values in domain order.
    /// Body: server(u32) domain_len(u64) bitmap_words nnz(u32) values
    PullHashBitmap {
        server: u32,
        bitmap: Bitmap,
        values: Vec<f32>,
    },
    /// Pull payload in COO (Zen-COO ablation / Sparse PS).
    PullCoo { server: u32, tensor: CooTensor },
    /// Control: barrier/done marker used by the fabric tests.
    Barrier { epoch: u32 },
}

impl Message {
    fn kind(&self) -> u8 {
        match self {
            Message::PushCoo { .. } => 1,
            Message::PullHashBitmap { .. } => 2,
            Message::PullCoo { .. } => 3,
            Message::Barrier { .. } => 4,
        }
    }
}

/// Encoding into a byte buffer.
pub trait Encode {
    fn encode(&self, out: &mut Vec<u8>);
    fn encoded_len(&self) -> usize;
}

/// Decoding from a byte slice, returning (value, bytes consumed).
pub trait Decode: Sized {
    fn decode(buf: &[u8]) -> Result<(Self, usize), WireError>;
}

// -- primitive helpers -------------------------------------------------

struct Writer<'a>(&'a mut Vec<u8>);

impl Writer<'_> {
    fn u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u32s(&mut self, vs: &[u32]) {
        for v in vs {
            self.u32(*v);
        }
    }
    fn f32s(&mut self, vs: &[f32]) {
        for v in vs {
            self.0.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn u64s(&mut self, vs: &[u64]) {
        for v in vs {
            self.u64(*v);
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Reader<'_> {
    fn need(&self, n: usize) -> Result<(), WireError> {
        if self.pos + n > self.buf.len() {
            Err(WireError::Truncated {
                need: self.pos + n,
                have: self.buf.len(),
            })
        } else {
            Ok(())
        }
    }
    fn u16(&mut self) -> Result<u16, WireError> {
        self.need(2)?;
        let v = u16::from_le_bytes(self.buf[self.pos..self.pos + 2].try_into().unwrap());
        self.pos += 2;
        Ok(v)
    }
    fn u8(&mut self) -> Result<u8, WireError> {
        self.need(1)?;
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        self.need(4)?;
        let v = u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        Ok(v)
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        self.need(8)?;
        let v = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        Ok(v)
    }
    fn u32s(&mut self, n: usize) -> Result<Vec<u32>, WireError> {
        self.need(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Ok(out)
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        self.need(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let v = f32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
            self.pos += 4;
            out.push(v);
        }
        Ok(out)
    }
    fn u64s(&mut self, n: usize) -> Result<Vec<u64>, WireError> {
        self.need(n * 8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }
}

fn coo_body_len(t: &CooTensor) -> usize {
    8 + 4 + t.nnz() * 8
}

fn write_coo(w: &mut Writer, t: &CooTensor) {
    w.u64(t.dense_len as u64);
    w.u32(t.nnz() as u32);
    w.u32s(&t.indices);
    w.f32s(&t.values);
}

fn read_coo(r: &mut Reader) -> Result<CooTensor, WireError> {
    let dense_len = r.u64()? as usize;
    let nnz = r.u32()? as usize;
    let indices = r.u32s(nnz)?;
    let values = r.f32s(nnz)?;
    if indices.windows(2).any(|w| w[0] >= w[1]) {
        return Err(WireError::Malformed("indices not strictly ascending"));
    }
    if indices.last().map(|&i| i as usize >= dense_len).unwrap_or(false) {
        return Err(WireError::Malformed("index out of range"));
    }
    Ok(CooTensor::from_sorted(dense_len, indices, values))
}

impl Encode for Message {
    fn encoded_len(&self) -> usize {
        FRAME_HEADER
            + match self {
                Message::PushCoo { tensor, .. } => 4 + coo_body_len(tensor),
                Message::PullHashBitmap { bitmap, values, .. } => {
                    let words = crate::util::ceil_div(bitmap.len().max(1), 64);
                    4 + 8 + words * 8 + 4 + values.len() * 4
                }
                Message::PullCoo { tensor, .. } => 4 + coo_body_len(tensor),
                Message::Barrier { .. } => 4,
            }
    }

    fn encode(&self, out: &mut Vec<u8>) {
        let start = out.len();
        let mut w = Writer(out);
        w.u16(MAGIC);
        w.u8(VERSION);
        w.u8(self.kind());
        w.u32(0); // body_len placeholder
        let body_start = w.0.len();
        match self {
            Message::PushCoo { from, tensor } => {
                w.u32(*from);
                write_coo(&mut w, tensor);
            }
            Message::PullHashBitmap {
                server,
                bitmap,
                values,
            } => {
                w.u32(*server);
                w.u64(bitmap.len() as u64);
                let words = bitmap_words(bitmap);
                w.u64s(&words);
                w.u32(values.len() as u32);
                w.f32s(values);
            }
            Message::PullCoo { server, tensor } => {
                w.u32(*server);
                write_coo(&mut w, tensor);
            }
            Message::Barrier { epoch } => {
                w.u32(*epoch);
            }
        }
        let body_len = (out.len() - body_start) as u32;
        out[start + 4..start + 8].copy_from_slice(&body_len.to_le_bytes());
    }
}

impl Decode for Message {
    fn decode(buf: &[u8]) -> Result<(Self, usize), WireError> {
        let mut r = Reader { buf, pos: 0 };
        let magic = r.u16()?;
        if magic != MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        let version = r.u8()?;
        if version != VERSION {
            return Err(WireError::BadVersion(version));
        }
        let kind = r.u8()?;
        let body_len = r.u32()? as usize;
        let body_start = r.pos;
        let msg = match kind {
            1 => {
                let from = r.u32()?;
                let tensor = read_coo(&mut r)?;
                Message::PushCoo { from, tensor }
            }
            2 => {
                let server = r.u32()?;
                let bits = r.u64()? as usize;
                let n_words = crate::util::ceil_div(bits.max(1), 64);
                let words = r.u64s(n_words)?;
                let nnz = r.u32()? as usize;
                let values = r.f32s(nnz)?;
                let bitmap = bitmap_from_words(bits, &words);
                if bitmap.count_ones() != nnz {
                    return Err(WireError::Malformed("bitmap popcount != value count"));
                }
                Message::PullHashBitmap {
                    server,
                    bitmap,
                    values,
                }
            }
            3 => {
                let server = r.u32()?;
                let tensor = read_coo(&mut r)?;
                Message::PullCoo { server, tensor }
            }
            4 => Message::Barrier { epoch: r.u32()? },
            k => return Err(WireError::BadKind(k)),
        };
        let actual = r.pos - body_start;
        if actual != body_len {
            return Err(WireError::LengthMismatch {
                header: body_len,
                actual,
            });
        }
        Ok((msg, r.pos))
    }
}

fn bitmap_words(b: &Bitmap) -> Vec<u64> {
    // reconstruct word storage through the public API
    let mut words = vec![0u64; crate::util::ceil_div(b.len().max(1), 64)];
    for i in b.ones() {
        words[i as usize / 64] |= 1u64 << (i % 64);
    }
    words
}

fn bitmap_from_words(bits: usize, words: &[u64]) -> Bitmap {
    let mut b = Bitmap::zeros(bits);
    for (wi, &w) in words.iter().enumerate() {
        let mut w = w;
        while w != 0 {
            let t = w.trailing_zeros() as usize;
            let pos = wi * 64 + t;
            if pos < bits {
                b.set(pos);
            }
            w &= w - 1;
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, prop_assert};

    fn roundtrip(m: &Message) -> Message {
        let mut buf = Vec::new();
        m.encode(&mut buf);
        assert_eq!(buf.len(), m.encoded_len(), "encoded_len must be exact");
        let (back, used) = Message::decode(&buf).unwrap();
        assert_eq!(used, buf.len());
        back
    }

    #[test]
    fn push_coo_roundtrip() {
        let t = CooTensor::from_sorted(100, vec![3, 40, 99], vec![1.0, -2.5, 0.125]);
        let m = Message::PushCoo { from: 7, tensor: t };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn pull_hash_bitmap_roundtrip() {
        let bitmap = Bitmap::from_ones(130, &[0, 64, 129]);
        let m = Message::PullHashBitmap {
            server: 2,
            bitmap,
            values: vec![1.0, 2.0, 3.0],
        };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn barrier_roundtrip() {
        let m = Message::Barrier { epoch: 42 };
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn bad_magic_rejected() {
        let m = Message::Barrier { epoch: 1 };
        let mut buf = Vec::new();
        m.encode(&mut buf);
        buf[0] = 0;
        assert!(matches!(Message::decode(&buf), Err(WireError::BadMagic(_))));
    }

    #[test]
    fn truncation_rejected() {
        let t = CooTensor::from_sorted(50, vec![1, 2], vec![1.0, 2.0]);
        let m = Message::PushCoo { from: 0, tensor: t };
        let mut buf = Vec::new();
        m.encode(&mut buf);
        for cut in [1, 5, buf.len() - 1] {
            assert!(Message::decode(&buf[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn malformed_indices_rejected() {
        // hand-craft a PushCoo with descending indices
        let t = CooTensor::from_sorted(50, vec![1, 2], vec![1.0, 2.0]);
        let m = Message::PushCoo { from: 0, tensor: t };
        let mut buf = Vec::new();
        m.encode(&mut buf);
        // indices start after header(8) + from(4) + dense_len(8) + nnz(4)
        let idx_off = FRAME_HEADER + 4 + 8 + 4;
        buf[idx_off..idx_off + 4].copy_from_slice(&10u32.to_le_bytes());
        buf[idx_off + 4..idx_off + 8].copy_from_slice(&3u32.to_le_bytes());
        assert!(matches!(
            Message::decode(&buf),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn version_checked() {
        let m = Message::Barrier { epoch: 1 };
        let mut buf = Vec::new();
        m.encode(&mut buf);
        buf[2] = 99;
        assert_eq!(Message::decode(&buf), Err(WireError::BadVersion(99)));
    }

    #[test]
    fn encoded_size_matches_analytic_accounting() {
        // PushCoo body ≈ CooTensor::wire_bytes + (frame + from + header)
        let t = CooTensor::from_sorted(1000, (0..100).collect(), vec![1.0; 100]);
        let m = Message::PushCoo {
            from: 0,
            tensor: t.clone(),
        };
        let overhead = FRAME_HEADER + 4 + 8 + 4;
        assert_eq!(
            m.encoded_len(),
            crate::tensor::WireFormat::wire_bytes(&t) + overhead
        );
    }

    #[test]
    fn prop_coo_roundtrip_any_shape() {
        check(100, |g| {
            let len = g.usize_in(1, 2000);
            let nnz = g.usize_in(0, len.min(200));
            let idx = g.distinct_sorted_u32(nnz, len as u32);
            let vals: Vec<f32> = (0..nnz).map(|_| g.f64_unit() as f32 - 0.5).collect();
            let t = CooTensor::from_sorted(len, idx, vals);
            let m = Message::PushCoo { from: 1, tensor: t };
            prop_assert(roundtrip(&m) == m, "coo roundtrip")
        });
    }

    #[test]
    fn prop_bitmap_roundtrip_any_shape() {
        check(100, |g| {
            let bits = g.usize_in(1, 1500);
            let n = g.usize_in(0, bits.min(128));
            let ones = g.distinct_sorted_u32(n, bits as u32);
            let bitmap = Bitmap::from_ones(bits, &ones);
            let m = Message::PullHashBitmap {
                server: 0,
                bitmap,
                values: vec![0.5; n],
            };
            prop_assert(roundtrip(&m) == m, "bitmap roundtrip")
        });
    }
}
