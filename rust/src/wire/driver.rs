//! Drivers: the IO shells under the sans-IO [`Protocol`] machines.
//!
//! A driver owns the byte-moving side of a synchronization: it polls
//! every local protocol machine, transmits the frames they emit,
//! delivers arrivals back, and closes each synchronous stage when the
//! machines reach consensus (see [`crate::wire::protocol`] for the
//! event vocabulary and lifecycle contract). Alongside the
//! discrete-event [`EventDriver`](crate::wire::EventDriver) and the
//! thread-per-rank [`ThreadedDriver`](crate::wire::ThreadedDriver)
//! (their own modules), three shells live here:
//!
//! - [`TransportDriver`] — a thin loop over any in-process
//!   [`Transport`] (virtual-time sim, real-frames channel). Every
//!   emitted frame is delivered before the next poll, so queues never
//!   grow and the byte matrices are identical to the old orchestrated
//!   bodies.
//! - [`SocketDriver`] — a readiness-polled loopback socket mesh with
//!   per-peer send/recv queues: writes are non-blocking and queued,
//!   reads drain concurrently in the same pump pass, so a frame larger
//!   than the kernel socket buffer makes progress instead of
//!   deadlocking — this is what retired the old `TcpTransport`'s
//!   `MAX_TCP_INFLIGHT_BYTES` cap and its up-front workload rejection.
//! - [`WorkerDriver`] — one OS process per rank (`zen worker`). Only
//!   the local rank's machine is driven; stage closure is negotiated
//!   with `Barrier` control frames (per-link FIFO makes a peer's
//!   barrier a completeness proof for its stage traffic). Barrier bytes
//!   are control overhead and excluded from the [`CommReport`], so a
//!   worker's per-stage matrices match the in-process run exactly.
//!
//! ## Adding a backend
//!
//! Implement [`Driver::drive`]: repeatedly poll runnable machines,
//! move `Send` frames, `deliver` arrivals (per-source FIFO must be
//! preserved), and when every machine is parked on the same
//! `StageDone` name with no frame in flight, charge the stage
//! ([`StageAcc`]-style accounting) and call `stage_closed` on each
//! machine. Bound every wait: a dead peer must surface
//! [`WireError::Disconnected`], never a hang.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use super::codec::{Decode, FrameRef, Message, WireError, FRAME_HEADER};
use super::protocol::{Event, Protocol};
use super::transport::{StageAcc, Transport, TransportKind};
use crate::cluster::{CommReport, Network};
use crate::schemes::SyncScratch;
use crate::tensor::CooTensor;

/// What a completed drive returns: one aggregate per rank plus the
/// uniformly-produced communication report. (A [`WorkerDriver`] fills
/// every slot with its local rank's aggregate — all ranks converge to
/// the same tensor by construction.)
#[derive(Clone, Debug)]
pub struct DriveOutcome {
    pub outputs: Vec<CooTensor>,
    pub report: CommReport,
}

/// An IO shell that can run a set of per-rank [`Protocol`] machines to
/// completion. `machines` must have one entry per endpoint, indexed by
/// rank; a driver may drive all of them (in-process backends) or only
/// the local one (multi-process).
pub trait Driver {
    /// Number of endpoints on this driver's fabric.
    fn endpoints(&self) -> usize;

    /// Run the machines to completion. Reusable: each call is one
    /// synchronization, and the accumulated report is taken at the end.
    fn drive<'a>(
        &mut self,
        machines: Vec<Box<dyn Protocol + 'a>>,
        scratch: &mut SyncScratch,
    ) -> Result<DriveOutcome, WireError>;
}

/// Collect the per-rank aggregates once every machine completed — each
/// slot was filled when its `Complete` was counted, so a hole here is a
/// driver-logic bug, not a runtime condition.
pub(crate) fn collect_outputs(outs: Vec<Option<CooTensor>>) -> Vec<CooTensor> {
    outs.into_iter()
        .enumerate()
        .map(|(i, o)| match o {
            Some(t) => t,
            None => unreachable!("rank {i} counted finished without an output"),
        })
        .collect()
}

/// How long a socket-backed driver waits without any byte or machine
/// progress before declaring the peer gone.
const DEFAULT_DEADLINE: Duration = Duration::from_secs(10);

/// Poll interval while idle-waiting on socket readiness.
const IDLE_SLEEP: Duration = Duration::from_micros(200);

enum TxSlot<'t> {
    Owned(Box<dyn Transport>),
    Borrowed(&'t mut dyn Transport),
}

/// The thin loop driver over any in-process [`Transport`]: frames are
/// delivered to the destination machine immediately after each send, so
/// transport queues hold at most one frame and per-receiver FIFO
/// trivially equals per-source FIFO.
pub struct TransportDriver<'t> {
    tx: TxSlot<'t>,
}

impl TransportDriver<'static> {
    /// Own a transport (the [`make_driver`] path).
    pub fn new(tx: Box<dyn Transport>) -> TransportDriver<'static> {
        TransportDriver {
            tx: TxSlot::Owned(tx),
        }
    }
}

impl<'t> TransportDriver<'t> {
    /// Borrow an existing transport for one or more drives — the caller
    /// keeps access to backend-specific state (fabric counters,
    /// disconnect injection) between syncs.
    pub fn over(tx: &'t mut dyn Transport) -> TransportDriver<'t> {
        TransportDriver {
            tx: TxSlot::Borrowed(tx),
        }
    }

    fn tx(&mut self) -> &mut dyn Transport {
        match &mut self.tx {
            TxSlot::Owned(t) => t.as_mut(),
            TxSlot::Borrowed(t) => *t,
        }
    }
}

impl Driver for TransportDriver<'_> {
    fn endpoints(&self) -> usize {
        match &self.tx {
            TxSlot::Owned(t) => t.endpoints(),
            TxSlot::Borrowed(t) => t.endpoints(),
        }
    }

    fn drive<'a>(
        &mut self,
        mut machines: Vec<Box<dyn Protocol + 'a>>,
        scratch: &mut SyncScratch,
    ) -> Result<DriveOutcome, WireError> {
        let n = machines.len();
        if n != self.endpoints() {
            return Err(WireError::Malformed("machine count != endpoints"));
        }
        let mut done: Vec<Option<&'static str>> = (0..n).map(|_| None).collect();
        let mut need = vec![false; n];
        let mut outs: Vec<Option<CooTensor>> = (0..n).map(|_| None).collect();
        let mut finished = 0usize;

        while finished < n {
            let mut progressed = false;
            for i in 0..n {
                if outs[i].is_some() || done[i].is_some() || need[i] {
                    continue;
                }
                loop {
                    match machines[i].poll(scratch)? {
                        Event::Send { dst, msg } => {
                            progressed = true;
                            let tx = self.tx();
                            tx.send_msg(i, dst, msg)?;
                            // Every frame is delivered before the next
                            // poll, so dst's queue holds exactly this
                            // frame — FIFO recv returns it.
                            let delivered = tx.recv(dst)?;
                            machines[dst].deliver(i, delivered)?;
                            need[dst] = false;
                        }
                        Event::NeedFrame { .. } => {
                            need[i] = true;
                            break;
                        }
                        Event::StageDone { name } => {
                            progressed = true;
                            done[i] = Some(name);
                            break;
                        }
                        Event::Complete(t) => {
                            progressed = true;
                            outs[i] = Some(t);
                            finished += 1;
                            break;
                        }
                    }
                }
            }
            if finished == n {
                break;
            }
            let all_parked = (0..n).all(|i| outs[i].is_some() || done[i].is_some());
            if all_parked {
                let name = consensus_stage(&done)?;
                self.tx().end_stage(name)?;
                for i in 0..n {
                    if done[i].take().is_some() {
                        machines[i].stage_closed(name)?;
                    }
                }
            } else if !progressed {
                // A machine is parked on NeedFrame but every frame was
                // already delivered: the protocol is wedged.
                return Err(WireError::Malformed(
                    "protocol stalled: machine waits for a frame nobody sends",
                ));
            }
        }
        let report = self.tx().take_report();
        Ok(DriveOutcome {
            outputs: collect_outputs(outs),
            report,
        })
    }
}

/// All parked machines must agree on the open stage's name. Shared by
/// every in-process driver ([`TransportDriver`], [`SocketDriver`], the
/// event and threaded drivers).
pub(crate) fn consensus_stage(done: &[Option<&'static str>]) -> Result<&'static str, WireError> {
    let name = done
        .iter()
        .flatten()
        .next()
        .copied()
        .ok_or(WireError::Malformed("no open stage at consensus point"))?;
    if done.iter().flatten().any(|&d| d != name) {
        return Err(WireError::Malformed("ranks disagree on the current stage"));
    }
    Ok(name)
}

/// Construct a driver for `kind` over `net`'s endpoints. Socket mesh
/// setup can fail (sandboxes may forbid loopback sockets); the
/// in-process backends cannot.
pub fn make_driver(kind: TransportKind, net: &Network) -> anyhow::Result<Box<dyn Driver>> {
    Ok(match kind {
        TransportKind::Sim => Box::new(TransportDriver::new(Box::new(
            super::transport::SimTransport::new(net.clone()),
        ))),
        TransportKind::Channel => Box::new(TransportDriver::new(Box::new(
            super::transport::ChannelTransport::new(net.clone()),
        ))),
        TransportKind::Socket => {
            let mesh = SocketDriver::mesh(net.clone())
                .map_err(|e| anyhow::anyhow!("socket mesh setup: {e}"))?;
            Box::new(mesh)
        }
        TransportKind::Event => Box::new(super::event::EventDriver::new(net.clone())),
        TransportKind::Threaded => Box::new(super::threaded::ThreadedDriver::new(net.clone())),
    })
}

/// A non-blocking duplex stream with per-peer send/recv queues: the
/// unit of readiness polling shared by [`SocketDriver`] and
/// [`WorkerDriver`]. Writes append to an outgoing byte queue flushed
/// opportunistically; reads accumulate until whole frames parse out.
struct NbStream {
    stream: TcpStream,
    out: VecDeque<u8>,
    inbuf: Vec<u8>,
    read_pos: usize,
    encode_buf: Vec<u8>,
    eof: bool,
}

impl NbStream {
    fn new(stream: TcpStream) -> io::Result<NbStream> {
        stream.set_nodelay(true)?;
        stream.set_nonblocking(true)?;
        Ok(NbStream {
            stream,
            out: VecDeque::new(),
            inbuf: Vec::new(),
            read_pos: 0,
            encode_buf: Vec::new(),
            eof: false,
        })
    }

    /// Queue one encoded frame for transmission.
    fn queue_frame(&mut self, frame: &FrameRef<'_>) {
        self.encode_buf.clear();
        frame.encode(&mut self.encode_buf);
        self.out.extend(self.encode_buf.iter().copied());
    }

    fn has_pending_writes(&self) -> bool {
        !self.out.is_empty()
    }

    /// Write as much of the outgoing queue as the socket accepts.
    fn pump_write(&mut self) -> Result<bool, WireError> {
        let mut progress = false;
        while !self.out.is_empty() {
            let (front, _) = self.out.as_slices();
            match self.stream.write(front) {
                Ok(0) => break,
                Ok(k) => {
                    self.out.drain(..k);
                    progress = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return Err(WireError::Disconnected),
            }
        }
        Ok(progress)
    }

    /// Read whatever is available and parse out complete frames
    /// (appended to `frames` as `(message, encoded_len)`). EOF is
    /// recorded, not an immediate error: bytes already buffered may
    /// still contain the frames we need — the drive loop errors only
    /// if it then stalls.
    fn pump_read(&mut self, frames: &mut Vec<(Message, usize)>) -> Result<bool, WireError> {
        let mut progress = false;
        if !self.eof {
            let mut buf = [0u8; 16 * 1024];
            loop {
                match self.stream.read(&mut buf) {
                    Ok(0) => {
                        self.eof = true;
                        break;
                    }
                    Ok(k) => {
                        self.inbuf.extend_from_slice(&buf[..k]);
                        progress = true;
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => return Err(WireError::Disconnected),
                }
            }
        }
        loop {
            let avail = &self.inbuf[self.read_pos..];
            if avail.len() < FRAME_HEADER {
                break;
            }
            let body_len = match avail[4..8].try_into() {
                Ok(b) => u32::from_le_bytes(b) as usize,
                Err(_) => unreachable!("a 4-byte slice converts to a 4-byte array"),
            };
            if body_len > (1 << 31) {
                return Err(WireError::Malformed("implausible frame body length"));
            }
            let total = FRAME_HEADER + body_len;
            if avail.len() < total {
                break;
            }
            let (msg, used) = Message::decode(&avail[..total])?;
            debug_assert_eq!(used, total);
            self.read_pos += total;
            frames.push((msg, total));
        }
        if self.read_pos == self.inbuf.len() {
            self.inbuf.clear();
            self.read_pos = 0;
        } else if self.read_pos > 64 * 1024 {
            self.inbuf.drain(..self.read_pos);
            self.read_pos = 0;
        }
        Ok(progress)
    }
}

/// Readiness-polled loopback socket mesh: every rank's machine runs in
/// this process, but every frame traverses a real kernel socket. There
/// is deliberately **no in-flight byte cap**: queued writes and reads
/// are pumped in the same pass, so arbitrarily large frames drain
/// concurrently instead of deadlocking the single orchestrating thread.
pub struct SocketDriver {
    acc: StageAcc,
    /// `streams[a][b]`: the duplex socket rank `a` shares with `b`.
    streams: Vec<Vec<Option<NbStream>>>,
    deadline: Duration,
}

impl SocketDriver {
    /// Build the full loopback mesh for `net.endpoints` ranks.
    pub fn mesh(net: Network) -> io::Result<SocketDriver> {
        let n = net.endpoints;
        let mut streams: Vec<Vec<Option<NbStream>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        if n > 1 {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            let addr = listener.local_addr()?;
            for a in 0..n {
                for b in a + 1..n {
                    let out = TcpStream::connect(addr)?;
                    let (inc, _) = listener.accept()?;
                    streams[a][b] = Some(NbStream::new(out)?);
                    streams[b][a] = Some(NbStream::new(inc)?);
                }
            }
        }
        Ok(SocketDriver {
            acc: StageAcc::new(net),
            streams,
            deadline: DEFAULT_DEADLINE,
        })
    }

    /// Override the no-progress deadline (tests).
    pub fn with_deadline(mut self, deadline: Duration) -> SocketDriver {
        self.deadline = deadline;
        self
    }
}

impl Driver for SocketDriver {
    fn endpoints(&self) -> usize {
        self.acc.net.endpoints
    }

    fn drive<'a>(
        &mut self,
        mut machines: Vec<Box<dyn Protocol + 'a>>,
        scratch: &mut SyncScratch,
    ) -> Result<DriveOutcome, WireError> {
        let n = machines.len();
        if n != self.endpoints() {
            return Err(WireError::Malformed("machine count != endpoints"));
        }
        let mut done: Vec<Option<&'static str>> = (0..n).map(|_| None).collect();
        let mut need = vec![false; n];
        let mut outs: Vec<Option<CooTensor>> = (0..n).map(|_| None).collect();
        let mut finished = 0usize;
        let mut outstanding = 0usize;
        let mut frames: Vec<(Message, usize)> = Vec::new();
        let mut last_progress = Instant::now();

        while finished < n {
            let mut progressed = false;
            for i in 0..n {
                if outs[i].is_some() || done[i].is_some() || need[i] {
                    continue;
                }
                loop {
                    match machines[i].poll(scratch)? {
                        Event::Send { dst, msg } => {
                            progressed = true;
                            let frame = msg.as_frame();
                            self.acc.check_send(i, dst, &frame)?;
                            let len = frame.encoded_len() as u64;
                            let s = self.streams[i][dst]
                                .as_mut()
                                .ok_or(WireError::Malformed("no stream for endpoint pair"))?;
                            s.queue_frame(&frame);
                            self.acc.charge(i, dst, len);
                            outstanding += 1;
                        }
                        Event::NeedFrame { .. } => {
                            need[i] = true;
                            break;
                        }
                        Event::StageDone { name } => {
                            progressed = true;
                            done[i] = Some(name);
                            break;
                        }
                        Event::Complete(t) => {
                            progressed = true;
                            outs[i] = Some(t);
                            finished += 1;
                            break;
                        }
                    }
                }
            }
            // Pump every stream: flush queued writes, deliver arrivals.
            let mut dead = false;
            for a in 0..n {
                for b in 0..n {
                    if let Some(s) = self.streams[a][b].as_mut() {
                        progressed |= s.pump_write()?;
                        frames.clear();
                        progressed |= s.pump_read(&mut frames)?;
                        dead |= s.eof;
                        for (msg, _) in frames.drain(..) {
                            progressed = true;
                            machines[a].deliver(b, msg)?;
                            self.acc.on_recv();
                            outstanding -= 1;
                            need[a] = false;
                        }
                    }
                }
            }
            if finished == n {
                break;
            }
            let all_parked = (0..n).all(|i| outs[i].is_some() || done[i].is_some());
            if all_parked && outstanding == 0 {
                let name = consensus_stage(&done)?;
                self.acc.end_stage(name)?;
                for i in 0..n {
                    if done[i].take().is_some() {
                        machines[i].stage_closed(name)?;
                    }
                }
                progressed = true;
            }
            if progressed {
                last_progress = Instant::now();
            } else if dead || last_progress.elapsed() > self.deadline {
                return Err(WireError::Disconnected);
            } else {
                std::thread::sleep(IDLE_SLEEP);
            }
        }
        let report = self.acc.take_report();
        Ok(DriveOutcome {
            outputs: collect_outputs(outs),
            report,
        })
    }
}

/// One-rank-per-process driver: drives only `machines[me]`, speaking to
/// remote peers over sockets. Stage closure is two-phase: when the
/// local machine parks on `StageDone`, a `Barrier{epoch}` control frame
/// is queued to every peer; the stage closes once every peer's barrier
/// for the current epoch arrived and the outgoing queues are flushed.
/// Per-link FIFO means a peer's barrier proves all of its stage traffic
/// was already received — frames read *after* a barrier belong to the
/// peer's next stage and are held back until the local stage boundary
/// passes, so receive-until-stage-closed schemes stay exact.
pub struct WorkerDriver {
    me: usize,
    acc: StageAcc,
    /// Indexed by rank; `None` at `me`.
    peers: Vec<Option<NbStream>>,
    /// Barrier epoch, monotonically increasing across stages and syncs
    /// (both sides advance in lockstep).
    epoch: u32,
    deadline: Duration,
}

impl WorkerDriver {
    /// Rank 0 of a two-rank mesh: bind `addr`, wait for rank 1.
    pub fn listen<A: ToSocketAddrs>(addr: A, net: Network) -> io::Result<WorkerDriver> {
        assert_eq!(net.endpoints, 2, "listen/connect bootstrap is two-rank");
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let start = Instant::now();
        let stream = loop {
            match listener.accept() {
                Ok((s, _)) => break s,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if start.elapsed() > DEFAULT_DEADLINE {
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            "no peer connected within the deadline",
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e),
            }
        };
        Self::over_stream(0, stream, net)
    }

    /// Rank 1 of a two-rank mesh: connect to rank 0 at `addr`,
    /// retrying until it is listening (bounded).
    pub fn connect(addr: &str, net: Network) -> io::Result<WorkerDriver> {
        assert_eq!(net.endpoints, 2, "listen/connect bootstrap is two-rank");
        let target: SocketAddr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "unresolvable address"))?;
        let start = Instant::now();
        let stream = loop {
            match TcpStream::connect(target) {
                Ok(s) => break s,
                Err(e) => {
                    if start.elapsed() > DEFAULT_DEADLINE {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        Self::over_stream(1, stream, net)
    }

    fn over_stream(me: usize, stream: TcpStream, net: Network) -> io::Result<WorkerDriver> {
        let n = net.endpoints;
        let mut peers: Vec<Option<NbStream>> = (0..n).map(|_| None).collect();
        peers[1 - me] = Some(NbStream::new(stream)?);
        Ok(WorkerDriver {
            me,
            acc: StageAcc::new(net),
            peers,
            epoch: 0,
            deadline: DEFAULT_DEADLINE,
        })
    }

    /// Override the no-progress deadline (tests).
    pub fn with_deadline(mut self, deadline: Duration) -> WorkerDriver {
        self.deadline = deadline;
        self
    }

    /// The local rank.
    pub fn rank(&self) -> usize {
        self.me
    }
}

impl Driver for WorkerDriver {
    fn endpoints(&self) -> usize {
        self.acc.net.endpoints
    }

    fn drive<'a>(
        &mut self,
        mut machines: Vec<Box<dyn Protocol + 'a>>,
        scratch: &mut SyncScratch,
    ) -> Result<DriveOutcome, WireError> {
        let n = machines.len();
        if n != self.endpoints() {
            return Err(WireError::Malformed("machine count != endpoints"));
        }
        let me = self.me;
        let m = &mut machines[me];
        let mut done: Option<&'static str> = None;
        let mut need = false;
        let mut out: Option<CooTensor> = None;
        // Frames read but not yet deliverable (beyond a peer's barrier).
        let mut staged: Vec<VecDeque<(Message, usize)>> = (0..n).map(|_| VecDeque::new()).collect();
        let mut barrier_seen = vec![false; n];
        let mut frames: Vec<(Message, usize)> = Vec::new();
        let mut last_progress = Instant::now();

        while out.is_none() {
            let mut progressed = false;
            if done.is_none() && !need {
                loop {
                    match m.poll(scratch)? {
                        Event::Send { dst, msg } => {
                            progressed = true;
                            let frame = msg.as_frame();
                            self.acc.check_send(me, dst, &frame)?;
                            let len = frame.encoded_len() as u64;
                            let s = self.peers[dst]
                                .as_mut()
                                .ok_or(WireError::Malformed("no stream for endpoint pair"))?;
                            s.queue_frame(&frame);
                            // Charged as already-delivered: the remote
                            // end drains it, not this process.
                            self.acc.charge_delivered(me, dst, len);
                        }
                        Event::NeedFrame { .. } => {
                            need = true;
                            break;
                        }
                        Event::StageDone { name } => {
                            progressed = true;
                            done = Some(name);
                            // Announce the stage boundary to every peer.
                            // Control bytes: excluded from the report so
                            // worker matrices match the in-process run.
                            let barrier = FrameRef::Barrier { epoch: self.epoch };
                            for s in self.peers.iter_mut().flatten() {
                                s.queue_frame(&barrier);
                            }
                            break;
                        }
                        Event::Complete(t) => {
                            progressed = true;
                            out = Some(t);
                            break;
                        }
                    }
                }
            }
            // Pump peers: flush writes, stage arrivals.
            let mut dead = false;
            for (src, slot) in self.peers.iter_mut().enumerate() {
                if let Some(s) = slot {
                    progressed |= s.pump_write()?;
                    frames.clear();
                    progressed |= s.pump_read(&mut frames)?;
                    dead |= s.eof;
                    for f in frames.drain(..) {
                        staged[src].push_back(f);
                    }
                }
            }
            // Deliver staged frames up to each peer's current barrier.
            for src in 0..n {
                if src == me {
                    continue;
                }
                while !barrier_seen[src] {
                    match staged[src].pop_front() {
                        Some((Message::Barrier { epoch }, _)) => {
                            if epoch != self.epoch {
                                return Err(WireError::Malformed("barrier epoch out of order"));
                            }
                            barrier_seen[src] = true;
                            progressed = true;
                        }
                        Some((msg, len)) => {
                            progressed = true;
                            self.acc.charge_delivered(src, me, len as u64);
                            m.deliver(src, msg)?;
                            need = false;
                        }
                        None => break,
                    }
                }
            }
            // Close the stage once everyone (local machine + peers)
            // reached the boundary and our writes are on the wire.
            if let Some(name) = done {
                let all_barriers = (0..n).filter(|&s| s != me).all(|s| barrier_seen[s]);
                let flushed = self.peers.iter().flatten().all(|s| !s.has_pending_writes());
                if all_barriers && flushed {
                    self.acc.end_stage(name)?;
                    m.stage_closed(name)?;
                    done = None;
                    self.epoch = self.epoch.wrapping_add(1);
                    barrier_seen.iter_mut().for_each(|b| *b = false);
                    progressed = true;
                }
            }
            if progressed {
                last_progress = Instant::now();
            } else if dead || last_progress.elapsed() > self.deadline {
                return Err(WireError::Disconnected);
            } else {
                std::thread::sleep(IDLE_SLEEP);
            }
        }
        // Flush any bytes the peer still needs to finish its own run.
        let flush_start = Instant::now();
        while self.peers.iter().flatten().any(|s| s.has_pending_writes()) {
            for s in self.peers.iter_mut().flatten() {
                s.pump_write()?;
            }
            if flush_start.elapsed() > self.deadline {
                return Err(WireError::Disconnected);
            }
            std::thread::sleep(IDLE_SLEEP);
        }
        let report = self.acc.take_report();
        let local = match out {
            Some(t) => t,
            None => unreachable!("drive loop exits only when the local machine completed"),
        };
        Ok(DriveOutcome {
            outputs: vec![local; n],
            report,
        })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]
mod tests {
    use super::*;
    use crate::cluster::LinkKind;
    use crate::wire::transport::SimTransport;

    /// A 2-rank toy protocol: each rank sends one barrier-like COO
    /// frame to the other in stage "swap", then completes with the
    /// received tensor — enough to exercise every driver event path
    /// without pulling in a scheme.
    struct Swap {
        rank: usize,
        sent: bool,
        parked: bool,
        closed: bool,
        got: Option<CooTensor>,
    }

    impl Swap {
        fn pair() -> Vec<Box<dyn Protocol>> {
            (0..2)
                .map(|rank| {
                    Box::new(Swap {
                        rank,
                        sent: false,
                        parked: false,
                        closed: false,
                        got: None,
                    }) as Box<dyn Protocol>
                })
                .collect()
        }
    }

    impl Protocol for Swap {
        fn rank(&self) -> usize {
            self.rank
        }

        fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
            if !self.sent {
                self.sent = true;
                let t = CooTensor::from_sorted(8, vec![self.rank as u32], vec![1.0]);
                return Ok(Event::Send {
                    dst: 1 - self.rank,
                    msg: Message::PushCoo {
                        from: self.rank as u32,
                        tensor: t,
                    },
                });
            }
            if self.got.is_none() {
                return Ok(Event::NeedFrame { src: 1 - self.rank });
            }
            if !self.parked {
                self.parked = true;
                return Ok(Event::StageDone { name: "swap" });
            }
            assert!(self.closed, "completed before stage closure");
            Ok(Event::Complete(self.got.take().unwrap()))
        }

        fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
            assert_eq!(src, 1 - self.rank);
            match msg {
                Message::PushCoo { tensor, .. } => self.got = Some(tensor),
                other => panic!("unexpected frame {other:?}"),
            }
            Ok(())
        }

        fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
            assert_eq!(name, "swap");
            self.closed = true;
            Ok(())
        }
    }

    #[test]
    fn transport_driver_runs_a_toy_protocol() {
        let net = Network::new(2, LinkKind::Tcp25);
        let mut d = TransportDriver::new(Box::new(SimTransport::new(net)));
        let got = d
            .drive(Swap::pair(), &mut SyncScratch::new())
            .expect("toy protocol");
        assert_eq!(got.outputs[0].indices, vec![1]);
        assert_eq!(got.outputs[1].indices, vec![0]);
        assert_eq!(got.report.stages.len(), 1);
        assert_eq!(got.report.stages[0].name, "swap");
        assert!(got.report.stages[0].total_bytes() > 0);
    }

    #[test]
    fn socket_mesh_matches_sim_for_the_toy_protocol() {
        let net = Network::new(2, LinkKind::Tcp25);
        let mut sim = TransportDriver::new(Box::new(SimTransport::new(net.clone())));
        let want = sim.drive(Swap::pair(), &mut SyncScratch::new()).unwrap();
        let mut mesh = match SocketDriver::mesh(net) {
            Ok(m) => m,
            Err(e) => {
                // Sandboxes may forbid loopback sockets.
                eprintln!("skipping socket mesh test: {e}");
                return;
            }
        };
        let got = mesh.drive(Swap::pair(), &mut SyncScratch::new()).unwrap();
        assert_eq!(got.outputs, want.outputs);
        assert_eq!(got.report.stages[0].sent, want.report.stages[0].sent);
        assert_eq!(got.report.stages[0].recv, want.report.stages[0].recv);
    }

    #[test]
    fn machine_count_mismatch_is_an_error() {
        let net = Network::new(3, LinkKind::Tcp25);
        let mut d = TransportDriver::new(Box::new(SimTransport::new(net)));
        let err = d
            .drive(Swap::pair(), &mut SyncScratch::new())
            .expect_err("2 machines on 3 endpoints");
        assert!(matches!(err, WireError::Malformed(_)));
    }
}
