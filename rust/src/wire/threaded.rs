//! Thread-per-rank driver: real concurrency, one OS thread per machine.
//!
//! [`ThreadedDriver`] is the in-process realization of "every rank is a
//! real execution context": each [`Protocol`] machine runs on its own
//! scoped OS thread, frames move through per-rank mpsc channels, and a
//! coordinator (the calling thread) closes synchronous stages once all
//! ranks park and every charged frame is delivered. It completes the
//! PR-6 follow-on ("multi-threaded, one thread per rank, in-process
//! driving") — and it is the honest wall-clock baseline the
//! discrete-event [`EventDriver`](crate::wire::EventDriver) is
//! benchmarked against (`examples/bench_simscale.rs`): simulation cost
//! here scales with thread count, there with event count.
//!
//! Accounting is the shared [`StageAcc`] behind a mutex, so per-stage
//! byte matrices and α–β stage times are identical to every other
//! backend; outputs are bit-identical because machines consume frames
//! through the per-source-FIFO [`Inbox`](crate::wire::Inbox) merge path
//! and mpsc channels preserve per-sender order.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::codec::{Message, WireError};
use super::driver::{consensus_stage, DriveOutcome, Driver};
use super::protocol::{Event, Protocol};
use super::transport::StageAcc;
use crate::cluster::Network;
use crate::schemes::SyncScratch;
use crate::tensor::CooTensor;

/// What a rank thread can find in its channel.
enum RankMsg {
    /// A frame from `src`.
    Frame(usize, Message),
    /// The named stage every rank parked on is closed.
    Close(&'static str),
    /// The drive is failing; unwind now.
    Abort,
}

/// What rank threads report to the coordinator.
enum CoordMsg {
    Parked { rank: usize, name: &'static str },
    Done { rank: usize, output: CooTensor },
    Failed { err: WireError },
}

/// How long any wait (a parked rank, the coordinator, a frame-starved
/// machine) may go without progress before the drive fails with
/// [`WireError::Disconnected`].
const DEFAULT_DEADLINE: Duration = Duration::from_secs(30);

/// One OS thread per rank over in-process channels.
pub struct ThreadedDriver {
    net: Network,
    deadline: Duration,
}

impl ThreadedDriver {
    pub fn new(net: Network) -> ThreadedDriver {
        ThreadedDriver {
            net,
            deadline: DEFAULT_DEADLINE,
        }
    }

    /// Override the no-progress deadline (tests).
    pub fn with_deadline(mut self, deadline: Duration) -> ThreadedDriver {
        self.deadline = deadline;
        self
    }
}

/// One rank's thread body: poll the machine, move frames through the
/// channels, park on stage boundaries until the coordinator closes
/// them. Every blocking wait is bounded by `deadline`.
fn rank_loop<'a>(
    me: usize,
    mut machine: Box<dyn Protocol + 'a>,
    rx: &Receiver<RankMsg>,
    txs: &[Sender<RankMsg>],
    coord: &Sender<CoordMsg>,
    acc: &Mutex<StageAcc>,
    deadline: Duration,
) -> Result<CooTensor, WireError> {
    let mut scratch = SyncScratch::new();
    loop {
        match machine.poll(&mut scratch)? {
            Event::Send { dst, msg } => {
                {
                    let mut a = super::lock_or_panic(acc, "stage accounting");
                    let frame = msg.as_frame();
                    a.check_send(me, dst, &frame)?;
                    let len = frame.encoded_len() as u64;
                    // Charged before the channel send: the coordinator
                    // treats in_flight == 0 as "all emitted frames
                    // delivered", which holds only with this ordering.
                    a.charge(me, dst, len);
                }
                txs.get(dst)
                    .ok_or(WireError::Malformed("no stream for endpoint pair"))?
                    .send(RankMsg::Frame(me, msg))
                    .map_err(|_| WireError::Disconnected)?;
            }
            Event::NeedFrame { .. } => match rx.recv_timeout(deadline) {
                Ok(RankMsg::Frame(src, msg)) => {
                    machine.deliver(src, msg)?;
                    super::lock_or_panic(acc, "stage accounting").on_recv();
                }
                Ok(RankMsg::Close(_)) => {
                    return Err(WireError::Malformed("stage closed under a waiting machine"))
                }
                Ok(RankMsg::Abort) | Err(_) => return Err(WireError::Disconnected),
            },
            Event::StageDone { name } => {
                coord
                    .send(CoordMsg::Parked { rank: me, name })
                    .map_err(|_| WireError::Disconnected)?;
                // Parked: keep draining arrivals (peers may still be
                // emitting this stage's frames) until the close lands.
                loop {
                    match rx.recv_timeout(deadline) {
                        Ok(RankMsg::Frame(src, msg)) => {
                            machine.deliver(src, msg)?;
                            super::lock_or_panic(acc, "stage accounting").on_recv();
                        }
                        Ok(RankMsg::Close(closed)) => {
                            machine.stage_closed(closed)?;
                            break;
                        }
                        Ok(RankMsg::Abort) | Err(_) => return Err(WireError::Disconnected),
                    }
                }
            }
            Event::Complete(t) => return Ok(t),
        }
    }
}

impl Driver for ThreadedDriver {
    fn endpoints(&self) -> usize {
        self.net.endpoints
    }

    fn drive<'a>(
        &mut self,
        machines: Vec<Box<dyn Protocol + 'a>>,
        _scratch: &mut SyncScratch,
    ) -> Result<DriveOutcome, WireError> {
        let n = machines.len();
        if n != self.endpoints() {
            return Err(WireError::Malformed("machine count != endpoints"));
        }
        let acc = Mutex::new(StageAcc::new(self.net.clone()));
        let deadline = self.deadline;
        let (coord_tx, coord_rx) = channel::<CoordMsg>();
        let mut rank_txs: Vec<Sender<RankMsg>> = Vec::with_capacity(n);
        let mut rank_rxs: Vec<Option<Receiver<RankMsg>>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            rank_txs.push(tx);
            rank_rxs.push(Some(rx));
        }

        let outs = std::thread::scope(|s| {
            for (i, machine) in machines.into_iter().enumerate() {
                let rx = match rank_rxs[i].take() {
                    Some(rx) => rx,
                    None => unreachable!("receiver {i} handed out once"),
                };
                let txs = rank_txs.clone();
                let coord = coord_tx.clone();
                let acc = &acc;
                s.spawn(move || {
                    let msg = match rank_loop(i, machine, &rx, &txs, &coord, acc, deadline) {
                        Ok(output) => CoordMsg::Done { rank: i, output },
                        Err(err) => CoordMsg::Failed { err },
                    };
                    let _ = coord.send(msg);
                });
            }

            // Coordinator: collect parks, close stages, collect outputs.
            let mut done: Vec<Option<&'static str>> = (0..n).map(|_| None).collect();
            let mut outs: Vec<Option<CooTensor>> = (0..n).map(|_| None).collect();
            let mut finished = 0usize;
            let mut failure: Option<WireError> = None;
            while finished < n && failure.is_none() {
                match coord_rx.recv_timeout(deadline) {
                    Ok(CoordMsg::Parked { rank, name }) => done[rank] = Some(name),
                    Ok(CoordMsg::Done { rank, output }) => {
                        outs[rank] = Some(output);
                        finished += 1;
                    }
                    Ok(CoordMsg::Failed { err }) => failure = Some(err),
                    Err(_) => failure = Some(WireError::Disconnected),
                }
                let all_parked = (0..n).all(|i| outs[i].is_some() || done[i].is_some());
                if failure.is_none() && finished < n && all_parked {
                    // Every stage send was charged before its rank
                    // parked; wait for the channels to drain so the
                    // byte matrix is complete, then close.
                    let drain = Instant::now();
                    loop {
                        if super::lock_or_panic(&acc, "stage accounting").in_flight() == 0 {
                            break;
                        }
                        if drain.elapsed() > deadline {
                            failure = Some(WireError::Disconnected);
                            break;
                        }
                        std::thread::sleep(Duration::from_micros(50));
                    }
                    if failure.is_none() {
                        match consensus_stage(&done)
                            .and_then(|name| {
                                super::lock_or_panic(&acc, "stage accounting")
                                    .end_stage(name)
                                    .map(|_| name)
                            })
                        {
                            Ok(name) => {
                                for i in 0..n {
                                    if done[i].take().is_some()
                                        && rank_txs[i].send(RankMsg::Close(name)).is_err()
                                    {
                                        failure = Some(WireError::Disconnected);
                                    }
                                }
                            }
                            Err(e) => failure = Some(e),
                        }
                    }
                }
            }
            if let Some(err) = failure {
                // Unwind: wake every rank; scope join is bounded because
                // every thread wait carries the deadline.
                for tx in &rank_txs {
                    let _ = tx.send(RankMsg::Abort);
                }
                return Err(err);
            }
            Ok(outs)
        })?;

        let report = match acc.into_inner() {
            Ok(a) => a.take_report(),
            // A rank panic while holding the lock would already have
            // propagated through the scope join above.
            Err(_) => unreachable!("accounting mutex poisoned after a clean scope join"),
        };
        Ok(DriveOutcome {
            outputs: super::driver::collect_outputs(outs),
            report,
        })
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

    use super::*;
    use crate::cluster::LinkKind;
    use crate::schemes::{self, SyncScheme};
    use crate::wire::transport::SimTransport;
    use crate::wire::TransportDriver;
    use crate::workload::random_uniform_inputs;

    #[test]
    fn threaded_driver_matches_sim_for_real_schemes() {
        for machines in [2usize, 4] {
            let inputs = random_uniform_inputs(0x7d ^ machines as u64, machines, 2_000, 0.05);
            let nnz = inputs[0].nnz().max(8);
            for name in ["zen", "dense", "sparseps"] {
                let scheme = schemes::by_name(name, machines, 0x7ace, nnz).unwrap();
                let net = Network::new(machines, LinkKind::Tcp25);
                let mut sim = TransportDriver::new(Box::new(SimTransport::new(net.clone())));
                let want = scheme
                    .run(&inputs, &mut sim, &mut SyncScratch::new())
                    .unwrap();
                let mut th = ThreadedDriver::new(net);
                let got = scheme
                    .run(&inputs, &mut th, &mut SyncScratch::new())
                    .unwrap();
                assert_eq!(got.outputs, want.outputs, "{name} n={machines}");
                assert_eq!(got.report.stages.len(), want.report.stages.len());
                for (s, c) in want.report.stages.iter().zip(got.report.stages.iter()) {
                    assert_eq!(s.name, c.name, "{name} n={machines}");
                    assert_eq!(s.sent, c.sent, "{name} n={machines} stage {}", s.name);
                    assert_eq!(s.recv, c.recv, "{name} n={machines} stage {}", s.name);
                    assert_eq!(s.time, c.time, "{name} n={machines} stage {}", s.name);
                }
            }
        }
    }

    #[test]
    fn machine_count_mismatch_is_an_error() {
        let net = Network::new(3, LinkKind::Tcp25);
        let mut th = ThreadedDriver::new(net);
        let scheme = schemes::by_name("dense", 2, 1, 8).unwrap();
        let inputs = random_uniform_inputs(1, 2, 256, 0.1);
        let err = scheme
            .run(&inputs, &mut th, &mut SyncScratch::new())
            .expect_err("2 machines on 3 endpoints");
        assert!(matches!(err, WireError::Malformed(_)));
    }
}
