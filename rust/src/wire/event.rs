//! Discrete-event virtual time: thousands of ranks on one thread.
//!
//! [`EventDriver`] owns all n per-rank [`Protocol`] machines of a
//! synchronization and drives them from one binary event heap, so
//! simulation cost scales with *event* count, not thread count — the
//! regime where the paper's scheme crossovers actually matter (512–1024
//! GPUs across dozens of nodes, Fig. 7) runs on a single thread in
//! seconds. The classed α–β charging model follows "A DAG Model of
//! Synchronous SGD" (PAPERS.md): each frame is charged latency plus
//! serialization from the [`Topology`](crate::cluster::Topology) link
//! class it crosses.
//!
//! ## Heap ordering rules
//!
//! Deliveries pop in ascending `(time, src, seq)` order — `time` via
//! `f64::total_cmp`, then source rank, then a global send sequence
//! number. Per-(src, dst) delivery times are strictly monotone (each
//! later frame starts no earlier than the previous one freed the link
//! and serialization time is never zero), so per-source FIFO — the only
//! order the [`Inbox`](crate::wire::Inbox) merge path depends on — is
//! preserved and outputs stay bit-identical to every other backend.
//!
//! ## Contention model
//!
//! Each endpoint keeps a per-link-class busy-until horizon for its
//! transmit and receive sides. A frame from `src` to `dst` over class
//! `c` starts at `max(rank_time[src], tx_free[c][src], rx_free[c][dst])`,
//! occupies both horizons for its serialization time `bytes·8/B_c`, and
//! arrives a propagation latency `α_c` later — so multiple in-flight
//! frames sharing a link class queue behind each other instead of
//! overlapping for free. Stage *totals* stay exactly equal to
//! [`SimTransport`](crate::wire::SimTransport): byte matrices flow
//! through the same [`StageAcc`], and at each stage boundary the global
//! clock advances by the stage's max-over-classes α–β time (the same
//! number every backend charges), with all horizons reset to the
//! boundary — a synchronous stage is a barrier.
//!
//! ## Allocation-free invariants
//!
//! The steady-state loop allocates nothing per simulated iteration:
//! event nodes live in a free-listed slot pool (messages are moved in
//! and out by `Option::take`), the heap and per-endpoint horizon vectors
//! are retained across drives, and in [`totals-only`](EventDriver::totals_only)
//! mode stage closure goes through `StageAcc::end_stage_lite`, which
//! zeroes the byte matrices in place instead of materializing per-stage
//! reports. `rust/tests/alloc_steady_state.rs` pins this with a
//! counting allocator. [`pool_high_water`](EventDriver::pool_high_water)
//! exposes the slot pool's high-water mark as a peak-memory proxy for
//! the scale bench.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::codec::{Message, WireError};
use super::driver::{consensus_stage, DriveOutcome, Driver};
use super::protocol::{Event, Protocol};
use super::transport::StageAcc;
use crate::cluster::Network;
use crate::schemes::SyncScratch;
use crate::tensor::CooTensor;

/// Endpoint ranks fit `u32` by construction (a `Network` never has
/// anywhere near 2^32 endpoints); spelled out so the conversion can't
/// silently truncate if that ever changes.
fn rank_u32(r: usize) -> u32 {
    match u32::try_from(r) {
        Ok(v) => v,
        Err(_) => panic!("rank {r} exceeds the u32 event-key range"),
    }
}

/// One scheduled delivery: the heap key plus the slot holding the
/// message. Ordered by `(time, src, seq)` — see the module docs.
#[derive(Clone, Copy, Debug)]
struct DeliveryEv {
    time: f64,
    src: u32,
    dst: u32,
    seq: u64,
    slot: u32,
}

impl PartialEq for DeliveryEv {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for DeliveryEv {}
impl PartialOrd for DeliveryEv {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for DeliveryEv {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.src.cmp(&other.src))
            .then(self.seq.cmp(&other.seq))
    }
}

/// Compact accumulated totals for [`EventDriver::totals_only`] mode:
/// what a large-n sweep needs from a drive without the per-stage
/// [`StageReport`](crate::cluster::StageReport) allocations.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EventTotals {
    /// Stages closed.
    pub stages: u64,
    /// Bytes moved per link class (`[intra, inter]`).
    pub bytes_by_class: [u64; 2],
    /// Accumulated α–β stage time per link class.
    pub time_by_class: [f64; 2],
    /// Accumulated stage time (max over classes per stage).
    pub time: f64,
}

/// Single-threaded discrete-event scheduler over all n protocol
/// machines. Reusable across drives: the heap, slot pool, and horizon
/// vectors are retained, and [`virtual_time`](EventDriver::virtual_time)
/// accumulates monotonically across synchronizations.
pub struct EventDriver {
    acc: StageAcc,
    totals_only: bool,
    totals: EventTotals,
    /// Virtual time of the last closed stage boundary.
    clock: f64,
    /// Virtual time at which the current stage opened.
    rank_time: Vec<f64>,
    /// Per-class per-endpoint transmit-side busy-until horizon.
    tx_free: [Vec<f64>; 2],
    /// Per-class per-endpoint receive-side busy-until horizon.
    rx_free: [Vec<f64>; 2],
    heap: BinaryHeap<Reverse<DeliveryEv>>,
    /// Free-listed message pool: in-flight frames park here so the
    /// steady-state loop never allocates event nodes.
    slots: Vec<Option<Message>>,
    free: Vec<u32>,
    seq: u64,
    events: u64,
}

impl EventDriver {
    pub fn new(net: Network) -> EventDriver {
        let n = net.endpoints;
        EventDriver {
            acc: StageAcc::new(net),
            totals_only: false,
            totals: EventTotals::default(),
            clock: 0.0,
            rank_time: vec![0.0; n],
            tx_free: [vec![0.0; n], vec![0.0; n]],
            rx_free: [vec![0.0; n], vec![0.0; n]],
            heap: BinaryHeap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            seq: 0,
            events: 0,
        }
    }

    /// Totals-only accounting: skip per-stage `StageReport`s (and their
    /// allocations) and accumulate [`EventTotals`] instead. The mode for
    /// large-n sweeps and the allocation-pinned steady-state loop; the
    /// returned [`DriveOutcome`] carries an empty report.
    pub fn totals_only(mut self) -> EventDriver {
        self.totals_only = true;
        self
    }

    /// Accumulated virtual time: the sum of every closed stage's
    /// max-over-classes α–β time, across all drives — exactly what
    /// `CommReport::comm_time()` sums for the same run.
    pub fn virtual_time(&self) -> f64 {
        self.clock
    }

    /// Delivery events processed across all drives.
    pub fn events_processed(&self) -> u64 {
        self.events
    }

    /// High-water mark of the in-flight message pool (peak concurrent
    /// frames): the scale bench's peak-memory proxy.
    pub fn pool_high_water(&self) -> usize {
        self.slots.len()
    }

    /// Accumulated totals (populated in [`totals_only`](Self::totals_only)
    /// mode).
    pub fn totals(&self) -> EventTotals {
        self.totals
    }

    /// Validate, charge, and heap-schedule one emitted frame.
    fn schedule_send(&mut self, src: usize, dst: usize, msg: Message) -> Result<(), WireError> {
        let len = {
            let frame = msg.as_frame();
            self.acc.check_send(src, dst, &frame)?;
            frame.encoded_len() as u64
        };
        let class = self.acc.net.topo.class_of(src, dst);
        let c = class.idx();
        let link = self.acc.net.topo.link_of(class);
        let ser = len as f64 * 8.0 / link.bandwidth_bps();
        let start = self.rank_time[src]
            .max(self.tx_free[c][src])
            .max(self.rx_free[c][dst]);
        let busy_until = start + ser;
        self.tx_free[c][src] = busy_until;
        self.rx_free[c][dst] = busy_until;
        self.acc.charge(src, dst, len);
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slots.push(None);
                rank_u32(self.slots.len() - 1)
            }
        };
        self.slots[slot as usize] = Some(msg);
        self.seq += 1;
        self.heap.push(Reverse(DeliveryEv {
            time: busy_until + link.latency(),
            src: rank_u32(src),
            dst: rank_u32(dst),
            seq: self.seq,
            slot,
        }));
        Ok(())
    }

    /// Close the consensus stage: charge its α–β time, jump the global
    /// clock to the stage boundary, and reset every horizon to it.
    fn close_stage(&mut self, name: &str) -> Result<(), WireError> {
        let stage_time = if self.totals_only {
            let classes = self.acc.end_stage_lite()?;
            self.totals.stages += 1;
            for c in 0..2 {
                self.totals.bytes_by_class[c] += classes[c].bytes;
                self.totals.time_by_class[c] += classes[c].time;
            }
            let t = classes[0].time.max(classes[1].time);
            self.totals.time += t;
            t
        } else {
            self.acc.end_stage(name)?
        };
        self.clock += stage_time;
        let t = self.clock;
        self.rank_time.iter_mut().for_each(|v| *v = t);
        for c in 0..2 {
            self.tx_free[c].iter_mut().for_each(|v| *v = t);
            self.rx_free[c].iter_mut().for_each(|v| *v = t);
        }
        Ok(())
    }
}

impl Driver for EventDriver {
    fn endpoints(&self) -> usize {
        self.acc.net.endpoints
    }

    fn drive<'a>(
        &mut self,
        mut machines: Vec<Box<dyn Protocol + 'a>>,
        scratch: &mut SyncScratch,
    ) -> Result<DriveOutcome, WireError> {
        let n = machines.len();
        if n != self.endpoints() {
            return Err(WireError::Malformed("machine count != endpoints"));
        }
        let mut done: Vec<Option<&'static str>> = (0..n).map(|_| None).collect();
        let mut need = vec![false; n];
        let mut outs: Vec<Option<CooTensor>> = (0..n).map(|_| None).collect();
        let mut finished = 0usize;

        while finished < n {
            let mut progressed = false;
            for i in 0..n {
                if outs[i].is_some() || done[i].is_some() || need[i] {
                    continue;
                }
                loop {
                    match machines[i].poll(scratch)? {
                        Event::Send { dst, msg } => {
                            progressed = true;
                            self.schedule_send(i, dst, msg)?;
                        }
                        Event::NeedFrame { .. } => {
                            need[i] = true;
                            break;
                        }
                        Event::StageDone { name } => {
                            progressed = true;
                            done[i] = Some(name);
                            break;
                        }
                        Event::Complete(t) => {
                            progressed = true;
                            outs[i] = Some(t);
                            finished += 1;
                            break;
                        }
                    }
                }
            }
            // Drain the heap: every scheduled frame is delivered in
            // deterministic (time, src, seq) order before the next poll
            // round — per-source FIFO is monotone by construction, so
            // the Inbox merge path sees the same order as every other
            // backend.
            while let Some(Reverse(ev)) = self.heap.pop() {
                let msg = match self.slots[ev.slot as usize].take() {
                    Some(m) => m,
                    None => unreachable!("scheduled slot {} holds no message", ev.slot),
                };
                self.free.push(ev.slot);
                let dst = ev.dst as usize;
                if self.rank_time[dst] < ev.time {
                    self.rank_time[dst] = ev.time;
                }
                self.acc.on_recv();
                self.events += 1;
                machines[dst].deliver(ev.src as usize, msg)?;
                need[dst] = false;
                progressed = true;
            }
            if finished == n {
                break;
            }
            let all_parked = (0..n).all(|i| outs[i].is_some() || done[i].is_some());
            if all_parked {
                let name = consensus_stage(&done)?;
                self.close_stage(name)?;
                for i in 0..n {
                    if done[i].take().is_some() {
                        machines[i].stage_closed(name)?;
                    }
                }
            } else if !progressed {
                return Err(WireError::Malformed(
                    "protocol stalled: machine waits for a frame nobody sends",
                ));
            }
        }
        let report = self.acc.take_report();
        Ok(DriveOutcome {
            outputs: super::driver::collect_outputs(outs),
            report,
        })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]
mod tests {
    use super::*;
    use crate::cluster::LinkKind;
    use crate::schemes::{self, verify_outputs, SyncScheme};
    use crate::wire::transport::SimTransport;
    use crate::wire::TransportDriver;
    use crate::workload::random_uniform_inputs;

    /// Minimal toy: each rank pushes one COO frame to the next rank
    /// (mod n) in stage "swap", then completes with what it received.
    struct RingSwap {
        rank: usize,
        n: usize,
        sent: bool,
        parked: bool,
        closed: bool,
        got: Option<CooTensor>,
    }

    impl RingSwap {
        fn machines(n: usize) -> Vec<Box<dyn Protocol>> {
            (0..n)
                .map(|rank| {
                    Box::new(RingSwap {
                        rank,
                        n,
                        sent: false,
                        parked: false,
                        closed: false,
                        got: None,
                    }) as Box<dyn Protocol>
                })
                .collect()
        }
    }

    impl Protocol for RingSwap {
        fn rank(&self) -> usize {
            self.rank
        }

        fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
            if !self.sent {
                self.sent = true;
                let t = CooTensor::from_sorted(64, vec![self.rank as u32], vec![1.0]);
                return Ok(Event::Send {
                    dst: (self.rank + 1) % self.n,
                    msg: Message::PushCoo {
                        from: self.rank as u32,
                        tensor: t,
                    },
                });
            }
            if self.got.is_none() {
                return Ok(Event::NeedFrame {
                    src: (self.rank + self.n - 1) % self.n,
                });
            }
            if !self.parked {
                self.parked = true;
                return Ok(Event::StageDone { name: "swap" });
            }
            assert!(self.closed, "completed before stage closure");
            Ok(Event::Complete(self.got.take().unwrap()))
        }

        fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
            assert_eq!(src, (self.rank + self.n - 1) % self.n);
            match msg {
                Message::PushCoo { tensor, .. } => self.got = Some(tensor),
                other => panic!("unexpected frame {other:?}"),
            }
            Ok(())
        }

        fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
            assert_eq!(name, "swap");
            self.closed = true;
            Ok(())
        }
    }

    #[test]
    fn event_driver_matches_sim_on_the_toy_protocol() {
        let net = Network::new(4, LinkKind::Tcp25);
        let mut sim = TransportDriver::new(Box::new(SimTransport::new(net.clone())));
        let want = sim
            .drive(RingSwap::machines(4), &mut SyncScratch::new())
            .unwrap();
        let mut ev = EventDriver::new(net);
        let got = ev
            .drive(RingSwap::machines(4), &mut SyncScratch::new())
            .unwrap();
        assert_eq!(got.outputs, want.outputs);
        assert_eq!(got.report.stages.len(), want.report.stages.len());
        let (s, c) = (&want.report.stages[0], &got.report.stages[0]);
        assert_eq!(s.name, c.name);
        assert_eq!(s.sent, c.sent);
        assert_eq!(s.recv, c.recv);
        assert_eq!(s.time, c.time, "stage α–β time is exact across backends");
        assert_eq!(
            ev.virtual_time(),
            got.report.comm_time(),
            "virtual clock equals the summed stage times"
        );
    }

    /// Two senders share rank 0's receive link: the big frame (polled
    /// first, rank order) seizes the link, so the small frame — which
    /// would arrive first on an uncontended link — queues behind it.
    struct Probe {
        rank: usize,
        sent: bool,
        parked: bool,
        closed: bool,
        order: Vec<u32>,
    }

    impl Probe {
        fn machines() -> Vec<Box<dyn Protocol>> {
            (0..3)
                .map(|rank| {
                    Box::new(Probe {
                        rank,
                        sent: false,
                        parked: false,
                        closed: false,
                        order: Vec::new(),
                    }) as Box<dyn Protocol>
                })
                .collect()
        }
    }

    impl Protocol for Probe {
        fn rank(&self) -> usize {
            self.rank
        }

        fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
            if self.rank != 0 && !self.sent {
                self.sent = true;
                // rank 1: 500-entry frame; rank 2: 1-entry frame.
                let nnz = if self.rank == 1 { 500 } else { 1 };
                let t = CooTensor::from_sorted(
                    1 << 16,
                    (0..nnz as u32).collect(),
                    vec![self.rank as f32; nnz],
                );
                return Ok(Event::Send {
                    dst: 0,
                    msg: Message::PushCoo {
                        from: self.rank as u32,
                        tensor: t,
                    },
                });
            }
            if self.rank == 0 && self.order.len() < 2 {
                return Ok(Event::NeedFrame { src: 1 });
            }
            if !self.parked {
                self.parked = true;
                return Ok(Event::StageDone { name: "probe" });
            }
            assert!(self.closed);
            let out = CooTensor::from_sorted(
                8,
                (0..self.order.len() as u32).collect(),
                self.order.iter().map(|&s| s as f32).collect(),
            );
            Ok(Event::Complete(out))
        }

        fn deliver(&mut self, src: usize, _msg: Message) -> Result<(), WireError> {
            assert_eq!(self.rank, 0);
            self.order.push(src as u32);
            Ok(())
        }

        fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
            assert_eq!(name, "probe");
            self.closed = true;
            Ok(())
        }
    }

    #[test]
    fn shared_receive_link_serializes_in_flight_frames() {
        let net = Network::new(3, LinkKind::Tcp25);
        let mut ev = EventDriver::new(net);
        let got = ev.drive(Probe::machines(), &mut SyncScratch::new()).unwrap();
        // Contention-aware order: rank 1's big frame first. Without the
        // rx-horizon the 1-entry frame would overtake it.
        assert_eq!(got.outputs[0].values, vec![1.0, 2.0]);
    }

    #[test]
    fn totals_only_mode_accumulates_without_stage_reports() {
        let net = Network::new(4, LinkKind::Tcp25);
        let mut full = EventDriver::new(net.clone());
        let report = full
            .drive(RingSwap::machines(4), &mut SyncScratch::new())
            .unwrap()
            .report;
        let mut lite = EventDriver::new(net).totals_only();
        let out = lite
            .drive(RingSwap::machines(4), &mut SyncScratch::new())
            .unwrap();
        assert!(out.report.stages.is_empty(), "totals mode skips reports");
        let t = lite.totals();
        assert_eq!(t.stages, 1);
        assert_eq!(t.bytes_by_class, report.bytes_by_class());
        assert_eq!(t.time, report.comm_time());
        assert_eq!(lite.virtual_time(), full.virtual_time());
        assert!(lite.events_processed() == 4 && lite.pool_high_water() >= 1);
    }

    #[test]
    fn machine_count_mismatch_is_an_error() {
        let net = Network::new(5, LinkKind::Tcp25);
        let mut ev = EventDriver::new(net);
        let err = ev
            .drive(RingSwap::machines(4), &mut SyncScratch::new())
            .expect_err("4 machines on 5 endpoints");
        assert!(matches!(err, WireError::Malformed(_)));
    }

    #[test]
    fn full_scheme_parity_against_run_sim() {
        // A real scheme end to end: outputs bit-identical, per-stage
        // bytes and times exact, on flat and two-level topologies.
        for machines in [3usize, 4] {
            let inputs = random_uniform_inputs(0xe7e ^ machines as u64, machines, 2_000, 0.05);
            let nnz = inputs[0].nnz().max(8);
            for name in ["zen", "agsparse", "sparseps"] {
                let scheme = schemes::by_name(name, machines, 0x7ace, nnz).unwrap();
                let net = Network::new(machines, LinkKind::Tcp25);
                let want = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
                let mut ev = EventDriver::new(net);
                let got = scheme
                    .run(&inputs, &mut ev, &mut SyncScratch::new())
                    .unwrap();
                verify_outputs(&got, &inputs);
                assert_eq!(got.outputs, want.outputs, "{name} n={machines}");
                assert_eq!(
                    got.report.stages.len(),
                    want.report.stages.len(),
                    "{name} n={machines}"
                );
                for (s, c) in want.report.stages.iter().zip(got.report.stages.iter()) {
                    assert_eq!(s.sent, c.sent, "{name} n={machines} stage {}", s.name);
                    assert_eq!(s.recv, c.recv, "{name} n={machines} stage {}", s.name);
                    assert_eq!(s.time, c.time, "{name} n={machines} stage {}", s.name);
                }
                assert_eq!(ev.virtual_time(), want.report.comm_time(), "{name}");
            }
        }
    }
}
