//! Sans-IO protocol cores: per-rank state machines under every scheme.
//!
//! A synchronization scheme used to run *all* endpoints inside one
//! `sync_transport` body that called blocking `send`/`recv` in global
//! order — correct on an in-process transport, impossible to deploy on
//! a real network where each rank owns only its endpoint. Following the
//! sans-IO split (protocol cores compute events; IO shells move bytes),
//! each scheme now builds one [`Protocol`] state machine per rank. A
//! machine never performs IO: the driver polls it, the machine answers
//! with an [`Event`], and delivered frames are handed back through
//! [`Protocol::deliver`].
//!
//! ## Event vocabulary
//!
//! - [`Event::Send`] — the machine wants a frame on the wire. The
//!   driver transmits it and re-polls; a machine emits every send of a
//!   stage through successive polls.
//! - [`Event::NeedFrame`] — the machine is parked waiting for a frame
//!   from `src` it knows must arrive (deterministic-count protocols:
//!   Zen's `n−1` pushes, a ring neighbor's chunk). The driver re-polls
//!   it after the next delivery.
//! - [`Event::StageDone`] — the machine finished its part of the named
//!   synchronous stage. When *every* machine is parked on the same
//!   stage name and every sent frame is delivered, the driver closes
//!   the stage (charging its α–β time) and calls
//!   [`Protocol::stage_closed`] on each machine.
//! - [`Event::Complete`] — the machine's final aggregate; it will not
//!   be polled again.
//!
//! ## Machine lifecycle contract
//!
//! Stages are globally synchronous and identically named across ranks
//! (rank sequences never diverge — idle ranks still emit `StageDone`).
//! Within a stage a machine first emits all its sends, then either
//! consumes a known number of frames (parking on `NeedFrame` until they
//! arrive) or parks on `StageDone` immediately and consumes its whole
//! inbox after `stage_closed` — the latter is how the
//! receive-until-stage-closed schemes (SparsePS, OmniReduce, the
//! strawman) handle data-dependent frame counts (empty shards are never
//! sent). Frames are buffered per source ([`Inbox`]) and consumed in
//! ascending-source order, which reproduces the old orchestrated
//! global-FIFO merge order on every backend — the per-stage byte parity
//! and bit-identical outputs the transport-parity suite pins.

use std::collections::VecDeque;

use super::codec::{Message, WireError};
use crate::schemes::SyncScratch;
use crate::tensor::CooTensor;

/// What a protocol machine wants next (see the module docs for the
/// lifecycle contract).
#[derive(Debug)]
pub enum Event {
    /// Put `msg` on the wire to rank `dst`.
    Send { dst: usize, msg: Message },
    /// Parked: progress needs a frame from `src`.
    NeedFrame { src: usize },
    /// Parked: this rank's part of stage `name` is finished.
    StageDone { name: &'static str },
    /// The protocol is finished; this is the rank's aggregate.
    Complete(CooTensor),
}

/// One rank's sans-IO state machine for one synchronization.
///
/// Machines are built by
/// [`SyncScheme::protocols`](crate::schemes::SyncScheme::protocols) and
/// driven by a [`Driver`](crate::wire::Driver); they borrow the
/// scheme's inputs (and the scheme itself) for the duration of the
/// sync. The shared [`SyncScratch`] is passed into every poll; machines
/// may use it only transiently within a poll *or* through the per-rank
/// slot convention (`scratch.partitions[rank]` belongs to machine
/// `rank` for the whole sync).
///
/// Machines are `Send`: a driver may move each machine onto its own OS
/// thread ([`ThreadedDriver`](crate::wire::ThreadedDriver)) — the state
/// a machine borrows from its scheme is shared read-only (`SyncScheme`
/// is `Sync`), so the bound costs implementors nothing.
pub trait Protocol: Send {
    /// The rank this machine plays.
    fn rank(&self) -> usize;

    /// Advance until the next event. Never blocks; `Err` is a wire-level
    /// failure (malformed frame), protocol violations panic.
    fn poll(&mut self, scratch: &mut SyncScratch) -> Result<Event, WireError>;

    /// Hand the machine a frame that arrived from `src`.
    fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError>;

    /// The stage every rank reported done is now closed: all its frames
    /// are delivered and its time is charged. The machine may advance
    /// past the stage boundary on its next poll.
    fn stage_closed(&mut self, name: &str) -> Result<(), WireError>;
}

/// Per-source frame buffer every machine owns: frames are pushed in
/// arrival order (per-source FIFO, which every backend preserves) and
/// consumed either per-source ([`take_from`](Inbox::take_from)) or in
/// ascending-source order ([`drain_ascending`](Inbox::drain_ascending))
/// — the deterministic merge order that makes outputs bit-identical
/// across sim, channel, and socket backends.
#[derive(Debug)]
pub struct Inbox {
    slots: Vec<VecDeque<Message>>,
    len: usize,
}

impl Inbox {
    pub fn new(n: usize) -> Inbox {
        Inbox {
            slots: (0..n).map(|_| VecDeque::new()).collect(),
            len: 0,
        }
    }

    /// Buffer a frame from `src`.
    pub fn push(&mut self, src: usize, msg: Message) {
        self.slots[src].push_back(msg);
        self.len += 1;
    }

    /// Total buffered frames.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Buffered frames from one source.
    pub fn from_src(&self, src: usize) -> usize {
        self.slots[src].len()
    }

    /// Pop the oldest frame from `src`, if any.
    pub fn take_from(&mut self, src: usize) -> Option<Message> {
        let msg = self.slots[src].pop_front();
        if msg.is_some() {
            self.len -= 1;
        }
        msg
    }

    /// Drain every buffered frame in ascending-source order (FIFO within
    /// a source).
    pub fn drain_ascending(&mut self) -> Vec<(usize, Message)> {
        let mut out = Vec::with_capacity(self.len);
        for (src, q) in self.slots.iter_mut().enumerate() {
            while let Some(msg) = q.pop_front() {
                out.push((src, msg));
            }
        }
        self.len = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inbox_orders_by_source_then_fifo() {
        let mut inbox = Inbox::new(3);
        inbox.push(2, Message::Barrier { epoch: 20 });
        inbox.push(0, Message::Barrier { epoch: 1 });
        inbox.push(2, Message::Barrier { epoch: 21 });
        assert_eq!(inbox.len(), 3);
        assert_eq!(inbox.from_src(2), 2);
        let drained = inbox.drain_ascending();
        assert_eq!(
            drained,
            vec![
                (0, Message::Barrier { epoch: 1 }),
                (2, Message::Barrier { epoch: 20 }),
                (2, Message::Barrier { epoch: 21 }),
            ]
        );
        assert!(inbox.is_empty());
    }

    #[test]
    fn inbox_take_from_is_per_source_fifo() {
        let mut inbox = Inbox::new(2);
        inbox.push(1, Message::Barrier { epoch: 5 });
        inbox.push(1, Message::Barrier { epoch: 6 });
        assert_eq!(inbox.take_from(0), None);
        assert_eq!(inbox.take_from(1), Some(Message::Barrier { epoch: 5 }));
        assert_eq!(inbox.take_from(1), Some(Message::Barrier { epoch: 6 }));
        assert_eq!(inbox.len(), 0);
    }
}
