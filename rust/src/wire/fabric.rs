//! In-process message fabric: one mailbox per endpoint, mpsc channels,
//! per-endpoint byte counters.
//!
//! This is the byte-moving substrate of
//! [`ChannelTransport`](crate::wire::ChannelTransport): worker threads
//! (or a single orchestrating thread) exchange real encoded frames. The
//! byte counters must agree with the transport-observed accounting of
//! [`crate::schemes`] (asserted by the wire/parity integration tests),
//! and `Fabric::execute_zen_push_pull` runs Zen's full
//! push/aggregate/pull round with one real thread per endpoint as a
//! reference deployment of the protocol.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use super::codec::{Decode, Encode, Message, WireError};
use crate::hashing::{HashBitmapCodec, HierarchicalHasher};
use crate::tensor::CooTensor;

/// Shared byte counters per endpoint.
#[derive(Debug, Default)]
pub struct Counters {
    pub sent: AtomicU64,
    pub recv: AtomicU64,
}

/// One endpoint's handle: its inbox + senders to everyone.
pub struct Endpoint {
    pub id: usize,
    inbox: Receiver<Vec<u8>>,
    peers: Vec<Sender<Vec<u8>>>,
    counters: Arc<Vec<Counters>>,
}

impl Endpoint {
    /// Encode and send a message to `dst`.
    pub fn send(&self, dst: usize, msg: &Message) -> Result<(), WireError> {
        let mut buf = Vec::with_capacity(msg.encoded_len());
        msg.encode(&mut buf);
        self.send_owned(dst, buf)
    }

    /// Send an already-encoded frame to `dst`, transferring ownership of
    /// the buffer into the channel (the transport layer's entry point —
    /// one encode, one move, no re-copy).
    pub fn send_owned(&self, dst: usize, frame: Vec<u8>) -> Result<(), WireError> {
        let len = frame.len() as u64;
        self.peers
            .get(dst)
            .ok_or(WireError::Disconnected)?
            .send(frame)
            .map_err(|_| WireError::Disconnected)?;
        self.counters[self.id].sent.fetch_add(len, Ordering::Relaxed);
        self.counters[dst].recv.fetch_add(len, Ordering::Relaxed);
        Ok(())
    }

    /// Block until one message arrives; decode it. Fails with
    /// [`WireError::Disconnected`] once every sender to this inbox is
    /// gone.
    pub fn recv(&self) -> Result<Message, WireError> {
        let buf = self.inbox.recv().map_err(|_| WireError::Disconnected)?;
        let (msg, _) = Message::decode(&buf)?;
        Ok(msg)
    }

    /// Non-blocking receive: `Ok(None)` when the inbox is currently
    /// empty, [`WireError::Disconnected`] when every sender is gone.
    pub fn try_recv(&self) -> Result<Option<Message>, WireError> {
        use std::sync::mpsc::TryRecvError;
        match self.inbox.try_recv() {
            Ok(buf) => {
                let (msg, _) = Message::decode(&buf)?;
                Ok(Some(msg))
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(WireError::Disconnected),
        }
    }

    /// Receive exactly `n` messages.
    pub fn recv_n(&self, n: usize) -> Result<Vec<Message>, WireError> {
        (0..n).map(|_| self.recv()).collect()
    }

    /// Drop this endpoint's senders: subsequent `send`s fail with
    /// [`WireError::Disconnected`], and peers whose every other sender is
    /// also gone observe `Disconnected` on `recv`.
    pub fn disconnect(&mut self) {
        self.peers.clear();
    }
}

/// The fabric: constructs all endpoints and owns the counters.
pub struct Fabric {
    pub n: usize,
    counters: Arc<Vec<Counters>>,
}

impl Fabric {
    /// Build a fully connected fabric of `n` endpoints.
    pub fn new(n: usize) -> (Fabric, Vec<Endpoint>) {
        let counters: Arc<Vec<Counters>> =
            Arc::new((0..n).map(|_| Counters::default()).collect());
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<Vec<u8>>();
            senders.push(tx);
            receivers.push(rx);
        }
        let endpoints = receivers
            .into_iter()
            .enumerate()
            .map(|(id, inbox)| Endpoint {
                id,
                inbox,
                peers: senders.clone(),
                counters: counters.clone(),
            })
            .collect();
        (Fabric { n, counters }, endpoints)
    }

    pub fn sent_bytes(&self, endpoint: usize) -> u64 {
        self.counters[endpoint].sent.load(Ordering::Relaxed)
    }

    pub fn recv_bytes(&self, endpoint: usize) -> u64 {
        self.counters[endpoint].recv.load(Ordering::Relaxed)
    }

    pub fn total_bytes(&self) -> u64 {
        (0..self.n).map(|e| self.sent_bytes(e)).sum()
    }

    /// Execute Zen's push/aggregate/pull protocol over the real fabric:
    /// every endpoint is both worker and server. Returns each worker's
    /// aggregated tensor. This is the reference deployment of the
    /// protocol the analytic scheme models.
    // Reference harness: any wire error here is a bug in the protocol
    // itself, and the scope join turns the panic into a test failure —
    // unwrap-to-panic is the intended behavior, not missing handling.
    #[allow(clippy::unwrap_used)]
    pub fn execute_zen_push_pull(
        endpoints: Vec<Endpoint>,
        inputs: Vec<CooTensor>,
        hasher: &HierarchicalHasher,
    ) -> Vec<CooTensor> {
        let n = endpoints.len();
        assert_eq!(inputs.len(), n);
        assert_eq!(hasher.n, n);
        let dense_len = inputs[0].dense_len;
        let domains = Arc::new(hasher.partition_domains(dense_len));

        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(n);
            for (ep, tensor) in endpoints.into_iter().zip(inputs.into_iter()) {
                let domains = domains.clone();
                let hasher = hasher.clone();
                handles.push(s.spawn(move || {
                    let me = ep.id;
                    // -- Push: partition and send shard p to server p.
                    let parts = hasher.partition(&tensor).parts;
                    let mut own_shard = None;
                    for (p, part) in parts.into_iter().enumerate() {
                        if p == me {
                            own_shard = Some(part);
                        } else {
                            ep.send(
                                p,
                                &Message::PushCoo {
                                    from: u32::try_from(me).unwrap(),
                                    tensor: part,
                                },
                            )
                            .unwrap();
                        }
                    }
                    // -- Server role: receive n-1 shards, aggregate.
                    // A fast peer may already be in its Pull phase, so
                    // out-of-phase Pull messages are stashed, not errors.
                    let mut shards = vec![own_shard.unwrap()];
                    let mut stashed_pulls = Vec::new();
                    while shards.len() < n {
                        match ep.recv().unwrap() {
                            Message::PushCoo { tensor, .. } => shards.push(tensor),
                            pull @ Message::PullHashBitmap { .. } => stashed_pulls.push(pull),
                            other => panic!("unexpected during push: {other:?}"),
                        }
                    }
                    let aggregated = CooTensor::merge_all(&shards);
                    // -- Pull: broadcast my aggregate as a hash bitmap.
                    let codec = HashBitmapCodec::new(&domains[me]);
                    let payload = codec.encode(&aggregated);
                    for w in 0..n {
                        if w != me {
                            ep.send(
                                w,
                                &Message::PullHashBitmap {
                                    server: u32::try_from(me).unwrap(),
                                    bitmap: payload.bitmap.clone(),
                                    values: payload.values.clone(),
                                },
                            )
                            .unwrap();
                        }
                    }
                    // -- Worker role: decode n-1 pulls + my own
                    // (stashed ones first, then the channel).
                    let mut pieces = vec![aggregated];
                    let decode_pull = |msg: Message, pieces: &mut Vec<CooTensor>| match msg {
                        Message::PullHashBitmap {
                            server,
                            bitmap,
                            values,
                        } => {
                            let codec = HashBitmapCodec::new(&domains[server as usize]);
                            let payload =
                                crate::hashing::hashbitmap::HashBitmapPayload { bitmap, values };
                            pieces.push(codec.decode(&payload, dense_len));
                        }
                        other => panic!("unexpected during pull: {other:?}"),
                    };
                    let stashed = stashed_pulls.len();
                    for msg in stashed_pulls {
                        decode_pull(msg, &mut pieces);
                    }
                    for _ in 0..(n - 1 - stashed) {
                        let msg = ep.recv().unwrap();
                        decode_pull(msg, &mut pieces);
                    }
                    CooTensor::merge_all(&pieces)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

    use super::*;

    #[test]
    fn point_to_point_delivery() {
        let (fabric, eps) = Fabric::new(2);
        let m = Message::Barrier { epoch: 9 };
        eps[0].send(1, &m).unwrap();
        assert_eq!(eps[1].recv().unwrap(), m);
        assert!(fabric.sent_bytes(0) > 0);
        assert_eq!(fabric.sent_bytes(0), fabric.recv_bytes(1));
    }

    #[test]
    fn counters_accumulate() {
        let (fabric, eps) = Fabric::new(3);
        for _ in 0..5 {
            eps[0].send(2, &Message::Barrier { epoch: 0 }).unwrap();
        }
        let one = Message::Barrier { epoch: 0 }.encoded_len() as u64;
        assert_eq!(fabric.sent_bytes(0), 5 * one);
        assert_eq!(fabric.recv_bytes(2), 5 * one);
        assert_eq!(fabric.recv_bytes(1), 0);
    }

    #[test]
    fn hung_up_peer_is_disconnected_not_malformed() {
        let (_fabric, mut eps) = Fabric::new(2);
        let gone = eps.remove(1);
        drop(gone);
        let err = eps[0].send(1, &Message::Barrier { epoch: 0 }).unwrap_err();
        assert_eq!(err, WireError::Disconnected);
        assert!(err.to_string().contains("disconnected"));
    }

    #[test]
    fn disconnect_tears_down_both_directions() {
        let (_fabric, mut eps) = Fabric::new(2);
        // Sever every sender: both explicit disconnects, so endpoint 0's
        // inbox has no live senders left.
        eps[0].disconnect();
        eps[1].disconnect();
        assert_eq!(
            eps[0].send(1, &Message::Barrier { epoch: 0 }),
            Err(WireError::Disconnected)
        );
        assert_eq!(eps[0].recv(), Err(WireError::Disconnected));
        assert_eq!(eps[0].try_recv(), Err(WireError::Disconnected));
    }

    #[test]
    fn try_recv_empty_vs_delivered() {
        let (_fabric, eps) = Fabric::new(2);
        assert_eq!(eps[1].try_recv().unwrap(), None);
        eps[0].send(1, &Message::Barrier { epoch: 5 }).unwrap();
        assert_eq!(
            eps[1].try_recv().unwrap(),
            Some(Message::Barrier { epoch: 5 })
        );
        assert_eq!(eps[1].try_recv().unwrap(), None);
    }

    #[test]
    fn zen_protocol_over_real_fabric() {
        use crate::util::Pcg64;
        let n = 4;
        let dense_len = 5_000;
        let mut rng = Pcg64::seeded(3);
        let inputs: Vec<CooTensor> = (0..n)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_distinct(dense_len, 400)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                idx.sort_unstable();
                CooTensor::from_sorted(dense_len, idx, vec![1.0; 400])
            })
            .collect();
        let hasher = HierarchicalHasher::with_defaults(11, n, 400);
        let (fabric, eps) = Fabric::new(n);
        let outputs = Fabric::execute_zen_push_pull(eps, inputs.clone(), &hasher);
        // every endpoint ends with the exact reference aggregation
        let reference = crate::schemes::reference_sum(&inputs);
        for out in &outputs {
            assert_eq!(out.to_dense(), reference);
        }
        assert!(fabric.total_bytes() > 0);
    }
}
