//! Scalar fallback kernels — the element-at-a-time ground truth.
//!
//! These are the loops the hot paths ran before the kernel layer
//! existed, moved here verbatim. [`super::chunked`] must match them
//! bit-for-bit (`tests/kernel_parity.rs`); select them crate-wide with
//! the `scalar_kernels` Cargo feature.

/// Bitwise OR of `src` into `dst`, word by word (bitmap set union).
/// Panics if the word counts differ.
pub fn or_words(dst: &mut [u64], src: &[u64]) {
    assert_eq!(dst.len(), src.len());
    for (a, b) in dst.iter_mut().zip(src.iter()) {
        *a |= *b;
    }
}

/// Population count of the word-wise AND (bitmap overlap cardinality).
/// Panics if the word counts differ.
pub fn and_count_words(a: &[u64], b: &[u64]) -> usize {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x & y).count_ones() as usize)
        .sum()
}

/// Total population count of a word array.
pub fn count_ones_words(words: &[u64]) -> usize {
    words.iter().map(|w| w.count_ones() as usize).sum()
}

/// Linear merge of two strictly-ascending (index, value) sequences into
/// caller-owned output buffers; values at equal indices are summed.
/// Appends (never clears) — the caller reserves capacity, so with
/// warmed buffers this performs no allocation.
pub fn merge_sorted(
    a_idx: &[u32],
    a_val: &[f32],
    b_idx: &[u32],
    b_val: &[f32],
    out_idx: &mut Vec<u32>,
    out_val: &mut Vec<f32>,
) {
    debug_assert_eq!(a_idx.len(), a_val.len());
    debug_assert_eq!(b_idx.len(), b_val.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a_idx.len() && j < b_idx.len() {
        match a_idx[i].cmp(&b_idx[j]) {
            std::cmp::Ordering::Less => {
                out_idx.push(a_idx[i]);
                out_val.push(a_val[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out_idx.push(b_idx[j]);
                out_val.push(b_val[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                out_idx.push(a_idx[i]);
                out_val.push(a_val[i] + b_val[j]);
                i += 1;
                j += 1;
            }
        }
    }
    out_idx.extend_from_slice(&a_idx[i..]);
    out_val.extend_from_slice(&a_val[i..]);
    out_idx.extend_from_slice(&b_idx[j..]);
    out_val.extend_from_slice(&b_val[j..]);
}

/// One radix counting pass: overwrite `counts` with the tally of
/// `(key >> shift) & 0xFF` over all keys. The caller does not need to
/// zero `counts` first.
pub fn histogram_u8(keys: &[u32], shift: u32, counts: &mut [u32; 256]) {
    counts.fill(0);
    for &k in keys {
        counts[((k >> shift) & 0xFF) as usize] += 1;
    }
}

/// Advance a cursor through a strictly-ascending `domain` from `start`
/// to the first position whose entry is `>= idx` (or `domain.len()`).
/// The hash-bitmap encoder's domain-merge step: successive calls with
/// ascending `idx` make one linear scan overall.
pub fn domain_rank(domain: &[u32], start: usize, idx: u32) -> usize {
    let mut d = start;
    while d < domain.len() && domain[d] < idx {
        d += 1;
    }
    d
}

/// Hash-partition scatter (Algorithm 1 phase 1): visit every
/// (index, value) pair in order with its partition id `pid(index)`.
/// The sink sees pairs in exactly the input order.
pub fn partition_scatter<P, F>(pid: P, indices: &[u32], values: &[f32], mut sink: F)
where
    P: Fn(u32) -> usize,
    F: FnMut(usize, u32, f32),
{
    debug_assert_eq!(indices.len(), values.len());
    for (&idx, &val) in indices.iter().zip(values.iter()) {
        sink(pid(idx), idx, val);
    }
}

/// Positions (ascending) of the `k` largest-magnitude values, appended
/// into a caller-reserved buffer — heap-free partial selection for the
/// Top-k compressor. Ties on magnitude break toward the lower position,
/// so the selection is a pure function of the input. `k = 0` selects
/// nothing; `k >= len` selects every position.
///
/// The magnitude key is `v.abs().to_bits()`: for non-negative floats
/// the IEEE-754 bit pattern orders exactly like the value, so the
/// selection runs entirely in integer arithmetic — an MSB-first radix
/// refinement (four 8-bit passes over a 256-counter histogram, each
/// restricted to the high-bit prefix fixed so far) pins down the k-th
/// largest key and the rank within its tie class, then one ascending
/// scan emits the selected positions. No sorting, no heap, no
/// allocation beyond the caller's output pushes.
pub fn select_topk(values: &[f32], k: usize, out: &mut Vec<u32>) {
    let n = values.len();
    if k == 0 {
        return;
    }
    if k >= n {
        out.extend(0..n as u32);
        return;
    }
    // Refinement state: the top `8·pass` bits of the k-th largest key,
    // and the rank still to place inside that prefix class.
    let mut prefix: u32 = 0;
    let mut remaining = k as u32;
    for pass in 0..4u32 {
        let shift = 24 - 8 * pass;
        let mut counts = [0u32; 256];
        for &v in values {
            let kb = v.abs().to_bits();
            if pass == 0 || (kb >> (shift + 8)) == prefix {
                counts[((kb >> shift) & 0xFF) as usize] += 1;
            }
        }
        let mut digit = 255usize;
        loop {
            let c = counts[digit];
            if remaining <= c {
                prefix = (prefix << 8) | digit as u32;
                break;
            }
            remaining -= c;
            debug_assert!(digit > 0, "rank exceeds prefix-class population");
            digit -= 1;
        }
    }
    // `prefix` is now the full k-th largest key; `remaining` is how many
    // of the keys equal to it are selected (lowest positions first).
    let threshold = prefix;
    let mut take_eq = remaining;
    for (i, &v) in values.iter().enumerate() {
        let kb = v.abs().to_bits();
        if kb > threshold {
            out.push(i as u32);
        } else if kb == threshold && take_eq > 0 {
            take_eq -= 1;
            out.push(i as u32);
        }
    }
}
