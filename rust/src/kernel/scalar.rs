//! Scalar fallback kernels — the element-at-a-time ground truth.
//!
//! These are the loops the hot paths ran before the kernel layer
//! existed, moved here verbatim. [`super::chunked`] must match them
//! bit-for-bit (`tests/kernel_parity.rs`); select them crate-wide with
//! the `scalar_kernels` Cargo feature.

/// Bitwise OR of `src` into `dst`, word by word (bitmap set union).
/// Panics if the word counts differ.
pub fn or_words(dst: &mut [u64], src: &[u64]) {
    assert_eq!(dst.len(), src.len());
    for (a, b) in dst.iter_mut().zip(src.iter()) {
        *a |= *b;
    }
}

/// Population count of the word-wise AND (bitmap overlap cardinality).
/// Panics if the word counts differ.
pub fn and_count_words(a: &[u64], b: &[u64]) -> usize {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x & y).count_ones() as usize)
        .sum()
}

/// Total population count of a word array.
pub fn count_ones_words(words: &[u64]) -> usize {
    words.iter().map(|w| w.count_ones() as usize).sum()
}

/// Linear merge of two strictly-ascending (index, value) sequences into
/// caller-owned output buffers; values at equal indices are summed.
/// Appends (never clears) — the caller reserves capacity, so with
/// warmed buffers this performs no allocation.
pub fn merge_sorted(
    a_idx: &[u32],
    a_val: &[f32],
    b_idx: &[u32],
    b_val: &[f32],
    out_idx: &mut Vec<u32>,
    out_val: &mut Vec<f32>,
) {
    debug_assert_eq!(a_idx.len(), a_val.len());
    debug_assert_eq!(b_idx.len(), b_val.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a_idx.len() && j < b_idx.len() {
        match a_idx[i].cmp(&b_idx[j]) {
            std::cmp::Ordering::Less => {
                out_idx.push(a_idx[i]);
                out_val.push(a_val[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out_idx.push(b_idx[j]);
                out_val.push(b_val[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                out_idx.push(a_idx[i]);
                out_val.push(a_val[i] + b_val[j]);
                i += 1;
                j += 1;
            }
        }
    }
    out_idx.extend_from_slice(&a_idx[i..]);
    out_val.extend_from_slice(&a_val[i..]);
    out_idx.extend_from_slice(&b_idx[j..]);
    out_val.extend_from_slice(&b_val[j..]);
}

/// One radix counting pass: overwrite `counts` with the tally of
/// `(key >> shift) & 0xFF` over all keys. The caller does not need to
/// zero `counts` first.
pub fn histogram_u8(keys: &[u32], shift: u32, counts: &mut [u32; 256]) {
    counts.fill(0);
    for &k in keys {
        counts[((k >> shift) & 0xFF) as usize] += 1;
    }
}

/// Advance a cursor through a strictly-ascending `domain` from `start`
/// to the first position whose entry is `>= idx` (or `domain.len()`).
/// The hash-bitmap encoder's domain-merge step: successive calls with
/// ascending `idx` make one linear scan overall.
pub fn domain_rank(domain: &[u32], start: usize, idx: u32) -> usize {
    let mut d = start;
    while d < domain.len() && domain[d] < idx {
        d += 1;
    }
    d
}

/// Hash-partition scatter (Algorithm 1 phase 1): visit every
/// (index, value) pair in order with its partition id `pid(index)`.
/// The sink sees pairs in exactly the input order.
pub fn partition_scatter<P, F>(pid: P, indices: &[u32], values: &[f32], mut sink: F)
where
    P: Fn(u32) -> usize,
    F: FnMut(usize, u32, f32),
{
    debug_assert_eq!(indices.len(), values.len());
    for (&idx, &val) in indices.iter().zip(values.iter()) {
        sink(pid(idx), idx, val);
    }
}
