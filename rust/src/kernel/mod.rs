//! Vectorized inner-loop kernels with scalar fallbacks.
//!
//! The sparse hot paths — bitmap union, sorted COO merge, the radix
//! histogram, the hash-partition scatter, and the domain-rank scan of
//! the hash-bitmap encoder — all reduce to a handful of tight loops
//! over flat arrays. This module hoists those loops out of their call
//! sites into named kernels with two interchangeable implementations:
//!
//! - [`scalar`]: the straightforward element-at-a-time loops (the
//!   pre-PR-8 code, kept verbatim as the semantic ground truth);
//! - [`chunked`]: explicit `u64x8`-style chunked forms — fixed-width
//!   [`LANES`]-element blocks over `chunks_exact`, per-lane partial
//!   accumulators, bulk-run fast paths, and split sub-histograms — the
//!   shapes LLVM reliably auto-vectorizes and pipelines on stable Rust
//!   (no `std::simd` dependency).
//!
//! **Selection is at compile time**: [`active`] aliases [`chunked`] by
//! default and [`scalar`] under the `scalar_kernels` Cargo feature.
//! Both modules are always compiled, so `tests/kernel_parity.rs` can
//! compare them function-by-function regardless of which one the rest
//! of the crate runs on.
//!
//! **Contract** (pinned by the parity suite): every chunked kernel is
//! bit-for-bit identical to its scalar fallback on all inputs — same
//! outputs, same visit order for callback kernels, same panics. The
//! chunked forms only ever reassociate *integer* reductions (bit
//! counts, histogram tallies), never floating-point arithmetic, so the
//! guarantee is exact, not approximate. All kernels are
//! allocation-free: temporaries are fixed-size stack arrays, and
//! `Vec`-filling kernels only `extend` into caller-reserved buffers —
//! the scratch-arena zero-allocation tests cover them unchanged.

pub mod chunked;
pub mod scalar;

/// Chunk width of the vectorized kernels: eight 64-bit lanes (a 512-bit
/// block — one AVX-512 register, two NEON/SSE pairs), matching the
/// `u64x8` shape the chunked forms are written around.
pub const LANES: usize = 8;

#[cfg(feature = "scalar_kernels")]
pub use scalar as active;

#[cfg(not(feature = "scalar_kernels"))]
pub use chunked as active;
