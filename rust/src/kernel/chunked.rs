//! Chunked (`u64x8`-style) kernels — the default implementations.
//!
//! Each kernel processes fixed [`LANES`]-wide blocks through
//! `chunks_exact`, with a scalar epilogue for the unaligned tail. The
//! block bodies are written so LLVM can vectorize them: no
//! loop-carried dependency inside a block (per-lane partial
//! accumulators, batched hash computation, split sub-histograms) and
//! branch-free lane operations. Integer reductions are reassociated
//! across lanes — which is exact — and floating-point arithmetic is
//! never reassociated, so every kernel is bit-for-bit identical to its
//! [`super::scalar`] fallback (pinned by `tests/kernel_parity.rs`).
//! No kernel allocates: temporaries are fixed-size stack arrays.

use super::LANES;

/// Bitwise OR of `src` into `dst` in 8-word blocks (bitmap set union).
/// Panics if the word counts differ.
pub fn or_words(dst: &mut [u64], src: &[u64]) {
    assert_eq!(dst.len(), src.len());
    let mut d = dst.chunks_exact_mut(LANES);
    let mut s = src.chunks_exact(LANES);
    for (db, sb) in (&mut d).zip(&mut s) {
        for k in 0..LANES {
            db[k] |= sb[k];
        }
    }
    for (a, b) in d.into_remainder().iter_mut().zip(s.remainder().iter()) {
        *a |= *b;
    }
}

/// Population count of the word-wise AND, with per-lane partial counts
/// summed at the end (integer reassociation — exact). Panics if the
/// word counts differ.
pub fn and_count_words(a: &[u64], b: &[u64]) -> usize {
    assert_eq!(a.len(), b.len());
    let mut lanes = [0usize; LANES];
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (ab, bb) in (&mut ac).zip(&mut bc) {
        for k in 0..LANES {
            lanes[k] += (ab[k] & bb[k]).count_ones() as usize;
        }
    }
    let mut total: usize = lanes.iter().sum();
    for (x, y) in ac.remainder().iter().zip(bc.remainder().iter()) {
        total += (x & y).count_ones() as usize;
    }
    total
}

/// Total population count, accumulated per lane.
pub fn count_ones_words(words: &[u64]) -> usize {
    let mut lanes = [0usize; LANES];
    let mut c = words.chunks_exact(LANES);
    for block in &mut c {
        for k in 0..LANES {
            lanes[k] += block[k].count_ones() as usize;
        }
    }
    let mut total: usize = lanes.iter().sum();
    for w in c.remainder() {
        total += w.count_ones() as usize;
    }
    total
}

/// Linear merge of two strictly-ascending (index, value) sequences with
/// a bulk-run fast path: whenever the next [`LANES`] keys of one side
/// all precede the other side's head key, they are copied in one
/// `extend_from_slice` instead of eight compare-branch iterations —
/// the common shape when worker supports barely overlap (low-density
/// gradients). Interleaved and equal-key regions fall back to the
/// scalar step, so output order and float summation order are exactly
/// the scalar kernel's. Appends into caller-reserved buffers.
pub fn merge_sorted(
    a_idx: &[u32],
    a_val: &[f32],
    b_idx: &[u32],
    b_val: &[f32],
    out_idx: &mut Vec<u32>,
    out_val: &mut Vec<f32>,
) {
    debug_assert_eq!(a_idx.len(), a_val.len());
    debug_assert_eq!(b_idx.len(), b_val.len());
    let (na, nb) = (a_idx.len(), b_idx.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < na && j < nb {
        if i + LANES <= na && a_idx[i + LANES - 1] < b_idx[j] {
            out_idx.extend_from_slice(&a_idx[i..i + LANES]);
            out_val.extend_from_slice(&a_val[i..i + LANES]);
            i += LANES;
            continue;
        }
        if j + LANES <= nb && b_idx[j + LANES - 1] < a_idx[i] {
            out_idx.extend_from_slice(&b_idx[j..j + LANES]);
            out_val.extend_from_slice(&b_val[j..j + LANES]);
            j += LANES;
            continue;
        }
        match a_idx[i].cmp(&b_idx[j]) {
            std::cmp::Ordering::Less => {
                out_idx.push(a_idx[i]);
                out_val.push(a_val[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out_idx.push(b_idx[j]);
                out_val.push(b_val[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                out_idx.push(a_idx[i]);
                out_val.push(a_val[i] + b_val[j]);
                i += 1;
                j += 1;
            }
        }
    }
    out_idx.extend_from_slice(&a_idx[i..]);
    out_val.extend_from_slice(&a_val[i..]);
    out_idx.extend_from_slice(&b_idx[j..]);
    out_val.extend_from_slice(&b_val[j..]);
}

/// Sub-tables of the split radix histogram: four independent 256-entry
/// tallies (4 KiB of stack) so consecutive keys hitting the same digit
/// don't serialize on one counter's store-to-load dependency.
const HIST_SPLIT: usize = 4;

/// One radix counting pass: overwrite `counts` with the tally of
/// `(key >> shift) & 0xFF` over all keys, accumulated in [`HIST_SPLIT`]
/// independent sub-tables and summed per digit (integer reassociation —
/// exact). The caller does not need to zero `counts` first.
pub fn histogram_u8(keys: &[u32], shift: u32, counts: &mut [u32; 256]) {
    let mut sub = [[0u32; 256]; HIST_SPLIT];
    let mut blocks = keys.chunks_exact(HIST_SPLIT);
    for block in &mut blocks {
        for (t, &k) in sub.iter_mut().zip(block.iter()) {
            t[((k >> shift) & 0xFF) as usize] += 1;
        }
    }
    for &k in blocks.remainder() {
        sub[0][((k >> shift) & 0xFF) as usize] += 1;
    }
    for (digit, c) in counts.iter_mut().enumerate() {
        *c = sub[0][digit] + sub[1][digit] + sub[2][digit] + sub[3][digit];
    }
}

/// Advance a cursor through a strictly-ascending `domain` to the first
/// position whose entry is `>= idx`, skipping [`LANES`] entries per
/// probe while the block's last key still precedes `idx` — one branch
/// per eight domain entries on the long gaps between sparse non-zeros
/// — then stepping the final block scalar-wise. Domain monotonicity
/// makes the skip exact: if `domain[d + LANES - 1] < idx`, every entry
/// of the block is `< idx`.
pub fn domain_rank(domain: &[u32], start: usize, idx: u32) -> usize {
    let mut d = start;
    while d + LANES <= domain.len() && domain[d + LANES - 1] < idx {
        d += LANES;
    }
    while d < domain.len() && domain[d] < idx {
        d += 1;
    }
    d
}

/// Hash-partition scatter: partition ids are computed [`LANES`] at a
/// time into a stack block — eight independent hash evaluations with no
/// interleaved stores, which unrolls and pipelines — before the sink
/// consumes the block in order. Visit order is exactly the input order,
/// matching the scalar kernel.
pub fn partition_scatter<P, F>(pid: P, indices: &[u32], values: &[f32], mut sink: F)
where
    P: Fn(u32) -> usize,
    F: FnMut(usize, u32, f32),
{
    debug_assert_eq!(indices.len(), values.len());
    let mut ic = indices.chunks_exact(LANES);
    let mut vc = values.chunks_exact(LANES);
    for (ib, vb) in (&mut ic).zip(&mut vc) {
        let mut pids = [0usize; LANES];
        for (p, &idx) in pids.iter_mut().zip(ib.iter()) {
            *p = pid(idx);
        }
        for k in 0..LANES {
            sink(pids[k], ib[k], vb[k]);
        }
    }
    for (&idx, &val) in ic.remainder().iter().zip(vc.remainder().iter()) {
        sink(pid(idx), idx, val);
    }
}

/// Positions (ascending) of the `k` largest-magnitude values — the
/// chunked form of the Top-k radix selection. The histogram passes
/// tally into [`HIST_SPLIT`] independent sub-tables (summed per digit;
/// integer reassociation — exact) and the final emission scan computes
/// magnitude keys [`LANES`] at a time into a stack block before
/// consuming them in order, so push order and lower-position tie-breaks
/// match the scalar kernel bit for bit.
pub fn select_topk(values: &[f32], k: usize, out: &mut Vec<u32>) {
    let n = values.len();
    if k == 0 {
        return;
    }
    if k >= n {
        out.extend(0..n as u32);
        return;
    }
    let mut prefix: u32 = 0;
    let mut remaining = k as u32;
    for pass in 0..4u32 {
        let shift = 24 - 8 * pass;
        let mut sub = [[0u32; 256]; HIST_SPLIT];
        let mut blocks = values.chunks_exact(HIST_SPLIT);
        for block in &mut blocks {
            for (t, &v) in sub.iter_mut().zip(block.iter()) {
                let kb = v.abs().to_bits();
                if pass == 0 || (kb >> (shift + 8)) == prefix {
                    t[((kb >> shift) & 0xFF) as usize] += 1;
                }
            }
        }
        for &v in blocks.remainder() {
            let kb = v.abs().to_bits();
            if pass == 0 || (kb >> (shift + 8)) == prefix {
                sub[0][((kb >> shift) & 0xFF) as usize] += 1;
            }
        }
        let mut digit = 255usize;
        loop {
            let c = sub[0][digit] + sub[1][digit] + sub[2][digit] + sub[3][digit];
            if remaining <= c {
                prefix = (prefix << 8) | digit as u32;
                break;
            }
            remaining -= c;
            debug_assert!(digit > 0, "rank exceeds prefix-class population");
            digit -= 1;
        }
    }
    let threshold = prefix;
    let mut take_eq = remaining;
    let mut vc = values.chunks_exact(LANES);
    let mut base = 0u32;
    for vb in &mut vc {
        let mut keys = [0u32; LANES];
        for (slot, &v) in keys.iter_mut().zip(vb.iter()) {
            *slot = v.abs().to_bits();
        }
        for (off, &kb) in keys.iter().enumerate() {
            if kb > threshold {
                out.push(base + off as u32);
            } else if kb == threshold && take_eq > 0 {
                take_eq -= 1;
                out.push(base + off as u32);
            }
        }
        base += LANES as u32;
    }
    for (off, &v) in vc.remainder().iter().enumerate() {
        let kb = v.abs().to_bits();
        if kb > threshold {
            out.push(base + off as u32);
        } else if kb == threshold && take_eq > 0 {
            take_eq -= 1;
            out.push(base + off as u32);
        }
    }
}
