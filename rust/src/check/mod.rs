//! `zen check` — exhaustive delivery-order model checking for the
//! sans-IO protocol layer.
//!
//! Every driver in [`crate::wire`] exercises exactly one frame-delivery
//! order per run, so interleaving bugs in the protocol machines are
//! invisible to the example-based suites. This module exploits the
//! sans-IO design to explore *all* of them: the
//! [`ScheduleDriver`](crate::wire::trace::ScheduleDriver) defers every
//! delivery into a pending matrix and records each point where more
//! than one source competed for a destination; [`check_scheme`] then
//! DFS-enumerates those branch points — replaying a schedule prefix and
//! continuing canonically — with stage-boundary state hashing so
//! delivery orders that converge to the same protocol state are
//! explored once (see the DPOR notes on [`crate::wire::trace`]).
//!
//! ## Invariants checked on every explored order
//!
//! - **No deadlock** — some machine can always make progress until all
//!   emit `Complete` ([`Violation::Deadlock`]).
//! - **No frame outlives its stage or its receiver** — the stage can
//!   only close with zero pending frames (enforced structurally and by
//!   `StageAcc`), and a frame sent to or still addressed to a finished
//!   machine is flagged ([`Violation::SentToFinished`],
//!   [`Violation::CompletedWithPending`]).
//! - **Byte conservation** — per stage, the bytes the trace delivered
//!   equal the sent and received totals `StageAcc` reported
//!   ([`Violation::StageError`]).
//! - **Bit-identical outputs** — every explored order must produce the
//!   same [`fnv_digest`] per endpoint as the canonical order
//!   ([`Violation::OutputDivergence`]).
//! - **Losslessness** — for lossless schemes the canonical outputs must
//!   equal the dense sum of the inputs within float tolerance (the
//!   `tests/properties.rs` oracle; [`Violation::OracleFailure`]).
//!
//! A violation yields a minimized, replayable counterexample: the
//! shortest schedule prefix whose canonical continuation reproduces the
//! same violation kind, printable as `src>dst,…` and re-runnable via
//! `zen check --replay`.

#![deny(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]

use std::collections::HashSet;

use crate::cluster::{CommReport, LinkKind, Network};
use crate::schemes::{self, SyncScheme, SyncScratch};
use crate::tensor::CooTensor;
use crate::util::Pcg64;
use crate::wire::trace::{
    fnv1a, mix3, schedule_string, RunRecord, ScheduleDriver, Violation,
};
use crate::wire::DriveOutcome;

/// Scheme variants `zen check --all` covers, with whether the lossless
/// sum oracle applies. The strawman deliberately loses colliding
/// gradients, so only determinism (bit-identical outputs across orders)
/// is required of it.
pub const CHECK_SCHEMES: [(&str, bool); 11] = [
    ("allreduce", true),
    ("agsparse", true),
    ("agsparse-ring", true),
    ("agsparse-hier", true),
    ("sparcml", true),
    ("sparseps", true),
    ("omnireduce", true),
    ("oktopk", true),
    ("zen", true),
    ("zen-coo", true),
    ("strawman:8", false),
];

/// Default schedule budget: far above what exhaustive n ∈ {2, 3}
/// exploration needs, a hard bound at larger n.
pub const DEFAULT_MAX_RUNS: usize = 20_000;

/// Exploration counters for one scheme × input-set check.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CheckStats {
    /// Schedules executed (each is one full protocol run).
    pub runs: usize,
    /// Frames delivered across all runs.
    pub deliveries: u64,
    /// Branch points encountered (a destination with ≥ 2 competing
    /// sources).
    pub choice_points: u64,
    /// Distinct stage-boundary states in the dedup cache.
    pub distinct_states: usize,
    /// Subtrees cut because their boundary state was already explored.
    pub pruned: u64,
    /// Peak DFS frontier (stack depth including the running schedule).
    pub max_frontier: usize,
    /// True when `max_runs` ended exploration before it was exhausted.
    pub truncated: bool,
}

/// A caught violation plus the minimized schedule that reproduces it.
#[derive(Clone, Debug)]
pub struct CheckFailure {
    pub violation: Violation,
    /// Shortest schedule prefix whose canonical continuation reproduces
    /// the violation kind (empty = the canonical order itself fails).
    pub schedule: Vec<(usize, usize)>,
}

impl CheckFailure {
    /// The `--replay` argument form of the counterexample.
    pub fn replay_arg(&self) -> String {
        schedule_string(&self.schedule)
    }
}

/// Result of exploring one scheme over one input set.
#[derive(Clone, Debug)]
pub struct CheckReport {
    pub scheme: String,
    pub n: usize,
    pub lossless: bool,
    /// Digest of the canonical order's outputs (the value every other
    /// order must reproduce bit-for-bit).
    pub output_digest: Option<u64>,
    pub stats: CheckStats,
    pub failure: Option<CheckFailure>,
}

impl CheckReport {
    pub fn ok(&self) -> bool {
        self.failure.is_none()
    }
}

/// FNV-1a fingerprint of one tensor (dense length, indices, value
/// bits) — the digest `zen worker` prints and the bit-identical-output
/// invariant compares.
pub fn fnv_digest(t: &CooTensor) -> u64 {
    let mut buf = Vec::with_capacity(8 + t.indices.len() * 8);
    buf.extend_from_slice(&(t.dense_len as u64).to_le_bytes());
    for &i in &t.indices {
        buf.extend_from_slice(&i.to_le_bytes());
    }
    for &v in &t.values {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    fnv1a(&buf)
}

/// Order-sensitive digest of all endpoint outputs of one run.
pub fn outputs_digest(outs: &[CooTensor]) -> u64 {
    let mut h = 0x6f75_7470_7574_7321;
    for (i, t) in outs.iter().enumerate() {
        h = mix3(h, i as u64, fnv_digest(t));
    }
    h
}

fn index_u32(v: u64) -> u32 {
    match u32::try_from(v) {
        Ok(x) => x,
        Err(_) => panic!("index {v} exceeds the u32 tensor index range"),
    }
}

/// Deterministic per-worker sparse gradients with a shared hot set plus
/// private tails — the §2.2 overlap structure in miniature. Shared by
/// `zen check`, `zen worker` (both sides derive identical inputs from
/// the seed), and the checker test suites.
pub fn gen_inputs(
    seed: u64,
    n: usize,
    dense_len: usize,
    shared: usize,
    private: usize,
) -> Vec<CooTensor> {
    let mut rng = Pcg64::seeded(seed);
    let hot: Vec<usize> = rng.sample_distinct(dense_len, shared);
    (0..n)
        .map(|w| {
            let mut idx: Vec<u32> = hot.iter().map(|&i| index_u32(i as u64)).collect();
            let mut priv_rng = Pcg64::new(seed ^ w as u64, 55);
            for _ in 0..private {
                idx.push(index_u32(priv_rng.below(dense_len as u64)));
            }
            idx.sort_unstable();
            idx.dedup();
            let vals: Vec<f32> = idx
                .iter()
                .map(|_| priv_rng.next_f32() * 2.0 - 1.0)
                .map(|v| if v == 0.0 { 0.5 } else { v })
                .collect();
            CooTensor::from_sorted(dense_len, idx, vals)
        })
        .collect()
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Execute one schedule (prefix prescribed, canonical past it) with
/// machine panics caught and classified. Returns the run outcome or the
/// first violation, plus the full record either way.
fn run_schedule(
    scheme: &dyn SyncScheme,
    inputs: &[CooTensor],
    net: &Network,
    prefix: &[(usize, usize)],
) -> (Result<DriveOutcome, Violation>, RunRecord) {
    let mut driver = ScheduleDriver::with_prefix(net.clone(), prefix.to_vec());
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut scratch = SyncScratch::new();
        driver.run_checked(scheme.protocols(inputs), &mut scratch)
    }));
    let record = driver.take_record();
    match res {
        Ok(Ok(outcome)) => (Ok(outcome), record),
        Ok(Err(v)) => (Err(v), record),
        Err(p) => (
            Err(Violation::MachinePanic {
                detail: panic_message(p),
            }),
            record,
        ),
    }
}

/// Per-stage byte conservation: the bytes the trace delivered must
/// equal the sent and received totals `StageAcc` reported.
fn conservation_violation(record: &RunRecord, report: &CommReport) -> Option<Violation> {
    if record.boundaries.len() != report.stages.len() {
        return Some(Violation::StageError {
            detail: format!(
                "{} recorded stage boundaries vs {} reported stages",
                record.boundaries.len(),
                report.stages.len()
            ),
        });
    }
    let mut from = 0usize;
    for (b, st) in record.boundaries.iter().zip(&report.stages) {
        let delivered: u64 = record.trace[from..b.step].iter().map(|d| d.bytes).sum();
        let sent: u64 = st.sent.iter().sum();
        let recv: u64 = st.recv.iter().sum();
        if delivered != sent || delivered != recv {
            return Some(Violation::StageError {
                detail: format!(
                    "stage '{}': trace delivered {delivered} B, report sent {sent} B / recv {recv} B",
                    b.name
                ),
            });
        }
        from = b.step;
    }
    None
}

/// The `tests/properties.rs` losslessness oracle as a closure-friendly
/// check: every endpoint's aggregate must equal the dense sum of the
/// inputs within float tolerance.
fn oracle_violation(outputs: &[CooTensor], inputs: &[CooTensor]) -> Option<Violation> {
    let reference = schemes::reference_sum(inputs);
    for (e, out) in outputs.iter().enumerate() {
        let d = out.to_dense();
        if d.len() != reference.len() {
            return Some(Violation::OracleFailure {
                detail: format!(
                    "endpoint {e}: dense length {} != reference {}",
                    d.len(),
                    reference.len()
                ),
            });
        }
        for i in 0..d.len() {
            let (a, b) = (d.values[i], reference.values[i]);
            if (a - b).abs() > 1e-4_f32.max(b.abs() * 1e-4) {
                return Some(Violation::OracleFailure {
                    detail: format!("endpoint {e}, index {i}: got {a}, reference {b}"),
                });
            }
        }
    }
    None
}

/// Shortest prefix of the failing trace whose canonical continuation
/// reproduces the same violation kind (linear scan from the front; the
/// full trace always reproduces, so this terminates with a match).
fn minimize_violation(
    scheme: &dyn SyncScheme,
    inputs: &[CooTensor],
    net: &Network,
    failing: &RunRecord,
    v: Violation,
) -> CheckFailure {
    let full = failing.schedule();
    for k in 0..=full.len() {
        let (res, _rec) = run_schedule(scheme, inputs, net, &full[..k]);
        if let Err(v2) = res {
            if v2.kind() == v.kind() {
                return CheckFailure {
                    violation: v2,
                    schedule: full[..k].to_vec(),
                };
            }
        }
    }
    CheckFailure {
        violation: v,
        schedule: full,
    }
}

/// Minimization for output-level violations (divergence from the
/// canonical digest, or oracle failure): the shortest prefix whose
/// canonical continuation completes with the same bad outputs.
fn minimize_outputs(
    scheme: &dyn SyncScheme,
    inputs: &[CooTensor],
    net: &Network,
    failing: &RunRecord,
    want_digest: Option<u64>,
    v: &Violation,
) -> CheckFailure {
    let full = failing.schedule();
    for k in 0..=full.len() {
        let (res, _rec) = run_schedule(scheme, inputs, net, &full[..k]);
        if let Ok(outcome) = res {
            let bad = match want_digest {
                Some(w) => outputs_digest(&outcome.outputs) != w,
                None => oracle_violation(&outcome.outputs, inputs).is_some(),
            };
            if bad {
                return CheckFailure {
                    violation: v.clone(),
                    schedule: full[..k].to_vec(),
                };
            }
        }
    }
    CheckFailure {
        violation: v.clone(),
        schedule: full,
    }
}

/// Explore the delivery orders of `scheme` over `inputs` up to
/// `max_runs` schedules: exhaustive when the budget suffices (it always
/// does at n ∈ {2, 3} with the default), bounded-depth beyond.
///
/// The DFS pops a schedule prefix, runs it with canonical continuation,
/// checks every invariant, dedupes on stage-boundary state hashes
/// (alternatives branching after an already-seen boundary are pruned —
/// the canonical continuation from that state was explored by its first
/// visitor), and pushes one new prefix per unexplored alternative
/// source at each choice point.
pub fn check_scheme(
    scheme: &dyn SyncScheme,
    inputs: &[CooTensor],
    lossless: bool,
    max_runs: usize,
) -> CheckReport {
    let n = inputs.len();
    let net = Network::new(n, LinkKind::Tcp25);
    let mut stats = CheckStats::default();
    let mut seen: HashSet<u64> = HashSet::new();
    let mut stack: Vec<Vec<(usize, usize)>> = vec![Vec::new()];
    let mut reference: Option<u64> = None;
    let mut failure: Option<CheckFailure> = None;

    while let Some(prefix) = stack.pop() {
        if stats.runs >= max_runs {
            stats.truncated = true;
            break;
        }
        stats.max_frontier = stats.max_frontier.max(stack.len() + 1);
        stats.runs += 1;
        let (res, record) = run_schedule(scheme, inputs, &net, &prefix);
        stats.deliveries += record.trace.len() as u64;
        stats.choice_points += record.choices.len() as u64;
        let outcome = match res {
            Err(v) => {
                failure = Some(minimize_violation(scheme, inputs, &net, &record, v));
                break;
            }
            Ok(o) => o,
        };
        if let Some(v) = conservation_violation(&record, &outcome.report) {
            failure = Some(CheckFailure {
                violation: v,
                schedule: record.schedule(),
            });
            break;
        }
        let digest = outputs_digest(&outcome.outputs);
        match reference {
            None => {
                reference = Some(digest);
                if lossless {
                    if let Some(v) = oracle_violation(&outcome.outputs, inputs) {
                        failure = Some(minimize_outputs(scheme, inputs, &net, &record, None, &v));
                        break;
                    }
                }
            }
            Some(want) if want != digest => {
                let v = Violation::OutputDivergence {
                    detail: format!("digest {digest:#018x} != canonical {want:#018x}"),
                };
                failure = Some(minimize_outputs(
                    scheme,
                    inputs,
                    &net,
                    &record,
                    Some(want),
                    &v,
                ));
                break;
            }
            // Same digest as a reference that already passed the
            // oracle → the outputs are bit-identical, nothing to
            // re-verify.
            Some(_) => {}
        }
        // Prune on revisited boundary states, then expand alternatives.
        // Dedup applies only to boundaries in the canonical region
        // (step ≥ prefix length): inside the prefix the continuation is
        // prescribed, so a state match there says nothing about what
        // was explored from it.
        let mut cutoff = usize::MAX;
        for (bi, b) in record.boundaries.iter().enumerate() {
            if b.step < prefix.len() {
                continue;
            }
            if !seen.insert(mix3(0x5eed, bi as u64, b.state_hash)) {
                cutoff = b.step;
                stats.pruned += 1;
                break;
            }
        }
        for cp in record.choices.iter().rev() {
            if cp.step >= cutoff {
                continue;
            }
            for &alt in &cp.alternatives {
                let mut p: Vec<(usize, usize)> = record.trace[..cp.step]
                    .iter()
                    .map(|d| (d.src, d.dst))
                    .collect();
                p.push((alt, cp.dst));
                stack.push(p);
            }
        }
    }
    stats.distinct_states = seen.len();
    CheckReport {
        scheme: scheme.name().to_string(),
        n,
        lossless,
        output_digest: reference,
        stats,
        failure,
    }
}

/// Re-run one explicit schedule under the same invariants the explorer
/// applies (conservation, optional expected digest, optional lossless
/// oracle). Returns the violation it produces, if any, plus the record.
pub fn replay_schedule(
    scheme: &dyn SyncScheme,
    inputs: &[CooTensor],
    lossless: bool,
    expect_digest: Option<u64>,
    schedule: &[(usize, usize)],
) -> (Option<Violation>, RunRecord) {
    let net = Network::new(inputs.len(), LinkKind::Tcp25);
    let (res, record) = run_schedule(scheme, inputs, &net, schedule);
    let v = match res {
        Err(v) => Some(v),
        Ok(outcome) => conservation_violation(&record, &outcome.report)
            .or_else(|| match expect_digest {
                Some(w) => {
                    let got = outputs_digest(&outcome.outputs);
                    if got != w {
                        Some(Violation::OutputDivergence {
                            detail: format!("digest {got:#018x} != expected {w:#018x}"),
                        })
                    } else {
                        None
                    }
                }
                None => None,
            })
            .or_else(|| {
                if lossless {
                    oracle_violation(&outcome.outputs, inputs)
                } else {
                    None
                }
            }),
    };
    (v, record)
}

/// Parse the `--replay` schedule form: `src>dst,src>dst,…`.
pub fn parse_schedule(s: &str) -> Result<Vec<(usize, usize)>, String> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let (a, b) = tok
            .split_once('>')
            .ok_or_else(|| format!("bad step '{tok}': want src>dst"))?;
        let src = a
            .trim()
            .parse::<usize>()
            .map_err(|e| format!("bad src in '{tok}': {e}"))?;
        let dst = b
            .trim()
            .parse::<usize>()
            .map_err(|e| format!("bad dst in '{tok}': {e}"))?;
        out.push((src, dst));
    }
    Ok(out)
}

/// One report as a JSON object (hand-rolled — no serde offline).
pub fn report_json(r: &CheckReport) -> String {
    let violation = match &r.failure {
        Some(f) => format!(
            "{{\"kind\":\"{}\",\"schedule\":\"{}\"}}",
            f.violation.kind(),
            f.replay_arg()
        ),
        None => "null".to_string(),
    };
    format!(
        "{{\"scheme\":\"{}\",\"n\":{},\"runs\":{},\"deliveries\":{},\"choice_points\":{},\
         \"distinct_states\":{},\"pruned\":{},\"max_frontier\":{},\"truncated\":{},\
         \"violation\":{}}}",
        r.scheme,
        r.n,
        r.stats.runs,
        r.stats.deliveries,
        r.stats.choice_points,
        r.stats.distinct_states,
        r.stats.pruned,
        r.stats.max_frontier,
        r.stats.truncated,
        violation
    )
}

/// The `BENCH_PR10.json` suite summary: states explored, states/sec,
/// max frontier, plus one object per scheme × n.
pub fn suite_json(reports: &[CheckReport], elapsed_secs: f64) -> String {
    let runs: usize = reports.iter().map(|r| r.stats.runs).sum();
    let deliveries: u64 = reports.iter().map(|r| r.stats.deliveries).sum();
    let states: usize = reports.iter().map(|r| r.stats.distinct_states).sum();
    let frontier: usize = reports
        .iter()
        .map(|r| r.stats.max_frontier)
        .max()
        .unwrap_or(0);
    let violations: usize = reports.iter().filter(|r| !r.ok()).count();
    let states_per_sec = if elapsed_secs > 0.0 {
        states as f64 / elapsed_secs
    } else {
        0.0
    };
    let entries: Vec<String> = reports.iter().map(report_json).collect();
    format!(
        "{{\"bench\":\"check\",\"states_explored\":{states},\"runs\":{runs},\
         \"deliveries\":{deliveries},\"states_per_sec\":{states_per_sec:.1},\
         \"max_frontier\":{frontier},\"elapsed_secs\":{elapsed_secs:.3},\
         \"violations\":{violations},\"schemes\":[{}]}}",
        entries.join(",")
    )
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used, clippy::cast_possible_truncation)]
mod tests {
    use super::*;

    fn inputs(n: usize) -> Vec<CooTensor> {
        gen_inputs(11, n, 48, 5, 3)
    }

    #[test]
    fn gen_inputs_is_deterministic_and_overlapping() {
        let a = inputs(3);
        let b = inputs(3);
        assert_eq!(a.len(), 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        // the shared hot set overlaps across workers
        let common: Vec<u32> = a[0]
            .indices
            .iter()
            .filter(|i| a[1].indices.contains(i))
            .copied()
            .collect();
        assert!(common.len() >= 5);
        assert!(a.iter().all(|t| !t.values.contains(&0.0)));
    }

    #[test]
    fn ring_scheme_has_a_single_delivery_order() {
        let ins = inputs(3);
        let scheme = schemes::by_name("allreduce", 3, 1, 16).unwrap();
        let r = check_scheme(scheme.as_ref(), &ins, true, DEFAULT_MAX_RUNS);
        assert!(r.ok(), "{:?}", r.failure);
        assert_eq!(
            r.stats.choice_points, 0,
            "ring stages have one source per destination"
        );
        assert_eq!(r.stats.runs, 1);
        assert!(!r.stats.truncated);
    }

    #[test]
    fn star_scheme_branches_and_stays_clean() {
        let ins = inputs(3);
        let scheme = schemes::by_name("sparseps", 3, 1, 16).unwrap();
        let r = check_scheme(scheme.as_ref(), &ins, true, DEFAULT_MAX_RUNS);
        assert!(r.ok(), "{:?}", r.failure);
        assert!(r.stats.runs > 1, "fan-in must create delivery branches");
        assert!(r.stats.choice_points > 0);
        assert!(!r.stats.truncated);
        assert!(r.output_digest.is_some());
    }

    #[test]
    fn exploration_is_deterministic() {
        let ins = inputs(3);
        let scheme = schemes::by_name("sparseps", 3, 1, 16).unwrap();
        let a = check_scheme(scheme.as_ref(), &ins, true, DEFAULT_MAX_RUNS);
        let b = check_scheme(scheme.as_ref(), &ins, true, DEFAULT_MAX_RUNS);
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.output_digest, b.output_digest);
    }

    #[test]
    fn truncation_is_reported() {
        let ins = inputs(3);
        let scheme = schemes::by_name("zen", 3, 1, 16).unwrap();
        let r = check_scheme(scheme.as_ref(), &ins, true, 1);
        assert!(r.stats.truncated || r.stats.runs <= 1);
    }

    #[test]
    fn parse_schedule_roundtrips() {
        let sched = vec![(0, 1), (2, 1), (1, 0)];
        let s = schedule_string(&sched);
        assert_eq!(parse_schedule(&s).unwrap(), sched);
        assert!(parse_schedule("0-1").is_err());
        assert!(parse_schedule("a>b").is_err());
        assert_eq!(parse_schedule("").unwrap(), vec![]);
    }

    #[test]
    fn replay_of_a_clean_schedule_is_clean() {
        let ins = inputs(2);
        let scheme = schemes::by_name("zen", 2, 1, 16).unwrap();
        let r = check_scheme(scheme.as_ref(), &ins, true, DEFAULT_MAX_RUNS);
        assert!(r.ok(), "{:?}", r.failure);
        let (v, record) =
            replay_schedule(scheme.as_ref(), &ins, true, r.output_digest, &[]);
        assert!(v.is_none(), "{v:?}");
        assert!(!record.trace.is_empty());
    }

    #[test]
    fn json_emits_expected_fields() {
        let ins = inputs(2);
        let scheme = schemes::by_name("allreduce", 2, 1, 16).unwrap();
        let r = check_scheme(scheme.as_ref(), &ins, true, DEFAULT_MAX_RUNS);
        let j = suite_json(&[r], 0.5);
        for key in [
            "\"bench\":\"check\"",
            "\"states_explored\"",
            "\"states_per_sec\"",
            "\"max_frontier\"",
            "\"violations\":0",
            "\"scheme\":\"AllReduce\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }
}
