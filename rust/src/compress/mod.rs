//! Lossy gradient compression with error-feedback residuals.
//!
//! The compression tier slots between gradient generation and the wire:
//! a [`Compressor`] takes one rank's sparse gradient for one tensor and
//! returns an ordinary [`CooTensor`] holding only the entries worth
//! shipping this iteration. Because the output is a plain COO tensor,
//! every existing scheme and driver (sim/channel/socket/event/worker)
//! runs compressed gradients unchanged — compression is invisible to
//! the protocol layer.
//!
//! Two selection rules are provided:
//!
//! - [`TopK`]: the `k` largest-magnitude entries per tensor, selected
//!   exactly by [`crate::kernel::active::select_topk`] (heap-free
//!   radix partial selection, deterministic lower-index tie-break);
//! - [`Threshold`]: every entry with `|v| >= t`.
//!
//! Both wrap an [`ErrorFeedback`] residual store: the mass *not* sent
//! is kept in a per-rank, per-tensor accumulator and merged into the
//! next iteration's gradient before selection, so dropped updates are
//! delayed, never lost (the classic EF-SGD construction; see
//! "Near-Optimal Sparse Allreduce", PAPERS.md). The accounting is
//! exact by design: the merged accumulator is *partitioned* into sent
//! and residual entries — no arithmetic happens at the split — so
//! `sent ⊎ residual` always reconstructs `residual_prev + grad`
//! bit for bit (pinned by `tests/compress_integration.rs`).
//!
//! Working buffers come from a [`ScratchPool`] and residual vectors
//! are recycled in place, so steady-state compression performs no
//! allocation beyond the output tensor itself.

use std::collections::HashMap;

use crate::kernel;
use crate::tensor::CooTensor;
use crate::util::arena::ScratchPool;

/// Parsed `--compress` specification (`topk:K | threshold:T | none`).
#[derive(Clone, Debug, PartialEq)]
pub enum CompressSpec {
    /// Lossless: compression disabled.
    None,
    /// Top-k by magnitude. `k >= 1` is an absolute per-tensor entry
    /// count; `0 < k < 1` is a fraction of the dense length.
    TopK(f64),
    /// Magnitude threshold: keep entries with `|v| >= t`.
    Threshold(f32),
}

impl CompressSpec {
    /// Parse a `topk:K|threshold:T|none` spec. Error messages name the
    /// offending field; the CLI wraps them with the flag name.
    pub fn parse(s: &str) -> Result<CompressSpec, String> {
        let s = s.trim();
        if s.is_empty() || s == "none" {
            return Ok(CompressSpec::None);
        }
        if let Some(arg) = s.strip_prefix("topk:") {
            let k: f64 = arg
                .parse()
                .map_err(|_| format!("topk wants a number, got '{arg}'"))?;
            if !k.is_finite() || k <= 0.0 {
                return Err(format!(
                    "topk wants a count >= 1 or a fraction in (0, 1), got {k}"
                ));
            }
            return Ok(CompressSpec::TopK(k));
        }
        if let Some(arg) = s.strip_prefix("threshold:") {
            let t: f32 = arg
                .parse()
                .map_err(|_| format!("threshold wants a number, got '{arg}'"))?;
            if !t.is_finite() || t <= 0.0 {
                return Err(format!("threshold must be a finite positive number, got {t}"));
            }
            return Ok(CompressSpec::Threshold(t));
        }
        Err(format!("unknown compressor '{s}' (topk:K|threshold:T|none)"))
    }

    /// Whether this spec compresses at all.
    pub fn is_active(&self) -> bool {
        !matches!(self, CompressSpec::None)
    }

    /// Build the compressor this spec describes (`None` when inactive).
    pub fn build(&self) -> Option<Box<dyn Compressor>> {
        match *self {
            CompressSpec::None => None,
            CompressSpec::TopK(k) => Some(Box::new(TopK::new(k))),
            CompressSpec::Threshold(t) => Some(Box::new(Threshold::new(t))),
        }
    }

    /// Predicted post-compression per-worker density given the dense
    /// length and the measured per-worker density `d1`. Top-k has a
    /// closed form (`min(d1, k/len)`); a magnitude threshold depends on
    /// the value distribution, so its analytic prediction stays at `d1`
    /// (the planner measures the survivor fraction from real tensors
    /// instead — see [`crate::planner::CostPlanner`]).
    pub fn predicted_density(&self, dense_len: usize, d1: f64) -> f64 {
        match *self {
            CompressSpec::None | CompressSpec::Threshold(_) => d1,
            CompressSpec::TopK(k) => {
                let kk = resolve_k(k, dense_len) as f64;
                d1.min(kk / dense_len.max(1) as f64)
            }
        }
    }

    /// Short display name for plan tables and bench output.
    pub fn label(&self) -> String {
        match *self {
            CompressSpec::None => "none".to_string(),
            CompressSpec::TopK(k) => format!("topk:{k}"),
            CompressSpec::Threshold(t) => format!("threshold:{t}"),
        }
    }
}

/// Resolve a Top-k parameter to an absolute entry count for a tensor of
/// `dense_len` positions: counts pass through, fractions scale.
fn resolve_k(k: f64, dense_len: usize) -> usize {
    if k >= 1.0 {
        k.round() as usize
    } else {
        ((k * dense_len as f64).round() as usize).max(1)
    }
}

/// Cumulative compression accounting (entries, not bytes — one COO
/// entry is 8 wire bytes regardless of scheme).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CompressStats {
    /// Entries offered to the compressor (raw gradients, pre-residual).
    pub raw_entries: u64,
    /// Entries actually sent after selection.
    pub sent_entries: u64,
}

impl CompressStats {
    /// COO wire bytes avoided relative to sending the raw gradients.
    pub fn bytes_saved(&self) -> u64 {
        self.raw_entries.saturating_sub(self.sent_entries) * 8
    }
}

/// A lossy gradient compressor with error feedback.
pub trait Compressor: Send {
    fn name(&self) -> &'static str;
    /// Predicted post-compression per-worker density (see
    /// [`CompressSpec::predicted_density`]).
    fn predicted_density(&self, dense_len: usize, d1: f64) -> f64;
    /// Compress one rank's gradient for tensor `label`, folding the
    /// rank's residual in first and retaining the unsent remainder.
    fn compress(&mut self, label: &str, rank: usize, grad: &CooTensor) -> CooTensor;
    /// Cumulative entry accounting across all `compress` calls.
    fn stats(&self) -> CompressStats;
}

/// Compress each rank's tensor in a batch (the per-iteration shape the
/// coordinator and trainer use).
pub fn compress_all(
    c: &mut dyn Compressor,
    label: &str,
    inputs: &[CooTensor],
) -> Vec<CooTensor> {
    inputs
        .iter()
        .enumerate()
        .map(|(rank, t)| c.compress(label, rank, t))
        .collect()
}

/// One rank's unsent remainder for one tensor. Sorted-unique COO halves,
/// recycled in place across iterations.
#[derive(Default)]
struct Residual {
    indices: Vec<u32>,
    values: Vec<f32>,
}

/// Reusable working buffers for one compression call.
#[derive(Default)]
pub struct CompressScratch {
    acc_idx: Vec<u32>,
    acc_val: Vec<f32>,
    sel: Vec<u32>,
}

/// Per-rank, per-tensor residual store shared by every selection rule.
///
/// `compress_with` merges `residual + grad` into a scratch accumulator
/// (sorted COO merge — the only arithmetic in the pipeline), lets the
/// selection rule pick ascending positions, then splits the accumulator
/// exactly: selected entries become the sent tensor, the rest (minus
/// entries that cancelled to exactly 0.0, which carry no mass) become
/// the new residual.
#[derive(Default)]
pub struct ErrorFeedback {
    residuals: HashMap<String, Vec<Residual>>,
    pool: ScratchPool<CompressScratch>,
    stats: CompressStats,
}

impl ErrorFeedback {
    pub fn new() -> Self {
        Self::default()
    }

    fn compress_with<F>(&mut self, label: &str, rank: usize, grad: &CooTensor, select: F) -> CooTensor
    where
        F: FnOnce(&[f32], &mut Vec<u32>),
    {
        if !self.residuals.contains_key(label) {
            self.residuals.insert(label.to_string(), Vec::new());
        }
        let per_rank = self.residuals.get_mut(label).expect("inserted above");
        while per_rank.len() <= rank {
            per_rank.push(Residual::default());
        }
        let residual = &mut per_rank[rank];
        let mut scratch = self.pool.acquire();
        let CompressScratch { acc_idx, acc_val, sel } = &mut *scratch;
        acc_idx.clear();
        acc_val.clear();
        sel.clear();
        kernel::active::merge_sorted(
            &residual.indices,
            &residual.values,
            &grad.indices,
            &grad.values,
            acc_idx,
            acc_val,
        );
        select(acc_val, sel);

        let mut sent_idx = Vec::with_capacity(sel.len());
        let mut sent_val = Vec::with_capacity(sel.len());
        residual.indices.clear();
        residual.values.clear();
        let mut next = sel.iter().copied().peekable();
        for (pos, (&idx, &val)) in acc_idx.iter().zip(acc_val.iter()).enumerate() {
            if next.peek() == Some(&(pos as u32)) {
                next.next();
                sent_idx.push(idx);
                sent_val.push(val);
            } else if val != 0.0 {
                residual.indices.push(idx);
                residual.values.push(val);
            }
        }
        self.stats.raw_entries += grad.nnz() as u64;
        self.stats.sent_entries += sent_idx.len() as u64;
        CooTensor::from_sorted(grad.dense_len, sent_idx, sent_val)
    }

    /// One rank's current residual mass for one tensor (empty when the
    /// rank never compressed), as an owned tensor over `dense_len` —
    /// test/report surface.
    pub fn residual(&self, label: &str, rank: usize, dense_len: usize) -> CooTensor {
        match self.residuals.get(label).and_then(|v| v.get(rank)) {
            Some(r) => {
                CooTensor::from_sorted(dense_len, r.indices.clone(), r.values.clone())
            }
            None => CooTensor::empty(dense_len),
        }
    }
}

/// Error-feedback Top-k: ship the `k` largest-magnitude entries of
/// `residual + grad`, retain the rest.
pub struct TopK {
    k: f64,
    feedback: ErrorFeedback,
}

impl TopK {
    /// `k >= 1`: absolute per-tensor entry count; `0 < k < 1`: fraction
    /// of the dense length; `k = 0` degenerates to sending nothing
    /// (every gradient becomes all-empty and accumulates as residual).
    pub fn new(k: f64) -> Self {
        TopK {
            k: k.max(0.0),
            feedback: ErrorFeedback::new(),
        }
    }

    /// The residual store (test/report surface).
    pub fn feedback(&self) -> &ErrorFeedback {
        &self.feedback
    }
}

impl Compressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn predicted_density(&self, dense_len: usize, d1: f64) -> f64 {
        if self.k == 0.0 {
            return 0.0;
        }
        CompressSpec::TopK(self.k).predicted_density(dense_len, d1)
    }

    fn compress(&mut self, label: &str, rank: usize, grad: &CooTensor) -> CooTensor {
        let k = if self.k == 0.0 {
            0
        } else {
            resolve_k(self.k, grad.dense_len)
        };
        self.feedback.compress_with(label, rank, grad, |vals, sel| {
            kernel::active::select_topk(vals, k, sel);
        })
    }

    fn stats(&self) -> CompressStats {
        self.feedback.stats
    }
}

/// Error-feedback magnitude threshold: ship entries of
/// `residual + grad` with `|v| >= t`, retain the rest.
pub struct Threshold {
    t: f32,
    feedback: ErrorFeedback,
}

impl Threshold {
    pub fn new(t: f32) -> Self {
        Threshold {
            t,
            feedback: ErrorFeedback::new(),
        }
    }

    pub fn feedback(&self) -> &ErrorFeedback {
        &self.feedback
    }
}

impl Compressor for Threshold {
    fn name(&self) -> &'static str {
        "threshold"
    }

    fn predicted_density(&self, dense_len: usize, d1: f64) -> f64 {
        CompressSpec::Threshold(self.t).predicted_density(dense_len, d1)
    }

    fn compress(&mut self, label: &str, rank: usize, grad: &CooTensor) -> CooTensor {
        let t = self.t;
        self.feedback.compress_with(label, rank, grad, |vals, sel| {
            for (i, &v) in vals.iter().enumerate() {
                if v.abs() >= t {
                    sel.push(i as u32);
                }
            }
        })
    }

    fn stats(&self) -> CompressStats {
        self.feedback.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coo(dense_len: usize, pairs: &[(u32, f32)]) -> CooTensor {
        CooTensor::from_sorted(
            dense_len,
            pairs.iter().map(|&(i, _)| i).collect(),
            pairs.iter().map(|&(_, v)| v).collect(),
        )
    }

    #[test]
    fn parse_specs() {
        assert_eq!(CompressSpec::parse("none").unwrap(), CompressSpec::None);
        assert_eq!(CompressSpec::parse("").unwrap(), CompressSpec::None);
        assert_eq!(
            CompressSpec::parse("topk:64").unwrap(),
            CompressSpec::TopK(64.0)
        );
        assert_eq!(
            CompressSpec::parse("topk:0.01").unwrap(),
            CompressSpec::TopK(0.01)
        );
        assert_eq!(
            CompressSpec::parse("threshold:0.5").unwrap(),
            CompressSpec::Threshold(0.5)
        );
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "topk:0",
            "topk:-3",
            "topk:NaN",
            "topk:inf",
            "topk:abc",
            "threshold:-0.5",
            "threshold:0",
            "threshold:NaN",
            "gzip:9",
        ] {
            let err = CompressSpec::parse(bad).unwrap_err();
            assert!(!err.is_empty(), "{bad}: {err}");
        }
        assert!(CompressSpec::parse("topk:0").unwrap_err().contains("topk"));
        assert!(CompressSpec::parse("threshold:-0.5")
            .unwrap_err()
            .contains("positive"));
    }

    #[test]
    fn topk_selects_largest_magnitudes_with_feedback() {
        let mut c = TopK::new(2.0);
        let g = coo(100, &[(3, 0.5), (10, -2.0), (50, 1.0), (80, -0.25)]);
        let sent = c.compress("t", 0, &g);
        assert_eq!(sent.indices, vec![10, 50]);
        assert_eq!(sent.values, vec![-2.0, 1.0]);
        // Dropped mass re-enters: next iteration's empty gradient still
        // ships the two largest residual entries.
        let sent2 = c.compress("t", 0, &CooTensor::empty(100));
        assert_eq!(sent2.indices, vec![3, 80]);
        assert_eq!(sent2.values, vec![0.5, -0.25]);
        // Residual is now fully drained.
        let sent3 = c.compress("t", 0, &CooTensor::empty(100));
        assert_eq!(sent3.nnz(), 0);
        assert_eq!(c.stats().raw_entries, 4);
        assert_eq!(c.stats().sent_entries, 4);
    }

    #[test]
    fn topk_k_at_least_nnz_is_bit_identical_passthrough() {
        let mut c = TopK::new(10.0);
        let g = coo(64, &[(1, 0.125), (7, -0.5), (9, 3.0)]);
        let sent = c.compress("t", 0, &g);
        assert_eq!(sent, g, "k >= nnz with empty residual is lossless");
    }

    #[test]
    fn topk_zero_sends_nothing_and_accumulates() {
        let mut c = TopK::new(0.0);
        let g = coo(64, &[(2, 1.0), (5, -1.0)]);
        for _ in 0..3 {
            assert_eq!(c.compress("t", 0, &g).nnz(), 0);
        }
        // All mass is in the residual: one full-k flush returns 3x.
        let mut flush = TopK::new(64.0);
        std::mem::swap(&mut flush.feedback, &mut c.feedback);
        let sent = flush.compress("t", 0, &CooTensor::empty(64));
        assert_eq!(sent.indices, vec![2, 5]);
        assert_eq!(sent.values, vec![3.0, -3.0]);
    }

    #[test]
    fn threshold_keeps_only_large_entries() {
        let mut c = Threshold::new(0.75);
        let g = coo(32, &[(0, 0.5), (4, -1.5), (8, 0.75), (16, 0.25)]);
        let sent = c.compress("t", 0, &g);
        assert_eq!(sent.indices, vec![4, 8], ">= is inclusive");
        // 0.5 + 0.25 stay back; a second identical gradient pushes 0.5
        // past the threshold (1.0) while 0.25 reaches only 0.5.
        let sent2 = c.compress("t", 0, &g);
        assert_eq!(sent2.indices, vec![0, 4, 8]);
        assert_eq!(sent2.values[0], 1.0);
    }

    #[test]
    fn ranks_and_labels_have_independent_residuals() {
        let mut c = TopK::new(1.0);
        let g = coo(16, &[(1, 1.0), (2, 2.0)]);
        c.compress("a", 0, &g);
        c.compress("a", 1, &g);
        c.compress("b", 0, &g);
        // Each (label, rank) kept its own 1-entry residual at index 1.
        for (label, rank) in [("a", 0), ("a", 1), ("b", 0)] {
            let sent = c.compress(label, rank, &CooTensor::empty(16));
            assert_eq!(sent.indices, vec![1], "{label}/{rank}");
            assert_eq!(sent.values, vec![1.0], "{label}/{rank}");
        }
    }

    #[test]
    fn exact_cancellation_prunes_residual() {
        let mut c = TopK::new(1.0);
        c.compress("t", 0, &coo(8, &[(1, 0.5), (3, 2.0)]));
        // residual holds (1, 0.5); cancel it exactly.
        c.compress("t", 0, &coo(8, &[(1, -0.5), (3, 2.0)]));
        let sent = c.compress("t", 0, &CooTensor::empty(8));
        assert_eq!(sent.nnz(), 0, "cancelled entries leave no residual");
    }

    #[test]
    fn predicted_density_forms() {
        let s = CompressSpec::TopK(64.0);
        assert!((s.predicted_density(6400, 0.5) - 0.01).abs() < 1e-12);
        assert_eq!(s.predicted_density(6400, 0.001), 0.001, "capped at d1");
        let f = CompressSpec::TopK(0.01);
        assert!((f.predicted_density(6400, 0.5) - 0.01).abs() < 1e-12);
        assert_eq!(
            CompressSpec::Threshold(0.5).predicted_density(6400, 0.2),
            0.2,
            "threshold has no analytic reduction"
        );
        assert_eq!(CompressSpec::None.predicted_density(6400, 0.2), 0.2);
    }

    #[test]
    fn build_matches_spec() {
        assert!(CompressSpec::None.build().is_none());
        assert_eq!(CompressSpec::TopK(4.0).build().unwrap().name(), "topk");
        assert_eq!(
            CompressSpec::Threshold(0.1).build().unwrap().name(),
            "threshold"
        );
    }
}
