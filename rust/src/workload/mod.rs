//! Synthetic sparse-gradient workloads calibrated to the paper's Table 1.
//!
//! The paper profiles four embedding-heavy models (LSTM, DeepFM, NMT,
//! BERT). Their sparsity structure comes from *embedding-row access*:
//! a training batch touches a subset of rows; only those rows get
//! non-zero gradients. Row popularity is Zipf-like over a
//! frequency-sorted vocabulary, which simultaneously produces all three
//! §2.2 characteristics:
//!
//! - **overlap** (Fig 1a): different workers' batches share hot rows;
//! - **densification** (Fig 1b): unions across workers grow sublinearly;
//! - **skew** (Fig 2): hot rows cluster at low indices, so contiguous
//!   partitions are wildly uneven.
//!
//! [`GradientGen`] samples row accesses per (iteration, worker) from a
//! shared Zipf law and expands touched rows into element-level non-zeros
//! (rows are contiguous `dim`-wide runs — exactly the block structure
//! OmniReduce exploits). Draw counts are calibrated so the per-worker
//! density matches the profile's Table-1 value.

pub mod profiles;

pub use profiles::{table1, ModelProfile};

use crate::tensor::CooTensor;
use crate::util::{Pcg64, Zipf};

/// Uniform random per-worker sparse tensors at a given density —
/// structureless inputs (no Zipf skew, no row blocks) shared by the
/// transport parity tests and the transport benches so both exercise
/// the exact same workload.
pub fn random_uniform_inputs(
    seed: u64,
    n: usize,
    dense_len: usize,
    density: f64,
) -> Vec<CooTensor> {
    let nnz = ((dense_len as f64 * density) as usize).clamp(1, dense_len);
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| {
            let mut idx: Vec<u32> = rng
                .sample_distinct(dense_len, nnz)
                .into_iter()
                .map(|i| i as u32)
                .collect();
            idx.sort_unstable();
            let vals: Vec<f32> = (0..nnz).map(|_| rng.next_f32() * 2.0 - 0.99).collect();
            CooTensor::from_sorted(dense_len, idx, vals)
        })
        .collect()
}

/// Per-worker tensors whose non-zero supports are correlated by worker
/// *group*: consecutive runs of `workers_per_group` workers share one
/// group-private support of `density · dense_len` scattered positions
/// (per-worker values still differ). Models placement-correlated
/// sparsity — locality-aware data loaders hand co-located workers
/// similar shards, so the union density stays flat within a group and
/// steps up only when the next group joins. This is the workload where
/// topology-aware planning diverges from the flat mesh
/// (`figures::topology_crossover`, `tests/topology_integration.rs`).
pub fn group_clustered_inputs(
    seed: u64,
    groups: usize,
    workers_per_group: usize,
    dense_len: usize,
    density: f64,
) -> Vec<CooTensor> {
    assert!(groups >= 1 && workers_per_group >= 1);
    let nnz = ((dense_len as f64 * density) as usize).clamp(1, dense_len);
    let mut rng = Pcg64::seeded(seed);
    let supports: Vec<Vec<u32>> = (0..groups)
        .map(|_| {
            let mut idx: Vec<u32> = rng
                .sample_distinct(dense_len, nnz)
                .into_iter()
                .map(|i| i as u32)
                .collect();
            idx.sort_unstable();
            idx
        })
        .collect();
    (0..groups * workers_per_group)
        .map(|w| {
            let support = &supports[w / workers_per_group];
            let vals: Vec<f32> = support
                .iter()
                .map(|_| rng.next_f32() * 2.0 - 0.99)
                .collect();
            CooTensor::from_sorted(dense_len, support.clone(), vals)
        })
        .collect()
}

/// What kind of gradient a [`LayerSpec`] produces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Dense (MLP / head) gradient — every parameter non-zero.
    Dense,
    /// A contiguous shard of embedding rows `[row_lo, row_hi)`.
    EmbeddingShard { row_lo: usize, row_hi: usize },
}

/// One layer of the model's gradient, in backward-completion order.
///
/// Real frameworks surface gradients tensor-by-tensor as the backward
/// pass walks from the output towards the input; `ready_frac` models
/// that: the fraction of the backward pass completed when this layer's
/// gradient is available for synchronization. The engine
/// ([`crate::engine`]) uses it to start bucket communication *before*
/// the full backward pass has finished.
#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub name: String,
    /// Parameters in this layer (the dense length of its gradient).
    pub params: usize,
    pub kind: LayerKind,
    /// Fraction of backward compute done when this gradient is ready,
    /// in (0, 1]; monotone non-decreasing across the spec list.
    pub ready_frac: f64,
    /// Consumption rank in the *next* iteration's forward pass
    /// (0 = needed first). The forward pass walks input → output, the
    /// exact reverse of backward-completion order: the embedding (input
    /// layer, last gradient out) is the first parameter the next
    /// forward touches. Priority scheduling
    /// ([`crate::cluster::Timeline::schedule_priority`]) transmits
    /// low-`fwd_order` buckets first when a backlog forms.
    pub fwd_order: usize,
}

/// Deterministic sparse-gradient generator for one model profile.
pub struct GradientGen {
    pub profile: ModelProfile,
    zipf: Zipf,
    /// Row-access draws per iteration per worker (calibrated).
    pub draws: usize,
    seed: u64,
}

impl GradientGen {
    /// Calibrates the number of Zipf draws so that the expected number of
    /// distinct touched rows ≈ `density · rows`.
    pub fn new(profile: ModelProfile, seed: u64) -> Self {
        let zipf = Zipf::new(profile.rows, profile.zipf_theta);
        let target = (profile.density * profile.rows as f64).max(1.0);
        let draws = calibrate_draws(&zipf, profile.rows, target);
        GradientGen {
            profile,
            zipf,
            draws,
            seed,
        }
    }

    /// The sparse gradient tensor produced by `worker` at `iteration`.
    /// Deterministic in (seed, iteration, worker).
    pub fn iteration(&self, iteration: u64, worker: usize) -> CooTensor {
        let mut rng = Pcg64::new(
            self.seed ^ iteration.wrapping_mul(0x9e37_79b9_7f4a_7c15),
            worker as u64 + 1,
        );
        let mut rows: Vec<u32> = (0..self.draws)
            .map(|_| self.zipf.sample(&mut rng) as u32)
            .collect();
        rows.sort_unstable();
        rows.dedup();
        let dim = self.profile.dim;
        let dense_len = self.profile.emb_params();
        let mut indices = Vec::with_capacity(rows.len() * dim);
        let mut values = Vec::with_capacity(rows.len() * dim);
        for &r in &rows {
            let base = r as usize * dim;
            for c in 0..dim {
                indices.push((base + c) as u32);
                // gradient magnitudes: zero-mean, non-zero guaranteed
                let v = rng.normal_ms(0.0, 0.05) as f32;
                values.push(if v == 0.0 { 1e-4 } else { v });
            }
        }
        CooTensor::from_sorted(dense_len, indices, values)
    }

    /// Generate one iteration's tensors for all `n` workers.
    pub fn iteration_all(&self, iteration: u64, n: usize) -> Vec<CooTensor> {
        (0..n).map(|w| self.iteration(iteration, w)).collect()
    }

    /// One *machine's* tensor at `iteration`: the merge of its `gpus`
    /// colocated workers' tensors — the intra-machine NVLink aggregation
    /// phase, densification included. Worker ids are
    /// `machine·gpus .. (machine+1)·gpus`, matching
    /// [`iteration_all`](GradientGen::iteration_all)'s numbering.
    pub fn machine_iteration(&self, iteration: u64, machine: usize, gpus: usize) -> CooTensor {
        assert!(gpus >= 1);
        let per_gpu: Vec<CooTensor> = (0..gpus)
            .map(|g| self.iteration(iteration, machine * gpus + g))
            .collect();
        CooTensor::merge_all(&per_gpu)
    }

    /// Expected non-zeros per worker tensor.
    pub fn expected_nnz(&self) -> usize {
        (self.profile.density * self.profile.emb_params() as f64) as usize
    }

    /// Decompose the profile into per-layer gradients in
    /// backward-completion order: the dense head layers finish first
    /// (they sit near the output), then the embedding shards (the input
    /// layer's gradient completes last). `ready_frac` is spaced evenly
    /// across the layer list — a linear backward-cost model, documented
    /// in DESIGN.md §Substitutions.
    pub fn layer_specs(&self, dense_layers: usize, emb_shards: usize) -> Vec<LayerSpec> {
        assert!(emb_shards >= 1, "the embedding needs at least one shard");
        let total = dense_layers + emb_shards;
        let mut specs = Vec::with_capacity(total);
        let mlp = self.profile.mlp_params;
        for i in 0..dense_layers {
            let lo = i * mlp / dense_layers;
            let hi = (i + 1) * mlp / dense_layers;
            specs.push(LayerSpec {
                name: format!("mlp{i}"),
                params: hi - lo,
                kind: LayerKind::Dense,
                ready_frac: (i + 1) as f64 / total as f64,
                // forward consumption is the reverse of backward
                // completion: mlp0 (nearest the output) is needed last
                fwd_order: total - 1 - i,
            });
        }
        let rows = self.profile.rows;
        for s in 0..emb_shards {
            let row_lo = s * rows / emb_shards;
            let row_hi = (s + 1) * rows / emb_shards;
            specs.push(LayerSpec {
                name: format!("emb{s}"),
                params: (row_hi - row_lo) * self.profile.dim,
                kind: LayerKind::EmbeddingShard { row_lo, row_hi },
                ready_frac: (dense_layers + s + 1) as f64 / total as f64,
                // the embedding is the input layer: last gradient to
                // complete, first parameter the next forward reads
                fwd_order: total - 1 - (dense_layers + s),
            });
        }
        specs
    }

    /// One worker's per-layer gradient tensors for `specs`. Embedding
    /// shards are exact row-range slices of the flat [`iteration`]
    /// tensor (so the multi-tensor path aggregates to the same values as
    /// the single-tensor path); dense layers get synthetic dense
    /// gradients from a per-(iteration, worker, layer) RNG stream.
    ///
    /// [`iteration`]: GradientGen::iteration
    pub fn layer_iteration(
        &self,
        specs: &[LayerSpec],
        iteration: u64,
        worker: usize,
    ) -> Vec<CooTensor> {
        let flat = self.iteration(iteration, worker);
        let dim = self.profile.dim as u32;
        specs
            .iter()
            .enumerate()
            .map(|(li, spec)| match spec.kind {
                LayerKind::EmbeddingShard { row_lo, row_hi } => {
                    flat.slice_range(row_lo as u32 * dim, row_hi as u32 * dim)
                }
                LayerKind::Dense => {
                    let mut rng = Pcg64::new(
                        self.seed
                            ^ iteration.wrapping_mul(0x517c_c1b7_2722_0a95)
                            ^ ((li as u64 + 1) << 17),
                        worker as u64 + 1,
                    );
                    let indices: Vec<u32> = (0..spec.params as u32).collect();
                    let values: Vec<f32> = (0..spec.params)
                        .map(|_| {
                            let v = rng.normal_ms(0.0, 0.02) as f32;
                            if v == 0.0 {
                                1e-4
                            } else {
                                v
                            }
                        })
                        .collect();
                    CooTensor::from_sorted(spec.params, indices, values)
                }
            })
            .collect()
    }

    /// One iteration's per-layer tensors for all `n` workers:
    /// `out[worker][layer]`.
    pub fn layer_iteration_all(
        &self,
        specs: &[LayerSpec],
        iteration: u64,
        n: usize,
    ) -> Vec<Vec<CooTensor>> {
        (0..n)
            .map(|w| self.layer_iteration(specs, iteration, w))
            .collect()
    }
}

/// Find the draw count whose expected distinct-row coverage hits
/// `target_rows`, using E[distinct] = Σ_k (1 − (1 − p_k)^T) and binary
/// search over T.
fn calibrate_draws(zipf: &Zipf, rows: usize, target_rows: f64) -> usize {
    // Recover the pmf from the CDF by sampling its analytic form again.
    let theta_pmf: Vec<f64> = {
        // p_k ∝ (k+1)^-θ; infer θ-independent: recompute from Zipf table
        // by finite differences of the CDF is noisy — instead rebuild.
        // Zipf stores only the CDF; expose via support+probe.
        let n = zipf.support();
        let mut pmf = Vec::with_capacity(n);
        let mut prev = 0.0;
        for k in 0..n {
            let c = zipf_cdf(zipf, k);
            pmf.push(c - prev);
            prev = c;
        }
        pmf
    };
    let expected = |t: f64| -> f64 {
        theta_pmf
            .iter()
            .map(|&p| 1.0 - (1.0 - p).powf(t))
            .sum::<f64>()
    };
    let target = target_rows.min(rows as f64 * 0.999);
    let (mut lo, mut hi) = (1.0f64, 4.0 * rows as f64 + 16.0);
    // expected() is monotone in t; expand hi until it covers the target.
    while expected(hi) < target && hi < 1e12 {
        hi *= 2.0;
    }
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        if expected(mid) < target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    hi.ceil() as usize
}

fn zipf_cdf(z: &Zipf, k: usize) -> f64 {
    z.cdf_at(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::metrics;

    fn small_profile() -> ModelProfile {
        ModelProfile {
            name: "toy",
            task: "test",
            dataset: "synthetic",
            mlp_params: 1_000,
            rows: 4_096,
            dim: 8,
            batch_size: 32,
            density: 0.02,
            zipf_theta: 1.05,
        }
    }

    #[test]
    fn density_calibrated() {
        let g = GradientGen::new(small_profile(), 1);
        let mut densities = Vec::new();
        for it in 0..8 {
            let t = g.iteration(it, 0);
            densities.push(t.density());
        }
        let mean: f64 = densities.iter().sum::<f64>() / densities.len() as f64;
        let target = small_profile().density;
        assert!(
            (mean - target).abs() / target < 0.25,
            "calibration off: mean {mean}, target {target}"
        );
    }

    #[test]
    fn deterministic_per_iter_worker() {
        let g = GradientGen::new(small_profile(), 7);
        assert_eq!(g.iteration(3, 2), g.iteration(3, 2));
        assert_ne!(g.iteration(3, 2), g.iteration(4, 2));
        assert_ne!(g.iteration(3, 2), g.iteration(3, 1));
    }

    #[test]
    fn workers_overlap_partially() {
        // Fig 1a: overlap strictly between 0 and 1.
        let g = GradientGen::new(small_profile(), 3);
        let a = g.iteration(0, 0);
        let b = g.iteration(0, 1);
        let ov = metrics::overlap_ratio(&a, &b);
        assert!(ov > 0.05 && ov < 0.98, "overlap {ov}");
    }

    #[test]
    fn aggregation_densifies_sublinearly() {
        // Fig 1b: 1 < γ^n < n.
        let g = GradientGen::new(small_profile(), 5);
        let tensors = g.iteration_all(0, 8);
        let gamma = metrics::densification_ratio(&tensors);
        assert!(gamma > 1.5 && gamma < 8.0, "densification {gamma}");
    }

    #[test]
    fn distribution_is_skewed() {
        // Fig 2: contiguous split concentrates non-zeros up front.
        let g = GradientGen::new(small_profile(), 9);
        let t = g.iteration(0, 0);
        let s = metrics::skewness_ratio(&t, 8);
        assert!(s > 2.0, "skewness {s}");
        let counts = metrics::partition_nnz(&t, 8);
        assert!(counts[0] > counts[7], "head partition should dominate");
    }

    #[test]
    fn machine_iteration_merges_gpu_tensors() {
        let g = GradientGen::new(small_profile(), 21);
        let machine = g.machine_iteration(0, 1, 3);
        let per_gpu = vec![g.iteration(0, 3), g.iteration(0, 4), g.iteration(0, 5)];
        assert_eq!(machine, CooTensor::merge_all(&per_gpu));
        // single-GPU machines degenerate to the worker tensor
        assert_eq!(g.machine_iteration(2, 0, 1), g.iteration(2, 0));
    }

    #[test]
    fn rows_expand_to_dim_runs() {
        let g = GradientGen::new(small_profile(), 11);
        let t = g.iteration(0, 0);
        assert_eq!(t.nnz() % small_profile().dim, 0);
    }

    #[test]
    fn layer_specs_cover_the_model() {
        let g = GradientGen::new(small_profile(), 13);
        let specs = g.layer_specs(3, 4);
        assert_eq!(specs.len(), 7);
        let p = small_profile();
        let dense_total: usize = specs
            .iter()
            .filter(|s| s.kind == LayerKind::Dense)
            .map(|s| s.params)
            .sum();
        assert_eq!(dense_total, p.mlp_params);
        let emb_total: usize = specs
            .iter()
            .filter(|s| matches!(s.kind, LayerKind::EmbeddingShard { .. }))
            .map(|s| s.params)
            .sum();
        assert_eq!(emb_total, p.emb_params());
        // ready fractions are monotone and end at 1.0
        assert!(specs.windows(2).all(|w| w[0].ready_frac <= w[1].ready_frac));
        assert!((specs.last().unwrap().ready_frac - 1.0).abs() < 1e-12);
    }

    #[test]
    fn embedding_shards_reassemble_to_flat_tensor() {
        let g = GradientGen::new(small_profile(), 17);
        let specs = g.layer_specs(0, 4);
        let layers = g.layer_iteration(&specs, 2, 1);
        let flat = g.iteration(2, 1);
        let mut offset = 0u32;
        let parts: Vec<(u32, CooTensor)> = layers
            .into_iter()
            .map(|t| {
                let off = offset;
                offset += t.dense_len as u32;
                (off, t)
            })
            .collect();
        let back = CooTensor::concat_ranges(&parts, flat.dense_len);
        assert_eq!(back, flat);
    }

    #[test]
    fn dense_layers_are_dense_and_deterministic() {
        let g = GradientGen::new(small_profile(), 19);
        let specs = g.layer_specs(2, 1);
        let a = g.layer_iteration(&specs, 0, 0);
        let b = g.layer_iteration(&specs, 0, 0);
        assert_eq!(a, b);
        for (spec, t) in specs.iter().zip(a.iter()) {
            if spec.kind == LayerKind::Dense {
                assert_eq!(t.nnz(), spec.params, "dense layer fully non-zero");
            }
        }
        // different workers draw different dense gradients
        let c = g.layer_iteration(&specs, 0, 1);
        assert_ne!(a[0], c[0]);
    }
}
