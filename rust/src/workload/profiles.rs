//! Model profiles — paper Table 1.
//!
//! Gradient sizes are parameter counts; `density` is the average density
//! of the embedding gradient tensor on one GPU. `rows × dim` factors the
//! embedding parameter count into a vocabulary × embedding-dim shape
//! (the paper does not publish the exact shapes; we pick representative
//! ones — LSTM/One-Billion-Word and NMT/IWSLT vocabularies are ~800k and
//! ~32k, DeepFM/Criteo feature tables are wide and shallow, BERT's
//! WordPiece vocab is 30k — and scale them together with the totals).
//! `zipf_theta` controls access skew, fitted so the measured skewness
//! ratios land in the Fig 2b regime.

/// One row of Table 1 plus the generator's structural parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    pub task: &'static str,
    pub dataset: &'static str,
    /// Dense (MLP) gradient parameter count.
    pub mlp_params: usize,
    /// Embedding rows (vocabulary / feature ids).
    pub rows: usize,
    /// Embedding width; `emb_params = rows * dim`.
    pub dim: usize,
    pub batch_size: usize,
    /// Per-GPU embedding-gradient density (Table 1).
    pub density: f64,
    /// Zipf exponent for row-access popularity.
    pub zipf_theta: f64,
}

impl ModelProfile {
    pub fn emb_params(&self) -> usize {
        self.rows * self.dim
    }

    pub fn total_params(&self) -> usize {
        self.mlp_params + self.emb_params()
    }

    /// Scale the model down by `factor` (rows and MLP shrink; dim, batch,
    /// density, skew preserved) — traffic *ratios* between schemes are
    /// scale-invariant, so experiments run on laptop-sized tensors.
    pub fn scaled(&self, factor: usize) -> ModelProfile {
        assert!(factor >= 1);
        let mut p = self.clone();
        p.rows = (p.rows / factor).max(64);
        p.mlp_params = (p.mlp_params / factor).max(64);
        p
    }
}

/// Table 1, full size.
pub fn table1() -> Vec<ModelProfile> {
    vec![
        ModelProfile {
            name: "LSTM",
            task: "Language Modeling",
            dataset: "One Billion Word",
            mlp_params: 20_000_000,
            rows: 793_470,
            dim: 512, // 406.3M embedding params
            batch_size: 128,
            density: 0.0113,
            zipf_theta: 1.1,
        },
        ModelProfile {
            name: "DeepFM",
            task: "Click-through Rate Prediction",
            dataset: "Criteo",
            mlp_params: 68_000_000,
            rows: 13_375_000,
            dim: 16, // 214M embedding params
            batch_size: 1024,
            density: 0.0280,
            zipf_theta: 1.05,
        },
        ModelProfile {
            name: "NMT",
            task: "Machine Translation",
            dataset: "IWSLT 2014 De-En",
            mlp_params: 31_000_000,
            rows: 218_750,
            dim: 512, // 112M embedding params
            batch_size: 64,
            density: 0.0247,
            zipf_theta: 1.0,
        },
        ModelProfile {
            name: "BERT",
            task: "Question Answering",
            dataset: "SQuAD v1.1",
            mlp_params: 86_000_000,
            rows: 29_950,
            dim: 768, // 23M embedding params
            batch_size: 4,
            density: 0.0106,
            zipf_theta: 0.95,
        },
    ]
}

/// Table-1 profiles scaled for in-process experiments (default 1/64).
pub fn table1_scaled(factor: usize) -> Vec<ModelProfile> {
    table1().into_iter().map(|p| p.scaled(factor)).collect()
}

/// Look up a profile by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<ModelProfile> {
    table1()
        .into_iter()
        .find(|p| p.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_sizes() {
        let t = table1();
        assert_eq!(t.len(), 4);
        // embedding param counts within 2% of Table 1
        let expect = [406e6, 214e6, 112e6, 23e6];
        for (p, e) in t.iter().zip(expect) {
            let got = p.emb_params() as f64;
            assert!(
                (got - e).abs() / e < 0.02,
                "{}: emb {got} vs paper {e}",
                p.name
            );
        }
        // densities exactly as Table 1
        assert_eq!(t[0].density, 0.0113);
        assert_eq!(t[1].density, 0.0280);
        assert_eq!(t[2].density, 0.0247);
        assert_eq!(t[3].density, 0.0106);
    }

    #[test]
    fn scaling_preserves_density_and_dim() {
        for p in table1() {
            let s = p.scaled(64);
            assert_eq!(s.density, p.density);
            assert_eq!(s.dim, p.dim);
            assert!(s.emb_params() < p.emb_params());
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("nmt").is_some());
        assert!(by_name("LSTM").is_some());
        assert!(by_name("resnet").is_none());
    }
}
