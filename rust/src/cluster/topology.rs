//! Two-level cluster topology: rank placement + per-link-class α–β.
//!
//! Real clusters are not flat meshes: ranks inside one node talk over
//! NVLink-class links an order of magnitude faster than the inter-node
//! fabric. [`Topology`] captures that as a two-level model — `nodes`
//! nodes of `ranks_per_node` ranks each — with its own [`LinkKind`]
//! per [`LinkClass`]. Every layer that used a single global α–β pair
//! now prices per class:
//!
//! - the transports charge a stage as the *max* over classes of that
//!   class's α–β time (classes are physically parallel links),
//! - [`crate::cluster::StageReport`] splits observed bytes and time by
//!   class,
//! - [`crate::analysis::CostModel::with_topology`] prices each scheme's
//!   stage structure per class, which is what lets the planner pick
//!   different winners for intra-heavy vs inter-heavy placements.
//!
//! The same struct doubles as the classic "machines × GPUs" cluster
//! shape (the paper's testbeds): [`Topology::intra_machine_time`]
//! charges the per-machine NVLink reduce-scatter/all-gather phase the
//! flat simulation path pre-aggregates with.

use super::LinkKind;

/// Which physical link a frame crosses: node-local or cross-node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// Both endpoints share a node (NVLink-class).
    Intra = 0,
    /// The endpoints sit on different nodes (network fabric).
    Inter = 1,
}

/// Both classes, in index order (`class as usize`).
pub const LINK_CLASSES: [LinkClass; 2] = [LinkClass::Intra, LinkClass::Inter];

impl LinkClass {
    /// Stable array index (`[intra, inter]`).
    pub fn idx(&self) -> usize {
        *self as usize
    }

    pub fn name(&self) -> &'static str {
        match self {
            LinkClass::Intra => "intra",
            LinkClass::Inter => "inter",
        }
    }
}

/// Cluster shape: `nodes` nodes × `ranks_per_node` ranks, with one
/// link preset per class. Rank `r` lives on node `r / ranks_per_node`.
///
/// A *flat* topology (`ranks_per_node == 1`) reproduces the historical
/// single-link model exactly: every pair of endpoints crosses the
/// inter-node link, and the intra link never carries traffic.
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    pub nodes: usize,
    pub ranks_per_node: usize,
    /// Node-local link (NVLink in the paper's testbeds).
    pub intra: LinkKind,
    /// Cross-node link (the 25 Gbps TCP / 100 Gbps RDMA fabric).
    pub inter: LinkKind,
}

impl Topology {
    /// The historical model: every endpoint is its own node, all
    /// traffic crosses `link`.
    pub fn flat(endpoints: usize, link: LinkKind) -> Self {
        Topology {
            nodes: endpoints,
            ranks_per_node: 1,
            intra: link,
            inter: link,
        }
    }

    /// A two-level topology with explicit per-class links.
    pub fn two_level(
        nodes: usize,
        ranks_per_node: usize,
        intra: LinkKind,
        inter: LinkKind,
    ) -> Self {
        assert!(nodes >= 1 && ranks_per_node >= 1);
        Topology {
            nodes,
            ranks_per_node,
            intra,
            inter,
        }
    }

    /// Classic cluster shape (machines × GPUs on NVLink) for the flat
    /// simulation path, where machines are the fabric endpoints.
    pub fn new(machines: usize, gpus_per_machine: usize, inter: LinkKind) -> Self {
        Topology {
            nodes: machines,
            ranks_per_node: gpus_per_machine,
            intra: LinkKind::NvLink,
            inter,
        }
    }

    /// Paper testbed 1: m machines × 8 V100, 25 Gbps TCP.
    pub fn testbed_tcp(machines: usize) -> Self {
        Self::new(machines, 8, LinkKind::Tcp25)
    }

    /// Paper testbed 2: m machines × 8 A100, 100 Gbps RDMA.
    pub fn testbed_rdma(machines: usize) -> Self {
        Self::new(machines, 8, LinkKind::Rdma100)
    }

    /// Total ranks (the endpoint count of a topology-aware fabric).
    pub fn endpoints(&self) -> usize {
        self.nodes * self.ranks_per_node
    }

    /// Alias of [`endpoints`](Topology::endpoints) for the classic
    /// machines-×-GPUs reading.
    pub fn total_gpus(&self) -> usize {
        self.endpoints()
    }

    /// Node a rank lives on.
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.ranks_per_node
    }

    /// Link class of the (a, b) endpoint pair.
    pub fn class_of(&self, a: usize, b: usize) -> LinkClass {
        if self.ranks_per_node > 1 && self.node_of(a) == self.node_of(b) {
            LinkClass::Intra
        } else {
            LinkClass::Inter
        }
    }

    /// Link preset of a class.
    pub fn link_of(&self, class: LinkClass) -> LinkKind {
        match class {
            LinkClass::Intra => self.intra,
            LinkClass::Inter => self.inter,
        }
    }

    /// Whether this behaves like the historical single-link model
    /// (every pair of endpoints crosses the inter link).
    pub fn is_flat(&self) -> bool {
        self.ranks_per_node <= 1
    }

    /// Time for the intra-machine dense reduce-scatter + all-gather over
    /// the intra link (ring over g ranks, `2(g-1)/g · bytes` each way) —
    /// the pre-aggregation phase of the flat simulation path.
    pub fn intra_machine_time(&self, dense_bytes: u64) -> f64 {
        let g = self.ranks_per_node;
        if g <= 1 {
            return 0.0;
        }
        let moved = 2.0 * (g as f64 - 1.0) / g as f64 * dense_bytes as f64;
        2.0 * (g as f64 - 1.0) * self.intra.latency() + moved * 8.0 / self.intra.bandwidth_bps()
    }

    /// Parse a CLI topology spec: `NxG` or `N×G`, optionally followed by
    /// per-class link parameters `:ia,ib/ea,eb` — intra then inter, each
    /// as `alpha_us,gbps`. Without the suffix the intra link defaults to
    /// NVLink and the inter link to `default_inter`.
    ///
    /// Examples: `4x2`, `4x2:2,300/50,25` (2 µs / 300 Gbps inside a
    /// node, 50 µs / 25 Gbps between nodes).
    pub fn parse(spec: &str, default_inter: LinkKind) -> Result<Topology, String> {
        let (shape, links) = match spec.split_once(':') {
            Some((s, l)) => (s, Some(l)),
            None => (spec, None),
        };
        let (n, g) = shape
            .split_once(['x', 'X', '×'])
            .ok_or_else(|| format!("topology '{spec}': want NxG, e.g. 4x2"))?;
        let nodes: usize = n
            .trim()
            .parse()
            .map_err(|_| format!("topology '{spec}': bad node count '{n}'"))?;
        let ranks: usize = g
            .trim()
            .parse()
            .map_err(|_| format!("topology '{spec}': bad ranks-per-node '{g}'"))?;
        if nodes == 0 || ranks == 0 {
            return Err(format!("topology '{spec}': counts must be >= 1"));
        }
        let (intra, inter) = match links {
            None => (LinkKind::NvLink, default_inter),
            Some(l) => {
                let (a, b) = l.split_once('/').ok_or_else(|| {
                    format!("topology '{spec}': link suffix wants intra/inter, e.g. 2,300/50,25")
                })?;
                (parse_link(a, spec)?, parse_link(b, spec)?)
            }
        };
        Ok(Topology::two_level(nodes, ranks, intra, inter))
    }

    /// Human-readable shape + link summary for logs.
    pub fn describe(&self) -> String {
        let link = |l: LinkKind| {
            format!(
                "{:.0}us/{:.0}Gbps",
                l.latency() * 1e6,
                l.bandwidth_bps() / 1e9
            )
        };
        format!(
            "{}x{} (intra {}, inter {})",
            self.nodes,
            self.ranks_per_node,
            link(self.intra),
            link(self.inter)
        )
    }
}

/// Parse one `alpha_us,gbps` pair into a custom link.
fn parse_link(pair: &str, spec: &str) -> Result<LinkKind, String> {
    let (alpha, gbps) = pair
        .split_once(',')
        .ok_or_else(|| format!("topology '{spec}': link wants alpha_us,gbps, got '{pair}'"))?;
    let alpha_us: f64 = alpha
        .trim()
        .parse()
        .map_err(|_| format!("topology '{spec}': bad latency '{alpha}' (µs)"))?;
    let gbps: f64 = gbps
        .trim()
        .parse()
        .map_err(|_| format!("topology '{spec}': bad bandwidth '{gbps}' (Gbps)"))?;
    // `NaN` compares false against every bound and `inf` saturates the
    // `as u64` casts below to u64::MAX — both would silently build a
    // nonsense link, so finiteness is checked before the range.
    if !alpha_us.is_finite() || alpha_us < 0.0 {
        return Err(format!(
            "topology '{spec}': latency '{alpha}' must be a finite number of µs >= 0"
        ));
    }
    if !gbps.is_finite() || gbps <= 0.0 {
        return Err(format!(
            "topology '{spec}': bandwidth '{gbps}' must be a finite number of Gbps > 0"
        ));
    }
    let bps = (gbps * 1e9) as u64;
    // Validate the *converted* value: a sub-1-bps spec would truncate
    // to 0 and turn every α–β time into +inf instead of an error.
    if bps == 0 {
        return Err(format!(
            "topology '{spec}': bandwidth must come to at least 1 bps"
        ));
    }
    Ok(LinkKind::Custom(bps, (alpha_us * 1e3) as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_and_classes() {
        let t = Topology::two_level(4, 2, LinkKind::NvLink, LinkKind::Tcp25);
        assert_eq!(t.endpoints(), 8);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(5), 2);
        assert_eq!(t.class_of(0, 1), LinkClass::Intra);
        assert_eq!(t.class_of(1, 2), LinkClass::Inter);
        assert_eq!(t.class_of(6, 7), LinkClass::Intra);
        assert_eq!(t.link_of(LinkClass::Intra), LinkKind::NvLink);
        assert_eq!(t.link_of(LinkClass::Inter), LinkKind::Tcp25);
        assert!(!t.is_flat());
    }

    #[test]
    fn flat_topology_is_all_inter() {
        let t = Topology::flat(4, LinkKind::Tcp25);
        assert!(t.is_flat());
        for a in 0..4 {
            for b in 0..4 {
                if a != b {
                    assert_eq!(t.class_of(a, b), LinkClass::Inter, "{a}-{b}");
                }
            }
        }
    }

    #[test]
    fn parse_shapes_and_links() {
        let t = Topology::parse("4x2", LinkKind::Tcp25).unwrap();
        assert_eq!((t.nodes, t.ranks_per_node), (4, 2));
        assert_eq!(t.intra, LinkKind::NvLink);
        assert_eq!(t.inter, LinkKind::Tcp25);

        let t = Topology::parse("2×8:2,300/50,25", LinkKind::Rdma100).unwrap();
        assert_eq!((t.nodes, t.ranks_per_node), (2, 8));
        assert_eq!(t.intra, LinkKind::Custom(300_000_000_000, 2_000));
        assert_eq!(t.inter, LinkKind::Custom(25_000_000_000, 50_000));
        assert!((t.intra.latency() - 2e-6).abs() < 1e-12);
        assert!((t.inter.bandwidth_bps() - 25e9).abs() < 1.0);

        for bad in [
            "4",
            "0x2",
            "4x0",
            "4x2:1,2",
            "4x2:a,b/c,d",
            "4x2:1,-2/3,4",
            // sub-1-bps bandwidth would truncate to Custom(0, _)
            "4x2:1,1e-10/3,4",
        ] {
            assert!(Topology::parse(bad, LinkKind::Tcp25).is_err(), "{bad}");
        }
    }

    #[test]
    fn parse_rejections_name_the_offending_field() {
        let msg = |spec: &str| Topology::parse(spec, LinkKind::Tcp25).unwrap_err();
        assert!(msg("4").contains("want NxG"), "{}", msg("4"));
        assert!(msg("ax2").contains("bad node count"), "{}", msg("ax2"));
        assert!(
            msg("4xb").contains("bad ranks-per-node"),
            "{}",
            msg("4xb")
        );
        assert!(msg("0x2").contains("counts must be >= 1"), "{}", msg("0x2"));
        assert!(msg("4x0").contains("counts must be >= 1"), "{}", msg("4x0"));
        assert!(
            msg("4x2:1,2").contains("intra/inter"),
            "{}",
            msg("4x2:1,2")
        );
        assert!(
            msg("4x2:1/3,4").contains("alpha_us,gbps"),
            "{}",
            msg("4x2:1/3,4")
        );
        assert!(
            msg("4x2:a,300/50,25").contains("bad latency"),
            "{}",
            msg("4x2:a,300/50,25")
        );
        assert!(
            msg("4x2:1,b/50,25").contains("bad bandwidth"),
            "{}",
            msg("4x2:1,b/50,25")
        );
        // NaN slips past plain `< 0.0` range checks; inf saturates the
        // u64 cast — both must be rejected with the finiteness message.
        assert!(
            msg("4x2:NaN,300/50,25").contains("finite number of µs"),
            "{}",
            msg("4x2:NaN,300/50,25")
        );
        assert!(
            msg("4x2:inf,300/50,25").contains("finite number of µs"),
            "{}",
            msg("4x2:inf,300/50,25")
        );
        assert!(
            msg("4x2:1,inf/50,25").contains("finite number of Gbps"),
            "{}",
            msg("4x2:1,inf/50,25")
        );
        assert!(
            msg("4x2:1,NaN/50,25").contains("finite number of Gbps"),
            "{}",
            msg("4x2:1,NaN/50,25")
        );
        assert!(
            msg("4x2:1,-2/50,25").contains("Gbps > 0"),
            "{}",
            msg("4x2:1,-2/50,25")
        );
        assert!(
            msg("4x2:1,1e-10/50,25").contains("at least 1 bps"),
            "{}",
            msg("4x2:1,1e-10/50,25")
        );
    }

    #[test]
    fn describe_mentions_shape() {
        let t = Topology::parse("4x2", LinkKind::Tcp25).unwrap();
        assert!(t.describe().starts_with("4x2"));
    }

    #[test]
    fn intra_machine_scales_with_gpus() {
        let t8 = Topology::testbed_tcp(4).intra_machine_time(1 << 30);
        let mut t1 = Topology::testbed_tcp(4);
        t1.ranks_per_node = 1;
        assert_eq!(t1.intra_machine_time(1 << 30), 0.0);
        assert!(t8 > 0.0);
    }
}
