//! Simulated GPU cluster: topology + virtual-time network model.
//!
//! The paper's testbeds are 16 machines × 8 GPUs on 25 Gbps TCP or
//! 100 Gbps RDMA, with NVLink inside a machine. We reproduce the
//! *communication structure* exactly — every scheme really moves the
//! bytes it claims between in-process endpoints — and charge time with
//! the standard synchronous α–β model that the paper's own Appendix B
//! analysis uses:
//!
//! `stage_time = α + max_endpoint(max(bytes_sent, bytes_recv)) · 8 / B`
//!
//! Full-duplex NICs, receiver/sender bottleneck at the busiest endpoint —
//! which is precisely what makes imbalanced schemes slow (Lemma 4) and
//! balanced ones fast.
//!
//! GPUs inside a machine first reduce-scatter/all-gather dense shards
//! over NVLink (§4.1 of the paper); `intra_machine_time` charges that
//! phase, and the inter-machine schemes then operate on per-machine
//! tensors (whose density reflects intra-machine densification).

pub mod report;

pub use report::{CommReport, StageReport, Timeline, TimelineEntry, TimelineJob};

/// Link presets matching the paper's two testbeds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LinkKind {
    /// 25 Gbps Ethernet, TCP/IP (testbed 1).
    Tcp25,
    /// 100 Gbps, RDMA (testbed 2).
    Rdma100,
    /// NVLink (V100-gen: ~150 GB/s per direction aggregate).
    NvLink,
    /// Custom bits/s + latency.
    Custom(u64, u64),
}

impl LinkKind {
    /// Bandwidth in bits per second.
    pub fn bandwidth_bps(&self) -> f64 {
        match self {
            LinkKind::Tcp25 => 25e9,
            LinkKind::Rdma100 => 100e9,
            LinkKind::NvLink => 150e9 * 8.0,
            LinkKind::Custom(bps, _) => *bps as f64,
        }
    }

    /// Per-stage latency α in seconds (TCP pays kernel/stack overhead;
    /// RDMA and NVLink are in the microsecond regime).
    pub fn latency(&self) -> f64 {
        match self {
            LinkKind::Tcp25 => 50e-6,
            LinkKind::Rdma100 => 5e-6,
            LinkKind::NvLink => 2e-6,
            LinkKind::Custom(_, ns) => *ns as f64 * 1e-9,
        }
    }
}

/// Cluster shape: `machines` endpoints on the inter-machine fabric, each
/// with `gpus_per_machine` GPUs joined by NVLink.
#[derive(Clone, Debug)]
pub struct Topology {
    pub machines: usize,
    pub gpus_per_machine: usize,
    pub inter: LinkKind,
    pub intra: LinkKind,
}

impl Topology {
    pub fn new(machines: usize, gpus_per_machine: usize, inter: LinkKind) -> Self {
        Topology {
            machines,
            gpus_per_machine,
            inter,
            intra: LinkKind::NvLink,
        }
    }

    /// Paper testbed 1: m machines × 8 V100, 25 Gbps TCP.
    pub fn testbed_tcp(machines: usize) -> Self {
        Self::new(machines, 8, LinkKind::Tcp25)
    }

    /// Paper testbed 2: m machines × 8 A100, 100 Gbps RDMA.
    pub fn testbed_rdma(machines: usize) -> Self {
        Self::new(machines, 8, LinkKind::Rdma100)
    }

    pub fn total_gpus(&self) -> usize {
        self.machines * self.gpus_per_machine
    }

    /// Time for the intra-machine dense reduce-scatter + all-gather over
    /// NVLink (ring over g GPUs, `2(g-1)/g · bytes` each way).
    pub fn intra_machine_time(&self, dense_bytes: u64) -> f64 {
        let g = self.gpus_per_machine;
        if g <= 1 {
            return 0.0;
        }
        let moved = 2.0 * (g as f64 - 1.0) / g as f64 * dense_bytes as f64;
        2.0 * (g as f64 - 1.0) * self.intra.latency() + moved * 8.0 / self.intra.bandwidth_bps()
    }
}

/// The inter-machine network: charges virtual time per synchronous stage.
#[derive(Clone, Debug)]
pub struct Network {
    pub link: LinkKind,
    pub endpoints: usize,
}

impl Network {
    pub fn new(endpoints: usize, link: LinkKind) -> Self {
        assert!(endpoints >= 1);
        Network { endpoints, link }
    }

    /// Time for one synchronous stage given per-endpoint sent/recv bytes.
    pub fn stage_time(&self, sent: &[u64], recv: &[u64]) -> f64 {
        assert_eq!(sent.len(), self.endpoints);
        assert_eq!(recv.len(), self.endpoints);
        let busiest = sent
            .iter()
            .zip(recv.iter())
            .map(|(&s, &r)| s.max(r))
            .max()
            .unwrap_or(0);
        if busiest == 0 {
            return 0.0;
        }
        self.link.latency() + busiest as f64 * 8.0 / self.link.bandwidth_bps()
    }

    /// Build a stage report from a per-(src,dst) byte matrix
    /// (`bytes[src][dst]`, diagonal ignored — local moves are free).
    pub fn stage_from_matrix(&self, name: &str, bytes: &[Vec<u64>]) -> StageReport {
        assert_eq!(bytes.len(), self.endpoints);
        let mut sent = vec![0u64; self.endpoints];
        let mut recv = vec![0u64; self.endpoints];
        for (src, row) in bytes.iter().enumerate() {
            assert_eq!(row.len(), self.endpoints);
            for (dst, &b) in row.iter().enumerate() {
                if src != dst {
                    sent[src] += b;
                    recv[dst] += b;
                }
            }
        }
        let time = self.stage_time(&sent, &recv);
        StageReport {
            name: name.to_string(),
            sent,
            recv,
            time,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_sane() {
        assert_eq!(LinkKind::Tcp25.bandwidth_bps(), 25e9);
        assert_eq!(LinkKind::Rdma100.bandwidth_bps(), 100e9);
        assert!(LinkKind::NvLink.bandwidth_bps() > LinkKind::Rdma100.bandwidth_bps());
        assert!(LinkKind::Tcp25.latency() > LinkKind::Rdma100.latency());
    }

    #[test]
    fn stage_time_bottleneck_endpoint() {
        let net = Network::new(3, LinkKind::Custom(8_000_000_000, 0)); // 1 GB/s
        // endpoint 1 receives 2 GB → 2 s
        let t = net.stage_time(&[0, 0, 0], &[0, 2_000_000_000, 0]);
        assert!((t - 2.0).abs() < 1e-9);
        // balanced: 3 endpoints each receive 1 GB → 1 s (3× better than
        // one endpoint receiving 3 GB — the Lemma 4 effect)
        let bal = net.stage_time(&[0, 0, 0], &[1_000_000_000; 3]);
        let imb = net.stage_time(&[0, 0, 0], &[3_000_000_000, 0, 0]);
        assert!((imb / bal - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_stage_free() {
        let net = Network::new(2, LinkKind::Tcp25);
        assert_eq!(net.stage_time(&[0, 0], &[0, 0]), 0.0);
    }

    #[test]
    fn matrix_accounting() {
        let net = Network::new(3, LinkKind::Custom(8, 0)); // 1 B/s
        let m = vec![
            vec![0, 10, 20], // node 0 sends 30
            vec![5, 0, 0],
            vec![0, 0, 7], // diagonal ignored
        ];
        let st = net.stage_from_matrix("x", &m);
        assert_eq!(st.sent, vec![30, 5, 0]);
        assert_eq!(st.recv, vec![5, 10, 20]);
        assert!((st.time - 30.0).abs() < 1e-9);
    }

    #[test]
    fn intra_machine_scales_with_gpus() {
        let t8 = Topology::testbed_tcp(4).intra_machine_time(1 << 30);
        let mut t1 = Topology::testbed_tcp(4);
        t1.gpus_per_machine = 1;
        assert_eq!(t1.intra_machine_time(1 << 30), 0.0);
        assert!(t8 > 0.0);
    }
}
